"""Dry-run machinery regression: one LM cell + one graph cell lower+compile
on the production meshes (512 fake devices, subprocess), and the HLO walker's
loop-aware FLOP accounting matches an analytic count."""
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import jax
from repro.launch.dryrun import lower_cell
from repro.launch.dryrun_graph import lower_graph_cell
from repro.launch.mesh import make_production_mesh
from repro.launch import hlo_walk

mesh = make_production_mesh(multi_pod=True)
lowered, compiled = lower_cell("olmo_1b", "decode_32k", mesh)
w = hlo_walk.analyze(compiled.as_text())
assert w["dot_flops_per_device"] > 0
meta, n_parts, compiled_g = lower_graph_cell("kron26", "cc", True)
assert n_parts == 32
wg = hlo_walk.analyze(compiled_g.as_text())
assert wg["collective_bytes_per_device"] > 0
print("DRYRUN_OK")
"""

WALKER_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import jax, jax.numpy as jnp
from repro.configs import get_smoke_config
from repro.models import model as M
from repro.sharding import rules as R
from repro.training import steps as S
from repro.launch import hlo_walk

cfg = get_smoke_config("olmo_1b")
from repro.compat import make_mesh, set_mesh
mesh = make_mesh((4, 4), ("data", "model"))
p_shapes = jax.eval_shape(lambda k: M.init_model(k, cfg),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))
p_shard = R.param_shardings(mesh, M.model_specs(cfg), p_shapes)
params_in = jax.tree.map(lambda sh, sd: jax.ShapeDtypeStruct(
    sd.shape, sd.dtype, sharding=sh), p_shard, p_shapes)
batch_in = {k: jax.ShapeDtypeStruct(
    (8, 64), jnp.int32,
    sharding=jax.NamedSharding(mesh, jax.sharding.PartitionSpec("data", None)))
    for k in ("tokens", "labels")}


def fwd(params, batch):
    return S.loss_fn(params, batch, cfg)[0]


with set_mesh(mesh):
    compiled = jax.jit(fwd).lower(params_in, batch_in).compile()
w = hlo_walk.analyze(compiled.as_text())
B, S_, d, ff, V, L = 8, 64, 64, 256, 128, 2
per_layer = 2*B*S_*d*(4*d) + 2*B*S_*d*(3*ff) + 2*2*B*S_*S_*d
total = L * per_layer + 2*B*S_*d*V
got = w["dot_flops_per_device"] * 16
assert abs(got - total) / total < 0.02, (got, total)
print("WALKER_OK")
"""


def test_dryrun_cells_compile():
    res = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, timeout=1200)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "DRYRUN_OK" in res.stdout


def test_hlo_walker_matches_analytic_flops():
    res = subprocess.run([sys.executable, "-c", WALKER_SCRIPT],
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "WALKER_OK" in res.stdout
