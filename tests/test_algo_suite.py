"""The algorithm-suite differential tests — every registered algorithm
(tests/harness.py ALGOS) through every harness check family:

  oracle parity on drawn power-law graphs and the pathological zoo,
  cross-edge-backend equivalence, fresh-vs-incremental parity over
  randomized delta schedules, sim-vs-shard_map parity (subprocess), and
  the loud-failure gate for custom sweeps that never declared their
  supported edge backends.
"""
import dataclasses
import os
import subprocess
import sys

import numpy as np
import pytest
from _hypcompat import given, settings, st

import harness
from harness import (ALGOS, AlgoCase, case_by_name, canonicalize,
                     check_backend_equivalence, check_fresh_vs_incremental,
                     check_oracle, harness_powerlaw, pathological_graphs)
from repro.algos import SSSP, LabelPropagation, brandes_betweenness
from repro.core import EngineConfig, partition_and_build, resolve_edge_backend, run_sim
from repro.core.api import VertexProgram
from repro.graphgen import powerlaw_graph

CASE_NAMES = [c.name for c in ALGOS]
MONOTONE = ["bfs", "msbfs", "lp", "kcore2"]
ZOO = pathological_graphs()


# --------------------------------------------------------------------------- #
# oracle parity: power-law draws + the pathological zoo
# --------------------------------------------------------------------------- #
@settings(max_examples=harness.MAX_EXAMPLES)
@given(st.integers(0, 10_000))
def test_oracle_powerlaw(seed):
    g = harness_powerlaw(160, seed)
    for case in ALGOS:
        check_oracle(case, g)


@pytest.mark.parametrize("zoo", [z[0] for z in ZOO])
@pytest.mark.parametrize("name", CASE_NAMES)
def test_oracle_zoo(name, zoo):
    g = dict(ZOO)[zoo]
    check_oracle(case_by_name(name), g, n_parts=2)


@pytest.mark.parametrize("name", ["bfs", "lp", "kcore2", "triangles"])
def test_oracle_vc_mode(name):
    """Vertex-centric mode (no local fixpoint) reaches the same answers."""
    check_oracle(case_by_name(name), harness_powerlaw(160, 7), mode="vc")


@pytest.mark.parametrize("part", ["rh-vc", "rh-ec"])
@pytest.mark.parametrize("name", ["bfs", "kcore2", "triangles"])
def test_oracle_other_partitioners(name, part):
    check_oracle(case_by_name(name), harness_powerlaw(160, 11), part=part)


# --------------------------------------------------------------------------- #
# edge-backend equivalence
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("name", CASE_NAMES)
def test_backend_equivalence(name):
    check_backend_equivalence(case_by_name(name), harness_powerlaw(160, 3))


# --------------------------------------------------------------------------- #
# fresh vs incremental over randomized delta schedules
# --------------------------------------------------------------------------- #
@settings(max_examples=1 if harness.FAST else 2)
@given(st.integers(0, 10_000))
def test_fresh_vs_incremental(seed):
    g = harness_powerlaw(160, 2)
    for name in MONOTONE:
        check_fresh_vs_incremental(case_by_name(name), g, seed=seed,
                                   n_chunks=2 if harness.FAST else 3)


def test_kcore_incremental_is_delete_polarity():
    assert case_by_name("kcore2").make(harness_powerlaw(60, 0))[0] \
        .warm_under == "deletes"
    assert case_by_name("bfs").make(harness_powerlaw(60, 0))[0] \
        .warm_under == "inserts"


# --------------------------------------------------------------------------- #
# betweenness end-to-end: three staged programs -> centrality scores
# --------------------------------------------------------------------------- #
def test_betweenness_end_to_end():
    g = harness_powerlaw(120, 5)
    pg = partition_and_build(g, 4, "cdbh")
    cfg = EngineConfig(mode="sc")

    def query(prog, params):
        res, _ = run_sim(prog, pg, params, cfg)
        fill = np.inf if prog.combiner == "min" else 0.0
        return pg.collect(res, fill=fill)

    pv = harness._pivots(g)
    out = brandes_betweenness(query, pv)
    lev_e, sig_e, dl_e = harness.brandes_oracle(g, pv)
    np.testing.assert_array_equal(out["levels"], lev_e)
    np.testing.assert_allclose(out["sigma"], sig_e, rtol=1e-5)
    np.testing.assert_allclose(out["delta"], dl_e, rtol=1e-4, atol=1e-4)
    not_pivot = np.arange(g.n_vertices)[:, None] != np.asarray(pv)[None, :]
    np.testing.assert_allclose(out["bc"], (dl_e * not_pivot).sum(1) / 2.0,
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------- #
# custom sweeps must declare their edge backends — satellite gate
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class _UnregisteredSweep(VertexProgram):
    """Overrides sweep() but never declares supports_edge_backends."""

    def sweep(self, sg, params, state, ec):
        return state, np.int32(0)


@dataclasses.dataclass
class _BogusBackends(VertexProgram):
    supports_edge_backends = ("coo", "pallas_ultra")

    def sweep(self, sg, params, state, ec):
        return state, np.int32(0)


def test_unregistered_custom_sweep_fails_loudly():
    with pytest.raises(ValueError, match="supports_edge_backends"):
        resolve_edge_backend(_UnregisteredSweep(), EngineConfig())


def test_unknown_declared_backend_fails_loudly():
    with pytest.raises(ValueError, match="pallas_ultra"):
        resolve_edge_backend(_BogusBackends(), EngineConfig())


def test_declared_backend_fallback():
    # LP declares ('coo',): a pallas request resolves there, never crashes
    prog = LabelPropagation(hops=3)
    cfg = EngineConfig(edge_backend="pallas_windows")
    assert resolve_edge_backend(prog, cfg) == "coo"
    # declarative programs still honour the request
    assert resolve_edge_backend(SSSP(), cfg) == "pallas_windows"


# --------------------------------------------------------------------------- #
# sim vs shard_map parity (fake host devices need a fresh process)
# --------------------------------------------------------------------------- #
SHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import numpy as np
from jax.sharding import Mesh

import harness
from repro.core import EngineConfig, run_shard_map, run_sim

g = harness.harness_powerlaw(160, 3)
pg = harness.build(g, 4, "cdbh")
mesh = Mesh(np.array(jax.devices()[:4]).reshape(4), ("sub",))
for name in ("bfs", "lp", "kcore2", "triangles"):
    case = harness.case_by_name(name)
    prog, params = case.make(g)
    sim, _ = run_sim(prog, pg, params, EngineConfig(mode="sc"))
    res, st = run_shard_map(prog, pg, mesh, params,
                            EngineConfig(backend="shard_map",
                                         subgraph_axes=("sub",), mode="sc"))
    a = pg.collect(sim, fill=case.fill)
    b = pg.collect(np.asarray(res), fill=case.fill)
    assert case.compare(a, b), f"{name}: shard_map != sim"
print("ALGO_SHARD_OK")
"""


def test_shard_map_parity():
    here = os.path.dirname(os.path.abspath(__file__))
    src = os.path.join(os.path.dirname(here), "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [src, here] + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    res = subprocess.run([sys.executable, "-c", SHARD_SCRIPT],
                         capture_output=True, text=True, timeout=1200,
                         env=env)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "ALGO_SHARD_OK" in res.stdout
