"""GraphSession serving API: compiled-runner caching (zero retraces on
repeat + shape-preserving updates, exactly one rebuild on capacity growth),
auto warm starts, the folded streaming lifecycle, legacy-wrapper parity, and
the EngineConfig / combiner_identity construction-time validation."""
import subprocess
import sys

import numpy as np
import pytest

from repro.algos import ConnectedComponents, PageRank, SSSP
from repro.analysis.sanitizer import retrace_guard
from repro.core import EngineConfig, ShapePolicy, partition_and_build, run_sim
from repro.core.api import combiner_identity
from repro.graphgen import powerlaw_graph
from repro.session import GraphSession
from repro.stream import write_edge_log


@pytest.fixture(scope="module")
def graph():
    return powerlaw_graph(2000, seed=2, weighted=True).as_undirected()


@pytest.fixture()
def session(graph):
    return GraphSession.from_graph(graph, 5, "cdbh")


def _grow_insert(g, pg, n=40, seed=8):
    """Insert-only batch attaching brand-new vertices: guarantees capacity
    growth (new membership rows + new edges) while staying warm-safe."""
    new = np.arange(pg.n_vertices, pg.n_vertices + n, dtype=np.int64)
    zeros = np.zeros(n, np.int64)
    return (np.concatenate([zeros, new]), np.concatenate([new, zeros]),
            np.full(2 * n, 9.0, np.float32))


# --------------------------------------------------------------------------- #
# compilation caching (satellite: trace-counter regression tests)
# --------------------------------------------------------------------------- #
def test_second_identical_query_zero_traces(session):
    r1, s1 = session.query(SSSP(), {"source": 0})
    assert s1.compile_time > 0.0              # cold query paid the compile
    with retrace_guard(label="second identical query"):
        r2, s2 = session.query(SSSP(), {"source": 0})
    assert s2.compile_time == 0.0             # billed zero on a cache hit
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
    assert session.stats.cache_misses == 1 and session.stats.cache_hits == 1


def test_shape_preserving_update_zero_traces(session):
    """A flush that grows no padded dim (parallel copy of a resident edge in
    an under-capacity partition) must reuse the compiled runner."""
    session.query(SSSP(), {"source": 0})
    pg = session.pg
    p = int(np.argmin(pg.edges_per_part))
    assert pg.edges_per_part[p] < pg.e_max, "need slack for this test"
    m = pg.emask[p]
    gs = int(pg.gvid[p][pg.esrc[p][m]][0])
    gd = int(pg.gvid[p][pg.edst[p][m]][0])
    shape_before = session.shape_key
    session.update(adds=([gs], [gd], [50.0]))
    st = session.flush()
    assert not st.repadded and session.shape_key == shape_before
    with retrace_guard(label="shape-preserving update"):
        r, s = session.query(SSSP(), {"source": 0})
    assert s.compile_time == 0.0
    # ...and the device pytree was re-uploaded (the graph did change)
    assert session.stats.uploads == 2
    cold, _ = session.query(SSSP(), {"source": 0}, warm=False)
    np.testing.assert_array_equal(np.asarray(r), np.asarray(cold))


def test_capacity_growing_update_compiles_exactly_once(graph):
    # exact policy: this test probes the growth/evict/rebuild mechanics, so
    # capacity must overflow on a small insert (buckets would absorb it)
    session = GraphSession.from_graph(graph, 5, "cdbh",
                                      shape_policy=ShapePolicy.exact())
    session.query(SSSP(), {"source": 0})
    session.update(adds=_grow_insert(graph, session.pg))
    st = session.flush()
    assert st.repadded, "delta was supposed to grow the padded shapes"
    assert not session._runners, "stale-shape runners must be evicted"
    misses = session.stats.cache_misses
    _, s = session.query(SSSP(), {"source": 0})
    assert session.stats.cache_misses == misses + 1, \
        "capacity growth must rebuild the runner exactly once"
    assert s.compile_time > 0.0
    with retrace_guard(label="second post-growth query"):
        session.query(SSSP(), {"source": 0})


def test_param_values_share_one_runner(session):
    """Params are traced inputs: SSSP from any source reuses the compiled
    executable (the serving pattern the cache exists for)."""
    session.query(SSSP(), {"source": 0})
    with retrace_guard(label="per-source queries"):
        for src in (3, 11, 42):
            session.query(SSSP(), {"source": src})
    assert session.stats.cache_misses == 1 and session.stats.cache_hits == 3


def test_multi_algorithm_cache_entries(graph, session):
    session.query(SSSP(), {"source": 0})
    session.query(ConnectedComponents())
    session.query(PageRank(tol=1e-9), {"n_vertices": graph.n_vertices})
    assert session.stats.cache_misses == 3
    with retrace_guard(label="repeat algorithm queries"):
        session.query(SSSP(), {"source": 1})
        session.query(ConnectedComponents())
        session.query(PageRank(tol=1e-9), {"n_vertices": graph.n_vertices})
    assert session.stats.cache_misses == 3
    # a different EngineConfig is a different runner
    session.query(ConnectedComponents(), cfg=EngineConfig(mode="vc"))
    assert session.stats.cache_misses == 4


# --------------------------------------------------------------------------- #
# query semantics: parity with the low-level layer, warm starts
# --------------------------------------------------------------------------- #
def test_query_matches_run_sim(graph):
    # exact policy: bit-identical [P, v_max, K] layout + byte-accounting
    # parity with the low-level one-shot layer (buckets pad differently)
    session = GraphSession.from_graph(graph, 5, "cdbh",
                                      shape_policy=ShapePolicy.exact())
    pg = partition_and_build(graph, 5, "cdbh")
    for prog, params in ((SSSP(), {"source": 7}), (ConnectedComponents(),
                                                   None)):
        r_sess, s_sess = session.query(prog, params, warm=False)
        r_ref, s_ref = run_sim(prog, pg, params, EngineConfig())
        np.testing.assert_array_equal(np.asarray(r_sess), np.asarray(r_ref))
        assert s_sess.supersteps == s_ref.supersteps
        assert s_sess.total_messages == s_ref.total_messages
        assert s_sess.total_bytes == s_ref.total_bytes
    r_pr, _ = session.query(PageRank(tol=1e-9),
                            {"n_vertices": graph.n_vertices})
    r_ref, _ = run_sim(PageRank(tol=1e-9), pg,
                       {"n_vertices": graph.n_vertices}, EngineConfig())
    np.testing.assert_array_equal(np.asarray(r_pr), np.asarray(r_ref))


def test_warm_auto_after_insert_matches_cold(graph, session):
    session.query(SSSP(), {"source": 0})
    rng = np.random.default_rng(3)
    n = graph.n_edges // 200
    s = rng.integers(0, graph.n_vertices, n)
    d = rng.integers(0, graph.n_vertices, n)
    keep = s != d
    s, d = s[keep], d[keep]
    w = rng.uniform(5, 10, s.size).astype(np.float32)
    session.update(adds=(np.concatenate([s, d]), np.concatenate([d, s]),
                         np.concatenate([w, w])))
    session.flush()
    warm, st_w = session.query(SSSP(), {"source": 0})          # warm="auto"
    cold, st_c = session.query(SSSP(), {"source": 0}, warm=False)
    np.testing.assert_array_equal(np.asarray(warm), np.asarray(cold))
    assert st_w.supersteps < st_c.supersteps, \
        (st_w.supersteps, st_c.supersteps)
    assert session.stats.warm_queries >= 1


def test_warm_is_per_params(session):
    """Source-0 distances must never seed a source-7 query."""
    session.query(SSSP(), {"source": 0})
    r7, s7 = session.query(SSSP(), {"source": 7})    # no warm entry for 7
    ref, _ = session.query(SSSP(), {"source": 7}, warm=False)
    np.testing.assert_array_equal(np.asarray(r7), np.asarray(ref))


def test_warm_true_raises_without_entry(session):
    with pytest.raises(ValueError, match="not monotone"):
        session.query(PageRank(), {"n_vertices": 10}, warm=True)
    with pytest.raises(ValueError, match="no previous converged result"):
        session.query(SSSP(), {"source": 0}, warm=True)
    session.query(SSSP(), {"source": 0})
    session.query(SSSP(), {"source": 0}, warm=True)  # now fine


def test_deletes_invalidate_warm(graph, session):
    session.query(SSSP(), {"source": 0})
    session.update(deletes=(graph.src[:50], graph.dst[:50]))
    session.flush()
    with pytest.raises(ValueError, match="no previous converged result"):
        session.query(SSSP(), {"source": 0}, warm=True)
    # auto falls back cold and matches a from-scratch reference
    r, _ = session.query(SSSP(), {"source": 0})
    ref_sess = GraphSession(session.pg)
    ref, _ = ref_sess.query(SSSP(), {"source": 0})
    np.testing.assert_array_equal(np.asarray(r), np.asarray(ref))


def test_query_flushes_pending_updates(graph, session):
    """A query must see every mutation accepted by update()."""
    r0, _ = session.query(ConnectedComponents())
    new = session.pg.n_vertices
    session.update(adds=([0, new], [new, 0]))
    assert len(session.buffer) == 2
    r1, _ = session.query(ConnectedComponents())
    assert len(session.buffer) == 0 and session.stats.flushes == 1
    lab = session.pg.collect(r1, fill=-1)
    assert lab[new] == lab[0], "buffered edge must be visible to the query"


def test_flush_after_auto_flush_returns_stats(graph):
    """A threshold auto-flush inside update() must not make the explicit
    flush() return None (regression: benchmarks dereferenced .n_added)."""
    sess = GraphSession.from_graph(graph, 5, "cdbh", max_buffer_edges=8)
    rng = np.random.default_rng(0)
    s = rng.integers(0, graph.n_vertices, 32).astype(np.int64)
    d = (s + 1) % graph.n_vertices
    sess.update(adds=(s, d))                 # trips the threshold in-flight
    assert sess.stats.flushes >= 1 and len(sess.buffer) == 0
    st = sess.flush()
    assert st is not None and st.n_added > 0
    assert sess.flush() is st                # idempotent: last applied patch


def test_compact_carries_warm_results(graph):
    # exact policy so the deletes are guaranteed to shrink the capacities
    # (a bucketed session may legitimately stay on the same bucket floor)
    sess = GraphSession.from_graph(graph, 5, "cdbh",
                                   shape_policy=ShapePolicy.exact())
    rng = np.random.default_rng(7)
    sel = rng.choice(graph.n_edges, size=graph.n_edges // 3, replace=False)
    sess.update(deletes=(np.concatenate([graph.src[sel], graph.dst[sel]]),
                         np.concatenate([graph.dst[sel], graph.src[sel]])))
    sess.flush()
    cold, _ = sess.query(SSSP(), {"source": 0})
    prev = sess.pg.collect(cold, fill=np.float32(np.inf))
    cs = sess.compact()
    assert cs.shrunk
    warm, st_w = sess.query(SSSP(), {"source": 0})
    np.testing.assert_array_equal(
        sess.pg.collect(warm, fill=np.float32(np.inf)), prev)
    assert st_w.supersteps <= 2, \
        "compaction changes layout, not the graph: warm is already converged"


def test_from_edge_log(graph, tmp_path):
    d = str(tmp_path / "log")
    write_edge_log(graph, d, chunk_size=8192)
    sess = GraphSession.from_edge_log(d, 5, "cdbh")
    assert sess.ingest_stats.n_edges == graph.n_edges
    mem = GraphSession.from_graph(graph, 5, "cdbh")
    r1, _ = sess.query(ConnectedComponents())
    r2, _ = mem.query(ConnectedComponents())
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))


def test_readonly_session_rejects_updates(graph):
    pg = partition_and_build(graph, 5, "cdbh")
    sess = GraphSession(pg)                      # no StreamContext
    sess.query(ConnectedComponents())            # queries are fine
    with pytest.raises(ValueError, match="StreamContext"):
        sess.update(adds=([0], [1]))
    with pytest.raises(ValueError, match="StreamContext"):
        sess.compact()


def test_trace_cfg_delegates_to_run_sim(graph, session):
    r, st = session.query(ConnectedComponents(),
                          cfg=EngineConfig(mode="vc", trace=True))
    assert st.messages_per_step, "trace mode keeps per-superstep stats"
    ref_pg = partition_and_build(graph, 5, "cdbh")
    ref, _ = run_sim(ConnectedComponents(), ref_pg, None,
                     EngineConfig(mode="vc"))
    # padded layouts differ (bucketed session vs exact one-shot build):
    # compare the collected global labels
    np.testing.assert_array_equal(session.pg.collect(np.asarray(r), fill=-1),
                                  ref_pg.collect(np.asarray(ref), fill=-1))


# --------------------------------------------------------------------------- #
# construction-time validation satellites
# --------------------------------------------------------------------------- #
def test_engineconfig_validates_at_construction():
    with pytest.raises(ValueError, match=r"mode.*'sc', 'vc'"):
        EngineConfig(mode="subgraph")
    with pytest.raises(ValueError, match=r"backend.*'sim', 'shard_map'"):
        EngineConfig(backend="gpu")
    with pytest.raises(ValueError, match="axis names"):
        EngineConfig(subgraph_axes="sub")        # bare string, not a tuple
    with pytest.raises(ValueError, match="max_supersteps"):
        EngineConfig(max_supersteps=0)
    with pytest.raises(ValueError, match="sparse_sync_capacity"):
        EngineConfig(sparse_sync_capacity=-1)
    # lists normalize to tuples so the config stays hashable (cache key)
    cfg = EngineConfig(subgraph_axes=["pod", "data"], edge_axes=[])
    assert cfg.subgraph_axes == ("pod", "data") and hash(cfg) is not None


def test_combiner_identity_error_names_pairs():
    with pytest.raises(ValueError, match=r"\('min', float32\)"):
        combiner_identity("min", np.float64)
    with pytest.raises(ValueError, match="supported"):
        combiner_identity("prod", np.float32)
    assert combiner_identity("min", np.float32) == np.float32(np.inf)


# --------------------------------------------------------------------------- #
# shard_map backend (subprocess: needs fake devices before jax init)
# --------------------------------------------------------------------------- #
SHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
from repro.analysis.sanitizer import retrace_guard
from repro.compat import make_mesh
from repro.session import GraphSession
from repro.core import EngineConfig
from repro.graphgen import powerlaw_graph
from repro.algos import SSSP, ConnectedComponents, PageRank

g = powerlaw_graph(400, seed=7, weighted=True).as_undirected()
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = EngineConfig(subgraph_axes=("pod", "data"), edge_axes=("model",))
sess = GraphSession.from_graph(g, 4, "cdbh", mesh=mesh, cfg=cfg)
sim = GraphSession.from_graph(g, 4, "cdbh")

# cross-backend parity + zero retraces on the second identical query
r1, s1 = sess.query(SSSP(), {"source": 0})
rs, ss = sim.query(SSSP(), {"source": 0})
assert (np.asarray(r1) == np.asarray(rs)).all(), "shard != sim"
assert s1.supersteps == ss.supersteps
with retrace_guard(label="second shard-backend query"):
    r2, s2 = sess.query(SSSP(), {"source": 0})
assert s2.compile_time == 0.0
assert (np.asarray(r1) == np.asarray(r2)).all(), "repeat not bit-identical"

# params are traced inputs on the shard backend too
with retrace_guard(label="new-source shard-backend query"):
    r3, _ = sess.query(SSSP(), {"source": 5})
r3s, _ = sim.query(SSSP(), {"source": 5}, warm=False)
assert (np.asarray(r3) == np.asarray(r3s)).all()

# non-monotone program parity
rp, _ = sess.query(PageRank(tol=1e-9), {"n_vertices": g.n_vertices})
rp2, _ = sim.query(PageRank(tol=1e-9), {"n_vertices": g.n_vertices})
assert np.allclose(np.asarray(rp), np.asarray(rp2), atol=1e-6)

# insert-only update: warm-auto == cold bit-for-bit, strictly fewer steps,
# superstep parity with the sim session
rng = np.random.default_rng(8)
n = 32
s = rng.integers(0, g.n_vertices, n); d = rng.integers(0, g.n_vertices, n)
keep = s != d; s, d = s[keep], d[keep]
w = rng.uniform(5, 10, s.size).astype(np.float32)
adds = (np.concatenate([s, d]), np.concatenate([d, s]),
        np.concatenate([w, w]))
for ss_ in (sess, sim):
    ss_.update(adds=adds)
    ss_.flush()
warm, st_w = sess.query(SSSP(), {"source": 0})
cold, st_c = sess.query(SSSP(), {"source": 0}, warm=False)
assert (np.asarray(warm) == np.asarray(cold)).all(), "warm != cold"
assert st_w.supersteps < st_c.supersteps, (st_w.supersteps, st_c.supersteps)
wsim, st_wsim = sim.query(SSSP(), {"source": 0})
assert (np.asarray(warm) == np.asarray(wsim)).all(), "shard warm != sim warm"
assert st_w.supersteps == st_wsim.supersteps
print("SESSION_SHARD_OK")
"""


def test_session_shard_map_backend():
    res = subprocess.run([sys.executable, "-c", SHARD_SCRIPT],
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stderr[-4000:]
    assert "SESSION_SHARD_OK" in res.stdout
