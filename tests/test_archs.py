"""Per-architecture smoke tests (deliverable f): reduced configs of the same
family — one forward + one train step on CPU, asserting shapes + no NaNs;
plus decode-path consistency (prefill + stepwise decode == full forward)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import model as M
from repro.models.config import SHAPES, shape_applicable
from repro.training import steps as S

LM_ARCHS = [a for a in ARCHS if a != "drone_graph"]


def _batch(cfg, key, B=2, S_len=16):
    toks = jax.random.randint(key, (B, S_len), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if cfg.frontend:
        batch["frontend"] = jax.random.normal(
            key, (B, cfg.frontend_len, cfg.frontend_dim)) * 0.02
    return batch


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = M.init_model(key, cfg)
    batch = _batch(cfg, key)
    logits, _ = M.forward(params, batch, cfg)
    S_out = 16 + (cfg.frontend_len if (cfg.frontend and not cfg.n_enc_layers)
                  else 0)
    assert logits.shape == (2, S_out, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_train_step_loss_finite_and_decreases(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(1)
    state = S.make_train_state(key, cfg)
    step = jax.jit(S.make_train_step(cfg, peak_lr=1e-3, warmup=2, total=50))
    batch = _batch(cfg, key, B=4, S_len=32)
    losses = []
    for i in range(5):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(losses)), losses
    assert losses[-1] < losses[0], losses  # same batch -> loss must drop


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(2)
    params = M.init_model(key, cfg)
    B, S_len = 2, 12
    batch = _batch(cfg, key, B, S_len)
    toks = batch["tokens"]
    logits_full, _ = M.forward(params, batch, cfg)
    off = cfg.frontend_len if (cfg.frontend and not cfg.n_enc_layers) else 0
    P = S_len - 3
    memory = M._encode(params, batch, cfg) if cfg.n_enc_layers else None
    lg, caches = M.prefill(params, dict(batch, tokens=toks[:, :P]), cfg,
                           max_len=S_len + 4 + off)
    errs = [float(jnp.abs(lg[:, -1] - logits_full[:, P - 1 + off]).max())]
    for t in range(P, S_len):
        db = {"tokens": toks[:, t:t + 1]}
        if memory is not None:
            db["memory"] = memory
        lg, caches = M.decode_step(params, caches, db, cfg)
        errs.append(float(jnp.abs(lg[:, 0] - logits_full[:, t + off]).max()))
    assert max(errs) < 5e-4, errs


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    spec = {
        "deepseek_v3_671b": (61, 7168, 128, 128, 129280),
        "phi35_moe_42b": (32, 4096, 32, 8, 32064),
        "olmo_1b": (16, 2048, 16, 16, 50304),
        "phi4_mini_3p8b": (32, 3072, 24, 8, 200064),
        "llama3_405b": (126, 16384, 128, 8, 128256),
        "stablelm_3b": (32, 2560, 32, 32, 50304),
        "internvl2_26b": (48, 6144, 48, 8, 92553),
        "seamless_m4t_large_v2": (24, 1024, 16, 16, 256206),
        "jamba_v01_52b": (32, 4096, 32, 8, 65536),
        "xlstm_350m": (24, 1024, 4, 4, 50304),
    }[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.vocab) == spec
    if arch == "deepseek_v3_671b":
        assert cfg.moe.n_experts == 256 and cfg.moe.top_k == 8
        assert cfg.moe.d_ff_expert == 2048 and cfg.moe.n_shared == 1
        assert cfg.mla is not None and cfg.mtp_depth == 1
    if arch in ("phi35_moe_42b", "jamba_v01_52b"):
        assert cfg.moe.n_experts == 16 and cfg.moe.top_k == 2
    if arch == "jamba_v01_52b":
        pat = cfg.layer_pattern()
        assert sum(s.mixer == "attn" for s in pat) * 7 == \
            sum(s.mixer == "mamba" for s in pat)
    if arch == "xlstm_350m":
        pat = cfg.layer_pattern()
        assert sum(s.mixer == "mlstm" for s in pat) == 21
        assert sum(s.mixer == "slstm" for s in pat) == 3
    if arch == "seamless_m4t_large_v2":
        assert cfg.n_enc_layers == 24


def test_long_500k_applicability():
    """long_500k runs only for sub-quadratic archs (DESIGN.md §6)."""
    runnable = {a for a in LM_ARCHS
                if shape_applicable(get_config(a), "long_500k")[0]}
    assert runnable == {"jamba_v01_52b", "xlstm_350m"}


def test_shape_cells_enumerate_40():
    assert len(LM_ARCHS) * len(SHAPES) == 40
