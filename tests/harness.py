"""Differential test harness for the algorithm suite.

One registration per algorithm (an ``AlgoCase`` appended to ``ALGOS``)
buys every check family the suite runs:

  - **oracle parity** — engine results vs a tiny numpy reference, over
    hypothesis-drawn power-law graphs plus the pathological zoo (stars,
    chains, multi-component graphs, self-loops, duplicate edges);
  - **backend equivalence** — identical results across
    ``edge_backend='coo' | 'pallas_tiles' | 'pallas_windows'`` (custom
    sweeps resolve to their declared backend — the check then pins that
    the resolution itself is equivalent, not silent divergence);
  - **fresh-vs-incremental parity** — a ``GraphSession`` streaming a
    randomized delta schedule of the program's ``warm_under`` polarity,
    asserting warm answers are bit-identical to cold recomputes and never
    take more supersteps;
  - **sim-vs-shard_map** — via ``run_case_shard`` inside the multi-device
    subprocess driven by tests/test_algo_suite.py.

Registering a new algorithm:

    ALGOS.append(AlgoCase(
        name="myalgo",
        make=lambda g: (MyProgram(), {}),          # program + params
        oracle=my_numpy_oracle,                    # Graph -> [n(,K)]
        fill=<collect fill for non-master rows>,
    ))

``make`` receives the *canonical* graph (simple + undirected unless
``canonical=False``) so K-payload programs can pick pivots from
``g.n_vertices``. Set ``exact=False`` for float sum-combined programs
whose cross-backend reductions legitimately reorder.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import EngineConfig, partition_and_build, run_sim
from repro.core.graph import Graph
from repro.graphgen import powerlaw_graph
from repro.session import GraphSession
from repro.algos import (BFS, KCore, LabelPropagation, SigmaCount,
                         BrandesAccum, make_msbfs, make_triangles)

_IMAX = 2**31 - 1

# DRONE_HARNESS_FAST=1 (the CI algo-suite job) caps the drawn-example and
# delta-chunk counts so the whole suite stays inside a smoke budget.
FAST = bool(os.environ.get("DRONE_HARNESS_FAST"))
MAX_EXAMPLES = 2 if FAST else 4


# --------------------------------------------------------------------- #
# registry
# --------------------------------------------------------------------- #
@dataclasses.dataclass
class AlgoCase:
    """One algorithm's registration with the differential harness."""
    name: str
    make: Callable[[Graph], Tuple[Any, Dict[str, Any]]]
    oracle: Callable[[Graph], np.ndarray]
    fill: Any
    exact: bool = True            # bit-identical vs allclose comparisons
    canonical: bool = True        # oracle semantics need simple+undirected

    def compare(self, got, want) -> bool:
        got, want = np.asarray(got), np.asarray(want)
        if self.exact:
            return bool(np.array_equal(got, want, equal_nan=True))
        return bool(np.allclose(got, want, rtol=1e-5, atol=1e-5))


def canonicalize(g: Graph) -> Graph:
    """Simple undirected form: no self-loops, no duplicates, both edge
    directions stored — the domain every suite oracle is defined on."""
    return g.drop_self_loops().dedup().as_undirected()


def build(g: Graph, n_parts: int = 4, part: str = "cdbh"):
    return partition_and_build(g, n_parts, part)


# --------------------------------------------------------------------- #
# graph generators: power-law + the pathological zoo
# --------------------------------------------------------------------- #
def harness_powerlaw(n: int, seed: int) -> Graph:
    return canonicalize(powerlaw_graph(n, seed=seed))


def pathological_graphs() -> List[Tuple[str, Graph]]:
    """Canonicalized adversarial shapes, each a historical engine bug
    class: hubs (star), deep diameter (chain), multiple components,
    self-loops and duplicate edges (must vanish in canonical form),
    and a dense clique (triangle-heavy)."""
    out: List[Tuple[str, Graph]] = []

    hub = np.zeros(19, np.int64)
    leaves = np.arange(1, 20, dtype=np.int64)
    out.append(("star", canonicalize(Graph(20, hub, leaves))))

    chain = np.arange(23, dtype=np.int64)
    out.append(("chain", canonicalize(Graph(24, chain, chain + 1))))

    s = np.concatenate([np.zeros(7, np.int64), np.full(7, 10, np.int64)])
    d = np.concatenate([np.arange(1, 8), np.arange(11, 18)]).astype(np.int64)
    out.append(("two_components", canonicalize(Graph(20, s, d))))

    s = np.array([0, 0, 1, 1, 1, 2, 3, 3, 4], np.int64)
    d = np.array([0, 1, 1, 2, 2, 3, 3, 0, 4], np.int64)
    out.append(("loops_and_dups", canonicalize(Graph(6, s, d))))

    k = 6
    s, d = np.meshgrid(np.arange(k, dtype=np.int64),
                       np.arange(k, dtype=np.int64))
    m = s.ravel() != d.ravel()
    out.append(("clique", canonicalize(Graph(k, s.ravel()[m], d.ravel()[m]))))
    return out


# --------------------------------------------------------------------- #
# numpy oracles
# --------------------------------------------------------------------- #
def bfs_levels_oracle(g: Graph, source: int = 0) -> np.ndarray:
    lvl = np.full(g.n_vertices, np.inf)
    if g.n_vertices:
        lvl[source] = 0.0
    for _ in range(g.n_vertices):
        new = lvl.copy()
        np.minimum.at(new, g.dst, lvl[g.src] + 1.0)
        if np.array_equal(new, lvl):
            break
        lvl = new
    return lvl.astype(np.float32)


def msbfs_oracle(g: Graph, sources) -> np.ndarray:
    return np.stack([bfs_levels_oracle(g, s) for s in sources], axis=1)


def lp_lanes_oracle(g: Graph, hops: int) -> np.ndarray:
    """[n, hops+1] — lane h is the smallest vertex id within h hops."""
    ids = np.arange(g.n_vertices, dtype=np.int32)
    lanes = [ids]
    for _ in range(hops):
        new = ids.copy()
        np.minimum.at(new, g.dst, lanes[-1][g.src])
        lanes.append(new)
    return np.stack(lanes, axis=1)


def kcore_peeled_oracle(g: Graph, k: int) -> np.ndarray:
    alive = np.ones(g.n_vertices, bool)
    while True:
        deg = np.zeros(g.n_vertices, np.int64)
        np.add.at(deg, g.src, alive[g.dst].astype(np.int64))
        kill = alive & (deg < k)
        if not kill.any():
            break
        alive &= ~kill
    return (~alive).astype(np.int32)


def triangles_oracle(g: Graph, pivots) -> np.ndarray:
    """Per-vertex [K] summands of diag(A^3): y_p * z_p."""
    n = g.n_vertices
    A = np.zeros((n, n), np.float32)
    A[g.src, g.dst] = 1.0
    cols = []
    for p in pivots:
        x = np.zeros(n, np.float32)
        x[p] = 1.0
        y = A.T @ x
        z = A.T @ y
        cols.append(y * z)
    return np.stack(cols, axis=1)


def brandes_oracle(g: Graph, pivots):
    """(levels, sigma, delta), each [n, K], by textbook Brandes."""
    from collections import deque
    n = g.n_vertices
    adj: List[List[int]] = [[] for _ in range(n)]
    for s, d in zip(g.src.tolist(), g.dst.tolist()):
        adj[s].append(d)
    levels = np.full((n, len(pivots)), np.inf, np.float32)
    sigma = np.zeros((n, len(pivots)), np.float32)
    delta = np.zeros((n, len(pivots)), np.float32)
    for ki, s in enumerate(pivots):
        dist = np.full(n, -1, np.int64)
        sig = np.zeros(n)
        dist[s] = 0
        sig[s] = 1.0
        order: List[int] = []
        q = deque([s])
        while q:
            v = q.popleft()
            order.append(v)
            for w in adj[v]:
                if dist[w] < 0:
                    dist[w] = dist[v] + 1
                    q.append(w)
                if dist[w] == dist[v] + 1:
                    sig[w] += sig[v]
        dl = np.zeros(n)
        for v in reversed(order):
            for w in adj[v]:
                if dist[w] == dist[v] + 1:
                    dl[v] += sig[v] / sig[w] * (1.0 + dl[w])
        levels[:, ki] = np.where(dist < 0, np.inf, dist)
        sigma[:, ki] = sig
        delta[:, ki] = dl
    return levels, sigma, delta


def _pivots(g: Graph, k: int = 4) -> np.ndarray:
    n = max(g.n_vertices, 1)
    return np.unique(np.array([0, n // 3, n // 2, n - 1][:k]) % n)


# --------------------------------------------------------------------- #
# the suite registry (one ~10-line entry per algorithm)
# --------------------------------------------------------------------- #
def _sigma_case_make(g: Graph):
    import jax.numpy as jnp
    pv = _pivots(g)
    lev, _, _ = brandes_oracle(g, pv)
    return SigmaCount(payload=len(pv)), {
        "pivots": jnp.asarray(pv, jnp.int32), "levels": jnp.asarray(lev)}


def _accum_case_make(g: Graph):
    import jax.numpy as jnp
    pv = _pivots(g)
    lev, sig, _ = brandes_oracle(g, pv)
    return BrandesAccum(payload=len(pv)), {"levels": jnp.asarray(lev),
                                           "sigma": jnp.asarray(sig)}


ALGOS: List[AlgoCase] = [
    AlgoCase(name="bfs",
             make=lambda g: (BFS(), {"source": 0}),
             oracle=lambda g: bfs_levels_oracle(g, 0),
             fill=np.inf),
    AlgoCase(name="msbfs",
             make=lambda g: make_msbfs(_pivots(g)),
             oracle=lambda g: msbfs_oracle(g, _pivots(g)),
             fill=np.inf),
    AlgoCase(name="lp",
             make=lambda g: (LabelPropagation(hops=3), {}),
             oracle=lambda g: lp_lanes_oracle(g, 3),
             fill=_IMAX),
    AlgoCase(name="kcore2",
             make=lambda g: (KCore(k=2), {}),
             oracle=lambda g: kcore_peeled_oracle(g, 2),
             fill=0),
    AlgoCase(name="kcore3",
             make=lambda g: (KCore(k=3), {}),
             oracle=lambda g: kcore_peeled_oracle(g, 3),
             fill=0),
    AlgoCase(name="triangles",
             make=lambda g: make_triangles(_pivots(g)),
             oracle=lambda g: triangles_oracle(g, _pivots(g)),
             fill=0.0, exact=False),
    AlgoCase(name="sigma",
             make=_sigma_case_make,
             oracle=lambda g: brandes_oracle(g, _pivots(g))[1],
             fill=0.0, exact=False),
    AlgoCase(name="brandes_delta",
             make=_accum_case_make,
             oracle=lambda g: brandes_oracle(g, _pivots(g))[2],
             fill=0.0, exact=False),
]


def case_by_name(name: str) -> AlgoCase:
    for c in ALGOS:
        if c.name == name:
            return c
    raise KeyError(name)


# --------------------------------------------------------------------- #
# check families
# --------------------------------------------------------------------- #
def check_oracle(case: AlgoCase, g: Graph, *, n_parts: int = 4,
                 part: str = "cdbh", mode: str = "sc",
                 edge_backend: str = "coo") -> None:
    g = canonicalize(g) if case.canonical else g
    pg = build(g, n_parts, part)
    prog, params = case.make(g)
    res, _ = run_sim(prog, pg, params, EngineConfig(mode=mode,
                                                    edge_backend=edge_backend))
    got = pg.collect(res, fill=case.fill)
    want = case.oracle(g)
    assert case.compare(got, want), \
        f"{case.name}: engine != oracle on n={g.n_vertices} ({part}/{mode})"


def check_backend_equivalence(case: AlgoCase, g: Graph, *,
                              n_parts: int = 4, part: str = "cdbh") -> None:
    """Identical answers whatever ``edge_backend`` the config requests —
    real three-way parity for declarative programs, resolution-stability
    for custom sweeps (which all normalize onto their declared backend)."""
    g = canonicalize(g) if case.canonical else g
    pg = build(g, n_parts, part)
    prog, params = case.make(g)
    ref = None
    for eb in ("coo", "pallas_tiles", "pallas_windows"):
        res, _ = run_sim(prog, pg, params,
                         EngineConfig(mode="sc", edge_backend=eb))
        got = pg.collect(res, fill=case.fill)
        if ref is None:
            ref = got
        else:
            assert case.compare(got, ref), \
                f"{case.name}: edge_backend={eb} diverges from coo"


def _drop_pairs(g: Graph, pairs: set) -> Graph:
    keep = np.array([(s, d) not in pairs and (d, s) not in pairs
                     for s, d in zip(g.src.tolist(), g.dst.tolist())])
    return Graph(g.n_vertices, g.src[keep], g.dst[keep],
                 None if g.weight is None else g.weight[keep],
                 directed=g.directed)


def _undirected_pairs(g: Graph) -> List[Tuple[int, int]]:
    return sorted({(min(s, d), max(s, d))
                   for s, d in zip(g.src.tolist(), g.dst.tolist())})


def check_fresh_vs_incremental(case: AlgoCase, g: Graph, *, seed: int = 0,
                               n_chunks: int = 2, n_parts: int = 4,
                               part: str = "cdbh") -> None:
    """Stream a randomized delta schedule of the program's ``warm_under``
    polarity through a ``GraphSession``; after every flush the warm="auto"
    answer must be bit-identical to a forced cold recompute and use no
    more supersteps."""
    g = canonicalize(g) if case.canonical else g
    prog, _ = case.make(g)
    assert prog.monotone, f"{case.name} is not monotone; no incremental path"
    rng = np.random.default_rng(seed)
    pairs = _undirected_pairs(g)
    n_move = max(1, len(pairs) // 5)
    moved = [pairs[i] for i in rng.choice(len(pairs), n_move, replace=False)]
    chunks = [moved[i::n_chunks] for i in range(n_chunks)]
    chunks = [c for c in chunks if c]

    if prog.warm_under == "inserts":
        base = _drop_pairs(g, set(moved))
    else:
        base = g
    sess = GraphSession.from_graph(base, n_parts, part)
    try:
        prog, params = case.make(g)     # pivots etc from the FULL graph
        sess.query(prog, params)        # seed the warm memory
        for chunk in chunks:
            s = np.array([p[0] for p in chunk] + [p[1] for p in chunk],
                         np.int64)
            d = np.array([p[1] for p in chunk] + [p[0] for p in chunk],
                         np.int64)
            if prog.warm_under == "inserts":
                sess.update(adds=(s, d, np.ones(len(s), np.float32)))
            else:
                sess.update(deletes=(s, d))
            sess.flush()
            res_w, st_w = sess.query(prog, params, warm=True)
            res_c, st_c = sess.query(prog, params, warm=False,
                                     use_result_cache=False)
            got_w = sess.pg.collect(res_w, fill=case.fill)
            got_c = sess.pg.collect(res_c, fill=case.fill)
            assert np.array_equal(got_w, got_c, equal_nan=True), \
                f"{case.name}: warm result != cold recompute after flush"
            assert st_w.supersteps <= st_c.supersteps, \
                (f"{case.name}: warm start took {st_w.supersteps} supersteps"
                 f" vs {st_c.supersteps} cold")
    finally:
        sess.close()


def run_case_all(case_name: str, g: Graph, *, mode: str = "sc",
                 n_parts: int = 4, part: str = "cdbh",
                 edge_backend: str = "coo"):
    """(collected values, supersteps) — helper the shard-parity subprocess
    shares with in-process tests so both sides run the same code path."""
    case = case_by_name(case_name)
    g = canonicalize(g) if case.canonical else g
    pg = build(g, n_parts, part)
    prog, params = case.make(g)
    res, st = run_sim(prog, pg, params,
                      EngineConfig(mode=mode, edge_backend=edge_backend))
    return pg.collect(res, fill=case.fill), st.supersteps
