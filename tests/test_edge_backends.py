"""Edge-compute backend parity: every SemiringSweep program must produce
the same answer on 'coo', 'pallas_tiles' and 'pallas_windows' — bit-identical
for the min_plus algorithms (SSSP float32, CC int32), allclose for the
plus_times accumulation (PageRank sums in a different order on the MXU
path) — on BOTH engine backends, cold and warm, through a live
stream-flush-then-query cycle, with trace-counter pins proving in-bucket
flushes retrace nothing per backend."""
import subprocess
import sys

import numpy as np
import pytest


from repro.algos import ConnectedComponents, PageRank, SSSP
from repro.algos.mssp import make_mssp
from repro.analysis.sanitizer import retrace_guard
from repro.core import (EngineConfig, partition_and_build,
                        resolve_edge_backend, run_sim)
from repro.core.layouts import build_edge_layouts
from repro.graphgen import powerlaw_graph
from repro.session import GraphSession

PALLAS = ("pallas_tiles", "pallas_windows")
PR_TOL = dict(rtol=1e-5, atol=1e-8)     # plus_times reassociation tolerance


def _algos(nv):
    return [("sssp", SSSP(), {"source": 0}, True),
            ("cc", ConnectedComponents(), None, True),
            ("pagerank", PageRank(tol=1e-7), {"n_vertices": nv}, False)]


@pytest.fixture(scope="module")
def graph():
    return powerlaw_graph(900, seed=5, weighted=True).as_undirected()


@pytest.fixture(scope="module")
def pg(graph):
    return partition_and_build(graph, 4, "cdbh")


@pytest.fixture(scope="module")
def coo_sim(pg, graph):
    return {name: run_sim(prog, pg, params, EngineConfig())[0]
            for name, prog, params, _ in _algos(graph.n_vertices)}


def _check(name, exact, want, got):
    if exact:
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got),
                                      err_msg=name)
    else:
        np.testing.assert_allclose(np.asarray(want), np.asarray(got),
                                   err_msg=name, **PR_TOL)


# --------------------------------------------------------------------------- #
# one-shot parity, simulator backend
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("eb", PALLAS)
def test_sim_parity_all_algos(pg, graph, coo_sim, eb):
    for name, prog, params, exact in _algos(graph.n_vertices):
        res, st = run_sim(prog, pg, params, EngineConfig(edge_backend=eb))
        _check(f"{name}/{eb}", exact, coo_sim[name], res)
        assert st.edge_backend == eb
        assert st.backend_flops > 0
        if eb == "pallas_tiles":
            assert 0.0 < st.tile_density <= 1.0


def test_cc_stays_int32_on_tiles(pg, coo_sim):
    """The dtype satellite: int32 min_plus rides the tile kernel without a
    float round-trip (labels above 2**24 would corrupt in float32)."""
    res, _ = run_sim(ConnectedComponents(), pg, None,
                     EngineConfig(edge_backend="pallas_tiles"))
    assert np.asarray(res).dtype == np.int32
    np.testing.assert_array_equal(coo_sim["cc"], res)


# --------------------------------------------------------------------------- #
# one-shot parity, shard_map backend — in a subprocess, like every other
# multi-device test in this suite: fake host devices must be requested
# before jax initializes, and the main pytest process has long since done
# that with a single CPU device
# --------------------------------------------------------------------------- #
SHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import numpy as np
from jax.sharding import Mesh

from repro.algos import ConnectedComponents, PageRank, SSSP
from repro.core import (EngineConfig, partition_and_build,
                        run_shard_map, run_sim)
from repro.graphgen import powerlaw_graph

g = powerlaw_graph(900, seed=5, weighted=True).as_undirected()
pg = partition_and_build(g, 4, "cdbh")
algos = [("sssp", SSSP(), {"source": 0}, True),
         ("cc", ConnectedComponents(), None, True),
         ("pagerank", PageRank(tol=1e-7), {"n_vertices": g.n_vertices},
          False)]
coo = {name: run_sim(prog, pg, params, EngineConfig())[0]
       for name, prog, params, _ in algos}

mesh = Mesh(np.array(jax.devices()[:4]).reshape(4), ("sub",))
for eb in ("pallas_tiles", "pallas_windows"):
    cfg = EngineConfig(backend="shard_map", subgraph_axes=("sub",),
                       edge_backend=eb)
    for name, prog, params, exact in algos:
        res, st = run_shard_map(prog, pg, mesh, params, cfg)
        assert st.edge_backend == eb, (name, eb, st.edge_backend)
        if exact:
            np.testing.assert_array_equal(coo[name], np.asarray(res),
                                          err_msg=f"{name}/{eb}")
        else:
            np.testing.assert_allclose(coo[name], np.asarray(res),
                                       rtol=1e-5, atol=1e-8,
                                       err_msg=f"{name}/{eb}")

# edge-axis sharding: each partition's tile/window lists shard over the
# 'edge' mesh axis and the generated sweep's EdgeCombine epilogue reduces
# the per-shard partial segment results — results must stay bit-identical
# (min_plus) / allclose (PageRank) to the unsharded runs above
mesh2 = Mesh(np.array(jax.devices()[:8]).reshape(4, 2), ("sub", "edge"))
for eb in ("pallas_tiles", "pallas_windows", "auto"):
    cfg2 = EngineConfig(backend="shard_map", subgraph_axes=("sub",),
                        edge_axes=("edge",), edge_backend=eb)
    for name, prog, params, exact in algos:
        res, st = run_shard_map(prog, pg, mesh2, params, cfg2)
        assert st.edge_backend == eb, (name, eb, st.edge_backend)
        if exact:
            np.testing.assert_array_equal(coo[name], np.asarray(res),
                                          err_msg=f"{name}/{eb}/sharded")
        else:
            np.testing.assert_allclose(coo[name], np.asarray(res),
                                       rtol=1e-5, atol=1e-8,
                                       err_msg=f"{name}/{eb}/sharded")
    if eb == "auto":
        assert len(st.partition_edge_backends) == pg.n_parts
print("SHARD_EB_OK")
"""


def test_shard_map_parity_and_edge_sharding():
    res = subprocess.run([sys.executable, "-c", SHARD_SCRIPT],
                         capture_output=True, text=True, timeout=1200)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "SHARD_EB_OK" in res.stdout


# --------------------------------------------------------------------------- #
# fallback: programs without a SemiringSweep always run COO
# --------------------------------------------------------------------------- #
def test_mssp_custom_sweep_falls_back_to_coo(pg):
    prog, params = make_mssp([0, 5, 9])
    cfg = EngineConfig(edge_backend="pallas_tiles")
    assert resolve_edge_backend(prog, cfg) == "coo"
    want, _ = run_sim(prog, pg, params, EngineConfig())
    got, st = run_sim(prog, pg, params, cfg)
    assert st.edge_backend == "coo"
    np.testing.assert_array_equal(want, got)


def test_engine_config_validates_edge_backend():
    with pytest.raises(ValueError, match="edge_backend"):
        EngineConfig(edge_backend="cusparse")


# --------------------------------------------------------------------------- #
# serving lifecycle: warm starts + stream-flush-then-query per backend
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("eb", ("coo",) + PALLAS)
def test_session_flush_then_query_cycle(graph, eb):
    """update -> flush -> warm query on every backend: the warm result is
    bit-identical to cold and converges in no more supersteps."""
    sess = GraphSession.from_graph(graph, 4, "cdbh",
                                   cfg=EngineConfig(edge_backend=eb))
    r0, _ = sess.query(SSSP(), {"source": 0})

    # attach a brand-new vertex through a long detour + one shortcut edge
    nv = sess.pg.n_vertices
    sess.update(adds=([0, nv], [nv, 1], [2.5, 2.5]))
    st = sess.flush()
    assert st.warm_start_safe

    warm, st_w = sess.query(SSSP(), {"source": 0})           # warm="auto"
    assert sess.stats.warm_queries == 1
    cold, st_c = sess.query(SSSP(), {"source": 0}, warm=False)
    np.testing.assert_array_equal(np.asarray(warm), np.asarray(cold))
    assert st_w.supersteps <= st_c.supersteps
    assert st_w.edge_backend == eb


@pytest.mark.parametrize("eb", PALLAS)
def test_inbucket_flush_zero_retraces(graph, eb):
    """The acceptance pin: a flush that stays inside every bucket (padded
    shapes AND layout capacities) must re-hit the compiled Pallas runner
    with zero retraces."""
    sess = GraphSession.from_graph(graph, 4, "cdbh",
                                   cfg=EngineConfig(edge_backend=eb))
    sess.query(SSSP(), {"source": 0})
    pg = sess.pg
    p = int(np.argmin(pg.edges_per_part))
    m = pg.emask[p]
    gs = int(pg.gvid[p][pg.esrc[p][m]][0])
    gd = int(pg.gvid[p][pg.edst[p][m]][0])
    lay = pg.edge_layouts
    caps_before = (lay.t_max, lay.b_max)
    sess.update(adds=([gs], [gd], [40.0]))
    sess.flush()
    assert (lay.t_max, lay.b_max) == caps_before, "in-bucket by design"
    with retrace_guard(label=f"{eb}: in-bucket flush requery"):
        _, st = sess.query(SSSP(), {"source": 0})
    assert st.compile_time == 0.0
    assert sess.stats.cache_misses == 1


def test_cross_backend_runners_coexist(graph):
    """One session serving mixed-backend traffic keeps one runner per
    backend (cfg is part of the cache key), all returning the same answer."""
    sess = GraphSession.from_graph(graph, 4, "cdbh")
    res = {}
    for eb in ("coo",) + PALLAS:
        res[eb], _ = sess.query(SSSP(), {"source": 2},
                                cfg=EngineConfig(edge_backend=eb),
                                warm=False)
    assert sess.stats.cache_misses == 3
    np.testing.assert_array_equal(res["coo"], res["pallas_tiles"])
    np.testing.assert_array_equal(res["coo"], res["pallas_windows"])
    # ...and repeat traffic hits all three
    for eb in ("coo",) + PALLAS:
        sess.query(SSSP(), {"source": 2},
                   cfg=EngineConfig(edge_backend=eb), warm=False)
    assert sess.stats.cache_misses == 3


# --------------------------------------------------------------------------- #
# incremental layout maintenance (stream/delta.py)
# --------------------------------------------------------------------------- #
def test_delta_rebuilds_only_touched_partitions(graph):
    """apply_delta refreshes layout geometry in place for the patched
    partitions and leaves the object (and untouched partitions' realized
    tiles) alone; the result matches a from-scratch build."""
    sess = GraphSession.from_graph(graph, 4, "cdbh",
                                   cfg=EngineConfig(edge_backend="pallas_tiles"))
    sess.query(SSSP(), {"source": 0})
    pg = sess.pg
    lay = pg.edge_layouts
    tiles_before = lay.tile_values(pg, "min_plus", "weight",
                                   np.float32).copy()
    p = int(np.argmin(pg.edges_per_part))
    m = pg.emask[p]
    gs = int(pg.gvid[p][pg.esrc[p][m]][0])
    gd = int(pg.gvid[p][pg.edst[p][m]][0])
    sess.update(adds=([gs], [gd], [0.125]))
    sess.flush()
    assert pg.edge_layouts is lay, "in-bucket delta must patch in place"

    fresh = build_edge_layouts(pg, lay.policy, lay.block_edges)
    tiles_inc = lay.tile_values(pg, "min_plus", "weight", np.float32)
    tiles_new = fresh.tile_values(pg, "min_plus", "weight", np.float32)
    np.testing.assert_array_equal(lay.n_tiles, fresh.n_tiles)
    np.testing.assert_array_equal(lay.n_blocks, fresh.n_blocks)
    for q in range(pg.n_parts):
        T = int(fresh.n_tiles[q])
        np.testing.assert_array_equal(lay.tile_dst[q, :T],
                                      fresh.tile_dst[q, :T])
        np.testing.assert_array_equal(tiles_inc[q, :T], tiles_new[q, :T])
        if q != p:
            np.testing.assert_array_equal(tiles_inc[q], tiles_before[q])


def test_compact_rebuilds_layouts(graph):
    """Compaction repacks the grid: layouts are rebuilt at assembly time and
    post-compact Pallas queries still match COO."""
    sess = GraphSession.from_graph(graph, 4, "cdbh",
                                   cfg=EngineConfig(edge_backend="pallas_windows"))
    sess.query(SSSP(), {"source": 0})
    lay0 = sess.pg.edge_layouts
    # delete a vertex's edges then compact
    m = sess.pg.emask[0]
    gs = sess.pg.gvid[0][sess.pg.esrc[0][m]]
    gd = sess.pg.gvid[0][sess.pg.edst[0][m]]
    sess.update(deletes=(gs[:3], gd[:3]))
    sess.flush()
    sess.compact()
    assert sess.pg.edge_layouts is not lay0, "compact rebuilds the layouts"
    got, st = sess.query(SSSP(), {"source": 0})
    assert st.edge_backend == "pallas_windows"
    want, _ = sess.query(SSSP(), {"source": 0},
                         cfg=EngineConfig(edge_backend="coo"), warm=False)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --------------------------------------------------------------------------- #
# byte-accounted LRU (satellite: max_runner_bytes / max_warm_bytes)
# --------------------------------------------------------------------------- #
def test_runner_cache_byte_bound(graph):
    sess = GraphSession.from_graph(graph, 4, "cdbh")
    sess.query(SSSP(), {"source": 0})
    info = sess.cache_info()
    assert info and info[0]["nbytes"] > 0
    assert sess.stats.runner_cache_bytes == sum(e["nbytes"] for e in info)
    # a bound below one entry keeps exactly the newest runner
    sess.max_runner_bytes = info[0]["nbytes"] // 2
    sess.query(ConnectedComponents())
    assert len(sess.cache_info()) == 1
    assert sess.stats.cache_evictions_lru == 1
    assert sess.cache_info()[0]["program"] == "ConnectedComponents"


def test_warm_memory_byte_bound(graph):
    sess = GraphSession.from_graph(graph, 4, "cdbh")
    sess.query(SSSP(), {"source": 0})
    one = sess.stats.warm_cache_bytes
    assert one > 0
    sess.max_warm_bytes = int(one * 1.5)        # room for one entry only
    sess.query(SSSP(), {"source": 1})
    assert len(sess._warm) == 1
    assert sess.stats.warm_evictions == 1
    assert sess.stats.warm_cache_bytes <= sess.max_warm_bytes


# --------------------------------------------------------------------------- #
# lazy warm-block remap (satellite: pending-remap chain)
# --------------------------------------------------------------------------- #
def test_lazy_warm_remap_defers_until_use(graph):
    """N insert-only flushes cost zero remaps; the next warm query replays
    the pending chain once per logged flush, bit-identically to cold."""
    sess = GraphSession.from_graph(graph, 4, "cdbh")
    sess.query(SSSP(), {"source": 0})
    nv = sess.pg.n_vertices
    for i in range(3):
        sess.update(adds=([0], [nv + i], [3.0 + i]))
        sess.flush()
    assert sess.stats.warm_remaps_applied == 0, "flushes must not remap"
    assert len(sess._remap_log) == 3
    warm, _ = sess.query(SSSP(), {"source": 0})
    assert sess.stats.warm_remaps_applied == 3, "chain replayed on use"
    cold, _ = sess.query(SSSP(), {"source": 0}, warm=False)
    np.testing.assert_array_equal(np.asarray(warm), np.asarray(cold))
    # the entry written by the warm query is current: the log is prunable
    assert not sess._remap_log


def test_remap_log_cleared_by_deleting_flush(graph):
    sess = GraphSession.from_graph(graph, 4, "cdbh")
    sess.query(SSSP(), {"source": 0})
    nv = sess.pg.n_vertices
    sess.update(adds=([0], [nv], [3.0]))
    sess.flush()
    assert len(sess._remap_log) == 1
    sess.update(deletes=([0], [nv]))
    sess.flush()
    assert not sess._remap_log and not sess._warm
