"""Streaming subsystem: edge-log IO, two-pass out-of-core ingest parity with
the in-memory path (bit-identical per partition), chunk-bounded memory
accounting, incremental delta patching, delta batching, membership
compaction, and warm-start recompute."""
import numpy as np
import pytest

from repro.algos import ConnectedComponents, PageRank, SSSP
from repro.core import EngineConfig, partition_and_build, run, run_sim
from repro.core.graph import Graph
from repro.graphgen import powerlaw_graph
from repro.stream import (DeltaBuffer, EdgeDelta, EdgeLogReader,
                          EdgeLogWriter, apply_delta, compact,
                          streaming_ingest, write_edge_log)
from repro.stream.edgelog import BYTES_PER_EDGE

PARITY_ARRAYS = ("gvid", "vmask", "esrc", "edst", "ew", "emask", "slot",
                 "is_frontier", "out_deg", "in_deg", "is_master",
                 "frontier_gvid")


@pytest.fixture(scope="module")
def big_graph():
    """Power-law graph with >= 100k edges (acceptance-criterion scale)."""
    g = powerlaw_graph(20_000, alpha=2.2, avg_degree=8, seed=11,
                       weighted=True)
    assert g.n_edges >= 100_000, g.n_edges
    return g


@pytest.fixture(scope="module")
def big_log(big_graph, tmp_path_factory):
    d = str(tmp_path_factory.mktemp("edgelog"))
    meta = write_edge_log(big_graph, d, chunk_size=16_384)
    assert meta.n_edges == big_graph.n_edges
    assert meta.n_chunks == -(-big_graph.n_edges // 16_384)
    return d


# --------------------------------------------------------------------------- #
# edge log
# --------------------------------------------------------------------------- #
def test_edgelog_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    src = rng.integers(0, 500, 10_000).astype(np.int64)
    dst = rng.integers(0, 500, 10_000).astype(np.int64)
    w = rng.uniform(0, 1, 10_000).astype(np.float32)
    with EdgeLogWriter(str(tmp_path / "log"), chunk_size=999,
                       weighted=True) as wr:
        for lo in range(0, 10_000, 1303):   # appends misaligned with chunks
            hi = min(lo + 1303, 10_000)
            wr.append(src[lo:hi], dst[lo:hi], w[lo:hi])
    rd = EdgeLogReader(str(tmp_path / "log"))
    assert rd.meta.n_edges == 10_000
    assert rd.meta.n_vertices == int(max(src.max(), dst.max())) + 1
    s, d, ww = rd.read_all()
    np.testing.assert_array_equal(s, src)
    np.testing.assert_array_equal(d, dst)
    np.testing.assert_array_equal(ww, w)
    # every chunk except the last is exactly chunk_size
    sizes = [c[0].shape[0] for c in rd.chunks()]
    assert all(n == 999 for n in sizes[:-1]) and sum(sizes) == 10_000


def test_edgelog_empty(tmp_path):
    with EdgeLogWriter(str(tmp_path / "log"), chunk_size=8) as wr:
        pass
    rd = EdgeLogReader(str(tmp_path / "log"))
    assert rd.meta.n_edges == 0 and rd.meta.n_chunks == 0
    s, d, w = rd.read_all()
    assert s.size == 0 and w is None


# --------------------------------------------------------------------------- #
# two-pass ingest parity (acceptance criterion)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("part", ["cdbh", "rh-ec"])
def test_streaming_parity(big_graph, big_log, part):
    """Chunked two-pass ingest == one-shot in-memory partitioning,
    bit-identical per partition, on a >= 100k-edge power-law graph."""
    n_parts = 8
    pg_mem = partition_and_build(big_graph, n_parts, part)
    pg_st, ctx, stats = streaming_ingest(big_log, n_parts, part)
    assert (pg_st.n_parts, pg_st.n_vertices, pg_st.n_edges, pg_st.n_slots,
            pg_st.v_max, pg_st.e_max) == \
           (pg_mem.n_parts, pg_mem.n_vertices, pg_mem.n_edges,
            pg_mem.n_slots, pg_mem.v_max, pg_mem.e_max)
    for name in PARITY_ARRAYS:
        np.testing.assert_array_equal(getattr(pg_st, name),
                                      getattr(pg_mem, name), err_msg=name)
    # chunk accounting: the streaming passes never held O(|E|) edge bytes
    assert stats.peak_stream_bytes <= stats.stream_bound_bytes
    full_bytes = big_graph.n_edges * BYTES_PER_EDGE
    assert stats.stream_bound_bytes < full_bytes / 2, \
        "chunk bound is not meaningfully below the full edge list"
    # routing context snapshot matches the full-degree table
    np.testing.assert_array_equal(ctx.routing_degrees,
                                  big_graph.total_degrees())


def test_streaming_rejects_stateful_partitioner(big_log):
    with pytest.raises(ValueError):
        streaming_ingest(big_log, 4, "greedy-ec")


def test_streaming_isolated_vertices(tmp_path):
    """Vertices with no edges get the same hash round-robin placement."""
    g = Graph(50, np.array([0, 1, 2], np.int64), np.array([1, 2, 3], np.int64))
    d = str(tmp_path / "log")
    write_edge_log(g, d, chunk_size=2)
    pg_mem = partition_and_build(g, 4, "cdbh")
    pg_st, _, _ = streaming_ingest(d, 4, "cdbh")
    for name in PARITY_ARRAYS:
        np.testing.assert_array_equal(getattr(pg_st, name),
                                      getattr(pg_mem, name), err_msg=name)


# --------------------------------------------------------------------------- #
# delta patching
# --------------------------------------------------------------------------- #
def _edge_multiset(pg):
    """Global (src, dst, w) multiset of resident edges, canonically sorted."""
    rows = []
    for p in range(pg.n_parts):
        m = pg.emask[p]
        rows.append(np.stack([pg.gvid[p][pg.esrc[p][m]].astype(np.float64),
                              pg.gvid[p][pg.edst[p][m]].astype(np.float64),
                              pg.ew[p][m].astype(np.float64)], 1))
    rows = np.concatenate(rows, 0)
    return rows[np.lexsort(rows.T)]


def test_delta_insert_matches_full_reingest(tmp_path):
    """Insert-only delta == re-ingesting the grown log with the same frozen
    routing degrees: same residency, membership superset-free, same slots."""
    g = powerlaw_graph(3000, seed=4, weighted=True)
    d = str(tmp_path / "log")
    write_edge_log(g, d, chunk_size=4096)
    pg, ctx, _ = streaming_ingest(d, 6, "cdbh")

    rng = np.random.default_rng(5)
    n_add = 500
    asrc = rng.integers(0, g.n_vertices, n_add).astype(np.int64)
    adst = rng.integers(0, g.n_vertices, n_add).astype(np.int64)
    aw = rng.uniform(1, 2, n_add).astype(np.float32)
    st = apply_delta(pg, ctx, EdgeDelta(add_src=asrc, add_dst=adst, add_w=aw))
    assert st.n_added == n_add and st.n_deleted == 0 and st.warm_start_safe
    assert pg.n_edges == g.n_edges + n_add

    # reference: route the grown edge list through the SAME frozen degrees
    from repro.core import build_partitioned_graph
    g2 = Graph(g.n_vertices, np.concatenate([g.src, asrc]),
               np.concatenate([g.dst, adst]),
               np.concatenate([g.weights, aw]))
    from repro.core.partition import route_edges_cdbh
    part2 = route_edges_cdbh(g2.src, g2.dst, ctx.routing_degrees, 6)
    pg2 = build_partitioned_graph(g2, part2, 6)

    np.testing.assert_array_equal(_edge_multiset(pg), _edge_multiset(pg2))
    # membership, slots and masters agree exactly (insert-only => no stale)
    assert pg.n_slots == pg2.n_slots
    for p in range(6):
        np.testing.assert_array_equal(pg.gvid[p][pg.vmask[p]],
                                      pg2.gvid[p][pg2.vmask[p]])
        np.testing.assert_array_equal(
            pg.is_master[p][pg.vmask[p]], pg2.is_master[p][pg2.vmask[p]])
        np.testing.assert_array_equal(
            pg.slot[p][pg.vmask[p]], pg2.slot[p][pg2.vmask[p]])
        np.testing.assert_array_equal(
            pg.out_deg[p][pg.vmask[p]], pg2.out_deg[p][pg2.vmask[p]])


def test_delta_delete_and_results(tmp_path):
    """Deletions remove resident copies; engine results match a fresh build
    of the mutated graph (undirected CC + SSSP)."""
    g = powerlaw_graph(1200, seed=6, weighted=True).as_undirected()
    d = str(tmp_path / "log")
    write_edge_log(g, d, chunk_size=2048)
    pg, ctx, _ = streaming_ingest(d, 5, "cdbh")

    rng = np.random.default_rng(7)
    sel = rng.choice(g.n_edges, size=200, replace=False)
    # undirected storage: drop both directions of each sampled edge
    ds = np.concatenate([g.src[sel], g.dst[sel]])
    dd = np.concatenate([g.dst[sel], g.src[sel]])
    st = apply_delta(pg, ctx, EdgeDelta(del_src=ds, del_dst=dd))
    assert st.n_deleted > 0 and not st.warm_start_safe
    assert pg.n_edges == int(pg.emask.sum())

    kept = np.ones(g.n_edges, bool)
    key = g.src * np.int64(g.n_vertices) + g.dst
    kept[np.isin(key, ds * np.int64(g.n_vertices) + dd)] = False
    g2 = Graph(g.n_vertices, g.src[kept], g.dst[kept], g.weights[kept])
    pg2 = partition_and_build(g2, 5, "cdbh")
    assert pg.n_edges == g2.n_edges

    r1, _ = run_sim(ConnectedComponents(), pg, None, EngineConfig())
    r2, _ = run_sim(ConnectedComponents(), pg2, None, EngineConfig())
    np.testing.assert_array_equal(pg.collect(r1, fill=-1),
                                  pg2.collect(r2, fill=-1))
    r3, _ = run_sim(SSSP(), pg, {"source": 3}, EngineConfig())
    r4, _ = run_sim(SSSP(), pg2, {"source": 3}, EngineConfig())
    np.testing.assert_allclose(pg.collect(r3, fill=np.float32(np.inf)),
                               pg2.collect(r4, fill=np.float32(np.inf)),
                               rtol=1e-5, atol=1e-4)


def test_delta_grows_vertex_space(tmp_path):
    g = powerlaw_graph(500, seed=8).as_undirected()
    d = str(tmp_path / "log")
    write_edge_log(g, d, chunk_size=1024)
    pg, ctx, _ = streaming_ingest(d, 4, "cdbh")
    old_v = pg.n_vertices
    # attach a chain of brand-new vertices to vertex 0
    new = np.arange(old_v, old_v + 10, dtype=np.int64)
    chain_s = np.concatenate([[0], new[:-1]])
    st = apply_delta(pg, ctx, EdgeDelta(
        add_src=np.concatenate([chain_s, new]),
        add_dst=np.concatenate([new, chain_s])))
    assert pg.n_vertices == old_v + 10 and ctx.n_vertices == old_v + 10
    assert st.n_added == 20
    r, _ = run_sim(ConnectedComponents(), pg, None, EngineConfig())
    lab = pg.collect(r, fill=-1)
    assert (lab[new] == lab[0]).all(), "new chain joins vertex 0's component"


def test_recompute_frontier_is_idempotent(tmp_path):
    from repro.core import recompute_frontier
    g = powerlaw_graph(800, seed=9)
    pg = partition_and_build(g, 5, "cdbh")
    before = {n: getattr(pg, n).copy() for n in
              ("slot", "is_frontier", "is_master", "frontier_gvid")}
    recompute_frontier(pg)
    for n, arr in before.items():
        np.testing.assert_array_equal(arr, getattr(pg, n), err_msg=n)


# --------------------------------------------------------------------------- #
# warm-start recompute (acceptance criterion)
# --------------------------------------------------------------------------- #
def test_warm_start_sssp_after_insert_batch(tmp_path):
    """After a ~1% edge-insert batch, warm-start SSSP converges in fewer
    supersteps than cold start and matches it to np.allclose."""
    g = powerlaw_graph(4000, seed=2, weighted=True).as_undirected()
    d = str(tmp_path / "log")
    write_edge_log(g, d, chunk_size=8192)
    pg, ctx, _ = streaming_ingest(d, 5, "cdbh")
    res0, _ = run_sim(SSSP(), pg, {"source": 0}, EngineConfig())
    prev = pg.collect(res0, fill=np.float32(np.inf))

    rng = np.random.default_rng(3)
    n_add = g.n_edges // 200          # ~1% counting both directions
    asrc = rng.integers(0, g.n_vertices, n_add)
    adst = rng.integers(0, g.n_vertices, n_add)
    keep = asrc != adst
    asrc, adst = asrc[keep], adst[keep]
    # mid/high-weight inserts: distances improve only locally, which is the
    # regime where incremental recompute pays off (a tiny-weight shortcut
    # into a hub can legitimately cascade as far as a cold start).
    aw = rng.uniform(5, 10, asrc.size).astype(np.float32)
    st = apply_delta(pg, ctx, EdgeDelta(
        add_src=np.concatenate([asrc, adst]),
        add_dst=np.concatenate([adst, asrc]),
        add_w=np.concatenate([aw, aw])))
    assert st.warm_start_safe

    cold, st_cold = run_sim(SSSP(), pg, {"source": 0}, EngineConfig())
    warm, st_warm = run_sim(SSSP(), pg, {"source": 0}, EngineConfig(),
                            init_state=prev)
    c = pg.collect(cold, fill=np.float32(np.inf))
    w = pg.collect(warm, fill=np.float32(np.inf))
    fin = np.isfinite(c)
    assert np.allclose(w[fin], c[fin], rtol=1e-5, atol=1e-4)
    assert np.isinf(w[~fin]).all()
    assert st_warm.supersteps < st_cold.supersteps, \
        (st_warm.supersteps, st_cold.supersteps)


def test_warm_start_cc(tmp_path):
    g = powerlaw_graph(2000, seed=12).as_undirected()
    pg = partition_and_build(g, 4, "cdbh")
    res0, _ = run_sim(ConnectedComponents(), pg, None, EngineConfig())
    prev = pg.collect(res0, fill=-1)
    warm, st_w = run_sim(ConnectedComponents(), pg, None, EngineConfig(),
                         init_state=prev)
    np.testing.assert_array_equal(pg.collect(warm, fill=-1), prev)
    assert st_w.supersteps <= 2, "already-converged warm start is immediate"


def test_warm_start_nonmonotone_falls_back_cold():
    g = powerlaw_graph(600, seed=13)
    pg = partition_and_build(g, 4, "cdbh")
    cfg = EngineConfig(max_local_iters=300, max_supersteps=3000)
    pr = PageRank(tol=1e-9)
    r1, _ = run_sim(pr, pg, {"n_vertices": g.n_vertices}, cfg)
    # bogus init_state must be ignored (cold-start correctness fallback)
    r2, _ = run_sim(pr, pg, {"n_vertices": g.n_vertices}, cfg,
                    init_state=np.full(g.n_vertices, 123.0, np.float32))
    np.testing.assert_array_equal(r1, r2)


def test_warm_start_init_state_dtype_cast():
    """A float64 (or int64) previous-result array must not leak its dtype
    into the warm block (regression: wv inherited warm.dtype)."""
    g = powerlaw_graph(1000, seed=2, weighted=True).as_undirected()
    pg = partition_and_build(g, 4, "cdbh")
    res0, _ = run_sim(SSSP(), pg, {"source": 0}, EngineConfig())
    prev = pg.collect(res0, fill=np.float32(np.inf))
    w32, s32 = run_sim(SSSP(), pg, {"source": 0}, EngineConfig(),
                       init_state=prev)
    w64, s64 = run_sim(SSSP(), pg, {"source": 0}, EngineConfig(),
                       init_state=prev.astype(np.float64))
    np.testing.assert_array_equal(np.asarray(w32), np.asarray(w64))
    assert s64.supersteps == s32.supersteps
    assert np.asarray(w64).dtype == np.float32

    cc0, _ = run_sim(ConnectedComponents(), pg, None, EngineConfig())
    lab = pg.collect(cc0, fill=np.iinfo(np.int32).max)
    c32, _ = run_sim(ConnectedComponents(), pg, None, EngineConfig(),
                     init_state=lab)
    c64, _ = run_sim(ConnectedComponents(), pg, None, EngineConfig(),
                     init_state=lab.astype(np.int64))
    np.testing.assert_array_equal(np.asarray(c32), np.asarray(c64))


def test_run_forwards_and_validates():
    """run() forwards init_state on the sim backend and refuses unsupported
    backend/mesh combinations instead of silently cold-starting."""
    g = powerlaw_graph(500, seed=14, weighted=True).as_undirected()
    pg = partition_and_build(g, 4, "cdbh")
    res0, _ = run_sim(SSSP(), pg, {"source": 0}, EngineConfig())
    prev = pg.collect(res0, fill=np.float32(np.inf))
    r_direct, s_direct = run_sim(SSSP(), pg, {"source": 0}, EngineConfig(),
                                 init_state=prev)
    r_run, s_run = run(SSSP(), pg, {"source": 0}, EngineConfig(),
                       init_state=prev)
    np.testing.assert_array_equal(np.asarray(r_direct), np.asarray(r_run))
    assert s_run.supersteps == s_direct.supersteps
    with pytest.raises(ValueError):
        run(SSSP(), pg, {"source": 0}, EngineConfig(backend="shard_map"))
    with pytest.raises(ValueError):
        run(SSSP(), pg, {"source": 0}, EngineConfig(backend="nope"))


# --------------------------------------------------------------------------- #
# same-batch add+delete semantics (deletes apply to the pre-delta graph)
# --------------------------------------------------------------------------- #
def test_apply_delta_same_batch_add_delete_nets_insert(tmp_path):
    """A pair in both lists of one EdgeDelta: pre-delta resident copies are
    deleted, the new copy is inserted. In-buffer producer-order cancellation
    is the DeltaBuffer's job, not apply_delta's."""
    g = powerlaw_graph(400, seed=15, weighted=True)
    d = str(tmp_path / "log")
    write_edge_log(g, d, chunk_size=1024)
    pg, ctx, _ = streaming_ingest(d, 4, "cdbh")
    n0 = pg.n_edges

    # fresh pair (src is a brand-new id, so the pair cannot be resident):
    # the delete leg is a no-op, the add leg inserts
    pair = (np.array([g.n_vertices], np.int64),
            np.array([0], np.int64))
    st = apply_delta(pg, ctx, EdgeDelta(
        add_src=pair[0], add_dst=pair[1],
        add_w=np.array([2.5], np.float32),
        del_src=pair[0], del_dst=pair[1]))
    assert st.n_added == 1 and st.n_deleted == 0
    assert pg.n_edges == n0 + 1
    assert not st.warm_start_safe   # the batch carried a delete

    # resident pair: old copy removed, new copy (new weight) inserted
    st2 = apply_delta(pg, ctx, EdgeDelta(
        add_src=pair[0], add_dst=pair[1],
        add_w=np.array([9.0], np.float32),
        del_src=pair[0], del_dst=pair[1]))
    assert st2.n_added == 1 and st2.n_deleted == 1
    assert pg.n_edges == n0 + 1
    ms = _edge_multiset(pg)
    row = ms[(ms[:, 0] == pair[0][0]) & (ms[:, 1] == pair[1][0])]
    assert row.shape[0] == 1 and row[0, 2] == 9.0


# --------------------------------------------------------------------------- #
# delta batching (DeltaBuffer)
# --------------------------------------------------------------------------- #
def test_delta_buffer_matches_sequential_applies(tmp_path):
    """A random producer op stream through the buffer produces the same
    resident edge multiset as one apply_delta per op (ops never duplicate a
    live add, so the merge coarsening is not exercised here)."""
    g = powerlaw_graph(800, seed=3, weighted=True).as_undirected()
    d = str(tmp_path / "log")
    write_edge_log(g, d, chunk_size=2048)
    pg_buf, ctx_buf, _ = streaming_ingest(d, 4, "cdbh")
    pg_seq, ctx_seq, _ = streaming_ingest(d, 4, "cdbh")

    rng = np.random.default_rng(1)
    buf = DeltaBuffer(pg_buf, ctx_buf, max_edges=64)
    live = set()
    for _ in range(500):
        s = int(rng.integers(0, g.n_vertices))
        t = int(rng.integers(0, g.n_vertices))
        if s == t:
            continue
        if rng.random() < 0.5 and (s, t) not in live:
            w = np.float32(rng.uniform(1, 2))
            buf.add(s, t, w)
            apply_delta(pg_seq, ctx_seq, EdgeDelta(
                add_src=[s], add_dst=[t], add_w=np.array([w], np.float32)))
            live.add((s, t))
        else:
            buf.delete(s, t)
            apply_delta(pg_seq, ctx_seq, EdgeDelta(del_src=[s], del_dst=[t]))
            live.discard((s, t))
    buf.flush()
    assert buf.stats.n_flushes > 1 and buf.stats.auto_flushes >= 1
    assert pg_buf.n_edges == pg_seq.n_edges
    np.testing.assert_array_equal(_edge_multiset(pg_buf),
                                  _edge_multiset(pg_seq))

    r1, _ = run_sim(ConnectedComponents(), pg_buf, None, EngineConfig())
    r2, _ = run_sim(ConnectedComponents(), pg_seq, None, EngineConfig())
    np.testing.assert_array_equal(pg_buf.collect(r1, fill=-1),
                                  pg_seq.collect(r2, fill=-1))


def test_delta_buffer_coalescing_rules(tmp_path):
    g = powerlaw_graph(300, seed=16, weighted=True)
    d = str(tmp_path / "log")
    write_edge_log(g, d, chunk_size=1024)
    pg, ctx, _ = streaming_ingest(d, 4, "cdbh")
    n0 = pg.n_edges

    buf = DeltaBuffer(pg, ctx, max_edges=None)   # manual flush only
    nv = g.n_vertices
    # add then delete of a brand-new pair cancels in-buffer
    buf.add(nv, nv + 1)
    buf.delete(nv, nv + 1)
    # duplicate adds merge to one copy, last weight wins
    buf.add(0, 1, 5.0)
    buf.add(0, 1, 7.5)
    # delete then add = replace (flushed as delete + insert)
    buf.delete(1, 2)
    buf.add(1, 2, 3.0)
    assert buf.pending_edges == 3
    st = buf.flush()
    assert buf.stats.adds_cancelled == 1
    assert buf.stats.adds_merged == 1
    assert st.n_added == 2                      # (0,1) and (1,2)
    assert pg.n_edges == n0 + 2 - st.n_deleted
    ms = _edge_multiset(pg)
    row01 = ms[(ms[:, 0] == 0) & (ms[:, 1] == 1) & (ms[:, 2] == 7.5)]
    assert row01.shape[0] == 1
    assert len(buf) == 0 and buf.flush() is None


def test_delta_buffer_new_ids_with_part_threshold(tmp_path):
    """Routing for the max_parts trigger must grow the id space first, like
    apply_delta does at flush (regression: IndexError on brand-new ids)."""
    g = powerlaw_graph(300, seed=24)
    d = str(tmp_path / "log")
    write_edge_log(g, d, chunk_size=1024)
    pg, ctx, _ = streaming_ingest(d, 4, "cdbh")
    buf = DeltaBuffer(pg, ctx, max_edges=None, max_parts=3)
    buf.add(g.n_vertices, 0)            # brand-new src id
    buf.add(g.n_vertices + 5, g.n_vertices + 6)   # both endpoints new
    buf.flush()
    assert pg.n_vertices == g.n_vertices + 7
    assert ctx.n_vertices == pg.n_vertices


def test_delta_buffer_part_threshold(tmp_path):
    g = powerlaw_graph(600, seed=17)
    d = str(tmp_path / "log")
    write_edge_log(g, d, chunk_size=1024)
    pg, ctx, _ = streaming_ingest(d, 6, "cdbh")
    buf = DeltaBuffer(pg, ctx, max_edges=None, max_parts=2)
    rng = np.random.default_rng(2)
    for _ in range(50):
        s = int(rng.integers(0, g.n_vertices))
        t = int(rng.integers(0, g.n_vertices))
        if s != t:
            buf.add(s, t)
        assert buf.pending_parts < 2 or buf.pending_edges == 0
    buf.flush()
    assert buf.stats.auto_flushes >= 1


# --------------------------------------------------------------------------- #
# membership compaction (acceptance criterion)
# --------------------------------------------------------------------------- #
def _delete_fraction(g, pg, ctx, frac, seed):
    rng = np.random.default_rng(seed)
    sel = rng.choice(g.n_edges, size=int(g.n_edges * frac / 2),
                     replace=False)
    ds = np.concatenate([g.src[sel], g.dst[sel]])
    dd = np.concatenate([g.dst[sel], g.src[sel]])
    apply_delta(pg, ctx, EdgeDelta(del_src=ds, del_dst=dd))
    kept = np.ones(g.n_edges, bool)
    key = g.src * np.int64(g.n_vertices) + g.dst
    kept[np.isin(key, ds * np.int64(g.n_vertices) + dd)] = False
    return Graph(g.n_vertices, g.src[kept], g.dst[kept], g.weights[kept])


def test_compact_shrinks_and_matches_reingest(tmp_path):
    """Delete-heavy delta -> compact shrinks v_max/e_max/n_slots versus the
    grow-only graph; a subsequent run matches a from-scratch re-ingest."""
    g = powerlaw_graph(1500, seed=6, weighted=True).as_undirected()
    d = str(tmp_path / "log")
    write_edge_log(g, d, chunk_size=2048)
    pg, ctx, _ = streaming_ingest(d, 5, "cdbh")
    g2 = _delete_fraction(g, pg, ctx, 0.7, seed=7)

    v_grow, e_grow, s_grow = pg.v_max, pg.e_max, pg.n_slots
    st = compact(pg, ctx)
    assert st.shrunk
    assert pg.v_max < v_grow and pg.e_max < e_grow and pg.n_slots < s_grow
    assert st.n_evicted > 0
    assert pg.n_edges == g2.n_edges
    # every global id is still resident exactly where collect needs it
    assert int((pg.vmask & pg.is_master).sum()) == pg.n_vertices

    # from-scratch re-ingest of the surviving edges
    d2 = str(tmp_path / "log2")
    write_edge_log(g2, d2, chunk_size=2048)
    pg2, _, _ = streaming_ingest(d2, 5, "cdbh")

    r1, _ = run_sim(ConnectedComponents(), pg, None, EngineConfig())
    r2, _ = run_sim(ConnectedComponents(), pg2, None, EngineConfig())
    np.testing.assert_array_equal(pg.collect(r1, fill=-1),
                                  pg2.collect(r2, fill=-1))
    r3, _ = run_sim(SSSP(), pg, {"source": 3}, EngineConfig())
    r4, _ = run_sim(SSSP(), pg2, {"source": 3}, EngineConfig())
    np.testing.assert_allclose(pg.collect(r3, fill=np.float32(np.inf)),
                               pg2.collect(r4, fill=np.float32(np.inf)),
                               rtol=1e-5, atol=1e-4)


def test_compact_then_delta_roundtrip(tmp_path):
    """compact -> delta -> run equals re-ingesting the final edge set from
    scratch: compaction does not break the frozen routing contract."""
    g = powerlaw_graph(1000, seed=18, weighted=True).as_undirected()
    d = str(tmp_path / "log")
    write_edge_log(g, d, chunk_size=2048)
    pg, ctx, _ = streaming_ingest(d, 5, "cdbh")
    g2 = _delete_fraction(g, pg, ctx, 0.6, seed=19)
    compact(pg, ctx)

    rng = np.random.default_rng(20)
    n_add = 300
    s = rng.integers(0, g.n_vertices, n_add).astype(np.int64)
    t = rng.integers(0, g.n_vertices, n_add).astype(np.int64)
    keep = s != t
    s, t = s[keep], t[keep]
    w = rng.uniform(1, 3, s.size).astype(np.float32)
    st = apply_delta(pg, ctx, EdgeDelta(
        add_src=np.concatenate([s, t]), add_dst=np.concatenate([t, s]),
        add_w=np.concatenate([w, w])))
    assert st.n_added == 2 * s.size

    g3 = Graph(g.n_vertices,
               np.concatenate([g2.src, s, t]), np.concatenate([g2.dst, t, s]),
               np.concatenate([g2.weights, w, w]))
    d3 = str(tmp_path / "log3")
    write_edge_log(g3, d3, chunk_size=2048)
    pg3, _, _ = streaming_ingest(d3, 5, "cdbh")
    assert pg.n_edges == pg3.n_edges

    r1, _ = run_sim(SSSP(), pg, {"source": 0}, EngineConfig())
    r2, _ = run_sim(SSSP(), pg3, {"source": 0}, EngineConfig())
    np.testing.assert_allclose(pg.collect(r1, fill=np.float32(np.inf)),
                               pg3.collect(r2, fill=np.float32(np.inf)),
                               rtol=1e-5, atol=1e-4)


def test_compact_remap_carries_state(tmp_path):
    """The remap moves live per-partition rows to their compacted slots, and
    a previous converged global result stays a valid warm start (compaction
    changes layout, never the graph)."""
    g = powerlaw_graph(1200, seed=21, weighted=True).as_undirected()
    d = str(tmp_path / "log")
    write_edge_log(g, d, chunk_size=2048)
    pg, ctx, _ = streaming_ingest(d, 5, "cdbh")
    _delete_fraction(g, pg, ctx, 0.6, seed=22)

    # distances converged on the post-delete graph (cold; deletes loosen)
    res, st_cold = run_sim(SSSP(), pg, {"source": 0}, EngineConfig())
    prev = pg.collect(res, fill=np.float32(np.inf))

    carried = np.where(pg.vmask, pg.gvid, -1).astype(np.int64)[..., None]
    st = compact(pg, ctx)
    out = st.remap_state(carried, fill=-1)
    assert out.shape == (pg.n_parts, pg.v_max, 1)
    kept = out[..., 0] >= 0
    np.testing.assert_array_equal(out[..., 0][kept], pg.gvid[kept])
    # every vertex with a resident edge was carried (only zombies/iso move)
    has_edge = np.zeros_like(pg.vmask)
    for p in range(pg.n_parts):
        m = pg.emask[p]
        has_edge[p][pg.esrc[p][m]] = True
        has_edge[p][pg.edst[p][m]] = True
    assert kept[has_edge].all()

    warm, st_warm = run_sim(SSSP(), pg, {"source": 0}, EngineConfig(),
                            init_state=prev)
    np.testing.assert_array_equal(
        pg.collect(warm, fill=np.float32(np.inf)), prev)
    assert st_warm.supersteps <= 2


def test_recompute_frontier_after_emptying_partition(tmp_path):
    """Deleting every edge of one partition leaves edge-less zombie members;
    frontier re-election and a subsequent run stay consistent, and compact
    then evicts the zombies."""
    g = powerlaw_graph(900, seed=23, weighted=True).as_undirected()
    d = str(tmp_path / "log")
    write_edge_log(g, d, chunk_size=2048)
    pg, ctx, _ = streaming_ingest(d, 5, "cdbh")

    victim = int(np.argmax(pg.edges_per_part))
    m = pg.emask[victim]
    ds = pg.gvid[victim][pg.esrc[victim][m]]
    dd = pg.gvid[victim][pg.edst[victim][m]]
    # drop the reverse copies too: the undirected pairs live elsewhere
    st = apply_delta(pg, ctx, EdgeDelta(
        del_src=np.concatenate([ds, dd]), del_dst=np.concatenate([dd, ds])))
    assert st.n_deleted >= ds.shape[0]
    assert pg.edges_per_part[victim] == 0
    # zombie members survive the delete (grow-only membership)...
    assert pg.vertices_per_part[victim] > 0

    kept = np.ones(g.n_edges, bool)
    key = g.src * np.int64(g.n_vertices) + g.dst
    dkey = np.concatenate([ds, dd]) * np.int64(g.n_vertices) \
        + np.concatenate([dd, ds])
    kept[np.isin(key, dkey)] = False
    g2 = Graph(g.n_vertices, g.src[kept], g.dst[kept], g.weights[kept])
    pg2 = partition_and_build(g2, 5, "cdbh")
    r1, _ = run_sim(ConnectedComponents(), pg, None, EngineConfig())
    r2, _ = run_sim(ConnectedComponents(), pg2, None, EngineConfig())
    np.testing.assert_array_equal(pg.collect(r1, fill=-1),
                                  pg2.collect(r2, fill=-1))

    # ...until compact evicts them (only re-homed isolated ids may remain)
    cs = compact(pg, ctx)
    assert cs.n_evicted > 0
    touched = np.zeros(pg.n_vertices, bool)
    for p in range(pg.n_parts):
        em = pg.emask[p]
        touched[pg.gvid[p][pg.esrc[p][em]]] = True
        touched[pg.gvid[p][pg.edst[p][em]]] = True
    vm = pg.vmask[victim]
    assert not touched[pg.gvid[victim][vm]].any()
    r3, _ = run_sim(ConnectedComponents(), pg, None, EngineConfig())
    np.testing.assert_array_equal(pg.collect(r3, fill=-1),
                                  pg2.collect(r2, fill=-1))
