"""Streaming subsystem: edge-log IO, two-pass out-of-core ingest parity with
the in-memory path (bit-identical per partition), chunk-bounded memory
accounting, incremental delta patching, and warm-start recompute."""
import numpy as np
import pytest

from repro.algos import ConnectedComponents, PageRank, SSSP
from repro.core import EngineConfig, partition_and_build, run_sim
from repro.core.graph import Graph
from repro.graphgen import powerlaw_graph
from repro.stream import (EdgeDelta, EdgeLogReader, EdgeLogWriter,
                          apply_delta, streaming_ingest, write_edge_log)
from repro.stream.edgelog import BYTES_PER_EDGE

PARITY_ARRAYS = ("gvid", "vmask", "esrc", "edst", "ew", "emask", "slot",
                 "is_frontier", "out_deg", "in_deg", "is_master",
                 "frontier_gvid")


@pytest.fixture(scope="module")
def big_graph():
    """Power-law graph with >= 100k edges (acceptance-criterion scale)."""
    g = powerlaw_graph(20_000, alpha=2.2, avg_degree=8, seed=11,
                       weighted=True)
    assert g.n_edges >= 100_000, g.n_edges
    return g


@pytest.fixture(scope="module")
def big_log(big_graph, tmp_path_factory):
    d = str(tmp_path_factory.mktemp("edgelog"))
    meta = write_edge_log(big_graph, d, chunk_size=16_384)
    assert meta.n_edges == big_graph.n_edges
    assert meta.n_chunks == -(-big_graph.n_edges // 16_384)
    return d


# --------------------------------------------------------------------------- #
# edge log
# --------------------------------------------------------------------------- #
def test_edgelog_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    src = rng.integers(0, 500, 10_000).astype(np.int64)
    dst = rng.integers(0, 500, 10_000).astype(np.int64)
    w = rng.uniform(0, 1, 10_000).astype(np.float32)
    with EdgeLogWriter(str(tmp_path / "log"), chunk_size=999,
                       weighted=True) as wr:
        for lo in range(0, 10_000, 1303):   # appends misaligned with chunks
            hi = min(lo + 1303, 10_000)
            wr.append(src[lo:hi], dst[lo:hi], w[lo:hi])
    rd = EdgeLogReader(str(tmp_path / "log"))
    assert rd.meta.n_edges == 10_000
    assert rd.meta.n_vertices == int(max(src.max(), dst.max())) + 1
    s, d, ww = rd.read_all()
    np.testing.assert_array_equal(s, src)
    np.testing.assert_array_equal(d, dst)
    np.testing.assert_array_equal(ww, w)
    # every chunk except the last is exactly chunk_size
    sizes = [c[0].shape[0] for c in rd.chunks()]
    assert all(n == 999 for n in sizes[:-1]) and sum(sizes) == 10_000


def test_edgelog_empty(tmp_path):
    with EdgeLogWriter(str(tmp_path / "log"), chunk_size=8) as wr:
        pass
    rd = EdgeLogReader(str(tmp_path / "log"))
    assert rd.meta.n_edges == 0 and rd.meta.n_chunks == 0
    s, d, w = rd.read_all()
    assert s.size == 0 and w is None


# --------------------------------------------------------------------------- #
# two-pass ingest parity (acceptance criterion)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("part", ["cdbh", "rh-ec"])
def test_streaming_parity(big_graph, big_log, part):
    """Chunked two-pass ingest == one-shot in-memory partitioning,
    bit-identical per partition, on a >= 100k-edge power-law graph."""
    n_parts = 8
    pg_mem = partition_and_build(big_graph, n_parts, part)
    pg_st, ctx, stats = streaming_ingest(big_log, n_parts, part)
    assert (pg_st.n_parts, pg_st.n_vertices, pg_st.n_edges, pg_st.n_slots,
            pg_st.v_max, pg_st.e_max) == \
           (pg_mem.n_parts, pg_mem.n_vertices, pg_mem.n_edges,
            pg_mem.n_slots, pg_mem.v_max, pg_mem.e_max)
    for name in PARITY_ARRAYS:
        np.testing.assert_array_equal(getattr(pg_st, name),
                                      getattr(pg_mem, name), err_msg=name)
    # chunk accounting: the streaming passes never held O(|E|) edge bytes
    assert stats.peak_stream_bytes <= stats.stream_bound_bytes
    full_bytes = big_graph.n_edges * BYTES_PER_EDGE
    assert stats.stream_bound_bytes < full_bytes / 2, \
        "chunk bound is not meaningfully below the full edge list"
    # routing context snapshot matches the full-degree table
    np.testing.assert_array_equal(ctx.routing_degrees,
                                  big_graph.total_degrees())


def test_streaming_rejects_stateful_partitioner(big_log):
    with pytest.raises(ValueError):
        streaming_ingest(big_log, 4, "greedy-ec")


def test_streaming_isolated_vertices(tmp_path):
    """Vertices with no edges get the same hash round-robin placement."""
    g = Graph(50, np.array([0, 1, 2], np.int64), np.array([1, 2, 3], np.int64))
    d = str(tmp_path / "log")
    write_edge_log(g, d, chunk_size=2)
    pg_mem = partition_and_build(g, 4, "cdbh")
    pg_st, _, _ = streaming_ingest(d, 4, "cdbh")
    for name in PARITY_ARRAYS:
        np.testing.assert_array_equal(getattr(pg_st, name),
                                      getattr(pg_mem, name), err_msg=name)


# --------------------------------------------------------------------------- #
# delta patching
# --------------------------------------------------------------------------- #
def _edge_multiset(pg):
    """Global (src, dst, w) multiset of resident edges, canonically sorted."""
    rows = []
    for p in range(pg.n_parts):
        m = pg.emask[p]
        rows.append(np.stack([pg.gvid[p][pg.esrc[p][m]].astype(np.float64),
                              pg.gvid[p][pg.edst[p][m]].astype(np.float64),
                              pg.ew[p][m].astype(np.float64)], 1))
    rows = np.concatenate(rows, 0)
    return rows[np.lexsort(rows.T)]


def test_delta_insert_matches_full_reingest(tmp_path):
    """Insert-only delta == re-ingesting the grown log with the same frozen
    routing degrees: same residency, membership superset-free, same slots."""
    g = powerlaw_graph(3000, seed=4, weighted=True)
    d = str(tmp_path / "log")
    write_edge_log(g, d, chunk_size=4096)
    pg, ctx, _ = streaming_ingest(d, 6, "cdbh")

    rng = np.random.default_rng(5)
    n_add = 500
    asrc = rng.integers(0, g.n_vertices, n_add).astype(np.int64)
    adst = rng.integers(0, g.n_vertices, n_add).astype(np.int64)
    aw = rng.uniform(1, 2, n_add).astype(np.float32)
    st = apply_delta(pg, ctx, EdgeDelta(add_src=asrc, add_dst=adst, add_w=aw))
    assert st.n_added == n_add and st.n_deleted == 0 and st.warm_start_safe
    assert pg.n_edges == g.n_edges + n_add

    # reference: route the grown edge list through the SAME frozen degrees
    from repro.core import build_partitioned_graph
    g2 = Graph(g.n_vertices, np.concatenate([g.src, asrc]),
               np.concatenate([g.dst, adst]),
               np.concatenate([g.weights, aw]))
    from repro.core.partition import route_edges_cdbh
    part2 = route_edges_cdbh(g2.src, g2.dst, ctx.routing_degrees, 6)
    pg2 = build_partitioned_graph(g2, part2, 6)

    np.testing.assert_array_equal(_edge_multiset(pg), _edge_multiset(pg2))
    # membership, slots and masters agree exactly (insert-only => no stale)
    assert pg.n_slots == pg2.n_slots
    for p in range(6):
        np.testing.assert_array_equal(pg.gvid[p][pg.vmask[p]],
                                      pg2.gvid[p][pg2.vmask[p]])
        np.testing.assert_array_equal(
            pg.is_master[p][pg.vmask[p]], pg2.is_master[p][pg2.vmask[p]])
        np.testing.assert_array_equal(
            pg.slot[p][pg.vmask[p]], pg2.slot[p][pg2.vmask[p]])
        np.testing.assert_array_equal(
            pg.out_deg[p][pg.vmask[p]], pg2.out_deg[p][pg2.vmask[p]])


def test_delta_delete_and_results(tmp_path):
    """Deletions remove resident copies; engine results match a fresh build
    of the mutated graph (undirected CC + SSSP)."""
    g = powerlaw_graph(1200, seed=6, weighted=True).as_undirected()
    d = str(tmp_path / "log")
    write_edge_log(g, d, chunk_size=2048)
    pg, ctx, _ = streaming_ingest(d, 5, "cdbh")

    rng = np.random.default_rng(7)
    sel = rng.choice(g.n_edges, size=200, replace=False)
    # undirected storage: drop both directions of each sampled edge
    ds = np.concatenate([g.src[sel], g.dst[sel]])
    dd = np.concatenate([g.dst[sel], g.src[sel]])
    st = apply_delta(pg, ctx, EdgeDelta(del_src=ds, del_dst=dd))
    assert st.n_deleted > 0 and not st.warm_start_safe
    assert pg.n_edges == int(pg.emask.sum())

    kept = np.ones(g.n_edges, bool)
    key = g.src * np.int64(g.n_vertices) + g.dst
    kept[np.isin(key, ds * np.int64(g.n_vertices) + dd)] = False
    g2 = Graph(g.n_vertices, g.src[kept], g.dst[kept], g.weights[kept])
    pg2 = partition_and_build(g2, 5, "cdbh")
    assert pg.n_edges == g2.n_edges

    r1, _ = run_sim(ConnectedComponents(), pg, None, EngineConfig())
    r2, _ = run_sim(ConnectedComponents(), pg2, None, EngineConfig())
    np.testing.assert_array_equal(pg.collect(r1, fill=-1),
                                  pg2.collect(r2, fill=-1))
    r3, _ = run_sim(SSSP(), pg, {"source": 3}, EngineConfig())
    r4, _ = run_sim(SSSP(), pg2, {"source": 3}, EngineConfig())
    np.testing.assert_allclose(pg.collect(r3, fill=np.float32(np.inf)),
                               pg2.collect(r4, fill=np.float32(np.inf)),
                               rtol=1e-5, atol=1e-4)


def test_delta_grows_vertex_space(tmp_path):
    g = powerlaw_graph(500, seed=8).as_undirected()
    d = str(tmp_path / "log")
    write_edge_log(g, d, chunk_size=1024)
    pg, ctx, _ = streaming_ingest(d, 4, "cdbh")
    old_v = pg.n_vertices
    # attach a chain of brand-new vertices to vertex 0
    new = np.arange(old_v, old_v + 10, dtype=np.int64)
    chain_s = np.concatenate([[0], new[:-1]])
    st = apply_delta(pg, ctx, EdgeDelta(
        add_src=np.concatenate([chain_s, new]),
        add_dst=np.concatenate([new, chain_s])))
    assert pg.n_vertices == old_v + 10 and ctx.n_vertices == old_v + 10
    assert st.n_added == 20
    r, _ = run_sim(ConnectedComponents(), pg, None, EngineConfig())
    lab = pg.collect(r, fill=-1)
    assert (lab[new] == lab[0]).all(), "new chain joins vertex 0's component"


def test_recompute_frontier_is_idempotent(tmp_path):
    from repro.core import recompute_frontier
    g = powerlaw_graph(800, seed=9)
    pg = partition_and_build(g, 5, "cdbh")
    before = {n: getattr(pg, n).copy() for n in
              ("slot", "is_frontier", "is_master", "frontier_gvid")}
    recompute_frontier(pg)
    for n, arr in before.items():
        np.testing.assert_array_equal(arr, getattr(pg, n), err_msg=n)


# --------------------------------------------------------------------------- #
# warm-start recompute (acceptance criterion)
# --------------------------------------------------------------------------- #
def test_warm_start_sssp_after_insert_batch(tmp_path):
    """After a ~1% edge-insert batch, warm-start SSSP converges in fewer
    supersteps than cold start and matches it to np.allclose."""
    g = powerlaw_graph(4000, seed=2, weighted=True).as_undirected()
    d = str(tmp_path / "log")
    write_edge_log(g, d, chunk_size=8192)
    pg, ctx, _ = streaming_ingest(d, 5, "cdbh")
    res0, _ = run_sim(SSSP(), pg, {"source": 0}, EngineConfig())
    prev = pg.collect(res0, fill=np.float32(np.inf))

    rng = np.random.default_rng(3)
    n_add = g.n_edges // 200          # ~1% counting both directions
    asrc = rng.integers(0, g.n_vertices, n_add)
    adst = rng.integers(0, g.n_vertices, n_add)
    keep = asrc != adst
    asrc, adst = asrc[keep], adst[keep]
    # mid/high-weight inserts: distances improve only locally, which is the
    # regime where incremental recompute pays off (a tiny-weight shortcut
    # into a hub can legitimately cascade as far as a cold start).
    aw = rng.uniform(5, 10, asrc.size).astype(np.float32)
    st = apply_delta(pg, ctx, EdgeDelta(
        add_src=np.concatenate([asrc, adst]),
        add_dst=np.concatenate([adst, asrc]),
        add_w=np.concatenate([aw, aw])))
    assert st.warm_start_safe

    cold, st_cold = run_sim(SSSP(), pg, {"source": 0}, EngineConfig())
    warm, st_warm = run_sim(SSSP(), pg, {"source": 0}, EngineConfig(),
                            init_state=prev)
    c = pg.collect(cold, fill=np.float32(np.inf))
    w = pg.collect(warm, fill=np.float32(np.inf))
    fin = np.isfinite(c)
    assert np.allclose(w[fin], c[fin], rtol=1e-5, atol=1e-4)
    assert np.isinf(w[~fin]).all()
    assert st_warm.supersteps < st_cold.supersteps, \
        (st_warm.supersteps, st_cold.supersteps)


def test_warm_start_cc(tmp_path):
    g = powerlaw_graph(2000, seed=12).as_undirected()
    pg = partition_and_build(g, 4, "cdbh")
    res0, _ = run_sim(ConnectedComponents(), pg, None, EngineConfig())
    prev = pg.collect(res0, fill=-1)
    warm, st_w = run_sim(ConnectedComponents(), pg, None, EngineConfig(),
                         init_state=prev)
    np.testing.assert_array_equal(pg.collect(warm, fill=-1), prev)
    assert st_w.supersteps <= 2, "already-converged warm start is immediate"


def test_warm_start_nonmonotone_falls_back_cold():
    g = powerlaw_graph(600, seed=13)
    pg = partition_and_build(g, 4, "cdbh")
    cfg = EngineConfig(max_local_iters=300, max_supersteps=3000)
    pr = PageRank(tol=1e-9)
    r1, _ = run_sim(pr, pg, {"n_vertices": g.n_vertices}, cfg)
    # bogus init_state must be ignored (cold-start correctness fallback)
    r2, _ = run_sim(pr, pg, {"n_vertices": g.n_vertices}, cfg,
                    init_state=np.full(g.n_vertices, 123.0, np.float32))
    np.testing.assert_array_equal(r1, r2)
