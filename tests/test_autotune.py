"""edge_backend='auto' policy tests: calibration-cache determinism, the
mixed-density fixture where every backend wins at least one partition,
auto-vs-COO result parity, and the zero-retrace pin that in-bucket
streaming growth never flips a partition's resolved backend mid-session
(both engine backends — the shard_map half runs in a subprocess like every
multi-device test)."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.algos import PageRank, SSSP
from repro.analysis.sanitizer import retrace_guard
from repro.core import (EngineConfig, build_partitioned_graph,
                        partition_and_build, run_sim)
from repro.core import autotune
from repro.core.engine import (normalize_edge_backend,
                               resolve_partition_backends)
from repro.core.graph import Graph
from repro.graphgen import powerlaw_graph
from repro.session import GraphSession

PR_TOL = dict(rtol=1e-5, atol=1e-8)


@pytest.fixture(autouse=True)
def _isolated_cache(tmp_path, monkeypatch):
    monkeypatch.setenv("DRONE_AUTOTUNE_DIR", str(tmp_path))


def _mixed_density_graph():
    """Three 256-vertex blocks — dense (~50%), mid (~6%), ultra-sparse
    (~100 edges) — each mapped to its own partition, so the modeled costs
    put a different winner on each: tiles (dense amortizes the fixed MXU
    tile traffic), windows (~8 B/edge beats COO's ~24), COO (the kernel
    coverage floors dominate a hundred edges)."""
    rng = np.random.default_rng(42)
    B = 256
    src, dst, part = [], [], []

    def block(lo, n_edges, pid):
        s = rng.integers(lo, lo + B, n_edges)
        d = rng.integers(lo, lo + B, n_edges)
        keep = s != d
        src.append(s[keep]); dst.append(d[keep])
        part.append(np.full(int(keep.sum()), pid, np.int64))

    block(0, int(0.50 * B * B), 0)      # dense
    block(B, int(0.06 * B * B), 1)      # mid
    block(2 * B, 100, 2)                # ultra-sparse
    src = np.concatenate(src); dst = np.concatenate(dst)
    part = np.concatenate(part)
    w = rng.random(src.size).astype(np.float32) + 0.1
    g = Graph(3 * B, src, dst, w)
    return g, build_partitioned_graph(g, part, 3)


# --------------------------------------------------------------------------- #
# calibration cache: deterministic replay
# --------------------------------------------------------------------------- #
def test_calibration_deterministic(tmp_path):
    t1 = autotune.calibrate()
    t2 = autotune.calibrate()
    assert t1.to_json() == t2.to_json(), \
        "same platform must produce a byte-identical calibration table"
    _, pg = _mixed_density_graph()
    lay = pg.ensure_edge_layouts()
    p1 = autotune.pick_backends(t1, pg, lay)
    p2 = autotune.pick_backends(t2, pg, lay)
    assert p1 == p2


def test_table_disk_roundtrip():
    t1 = autotune.get_table(force=True)
    path = autotune.table_path(t1.platform)
    assert os.path.exists(path)
    t2 = autotune.load_table(t1.platform)
    assert t2 is not None and t2.to_json() == t1.to_json()
    # a second get_table serves the cached file, not a fresh sweep
    t3 = autotune.get_table()
    assert t3.to_json() == t1.to_json()


def test_schema_mismatch_invalidates():
    t1 = autotune.get_table(force=True)
    raw = t1.to_json().replace(f'"version": {autotune.SCHEMA_VERSION}',
                               '"version": 999')
    with pytest.raises(ValueError):
        autotune.CalibrationTable.from_json(raw)


# --------------------------------------------------------------------------- #
# the acceptance fixture: every backend wins somewhere
# --------------------------------------------------------------------------- #
def test_mixed_density_picks_all_three_backends():
    _, pg = _mixed_density_graph()
    lay = pg.ensure_edge_layouts()
    cfg = EngineConfig(edge_backend="auto")
    asg = resolve_partition_backends(SSSP(), cfg, pg, lay=lay)
    assert len(asg) == pg.n_parts
    assert set(asg) == {"coo", "pallas_tiles", "pallas_windows"}, \
        f"auto must pick each backend on the mixed fixture, got {asg}"
    assert asg[0] == "pallas_tiles" and asg[2] == "coo", asg


def test_auto_matches_coo_and_bills_per_partition():
    g, pg = _mixed_density_graph()
    want, _ = run_sim(SSSP(), pg, {"source": 0}, EngineConfig())
    got, st = run_sim(SSSP(), pg, {"source": 0},
                      EngineConfig(edge_backend="auto"))
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))
    assert st.edge_backend == "auto"
    assert len(st.partition_edge_backends) == pg.n_parts
    assert set(st.partition_edge_backends) == {"coo", "pallas_tiles",
                                               "pallas_windows"}
    assert len(st.partition_tile_density) == pg.n_parts
    assert st.partition_tile_density[0] > st.partition_tile_density[2]
    assert st.backend_flops > 0

    want_pr, _ = run_sim(PageRank(tol=1e-7), pg,
                         {"n_vertices": g.n_vertices}, EngineConfig())
    got_pr, _ = run_sim(PageRank(tol=1e-7), pg,
                        {"n_vertices": g.n_vertices},
                        EngineConfig(edge_backend="auto"))
    np.testing.assert_allclose(np.asarray(want_pr), np.asarray(got_pr),
                               **PR_TOL)


def test_non_sweep_program_normalizes_to_coo():
    from repro.algos.mssp import make_mssp
    prog, _ = make_mssp([0, 5])
    eb, cfg = normalize_edge_backend(prog, EngineConfig(edge_backend="auto"))
    assert eb == "coo" and cfg.edge_backend == "coo"


# --------------------------------------------------------------------------- #
# zero-retrace pin: in-bucket growth never flips the resolved backend
# --------------------------------------------------------------------------- #
def test_auto_inbucket_flush_never_flips_sim():
    g = powerlaw_graph(900, seed=5, weighted=True).as_undirected()
    sess = GraphSession.from_graph(g, 4, "ebv",
                                   cfg=EngineConfig(edge_backend="auto"))
    _, st0 = sess.query(SSSP(), {"source": 0})
    asg0 = tuple(st0.partition_edge_backends)
    lay = sess.pg.edge_layouts
    caps = (lay.t_max, lay.b_max)
    rng = np.random.default_rng(7)
    s = rng.integers(0, g.n_vertices, 30)
    d = rng.integers(0, g.n_vertices, 30)
    keep = s != d
    sess.update(adds=(s[keep], d[keep],
                      np.ones(int(keep.sum()), np.float32)))
    sess.flush()
    assert (lay.t_max, lay.b_max) == caps, "in-bucket by design"
    with retrace_guard(label="auto: in-bucket flush requery"):
        _, st1 = sess.query(SSSP(), {"source": 0})
    assert tuple(st1.partition_edge_backends) == asg0, \
        "in-bucket growth flipped a pinned backend"
    assert st1.compile_time == 0.0
    assert sess.stats.cache_misses == 1


AUTO_SHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["DRONE_AUTOTUNE_DIR"] = os.environ["AUTOTUNE_TMP"]
import jax
import numpy as np
from jax.sharding import Mesh

from repro.algos import SSSP
from repro.analysis.sanitizer import retrace_guard
from repro.core import EngineConfig
from repro.graphgen import powerlaw_graph
from repro.session import GraphSession

g = powerlaw_graph(900, seed=5, weighted=True).as_undirected()
mesh = Mesh(np.array(jax.devices()[:4]).reshape(4), ("sub",))
sess = GraphSession.from_graph(g, 4, "ebv", mesh=mesh,
                               cfg=EngineConfig(edge_backend="auto"))
res, st0 = sess.query(SSSP(), {"source": 0})
asg0 = tuple(st0.partition_edge_backends)
assert len(asg0) == 4, asg0

# reference: a simulator session over the IDENTICAL partitioning (same
# router, seed, policy) on the pure-COO path
ref = GraphSession.from_graph(g, 4, "ebv")
want, _ = ref.query(SSSP(), {"source": 0}, cfg=EngineConfig(
    edge_backend="coo"))
np.testing.assert_array_equal(np.asarray(want), np.asarray(res))

lay = sess.pg.edge_layouts
caps = (lay.t_max, lay.b_max)
rng = np.random.default_rng(7)
s = rng.integers(0, g.n_vertices, 30)
d = rng.integers(0, g.n_vertices, 30)
keep = s != d
sess.update(adds=(s[keep], d[keep], np.ones(int(keep.sum()), np.float32)))
sess.flush()
assert (lay.t_max, lay.b_max) == caps, "in-bucket by design"
with retrace_guard(label="auto/shard_map: in-bucket flush requery"):
    _, st1 = sess.query(SSSP(), {"source": 0})
assert tuple(st1.partition_edge_backends) == asg0, (asg0,
    st1.partition_edge_backends)
assert st1.compile_time == 0.0, st1.compile_time
print("AUTO_SHARD_OK")
"""


def test_auto_inbucket_flush_never_flips_shard_map(tmp_path):
    env = dict(os.environ, AUTOTUNE_TMP=str(tmp_path))
    res = subprocess.run([sys.executable, "-c", AUTO_SHARD_SCRIPT],
                         capture_output=True, text=True, timeout=1200,
                         env=env)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "AUTO_SHARD_OK" in res.stdout
