"""Extensions: multi-source SSSP (vector payloads), BSP checkpoint/resume."""
import numpy as np
import networkx as nx

from repro.algos.mssp import make_mssp
from repro.algos import ConnectedComponents
from repro.core import EngineConfig, partition_and_build, run_sim
from repro.graphgen import powerlaw_graph, random_graph


def test_multi_source_sssp_matches_oracle():
    g = random_graph(300, 1500, seed=8, weighted=True)
    pg = partition_and_build(g, 5, "cdbh")
    sources = [0, 17, 42, 99]
    prog, params = make_mssp(sources)
    res, stats = run_sim(prog, pg, params, EngineConfig(mode="sc"))
    dist = pg.collect(res, fill=np.float32(np.inf))   # [V, K]
    G = nx.DiGraph()
    G.add_nodes_from(range(g.n_vertices))
    for s, d, w in zip(g.src.tolist(), g.dst.tolist(), g.weights.tolist()):
        if not G.has_edge(s, d) or G[s][d]["weight"] > w:
            G.add_edge(s, d, weight=w)
    for k, src in enumerate(sources):
        ref = np.full(g.n_vertices, np.inf)
        for v, d in nx.single_source_dijkstra_path_length(G, src).items():
            ref[v] = d
        finite = np.isfinite(ref)
        np.testing.assert_allclose(dist[finite, k], ref[finite], rtol=1e-5,
                                   atol=1e-4)
        assert np.isinf(dist[~finite, k]).all()
    assert stats.supersteps >= 1


def test_bsp_checkpoint_resume(tmp_path):
    """Graph-engine fault tolerance: run to completion == run with a mid-job
    checkpoint + restart from it."""
    g = powerlaw_graph(800, seed=10).as_undirected()
    pg = partition_and_build(g, 6, "cdbh")
    cc = ConnectedComponents()
    full, st_full = run_sim(cc, pg, None, EngineConfig(mode="vc", trace=True))
    assert st_full.supersteps > 2, "need a multi-superstep job for this test"

    ck = EngineConfig(mode="vc", trace=True, checkpoint_every=2,
                      checkpoint_dir=str(tmp_path))
    _, _ = run_sim(cc, pg, None, ck)
    ckpt = str(tmp_path / "bsp_000002.npz")
    resumed, st_res = run_sim(cc, pg, None,
                              EngineConfig(mode="vc", trace=True),
                              resume_from=ckpt)
    np.testing.assert_array_equal(full, resumed)
    assert st_res.supersteps <= st_full.supersteps


def test_bsp_checkpoint_creates_missing_dir(tmp_path):
    """Regression: checkpoint_dir that does not exist yet is created before
    the first save, and the written checkpoint round-trips via resume_from."""
    g = powerlaw_graph(800, seed=10).as_undirected()
    pg = partition_and_build(g, 6, "cdbh")
    cc = ConnectedComponents()
    full, st_full = run_sim(cc, pg, None, EngineConfig(mode="vc", trace=True))
    assert st_full.supersteps > 2

    ckdir = tmp_path / "does" / "not" / "exist"   # never mkdir'd here
    ck = EngineConfig(mode="vc", trace=True, checkpoint_every=2,
                      checkpoint_dir=str(ckdir))
    run_sim(cc, pg, None, ck)
    assert (ckdir / "bsp_000002.npz").exists()
    resumed, _ = run_sim(cc, pg, None, EngineConfig(mode="vc", trace=True),
                         resume_from=str(ckdir / "bsp_000002.npz"))
    np.testing.assert_array_equal(full, resumed)
