"""Shape-bucketed serving: ShapePolicy math, bucket-boundary flushes,
compact-to-bucket-floor runner stability, LRU eviction order under mixed
multi-algorithm traffic, and warm-memory carry across a bucket growth
(ISSUE 4 tentpole; docs/ARCHITECTURE.md "shape-bucket lifecycle")."""
import numpy as np
import pytest


from repro.algos import ConnectedComponents, PageRank, SSSP
from repro.analysis.sanitizer import retrace_guard
from repro.core import ShapePolicy, partition_and_build, run_sim
from repro.core.engine import EngineConfig
from repro.graphgen import powerlaw_graph
from repro.session import GraphSession
from repro.stream import EdgeDelta, StreamContext, apply_delta


# --------------------------------------------------------------------------- #
# policy math
# --------------------------------------------------------------------------- #
def test_bucket_series_is_geometric():
    p = ShapePolicy(growth=2.0, pad_multiple=8)
    assert [p.bucket(n) for n in (1, 8, 9, 16, 17, 100, 1000)] == \
        [8, 8, 16, 16, 32, 128, 1024]
    # bucket values are fixed points: landing on a boundary stays there
    for n in (8, 16, 32, 64, 1024):
        assert p.bucket(n) == n
    # monotone and always sufficient
    last = 0
    for n in range(1, 3000, 37):
        b = p.bucket(n)
        assert b >= n and b >= last
        last = b


def test_exact_policy_is_legacy_round_up():
    p = ShapePolicy.exact(pad_multiple=8)
    for n in (1, 7, 8, 9, 100, 1001):
        assert p.bucket(n) == -(-n // 8) * 8
    # exact policy never buckets the slot count (legacy shape key)
    assert p.slot_capacity(701) == 701
    assert ShapePolicy().slot_capacity(701) == 1024


def test_headroom_rounds_up_early():
    assert ShapePolicy(growth=2.0, headroom=1.5, pad_multiple=8).bucket(12) \
        == 32  # 12 * 1.5 = 18 -> next bucket after 16
    assert ShapePolicy(growth=2.0, headroom=1.0, pad_multiple=8).bucket(12) \
        == 16


def test_policy_validates():
    with pytest.raises(ValueError, match="growth"):
        ShapePolicy(growth=0.5)
    with pytest.raises(ValueError, match="headroom"):
        ShapePolicy(headroom=0.9)
    with pytest.raises(ValueError, match="pad_multiple"):
        ShapePolicy(pad_multiple=0)
    assert hash(ShapePolicy()) is not None  # usable inside cache keys


# --------------------------------------------------------------------------- #
# delta remap (the carry mechanism behind warm-across-growth)
# --------------------------------------------------------------------------- #
def test_delta_remap_carries_rows():
    g = powerlaw_graph(300, seed=5, weighted=True).as_undirected()
    pg = partition_and_build(g, 4, "cdbh")
    ctx = StreamContext(partitioner="cdbh", n_parts=4, seed=0,
                        n_vertices=g.n_vertices,
                        routing_degrees=g.total_degrees())
    # state[p, i] = that row's global id, so carried rows are self-checking
    state = pg.gvid.astype(np.int64).copy()
    state[~pg.vmask] = -1
    new = np.arange(g.n_vertices, g.n_vertices + 40, dtype=np.int64)
    st = apply_delta(pg, ctx, EdgeDelta(
        add_src=np.concatenate([np.zeros(40, np.int64), new]),
        add_dst=np.concatenate([new, np.zeros(40, np.int64)])))
    assert st.remap is not None and st.v_max_before == st.remap.shape[1]
    carried = st.remap_state(state, fill=-1)
    assert carried.shape == (pg.n_parts, pg.v_max)
    # every surviving row landed on the row now holding its global id;
    # brand-new members (and padding) hold the fill
    expect = pg.gvid.astype(np.int64).copy()
    expect[~pg.vmask] = -1
    expect[pg.vmask & ~np.isin(pg.gvid, state[state >= 0])] = -1
    np.testing.assert_array_equal(carried, expect)


# --------------------------------------------------------------------------- #
# session-level bucket lifecycle
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def graph():
    return powerlaw_graph(600, seed=3, weighted=True).as_undirected()


def _distinct_resident_pairs(pg, p):
    """Distinct (src, dst) global-id pairs resident in partition p."""
    m = pg.emask[p]
    gs = pg.gvid[p][pg.esrc[p][m]]
    gd = pg.gvid[p][pg.edst[p][m]]
    key = gs * np.int64(pg.n_vertices) + gd
    _, idx = np.unique(key, return_index=True)
    return gs[idx], gd[idx]


def test_flush_exactly_at_bucket_boundary_keeps_runner(graph):
    """Fill the most-slack partition's edge capacity to exactly e_max with
    parallel copies of resident pairs (membership untouched): need == bucket
    is *inside* the bucket, so the compiled runner survives; one more edge
    crosses the boundary and rebuilds exactly once."""
    sess = GraphSession.from_graph(graph, 4, "cdbh")
    sess.query(SSSP(), {"source": 0})
    key0 = sess.shape_key
    pg = sess.pg

    p = int(np.argmin(pg.edges_per_part))
    gs, gd = _distinct_resident_pairs(pg, p)
    slack = int(pg.e_max - pg.edges_per_part[p])
    assert slack > 0, "bucketed padding should leave slack"
    while slack > 0:                       # parallel copies, heavy weights
        k = min(slack, gs.shape[0])
        sess.update(adds=(gs[:k], gd[:k], np.full(k, 77.0, np.float32)))
        st = sess.flush()
        assert not st.repadded
        slack -= k
    assert int(sess.pg.edges_per_part[p]) == sess.pg.e_max  # exactly full
    assert sess.shape_key == key0

    misses = sess.stats.cache_misses
    with retrace_guard(label="bucket-boundary query"):
        r_at, s_at = sess.query(SSSP(), {"source": 0})
    assert s_at.compile_time == 0.0
    assert sess.stats.cache_misses == misses

    # one edge past the boundary: the bucket grows, one rebuild
    sess.update(adds=(gs[:1], gd[:1], [77.0]))
    st = sess.flush()
    assert st.repadded and sess.shape_key != key0
    _, s_over = sess.query(SSSP(), {"source": 0})
    assert s_over.compile_time > 0.0
    assert sess.stats.cache_misses == misses + 1
    np.testing.assert_array_equal(
        sess.pg.collect(np.asarray(r_at), fill=np.float32(np.inf)),
        sess.pg.collect(np.asarray(sess.query(SSSP(), {"source": 0},
                                              warm=False)[0]),
                        fill=np.float32(np.inf)))


def test_slot_bucket_absorbs_frontier_churn(graph):
    """Inserts that change n_slots (new replicas) but stay inside the slot
    bucket keep the shape key — the churn legacy exact shapes would always
    recompile on."""
    sess = GraphSession.from_graph(graph, 4, "cdbh")
    sess.query(ConnectedComponents())
    key0, slots0 = sess.shape_key, sess.pg.n_slots
    rng = np.random.default_rng(0)
    s = rng.integers(0, graph.n_vertices, 64).astype(np.int64)
    d = (s + graph.n_vertices // 2) % graph.n_vertices
    keep = s != d
    sess.update(adds=(np.concatenate([s[keep], d[keep]]),
                      np.concatenate([d[keep], s[keep]])))
    sess.flush()
    assert sess.pg.n_slots != slots0, "expected the frontier to re-elect"
    assert sess.shape_key == key0, "slot bucket must absorb the churn"
    with retrace_guard(label="post-churn CC query"):
        _, st = sess.query(ConnectedComponents())
    assert st.compile_time == 0.0


def test_compact_to_bucket_floor_then_regrow_rehits_runner(graph):
    """delete -> compact -> re-insert staying inside one bucket: the padded
    shapes never move, so the original compiled runner serves the whole
    sequence (trace-counter pinned; at HEAD, compact's exact-minimum shrink
    evicted everything)."""
    sess = GraphSession.from_graph(graph, 4, "cdbh")
    sess.query(SSSP(), {"source": 0})
    key0 = sess.shape_key
    assert sess.stats.cache_misses == 1

    n_del = graph.n_edges // 20
    ds, dd = graph.src[:n_del], graph.dst[:n_del]
    sess.update(deletes=(np.concatenate([ds, dd]),
                         np.concatenate([dd, ds])))
    sess.flush()
    cs = sess.compact()
    assert not cs.shrunk, "a modest delete must stay on the bucket floor"
    assert sess.shape_key == key0
    assert len(sess._runners) == 1, "bucket-floor compact keeps the runner"

    w = np.full(ds.shape, 5.0, np.float32)
    sess.update(adds=(np.concatenate([ds, dd]), np.concatenate([dd, ds]),
                      np.concatenate([w, w])))
    sess.flush()
    assert sess.shape_key == key0

    with retrace_guard(label="compact-then-regrow query"):
        res, st = sess.query(SSSP(), {"source": 0})
    assert st.compile_time == 0.0
    assert sess.stats.cache_misses == 1, \
        "the whole delete/compact/regrow cycle must reuse one compilation"
    ref, _ = run_sim(SSSP(), sess.pg, {"source": 0}, EngineConfig())
    np.testing.assert_array_equal(np.asarray(res), np.asarray(ref))


def test_lru_eviction_order_mixed_traffic(graph):
    sess = GraphSession.from_graph(graph, 4, "cdbh", max_runners=2)
    r_sssp0, _ = sess.query(SSSP(), {"source": 0})          # miss: [S]
    r_cc0, _ = sess.query(ConnectedComponents())            # miss: [S, C]
    _, st = sess.query(SSSP(), {"source": 0})               # hit:  [C, S]
    assert st.compile_time == 0.0
    pr_params = {"n_vertices": graph.n_vertices}
    _, st = sess.query(PageRank(tol=1e-9), pr_params)       # miss: evict C
    assert st.evicted_runners == 1
    assert sess.stats.cache_evictions_lru == 1
    info = sess.cache_info()
    assert [e["program"] for e in info] == ["SSSP", "PageRank"]

    # the evicted CC runner recompiles transparently and agrees bit-for-bit
    r_cc1, st = sess.query(ConnectedComponents())           # miss: evict S
    assert st.compile_time > 0.0 and st.evicted_runners == 1
    np.testing.assert_array_equal(np.asarray(r_cc0), np.asarray(r_cc1))
    assert [e["program"] for e in sess.cache_info()] == ["PageRank",
                                                         "ConnectedComponents"]
    r_sssp1, st = sess.query(SSSP(), {"source": 0}, warm=False)
    assert st.compile_time > 0.0                            # was evicted
    np.testing.assert_array_equal(np.asarray(r_sssp0), np.asarray(r_sssp1))
    assert sess.stats.cache_evictions_lru == 3
    assert len(sess._runners) == 2
    # hit counters survive in the introspection snapshot
    assert all(isinstance(e["hits"], int) for e in sess.cache_info())


def test_readonly_session_pads_exactly(graph):
    """A session that can never mutate (non-streamable partitioner, no
    StreamContext) gains nothing from buckets — it must not pay the padded
    sweep/exchange overhead."""
    ro = GraphSession.from_graph(graph, 4, "greedy-ec")
    assert ro.buffer is None
    ref = partition_and_build(graph, 4, "greedy-ec")
    assert (ro.pg.v_max, ro.pg.e_max) == (ref.v_max, ref.e_max)
    assert ro.slot_capacity == ro.pg.n_slots
    # a mutable session on the same graph does bucket
    rw = GraphSession.from_graph(graph, 4, "cdbh")
    assert rw.slot_capacity >= rw.pg.n_slots


def test_lru_eviction_prunes_id_keyed_program_pins(graph):
    """Programs with unhashable dataclass fields fall back to id()-keyed
    cache entries and are pinned alive; once neither a runner nor a warm
    entry can reference the id anymore, the pin must be released."""
    import dataclasses as dc

    @dc.dataclass
    class ListySSSP(SSSP):
        junk: list = dc.field(default_factory=list)

    sess = GraphSession.from_graph(graph, 4, "cdbh", max_runners=1)
    a, b = ListySSSP(), ListySSSP()
    r_a, _ = sess.query(a, {"source": 0})
    assert len(sess._keepalive) == 1
    r_b, _ = sess.query(b, {"source": 0})        # evicts a's runner...
    np.testing.assert_array_equal(np.asarray(r_a), np.asarray(r_b))
    assert sess.stats.cache_evictions_lru == 1
    # ...but a's warm entry still references its id: the pin must survive
    # (an id reuse could otherwise serve a's converged result to a stranger)
    assert len(sess._keepalive) == 2
    sess.update(deletes=(graph.src[:4], graph.dst[:4]))
    sess.flush()                                 # deleting flush drops warm
    assert len(sess._keepalive) == 1, \
        "only the resident runner's program may stay pinned"


def test_warm_memory_is_lru_bounded(graph):
    """Warm results are bounded like the runner cache: many distinct
    parameter values must not grow host memory (or per-flush remap cost)
    without bound, and an evicted entry just runs cold again — correctly."""
    sess = GraphSession.from_graph(graph, 4, "cdbh", max_warm_entries=2)
    r0, _ = sess.query(SSSP(), {"source": 0})
    for src in (1, 2, 3):
        sess.query(SSSP(), {"source": src})
    assert len(sess._warm) == 2 and sess.stats.warm_evictions == 2
    with pytest.raises(ValueError, match="no previous converged result"):
        sess.query(SSSP(), {"source": 0}, warm=True)   # evicted
    r0b, _ = sess.query(SSSP(), {"source": 0})          # cold, correct
    np.testing.assert_array_equal(np.asarray(r0), np.asarray(r0b))
    # querying an entry refreshes its recency
    sess.query(SSSP(), {"source": 0})                   # warm hit: refresh
    sess.query(SSSP(), {"source": 7})                   # evicts 3, not 0
    sess.query(SSSP(), {"source": 0}, warm=True)        # still warm


def test_warm_memory_carries_across_bucket_growth(graph):
    """An insert-only flush that crosses a v_max bucket rebuilds the runner
    (once) but must NOT lose warm="auto" memory: the device-layout block is
    remapped through DeltaStats.remap_state, and the warm query converges
    faster than cold with a bit-identical result."""
    sess = GraphSession.from_graph(graph, 4, "cdbh")
    sess.query(SSSP(), {"source": 0})
    (wkey,) = sess._warm.keys()
    v0 = sess.pg.v_max

    # attach enough brand-new vertices to overflow the vertex bucket
    n_new = sess.pg.v_max * sess.pg.n_parts  # certainly > remaining slack
    new = np.arange(sess.pg.n_vertices, sess.pg.n_vertices + n_new,
                    dtype=np.int64)
    anchors = np.arange(n_new, dtype=np.int64) % graph.n_vertices
    sess.update(adds=(np.concatenate([anchors, new]),
                      np.concatenate([new, anchors]),
                      np.full(2 * n_new, 9.0, np.float32)))
    st = sess.flush()
    assert st.repadded and sess.pg.v_max > v0

    entry = sess._warm[wkey]
    assert entry.device_block is not None, \
        "bucket growth must keep the warm block, not drop it"
    # the remap is LAZY (pending-remap chain): the flush only logs it, the
    # block still has the pre-growth layout until the entry's next use
    assert entry.device_block.shape[:2] == (sess.pg.n_parts, v0)
    assert len(sess._remap_log) == 1 and sess.stats.warm_remaps_applied == 0

    warm, st_w = sess.query(SSSP(), {"source": 0})          # warm="auto"
    assert st_w.compile_time > 0.0                          # new bucket
    assert sess.stats.warm_queries == 1
    # ...and the use applied the deferred remap to the current layout
    assert sess.stats.warm_remaps_applied == 1
    assert entry.device_block.shape[:2] == (sess.pg.n_parts, sess.pg.v_max)
    cold, st_c = sess.query(SSSP(), {"source": 0}, warm=False)
    np.testing.assert_array_equal(np.asarray(warm), np.asarray(cold))
    assert st_w.supersteps < st_c.supersteps
