"""Partitioner + subgraph-builder invariants (paper §4.1 Eq. 2-3, §6)."""
import numpy as np
import pytest
from _hypcompat import given, settings, st

from repro.core import (PARTITIONERS, Graph, build_partitioned_graph,
                        partition_metrics)
from repro.core.partition import cdbh_vertex_cut, random_hash_vertex_cut
from repro.graphgen import powerlaw_graph, random_graph


def _graph(n_v=200, n_e=800, seed=0, undirected=False):
    return random_graph(n_v, n_e, seed=seed, weighted=True,
                        undirected=undirected)


@pytest.mark.parametrize("name", list(PARTITIONERS))
def test_edge_partition_complete_and_disjoint(name):
    """Eq. 2: E = union E_i, disjoint — every edge exactly once, intact."""
    g = _graph()
    part = PARTITIONERS[name](g, 5)
    pg = build_partitioned_graph(g, part, 5)
    seen = []
    for p in range(5):
        m = pg.emask[p]
        gs = pg.gvid[p][pg.esrc[p][m]]
        gd = pg.gvid[p][pg.edst[p][m]]
        seen.append(np.stack([gs, gd], 1))
    seen = np.concatenate(seen, 0)
    assert seen.shape[0] == g.n_edges
    want = np.sort(g.src * np.int64(g.n_vertices) + g.dst)
    got = np.sort(seen[:, 0] * np.int64(g.n_vertices) + seen[:, 1])
    np.testing.assert_array_equal(want, got)


@pytest.mark.parametrize("name", list(PARTITIONERS))
def test_vertex_sets_are_edge_endpoints(name):
    """Eq. 3: V_i = endpoints of E_i (+ round-robin isolated vertices)."""
    g = _graph()
    part = PARTITIONERS[name](g, 4)
    pg = build_partitioned_graph(g, part, 4)
    iso = set(g.isolated_vertices().tolist())
    for p in range(4):
        m = pg.emask[p]
        endpoints = set(pg.gvid[p][pg.esrc[p][m]]) | set(pg.gvid[p][pg.edst[p][m]])
        vids = set(pg.gvid[p][pg.vmask[p]].tolist())
        assert endpoints <= vids
        assert vids - endpoints <= iso


def test_frontier_slots_and_masters():
    g = _graph()
    part = cdbh_vertex_cut(g, 6)
    pg = build_partitioned_graph(g, part, 6)
    # every frontier vertex has exactly one master across partitions
    master_count = np.zeros(g.n_vertices, np.int64)
    sel = pg.vmask & pg.is_master
    np.add.at(master_count, pg.gvid[sel], 1)
    present = np.zeros(g.n_vertices, np.int64)
    np.add.at(present, pg.gvid[pg.vmask], 1)
    assert (master_count[present > 0] == 1).all()
    # frontier <=> replicated
    frontier = set(pg.frontier_gvid.tolist())
    assert frontier == set(np.nonzero(present >= 2)[0].tolist())
    # slot ids consistent across replicas
    slot_of = {}
    for p in range(pg.n_parts):
        for lv in np.nonzero(pg.vmask[p] & pg.is_frontier[p])[0]:
            gv = pg.gvid[p][lv]
            s = pg.slot[p][lv]
            assert slot_of.setdefault(gv, s) == s


def test_cdbh_canonical_codirection():
    """(u,v) and (v,u) must land in the same partition (§6.3)."""
    g = _graph(undirected=True)
    part = cdbh_vertex_cut(g, 7)
    lut = {}
    for s, d, p in zip(g.src, g.dst, part):
        key = (min(s, d), max(s, d))
        assert lut.setdefault(key, p) == p


def test_partitioners_deterministic():
    g = _graph()
    for name, fn in PARTITIONERS.items():
        a = fn(g, 5, seed=3)
        b = fn(g, 5, seed=3)
        np.testing.assert_array_equal(a, b)


def test_cdbh_beats_rh_on_powerlaw_replication():
    """Paper Table 3: CDBH replication factor <= RH on power-law graphs."""
    g = powerlaw_graph(3000, alpha=2.1, avg_degree=16, seed=0).as_undirected()
    mc = partition_metrics(build_partitioned_graph(g, cdbh_vertex_cut(g, 16), 16))
    mr = partition_metrics(build_partitioned_graph(g, random_hash_vertex_cut(g, 16), 16))
    assert mc.replication_factor < mr.replication_factor
    assert mc.imbalance < 1.2 and mr.imbalance < 1.2


def test_metrics_bounds():
    g = _graph()
    for name in PARTITIONERS:
        pg = build_partitioned_graph(g, PARTITIONERS[name](g, 4), 4)
        m = partition_metrics(pg)
        assert m.imbalance >= 1.0 - 1e-9
        assert m.replication_factor >= 1.0 - 1e-9


def test_grid_nonsquare_uses_all_partitions():
    """Regression: the non-square grid fold used to be the identity on
    cell ids — partitions [q*q, P) never received an edge. The exact r x c
    factorization must feed every partition with bounded imbalance."""
    g = powerlaw_graph(5000, alpha=2.2, avg_degree=10, seed=2)
    for P in (6, 10, 12):
        part = PARTITIONERS["grid"](g, P, seed=0)
        counts = np.bincount(part, minlength=P)
        assert (counts > 0).all(), (P, counts)
        assert counts.max() / counts.mean() < 1.8, (P, counts)
    # square P keeps the historical sqrt x sqrt cell mapping
    part9 = PARTITIONERS["grid"](g, 9, seed=0)
    assert part9.min() >= 0 and part9.max() < 9


def test_grid_replication_bound():
    """Each vertex's edges stay inside one grid row + column: it can meet
    at most r + c - 1 partitions."""
    from repro.core.partition import route_edges_grid
    g = _graph(n_v=150, n_e=3000, seed=4)
    for P, bound in ((12, 3 + 4 - 1), (16, 4 + 4 - 1)):
        part = route_edges_grid(g.src, g.dst, P, seed=1)
        touched = {}
        for s, d, p in zip(g.src.tolist(), g.dst.tolist(), part.tolist()):
            touched.setdefault(s, set()).add(p)
            touched.setdefault(d, set()).add(p)
        assert max(len(v) for v in touched.values()) <= bound


@settings(max_examples=25, deadline=None)
@given(st.integers(20, 150), st.integers(10, 500), st.integers(2, 9),
       st.integers(0, 5), st.integers(1, 97))
def test_stream_routers_chunk_invariant(n_v, n_e, n_parts, seed, chunk):
    """Stateless STREAM_ROUTERS are pure per-edge: routing a stream in any
    chunking must equal routing it whole (the delta path depends on this).
    Stateful specs (ebv) are exempt — their placements depend on history
    and are pinned by checkpoint/replay tests instead."""
    from repro.core.partition import STREAM_ROUTERS, is_stateful_router
    g = random_graph(n_v, n_e, seed=seed)
    degrees = g.total_degrees()
    for name, entry in STREAM_ROUTERS.items():
        if is_stateful_router(entry):
            continue
        whole = entry(g.src, g.dst, degrees, n_v, n_parts, seed)
        parts = [entry(g.src[i:i + chunk], g.dst[i:i + chunk], degrees,
                       n_v, n_parts, seed)
                 for i in range(0, g.src.size, chunk)]
        np.testing.assert_array_equal(whole, np.concatenate(parts),
                                      err_msg=name)


@settings(max_examples=25, deadline=None)
@given(st.integers(10, 120), st.integers(0, 400), st.integers(1, 9),
       st.integers(0, 5))
def test_builder_properties_random(n_v, n_e, n_parts, seed):
    g = random_graph(n_v, max(n_e, 1), seed=seed)
    for name in ("cdbh", "rh-vc", "rh-ec"):
        part = PARTITIONERS[name](g, n_parts, seed=seed)
        pg = build_partitioned_graph(g, part, n_parts)
        assert pg.emask.sum() == g.n_edges
        # all vertices present somewhere
        present = np.zeros(g.n_vertices, bool)
        present[pg.gvid[pg.vmask]] = True
        assert present.all()
        # collect() roundtrip: identity values
        vals = np.where(pg.vmask, pg.gvid, 0).astype(np.int64)
        out = pg.collect(vals, fill=-1)
        np.testing.assert_array_equal(out, np.arange(g.n_vertices))
