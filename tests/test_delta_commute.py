"""Delta commutativity: ops on distinct pairs commute, and flush chunking
is invisible.

Two layers, extending the chunk-invariance pins of test_ebv_router.py:

  1. ``DeltaBuffer`` alone — interleavings of an op stream that preserve
     per-pair relative order, under any flush chunking, leave the graph
     with the identical edge multiset (the buffer's sequential-semantics
     contract, including the add-cancelled-by-delete and delete-then-add
     state-machine paths);
  2. through a ``GraphSession`` with live warm state — the incremental
     answers of monotone programs (BFS under inserts, k-core under
     deletes) after any such schedule are bit-identical, whether the warm
     entries survived each flush or the polarity gate dropped them.
"""
import numpy as np
import pytest
from _hypcompat import given, settings, st

import harness
from harness import canonicalize, harness_powerlaw
from repro.algos import BFS, make_kcore
from repro.core import partition_and_build
from repro.graphgen import powerlaw_graph
from repro.session import GraphSession
from repro.stream.buffer import DeltaBuffer
from repro.stream.ingest import StreamContext


def _edge_multiset(pg):
    rows = []
    for p in range(pg.n_parts):
        m = pg.emask[p]
        gs = pg.gvid[p][pg.esrc[p][m]]
        gd = pg.gvid[p][pg.edst[p][m]]
        w = pg.ew[p][m]
        rows.append(np.stack([gs.astype(np.int64), gd.astype(np.int64),
                              w.astype(np.int64)], 1))
    rows = np.concatenate(rows)
    return rows[np.lexsort(rows.T[::-1])]


def _make_ops(g, rng, n_new=6, n_del=4, n_churn=3):
    """Per-pair op sequences with a fixed net effect: plain inserts, plain
    deletes, add-then-delete (net absent, exercising in-buffer cancel) and
    delete-then-add (the DEL_ADD path)."""
    have = {(int(s), int(d)) for s, d in zip(g.src, g.dst)}
    pairs = sorted({(min(s, d), max(s, d)) for s, d in have})
    seqs = []

    def fresh_pair():
        while True:
            u, v = int(rng.integers(0, g.n_vertices)), \
                   int(rng.integers(0, g.n_vertices))
            if u != v and (u, v) not in have and (v, u) not in have:
                have.add((u, v))
                return u, v

    for _ in range(n_new):
        u, v = fresh_pair()
        seqs.append([("add", u, v, float(rng.integers(1, 5)))])
    for i in rng.choice(len(pairs), n_del, replace=False):
        u, v = pairs[i]
        seqs.append([("del", u, v, None)])
    for j in range(n_churn):
        u, v = fresh_pair()
        seqs.append([("add", u, v, 1.0), ("del", u, v, None)])
        if j == 0 and len(pairs) > n_del:      # delete-then-re-add a live pair
            u, v = pairs[-1]
            seqs.append([("del", u, v, None), ("add", u, v, 2.0)])
    return seqs


def _interleave(seqs, rng):
    """A random merge of the per-pair sequences that preserves each pair's
    internal order — the only order that must be preserved for the net
    delta to be well defined."""
    cursors = [0] * len(seqs)
    deck = [i for i, s in enumerate(seqs) for _ in s]
    rng.shuffle(deck)
    out = []
    for i in deck:
        out.append(seqs[i][cursors[i]])
        cursors[i] += 1
    return out


def _apply(target, ops, cuts):
    """Feed ops (both undirected directions per op, atomically) into a
    DeltaBuffer or GraphSession, flushing after positions in ``cuts``."""
    for i, (kind, u, v, w) in enumerate(ops):
        if kind == "add":
            if isinstance(target, GraphSession):
                target.update(adds=([u, v], [v, u], [w, w]))
            else:
                target.add([u, v], [v, u], [w, w])
        else:
            if isinstance(target, GraphSession):
                target.update(deletes=([u, v], [v, u]))
            else:
                target.delete([u, v], [v, u])
    # cuts land between update() calls in the session layer below; for the
    # buffer layer everything coalesces into the cut-defined chunks
        if i in cuts:
            target.flush()
    target.flush()


# --------------------------------------------------------------------------- #
@settings(max_examples=3)
@given(st.integers(0, 10_000))
def test_buffer_order_and_chunking_invariance(seed):
    rng = np.random.default_rng(seed)
    g = canonicalize(powerlaw_graph(160, seed=1))
    seqs = _make_ops(g, rng)
    n_ops = sum(len(s) for s in seqs)

    ref = None
    ref_stats = None
    for trial in range(3):
        pg = partition_and_build(g, 4, "cdbh")
        ctx = StreamContext("cdbh", 4, 0, g.n_vertices,
                            np.zeros(g.n_vertices, np.int64))
        buf = DeltaBuffer(pg, ctx, max_edges=None)
        ops = _interleave(seqs, np.random.default_rng(seed + trial))
        cuts = set() if trial == 0 else \
            set(rng.choice(n_ops, rng.integers(1, 4), replace=False).tolist())
        _apply(buf, ops, cuts)
        ms = _edge_multiset(buf.pg)
        if ref is None:
            ref, ref_stats = ms, buf.stats
        else:
            np.testing.assert_array_equal(ms, ref)
    # the single-flush trial actually exercised coalescing
    assert ref_stats.ops_in == 2 * n_ops
    assert ref_stats.adds_cancelled > 0
    assert ref_stats.n_flushes >= 1


# --------------------------------------------------------------------------- #
@settings(max_examples=1 if harness.FAST else 2)
@given(st.integers(0, 10_000))
def test_incremental_queries_commute(seed):
    """Same net delta, different op interleavings and flush chunkings:
    the warm="auto" answers of BFS and k-core are bit-identical across all
    schedules (and match regardless of which warm entries survived)."""
    rng = np.random.default_rng(seed)
    g = harness_powerlaw(160, 4)
    seqs = _make_ops(g, rng, n_new=4, n_del=3, n_churn=2)
    n_ops = sum(len(s) for s in seqs)
    kprog, kparams = make_kcore(2)

    results = []
    for trial in range(3):
        sess = GraphSession.from_graph(g, 4, "cdbh")
        try:
            sess.query(BFS(), {"source": 0})         # seed warm entries
            sess.query(kprog, kparams)
            ops = _interleave(seqs, np.random.default_rng(seed + trial))
            cuts = set() if trial == 0 else \
                set(rng.choice(n_ops, rng.integers(1, 4),
                               replace=False).tolist())
            _apply(sess, ops, cuts)
            rb, _ = sess.query(BFS(), {"source": 0})
            rk, _ = sess.query(kprog, kparams)
            results.append((np.asarray(sess.pg.collect(rb, fill=np.inf)),
                            np.asarray(sess.pg.collect(rk, fill=0))))
        finally:
            sess.close()
    for rb, rk in results[1:]:
        np.testing.assert_array_equal(rb, results[0][0])
        np.testing.assert_array_equal(rk, results[0][1])
