"""drone-lint (repro.analysis) tests: per-rule fixtures (true positive,
suppressed, clean), the suppression/baseline workflow, the src/repro
self-check (zero unbaselined findings), and the runtime retrace sanitizer —
including the deliberate mutated-closure retrace the static rules exist to
prevent, on both engine backends."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.algos import SSSP
from repro.analysis import (analyze_paths, analyze_source, baseline_delta,
                            load_baseline, write_baseline, RULES)
from repro.analysis.sanitizer import (RetraceError, RetraceWarning,
                                      retrace_guard)
from repro.graphgen import powerlaw_graph
from repro.session import GraphSession

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# --------------------------------------------------------------------------- #
# rule fixtures: (true positive, suppressed variant, clean variant)
# --------------------------------------------------------------------------- #
FIXTURES = {
    "DL001": dict(
        tp="""
import jax, jax.numpy as jnp
def build():
    blk = jnp.zeros((4, 4))
    def go(x):
        return x + blk
    return jax.jit(go)
""",
        suppressed="""
import jax, jax.numpy as jnp
def build():
    blk = jnp.zeros((4, 4))
    def go(x):  # drone-lint: disable=DL001
        return x + blk
    return jax.jit(go)
""",
        clean="""
import jax, jax.numpy as jnp
def build():
    blk = jnp.zeros((4, 4))
    def go(x, blk):
        return x + blk
    return jax.jit(go), blk
""",
    ),
    "DL002": dict(
        tp="""
import dataclasses
@dataclasses.dataclass(frozen=True)
class EngineConfig:
    tags: list = dataclasses.field(default_factory=list)
""",
        suppressed="""
import dataclasses
@dataclasses.dataclass(frozen=True)
class EngineConfig:
    tags: list = dataclasses.field(default_factory=list)  # drone-lint: disable=DL002
""",
        clean="""
import dataclasses
@dataclasses.dataclass(frozen=True)
class EngineConfig:
    tags: tuple = ()
""",
    ),
    "DL003": dict(
        tp="""
from jax.experimental.shard_map import shard_map
def f(x, y):
    return x + y
g = shard_map(f, mesh=None, in_specs=(None, None, None), out_specs=None)
""",
        suppressed="""
from jax.experimental.shard_map import shard_map
def f(x, y):
    return x + y
g = shard_map(f, mesh=None, in_specs=(None, None, None), out_specs=None)  # drone-lint: disable=DL003
""",
        clean="""
from jax.experimental.shard_map import shard_map
def f(x, y):
    return x + y
g = shard_map(f, mesh=None, in_specs=(None, None), out_specs=None)
""",
    ),
    "DL004": dict(
        tp="""
import jax, jax.numpy as jnp
@jax.jit
def step(x):
    if x > 0:
        return x
    return -x
""",
        suppressed="""
import jax, jax.numpy as jnp
@jax.jit
def step(x):
    if x > 0:  # drone-lint: disable=DL004
        return x
    return -x
""",
        clean="""
from functools import partial
import jax, jax.numpy as jnp
@partial(jax.jit, static_argnames=("mode",))
def step(x, mode="sc"):
    if mode == "sc":                      # static python knob: fine
        return jnp.where(x > 0, x, -x)    # traced select: fine
    if x.ndim == 1:                       # static metadata: fine
        return -x
    return x
""",
    ),
    "DL005": dict(
        tp="""
from jax.experimental import pallas as pl
import jax.numpy as jnp
def kernel_entry(vals):
    v = jnp.pad(vals, ((0, 8), (0, 0)), constant_values=0.0)
    return pl.pallas_call(lambda r, o: None)(v)
""",
        suppressed="""
from jax.experimental import pallas as pl
import jax.numpy as jnp
# drone-lint: disable=DL005
def kernel_entry(vals):
    # drone-lint: disable=DL005
    v = jnp.pad(vals, ((0, 8), (0, 0)), constant_values=0.0)
    return pl.pallas_call(lambda r, o: None)(v)
""",
        clean="""
from jax.experimental import pallas as pl
import jax.numpy as jnp
from repro.kernels.ref import tile_pad_identity
def kernel_entry(vals, semiring):
    assert vals.dtype == jnp.float32
    ident = tile_pad_identity(semiring, vals.dtype)
    v = jnp.pad(vals, ((0, 8), (0, 0)), constant_values=ident)
    return pl.pallas_call(lambda r, o: None)(v)
""",
    ),
    "DL006": dict(
        tp="""
def f():
    try:
        risky()
    except Exception:
        pass
""",
        suppressed="""
def f():
    try:
        risky()
    except Exception:  # drone-lint: disable=DL006
        pass
""",
        clean="""
import logging
log = logging.getLogger(__name__)
def f():
    try:
        risky()
    except (ValueError, KeyError) as e:
        log.debug("risky failed: %r", e)
""",
    ),
    "DL007": dict(
        tp="""
def dispatch(program, cfg):
    if cfg.edge_backend == "pallas_tiles":
        return "tiles"
    return "coo"
""",
        suppressed="""
def dispatch(program, cfg):
    if cfg.edge_backend == "pallas_tiles":  # drone-lint: disable=DL007
        return "tiles"
    return "coo"
""",
        clean="""
from repro.core.engine import resolve_edge_backend

def resolve_partition_backends(program, cfg, pg):
    return (cfg.edge_backend,) * pg.n_parts   # resolver itself: exempt

def dispatch(program, cfg):
    eb = resolve_edge_backend(program, cfg)
    if eb == "pallas_tiles":
        return "tiles"
    return "coo"

def write_it(cfg, value):
    cfg.edge_backend = value                  # Store, not a read: exempt
""",
    ),
}


@pytest.mark.parametrize("code", sorted(FIXTURES))
def test_rule_true_positive(code):
    findings = analyze_source(FIXTURES[code]["tp"], "fixture.py")
    assert code in {f.rule for f in findings}, \
        f"{code} must fire on its true-positive fixture"


@pytest.mark.parametrize("code", sorted(FIXTURES))
def test_rule_suppressed(code):
    findings = analyze_source(FIXTURES[code]["suppressed"], "fixture.py")
    assert code not in {f.rule for f in findings}, \
        f"inline disable comment must silence {code}"


@pytest.mark.parametrize("code", sorted(FIXTURES))
def test_rule_clean(code):
    findings = analyze_source(FIXTURES[code]["clean"], "fixture.py")
    got = [f for f in findings if f.rule == code]
    assert not got, f"{code} false-positived on the clean fixture: {got}"


def test_rule_registry_complete():
    assert set(FIXTURES) <= set(RULES)
    assert all(RULES[c].severity in ("error", "warning") for c in RULES)


def test_finding_render_and_severity():
    [f] = [x for x in analyze_source(FIXTURES["DL006"]["tp"], "mod.py")
           if x.rule == "DL006"]
    assert f.severity == "warning"
    assert "mod.py:" in f.render() and "DL006" in f.render()


# --------------------------------------------------------------------------- #
# baseline workflow
# --------------------------------------------------------------------------- #
def test_baseline_roundtrip_and_delta(tmp_path):
    findings = analyze_source(FIXTURES["DL006"]["tp"], "mod.py")
    bl = tmp_path / "baseline.json"
    write_baseline(str(bl), findings)
    baseline = load_baseline(str(bl))
    assert baseline_delta(findings, baseline) == []
    # the same finding at a shifted line number is still baselined ...
    shifted = analyze_source("\n\n\n" + FIXTURES["DL006"]["tp"], "mod.py")
    assert baseline_delta(shifted, baseline) == []
    # ... but a second occurrence exceeds the multiset budget
    doubled = findings + findings
    assert len(baseline_delta(doubled, baseline)) == len(findings)


def test_baseline_missing_file_is_empty():
    assert load_baseline("/nonexistent/baseline.json") == {}


def test_checked_in_baseline_is_valid_json():
    with open(os.path.join(ROOT, "tools", "drone_lint_baseline.json")) as fh:
        data = json.load(fh)
    assert data["version"] == 1
    assert isinstance(data["findings"], list)


# --------------------------------------------------------------------------- #
# self-check: the repo's own source has zero unbaselined findings
# --------------------------------------------------------------------------- #
def test_src_repro_has_zero_unbaselined_findings():
    findings = analyze_paths([os.path.join(ROOT, "src", "repro")],
                             relative_to=ROOT)
    baseline = load_baseline(
        os.path.join(ROOT, "tools", "drone_lint_baseline.json"))
    new = baseline_delta(findings, baseline)
    assert not new, "unbaselined drone-lint findings:\n" + "\n".join(
        f.render() for f in new)


def test_kernels_are_strict_clean():
    """The kernel tree must hold the DL005 contract with no baseline help
    (the CI kernels-parity job runs this same check via the CLI)."""
    findings = analyze_paths([os.path.join(ROOT, "src", "repro", "kernels")],
                             select=["DL005"], relative_to=ROOT)
    assert not findings, "\n".join(f.render() for f in findings)


def test_cli_gate_and_list_rules():
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "drone_lint.py"),
         "src/repro"], capture_output=True, text=True, env=env, cwd=ROOT)
    assert out.returncode == 0, out.stdout + out.stderr
    out = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "drone_lint.py"),
         "--list-rules"], capture_output=True, text=True, env=env, cwd=ROOT)
    assert out.returncode == 0 and "DL001" in out.stdout


# --------------------------------------------------------------------------- #
# runtime sanitizer: retrace_guard
# --------------------------------------------------------------------------- #
def test_retrace_guard_clean_region_passes():
    f = jax.jit(lambda x: x + 1)
    x = jnp.zeros((8,))
    f(x)                                   # compile outside the guard
    with retrace_guard() as g:
        f(x)
    assert g.traces == 0 and not g.triggered


def test_retrace_guard_catches_mutated_closure():
    """The DL001 failure mode at runtime: state captured by closure is
    mutated, the closure is rebuilt, and the 'cached' computation silently
    recompiles. The guard turns that silence into an error."""
    captured = {"blk": jnp.zeros((8,))}

    def build():
        blk = captured["blk"]              # closure capture (DL001!)
        return jax.jit(lambda x: x + blk)

    x = jnp.ones((8,))
    f = build()
    f(x)                                   # legitimate cold compile
    with retrace_guard():
        f(x)                               # cached: fine
    captured["blk"] = jnp.full((8,), 7.0)  # mutate the captured state ...
    f2 = build()                           # ... which forces a rebuild
    with pytest.raises(RetraceError, match="unexpected jax trace"):
        with retrace_guard():
            f2(x)                          # silent recompile -> caught


def test_retrace_guard_warn_action():
    captured = jnp.zeros((4,))
    f = jax.jit(lambda x: x + captured)
    with pytest.warns(RetraceWarning):
        with retrace_guard(action="warn") as g:
            f(jnp.ones((4,)))              # cold compile inside the guard
    assert g.triggered and g.traces > 0


def test_retrace_guard_invalid_action():
    with pytest.raises(ValueError, match="action"):
        with retrace_guard(action="explode"):
            pass


def test_retrace_guard_session_compiles_are_expected():
    """Passing the session excuses its recorded cold compiles; without it
    the same region trips the guard (sim engine backend)."""
    g = powerlaw_graph(300, seed=3, weighted=True).as_undirected()
    sess = GraphSession.from_graph(g, 4, "cdbh")
    with retrace_guard(sess) as gd:
        sess.query(SSSP(), {"source": 0})  # cold: compiles, excused
    assert gd.expected_compiles == 1 and not gd.triggered
    with retrace_guard(sess) as gd2:
        sess.query(SSSP(), {"source": 1})  # hit: no traces at all
    assert gd2.expected_compiles == 0 and gd2.traces == 0
    sess2 = GraphSession.from_graph(g, 4, "cdbh")
    with pytest.raises(RetraceError):
        with retrace_guard():              # session NOT passed
            sess2.query(SSSP(), {"source": 0})


def test_debug_sanitize_clean_session():
    g = powerlaw_graph(300, seed=4, weighted=True).as_undirected()
    sess = GraphSession.from_graph(g, 4, "cdbh", debug_sanitize=True)
    r0, _ = sess.query(SSSP(), {"source": 0})
    r1, st = sess.query(SSSP(), {"source": 0})   # guarded hit-path launch
    assert st.compile_time == 0.0
    np.testing.assert_array_equal(np.asarray(r0), np.asarray(r1))


def test_debug_sanitize_catches_poisoned_runner():
    """A cached executable that re-enters the tracer on launch (here: a
    wrapper that builds a fresh jit per call) must raise at the query."""
    g = powerlaw_graph(300, seed=5, weighted=True).as_undirected()
    sess = GraphSession.from_graph(g, 4, "cdbh", debug_sanitize=True)
    sess.query(SSSP(), {"source": 0})
    [entry] = sess._runner_cache.entries.values()
    real = entry.compiled

    def retracing_runner(*args):
        jax.jit(lambda v: v * 2)(1.0)      # fresh trace on every call
        return real(*args)

    entry.compiled = retracing_runner
    with pytest.raises(RetraceError, match="cache-hit launch"):
        sess.query(SSSP(), {"source": 0})


def test_debug_sanitize_warn_mode():
    g = powerlaw_graph(300, seed=6, weighted=True).as_undirected()
    sess = GraphSession.from_graph(g, 4, "cdbh", debug_sanitize="warn")
    sess.query(SSSP(), {"source": 0})
    [entry] = sess._runner_cache.entries.values()
    real = entry.compiled
    entry.compiled = lambda *a: (jax.jit(lambda v: v + 1)(0.0), real(*a))[1]
    with pytest.warns(RetraceWarning):
        res, _ = sess.query(SSSP(), {"source": 0})
    assert np.isfinite(np.asarray(res)).any()


# --------------------------------------------------------------------------- #
# shard_map engine backend (subprocess: fake devices before jax init)
# --------------------------------------------------------------------------- #
SHARD_SANITIZER_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.analysis.sanitizer import RetraceError, retrace_guard
from repro.compat import make_mesh, shard_map
from repro.core import EngineConfig
from repro.graphgen import powerlaw_graph
from repro.algos import SSSP
from repro.session import GraphSession

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))

# 1. mutated-closure retrace on a shard_map computation
captured = {"blk": jnp.zeros((8,))}
def build():
    blk = captured["blk"]                     # DL001 failure mode
    def body(x):
        return x + blk
    return jax.jit(shard_map(body, mesh=mesh, in_specs=P(),
                             out_specs=P()))
x = jnp.ones((8,))
f = build()
f(x)                                          # cold compile
with retrace_guard():
    f(x)                                      # cached: clean
captured["blk"] = jnp.full((8,), 3.0)
f2 = build()                                  # rebuilt closure
try:
    with retrace_guard():
        f2(x)
    raise SystemExit("guard missed the shard_map closure retrace")
except RetraceError:
    pass

# 2. the session integration on the shard engine backend
g = powerlaw_graph(300, seed=7, weighted=True).as_undirected()
cfg = EngineConfig(subgraph_axes=("pod", "data"), edge_axes=("model",))
sess = GraphSession.from_graph(g, 4, "cdbh", mesh=mesh, cfg=cfg,
                               debug_sanitize=True)
with retrace_guard(sess) as gd:
    sess.query(SSSP(), {"source": 0})         # cold compile: excused
assert gd.expected_compiles == 1 and not gd.triggered
with retrace_guard(sess) as gd2:
    sess.query(SSSP(), {"source": 1})         # guarded hit-path launch
assert gd2.traces == 0, f"shard hit-path traced {gd2.traces} times"
print("shard sanitizer OK")
"""


def test_retrace_guard_shard_backend():
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", SHARD_SANITIZER_SCRIPT],
                         capture_output=True, text=True, env=env, cwd=ROOT,
                         timeout=600)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "shard sanitizer OK" in out.stdout
