"""Algorithm correctness vs networkx / numpy oracles, across partitioners
(vertex-cut CDBH/RH, edge-cut RH = DRONE-EC) and execution modes (SC / VC)."""
import numpy as np
import networkx as nx
import pytest
from _hypcompat import given, settings, st

from repro.core import EngineConfig, partition_and_build, run_sim
from repro.algos import ConnectedComponents, PageRank, SSSP
from repro.algos.gsim import make_gsim
from repro.graphgen import grid_graph, powerlaw_graph, random_graph, ring_graph

PARTS = ["cdbh", "rh-vc", "rh-ec"]
MODES = ["sc", "vc"]


def _cc_oracle(g):
    G = nx.Graph()
    G.add_nodes_from(range(g.n_vertices))
    G.add_edges_from(zip(g.src.tolist(), g.dst.tolist()))
    lab = np.arange(g.n_vertices)
    for comp in nx.connected_components(G):
        lab[list(comp)] = min(comp)
    return lab


def _sssp_oracle(g, source):
    G = nx.DiGraph()
    G.add_nodes_from(range(g.n_vertices))
    for s, d, w in zip(g.src.tolist(), g.dst.tolist(), g.weights.tolist()):
        if not G.has_edge(s, d) or G[s][d]["weight"] > w:
            G.add_edge(s, d, weight=w)
    dist = np.full(g.n_vertices, np.inf)
    for v, d in nx.single_source_dijkstra_path_length(G, source).items():
        dist[v] = d
    return dist


def _pr_oracle(g, alpha=0.85, iters=300):
    n = g.n_vertices
    outd = np.bincount(g.src, minlength=n).astype(float)
    pr, cur = np.zeros(n), np.full(n, (1 - alpha) / n)
    for _ in range(iters):
        pr += cur
        nxt = np.zeros(n)
        push = alpha * np.where(outd > 0, cur / np.maximum(outd, 1), 0.0)
        np.add.at(nxt, g.dst, push[g.src])
        cur = nxt
        if cur.max() < 1e-16:
            break
    return pr


@pytest.mark.parametrize("part", PARTS)
@pytest.mark.parametrize("mode", MODES)
def test_cc(part, mode):
    g = powerlaw_graph(600, seed=1).as_undirected()
    pg = partition_and_build(g, 6, part)
    res, stats = run_sim(ConnectedComponents(), pg, None, EngineConfig(mode=mode))
    np.testing.assert_array_equal(pg.collect(res, fill=-1), _cc_oracle(g))
    assert stats.supersteps >= 1 and stats.total_messages > 0


@pytest.mark.parametrize("part", PARTS)
@pytest.mark.parametrize("mode", MODES)
def test_sssp(part, mode):
    g = grid_graph(16, weighted=True, seed=2)
    pg = partition_and_build(g, 5, part)
    res, _ = run_sim(SSSP(), pg, {"source": 7}, EngineConfig(mode=mode))
    dist = pg.collect(res, fill=np.float32(np.inf))
    ref = _sssp_oracle(g, 7)
    finite = np.isfinite(ref)
    np.testing.assert_allclose(dist[finite], ref[finite], rtol=1e-5, atol=1e-4)
    assert np.isinf(dist[~finite]).all()


def test_sssp_unreachable():
    # two disjoint cliques; distances to the far one must stay inf
    e = np.array([[0, 1], [1, 2], [3, 4], [4, 5]], np.int64)
    from repro.core.graph import Graph
    g = Graph(6, e[:, 0], e[:, 1]).as_undirected()
    pg = partition_and_build(g, 2, "cdbh")
    res, _ = run_sim(SSSP(), pg, {"source": 0}, EngineConfig())
    dist = pg.collect(res, fill=np.float32(np.inf))
    assert np.isfinite(dist[:3]).all() and np.isinf(dist[3:]).all()


@pytest.mark.parametrize("part", PARTS)
@pytest.mark.parametrize("mode", MODES)
def test_pagerank(part, mode):
    g = powerlaw_graph(500, seed=3)
    pg = partition_and_build(g, 4, part)
    cfg = EngineConfig(mode=mode, max_local_iters=300, max_supersteps=3000)
    res, _ = run_sim(PageRank(tol=1e-9), pg, {"n_vertices": g.n_vertices}, cfg)
    mine = pg.collect(res, fill=0.0)
    ref = _pr_oracle(g)
    np.testing.assert_allclose(mine, ref, atol=5e-5)
    # ranks are a probability-mass-like vector (no dangling redistribution)
    assert 0 < mine.sum() <= 1.0 + 1e-3


@pytest.mark.parametrize("part", PARTS)
def test_gsim(part):
    rng = np.random.default_rng(4)
    g = powerlaw_graph(400, seed=4)
    labels = rng.integers(0, 4, size=g.n_vertices).astype(np.int32)
    qadj = np.array([[0, 1, 1], [0, 0, 1], [0, 0, 0]], np.int32)
    qlabel = np.array([0, 1, 2], np.int32)
    pg = partition_and_build(g, 5, part)
    pg.set_vertex_labels(labels)
    prog, params = make_gsim(qadj, qlabel)
    res, _ = run_sim(prog, pg, params, EngineConfig())
    sim = pg.collect(res, fill=0).astype(bool)

    # oracle: naive pruning fixpoint
    VQ = 3
    ref = np.zeros((g.n_vertices, VQ), bool)
    for u in range(VQ):
        ref[:, u] = labels == qlabel[u]
    adj = [[] for _ in range(g.n_vertices)]
    for s, d in zip(g.src.tolist(), g.dst.tolist()):
        adj[s].append(d)
    changed = True
    while changed:
        changed = False
        for u in range(VQ):
            succ = np.nonzero(qadj[u])[0]
            for v in range(g.n_vertices):
                if ref[v, u] and any(not ref[adj[v], up].any() if adj[v] else True
                                     for up in succ):
                    ref[v, u] = False
                    changed = True
    np.testing.assert_array_equal(sim, ref)


def test_large_diameter_superstep_gap():
    """Paper §3/§8: SC needs far fewer supersteps than VC on large-diameter
    graphs (ring = extreme case), given a locality-preserving partition.
    (With a hash partition the subgraphs are scattered fragments and SC loses
    its advantage — the paper's own observation about hash partitioning
    destroying local structure, §3.)"""
    g = ring_graph(512)
    pg = partition_and_build(g, 4, "range")
    _, sc = run_sim(ConnectedComponents(), pg, None, EngineConfig(mode="sc"))
    _, vc = run_sim(ConnectedComponents(), pg, None, EngineConfig(mode="vc"))
    assert sc.supersteps * 10 < vc.supersteps
    # and on the same partition, SC also sends far fewer messages
    assert sc.total_messages * 5 < vc.total_messages


def test_single_partition_no_frontier():
    g = powerlaw_graph(300, seed=6).as_undirected()
    pg = partition_and_build(g, 1, "cdbh")
    assert pg.n_slots == 0
    res, stats = run_sim(ConnectedComponents(), pg, None, EngineConfig())
    np.testing.assert_array_equal(pg.collect(res, fill=-1), _cc_oracle(g))
    assert stats.total_messages == 0


@settings(max_examples=15, deadline=None)
@given(st.integers(8, 80), st.integers(1, 6), st.integers(0, 4),
       st.sampled_from(PARTS))
def test_cc_random_property(n_v, n_parts, seed, part):
    g = random_graph(n_v, n_v * 2, seed=seed, undirected=True)
    pg = partition_and_build(g, n_parts, part, seed=seed)
    res, _ = run_sim(ConnectedComponents(), pg, None, EngineConfig())
    np.testing.assert_array_equal(pg.collect(res, fill=-1), _cc_oracle(g))


@settings(max_examples=10, deadline=None)
@given(st.integers(8, 60), st.integers(1, 5), st.integers(0, 4))
def test_sssp_random_property(n_v, n_parts, seed):
    g = random_graph(n_v, n_v * 3, seed=seed, weighted=True)
    pg = partition_and_build(g, n_parts, "cdbh", seed=seed)
    src = seed % n_v
    res, _ = run_sim(SSSP(), pg, {"source": src}, EngineConfig())
    dist = pg.collect(res, fill=np.float32(np.inf))
    ref = _sssp_oracle(g, src)
    finite = np.isfinite(ref)
    np.testing.assert_allclose(dist[finite], ref[finite], rtol=1e-5, atol=1e-4)
    assert np.isinf(dist[~finite]).all()
