"""Optional-`hypothesis` shim for the property tests.

With hypothesis installed this re-exports the real ``given``/``settings``/
``st``. Without it (minimal CI images), a deterministic miniature stands in:
each strategy draws from a seeded numpy Generator and ``@given`` replays the
test body ``max_examples`` times. Coverage is narrower than real hypothesis
(no shrinking, fixed seed) but keeps the suite runnable and meaningful with
zero extra dependencies — install ``requirements-dev.txt`` for the real thing.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is present
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False
    import numpy as _np

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(
                lambda rng: elements[int(rng.integers(0, len(elements)))])

    st = _Strategies()

    def settings(max_examples: int = 20, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            # NB: no functools.wraps — copying fn's signature would make
            # pytest resolve the strategy parameters as fixtures.
            def runner(*args, **kwargs):
                # @settings may wrap either this runner (applied above
                # @given) or the raw fn (applied below), so check both.
                n = (getattr(runner, "_max_examples", None)
                     or getattr(fn, "_max_examples", None) or 20)
                rng = _np.random.default_rng(0)
                for _ in range(n):
                    vals = [s.draw(rng) for s in strategies]
                    fn(*args, *vals, **kwargs)
            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            return runner
        return deco
