"""Pallas kernel allclose sweeps vs ref.py oracles (interpret mode on CPU;
TPU is the target per DESIGN.md §5)."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypcompat import given, settings, st

from repro.graphgen import powerlaw_graph, random_graph
from repro.kernels import bsp_spmv, ops, ref
from repro.kernels.bsp_spmv import TM, TN
from repro.kernels.segment_combine import W, segment_combine_windowed

SEMIRINGS = ["plus_times", "min_plus"]


def _rand_tiles(rng, T, n_dst_tiles, n_src_tiles, semiring, density=0.3):
    ident = 0.0 if semiring == "plus_times" else np.inf
    tiles = np.full((T, TM, TN), ident, np.float32)
    mask = rng.random((T, TM, TN)) < density
    tiles[mask] = rng.uniform(0.1, 5.0, size=int(mask.sum())).astype(np.float32)
    # dst-major sorted, every dst tile covered
    tile_dst = np.sort(rng.integers(0, n_dst_tiles, size=T).astype(np.int32))
    tile_dst[:n_dst_tiles] = np.arange(n_dst_tiles)
    tile_dst = np.sort(tile_dst)
    tile_src = rng.integers(0, n_src_tiles, size=T).astype(np.int32)
    return tiles, tile_dst, tile_src


@pytest.mark.parametrize("semiring", SEMIRINGS)
@pytest.mark.parametrize("T,n_dst,n_src,K", [
    (4, 2, 2, 1), (9, 3, 2, 4), (16, 4, 4, 8), (5, 5, 1, 128),
])
def test_bsp_spmv_matches_ref(semiring, T, n_dst, n_src, K):
    rng = np.random.default_rng(T * 100 + K)
    tiles, td, ts = _rand_tiles(rng, T, n_dst, n_src, semiring)
    vals = rng.uniform(0, 3, size=(n_src, TN, K)).astype(np.float32)
    got = bsp_spmv(jnp.asarray(tiles), jnp.asarray(td), jnp.asarray(ts),
                   jnp.asarray(vals), n_dst_tiles=n_dst, semiring=semiring)
    want = ref.ref_tile_spmv(jnp.asarray(tiles), jnp.asarray(td),
                             jnp.asarray(ts), jnp.asarray(vals), n_dst,
                             semiring)
    got, want = np.asarray(got), np.asarray(want)
    both_inf = np.isinf(got) & np.isinf(want)
    np.testing.assert_allclose(np.where(both_inf, 0, got),
                               np.where(both_inf, 0, want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("combiner", ["sum", "min", "max"])
@pytest.mark.parametrize("E,n_rows,K,Be", [
    (100, 64, 1, 128), (1000, 300, 4, 256), (3000, 500, 8, 512),
    (50, 400, 1, 128),  # many empty windows
])
def test_segment_combine_matches_ref(combiner, E, n_rows, K, Be):
    rng = np.random.default_rng(E + K)
    dst = np.sort(rng.integers(0, n_rows, size=E).astype(np.int64))
    msgs = rng.uniform(-2, 2, size=(E, K)).astype(np.float32)
    layout = ops.window_align_edges(dst, n_rows, block_edges=Be)
    got = np.asarray(layout(jnp.asarray(msgs), combiner=combiner))[:n_rows]
    want = np.asarray(ref.ref_segment_combine(jnp.asarray(msgs),
                                              jnp.asarray(dst.astype(np.int32)),
                                              layout.n_windows * W, combiner))[:n_rows]
    both_inf = np.isinf(got) & np.isinf(want) & (np.sign(got) == np.sign(want))
    np.testing.assert_allclose(np.where(both_inf, 0, got),
                               np.where(both_inf, 0, want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("semiring", SEMIRINGS)
@pytest.mark.parametrize("kernel", ["tiles", "windowed"])
def test_spmv_end_to_end_powerlaw(semiring, kernel):
    g = powerlaw_graph(500, seed=2, weighted=True)
    rng = np.random.default_rng(0)
    vals = rng.uniform(0, 3, size=(g.n_vertices, 2)).astype(np.float32)
    ident = 0.0 if semiring == "plus_times" else np.inf
    dense = np.full((g.n_vertices, g.n_vertices), ident, np.float32)
    if semiring == "plus_times":
        np.add.at(dense, (g.dst, g.src), g.weights)
        want = dense @ vals
    else:
        np.minimum.at(dense, (g.dst, g.src), g.weights)
        want = (dense[:, :, None] + vals[None, :, :]).min(axis=1)
    got = np.asarray(ops.spmv(g.src, g.dst, g.weights, vals, g.n_vertices,
                              semiring=semiring, kernel=kernel))
    both_inf = np.isinf(got) & np.isinf(want)
    np.testing.assert_allclose(np.where(both_inf, 0, got),
                               np.where(both_inf, 0, want), rtol=2e-4, atol=2e-4)


def test_tile_layout_dense_crosscheck():
    g = random_graph(300, 900, seed=3, weighted=True)
    layout = ops.build_tiles(g.src, g.dst, g.weights, 300, 300, "min_plus")
    dense = ref.dense_from_tiles(layout.tiles, layout.tile_dst,
                                 layout.tile_src, layout.n_dst_tiles,
                                 layout.n_src_tiles, "min_plus")
    want = np.full((layout.n_dst_tiles * TM, layout.n_src_tiles * TN), np.inf,
                   np.float32)
    np.minimum.at(want, (g.dst, g.src), g.weights)
    np.testing.assert_array_equal(dense, want)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 40), st.integers(1, 3), st.integers(0, 5),
       st.sampled_from(SEMIRINGS))
def test_bsp_spmv_property(T_extra, n_tiles, seed, semiring):
    rng = np.random.default_rng(seed)
    T = n_tiles + T_extra
    tiles, td, ts = _rand_tiles(rng, T, n_tiles, n_tiles, semiring,
                                density=0.15)
    vals = rng.uniform(0, 2, size=(n_tiles, TN, 1)).astype(np.float32)
    got = bsp_spmv(jnp.asarray(tiles), jnp.asarray(td), jnp.asarray(ts),
                   jnp.asarray(vals), n_dst_tiles=n_tiles, semiring=semiring)
    want = ref.ref_tile_spmv(jnp.asarray(tiles), jnp.asarray(td),
                             jnp.asarray(ts), jnp.asarray(vals), n_tiles,
                             semiring)
    got, want = np.asarray(got), np.asarray(want)
    both_inf = np.isinf(got) & np.isinf(want)
    np.testing.assert_allclose(np.where(both_inf, 0, got),
                               np.where(both_inf, 0, want), rtol=1e-4,
                               atol=1e-4)


# --------------------------------------------------------------------------- #
# dtype support (satellite: layouts honor program.dtype; int32 min_plus for
# CC label propagation, with the wrap-safe halved pad identity)
# --------------------------------------------------------------------------- #
def test_bsp_spmv_int32_min_plus():
    from repro.kernels.ref import tile_pad_identity
    rng = np.random.default_rng(7)
    ident = int(tile_pad_identity("min_plus", np.int32))
    tiles = np.full((3, TM, TN), ident, np.int32)
    mask = rng.random((3, TM, TN)) < 0.2
    tiles[mask] = rng.integers(0, 50, size=int(mask.sum()))
    td = np.array([0, 1, 1], np.int32)
    ts = np.array([0, 0, 1], np.int32)
    vals = rng.integers(0, 1000, size=(2, TN, 2)).astype(np.int32)
    got = bsp_spmv(jnp.asarray(tiles), jnp.asarray(td), jnp.asarray(ts),
                   jnp.asarray(vals), n_dst_tiles=2, semiring="min_plus")
    assert got.dtype == jnp.int32
    want = ref.ref_tile_spmv(jnp.asarray(tiles), jnp.asarray(td),
                             jnp.asarray(ts), jnp.asarray(vals), 2,
                             "min_plus")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_segment_combine_int32_min():
    rng = np.random.default_rng(11)
    E, n_rows = 300, 200
    dst = np.sort(rng.integers(0, n_rows, size=E).astype(np.int64))
    msgs = rng.integers(-50, 50, size=(E, 3)).astype(np.int32)
    layout = ops.window_align_edges(dst, n_rows, block_edges=128)
    got = np.asarray(layout(jnp.asarray(msgs), combiner="min"))[:n_rows]
    assert got.dtype == np.int32
    want = np.asarray(ref.ref_segment_combine(
        jnp.asarray(msgs), jnp.asarray(dst.astype(np.int32)),
        layout.n_windows * W, "min"))[:n_rows]
    imax = np.iinfo(np.int32).max
    both_pad = (got == imax) & (want == np.inf)  # empty rows: int vs float id
    np.testing.assert_array_equal(np.where(both_pad, 0, got),
                                  np.where(both_pad, 0, want.astype(np.int64)
                                           .clip(max=imax).astype(np.int32)))


def test_tile_layout_honors_dtype():
    g = random_graph(200, 600, seed=9, weighted=False)
    layout = ops.build_tiles(g.src, g.dst, np.zeros(g.n_edges), 200, 200,
                             "min_plus", dtype=np.int32)
    assert layout.tiles.dtype == np.int32
    vals = np.arange(200, dtype=np.int32)[:, None]
    out = np.asarray(layout(jnp.asarray(vals)))[:200]
    assert out.dtype == np.int32
    # oracle: min label over in-neighbours
    want = np.full(200, np.iinfo(np.int32).max >> 1, np.int64)
    np.minimum.at(want, g.dst, vals[g.src, 0])
    real = want < (np.iinfo(np.int32).max >> 1)
    np.testing.assert_array_equal(out[real, 0], want[real])


def test_plus_times_rejects_int_dtype():
    with pytest.raises(ValueError, match="float"):
        bsp_spmv(jnp.zeros((1, TM, TN), jnp.int32),
                 jnp.zeros(1, jnp.int32), jnp.zeros(1, jnp.int32),
                 jnp.zeros((1, TN, 1), jnp.int32), n_dst_tiles=1,
                 semiring="plus_times")


def test_default_interpret_matches_platform():
    import jax
    assert ops.default_interpret() == (jax.default_backend() != "tpu")
