"""Long-context computational paths == dense references:
blockwise (flash-dataflow) attention, chunked Mamba scan, chunkwise mLSTM,
and hierarchical == global MoE dispatch (dropless)."""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
from _hypcompat import given, settings, st

from repro.configs import get_smoke_config
from repro.models import moe as moe_lib
from repro.models.layers import _sdpa_blockwise, _sdpa_dense
from repro.models.ssm import _mamba_scan, _mlstm_chunked


@settings(max_examples=8, deadline=None)
@given(st.integers(30, 300), st.integers(1, 3), st.booleans(),
       st.integers(0, 6))
def test_blockwise_sdpa_matches_dense(T, g, causal, seed):
    B, Hkv, D = 2, 2, 16
    H = Hkv * g
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, T, H, D))
    k = jax.random.normal(ks[1], (B, T, Hkv, D))
    v = jax.random.normal(ks[2], (B, T, Hkv, D))
    o1 = _sdpa_dense(q, k, v, causal=causal, q_offset=0)
    o2 = _sdpa_blockwise(q, k, v, causal=causal, q_offset=0, kv_block=64)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


def test_blockwise_sdpa_offset_and_valid():
    B, T, H, D = 2, 200, 4, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, T, H, D))
    k = jax.random.normal(ks[1], (B, T, H, D))
    v = jax.random.normal(ks[2], (B, T, H, D))
    o1 = _sdpa_dense(q, k, v, causal=True, q_offset=7, kv_len_valid=150)
    o2 = _sdpa_blockwise(q, k, v, causal=True, q_offset=7, kv_len_valid=150,
                         kv_block=32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


@settings(max_examples=6, deadline=None)
@given(st.integers(100, 700), st.integers(0, 4))
def test_mamba_chunked_matches_full(s, seed):
    b, di, n = 2, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    u = jax.random.normal(ks[0], (b, s, di)) * 0.1
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, di))) * 0.1
    B = jax.random.normal(ks[2], (b, s, n)) * 0.3
    C = jax.random.normal(ks[3], (b, s, n)) * 0.3
    A = -jnp.exp(jax.random.normal(ks[4], (di, n)) * 0.2)
    D = jnp.ones((di,))
    y1, h1 = _mamba_scan(u, dt, B, C, A, D, chunk=4096)   # single-shot
    y2, h2 = _mamba_scan(u, dt, B, C, A, D, chunk=128)    # chunked
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-5)


@settings(max_examples=6, deadline=None)
@given(st.integers(80, 400), st.integers(0, 4))
def test_mlstm_chunked_matches_parallel(S, seed):
    B, H, dh = 2, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (B, S, H, dh)) / math.sqrt(dh)
    k = jax.random.normal(ks[1], (B, S, H, dh)) / math.sqrt(dh)
    v = jax.random.normal(ks[2], (B, S, H, dh))
    ip = jax.random.normal(ks[3], (B, S, H))
    fp = jax.random.normal(ks[4], (B, S, H)) + 2.0
    # parallel reference (the paper's stabilized parallel form)
    lf = jax.nn.log_sigmoid(fp)
    a = jnp.cumsum(lf, 1)
    logD = a[:, :, None, :] - a[:, None, :, :] + ip[:, None, :, :]
    tri = jnp.arange(S)[:, None] >= jnp.arange(S)[None, :]
    logD = jnp.where(tri[None, :, :, None], logD, -jnp.inf)
    mrow = jnp.max(logD, 2, keepdims=True)
    Dm = jnp.exp(logD - mrow)
    sc = jnp.einsum("bthk,bshk->btsh", q, k) * Dm
    norm = jnp.maximum(jnp.abs(sc.sum(2)), jnp.exp(-mrow[:, :, 0, :]))
    h_ref = jnp.einsum("btsh,bshk->bthk", sc, v) / norm[..., None]
    h_ch, _ = _mlstm_chunked(q, k, v, ip, fp, chunk=64)
    np.testing.assert_allclose(np.asarray(h_ref), np.asarray(h_ch),
                               atol=5e-4)


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 4), st.integers(4, 24), st.integers(0, 5))
def test_moe_hierarchical_matches_global(B, S, seed):
    cfg = get_smoke_config("deepseek_v3_671b")   # dropless smoke capacity
    params = moe_lib.init_moe(jax.random.PRNGKey(seed), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1),
                          (B, S, cfg.d_model)) * 0.3
    y1, a1 = moe_lib.moe_apply(params, x, cfg)
    cfg2 = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, dispatch="hierarchical"))
    y2, a2 = moe_lib.moe_apply(params, x, cfg2)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(a1["load"]), np.asarray(a2["load"]))
