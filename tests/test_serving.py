"""Serving-subsystem tests (docs/SERVING.md): shared runner cache +
cross-tenant executable sharing, micro-batched launches vs singleton
parity, the tiered result cache, session close(), and the batcher's
coalescing policy. The shard_map backend repeats the sharing and batching
assertions in a subprocess with fake devices (jax must see them before
init)."""
import subprocess
import sys

import numpy as np
import pytest

from repro.algos import SSSP, ConnectedComponents, PageRank
from repro.analysis.sanitizer import retrace_guard
from repro.core import EngineConfig
from repro.graphgen import powerlaw_graph
from repro.serving import (BatchPolicy, DictStore, FileStore, MicroBatcher,
                           ResultCache, RunnerCache, RunnerEntry,
                           SessionPool, canonical_params, params_struct_key)
from repro.session import GraphSession


@pytest.fixture(scope="module")
def g():
    return powerlaw_graph(400, seed=7, weighted=True).as_undirected()


@pytest.fixture(scope="module")
def g2():
    # different content, same size: lands in the same shape bucket as g
    return powerlaw_graph(400, seed=8, weighted=True).as_undirected()


# --------------------------------------------------------------------------- #
# satellite: param leaf dtype drift must never retrace
# --------------------------------------------------------------------------- #
def test_param_dtype_drift_zero_retraces(g):
    sess = GraphSession.from_graph(g, 4, "cdbh")
    sess.query(SSSP(), {"source": 0}, warm=False)        # compiles once
    with retrace_guard(label="param dtype drift"):
        for p in (1, np.int32(2), np.int64(3), np.array(4),
                  np.array(5, dtype=np.int32)):
            sess.query(SSSP(), {"source": p}, warm=False)
    assert sess.stats.cache_misses == 1
    assert len(sess._runners) == 1


def test_canonical_params_scalar_normalization():
    variants = [{"source": 3}, {"source": np.int32(3)},
                {"source": np.int64(3)}, {"source": np.array(3)}]
    keys = {params_struct_key(canonical_params(v)) for v in variants}
    assert len(keys) == 1
    fkeys = {params_struct_key(canonical_params({"x": v}))
             for v in (0.5, np.float32(0.5), np.float64(0.5),
                       np.array(0.5))}
    assert len(fkeys) == 1
    # ndim >= 1 leaves keep their dtype (caller's choice): an int vector
    # param (e.g. MSSP sources) never collapses into a float one
    ai = canonical_params({"v": np.zeros(4, np.int32)})
    af = canonical_params({"v": np.zeros(4, np.float32)})
    assert params_struct_key(ai) != params_struct_key(af)


# --------------------------------------------------------------------------- #
# tentpole (a): cross-tenant executable sharing
# --------------------------------------------------------------------------- #
def test_cross_tenant_single_compile_sim(g, g2):
    pool = SessionPool(max_runners=8)
    a = pool.open("a", g, n_parts=4)
    b = pool.open("b", g2, n_parts=4)
    assert a.shape_key == b.shape_key, "fixtures must share a bucket"
    a.query(SSSP(), {"source": 0}, warm=False)
    with retrace_guard(label="tenant b shared-runner query"):
        rb, st = b.query(SSSP(), {"source": 5}, warm=False)
    assert st.compile_time == 0.0
    assert pool.runner_cache.misses == 1
    assert pool.runner_cache.hits == 1
    # tenant b's answer must be for tenant b's graph, not a's
    ref, _ = GraphSession.from_graph(g2, 4, "cdbh").query(
        SSSP(), {"source": 5}, warm=False)
    assert np.array_equal(rb, ref, equal_nan=True)
    [entry] = pool.runner_cache.entries.values()
    assert entry.owners == {"a", "b"}
    pool.close_all()


def test_eviction_fairness_unit():
    # flooding owner loses its own LRU entry; the small owner's survives
    cache = RunnerCache(max_entries=3)

    def entry():
        return RunnerEntry(compiled=object(), shape_key=(), program="P")

    cache.insert("b1", entry(), "b")
    cache.insert("a1", entry(), "a")
    cache.insert("a2", entry(), "a")
    cache.insert("a3", entry(), "a")          # overflow: a holds the most
    assert "b1" in cache
    assert "a1" not in cache                  # a's own LRU entry evicted
    assert cache.by_owner["a"].evicted_pins == 1
    assert cache.by_owner["b"].evicted_pins == 0


def test_eviction_fairness_sessions(g, g2):
    pool = SessionPool(max_runners=2)
    a = pool.open("a", g, n_parts=4)
    b = pool.open("b", g2, n_parts=4)
    b.query(SSSP(), {"source": 0}, warm=False)
    # tenant a floods the 2-slot cache with distinct programs
    for tol in (1e-5, 1e-6, 1e-7):
        a.query(PageRank(tol=tol), {"n_vertices": g.n_vertices}, warm=False)
    # b's runner survived the flood: re-query compiles nothing
    misses = pool.runner_cache.misses
    b.query(SSSP(), {"source": 1}, warm=False)
    assert pool.runner_cache.misses == misses
    assert pool.stats()["runner_cache"]["by_owner"]["b"].evicted_pins == 0
    pool.close_all()


def test_pool_lifecycle(g, g2):
    pool = SessionPool(max_runners=8)
    a = pool.open("a", g, n_parts=4)
    b = pool.open("b", g2, n_parts=4)
    a.query(SSSP(), {"source": 0}, warm=False)
    b.query(SSSP(), {"source": 0}, warm=False)
    # closing one tenant keeps the shared entry alive for the other
    pool.close("a")
    assert a.closed and "a" not in pool
    [entry] = pool.runner_cache.entries.values()
    assert entry.owners == {"b"}
    misses = pool.runner_cache.misses
    b.query(SSSP(), {"source": 2}, warm=False)
    assert pool.runner_cache.misses == misses
    pool.close("b")
    assert len(pool.runner_cache) == 0
    with pytest.raises(ValueError):
        pool.open("b", g, pg=a.pg)            # exactly one source
    pool.close_all()


def test_pool_max_sessions_lru(g):
    with SessionPool(max_sessions=2) as pool:
        a = pool.open("a", g, n_parts=4)
        pool.open("b", g, n_parts=4)
        pool.open("c", g, n_parts=4)          # evicts a (LRU)
        assert a.closed
        assert pool.tenants == ["b", "c"]
        assert pool.sessions_closed == 1


# --------------------------------------------------------------------------- #
# satellite: close() + context manager
# --------------------------------------------------------------------------- #
def test_session_close(g):
    sess = GraphSession.from_graph(g, 4, "cdbh")
    sess.query(SSSP(), {"source": 0})
    sess.close()
    assert sess.closed
    assert sess._device is None
    assert len(sess._runners) == 0 and not sess._warm
    for fn in (lambda: sess.query(SSSP(), {"source": 0}),
               lambda: sess.query_batch(SSSP(), [{"source": 0}]),
               lambda: sess.update(adds=([0], [1], [1.0])),
               lambda: sess.flush(),
               lambda: sess.compact(),
               lambda: sess.device_graph()):
        with pytest.raises(RuntimeError, match="closed"):
            fn()
    sess.close()                              # idempotent
    with GraphSession.from_graph(g, 4, "cdbh") as s2:
        s2.query(SSSP(), {"source": 0})
    assert s2.closed


# --------------------------------------------------------------------------- #
# tentpole (b): micro-batched launches == singleton launches
# --------------------------------------------------------------------------- #
def test_query_batch_bit_identical(g):
    sess = GraphSession.from_graph(g, 4, "cdbh")
    singles = [sess.query(SSSP(), {"source": i}, warm=False)[0]
               for i in range(3)]
    out = sess.query_batch(SSSP(), [{"source": i} for i in range(3)],
                           warm=False)
    assert len(out) == 3
    for i, (res, st) in enumerate(out):
        assert np.array_equal(res, singles[i], equal_nan=True)
        assert st.batch_size == 3
    # one launch for the whole batch
    assert sess.stats.batches == 1 and sess.stats.batched_queries == 3
    # B=3 pads to the B=4 bucket: a 4-lane batch re-hits the same runner
    misses = sess.stats.cache_misses
    out4 = sess.query_batch(SSSP(), [{"source": i} for i in range(4)],
                            warm=False)
    assert sess.stats.cache_misses == misses
    for i, (res, st) in enumerate(out4[:3]):
        assert np.array_equal(res, singles[i], equal_nan=True)

    cc1, _ = sess.query(ConnectedComponents(), warm=False)
    for res, _ in sess.query_batch(ConnectedComponents(), [None, None],
                                   warm=False):
        assert np.array_equal(res, cc1)

    pr1, _ = sess.query(PageRank(), {"n_vertices": g.n_vertices},
                        warm=False)
    for res, _ in sess.query_batch(
            PageRank(), [{"n_vertices": g.n_vertices}] * 2, warm=False):
        assert np.allclose(res, pr1)

    with pytest.raises(ValueError, match="structure"):
        sess.query_batch(SSSP(), [{"source": 0}, {"bad": 1}])
    assert sess.query_batch(SSSP(), []) == []


def test_query_batch_pallas_backend(g):
    cfg = EngineConfig(edge_backend="pallas_tiles")
    sess = GraphSession.from_graph(g, 4, "cdbh", cfg=cfg)
    singles = [sess.query(SSSP(), {"source": i}, warm=False)[0]
               for i in range(2)]
    out = sess.query_batch(SSSP(), [{"source": i} for i in range(2)],
                           warm=False)
    for i, (res, _) in enumerate(out):
        assert np.array_equal(res, singles[i], equal_nan=True)


# --------------------------------------------------------------------------- #
# tentpole (c): tiered result cache
# --------------------------------------------------------------------------- #
def test_result_cache_zero_launches_and_invalidation(g):
    rc = ResultCache(store=DictStore())
    sess = GraphSession.from_graph(g, 4, "cdbh", result_cache=rc,
                                   tenant="t")
    r1, st1 = sess.query(SSSP(), {"source": 0})
    assert st1.result_cache_tier == "miss"
    launches = sess.stats.device_launches
    r2, st2 = sess.query(SSSP(), {"source": 0})
    assert st2.result_cache_tier == "l1"
    assert sess.stats.device_launches == launches, "hit touched the device"
    assert st2.compile_time == 0.0 and st2.supersteps == st1.supersteps
    assert np.array_equal(r1, r2, equal_nan=True)
    # L2 promotion after the in-process tier is dropped
    rc.clear_l1()
    r3, st3 = sess.query(SSSP(), {"source": 0})
    assert st3.result_cache_tier == "l2"
    assert sess.stats.device_launches == launches
    assert np.array_equal(r1, r3, equal_nan=True)
    # a deleting flush moves the graph version: old entries unreachable
    s, d = g.src[:4], g.dst[:4]
    sess.update(deletes=(s, d))
    sess.flush()
    r4, st4 = sess.query(SSSP(), {"source": 0})
    assert st4.result_cache_tier == "miss"
    assert sess.stats.device_launches == launches + 1
    # ... and the post-delete result is served on re-query
    _, st5 = sess.query(SSSP(), {"source": 0})
    assert st5.result_cache_tier == "l1"
    assert sess.stats.result_cache_l1_hits == 1 + 1  # pre- and post-delete
    sess.close()


def test_result_cache_batch_all_hit(g):
    rc = ResultCache()
    sess = GraphSession.from_graph(g, 4, "cdbh", result_cache=rc,
                                   tenant="t")
    plist = [{"source": i} for i in range(3)]
    out1 = sess.query_batch(SSSP(), plist, warm=False)
    launches = sess.stats.device_launches
    out2 = sess.query_batch(SSSP(), plist, warm=False)
    assert sess.stats.device_launches == launches
    for (r1, _), (r2, st2) in zip(out1, out2):
        assert st2.result_cache_tier == "l1"
        assert np.array_equal(r1, r2, equal_nan=True)
    # a partial hit must NOT serve stale lanes from the cache path
    out3 = sess.query_batch(SSSP(), [{"source": 0}, {"source": 9}],
                            warm=False)
    assert sess.stats.device_launches == launches + 1
    assert all(st.result_cache_tier == "miss" for _, st in out3)
    sess.close()


def test_result_cache_ttl_and_stores(tmp_path):
    now = [0.0]
    rc = ResultCache(ttl=10.0, store=DictStore(clock=lambda: now[0]),
                     clock=lambda: now[0])
    rc.put("k", dict(results=np.arange(4.0), supersteps=3))
    val, tier = rc.get("k")
    assert tier == "l1" and val["supersteps"] == 3
    now[0] = 11.0                              # past the TTL in BOTH tiers
    val, tier = rc.get("k")
    assert tier == "miss" and val is None
    assert rc.stats.expirations == 1

    fs = FileStore(str(tmp_path), clock=lambda: now[0])
    rc2 = ResultCache(store=fs)
    blob = dict(results=np.arange(6, dtype=np.float32).reshape(2, 3),
                supersteps=5, edge_backend="coo")
    rc2.put("x", blob)
    rc2.clear_l1()
    val, tier = rc2.get("x")
    assert tier == "l2"
    assert np.array_equal(val["results"], blob["results"])
    assert val["results"].dtype == np.float32
    assert val["supersteps"] == 5 and val["edge_backend"] == "coo"
    # peek reports tiers without billing hits
    stats_before = dataclass_tuple = (rc2.stats.l1_hits, rc2.stats.l2_hits)
    assert rc2.peek("x") == "l1"
    assert (rc2.stats.l1_hits, rc2.stats.l2_hits) == stats_before
    assert rc2.peek("missing") is None

    rc3 = ResultCache(max_entries=2)
    for i in range(3):
        rc3.put(f"k{i}", dict(results=np.zeros(1)))
    assert len(rc3) == 2 and rc3.stats.l1_evictions == 1


# --------------------------------------------------------------------------- #
# the admission queue
# --------------------------------------------------------------------------- #
def test_batcher_coalescing(g):
    sess = GraphSession.from_graph(g, 4, "cdbh")
    bat = MicroBatcher(sess, BatchPolicy(max_batch=3, max_delay=0.005))
    futs = [bat.submit(SSSP(), {"source": i}, warm=False) for i in range(3)]
    # the third submit filled the group: launched inline, one batch
    assert all(f.done() for f in futs)
    assert bat.stats.launched_batches == 1 and bat.stats.batched_requests == 3
    for i, f in enumerate(futs):
        res, st = f.result(timeout=1)
        ref, _ = sess.query(SSSP(), {"source": i}, warm=False)
        assert np.array_equal(res, ref, equal_nan=True)
        assert st.batch_size == 3 and st.queue_time >= 0.0


def test_batcher_max_delay_and_deadline(g):
    now = [0.0]
    sess = GraphSession.from_graph(g, 4, "cdbh")
    bat = MicroBatcher(sess, BatchPolicy(max_batch=8, max_delay=1.0),
                       clock=lambda: now[0])
    f1 = bat.submit(SSSP(), {"source": 0}, warm=False)
    assert bat.poll() == 0 and not f1.done()   # not due yet
    now[0] = 1.5
    assert bat.poll() == 1                     # oldest waited past max_delay
    res, st = f1.result(timeout=1)
    assert st.batch_size == 1 and st.queue_time == 1.5
    assert bat.stats.launched_singletons == 1
    # a deadline forces the launch early
    f2 = bat.submit(SSSP(), {"source": 1}, warm=False, deadline=now[0] + 0.5)
    assert bat.poll() == 1 and f2.done()       # 0.5 <= max_delay horizon
    # incompatible structures coalesce into separate groups
    f3 = bat.submit(SSSP(), {"source": 2}, warm=False)
    f4 = bat.submit(SSSP(), {"source": np.array([3], np.int32)},
                    warm=False)
    assert bat.pending == 2
    assert bat.flush() == 2
    assert f3.done() and f4.done()
    f4.result(timeout=1)


def test_batcher_fast_path_and_pool(g, g2):
    rc = ResultCache()
    pool = SessionPool(result_cache=rc)
    pool.open("a", g, n_parts=4)
    pool.open("b", g2, n_parts=4)
    with MicroBatcher(pool, BatchPolicy(max_batch=2)) as bat:
        fa = bat.submit(SSSP(), {"source": 0}, tenant="a")
        fb = bat.submit(SSSP(), {"source": 0}, tenant="b")
        # different sessions -> different groups; stop() flushes both
    ra, _ = fa.result(timeout=1)
    rb, _ = fb.result(timeout=1)
    assert not np.array_equal(ra, rb, equal_nan=True)  # per-tenant graphs
    # second round: answered straight from the result cache, no queueing
    f2 = bat.submit(SSSP(), {"source": 0}, tenant="a")
    assert f2.done() and bat.stats.fast_path_hits == 1
    res, st = f2.result(timeout=1)
    assert st.result_cache_tier == "l1" and st.queue_time == 0.0
    assert np.array_equal(ra, res, equal_nan=True)
    pool.close_all()


# --------------------------------------------------------------------------- #
# shard_map backend (subprocess: fake devices before jax init)
# --------------------------------------------------------------------------- #
SERVING_SHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
from repro.analysis.sanitizer import retrace_guard
from repro.compat import make_mesh
from repro.core import EngineConfig
from repro.graphgen import powerlaw_graph
from repro.algos import SSSP
from repro.serving import SessionPool
from repro.session import GraphSession

g = powerlaw_graph(400, seed=7, weighted=True).as_undirected()
g2 = powerlaw_graph(400, seed=8, weighted=True).as_undirected()
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = EngineConfig(subgraph_axes=("pod", "data"), edge_axes=("model",))

# cross-tenant sharing: tenant b compiles nothing
pool = SessionPool(mesh=mesh, cfg=cfg)
a = pool.open("a", g, n_parts=4)
b = pool.open("b", g2, n_parts=4)
a.query(SSSP(), {"source": 0}, warm=False)
with retrace_guard(label="tenant b shared-runner query (shard)"):
    rb, st = b.query(SSSP(), {"source": 5}, warm=False)
assert pool.runner_cache.misses == 1 and pool.runner_cache.hits == 1
ref, _ = GraphSession.from_graph(g2, 4, "cdbh").query(
    SSSP(), {"source": 5}, warm=False)
assert np.array_equal(np.asarray(rb), np.asarray(ref), equal_nan=True)

# micro-batch == singleton, bit-identical, on the shard backend too
singles = [a.query(SSSP(), {"source": i}, warm=False)[0] for i in range(3)]
out = a.query_batch(SSSP(), [{"source": i} for i in range(3)], warm=False)
for i, (res, st) in enumerate(out):
    assert np.array_equal(np.asarray(res), np.asarray(singles[i]),
                          equal_nan=True), i
    assert st.batch_size == 3
pool.close_all()
print("SERVING_SHARD_OK")
"""


def test_serving_shard_map_backend():
    res = subprocess.run([sys.executable, "-c", SERVING_SHARD_SCRIPT],
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stderr[-4000:]
    assert "SERVING_SHARD_OK" in res.stdout
