"""Online rebalancing under the new algorithm workloads: a mid-stream
``plan_rebalance``/migration must preserve query answers bit-identically
for BFS and k-core, and their warm state must survive the layout-only
remap — including k-core's delete-polarity entries, since a migration
moves edges without changing the graph (mirrors test_rebalance.py's
session lifecycle pins for SSSP)."""
import numpy as np
import pytest

import harness
from repro.algos import BFS, make_kcore
from repro.core import build_partitioned_graph, partition_metrics
from repro.graphgen import powerlaw_graph
from repro.partition.rebalance import plan_rebalance
from repro.session import GraphSession
from repro.stream.ingest import StreamContext


def _skewed_session(n_v=900, P=4, hot=0.7, seed=5):
    g = harness.canonicalize(
        powerlaw_graph(n_v, alpha=2.2, avg_degree=6, seed=seed))
    E = g.src.size
    idx = np.arange(E)
    part = np.where(idx % 10 < int(hot * 10), 0,
                    idx % (P - 1) + 1).astype(np.int32)
    pg = build_partitioned_graph(g, part, P)
    ctx = StreamContext("rh-vc", P, 0, g.n_vertices,
                        np.zeros(g.n_vertices, np.int64))
    return g, GraphSession(pg, ctx=ctx, rebalance="manual")


@pytest.mark.parametrize("maker", [lambda: (BFS(), {"source": 0}),
                                   lambda: make_kcore(2)],
                         ids=["bfs", "kcore"])
def test_rebalance_query_parity_and_warm_survival(maker):
    g, sess = _skewed_session()
    try:
        prog, params = maker()
        cold, st0 = sess.query(prog, params, warm=False)
        before = np.asarray(sess.pg.collect(cold, fill=0))
        plan = plan_rebalance(sess.pg, target=1.0)
        assert plan.n_moves > 0, "skewed by construction"
        rs = sess.rebalance(target=1.0)
        assert rs is not None and rs.n_moved > 0
        assert partition_metrics(sess.pg).imbalance < plan.imbalance_before
        warm, st1 = sess.query(prog, params)
        after = np.asarray(sess.pg.collect(warm, fill=0))
        np.testing.assert_array_equal(before, after)
        # the warm entry survived the layout-only remap: a migration moves
        # edges without touching the graph, so both warm polarities hold
        assert st1.supersteps <= st0.supersteps
    finally:
        sess.close()


def test_rebalance_mid_stream_kcore_delete_polarity():
    """Rebalance *between* delete flushes: k-core's delete-polarity warm
    entry must survive both the flush and the migration, and the warm
    answer must stay bit-identical to a forced cold recompute."""
    g, sess = _skewed_session(seed=7)
    try:
        prog, params = make_kcore(2)
        sess.query(prog, params)
        pairs = sorted({(min(s, d), max(s, d))
                        for s, d in zip(g.src.tolist(), g.dst.tolist())})
        rng = np.random.default_rng(0)
        sel = [pairs[i] for i in rng.choice(len(pairs), 12, replace=False)]
        for chunk in (sel[:6], sel[6:]):
            s = np.array([p[0] for p in chunk] + [p[1] for p in chunk])
            d = np.array([p[1] for p in chunk] + [p[0] for p in chunk])
            sess.update(deletes=(s, d))
            sess.flush()
            sess.rebalance(target=1.0)       # may be a no-op once balanced
            warm, st_w = sess.query(prog, params, warm=True)
            cold, st_c = sess.query(prog, params, warm=False,
                                    use_result_cache=False)
            np.testing.assert_array_equal(
                np.asarray(sess.pg.collect(warm, fill=0)),
                np.asarray(sess.pg.collect(cold, fill=0)))
            assert st_w.supersteps <= st_c.supersteps
    finally:
        sess.close()


def test_rebalance_mid_stream_bfs_insert_polarity():
    """The mirror image: BFS's insert-polarity warm entry rides through
    insert flushes interleaved with migrations."""
    g, sess = _skewed_session(seed=11)
    try:
        _, st0 = sess.query(BFS(), {"source": 0})
        rng = np.random.default_rng(1)
        for _ in range(2):
            u = rng.integers(0, g.n_vertices, 8)
            v = rng.integers(0, g.n_vertices, 8)
            keep = u != v
            u, v = u[keep], v[keep]
            sess.update(adds=(np.concatenate([u, v]),
                              np.concatenate([v, u]),
                              np.ones(2 * u.size, np.float32)))
            sess.flush()
            sess.rebalance(target=1.0)
            warm, st_w = sess.query(BFS(), {"source": 0}, warm=True)
            cold, st_c = sess.query(BFS(), {"source": 0}, warm=False,
                                    use_result_cache=False)
            np.testing.assert_array_equal(
                np.asarray(sess.pg.collect(warm, fill=np.inf)),
                np.asarray(sess.pg.collect(cold, fill=np.inf)))
            assert st_w.supersteps <= st_c.supersteps
    finally:
        sess.close()
