"""Load monitor + online rebalancer invariants (docs/PARTITIONING.md).

Pins: hysteresis gating, deterministic cheapest-first planning, the
edge-preservation invariant of a migration, balance restoration, query
parity (bit-identical results before/after a migration) with zero retraces
when the padded buckets don't move, warm-state survival, and the
auto-trigger lifecycle under streaming churn — on both engine backends
(sim inline, shard_map via subprocess).
"""
import subprocess
import sys

import numpy as np
import pytest

from repro.algos import SSSP, ConnectedComponents
from repro.analysis.sanitizer import retrace_guard
from repro.core import build_partitioned_graph, partition_metrics
from repro.graphgen import powerlaw_graph, random_graph
from repro.partition.ebv import RelocationOverlay
from repro.partition.monitor import LoadMonitor, MonitorConfig
from repro.partition.rebalance import (execute_rebalance, plan_rebalance)
from repro.session import GraphSession
from repro.stream.ingest import StreamContext


def _skewed_pg(n_v=1500, P=4, hot=0.7, seed=5):
    """A deliberately imbalanced partition: most edges piled on part 0."""
    g = powerlaw_graph(n_v, alpha=2.2, avg_degree=6, seed=seed)
    E = g.src.size
    idx = np.arange(E)
    part = np.where(idx % 10 < int(hot * 10), 0,
                    idx % (P - 1) + 1).astype(np.int32)
    pg = build_partitioned_graph(g, part, P)
    ctx = StreamContext("rh-vc", P, 0, g.n_vertices,
                        np.zeros(g.n_vertices, np.int64))
    return g, pg, ctx


def _edge_multiset(pg):
    rows = []
    for p in range(pg.n_parts):
        m = pg.emask[p]
        gs = pg.gvid[p][pg.esrc[p][m]]
        gd = pg.gvid[p][pg.edst[p][m]]
        rows.append(gs.astype(np.int64) * pg.n_vertices + gd)
    return np.sort(np.concatenate(rows))


# --------------------------------------------------------------------------- #
# monitor
# --------------------------------------------------------------------------- #
class _FakePG:
    def __init__(self, epp, P=4, slots=8):
        self.edges_per_part = np.asarray(epp)
        self.vmask = np.zeros((P, slots), bool)
        self.is_frontier = np.zeros((P, slots), bool)


def test_monitor_hysteresis_cycle():
    m = LoadMonitor(MonitorConfig(high=1.5, low=1.15, patience=2))
    hot, cool = _FakePG([100, 10, 10, 10]), _FakePG([33, 33, 32, 32])
    assert m.observe_graph(hot) > 1.5
    assert not m.should_rebalance()          # patience not yet served
    m.observe_graph(hot)
    assert m.should_rebalance()
    m.notify_rebalanced()
    assert m.triggers == 1
    m.observe_graph(hot)
    m.observe_graph(hot)
    assert not m.should_rebalance()          # disarmed until gauge < low
    m.observe_graph(cool)                    # re-arms
    m.observe_graph(hot)
    m.observe_graph(hot)
    assert m.should_rebalance()


def test_monitor_query_signal_ewma():
    m = LoadMonitor(MonitorConfig(w_edges=0.0, w_frontier=0.0, ema=0.5))

    class _St:
        partition_sweep_time = [4.0, 1.0, 1.0, 2.0]
        partition_flops = []
    m.observe_query(_St())
    assert m.gauge == pytest.approx(4.0 / 2.0)
    _St.partition_sweep_time = [2.0, 2.0, 2.0, 2.0]
    m.observe_query(_St())                   # EWMA halves the skew
    assert 1.0 < m.gauge < 2.0
    s = m.signals()
    assert set(s) >= {"edges", "sweep_time", "frontier", "gauge", "armed"}


def test_monitor_balanced_graph_never_triggers():
    m = LoadMonitor()
    pg = _FakePG([25, 25, 25, 25])
    for _ in range(10):
        m.observe_graph(pg)
    assert not m.should_rebalance()
    assert m.gauge == pytest.approx(1.0)


# --------------------------------------------------------------------------- #
# planner + executor
# --------------------------------------------------------------------------- #
def test_plan_rebalance_deterministic_and_bounded():
    _, pg, _ = _skewed_pg()
    p1 = plan_rebalance(pg, target=1.05, max_fraction=0.5)
    p2 = plan_rebalance(pg, target=1.05, max_fraction=0.5)
    assert p1.n_moves == p2.n_moves > 0
    for p in p1.moves:
        np.testing.assert_array_equal(p1.moves[p][0], p2.moves[p][0])
        np.testing.assert_array_equal(p1.moves[p][1], p2.moves[p][1])
    assert p1.imbalance_after < p1.imbalance_before
    # the move budget is respected
    total = int(pg.emask.sum())
    small = plan_rebalance(pg, target=1.05, max_fraction=0.01)
    assert small.n_moves <= int(0.01 * total)
    # a balanced graph plans nothing
    g = random_graph(300, 2000, seed=1)
    bal = build_partitioned_graph(
        g, (np.arange(g.src.size) % 4).astype(np.int32), 4)
    assert plan_rebalance(bal, target=1.05).n_moves == 0


def test_execute_rebalance_preserves_edges_and_restores_balance():
    g, pg, ctx = _skewed_pg()
    before = _edge_multiset(pg)
    imb0 = partition_metrics(pg).imbalance
    plan = plan_rebalance(pg, target=1.05, max_fraction=0.5)
    # the planned pairs + destinations, captured before execution mutates pg
    moved = []
    for p, (idx, dst_part) in plan.moves.items():
        m = pg.emask[p]
        gs = pg.gvid[p][pg.esrc[p][m]][idx]
        gd = pg.gvid[p][pg.edst[p][m]][idx]
        moved.append((gs, gd, dst_part))
    rs = execute_rebalance(pg, ctx, plan)
    # not one edge lost or duplicated by the migration
    np.testing.assert_array_equal(before, _edge_multiset(pg))
    assert rs.n_moved == plan.n_moves
    assert rs.imbalance_after < imb0
    assert rs.imbalance_after <= 1.5         # monitor's high threshold
    # a stateless context got a relocation overlay: every moved pair now
    # routes (deletes AND re-adds) to its migration destination
    assert isinstance(ctx.router_state, RelocationOverlay)
    for gs, gd, dst_part in moved:
        np.testing.assert_array_equal(ctx.route_deletes(gs, gd), dst_part)
        np.testing.assert_array_equal(ctx.route_adds(gd, gs), dst_part)


def test_rebalance_warm_remap_contract():
    _, pg, ctx = _skewed_pg(n_v=800)
    P, vmax = pg.n_parts, pg.v_max
    # a warm block tagged by global id so survivors are checkable
    tag = np.where(pg.vmask, pg.gvid, -1).astype(np.float64)
    plan = plan_rebalance(pg, target=1.0, max_fraction=0.5)
    rs = execute_rebalance(pg, ctx, plan)
    out = rs.remap_state(tag, fill=np.float64(np.inf))
    assert out.shape == (P, rs.v_max_after)
    # every surviving member row carries its old value; new rows = fill
    want = np.where(pg.vmask, pg.gvid, -1)
    moved = out[pg.vmask]
    keep = np.isfinite(moved)
    np.testing.assert_array_equal(moved[keep], want[pg.vmask][keep])


# --------------------------------------------------------------------------- #
# session lifecycle (sim backend)
# --------------------------------------------------------------------------- #
def test_session_rebalance_query_parity_and_zero_retrace():
    g, pg, ctx = _skewed_pg(n_v=1000)
    sess = GraphSession(pg, ctx=ctx, rebalance="manual")
    cold, st0 = sess.query(SSSP(), {"source": 0}, warm=False)
    before = sess.pg.collect(cold)
    v0 = sess._host_version
    shape0 = sess.shape_key
    rs = sess.rebalance(target=1.0)
    assert rs is not None and rs.n_moved > 0
    assert sess.stats.rebalances == 1
    assert sess._host_version == v0 + 1      # result-cache keys roll over
    if sess.shape_key == shape0:
        # in-bucket migration: the compiled runner must be reused as-is
        with retrace_guard(label="post-rebalance query"):
            warm, st1 = sess.query(SSSP(), {"source": 0})
        assert st1.compile_time == 0.0
    else:
        warm, st1 = sess.query(SSSP(), {"source": 0})
    after = sess.pg.collect(warm)
    np.testing.assert_array_equal(np.asarray(before), np.asarray(after))
    # warm restart survived the migration (monotone program, fewer steps)
    assert st1.supersteps <= st0.supersteps
    # repeated triggers keep converging (spill is deferred, not forced)
    # until the graph sits under target — then rebalance() is a no-op
    for _ in range(6):
        if sess.rebalance(target=1.2) is None:
            break
    assert sess.rebalance(target=1.2) is None
    assert partition_metrics(sess.pg).imbalance <= 1.2 * 1.05
    sess.close()


def test_session_rebalance_validation():
    g = powerlaw_graph(300, alpha=2.2, avg_degree=4, seed=0)
    with pytest.raises(ValueError, match="rebalance"):
        GraphSession.from_graph(g, 2, "cdbh", rebalance="sometimes")
    # rebalance needs a StreamContext, like every mutation path
    from repro.core import partition_and_build
    pg = partition_and_build(g, 2, "cdbh")
    sess = GraphSession(pg)
    with pytest.raises(ValueError, match="rebalance"):
        sess.rebalance()
    sess.close()


def test_session_auto_rebalance_under_churn():
    """Streaming churn on a skewed partition trips the hysteresis gauge and
    migrates automatically — exactly once, then disarms (no thrash)."""
    g, pg, ctx = _skewed_pg(n_v=1200, hot=0.8, seed=9)
    mon = LoadMonitor(MonitorConfig(high=1.5, low=1.15, patience=2))
    sess = GraphSession(pg, ctx=ctx, rebalance="auto", monitor=mon)
    imb0 = partition_metrics(pg).imbalance
    assert imb0 > 2.0
    rng = np.random.default_rng(3)
    for _ in range(4):
        sess.update(adds=(rng.integers(0, 1200, 50),
                          rng.integers(0, 1200, 50)))
        sess.flush()
    assert sess.stats.rebalances == 1
    assert mon.triggers == 1
    assert partition_metrics(sess.pg).imbalance < imb0
    # still queryable, and the per-shard gauges flow
    _, st = sess.query(ConnectedComponents())
    assert len(st.partition_edge_counts) == sess.pg.n_parts
    assert len(st.partition_sweep_time) == sess.pg.n_parts
    assert sess.stats.partition_edge_counts == st.partition_edge_counts
    sess.close()


def test_session_ebv_end_to_end_rebalance():
    """EBV-partitioned session: manual rebalance keeps the router state
    consistent (resync) so later deletes still find resident copies."""
    g = powerlaw_graph(1000, alpha=2.2, avg_degree=5, seed=7)
    sess = GraphSession.from_graph(g, 4, "ebv", rebalance="manual")
    r0, _ = sess.query(ConnectedComponents())
    before = sess.pg.collect(r0)
    sess.rebalance(target=1.0)               # may be a no-op if balanced
    # delete a slice of original edges through the router's pair table
    sess.update(deletes=(g.src[:100], g.dst[:100]))
    sess.flush()
    assert int(sess.pg.emask.sum()) == g.src.size - 100
    r1, _ = sess.query(ConnectedComponents(), warm=False)
    assert sess.pg.collect(r1).shape == before.shape
    sess.close()


# --------------------------------------------------------------------------- #
# shard_map backend parity (subprocess: needs fake devices before jax init)
# --------------------------------------------------------------------------- #
REBALANCE_SHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
from repro.analysis.sanitizer import retrace_guard
from repro.compat import make_mesh
from repro.core import EngineConfig, build_partitioned_graph
from repro.graphgen import powerlaw_graph
from repro.algos import SSSP
from repro.session import GraphSession
from repro.stream.ingest import StreamContext

g = powerlaw_graph(1000, alpha=2.2, avg_degree=6, seed=5)
E = g.src.size
idx = np.arange(E)
part = np.where(idx % 10 < 7, 0, idx % 3 + 1).astype(np.int32)

def mk(mesh=None, cfg=None):
    pg = build_partitioned_graph(g, part.copy(), 4)
    ctx = StreamContext("rh-vc", 4, 0, g.n_vertices,
                        np.zeros(g.n_vertices, np.int64))
    return GraphSession(pg, ctx=ctx, rebalance="manual", mesh=mesh, cfg=cfg)

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = EngineConfig(subgraph_axes=("pod", "data"), edge_axes=("model",))
shard = mk(mesh, cfg)
sim = mk()

a0, _ = shard.query(SSSP(), {"source": 0})
b0, _ = sim.query(SSSP(), {"source": 0})
assert (np.asarray(a0) == np.asarray(b0)).all(), "pre-rebalance shard != sim"
ga = shard.pg.collect(a0)

rs_a = shard.rebalance(target=1.0)
rs_b = sim.rebalance(target=1.0)
assert rs_a is not None and rs_b is not None
assert rs_a.n_moved == rs_b.n_moved, "plans diverged across backends"

shape_same = True  # collected-global parity must hold regardless of buckets
if shape_same:
    a1, s1 = shard.query(SSSP(), {"source": 0})
    b1, _ = sim.query(SSSP(), {"source": 0})
assert (np.asarray(a1) == np.asarray(b1)).all(), "post-rebalance shard != sim"
assert (shard.pg.collect(a1) == ga).all(), "migration changed results"
print("REBALANCE_SHARD_OK")
"""


def test_rebalance_shard_map_backend():
    res = subprocess.run([sys.executable, "-c", REBALANCE_SHARD_SCRIPT],
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stderr[-4000:]
    assert "REBALANCE_SHARD_OK" in res.stdout
