"""Training substrate: optimizer, checkpoint/restart exactness, data
determinism, gradient compression."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.training import steps as S
from repro.training.checkpoint import (keep_last, latest_checkpoint,
                                       load_pytree, save_pytree)
from repro.training.data import SyntheticTokens
from repro.training.optimizer import (adamw_init, adamw_update,
                                      clip_by_global_norm, lr_schedule)


def test_adamw_converges_quadratic():
    params = {"w": jnp.array([3.0, -2.0])}
    opt = adamw_init(params)
    for _ in range(300):
        g = {"w": 2 * params["w"]}          # grad of ||w||^2
        params, opt = adamw_update(params, g, opt, lr=0.05, weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_clip_by_global_norm():
    g = {"a": jnp.ones((10,)) * 3.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 3.0 * np.sqrt(10)) < 1e-4
    n2 = float(jnp.sqrt(jnp.sum(jnp.square(clipped["a"]))))
    assert abs(n2 - 1.0) < 1e-5


def test_lr_schedule_shape():
    lrs = [float(lr_schedule(jnp.int32(s), peak_lr=1e-3, warmup=10,
                             total=100)) for s in range(100)]
    assert lrs[0] < lrs[9] and abs(lrs[10] - 1e-3) < 1e-9
    assert lrs[-1] < lrs[20]


def test_data_deterministic_and_resumable():
    ds = SyntheticTokens(1000, 64, 4, seed=3)
    a, b = ds.batch(7), ds.batch(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = ds.batch(8)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_checkpoint_roundtrip_and_retention(tmp_path):
    cfg = get_smoke_config("olmo_1b")
    state = S.make_train_state(jax.random.PRNGKey(0), cfg)
    p = str(tmp_path / "step_0000010.npz")
    save_pytree(p, state, extra_meta={"data_cursor": 10})
    restored, meta = load_pytree(p, like=state)
    assert meta["data_cursor"] == 10
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for i in (20, 30, 40):
        save_pytree(str(tmp_path / f"step_{i:07d}.npz"), state,
                    extra_meta={"data_cursor": i})
    keep_last(str(tmp_path), 2)
    assert latest_checkpoint(str(tmp_path)).endswith("0000040.npz")
    assert len([f for f in os.listdir(tmp_path) if f.endswith(".npz")]) == 2


def test_train_restart_bitexact(tmp_path):
    """Fault tolerance: train 6 steps straight == train 3, 'crash', resume 3."""
    from repro.launch.train import train
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    s_full, h_full = train("olmo_1b", steps=6, batch=2, seq=32, ckpt_dir=d1,
                           ckpt_every=100, log_every=100)
    train("olmo_1b", steps=3, batch=2, seq=32, ckpt_dir=d2, ckpt_every=3,
          log_every=100)
    s_res, h_res = train("olmo_1b", steps=6, batch=2, seq=32, ckpt_dir=d2,
                         ckpt_every=100, resume=True, log_every=100)
    assert np.allclose(h_full[-1], h_res[-1], atol=1e-6), (h_full, h_res)
    for a, b in zip(jax.tree.leaves(s_full.params), jax.tree.leaves(s_res.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_grad_compression_shard():
    """int8 stochastic-rounding compressed psum ~= exact psum (error feedback
    keeps the bias bounded) — run on 4 fake devices."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P
from repro.training.optimizer import compressed_psum
from repro.compat import make_mesh, shard_map
mesh = make_mesh((4,), ("dp",))
g = jax.random.normal(jax.random.PRNGKey(0), (4, 256)) * 0.01
@partial(shard_map, mesh=mesh, in_specs=P("dp"), out_specs=P("dp"))
def exact(x):
    return jax.lax.pmean(x, "dp")
@partial(shard_map, mesh=mesh, in_specs=P("dp"), out_specs=P("dp"))
def comp(x):
    out, _ = compressed_psum({"g": x}, None, jax.random.PRNGKey(1), "dp")
    return out["g"]
a, b = np.asarray(exact(g)), np.asarray(comp(g))
err = np.abs(a - b).max() / (np.abs(a).max() + 1e-12)
assert err < 0.05, err
print("COMPRESS_OK", err)
"""
    res = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=300)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "COMPRESS_OK" in res.stdout
