"""shard_map backend == simulator backend, run in a subprocess with 8 fake
host devices (2 pods x 2 data x 2 model: 4 subgraphs, edge lists sharded
2-way over the model axis — the hierarchical SVHM mapping of DESIGN.md §2)."""
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax
from repro.core import partition_and_build, run_sim, run_shard_map, EngineConfig
from repro.graphgen import powerlaw_graph, grid_graph
from repro.algos import ConnectedComponents, SSSP, PageRank
from repro.algos.gsim import make_gsim

from repro.compat import make_mesh

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg_sim = EngineConfig(mode="sc")
cfg_shard = EngineConfig(mode="sc", backend="shard_map",
                         subgraph_axes=("pod", "data"), edge_axes=("model",))

g = powerlaw_graph(300, seed=5).as_undirected()
pg = partition_and_build(g, 4, "cdbh")
cc = ConnectedComponents()
r1, s1 = run_sim(cc, pg, None, cfg_sim)
r2, s2 = run_shard_map(cc, pg, mesh, None, cfg_shard)
assert (r1 == r2).all(), "CC mismatch"
assert s1.supersteps == s2.supersteps and s1.total_messages == s2.total_messages

g2 = grid_graph(12, weighted=True, seed=3)
pg2 = partition_and_build(g2, 4, "cdbh")
r1, _ = run_sim(SSSP(), pg2, {"source": 0}, cfg_sim)
r2, _ = run_shard_map(SSSP(), pg2, mesh, {"source": 0}, cfg_shard)
assert np.allclose(r1, r2), "SSSP mismatch"

gd = powerlaw_graph(300, seed=6)
pg3 = partition_and_build(gd, 4, "cdbh")
pr = PageRank(tol=1e-9)
r1, _ = run_sim(pr, pg3, {"n_vertices": gd.n_vertices}, cfg_sim)
r2, _ = run_shard_map(pr, pg3, mesh, {"n_vertices": gd.n_vertices}, cfg_shard)
assert np.allclose(r1, r2, atol=1e-6), "PR mismatch"

labels = np.random.default_rng(0).integers(0, 3, size=gd.n_vertices).astype(np.int32)
pg4 = partition_and_build(gd, 4, "cdbh")
pg4.set_vertex_labels(labels)
prog, params = make_gsim(np.array([[0,1,0],[0,0,1],[0,0,0]], np.int32),
                         np.array([0,1,2], np.int32))
r1, _ = run_sim(prog, pg4, params, cfg_sim)
r2, _ = run_shard_map(prog, pg4, mesh, params, cfg_shard)
assert (r1 == r2).all(), "GSim mismatch"

# compacted sparse SBS == dense SBS
cfg_sparse = EngineConfig(mode="sc", backend="shard_map",
                          subgraph_axes=("pod", "data"), edge_axes=("model",),
                          sparse_sync_capacity=pg.n_slots + 1)
r3, _ = run_shard_map(cc, pg, mesh, None, cfg_sparse)
r4, _ = run_sim(cc, pg, None, cfg_sim)
assert (r3 == r4).all(), "sparse-sync mismatch"

# sharded-SBS (slot shards over the model axis) == dense SBS
cfg_ss = EngineConfig(mode="sc", backend="shard_map",
                      subgraph_axes=("pod", "data"), edge_axes=("model",),
                      shard_slots=True)
r7, s7 = run_shard_map(cc, pg, mesh, None, cfg_ss)
assert (r7 == run_sim(cc, pg, None, cfg_sim)[0]).all(), "shard_slots CC"
r8, _ = run_shard_map(pr, pg3, mesh, {"n_vertices": gd.n_vertices}, cfg_ss)
r9, _ = run_sim(pr, pg3, {"n_vertices": gd.n_vertices}, cfg_sim)
assert np.allclose(r8, r9, atol=1e-6), "shard_slots PR"

# 2D mesh without edge sharding (subgraph axes only)
mesh2 = make_mesh((8,), ("sub",))
pg8 = partition_and_build(g, 8, "cdbh")
cfg8 = EngineConfig(mode="sc", backend="shard_map", subgraph_axes=("sub",))
r5, _ = run_shard_map(cc, pg8, mesh2, None, cfg8)
r6, _ = run_sim(cc, pg8, None, cfg_sim)
assert (r5 == r6).all(), "8-way mismatch"

# ---- device-side warm start after an insert-only delta -------------------- #
import tempfile
from repro.core import run
from repro.stream import EdgeDelta, apply_delta, compact, streaming_ingest, \
    write_edge_log
gw = powerlaw_graph(400, seed=7, weighted=True).as_undirected()
logd = tempfile.mkdtemp(prefix="drone_shard_log_")
write_edge_log(gw, logd, chunk_size=4096)
pgw, ctx, _ = streaming_ingest(logd, 4, "cdbh")
sssp = SSSP()
r0, _ = run_sim(sssp, pgw, {"source": 0}, cfg_sim)
prev = pgw.collect(r0, fill=np.float32(np.inf))
rng = np.random.default_rng(8)
n_add = max(gw.n_edges // 100, 16)
s = rng.integers(0, pgw.n_vertices, n_add)
d = rng.integers(0, pgw.n_vertices, n_add)
keep = s != d
s, d = s[keep], d[keep]
w = rng.uniform(5, 10, s.size).astype(np.float32)
st = apply_delta(pgw, ctx, EdgeDelta(add_src=np.concatenate([s, d]),
                                     add_dst=np.concatenate([d, s]),
                                     add_w=np.concatenate([w, w])))
assert st.warm_start_safe
cold, st_c = run_shard_map(sssp, pgw, mesh, {"source": 0}, cfg_shard)
warm, st_w = run_shard_map(sssp, pgw, mesh, {"source": 0}, cfg_shard,
                           init_state=prev)
assert (np.asarray(cold) == np.asarray(warm)).all(), "warm != cold bit-for-bit"
assert st_w.supersteps < st_c.supersteps, (st_w.supersteps, st_c.supersteps)
sim_warm, sim_sw = run_sim(sssp, pgw, {"source": 0}, cfg_sim, init_state=prev)
assert (np.asarray(warm) == np.asarray(sim_warm)).all(), "shard warm != sim warm"
assert st_w.supersteps == sim_sw.supersteps, "warm superstep parity"
# run() routes init_state to the shard_map backend and rejects resume_from
r_run, st_run = run(sssp, pgw, {"source": 0}, cfg_shard, mesh=mesh,
                    init_state=prev)
assert (np.asarray(r_run) == np.asarray(warm)).all()
assert st_run.supersteps == st_w.supersteps
try:
    run(sssp, pgw, {"source": 0}, cfg_shard, mesh=mesh, resume_from="x")
    raise SystemExit("resume_from on shard_map must raise")
except NotImplementedError:
    pass

# shard_map on a compacted graph == sim (n_slots shrank under the runner)
dels = EdgeDelta(del_src=np.concatenate([gw.src[::2], gw.dst[::2]]),
                 del_dst=np.concatenate([gw.dst[::2], gw.src[::2]]))
apply_delta(pgw, ctx, dels)
cs = compact(pgw, ctx)
assert cs.shrunk and pgw.n_slots < cs.n_slots_before
rs, _ = run_shard_map(sssp, pgw, mesh, {"source": 0}, cfg_shard)
rss, _ = run_sim(sssp, pgw, {"source": 0}, cfg_sim)
assert (np.asarray(rs) == np.asarray(rss)).all(), "compacted shard != sim"

# ---- total_bytes matches the exchange actually used ----------------------- #
ns = pg.n_slots
itm = np.dtype(np.float32).itemsize
r_d, s_d = run_shard_map(cc, pg, mesh, None, cfg_shard)
assert s_d.total_bytes == s_d.supersteps * (ns + 1) * itm * 4, "dense bytes"
cap = max(ns // 4, 1)
cfg_sp = EngineConfig(mode="sc", backend="shard_map",
                      subgraph_axes=("pod", "data"), edge_axes=("model",),
                      sparse_sync_capacity=cap)
r_sp, s_sp = run_shard_map(cc, pg, mesh, None, cfg_sp)
assert (np.asarray(r_sp) == np.asarray(r_d)).all()
assert s_sp.total_bytes == s_sp.supersteps * cap * (4 + itm) * 4, "sparse bytes"
assert s_sp.total_bytes < s_d.total_bytes, "sparse SBS must bill fewer bytes"
n_loc = -(-(ns + 1) // 2)
r_ss, s_ss = run_shard_map(cc, pg, mesh, None, cfg_ss)
assert s_ss.total_bytes == s_ss.supersteps * (n_loc + 1) * itm * 4 * 2, \
    "sharded bytes"
print("SHARD_BACKEND_OK")
"""


def test_shard_map_backend_matches_sim():
    res = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, timeout=900)
    assert res.returncode == 0, res.stderr[-4000:]
    assert "SHARD_BACKEND_OK" in res.stdout
