"""EBV stateful-streaming router invariants (docs/PARTITIONING.md).

What these pin: the acceptance quality bar (replication factor strictly
below the stateless hash with bounded imbalance on skewed power-law
graphs), the determinism/resume contract (bit-identical replay, mid-stream
checkpoint/restore), pair-sticky co-location, exact delete routing, and
the end-to-end streaming-ingest / delta wiring.
"""
import numpy as np
import pytest

from repro.core import (PARTITIONERS, build_partitioned_graph,
                        partition_metrics)
from repro.core.partition import (STREAM_ROUTERS, StatefulRouterSpec,
                                  is_stateful_router)
from repro.graphgen import powerlaw_graph, random_graph
from repro.partition.ebv import (EBVConfig, EBVRouterState, _PairTable,
                                 ebv_vertex_cut, pair_keys)


def _pl(n=6000, alpha=2.1, deg=8, seed=0):
    return powerlaw_graph(n, alpha=alpha, avg_degree=deg, seed=seed)


# --------------------------------------------------------------------------- #
# quality bar
# --------------------------------------------------------------------------- #
def test_ebv_beats_rh_on_powerlaw():
    """Acceptance: on a skewed power-law graph at P=8, EBV's replication
    factor is strictly below rh-vc's AND edge imbalance stays <= 1.1."""
    g = _pl()
    me = partition_metrics(
        build_partitioned_graph(g, PARTITIONERS["ebv"](g, 8, seed=0), 8))
    mr = partition_metrics(
        build_partitioned_graph(g, PARTITIONERS["rh-vc"](g, 8, seed=0), 8))
    assert me.replication_factor < mr.replication_factor, (me, mr)
    assert me.imbalance <= 1.1, me


def test_ebv_registered_as_stateful_router():
    entry = STREAM_ROUTERS["ebv"]
    assert isinstance(entry, StatefulRouterSpec)
    assert is_stateful_router(entry)
    assert not is_stateful_router(STREAM_ROUTERS["rh-vc"])
    st = entry.make_state(4, 100, seed=3)
    assert isinstance(st, EBVRouterState)
    assert st.n_parts == 4 and st.seed == 3
    assert "ebv" in STREAM_ROUTERS   # streamability membership test


# --------------------------------------------------------------------------- #
# determinism / resume contract
# --------------------------------------------------------------------------- #
def test_ebv_deterministic_replay():
    g = _pl(2000)
    a = PARTITIONERS["ebv"](g, 5, seed=1)
    b = PARTITIONERS["ebv"](g, 5, seed=1)
    np.testing.assert_array_equal(a, b)
    # one-shot partitioner == ebv_vertex_cut == a fresh state's route_adds
    c = ebv_vertex_cut(g, 5, seed=1)
    np.testing.assert_array_equal(a, c)
    st = EBVRouterState(5, g.n_vertices, seed=1)
    np.testing.assert_array_equal(a, st.route_adds(g.src, g.dst))


def test_ebv_checkpoint_restore_bit_identical():
    """A restored router continues the stream bit-identically (the
    streaming-resume contract) — including its pair table."""
    g = _pl(3000)
    cut = g.src.size // 2
    a = EBVRouterState(4, g.n_vertices, seed=0)
    a.route_adds(g.src[:cut], g.dst[:cut])
    b = EBVRouterState.from_checkpoint(a.checkpoint())
    pa = a.route_adds(g.src[cut:], g.dst[cut:])
    pb = b.route_adds(g.src[cut:], g.dst[cut:])
    np.testing.assert_array_equal(pa, pb)
    np.testing.assert_array_equal(a.edge_load, b.edge_load)
    np.testing.assert_array_equal(a.replica_load, b.replica_load)
    np.testing.assert_array_equal(a.replicas, b.replicas)
    ka, va = a.table.snapshot()
    kb, vb = b.table.snapshot()
    np.testing.assert_array_equal(ka, kb)
    np.testing.assert_array_equal(va, vb)
    # deletes agree too (table + hash fallback share the seed)
    np.testing.assert_array_equal(a.route_deletes(g.src[:99], g.dst[:99]),
                                  b.route_deletes(g.src[:99], g.dst[:99]))


# --------------------------------------------------------------------------- #
# pair stickiness + deletes
# --------------------------------------------------------------------------- #
def test_ebv_pair_sticky_colocation():
    """Both directions and duplicate copies of a pair co-locate — whether
    the duplicates arrive in one call (same or different mini-blocks) or
    in later calls."""
    g = random_graph(300, 4000, seed=2, undirected=True)
    st = EBVRouterState(7, 300, cfg=EBVConfig(block=64))
    part = st.route_adds(g.src, g.dst)
    lut = {}
    for s, d, p in zip(g.src.tolist(), g.dst.tolist(), part.tolist()):
        key = (min(s, d), max(s, d))
        assert lut.setdefault(key, p) == p, (s, d)
    # a later re-add sticks to the recorded partition
    again = st.route_adds(g.dst[:50], g.src[:50])   # reversed direction
    np.testing.assert_array_equal(again, part[:50])


def test_ebv_route_deletes_finds_resident():
    g = _pl(1500)
    st = EBVRouterState(6, g.n_vertices)
    part = st.route_adds(g.src, g.dst)
    # resident pairs: the table answers exactly, in either direction
    np.testing.assert_array_equal(st.route_deletes(g.src, g.dst), part)
    np.testing.assert_array_equal(st.route_deletes(g.dst, g.src), part)
    # never-routed pairs fall back deterministically in [0, P)
    miss = st.route_deletes(np.array([1400, 1401]), np.array([1402, 1403]))
    assert miss.min() >= 0 and miss.max() < 6
    np.testing.assert_array_equal(
        miss, st.route_deletes(np.array([1400, 1401]),
                               np.array([1402, 1403])))


def test_ebv_preview_is_nonmutating():
    g = _pl(1000)
    st = EBVRouterState(4, g.n_vertices)
    st.route_adds(g.src[:2000], g.dst[:2000])
    before = st.checkpoint()
    st.route_preview(g.src[2000:3000], g.dst[2000:3000])
    st.route_deletes(g.src[:500], g.dst[:500])
    after = st.checkpoint()
    for k in before:
        np.testing.assert_array_equal(np.asarray(before[k]),
                                      np.asarray(after[k]), err_msg=k)


def test_ebv_growth():
    st = EBVRouterState(4, 10)
    p1 = st.route_adds(np.array([1, 2]), np.array([3, 4]))
    # ids beyond the declared space grow the replica table transparently
    p2 = st.route_adds(np.array([50]), np.array([51]))
    assert st.n_vertices == 52
    assert p2.min() >= 0 and p2.max() < 4
    # previously routed pairs survive the growth
    np.testing.assert_array_equal(st.route_deletes(np.array([1, 2]),
                                                   np.array([3, 4])), p1)


def test_pair_table_two_tier():
    t = _PairTable()
    k1 = pair_keys(np.arange(10), np.arange(10) + 100)
    t.put(k1, np.arange(10, dtype=np.int32) % 3)
    np.testing.assert_array_equal(t.get(k1), np.arange(10) % 3)
    t.merge()
    assert len(t.overlay) == 0
    # overlay wins over base on conflict, before and after merge
    t.put(k1[:4], np.full(4, 2, np.int32))
    np.testing.assert_array_equal(t.get(k1[:4]), [2, 2, 2, 2])
    t.merge()
    np.testing.assert_array_equal(t.get(k1[:4]), [2, 2, 2, 2])
    assert t.get(pair_keys(np.array([7]), np.array([999])))[0] == -1


# --------------------------------------------------------------------------- #
# end-to-end wiring: streaming ingest + delta
# --------------------------------------------------------------------------- #
def test_ebv_streaming_ingest_and_delta(tmp_path):
    from repro.stream import write_edge_log
    from repro.stream.delta import EdgeDelta, apply_delta
    from repro.stream.ingest import streaming_ingest

    g = _pl(2000, seed=5)
    log = str(tmp_path / "log")
    write_edge_log(g, log, chunk_size=512)
    pg, ctx, _ = streaming_ingest(log, 4, "ebv", seed=0)
    assert isinstance(ctx.router_state, EBVRouterState)
    assert pg.emask.sum() == g.n_edges
    m = partition_metrics(pg)
    assert m.imbalance <= 1.2

    # a stateless context for a stateful partitioner must refuse pure routing
    from repro.stream.ingest import StreamContext
    bare = StreamContext("ebv", 4, 0, g.n_vertices,
                         np.zeros(g.n_vertices, np.int64))
    with pytest.raises(ValueError, match="stateful"):
        bare.route(np.array([1]), np.array([2]))

    # deletes route through the pair table: removing resident edges works
    n0 = pg.n_edges
    ds = apply_delta(pg, ctx, EdgeDelta(del_src=g.src[:64],
                                        del_dst=g.dst[:64]))
    assert ds.n_deleted == 64
    assert pg.n_edges == n0 - 64
    # re-adding them lands back on the recorded partitions (stickiness)
    ds2 = apply_delta(pg, ctx, EdgeDelta(add_src=g.src[:64],
                                         add_dst=g.dst[:64],
                                         add_w=np.ones(64, np.float32)))
    assert ds2.n_added == 64
    assert pg.n_edges == n0
