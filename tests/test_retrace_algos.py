"""Trace-counter pins for the algorithm suite: every new algorithm's
compiled runner must be reused — zero retraces — across repeated queries,
parameter sweeps (params are traced inputs, not constants baked into the
jaxpr) and in-bucket flushes, on the sim backend inline and on shard_map
via subprocess (fake host devices must precede jax init)."""
import os
import subprocess
import sys

import numpy as np
import pytest

import harness
from repro.algos import (BFS, KCore, LabelPropagation, TriangleCount,
                         make_kcore, make_msbfs, make_triangles)
from repro.analysis.sanitizer import retrace_guard
from repro.session import GraphSession


@pytest.fixture(scope="module")
def graph():
    return harness.harness_powerlaw(200, 9)


def _cases(g):
    pv = harness._pivots(g)
    return [("bfs",) + (BFS(), {"source": 0}),
            ("msbfs",) + make_msbfs(pv),
            ("lp",) + (LabelPropagation(hops=3), {}),
            ("kcore",) + make_kcore(2),
            ("triangles",) + make_triangles(pv)]


@pytest.mark.parametrize("name", ["bfs", "msbfs", "lp", "kcore", "triangles"])
def test_repeated_query_zero_retraces(graph, name):
    prog, params = dict((n, (p, pp)) for n, p, pp in _cases(graph))[name]
    sess = GraphSession.from_graph(graph, 4, "cdbh")
    try:
        _, s1 = sess.query(prog, params)
        assert s1.compile_time > 0.0
        with retrace_guard(label=f"{name}: second identical query"):
            _, s2 = sess.query(prog, params)
        assert s2.compile_time == 0.0
        assert sess.stats.cache_misses == 1
    finally:
        sess.close()


def test_bfs_source_sweep_shares_one_runner(graph):
    """BFS from any source is the same compiled runner: params are traced."""
    sess = GraphSession.from_graph(graph, 4, "cdbh")
    try:
        sess.query(BFS(), {"source": 0})
        with retrace_guard(label="BFS source sweep"):
            for s in (1, 5, 17):
                _, st = sess.query(BFS(), {"source": s})
                assert st.compile_time == 0.0
        assert sess.stats.cache_misses == 1
    finally:
        sess.close()


def test_kcore_k_values_are_distinct_runners(graph):
    """k is a program field, so it is part of the runner cache key — two k
    values are two compilations, then both stay cached."""
    sess = GraphSession.from_graph(graph, 4, "cdbh")
    try:
        sess.query(*make_kcore(2))
        sess.query(*make_kcore(3))
        assert sess.stats.cache_misses == 2
        with retrace_guard(label="kcore k=2/k=3 requeries"):
            sess.query(*make_kcore(2))
            sess.query(*make_kcore(3))
        assert sess.stats.cache_misses == 2
    finally:
        sess.close()


@pytest.mark.parametrize("name", ["bfs", "lp", "kcore"])
def test_inbucket_flush_zero_retraces(graph, name):
    """A flush that moves no padded bucket must re-hit every compiled
    runner of the suite with zero retraces."""
    prog, params = dict((n, (p, pp)) for n, p, pp in _cases(graph))[name]
    sess = GraphSession.from_graph(graph, 4, "cdbh")
    try:
        sess.query(prog, params)
        pg = sess.pg
        p = int(np.argmin(pg.edges_per_part))
        m = pg.emask[p]
        gs = int(pg.gvid[p][pg.esrc[p][m]][0])
        gd = int(pg.gvid[p][pg.edst[p][m]][0])
        shape0 = sess.shape_key
        sess.update(adds=([gs], [gd], [7.0]))
        sess.flush()
        assert sess.shape_key == shape0, "in-bucket by design"
        with retrace_guard(label=f"{name}: in-bucket flush requery"):
            _, st = sess.query(prog, params)
        assert st.compile_time == 0.0
        assert sess.stats.cache_misses == 1
    finally:
        sess.close()


# --------------------------------------------------------------------------- #
# shard_map backend: same pins, fresh process for fake devices
# --------------------------------------------------------------------------- #
SHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import numpy as np
from jax.sharding import Mesh

import harness
from repro.algos import BFS, make_kcore
from repro.analysis.sanitizer import retrace_guard
from repro.core import EngineConfig
from repro.session import GraphSession

g = harness.harness_powerlaw(200, 9)
mesh = Mesh(np.array(jax.devices()[:4]).reshape(4), ("sub",))
cfg = EngineConfig(subgraph_axes=("sub",))
sess = GraphSession.from_graph(g, 4, "cdbh", mesh=mesh, cfg=cfg)
for prog, params in ((BFS(), {"source": 0}), make_kcore(2)):
    r1, s1 = sess.query(prog, params)
    assert s1.compile_time > 0.0
    with retrace_guard(label=f"{type(prog).__name__}: shard requery"):
        r2, s2 = sess.query(prog, params)
    assert s2.compile_time == 0.0
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
# param sweep shares the trace too
with retrace_guard(label="BFS shard source sweep"):
    _, st = sess.query(BFS(), {"source": 3})
assert st.compile_time == 0.0
# in-bucket flush re-hits both compiled runners
pg = sess.pg
p = int(np.argmin(pg.edges_per_part))
m = pg.emask[p]
gs = int(pg.gvid[p][pg.esrc[p][m]][0])
gd = int(pg.gvid[p][pg.edst[p][m]][0])
shape0 = sess.shape_key
sess.update(adds=([gs], [gd], [7.0]))
sess.flush()
assert sess.shape_key == shape0, "in-bucket by design"
with retrace_guard(label="shard in-bucket flush requery"):
    _, st = sess.query(BFS(), {"source": 0})
assert st.compile_time == 0.0
sess.close()
print("RETRACE_SHARD_OK")
"""


def test_shard_map_zero_retraces():
    here = os.path.dirname(os.path.abspath(__file__))
    src = os.path.join(os.path.dirname(here), "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [src, here] + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    res = subprocess.run([sys.executable, "-c", SHARD_SCRIPT],
                         capture_output=True, text=True, timeout=1200,
                         env=env)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "RETRACE_SHARD_OK" in res.stdout
