"""llama3-405b [dense] — 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256. [arXiv:2407.21783]"""
from repro.models.config import ModelConfig


def config():
    return ModelConfig(
        name="llama3-405b", family="dense",
        n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8,
        d_ff=53248, vocab=128256,
        norm="rmsnorm", act="swiglu", rope_theta=500000.0,
        param_dtype="bfloat16", activation_dtype="bfloat16",
    )


def smoke_config():
    return ModelConfig(
        name="llama3-smoke", family="dense",
        n_layers=3, d_model=64, n_heads=8, n_kv_heads=2, d_ff=192, vocab=256,
        rope_theta=500000.0,
        param_dtype="float32", activation_dtype="float32",
    )
