"""olmo-1b [dense] — 16L d_model=2048 16H (MHA kv=16) d_ff=8192 vocab=50304,
non-parametric LN. [arXiv:2402.00838; hf]"""
from repro.models.config import ModelConfig


def config():
    return ModelConfig(
        name="olmo-1b", family="dense",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=8192, vocab=50304,
        norm="nonparam_ln", act="swiglu", rope_theta=10000.0,
        tie_embeddings=True,
        param_dtype="float32", activation_dtype="bfloat16",
    )


def smoke_config():
    return ModelConfig(
        name="olmo-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=256, vocab=128,
        norm="nonparam_ln", tie_embeddings=True,
        param_dtype="float32", activation_dtype="float32",
    )
