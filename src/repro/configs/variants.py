"""Beyond-paper optimization variants for the §Perf hillclimb.

``optimized(cfg)`` returns the config with the per-arch perf levers flipped;
the dry-run records baseline and variant cells separately so the
paper-faithful baseline and the optimized version are both visible
(EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import dataclasses


def optimized(cfg):
    over = {}
    if cfg.moe is not None:
        over["moe"] = dataclasses.replace(cfg.moe, dispatch="hierarchical")
    # dense FSDP archs: gather weights per layer instead of GSPMD's
    # activation-partial all-reduces
    over["fsdp_gather_weights"] = True
    # keep TP activation all-reduce payloads bf16 (block f32-upcast hoisting)
    over["tp_bf16_payload"] = True
    return dataclasses.replace(cfg, **over)
