"""Paper-native configs: DRONE graph-engine workloads (not an LM arch).

Used by examples/benchmarks and by the graph-engine dry-run: the production
mesh maps (pod, data) -> subgraphs and model -> intra-partition edge shards
(hierarchical SVHM, DESIGN.md §2).
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class GraphWorkload:
    name: str
    algo: str              # cc | sssp | pagerank | gsim
    scale: int             # kronecker scale (2^scale vertices)
    edge_factor: int = 16
    n_parts: int = 256     # subgraphs (== pod*data of the production mesh)
    partitioner: str = "cdbh"
    mode: str = "sc"


def config():
    return GraphWorkload(name="drone-kron26-cc", algo="cc", scale=26)


def smoke_config():
    return GraphWorkload(name="drone-smoke", algo="cc", scale=10,
                         edge_factor=8, n_parts=4)


WORKLOADS = {
    "cc": GraphWorkload(name="drone-kron26-cc", algo="cc", scale=26),
    "pagerank": GraphWorkload(name="drone-kron26-pr", algo="pagerank", scale=26),
    "sssp": GraphWorkload(name="drone-kron26-sssp", algo="sssp", scale=26),
}
