"""deepseek-v3-671b [moe] — 61L d_model=7168 128H MLA d_ff(dense)=18432,
MoE 256 routed (d_ff_expert=2048) top-8 + 1 shared, first 3 layers dense,
vocab=129280, MTP. [arXiv:2412.19437; hf]"""
from repro.models.config import MLACfg, ModelConfig, MoECfg


def config():
    return ModelConfig(
        name="deepseek-v3-671b", family="moe",
        n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
        d_ff=18432, vocab=129280, d_head=128,
        norm="rmsnorm", act="swiglu", rope_theta=10000.0,
        mla=MLACfg(q_lora_rank=1536, kv_lora_rank=512, rope_head_dim=64,
                   nope_head_dim=128, v_head_dim=128),
        moe=MoECfg(n_experts=256, top_k=8, n_shared=1, d_ff_expert=2048,
                   capacity_factor=1.25, router_aux_free_bias=True),
        first_k_dense=3, mtp_depth=1,
        param_dtype="bfloat16", activation_dtype="bfloat16",
    )


def smoke_config():
    return ModelConfig(
        name="deepseek-v3-smoke", family="moe",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=256, d_head=16,
        mla=MLACfg(q_lora_rank=32, kv_lora_rank=16, rope_head_dim=8,
                   nope_head_dim=16, v_head_dim=16),
        moe=MoECfg(n_experts=8, top_k=2, n_shared=1, d_ff_expert=32,
                   capacity_factor=64.0),
        first_k_dense=1, mtp_depth=1,
        param_dtype="float32", activation_dtype="float32",
    )
