"""stablelm-3b [dense] — 32L d_model=2560 32H (MHA kv=32) d_ff=6912
vocab=50304, LayerNorm + partial rotary (25%).
[hf:stabilityai/stablelm-2-1_6b family]"""
from repro.models.config import ModelConfig


def config():
    return ModelConfig(
        name="stablelm-3b", family="dense",
        n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32,
        d_ff=6912, vocab=50304,
        norm="layernorm", act="swiglu", rope_theta=10000.0, rotary_pct=0.25,
        param_dtype="float32", activation_dtype="bfloat16",
    )


def smoke_config():
    return ModelConfig(
        name="stablelm-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160, vocab=128,
        norm="layernorm", rotary_pct=0.25,
        param_dtype="float32", activation_dtype="float32",
    )
