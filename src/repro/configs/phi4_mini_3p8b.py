"""phi4-mini-3.8b [dense] — 32L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=200064, RoPE SwiGLU GQA. [arXiv:2412.08905; hf]"""
from repro.models.config import ModelConfig


def config():
    return ModelConfig(
        name="phi4-mini-3.8b", family="dense",
        n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
        d_ff=8192, vocab=200064,
        norm="rmsnorm", act="swiglu", rope_theta=10000.0,
        tie_embeddings=True,
        param_dtype="bfloat16", activation_dtype="bfloat16",
    )


def smoke_config():
    return ModelConfig(
        name="phi4-mini-smoke", family="dense",
        n_layers=2, d_model=48, n_heads=6, n_kv_heads=2, d_ff=128, vocab=160,
        tie_embeddings=True,
        param_dtype="float32", activation_dtype="float32",
    )
