"""Architecture registry: --arch <id> -> ModelConfig (full) / smoke config."""
from __future__ import annotations

import importlib

ARCHS = [
    "deepseek_v3_671b", "phi35_moe_42b", "olmo_1b", "phi4_mini_3p8b",
    "llama3_405b", "stablelm_3b", "internvl2_26b", "seamless_m4t_large_v2",
    "jamba_v01_52b", "xlstm_350m",
    # paper-native configs (graph engine):
    "drone_graph",
]

_ALIASES = {
    "deepseek-v3-671b": "deepseek_v3_671b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b",
    "olmo-1b": "olmo_1b",
    "phi4-mini-3.8b": "phi4_mini_3p8b",
    "llama3-405b": "llama3_405b",
    "stablelm-3b": "stablelm_3b",
    "internvl2-26b": "internvl2_26b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "xlstm-350m": "xlstm_350m",
}


def _module(arch: str):
    arch = _ALIASES.get(arch, arch).replace("-", "_")
    assert arch in ARCHS, f"unknown arch {arch}; know {ARCHS}"
    return importlib.import_module(f"repro.configs.{arch}")


def get_config(arch: str):
    return _module(arch).config()


def get_smoke_config(arch: str):
    return _module(arch).smoke_config()
