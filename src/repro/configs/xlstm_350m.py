"""xlstm-350m [ssm] — 24 blocks d_model=1024 4H, xLSTM[7:1]
(7 mLSTM : 1 sLSTM per period), no separate FFN (d_ff=0 -> mlp='none'),
vocab=50304. [arXiv:2405.04517]"""
from repro.models.config import BlockSpec, ModelConfig, XLSTMCfg


def _pattern(n_layers, ratio=7):
    specs = []
    for i in range(n_layers):
        mixer = "slstm" if i % (ratio + 1) == ratio else "mlstm"
        specs.append(BlockSpec(mixer=mixer, mlp="none"))
    return tuple(specs)


def config():
    return ModelConfig(
        name="xlstm-350m", family="ssm",
        n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab=50304,
        norm="layernorm", act="gelu",
        xlstm=XLSTMCfg(mlstm_per_slstm=7),
        pattern=_pattern(24), subquadratic=True,
        tie_embeddings=True,
        param_dtype="float32", activation_dtype="bfloat16",
    )


def smoke_config():
    return ModelConfig(
        name="xlstm-smoke", family="ssm",
        n_layers=8, d_model=64, n_heads=4, n_kv_heads=4, d_ff=0, vocab=128,
        norm="layernorm", xlstm=XLSTMCfg(),
        pattern=_pattern(8), subquadratic=True, tie_embeddings=True,
        param_dtype="float32", activation_dtype="float32",
    )
