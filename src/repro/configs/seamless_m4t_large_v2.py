"""seamless-m4t-large-v2 [audio] — encoder-decoder transformer backbone:
24 enc + 24 dec layers, d_model=1024 16H (kv=16) d_ff=8192 vocab=256206.
The speech frontend (w2v-BERT conformer feature extractor) is a STUB per the
assignment spec: input_specs provide precomputed frame embeddings to the
encoder. [arXiv:2308.11596; hf]"""
from repro.models.config import BlockSpec, ModelConfig

FRAME_DIM = 1024
FRAME_LEN = 1024     # pooled speech frames fed to the encoder


def config():
    return ModelConfig(
        name="seamless-m4t-large-v2", family="audio",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=8192, vocab=256206,
        norm="layernorm", act="gelu", rope_theta=10000.0,
        n_enc_layers=24,
        pattern=tuple(BlockSpec(mixer="attn", mlp="dense", cross=True)
                      for _ in range(24)),
        frontend="frame_stub", frontend_dim=FRAME_DIM, frontend_len=FRAME_LEN,
        param_dtype="float32", activation_dtype="bfloat16",
    )


def smoke_config():
    return ModelConfig(
        name="seamless-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=200,
        norm="layernorm", act="gelu", n_enc_layers=2,
        pattern=tuple(BlockSpec(mixer="attn", mlp="dense", cross=True)
                      for _ in range(2)),
        frontend="frame_stub", frontend_dim=32, frontend_len=12,
        param_dtype="float32", activation_dtype="float32",
    )
