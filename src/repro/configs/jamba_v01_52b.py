"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336,
Mamba:attention 7:1 (attention at offset 4 of each 8-layer Jamba block),
MoE 16 experts top-2 at every other layer, vocab=65536.
[arXiv:2403.19887; hf]"""
from repro.models.config import BlockSpec, MambaCfg, ModelConfig, MoECfg


def _pattern(n_layers):
    specs = []
    for i in range(n_layers):
        mixer = "attn" if i % 8 == 4 else "mamba"
        mlp = "moe" if i % 2 == 1 else "dense"
        specs.append(BlockSpec(mixer=mixer, mlp=mlp))
    return tuple(specs)


def config():
    return ModelConfig(
        name="jamba-v0.1-52b", family="hybrid",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=65536,
        norm="rmsnorm", act="swiglu", rope_theta=10000.0,
        moe=MoECfg(n_experts=16, top_k=2, n_shared=0, d_ff_expert=14336,
                   router_aux_free_bias=False),
        mamba=MambaCfg(d_state=16, d_conv=4, expand=2),
        pattern=_pattern(32),
        subquadratic=True,   # 4 attention layers; SSM state carries the rest
        param_dtype="bfloat16", activation_dtype="bfloat16",
    )


def smoke_config():
    return ModelConfig(
        name="jamba-smoke", family="hybrid",
        n_layers=8, d_model=48, n_heads=4, n_kv_heads=2, d_ff=96, vocab=128,
        moe=MoECfg(n_experts=4, top_k=2, d_ff_expert=96, capacity_factor=64.0,
                   router_aux_free_bias=False),
        mamba=MambaCfg(d_state=8, d_conv=4, expand=2),
        pattern=_pattern(8), subquadratic=True,
        param_dtype="float32", activation_dtype="float32",
    )
