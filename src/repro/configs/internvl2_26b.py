"""internvl2-26b [vlm] — InternLM2-20B backbone: 48L d_model=6144 48H
(GQA kv=8) d_ff=16384 vocab=92553; InternViT frontend is a STUB per the
assignment spec (input_specs supply precomputed patch embeddings).
[arXiv:2404.16821; hf]"""
from repro.models.config import ModelConfig

# InternViT-6B emits 1024-d patch embeddings (pre pixel-shuffle projector);
# 256 visual tokens per image tile after pixel-shuffle.
PATCH_TOKENS = 256
PATCH_DIM = 3200


def config():
    return ModelConfig(
        name="internvl2-26b", family="vlm",
        n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=16384, vocab=92553,
        norm="rmsnorm", act="swiglu", rope_theta=1000000.0,
        frontend="patch_stub", frontend_dim=PATCH_DIM,
        frontend_len=PATCH_TOKENS,
        param_dtype="bfloat16", activation_dtype="bfloat16",
    )


def smoke_config():
    return ModelConfig(
        name="internvl2-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160, vocab=128,
        frontend="patch_stub", frontend_dim=48, frontend_len=8,
        param_dtype="float32", activation_dtype="float32",
    )
