from repro.configs.registry import ARCHS, get_config, get_smoke_config

__all__ = ["ARCHS", "get_config", "get_smoke_config"]
