"""phi3.5-moe-42b-a6.6b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=6400,
16 experts top-2, vocab=32064. [hf:microsoft/Phi-3.5-MoE-instruct]"""
from repro.models.config import ModelConfig, MoECfg


def config():
    return ModelConfig(
        name="phi3.5-moe-42b-a6.6b", family="moe",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=6400, vocab=32064,
        norm="layernorm", act="swiglu", rope_theta=10000.0,
        moe=MoECfg(n_experts=16, top_k=2, n_shared=0, d_ff_expert=6400,
                   capacity_factor=1.25, router_aux_free_bias=False),
        param_dtype="bfloat16", activation_dtype="bfloat16",
    )


def smoke_config():
    return ModelConfig(
        name="phi3.5-moe-smoke", family="moe",
        n_layers=3, d_model=48, n_heads=4, n_kv_heads=2, d_ff=96, vocab=128,
        norm="layernorm",
        moe=MoECfg(n_experts=4, top_k=2, d_ff_expert=96, capacity_factor=64.0,
                   router_aux_free_bias=False),
        param_dtype="float32", activation_dtype="float32",
    )
