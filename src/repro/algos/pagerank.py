"""PageRank — asynchronous accumulative formulation (paper §7.2, after
Zhang et al. [17]).

State per vertex: rank ``pr`` and accumulator ``delta``. Processing a vertex:
``pr += delta``; push ``alpha * delta / out_deg`` to each out-neighbour's
accumulator; reset ``delta``. Fixed point: ``pr = sum_n alpha^n M^n r`` with
``r = (1-alpha)/N`` — the standard PageRank (dangling mass not redistributed,
as in [17]).

SVHM replication protocol (DESIGN.md):
  - internal vertices are processed by local sweeps (to the partition-local
    fixed point, modulo ``tol``);
  - frontier vertices are processed only at superstep boundaries: local
    sweeps accumulate their inflow into ``delta``; SBS sums the accumulators
    (Aggregate = sum, as in the paper), and ``apply_frontier`` has every
    replica consume the *merged* delta identically (pr update + push along
    the replica's local out-edges, whose union over replicas is exactly the
    vertex's global out-edge set). Initial seeding of a frontier vertex
    happens on its master replica only, so the merged sum is not inflated.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.api import DeviceSubgraph, SemiringSweep, VertexProgram


@dataclasses.dataclass
class PageRank(VertexProgram):
    combiner: str = "sum"
    payload: int = 1
    dtype: object = jnp.float32
    delta_based: bool = True
    tol: float = 1e-7
    alpha: float = 0.85

    # plus-times over unit edges: the alpha/out_deg rate rides the vertex
    # values (sweep_values), so the edge-value map stays declarative
    sweep_spec = SemiringSweep("plus_times", "one")

    # -------------------------------------------------------------- #
    def _push(self, sg: DeviceSubgraph, d, ec):
        """Push alpha*d/out_deg along local out-edges; returns inflow."""
        rate = jnp.where(sg.out_deg > 0, self.alpha / jnp.maximum(sg.out_deg, 1.0), 0.0)
        send = d * rate
        contrib = jnp.where(sg.emask, send[sg.esrc], 0.0)
        recv = jnp.zeros((sg.v_max,), jnp.float32).at[sg.edst].add(contrib)
        return ec.sum(recv)

    def init(self, sg: DeviceSubgraph, params, ec):
        n = params["n_vertices"]
        seed = jnp.float32((1.0 - self.alpha) / n)
        # master-only seeding for frontier vertices (mirrors start at 0)
        d0 = jnp.where(sg.internal | (sg.frontier & sg.is_master), seed, 0.0)
        d0 = jnp.where(sg.vmask, d0, 0.0)
        return {"pr": jnp.zeros((sg.v_max,), jnp.float32), "delta": d0}

    def apply_frontier(self, sg, params, state, merged, ec):
        m = jnp.where(sg.frontier, merged[:, 0], 0.0)
        sig = jnp.abs(m) > self.tol
        pr = state["pr"] + jnp.where(sig, m, 0.0)
        inflow = self._push(sg, jnp.where(sig, m, 0.0), ec)
        # frontier accumulators were globally consumed: reset to new inflow;
        # internal accumulators keep pending value + new inflow.
        delta = jnp.where(sg.frontier, inflow, state["delta"] + inflow)
        changed = jnp.sum(sig & sg.frontier, dtype=jnp.int32)
        return {"pr": pr, "delta": delta}, changed

    def _processable(self, sg, state):
        """Internal vertices whose pending accumulator is significant, and
        the value they consume (shared by sweep_values and sweep_fold)."""
        d = state["delta"]
        proc = sg.internal & (jnp.abs(d) > self.tol)
        return proc, jnp.where(proc, d, 0.0)

    def sweep_values(self, sg, params, state):
        _, dp = self._processable(sg, state)
        rate = jnp.where(sg.out_deg > 0,
                         self.alpha / jnp.maximum(sg.out_deg, 1.0), 0.0)
        return dp * rate

    def sweep_fold(self, sg, params, state, agg):
        proc, dp = self._processable(sg, state)
        pr = state["pr"] + dp
        delta = jnp.where(proc, 0.0, state["delta"]) \
            + jnp.where(sg.vmask, agg, 0.0)
        changed = jnp.sum(proc, dtype=jnp.int32)
        return {"pr": pr, "delta": delta}, changed

    def frontier_out(self, sg, params, state):
        return jnp.where(sg.frontier, state["delta"], 0.0)[:, None]

    def result(self, sg, params, state):
        # remaining sub-tolerance delta is folded in for a tighter answer
        return state["pr"] + state["delta"]
