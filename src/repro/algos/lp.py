"""Label propagation / community detection, hop-stratified and confluent.

Classic LPA adopts the *most frequent* neighbour label with random
tie-breaks — a per-vertex histogram that neither fits the [v_max, K]
exchange model nor yields deterministic cross-backend parity. The
subgraph-centric formulation used here (the deterministic variant of the
GoFFish/Kakwani suite) is hop-bounded minimum-label propagation: every
vertex adopts the smallest vertex id reachable within ``hops`` edges, so
communities are balls around local id-minima and ties cannot occur.

A single packed (label, hop) min-code does NOT compute this: the target
``min id within h hops`` at a vertex can depend on a *transient* code a
neighbour held before its own minimum improved to something whose hop
budget is already spent — the packed fixpoint is evaluation-order
dependent, and the engine's SC mode (asynchronous per-partition local
fixpoints) legitimately visits different orders than VC mode or a
synchronous oracle. The confluent formulation keeps one lane per hop
budget, ``payload = hops + 1``:

    lane_h(v) = min id within h hops of v
              = min(v, min over in-neighbours u of lane_{h-1}(u))

The system is *stratified* — lane h only reads lane h-1 — and each lane
is a plain monotone min fixpoint, so chaotic iteration converges to the
same unique answer under any fair schedule (SC, VC, any partitioning).
The community label is the last lane. The lane-shifted edge map (lane h
of the message is the source's lane h-1) does not fit ``SemiringSweep``'s
declarative per-edge values, so this is a hand-rolled COO sweep
(``supports_edge_backends = ("coo",)``) exercising the custom-sweep
fallback seam.

Monotone under inserts: new edges only shrink distances, so every lane
only decreases — warm-startable after insert-only flushes
(``value_key = "lanes"``). Use ``make_lp()`` to construct and
``decode_labels()`` to project community ids from collected lanes.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.api import DeviceSubgraph, VertexProgram

_IMAX = 2**31 - 1


@dataclasses.dataclass
class LabelPropagation(VertexProgram):
    # lane-shifted per-edge map: COO gather/scatter only
    supports_edge_backends: ClassVar[Tuple[str, ...]] = ("coo",)

    combiner: str = "min"
    payload: int = 4            # hops + 1 lanes; keep in sync with hops
    dtype: object = jnp.int32
    delta_based: bool = False
    monotone: bool = True       # lanes only decrease -> warm-startable
    value_key: str = "lanes"
    hops: int = 3               # propagation radius L

    def __post_init__(self):
        self.payload = self.hops + 1

    def init(self, sg: DeviceSubgraph, params, ec):
        lanes = jnp.where(sg.vmask[:, None],
                          sg.vid32[:, None].astype(jnp.int32), _IMAX)
        return {"lanes": jnp.broadcast_to(
            lanes, (sg.vmask.shape[0], self.payload)).astype(jnp.int32)}

    def apply_frontier(self, sg, params, state, merged, ec):
        new = jnp.where(sg.frontier[:, None],
                        jnp.minimum(state["lanes"], merged), state["lanes"])
        changed = jnp.sum(jnp.any(new < state["lanes"], -1), dtype=jnp.int32)
        return {"lanes": new}, changed

    def sweep(self, sg, params, state, ec):
        lanes = state["lanes"]
        # message lane h carries the source's lane h-1; lane 0 never moves
        prev = jnp.where(sg.emask[:, None], lanes[sg.esrc, :-1], _IMAX)
        cand = jnp.concatenate(
            [jnp.full(prev[:, :1].shape, _IMAX, jnp.int32), prev], axis=1)
        agg = jnp.full(lanes.shape, _IMAX, jnp.int32).at[sg.edst].min(cand)
        agg = ec.min(agg)
        new = jnp.where(sg.vmask[:, None], jnp.minimum(lanes, agg), lanes)
        changed = jnp.sum(jnp.any(new < lanes, -1), dtype=jnp.int32)
        return {"lanes": new}, changed

    def frontier_out(self, sg, params, state):
        return state["lanes"]

    def result(self, sg, params, state):
        return state["lanes"]


def make_lp(hops: int = 3):
    """(program, params) for hop-bounded min-label propagation."""
    if hops < 1:
        raise ValueError(f"hops={hops}: the propagation radius must be >= 1")
    return LabelPropagation(hops=hops), {}


def decode_labels(lanes):
    """Community ids from collected lanes: the full-radius lane (IMAX
    padding rows stay IMAX)."""
    return np.asarray(lanes)[..., -1].astype(np.int32)
