"""Approximate betweenness centrality: K-pivot Brandes, staged queries.

Brandes' algorithm per source s needs (1) BFS distances d_s, (2) shortest
-path counts sigma_s via the BFS DAG, (3) a backward dependency
accumulation delta_s. Stages (2) and (3) are fixpoints over the DAG, so
each maps onto the engine as its own ``VertexProgram`` — K pivots batched
into [v_max, K] columns exactly like ``MultiSourceSSSP``'s landmark
batching (stage 1 *is* ``MultiSourceBFS``). Sampling K << n pivots gives
the standard Brandes–Pich approximation; pivots = all vertices is exact.

Replicated frontier vertices receive partial DAG sums from every replica,
merged with the delta-accumulation discipline (emit only the change in
the local partial since the last sync, so the sum-combined exchange is
exact and the emitted deltas shrink to zero — the engine's vote-to-halt
terminates once the DAG has drained):

    value = acc + pin - emitted       acc: merged global in-flow so far
                                      pin: current local partial
                                      emitted: local partial at last sync

``SigmaCount`` runs it forward (sigma flows source->sink: scatter at edge
destinations), ``BrandesAccum`` backward (delta flows sink->source:
scatter at edge sources, with the per-edge ratio sigma_s/sigma_d baked
into a coefficient at init). Both gate edges on the DAG predicate
``level[src] + 1 == level[dst]`` — a per-edge, per-pivot mask that no
declarative edge-value map expresses, hence hand-rolled COO sweeps.

``brandes_betweenness`` glues the three stages over any query callable
(raw ``run``, a ``GraphSession`` — anything returning collected global
values). Unweighted, simple graphs; not monotone (no warm start).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, ClassVar, Dict, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.api import DeviceSubgraph, VertexProgram
from repro.algos.bfs import make_msbfs


def _local_levels(sg: DeviceSubgraph, levels: jnp.ndarray) -> jnp.ndarray:
    """Gather the global [n, K] level table into this partition's rows."""
    idx = jnp.clip(sg.vid32, 0, levels.shape[0] - 1)
    return jnp.where(sg.vmask[:, None], levels[idx], jnp.inf)


def _dag_mask(sg: DeviceSubgraph, lev: jnp.ndarray) -> jnp.ndarray:
    """[e_max, K] — edges on some shortest path (one level down)."""
    ls = lev[sg.esrc]
    return sg.emask[:, None] & jnp.isfinite(ls) & (ls + 1.0 == lev[sg.edst])


@dataclasses.dataclass
class SigmaCount(VertexProgram):
    """Shortest-path counts sigma over the BFS DAG (forward fixpoint)."""

    supports_edge_backends: ClassVar[Tuple[str, ...]] = ("coo",)

    combiner: str = "sum"
    payload: int = 4               # K pivots; set at construction
    dtype: object = jnp.float32
    delta_based: bool = True
    monotone: bool = False

    def init(self, sg: DeviceSubgraph, params, ec):
        lev = _local_levels(sg, params["levels"])
        dag = _dag_mask(sg, lev)
        pivots = params["pivots"]
        seed = ((sg.vid32[:, None] == pivots[None, :]) &
                sg.vmask[:, None]).astype(jnp.float32)
        zeros = jnp.zeros_like(seed)
        return {"sigma": seed, "seed": seed, "dag": dag, "pin": zeros,
                "acc": zeros, "emitted": zeros}

    def apply_frontier(self, sg, params, state, merged, ec):
        f = sg.frontier[:, None]
        acc = jnp.where(f, state["acc"] + merged, state["acc"])
        emitted = jnp.where(f, state["pin"], state["emitted"])
        sigma = jnp.where(f, state["seed"] + acc, state["sigma"])
        changed = jnp.sum(jnp.any(merged != 0, -1) & sg.frontier,
                          dtype=jnp.int32)
        return {"sigma": sigma, "seed": state["seed"], "dag": state["dag"],
                "pin": state["pin"], "acc": acc, "emitted": emitted}, changed

    def sweep(self, sg, params, state, ec):
        sigma = state["sigma"]
        contrib = jnp.where(state["dag"], sigma[sg.esrc], 0.0)
        pin = jnp.zeros_like(sigma).at[sg.edst].add(contrib)
        pin = ec.sum(pin)
        new = jnp.where(sg.vmask[:, None],
                        state["seed"] + state["acc"] + pin - state["emitted"],
                        sigma)
        changed = jnp.sum(jnp.any(new != sigma, -1), dtype=jnp.int32)
        return {"sigma": new, "seed": state["seed"], "dag": state["dag"],
                "pin": pin, "acc": state["acc"],
                "emitted": state["emitted"]}, changed

    def frontier_out(self, sg, params, state):
        return jnp.where(sg.frontier[:, None],
                         state["pin"] - state["emitted"], 0.0)

    def result(self, sg, params, state):
        return state["sigma"]


@dataclasses.dataclass
class BrandesAccum(VertexProgram):
    """Backward dependency accumulation delta over the BFS DAG."""

    supports_edge_backends: ClassVar[Tuple[str, ...]] = ("coo",)

    combiner: str = "sum"
    payload: int = 4               # K pivots; set at construction
    dtype: object = jnp.float32
    delta_based: bool = True
    monotone: bool = False

    def init(self, sg: DeviceSubgraph, params, ec):
        lev = _local_levels(sg, params["levels"])
        dag = _dag_mask(sg, lev)
        sig = params["sigma"]
        idx = jnp.clip(sg.vid32, 0, sig.shape[0] - 1)
        sigl = jnp.where(sg.vmask[:, None], sig[idx], 0.0)
        ss, sd = sigl[sg.esrc], sigl[sg.edst]
        coef = jnp.where(dag & (sd > 0), ss / jnp.where(sd > 0, sd, 1.0), 0.0)
        zeros = jnp.zeros_like(sigl)
        return {"delta": zeros, "coef": coef, "pout": zeros,
                "acc": zeros, "emitted": zeros}

    def apply_frontier(self, sg, params, state, merged, ec):
        f = sg.frontier[:, None]
        acc = jnp.where(f, state["acc"] + merged, state["acc"])
        emitted = jnp.where(f, state["pout"], state["emitted"])
        delta = jnp.where(f, acc, state["delta"])
        changed = jnp.sum(jnp.any(merged != 0, -1) & sg.frontier,
                          dtype=jnp.int32)
        return {"delta": delta, "coef": state["coef"], "pout": state["pout"],
                "acc": acc, "emitted": emitted}, changed

    def sweep(self, sg, params, state, ec):
        delta = state["delta"]
        contrib = state["coef"] * (1.0 + delta[sg.edst])
        pout = jnp.zeros_like(delta).at[sg.esrc].add(
            jnp.where(state["coef"] > 0, contrib, 0.0))
        pout = ec.sum(pout)
        new = jnp.where(sg.vmask[:, None],
                        state["acc"] + pout - state["emitted"], delta)
        changed = jnp.sum(jnp.any(new != delta, -1), dtype=jnp.int32)
        return {"delta": new, "coef": state["coef"], "pout": pout,
                "acc": state["acc"], "emitted": state["emitted"]}, changed

    def frontier_out(self, sg, params, state):
        return jnp.where(sg.frontier[:, None],
                         state["pout"] - state["emitted"], 0.0)

    def result(self, sg, params, state):
        return state["delta"]


def brandes_betweenness(query: Callable[[VertexProgram, Any], Any],
                        pivots, undirected: bool = True) -> Dict[str, Any]:
    """Staged K-pivot Brandes over any engine entry point.

    ``query(program, params)`` must return collected global values ([n] or
    [n, K]) — e.g. ``lambda p, pp: pg.collect(run(...))`` or a
    ``GraphSession.query(...).values`` wrapper. Returns the per-stage
    arrays plus ``bc``: the dependency sum over pivots with the standard
    v != s exclusion, halved for undirected graphs (each undirected
    shortest path is seen from both directions)."""
    pivots = np.asarray(pivots, np.int32)
    K = int(pivots.shape[0])

    prog, p = make_msbfs(pivots)
    levels = np.asarray(query(prog, p), np.float32)

    sigma = np.asarray(query(
        SigmaCount(payload=K),
        {"pivots": jnp.asarray(pivots), "levels": jnp.asarray(levels)}),
        np.float32)

    delta = np.asarray(query(
        BrandesAccum(payload=K),
        {"levels": jnp.asarray(levels), "sigma": jnp.asarray(sigma)}),
        np.float32)

    not_pivot = np.arange(levels.shape[0])[:, None] != pivots[None, :]
    bc = (delta * not_pivot).sum(axis=1)
    if undirected:
        bc = bc / 2.0
    return {"levels": levels, "sigma": sigma, "delta": delta, "bc": bc}
