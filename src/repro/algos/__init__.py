from repro.algos.cc import ConnectedComponents
from repro.algos.sssp import SSSP
from repro.algos.pagerank import PageRank
from repro.algos.gsim import GraphSimulation
from repro.algos.mssp import MultiSourceSSSP
from repro.algos.bfs import BFS, MultiSourceBFS, make_msbfs
from repro.algos.lp import LabelPropagation, make_lp, decode_labels
from repro.algos.kcore import KCore, make_kcore
from repro.algos.triangles import (TriangleCount, make_triangles,
                                   triangles_from_result)
from repro.algos.betweenness import (SigmaCount, BrandesAccum,
                                     brandes_betweenness)

__all__ = ["ConnectedComponents", "SSSP", "PageRank", "GraphSimulation",
           "MultiSourceSSSP", "BFS", "MultiSourceBFS", "make_msbfs",
           "LabelPropagation", "make_lp", "decode_labels",
           "KCore", "make_kcore",
           "TriangleCount", "make_triangles", "triangles_from_result",
           "SigmaCount", "BrandesAccum", "brandes_betweenness"]
