from repro.algos.cc import ConnectedComponents
from repro.algos.sssp import SSSP
from repro.algos.pagerank import PageRank
from repro.algos.gsim import GraphSimulation
from repro.algos.mssp import MultiSourceSSSP

__all__ = ["ConnectedComponents", "SSSP", "PageRank", "GraphSimulation",
           "MultiSourceSSSP"]
