"""Multi-source shortest paths (landmarks): K sources solved simultaneously.

Exercises the SVHM engine's vector payload (K > 1): vertex values are
[K]-vectors, one distance per source; SBS reduces [n_slots, K] buffers with
``min``. This is the "graph algorithms for machine learning" direction the
paper names as future work (landmark embeddings / ANF sketches), and the
natural consumer of the model-axis feature parallelism (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, Tuple

import jax.numpy as jnp

from repro.core.api import DeviceSubgraph, VertexProgram

INF = jnp.float32(jnp.inf)


@dataclasses.dataclass
class MultiSourceSSSP(VertexProgram):
    # hand-rolled sweep: implements the COO gather/scatter path only
    supports_edge_backends: ClassVar[Tuple[str, ...]] = ("coo",)

    combiner: str = "min"
    payload: int = 4            # K sources; set at construction
    dtype: object = jnp.float32
    delta_based: bool = False
    monotone: bool = True       # distances only tighten -> warm-startable
    value_key: str = "dist"

    def init(self, sg: DeviceSubgraph, params, ec):
        sources = params["sources"]          # [K] global vertex ids
        dist = jnp.where(sg.vid32[:, None] == sources[None, :], 0.0, INF)
        return {"dist": jnp.where(sg.vmask[:, None], dist, INF)}

    def apply_frontier(self, sg, params, state, merged, ec):
        new = jnp.where(sg.frontier[:, None],
                        jnp.minimum(state["dist"], merged), state["dist"])
        changed = jnp.sum(jnp.any(new < state["dist"], -1), dtype=jnp.int32)
        return {"dist": new}, changed

    def sweep(self, sg, params, state, ec):
        d = state["dist"]
        cand = jnp.where(sg.emask[:, None], d[sg.esrc] + sg.ew[:, None], INF)
        agg = jnp.full(d.shape, INF, jnp.float32).at[sg.edst].min(cand)
        agg = ec.min(agg)
        new = jnp.where(sg.vmask[:, None], jnp.minimum(d, agg), d)
        changed = jnp.sum(jnp.any(new < d, -1), dtype=jnp.int32)
        return {"dist": new}, changed

    def frontier_out(self, sg, params, state):
        return state["dist"]

    def result(self, sg, params, state):
        return state["dist"]


def make_mssp(sources):
    import numpy as np
    sources = np.asarray(sources, np.int32)
    prog = MultiSourceSSSP(payload=int(sources.shape[0]))
    return prog, {"sources": jnp.asarray(sources)}
