"""k-core decomposition (fixed k): iterative peel with frontier re-election.

The k-core of a graph is the maximal subgraph where every vertex keeps
degree >= k; it is computed by repeatedly peeling vertices of degree < k
until none remain. Subgraph-centrically (GoFFish's formulation) each
partition peels to a *local* fixed point per superstep, exchanging degree
*decrements* for replicated frontier vertices — the same
post/pending/nsync bookkeeping as graph simulation (algos/gsim.py):

  post     last-synced global degree + this replica's un-synced decrements
  pending  decrements accumulated since the last SBS sync (sum-combined)
  nsync    frontier degree counts are only globally valid after one sync

Degrees count a vertex's stored out-edges whose destination is still
un-peeled (graphs stored undirected — both directions present — make this
the undirected degree; self-loops count until the vertex itself peels).
Between syncs a frontier replica's ``post`` is an upper bound on the true
degree (it has seen only its own local decrements), so ``post < k`` can
only fire *late*, never wrongly — replicas may peel a vertex in different
supersteps but each local edge is decremented exactly once globally.

The peel is monotone under DELETES (``warm_under = "deletes"``): removing
edges only shrinks the core, so a vertex peeled before stays peeled.
``result`` therefore reports a *peeled* flag (1 = out of the core) whose
sum-combiner identity 0 means "no information": a warm block re-kills the
previously peeled set in the first local sweep (``must``), letting the
ordinary decrement machinery rebuild every degree without a dedicated
edge reduction in ``warm_init`` — and an identity-filled cold block is a
no-op by construction.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, Tuple

import jax.numpy as jnp

from repro.core.api import DeviceSubgraph, VertexProgram


@dataclasses.dataclass
class KCore(VertexProgram):
    # per-edge alive-gated counting: COO gather/scatter only
    supports_edge_backends: ClassVar[Tuple[str, ...]] = ("coo",)
    warm_under: ClassVar[str] = "deletes"

    combiner: str = "sum"
    payload: int = 2            # lane 0: decrement sum; lane 1: sync marker
    dtype: object = jnp.int32
    delta_based: bool = True
    monotone: bool = True       # peeled flags only grow under deletes
    value_key: str = "peeled"
    k: int = 2

    def _dec_to_src(self, sg: DeviceSubgraph, removed, ec):
        """Degree decrements: one per local out-edge into a just-peeled
        destination, summed at the edge's source row."""
        contrib = jnp.where(sg.emask, removed.astype(jnp.int32)[sg.edst], 0)
        dec = jnp.zeros((sg.v_max,), jnp.int32).at[sg.esrc].add(contrib)
        return ec.sum(dec)

    def init(self, sg: DeviceSubgraph, params, ec):
        ldeg = jnp.zeros((sg.v_max,), jnp.int32).at[sg.esrc].add(
            sg.emask.astype(jnp.int32))
        ldeg = ec.sum(ldeg)
        return {"alive": sg.vmask, "post": ldeg, "pending": ldeg,
                "must": jnp.zeros((sg.v_max,), bool), "nsync": jnp.int32(0)}

    def warm_init(self, sg, params, state, warm):
        peeled = warm if warm.ndim == 1 else warm[..., 0]
        state = dict(state)
        state["must"] = (peeled > 0) & sg.vmask
        return state

    def apply_frontier(self, sg, params, state, merged, ec):
        f = sg.frontier
        m = merged[:, 0]
        post = jnp.where(f, state["post"] - state["pending"] + m,
                         state["post"])
        pending = jnp.where(f, 0, state["pending"])
        changed = jnp.sum((m != 0) & f, dtype=jnp.int32)
        return {"alive": state["alive"], "post": post, "pending": pending,
                "must": state["must"], "nsync": state["nsync"] + 1}, changed

    def sweep(self, sg, params, state, ec):
        alive, post, pending = state["alive"], state["post"], state["pending"]
        valid = sg.internal | (state["nsync"] >= 1)
        removed = alive & sg.vmask & \
            (state["must"] | (valid & (post < jnp.int32(self.k))))
        alive = alive & ~removed
        dec = self._dec_to_src(sg, removed, ec)
        changed = jnp.sum(removed, dtype=jnp.int32)
        return {"alive": alive, "post": post - dec, "pending": pending - dec,
                "must": state["must"] & ~removed,
                "nsync": state["nsync"]}, changed

    def frontier_out(self, sg, params, state):
        # lane 1 is nonzero exactly until the first sync: a replica whose
        # local degree cancels to zero before any exchange (a star hub
        # losing every local leaf in superstep one) must still emit once,
        # or no sync ever happens and the ``nsync`` validity gate that
        # allows ``post < k`` to fire on frontier rows never opens
        need = sg.frontier & (state["nsync"] == 0)
        return jnp.stack([jnp.where(sg.frontier, state["pending"], 0),
                          need.astype(jnp.int32)], axis=-1)

    def result(self, sg, params, state):
        """1 = peeled out of the k-core, 0 = still in it."""
        return (sg.vmask & ~state["alive"]).astype(jnp.int32)


def make_kcore(k: int):
    """(program, params) for the fixed-k peel."""
    if k < 1:
        raise ValueError(f"k={k}: the k-core peel needs k >= 1")
    return KCore(k=k), {}
