"""Graph Simulation (paper §7.3, Algorithm 2).

Pattern matching by simulation relation pruning: start with the label-match
relation R0 and prune ``v from sim(u)`` whenever some pattern successor u' of
u has ``post(v)[u'] == 0``, where ``post(v)[u'] = |{w in N_v^out : w in
sim(u')}|``. Decrements to ``post`` propagate to in-neighbours; across
partitions the decrement vectors Δpost are exchanged through SBS with the
``sum`` Aggregate operator, exactly as Algorithm 2's ``tempPost`` vectors.

Vertex-cut consistency: an *internal* vertex has all its edges in one
partition, so its ``post`` is complete locally from superstep 0. A *frontier*
vertex's out-edges are split, so its ``post`` is only valid after the first
SBS merge; pruning of frontier rows is gated on that (``nsync >= 2``),
keeping pruning monotone-safe (we can only ever over-estimate post before a
merge, which delays pruning but never mis-prunes).

State: ``sim [v_max, VQ]`` membership, ``post [v_max, VQ]`` effective counts
(last synced + own pending), ``pending [v_max, VQ]`` un-synced own delta.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, Tuple

import jax.numpy as jnp

from repro.core.api import DeviceSubgraph, VertexProgram


@dataclasses.dataclass
class GraphSimulation(VertexProgram):
    # label-indexed joins per edge: COO gather/scatter only
    supports_edge_backends: ClassVar[Tuple[str, ...]] = ("coo",)

    combiner: str = "sum"
    payload: int = 1          # set to |V_Q| at construction
    dtype: object = jnp.int32
    delta_based: bool = True

    def _scatter_to_src(self, sg: DeviceSubgraph, rows, ec):
        """sum_{(s,d) in E_local} rows[d]  ->  [v_max, VQ] at s."""
        contrib = jnp.where(sg.emask[:, None], rows[sg.edst], 0)
        out = jnp.zeros((sg.v_max, rows.shape[-1]), jnp.int32)
        out = out.at[sg.esrc].add(contrib)
        return ec.sum(out)

    def init(self, sg: DeviceSubgraph, params, ec):
        qlabel = params["qlabel"]  # [VQ]
        sim = sg.vmask[:, None] & (sg.vlabel[:, None] == qlabel[None, :])
        post = self._scatter_to_src(sg, sim.astype(jnp.int32), ec)
        return {"sim": sim, "post": post, "pending": post,
                "nsync": jnp.int32(0)}

    def apply_frontier(self, sg, params, state, merged, ec):
        f = sg.frontier[:, None]
        post = jnp.where(f, state["post"] - state["pending"] + merged,
                         state["post"])
        pending = jnp.where(f, 0, state["pending"])
        changed = jnp.sum(jnp.any(merged != 0, axis=-1) & sg.frontier,
                          dtype=jnp.int32)
        return {"sim": state["sim"], "post": post, "pending": pending,
                "nsync": state["nsync"] + 1}, changed

    def sweep(self, sg, params, state, ec):
        qadj = params["qadj"]  # [VQ, VQ] int32, qadj[u, u'] = 1 iff u->u' in Q
        sim, post, pending = state["sim"], state["post"], state["pending"]
        valid = (sg.internal | (state["nsync"] >= 1))[:, None]
        bad = (post == 0).astype(jnp.int32)                    # [v_max, VQ']
        viol = (bad @ qadj.T) > 0                              # [v_max, VQ]
        removed = sim & viol & valid & sg.vmask[:, None]
        sim = sim & ~removed
        dec = self._scatter_to_src(sg, removed.astype(jnp.int32), ec)
        post = post - dec
        pending = pending - dec
        changed = jnp.sum(removed, dtype=jnp.int32)
        return {"sim": sim, "post": post, "pending": pending,
                "nsync": state["nsync"]}, changed

    def frontier_out(self, sg, params, state):
        return jnp.where(sg.frontier[:, None], state["pending"], 0)

    def result(self, sg, params, state):
        return state["sim"].astype(jnp.int32)


def make_gsim(qadj, qlabel):
    """Build the program + params for a pattern graph."""
    import numpy as np
    qadj = np.asarray(qadj, dtype=np.int32)
    qlabel = np.asarray(qlabel, dtype=np.int32)
    prog = GraphSimulation(payload=int(qlabel.shape[0]))
    params = {"qadj": jnp.asarray(qadj), "qlabel": jnp.asarray(qlabel)}
    return prog, params
