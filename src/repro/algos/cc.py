"""Connected Components (paper §5.1 Algorithm 1, §5.2 Fig. 3).

Label propagation: every vertex starts labelled with its own global id; one
local sweep takes the min label over in-neighbours (the graph must be stored
undirected, i.e. both edge directions present, so this is symmetric). The
engine iterates sweeps to the partition-local fixed point — the vectorized
equivalent of the paper's ``SequentialCC`` per subgraph — and SBS merges
frontier labels with ``min`` (the paper's Aggregate operator for CC).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.api import DeviceSubgraph, SemiringSweep, VertexProgram

_IMAX = 2**31 - 1


@dataclasses.dataclass
class ConnectedComponents(VertexProgram):
    combiner: str = "min"
    payload: int = 1
    dtype: object = jnp.int32
    delta_based: bool = False
    monotone: bool = True       # labels only decrease -> warm-startable
    value_key: str = "label"

    # min-plus over zero-valued edges == min-label propagation; int32 all
    # the way through every backend (the Pallas kernels honor the dtype)
    sweep_spec = SemiringSweep("min_plus", "zero")

    def init(self, sg: DeviceSubgraph, params, ec):
        return {"label": jnp.where(sg.vmask, sg.vid32, _IMAX)}

    def apply_frontier(self, sg, params, state, merged, ec):
        m = merged[:, 0]
        new = jnp.where(sg.frontier, jnp.minimum(state["label"], m),
                        state["label"])
        changed = jnp.sum(new < state["label"], dtype=jnp.int32)
        return {"label": new}, changed

    def sweep_values(self, sg, params, state):
        return state["label"]

    def sweep_fold(self, sg, params, state, agg):
        lab = state["label"]
        new = jnp.where(sg.vmask, jnp.minimum(lab, agg), lab)
        changed = jnp.sum(new < lab, dtype=jnp.int32)
        return {"label": new}, changed

    def frontier_out(self, sg, params, state):
        return state["label"][:, None]

    def result(self, sg, params, state):
        return state["label"]
