"""Single-Source Shortest Path (paper §7.1).

The paper keeps sequential Dijkstra inside each subgraph. A priority queue is
hostile to a vector unit, so the TPU-native local solver is Bellman–Ford
iterated to the partition-local fixed point (min-plus semiring sweeps) — the
superstep/communication behaviour is identical to the paper's SC model
(distances propagate arbitrarily far inside a partition per superstep), and
the SBS Aggregate operator is ``min``, as in the paper.

Weights must be non-negative. Distances are float32.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.api import DeviceSubgraph, SemiringSweep, VertexProgram

INF = jnp.float32(jnp.inf)


@dataclasses.dataclass
class SSSP(VertexProgram):
    combiner: str = "min"
    payload: int = 1
    dtype: object = jnp.float32
    delta_based: bool = False
    monotone: bool = True          # distances only tighten -> warm-startable
    value_key: str = "dist"

    # declarative sweep: min-plus relax over the edge weights; the engine
    # routes the product through the configured edge-compute backend
    sweep_spec = SemiringSweep("min_plus", "weight")

    def init(self, sg: DeviceSubgraph, params, ec):
        src = params["source"]  # global vertex id (replicated scalar)
        dist = jnp.where(sg.vid32 == src, 0.0, INF).astype(jnp.float32)
        return {"dist": jnp.where(sg.vmask, dist, INF)}

    def apply_frontier(self, sg, params, state, merged, ec):
        m = merged[:, 0]
        new = jnp.where(sg.frontier, jnp.minimum(state["dist"], m),
                        state["dist"])
        changed = jnp.sum(new < state["dist"], dtype=jnp.int32)
        return {"dist": new}, changed

    def sweep_values(self, sg, params, state):
        return state["dist"]

    def sweep_fold(self, sg, params, state, agg):
        d = state["dist"]
        new = jnp.where(sg.vmask, jnp.minimum(d, agg), d)
        changed = jnp.sum(new < d, dtype=jnp.int32)
        return {"dist": new}, changed

    def frontier_out(self, sg, params, state):
        return state["dist"][:, None]

    def result(self, sg, params, state):
        return state["dist"]
