"""Breadth-First Search levels (Kakwani & Simmhan's first suite member).

BFS is SSSP over unit edge weights: the level of a vertex is the min-plus
distance where every hop costs 1. Declared as ``SemiringSweep("min_plus",
"one")`` — the first shipped program to exercise the ``'one'`` edge-value
map under ``min_plus`` on every edge backend (the COO reference and the
baked tile layouts add the 1 at the edge; ``engine._edge_messages`` does
the same for the windowed path).

Levels are float32 with ``inf`` at unreachable vertices: small integer
levels are exact in f32, and ``inf + 1 == inf`` keeps the unreachable
sentinel closed under the semiring on every backend (an int32 sentinel
would wrap under ``+ 1`` on the COO path and clamp on the tiles path —
two different wrong answers).

``MultiSourceBFS`` batches K root vertices into one launch ([v_max, K]
values, exactly the MSSP batching shape) — the distance phase of the
K-pivot Brandes betweenness driver (algos/betweenness.py).

Both are monotone under inserts (new edges only shorten levels), so a
serving session warm-starts them across insert-only flushes.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.api import DeviceSubgraph, SemiringSweep, VertexProgram

INF = jnp.float32(jnp.inf)


@dataclasses.dataclass
class BFS(VertexProgram):
    combiner: str = "min"
    payload: int = 1
    dtype: object = jnp.float32
    delta_based: bool = False
    monotone: bool = True          # levels only tighten under inserts
    value_key: str = "level"

    # unit-cost min-plus relax: level[d] = min_e level[src(e)] + 1
    sweep_spec = SemiringSweep("min_plus", "one")

    def init(self, sg: DeviceSubgraph, params, ec):
        src = params["source"]            # global vertex id (scalar)
        lvl = jnp.where(sg.vid32 == src, 0.0, INF).astype(jnp.float32)
        return {"level": jnp.where(sg.vmask, lvl, INF)}

    def apply_frontier(self, sg, params, state, merged, ec):
        m = merged[:, 0]
        new = jnp.where(sg.frontier, jnp.minimum(state["level"], m),
                        state["level"])
        changed = jnp.sum(new < state["level"], dtype=jnp.int32)
        return {"level": new}, changed

    def sweep_values(self, sg, params, state):
        return state["level"]

    def sweep_fold(self, sg, params, state, agg):
        lvl = state["level"]
        new = jnp.where(sg.vmask, jnp.minimum(lvl, agg), lvl)
        changed = jnp.sum(new < lvl, dtype=jnp.int32)
        return {"level": new}, changed

    def frontier_out(self, sg, params, state):
        return state["level"][:, None]

    def result(self, sg, params, state):
        return state["level"]


@dataclasses.dataclass
class MultiSourceBFS(VertexProgram):
    """K-root BFS in one launch: [v_max, K] levels, min-combined SBS."""

    combiner: str = "min"
    payload: int = 4               # K roots; set at construction
    dtype: object = jnp.float32
    delta_based: bool = False
    monotone: bool = True
    value_key: str = "level"

    sweep_spec = SemiringSweep("min_plus", "one")

    def init(self, sg: DeviceSubgraph, params, ec):
        sources = params["sources"]       # [K] global vertex ids
        lvl = jnp.where(sg.vid32[:, None] == sources[None, :], 0.0, INF)
        return {"level": jnp.where(sg.vmask[:, None], lvl, INF)}

    def apply_frontier(self, sg, params, state, merged, ec):
        new = jnp.where(sg.frontier[:, None],
                        jnp.minimum(state["level"], merged), state["level"])
        changed = jnp.sum(jnp.any(new < state["level"], -1), dtype=jnp.int32)
        return {"level": new}, changed

    def sweep_values(self, sg, params, state):
        return state["level"]

    def sweep_fold(self, sg, params, state, agg):
        lvl = state["level"]
        new = jnp.where(sg.vmask[:, None], jnp.minimum(lvl, agg), lvl)
        changed = jnp.sum(jnp.any(new < lvl, -1), dtype=jnp.int32)
        return {"level": new}, changed

    def frontier_out(self, sg, params, state):
        return state["level"]

    def result(self, sg, params, state):
        return state["level"]


def make_msbfs(sources):
    """(program, params) for K-root BFS from the given global vertex ids."""
    sources = np.asarray(sources, np.int32)
    prog = MultiSourceBFS(payload=int(sources.shape[0]))
    return prog, {"sources": jnp.asarray(sources)}
