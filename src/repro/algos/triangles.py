"""Triangle counting: K-pivot batched diag(A^3) over plus_times sweeps.

Exact per-edge triangle counting needs both endpoints' adjacency lists in
one place — impossible in a single pass under a vertex-cut. What the SBS
exchange model *does* support is the algebraic form: for a pivot vertex p
of a simple undirected graph (both directions stored, no self-loops, no
duplicates — the harness canonicalizes), the number of closed length-3
walks through p is

    diag(A^3)[p] = a_p^T A a_p = sum_u y_p[u] * z_p[u],
    y_p = A x_p (x_p one-hot at p, so y_p = a_p),   z_p = A y_p

i.e. exactly two ``SemiringSweep("plus_times", "one")`` products — the
same declarative spec as PageRank, so the program runs on every edge
backend. K pivots batch into [v_max, K] columns, one launch.

The two products are a *phase machine*: y must be globally synced before
z reads it, so phase 0 computes and sum-exchanges y partials, phase 1
does the same for z, phase 2 emits nothing and the engine's vote-to-halt
ends the run after exactly three supersteps. ``result`` is the per-vertex
product ``y * z``; hosts fold it with ``triangles_from_result``:
``diag(A^3)[p] = 2 * (triangles through p)``, and with pivots = all
vertices the global count is ``sum_p diag(A^3)[p] / 6``.

Not monotone (a new edge can create triangles, a deleted one destroy
them) — every query is a fresh three-superstep run.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.api import DeviceSubgraph, SemiringSweep, VertexProgram


@dataclasses.dataclass
class TriangleCount(VertexProgram):
    combiner: str = "sum"
    payload: int = 4               # K pivots; set at construction
    dtype: object = jnp.float32
    delta_based: bool = True
    monotone: bool = False

    sweep_spec = SemiringSweep("plus_times", "one")

    def init(self, sg: DeviceSubgraph, params, ec):
        pivots = params["pivots"]         # [K] global vertex ids
        x = ((sg.vid32[:, None] == pivots[None, :]) &
             sg.vmask[:, None]).astype(jnp.float32)
        zeros = jnp.zeros_like(x)
        return {"x": x, "y": zeros, "z": zeros,
                "phase": jnp.int32(0), "swept": jnp.int32(-1)}

    def apply_frontier(self, sg, params, state, merged, ec):
        f = sg.frontier[:, None]
        p = state["phase"]
        y = jnp.where((p == 0) & f, merged, state["y"])
        z = jnp.where((p == 1) & f, merged, state["z"])
        changed = jnp.sum(jnp.any(merged != 0, -1) & sg.frontier,
                          dtype=jnp.int32)
        return {"x": state["x"], "y": y, "z": z,
                "phase": jnp.minimum(p + 1, 2),
                "swept": state["swept"]}, changed

    def sweep_values(self, sg, params, state):
        return jnp.where(state["phase"] == 0, state["x"], state["y"])

    def sweep_fold(self, sg, params, state, agg):
        p = state["phase"]
        do = (state["swept"] < p) & (p <= 1)
        agg = jnp.where(sg.vmask[:, None], agg, 0.0)
        y = jnp.where((p == 0) & do, agg, state["y"])
        z = jnp.where((p == 1) & do, agg, state["z"])
        swept = jnp.where(do, p, state["swept"])
        return {"x": state["x"], "y": y, "z": z, "phase": p,
                "swept": swept}, do.astype(jnp.int32)

    def frontier_out(self, sg, params, state):
        p = state["phase"]
        out = jnp.where(p == 0, state["y"],
                        jnp.where(p == 1, state["z"], 0.0))
        return jnp.where(sg.frontier[:, None], out, 0.0)

    def result(self, sg, params, state):
        """Per-vertex [K] summands of diag(A^3) at each pivot."""
        return jnp.where(sg.vmask[:, None], state["y"] * state["z"], 0.0)


def make_triangles(pivots):
    """(program, params) counting triangles through the given pivots."""
    pivots = np.asarray(pivots, np.int32)
    prog = TriangleCount(payload=int(pivots.shape[0]))
    return prog, {"pivots": jnp.asarray(pivots)}


def triangles_from_result(values) -> np.ndarray:
    """Per-pivot triangle counts from collected [n, K] result values:
    triangles through pivot k = sum_u (y*z)[u, k] / 2. With pivots = all
    vertices, ``triangles_from_result(vals).sum() / 3`` is the global
    triangle count."""
    vals = np.asarray(values, np.float64)
    return vals.sum(axis=0) / 2.0
