"""Windowed one-hot segment combine — general-case SpMV reduce as a Pallas
TPU kernel (DESIGN.md §5).

The XLA fallback for the SVHM sweep's reduce-by-destination is a scatter,
which serializes badly on TPU. This kernel instead processes *edge blocks*
whose destinations are confined to one 128-row output window (a layout
produced by ``ops.window_align_edges`` — edges sorted by dst, padded per
window to a multiple of the block size, empty windows given one identity
block). No dynamic gather/scatter is needed inside the kernel:

  onehot[e, w] = (local_dst[e] == w)        # iota compare, VPU
  sum:  out_window += onehot.T @ msgs       # [W, Be] @ [Be, K] -> MXU
  min:  out_window = min(out_window, min_e where(onehot, msgs, +inf))

Scalar-prefetched ``block_window[b]`` routes each edge block to its output
window; consecutive blocks of the same window accumulate in VMEM.

Dtype: the kernel computes in ``msgs.dtype``. ``sum`` requires a float dtype
(MXU path); ``min``/``max`` work on any ordered dtype, with the identity
taken from ``ref.combine_identity`` (int32 min-combine pads with
``iinfo(int32).max``). ``interpret=None`` auto-selects compiled-on-TPU /
interpret-elsewhere, overridable per call.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.bsp_spmv import default_interpret
from repro.kernels.ref import combine_identity

W = 128       # output rows per window


def _kernel(block_window_ref, msgs_ref, ldst_ref, out_ref, *, combiner: str):
    b = pl.program_id(0)
    prev = block_window_ref[jnp.maximum(b - 1, 0)]
    first = (b == 0) | (block_window_ref[b] != prev)

    msgs = msgs_ref[0]                                   # [Be, K]
    ldst = ldst_ref[0]                                   # [Be]
    onehot = (ldst[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (ldst.shape[0], W), 1))

    if combiner == "sum":
        part = jnp.dot(onehot.astype(msgs.dtype).T, msgs,
                       preferred_element_type=msgs.dtype)             # MXU

        @pl.when(first)
        def _init():
            out_ref[0] = part

        @pl.when(jnp.logical_not(first))
        def _acc():
            out_ref[0] += part
    else:
        ident = combine_identity(combiner, msgs.dtype)
        cand = jnp.where(onehot[:, :, None], msgs[:, None, :], ident)  # [Be,W,K]
        red = jnp.min if combiner == "min" else jnp.max
        part = red(cand, axis=0)                                       # [W, K]

        @pl.when(first)
        def _init():
            out_ref[0] = part

        @pl.when(jnp.logical_not(first))
        def _acc():
            cur = out_ref[0]
            out_ref[0] = jnp.minimum(cur, part) if combiner == "min" \
                else jnp.maximum(cur, part)


@functools.partial(jax.jit, static_argnames=("n_windows", "combiner",
                                             "interpret"))
def segment_combine_windowed(msgs, local_dst, block_window, *, n_windows: int,
                             combiner: str = "sum", interpret=None):
    """msgs [B*Be, K] (identity-padded), local_dst [B*Be] i32 in [0, W),
    block_window [B] i32 sorted ascending covering every window
    ->  [n_windows, W, K] in msgs.dtype."""
    if interpret is None:
        interpret = default_interpret()
    if combiner == "sum" and not jnp.issubdtype(msgs.dtype, jnp.floating):
        raise ValueError(
            f"sum-combine rides the MXU and needs a float dtype, got "
            f"{msgs.dtype}; min/max are the integer-friendly combiners")
    B = block_window.shape[0]
    Be = msgs.shape[0] // B
    K = msgs.shape[-1]
    msgs = msgs.reshape(B, Be, K)
    local_dst = local_dst.reshape(B, Be)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, Be, K), lambda b, bw: (b, 0, 0)),
            pl.BlockSpec((1, Be), lambda b, bw: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, W, K), lambda b, bw: (bw[b], 0, 0)),
    )
    return pl.pallas_call(
        functools.partial(_kernel, combiner=combiner),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_windows, W, K), msgs.dtype),
        interpret=interpret,
    )(block_window, msgs, local_dst)
