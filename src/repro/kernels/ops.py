"""Host-side layout builders + jit'd wrappers around the Pallas kernels.

``build_tiles``        COO edges -> dst-major dense 128x128 tile list
                       (bsp_spmv input; identity filler rows guarantee every
                       output block is visited).
``window_align_edges`` dst-sorted COO -> per-128-row-window edge blocks
                       (segment_combine_windowed input; empty windows get one
                       identity block).
``spmv``               end-to-end semiring SpMV on COO via either kernel,
                       validated against ref.ref_* in tests.

These are the single-partition reference builders; the engine-facing stacked
[P, ...] layouts the edge-compute backends consume live in
``repro.core.layouts`` (same tile/window geometry, plus ShapePolicy
bucketing and incremental rebuild).

Layouts honor an explicit ``dtype`` (``min_plus`` works on float32 *and*
int32 — CC label propagation; ``plus_times``/``sum`` need floats for the
MXU). ``interpret=None`` everywhere auto-selects compiled on TPU, interpret
mode elsewhere (``default_interpret``), overridable per call.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.kernels.bsp_spmv import TM, TN, bsp_spmv, default_interpret
from repro.kernels.segment_combine import W, segment_combine_windowed
from repro.kernels.ref import combine_identity, tile_pad_identity

__all__ = ["build_tiles", "window_align_edges", "spmv", "TileLayout",
           "WindowLayout", "default_interpret"]


class TileLayout:
    """Dense-tile decomposition of one partition's adjacency (COO -> tiles)."""

    def __init__(self, src, dst, w, n_src_rows, n_dst_rows, semiring,
                 dtype=np.float32):
        src = np.asarray(src, np.int64)
        dst = np.asarray(dst, np.int64)
        self.dtype = np.dtype(dtype)
        w = np.asarray(w, self.dtype)
        # the kernel ADDS pads to values under min_plus: integer dtypes use
        # the wrap-safe halved identity (kernels/ref.py tile_pad_identity)
        ident = tile_pad_identity(semiring, self.dtype)
        self.semiring = semiring
        self.n_src_tiles = max(-(-int(n_src_rows) // TN), 1)
        self.n_dst_tiles = max(-(-int(n_dst_rows) // TM), 1)

        td, ts = dst // TM, src // TN
        key = td * self.n_src_tiles + ts
        order = np.argsort(key, kind="stable")
        key_s = key[order]
        uniq, start = np.unique(key_s, return_index=True)
        # one tile per unique (dst,src) block + identity fillers for dst rows
        # with no tiles at all
        covered = np.zeros(self.n_dst_tiles, bool)
        covered[(uniq // self.n_src_tiles).astype(np.int64)] = True
        missing = np.nonzero(~covered)[0]
        T = uniq.shape[0] + missing.shape[0]

        tiles = np.full((T, TM, TN), ident, self.dtype)
        tile_dst = np.zeros(T, np.int32)
        tile_src = np.zeros(T, np.int32)
        tile_dst[:uniq.shape[0]] = (uniq // self.n_src_tiles).astype(np.int32)
        tile_src[:uniq.shape[0]] = (uniq % self.n_src_tiles).astype(np.int32)
        tile_dst[uniq.shape[0]:] = missing.astype(np.int32)

        tidx = np.searchsorted(uniq, key)               # tile index per edge
        r = (dst % TM).astype(np.int64)
        c = (src % TN).astype(np.int64)
        if semiring == "plus_times":
            np.add.at(tiles, (tidx, r, c), w)
        else:
            np.minimum.at(tiles, (tidx, r, c), w)

        # re-sort whole list dst-major (fillers interleaved correctly)
        final = np.lexsort((tile_src, tile_dst))
        self.tiles = tiles[final]
        self.tile_dst = tile_dst[final]
        self.tile_src = tile_src[final]
        self.density = (self.tiles != ident).mean()

    def __call__(self, vals, *, interpret=None):
        """vals [n_src_rows(+pad), K] -> [n_dst_tiles*TM, K]."""
        K = vals.shape[-1]
        pad = self.n_src_tiles * TN - vals.shape[0]
        ident = tile_pad_identity(self.semiring, self.dtype)
        vals = vals.astype(self.dtype)
        if not np.issubdtype(self.dtype, np.floating):
            vals = jnp.minimum(vals, ident)   # keep ident + val wrap-free
        v = jnp.pad(vals, ((0, pad), (0, 0)), constant_values=ident)
        v = v.reshape(self.n_src_tiles, TN, K)
        out = bsp_spmv(jnp.asarray(self.tiles), jnp.asarray(self.tile_dst),
                       jnp.asarray(self.tile_src), v,
                       n_dst_tiles=self.n_dst_tiles, semiring=self.semiring,
                       interpret=interpret)
        return out.reshape(self.n_dst_tiles * TM, K)


def build_tiles(src, dst, w, n_src_rows, n_dst_rows, semiring,
                dtype=np.float32) -> TileLayout:
    return TileLayout(src, dst, w, n_src_rows, n_dst_rows, semiring,
                      dtype=dtype)


class WindowLayout:
    """Edge blocks confined to 128-dst-row windows (segment_combine input)."""

    def __init__(self, dst, n_rows, block_edges: int = 512):
        dst = np.asarray(dst, np.int64)
        self.n_windows = max(-(-int(n_rows) // W), 1)
        self.block_edges = Be = int(block_edges)
        order = np.argsort(dst, kind="stable")
        self.order = order
        dsts = dst[order]
        win = dsts // W
        counts = np.bincount(win, minlength=self.n_windows)
        blocks = np.maximum(-(-counts // Be), 1)         # >=1 block per window
        self.n_blocks = int(blocks.sum())
        self.block_window = np.repeat(np.arange(self.n_windows, dtype=np.int32),
                                      blocks)
        # slot of each (sorted) edge in the padded layout
        woff = np.concatenate([[0], np.cumsum(blocks)])[:-1] * Be
        estart = np.concatenate([[0], np.cumsum(counts)])[:-1]
        self.edge_slot = woff[win] + (np.arange(dsts.shape[0]) - estart[win])
        self.local_dst = np.zeros(self.n_blocks * Be, np.int32)
        self.local_dst[self.edge_slot] = (dsts % W).astype(np.int32)
        self.pad_mask = np.ones(self.n_blocks * Be, bool)
        self.pad_mask[self.edge_slot] = False

    def __call__(self, msgs, *, combiner="sum", interpret=None):
        """msgs [E, K] (in original edge order) -> [n_rows(+pad), K]."""
        msgs = jnp.asarray(msgs)
        K = msgs.shape[-1]
        ident = combine_identity(combiner, msgs.dtype)
        buf = jnp.full((self.n_blocks * self.block_edges, K), ident,
                       msgs.dtype)
        buf = buf.at[jnp.asarray(self.edge_slot)].set(
            msgs[jnp.asarray(self.order)])
        out = segment_combine_windowed(
            buf, jnp.asarray(self.local_dst), jnp.asarray(self.block_window),
            n_windows=self.n_windows, combiner=combiner, interpret=interpret)
        return out.reshape(self.n_windows * W, K)


def window_align_edges(dst, n_rows, block_edges: int = 512) -> WindowLayout:
    return WindowLayout(dst, n_rows, block_edges)


def spmv(src, dst, w, vals, n_rows, *, semiring="plus_times", kernel="tiles",
         interpret=None, dtype=np.float32):
    """One-shot semiring SpMV over COO edges (testing/benchmark entry)."""
    vals = jnp.asarray(vals, dtype)
    if vals.ndim == 1:
        vals = vals[:, None]
    if kernel == "tiles":
        layout = build_tiles(src, dst, w, vals.shape[0], n_rows, semiring,
                             dtype=dtype)
        return layout(vals, interpret=interpret)[:n_rows]
    # windowed: materialize edge messages then reduce
    sv = vals[jnp.asarray(np.asarray(src, np.int64))]
    wj = jnp.asarray(np.asarray(w, dtype))[:, None]
    msgs = sv * wj if semiring == "plus_times" else sv + wj
    layout = window_align_edges(dst, n_rows)
    comb = "sum" if semiring == "plus_times" else "min"
    return layout(msgs, combiner=comb, interpret=interpret)[:n_rows]
