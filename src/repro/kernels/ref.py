"""Pure-jnp oracles for the Pallas kernels (allclose targets).

Semirings (DESIGN.md §5): the SVHM local relaxation sweep is a semiring SpMV
over the partition's adjacency:
  - ``plus_times`` : out[d] = sum_s A[d,s] * v[s]      (PageRank push)
  - ``min_plus``   : out[d] = min_s A[d,s] + v[s]      (SSSP relax; CC with 0
                     weights — min-label propagation)
Absent entries are the semiring's multiplicative-absorbing pad: 0 for
plus_times, +inf for min_plus.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def combine_identity(combiner: str, dtype):
    """Identity element of a reduce combiner in ``dtype`` (the absorbing pad
    value of the kernels' empty slots): +inf / iinfo.max for ``min``,
    mirrored for ``max``, 0 for ``sum``."""
    dt = np.dtype(dtype)
    if combiner == "sum":
        return dt.type(0)
    if np.issubdtype(dt, np.floating):
        return dt.type(np.inf if combiner == "min" else -np.inf)
    info = np.iinfo(dt)
    return dt.type(info.max if combiner == "min" else info.min)


def semiring_identity(semiring: str, dtype=jnp.float32):
    """Additive identity of the semiring — what absent matrix entries hold:
    0 for ``plus_times``, +inf (or the integer max) for ``min_plus``."""
    return combine_identity("sum" if semiring == "plus_times" else "min",
                            dtype)


def tile_pad_identity(semiring: str, dtype):
    """Absorbing pad for *dense tile* contents and the value blocks fed to
    ``bsp_spmv``. The tile kernel ADDS pads to values under ``min_plus``
    (+inf + x = +inf keeps floats safe), so integer dtypes use the halved
    max: ``ident + ident`` must not wrap past the dtype, or a padding lane
    could win the min. Values entering the tile kernel are clamped to this
    bound for the same reason — sound as long as real values stay below it
    (int32: < 2**30, e.g. CC labels on graphs below a billion vertices)."""
    dt = np.dtype(dtype)
    if semiring == "plus_times" or np.issubdtype(dt, np.floating):
        return semiring_identity(semiring, dt)
    return dt.type(np.iinfo(dt).max >> 1)


def ref_tile_spmv(tiles, tile_dst, tile_src, vals, n_dst_tiles, semiring):
    """Oracle for kernels.bsp_spmv.

    tiles:    [T, tm, tn] dense tile values (pad = semiring absorbing elem)
    tile_dst: [T] int32 dst tile row per tile
    tile_src: [T] int32 src tile col per tile
    vals:     [n_src_tiles, tn, K]
    returns   [n_dst_tiles, tm, K]
    """
    T, tm, tn = tiles.shape
    K = vals.shape[-1]
    ident = semiring_identity(semiring)
    out = jnp.full((n_dst_tiles, tm, K), ident, jnp.float32)
    v = vals[tile_src]                                   # [T, tn, K]
    if semiring == "plus_times":
        part = jnp.einsum("tmn,tnk->tmk", tiles, v)      # [T, tm, K]
        return out.at[tile_dst].add(part)
    cand = tiles[:, :, :, None] + v[:, None, :, :]       # [T, tm, tn, K]
    part = jnp.min(cand, axis=2)                         # [T, tm, K]
    return out.at[tile_dst].min(part)


def ref_segment_combine(msgs, seg_ids, n_segments, combiner):
    """Oracle for kernels.segment_combine: combine msgs[e] into seg_ids[e].

    msgs: [E, K]; seg_ids: [E] int32 sorted ascending; returns [n_segments, K]
    (identity rows for empty segments).
    """
    if combiner == "sum":
        out = jnp.zeros((n_segments, msgs.shape[-1]), msgs.dtype)
        return out.at[seg_ids].add(msgs)
    if combiner == "min":
        out = jnp.full((n_segments, msgs.shape[-1]), jnp.inf, msgs.dtype)
        return out.at[seg_ids].min(msgs)
    if combiner == "max":
        out = jnp.full((n_segments, msgs.shape[-1]), -jnp.inf, msgs.dtype)
        return out.at[seg_ids].max(msgs)
    raise ValueError(combiner)


def dense_from_tiles(tiles, tile_dst, tile_src, n_dst_tiles, n_src_tiles,
                     semiring):
    """Expand the tile list into a dense [n_dst*tm, n_src*tn] matrix (small
    test graphs only) — second-level oracle used to cross-check the tile
    builder itself."""
    T, tm, tn = tiles.shape
    ident = float(semiring_identity(semiring))
    dense = np.full((n_dst_tiles * tm, n_src_tiles * tn), ident, np.float32)
    for t in range(T):
        r, c = int(tile_dst[t]) * tm, int(tile_src[t]) * tn
        block = np.asarray(tiles[t])
        if semiring == "plus_times":
            dense[r:r + tm, c:c + tn] += block
        else:
            dense[r:r + tm, c:c + tn] = np.minimum(dense[r:r + tm, c:c + tn], block)
    return dense
