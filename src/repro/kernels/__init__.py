from repro.kernels.bsp_spmv import bsp_spmv
from repro.kernels.segment_combine import segment_combine_windowed
from repro.kernels import ops, ref

__all__ = ["bsp_spmv", "segment_combine_windowed", "ops", "ref"]
