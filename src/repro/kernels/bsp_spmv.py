"""Block-sparse semiring SpMV — the SVHM local-sweep hot loop as a Pallas TPU
kernel (DESIGN.md §5).

TPU adaptation of the paper's per-subgraph sequential relaxation: the
partition's adjacency is decomposed into dense (tm x tn) = (128 x 128) tiles
listed in *dst-major* order. The kernel walks the tile list with
scalar-prefetched (tile_dst, tile_src) routing arrays
(``pltpu.PrefetchScalarGridSpec``): BlockSpec index maps pull the right value
block per tile, the output block stays resident in VMEM while consecutive
grid steps visit tiles of the same dst row (revisit-accumulate pattern,
``@pl.when`` on the first visit), and

  - ``plus_times`` rides the MXU: tile @ vals_block  (128x128 @ 128xK)
  - ``min_plus``   rides the VPU: min over src of (tile + vals)

Requirements (enforced by ``ops.build_tiles`` and ``core.layouts``):
  - tile list sorted by (tile_dst, tile_src); every dst tile row appears at
    least once (identity filler tiles), so every output block is initialized;
  - tiles dense with the semiring's absorbing pad (0 / +inf / INT_MAX).

Dtype: the kernel computes in the dtype of ``tiles``/``vals`` (they must
agree). ``min_plus`` supports any ordered dtype — float32 for SSSP
distances, int32 for CC min-label propagation (whose identity is
``iinfo(int32).max``, not +inf). ``plus_times`` requires a float dtype (the
MXU path accumulates through ``preferred_element_type``).

``interpret=None`` (the default) auto-selects: compiled on TPU, interpret
mode everywhere else (``ops.default_interpret``) — overridable per call.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TM = 128   # dst rows per tile (MXU-aligned)
TN = 128   # src cols per tile


def default_interpret() -> bool:
    """Pallas interpret mode unless we are actually on a TPU backend."""
    return jax.default_backend() != "tpu"


def _kernel(tile_dst_ref, tile_src_ref, tiles_ref, vals_ref, out_ref, *,
            semiring: str):
    i = pl.program_id(0)
    prev = tile_dst_ref[jnp.maximum(i - 1, 0)]
    first = (i == 0) | (tile_dst_ref[i] != prev)

    t = tiles_ref[0]                                     # [TM, TN]
    v = vals_ref[0]                                      # [TN, K]

    if semiring == "plus_times":
        part = jnp.dot(t, v, preferred_element_type=v.dtype)       # MXU

        @pl.when(first)
        def _init():
            out_ref[0] = part

        @pl.when(jnp.logical_not(first))
        def _acc():
            out_ref[0] += part
    else:  # min_plus
        cand = t[:, :, None] + v[None, :, :]             # [TM, TN, K]
        part = jnp.min(cand, axis=1)                     # [TM, K]

        @pl.when(first)
        def _init():
            out_ref[0] = part

        @pl.when(jnp.logical_not(first))
        def _acc():
            out_ref[0] = jnp.minimum(out_ref[0], part)


@functools.partial(jax.jit, static_argnames=("n_dst_tiles", "semiring",
                                             "interpret"))
def bsp_spmv(tiles, tile_dst, tile_src, vals, *, n_dst_tiles: int,
             semiring: str = "plus_times", interpret=None):
    """tiles [T,TM,TN], tile_dst/src [T] i32 (dst-major sorted),
    vals [n_src_tiles, TN, K]  ->  [n_dst_tiles, TM, K] (dtype of vals)."""
    if interpret is None:
        interpret = default_interpret()
    T, tm, tn = tiles.shape
    K = vals.shape[-1]
    assert (tm, tn) == (TM, TN)
    assert tiles.dtype == vals.dtype, (tiles.dtype, vals.dtype)
    if semiring == "plus_times" and not jnp.issubdtype(vals.dtype,
                                                       jnp.floating):
        raise ValueError(
            f"plus_times rides the MXU and needs a float dtype, got "
            f"{vals.dtype}; min_plus is the integer-friendly semiring")

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(T,),
        in_specs=[
            pl.BlockSpec((1, TM, TN), lambda i, td, ts: (i, 0, 0)),
            pl.BlockSpec((1, TN, K), lambda i, td, ts: (ts[i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, TM, K), lambda i, td, ts: (td[i], 0, 0)),
    )
    return pl.pallas_call(
        functools.partial(_kernel, semiring=semiring),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_dst_tiles, TM, K), vals.dtype),
        interpret=interpret,
    )(tile_dst, tile_src, tiles, vals)
