"""repro — DRONE/SVHM (Wen, Zhang, You 2018) on TPU: a distributed
subgraph-centric graph engine with vertex-cut partitioning, plus the assigned
LM-architecture zoo, sharded launch/dry-run and roofline tooling."""

__version__ = "0.1.0"
