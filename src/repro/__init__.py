"""repro — DRONE/SVHM (Wen, Zhang, You 2018) on TPU: a distributed
subgraph-centric graph engine with vertex-cut partitioning, plus the assigned
LM-architecture zoo, sharded launch/dry-run and roofline tooling.

Primary serving API: ``repro.session.GraphSession`` (resident device graph,
compiled-runner caching, streaming updates). The free functions in
``repro.core`` are the low-level one-shot layer underneath it.
"""

__version__ = "0.2.0"
