"""Engine-facing edge-compute layouts: stacked [P, ...] tile/window
decompositions of a ``PartitionedGraph``'s per-partition adjacencies, feeding
the Pallas semiring kernels (``repro.kernels``) from inside the BSP sweep.

``repro.kernels.ops`` holds the single-partition reference builders; this
module is their serving-grade counterpart, with three extra obligations:

  - **stacked + padded** — every per-partition quantity is padded to a
    shared capacity (``t_max`` tiles, ``b_max`` edge blocks) so the whole
    graph is one dense pytree: the simulator backend flattens all P
    partitions into a *single* kernel launch (tile/window ids offset by
    ``p * n_dst_tiles``), and the shard_map backend shards the leading axis.
    Padding tiles hold the semiring identity and point at the last dst tile
    (keeping the dst-major sort); padding blocks point at the last window.
  - **program-independent geometry, per-program realization** — the
    expensive part (edge -> tile/slot assignment) depends only on the graph
    and is built once; the dense tile *values* depend on the program's
    ``SemiringSweep`` (semiring x edge-value map x dtype) and are realized
    lazily per key and cached. Window layouts never bake values at all
    (messages are computed in-sweep), so one geometry serves every program.
  - **ShapePolicy-bucketed capacities** — ``t_max``/``b_max`` come from the
    same geometric bucketing as ``v_max``/``e_max`` (docs/ARCHITECTURE.md,
    "shape-bucket lifecycle") and are *grow-only* under delta patching, so a
    serving session's compiled Pallas runners survive in-bucket streaming
    growth with zero retraces. ``rebuild_partitions`` refreshes only the
    partitions a delta touched.

Layout invariants the kernels rely on (see kernels/bsp_spmv.py):
tile lists are (dst, src)-sorted per partition with every dst tile row
covered at least once; ``bwin`` is ascending covering every window; padded
edge slots are ``-1`` (dropped by the scatter); all values at padded
positions are the semiring/combiner identity.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, NamedTuple, Optional, Tuple

import numpy as np

from repro.kernels.bsp_spmv import TM, TN
from repro.kernels.segment_combine import W
from repro.kernels.ref import tile_pad_identity

__all__ = ["EdgeLayouts", "TileBlock", "WindowBlock", "build_edge_layouts",
           "EDGE_VALUE_KINDS"]

EDGE_VALUE_KINDS = ("weight", "zero", "one")
DEFAULT_BLOCK_EDGES = 512


class TileBlock(NamedTuple):
    """Device pytree for the ``pallas_tiles`` backend (stacked [P, ...])."""
    tiles: object      # [P, t_max, TM, TN] program dtype
    tile_dst: object   # [P, t_max] int32, partition-local dst tile ids
    tile_src: object   # [P, t_max] int32


class WindowBlock(NamedTuple):
    """Device pytree for the ``pallas_windows`` backend (stacked [P, ...])."""
    eslot: object      # [P, e_max] int32 buffer slot per edge (-1 = padding)
    ldst: object       # [P, b_max*Be] int32 dst row within the 128-window
    bwin: object       # [P, b_max] int32 window id per block (ascending)


def _edge_values(kind: str, ew: np.ndarray, dtype) -> np.ndarray:
    """The declarative edge-value map of a ``SemiringSweep``: what each edge
    contributes to the semiring product (SSSP relaxes by the weight, CC
    propagates labels over 0-weight edges, PageRank pushes unweighted)."""
    if kind == "weight":
        return ew.astype(dtype)
    if kind == "zero":
        return np.zeros(ew.shape[0], dtype)
    if kind == "one":
        return np.ones(ew.shape[0], dtype)
    raise ValueError(f"unknown edge-value kind {kind!r}; "
                     f"expected one of {EDGE_VALUE_KINDS}")


def _tile_geometry(ls, ld, ndt: int, nst: int):
    """(local src, local dst) -> (tile_dst, tile_src, edge_tile, r, c).

    Tile list sorted (dst, src)-major with identity fillers covering every
    dst tile row; ``edge_tile[e]`` indexes the *final* sorted list.
    """
    key = (ld.astype(np.int64) // TM) * nst + (ls.astype(np.int64) // TN)
    uniq = np.unique(key)
    covered = np.zeros(ndt, bool)
    covered[(uniq // nst).astype(np.int64)] = True
    missing = np.nonzero(~covered)[0]
    T = uniq.shape[0] + missing.shape[0]

    tile_dst = np.zeros(T, np.int32)
    tile_src = np.zeros(T, np.int32)
    tile_dst[:uniq.shape[0]] = (uniq // nst).astype(np.int32)
    tile_src[:uniq.shape[0]] = (uniq % nst).astype(np.int32)
    tile_dst[uniq.shape[0]:] = missing.astype(np.int32)

    final = np.lexsort((tile_src, tile_dst))
    inv = np.empty(T, np.int64)
    inv[final] = np.arange(T)
    edge_tile = inv[np.searchsorted(uniq, key)].astype(np.int32)
    return (tile_dst[final], tile_src[final], edge_tile,
            (ld % TM).astype(np.int32), (ls % TN).astype(np.int32))


def _window_geometry(ld, nw: int, Be: int):
    """Ascending-dst local edges -> (eslot, ldst, bwin, n_blocks)."""
    win = ld.astype(np.int64) // W
    counts = np.bincount(win, minlength=nw)
    blocks = np.maximum(-(-counts // Be), 1)          # >= 1 block per window
    n_blocks = int(blocks.sum())
    bwin = np.repeat(np.arange(nw, dtype=np.int32), blocks)
    woff = np.concatenate([[0], np.cumsum(blocks)])[:-1] * Be
    estart = np.concatenate([[0], np.cumsum(counts)])[:-1]
    eslot = (woff[win] + (np.arange(ld.shape[0]) - estart[win])).astype(
        np.int32)
    ldst = np.zeros(n_blocks * Be, np.int32)
    ldst[eslot] = (ld % W).astype(np.int32)
    return eslot, ldst, bwin, n_blocks


@dataclasses.dataclass
class EdgeLayouts:
    """Host-side stacked layout state attached to a ``PartitionedGraph``
    (``PartitionedGraph.ensure_edge_layouts``). All arrays are numpy; the
    ``device_tiles``/``device_windows`` accessors return cached jnp pytrees
    that a runner takes as explicit inputs (never closed over — the
    session's zero-retrace contract needs them to be arguments)."""

    n_parts: int
    v_max: int
    e_max: int
    t_max: int                    # padded tiles per partition (bucketed)
    b_max: int                    # padded edge blocks per partition
    block_edges: int
    policy: object                # ShapePolicy governing t_max/b_max growth

    tile_dst: np.ndarray          # [P, t_max] int32
    tile_src: np.ndarray          # [P, t_max] int32
    n_tiles: np.ndarray           # [P] int64 real (content) tiles
    edge_tile: np.ndarray         # [P, e_max] int32 (-1 = padding edge)
    edge_r: np.ndarray            # [P, e_max] int32 row within tile
    edge_c: np.ndarray            # [P, e_max] int32 col within tile
    eslot: np.ndarray             # [P, e_max] int32 (-1 = padding edge)
    ldst: np.ndarray              # [P, b_max*Be] int32
    bwin: np.ndarray              # [P, b_max] int32
    n_blocks: np.ndarray          # [P] int64 real blocks

    _tiles: Dict[Tuple, np.ndarray] = dataclasses.field(default_factory=dict)
    _filled: Dict[Tuple, np.ndarray] = dataclasses.field(
        default_factory=dict)             # [P] non-identity entries per part
    _density: Dict[Tuple, float] = dataclasses.field(default_factory=dict)
    _device: Dict[Tuple, object] = dataclasses.field(default_factory=dict)
    # edge-axis-sharded geometry (shard_map cfg.edge_axes on the Pallas
    # backends): host geometry per shard count, rebuilt wholesale on any
    # graph change; the per-shard caps are grow-only across rebuilds so a
    # compiled sharded runner survives in-bucket streaming growth.
    _shard_geom: Dict[int, Dict] = dataclasses.field(default_factory=dict)
    _shard_caps: Dict[int, Tuple[int, int]] = dataclasses.field(
        default_factory=dict)             # S -> (t_loc, b_loc), grow-only

    # ------------------------------------------------------------------ #
    @property
    def n_dst_tiles(self) -> int:
        return max(-(-self.v_max // TM), 1)

    @property
    def n_src_tiles(self) -> int:
        return max(-(-self.v_max // TN), 1)

    @property
    def n_windows(self) -> int:
        return max(-(-self.v_max // W), 1)

    def shape_key(self, backend: str, n_shards: int = 1, pg=None) -> tuple:
        """What a compiled Pallas runner is additionally specialized to —
        joins the session's padded-shape key for cache lookup/eviction.
        ``n_shards > 1`` keys the edge-axis-sharded variant (``pg``
        required: the per-shard caps come from the sharded geometry)."""
        if n_shards > 1:
            assert pg is not None, "sharded shape_key needs the graph"
            self._sharded_geometry(pg, n_shards)
            t_loc, b_loc = self._shard_caps[int(n_shards)]
            if backend == "pallas_tiles":
                return ("tiles", int(n_shards), t_loc, self.n_dst_tiles,
                        self.n_src_tiles)
            return ("windows", int(n_shards), b_loc, self.block_edges,
                    self.n_windows)
        if backend == "pallas_tiles":
            return ("tiles", self.t_max, self.n_dst_tiles, self.n_src_tiles)
        return ("windows", self.b_max, self.block_edges, self.n_windows)

    # ------------------------------------------------------------------ #
    # realization: dense tile values per (semiring, edge-value map, dtype)
    # ------------------------------------------------------------------ #
    def _realize_tiles(self, pg, key, parts: Optional[Iterable[int]] = None):
        semiring, kind, dtype_str = key
        dtype = np.dtype(dtype_str)
        # tile contents are ADDED to values under min_plus: integer dtypes
        # pad with the wrap-safe halved identity (kernels/ref.py)
        ident = tile_pad_identity(semiring, dtype)
        tiles = self._tiles.get(key)
        if tiles is None or parts is None:
            tiles = np.full((self.n_parts, self.t_max, TM, TN), ident, dtype)
            parts = range(self.n_parts)
            self._tiles[key] = tiles
            self._filled[key] = np.zeros(self.n_parts, np.int64)
        filled = self._filled[key]
        for p in parts:
            tiles[p] = ident
            valid = self.edge_tile[p] >= 0
            vals = _edge_values(kind, pg.ew[p][valid], dtype)
            idx = (self.edge_tile[p][valid], self.edge_r[p][valid],
                   self.edge_c[p][valid])
            if semiring == "plus_times":
                np.add.at(tiles[p], idx, vals)
            else:
                np.minimum.at(tiles[p], idx, vals)
            # per-partition count, so an incremental rebuild never scans the
            # untouched partitions' tile bytes just to refresh the density
            filled[p] = int((tiles[p] != ident).sum())
        self._density[key] = int(filled.sum()) / max(
            int(self.n_tiles.sum()) * TM * TN, 1)
        return tiles

    def tile_values(self, pg, semiring: str, kind: str, dtype) -> np.ndarray:
        key = (semiring, kind, np.dtype(dtype).str)
        if key not in self._tiles:
            self._realize_tiles(pg, key)
        return self._tiles[key]

    def density(self, pg, semiring: str, kind: str, dtype) -> float:
        """Fraction of non-identity entries across the real (content) tiles
        — the utilization the dense-tile MXU path achieves; low density
        means ``pallas_windows`` (or COO) is the better backend."""
        key = (semiring, kind, np.dtype(dtype).str)
        if key not in self._density:
            self._realize_tiles(pg, key)
        return self._density[key]

    def partition_density(self, pg, semiring: str, kind: str,
                          dtype) -> np.ndarray:
        """[P] per-partition tile density (non-identity fraction of each
        partition's real tiles) — the actual input of the ``'auto'`` backend
        policy, surfaced per partition in ``ExecutionStats``."""
        key = (semiring, kind, np.dtype(dtype).str)
        if key not in self._filled:
            self._realize_tiles(pg, key)
        denom = np.maximum(self.n_tiles * (TM * TN), 1).astype(np.float64)
        return self._filled[key].astype(np.float64) / denom

    # ------------------------------------------------------------------ #
    # device pytrees (cached; invalidated by any rebuild)
    # ------------------------------------------------------------------ #
    def device_tiles(self, pg, semiring: str, kind: str, dtype) -> TileBlock:
        import jax.numpy as jnp
        key = ("tiles", semiring, kind, np.dtype(dtype).str)
        blk = self._device.get(key)
        if blk is None:
            vals = self.tile_values(pg, semiring, kind, dtype)
            blk = TileBlock(tiles=jnp.asarray(vals),
                            tile_dst=jnp.asarray(self.tile_dst),
                            tile_src=jnp.asarray(self.tile_src))
            self._device[key] = blk
        return blk

    def device_windows(self) -> WindowBlock:
        import jax.numpy as jnp
        blk = self._device.get(("windows",))
        if blk is None:
            blk = WindowBlock(eslot=jnp.asarray(self.eslot),
                              ldst=jnp.asarray(self.ldst),
                              bwin=jnp.asarray(self.bwin))
            self._device[("windows",)] = blk
        return blk

    # ------------------------------------------------------------------ #
    # edge-axis-sharded geometry (shard_map edge_axes on Pallas backends)
    # ------------------------------------------------------------------ #
    def _sharded_geometry(self, pg, n_shards: int) -> Dict:
        """Per-(partition, shard) tile/window geometry over the ``n_shards``
        contiguous ``e_max / n_shards`` column chunks of the edge arrays —
        the chunks a ``P(sub_axes, edge_axes)`` sharding hands each device.

        Each partition's valid edges are dst-sorted ascending along the
        columns (``localize_edges``), so any chunk's valid subset is itself
        dst-ascending and the per-shard builders apply unchanged.
        Each shard gets its own coverage fillers (every dst tile / window
        covered at least once), per-shard-local slot ids, and a shared
        bucketed per-shard capacity (``t_loc`` tiles / ``b_loc`` blocks,
        grow-only across rebuilds) so the stacked arrays split evenly:
        tiles [P, S*t_loc, TM, TN], bwin [P, S*b_loc], ldst
        [P, S*b_loc*Be], eslot [P, e_max] holding *shard-local* slots."""
        S = int(n_shards)
        geom = self._shard_geom.get(S)
        if geom is not None:
            return geom
        assert self.e_max % S == 0, \
            (f"e_max={self.e_max} must divide by n_shards={S}; pad edges "
             f"to a multiple of the edge axes")
        Se = self.e_max // S
        ndt, nst, nw = self.n_dst_tiles, self.n_src_tiles, self.n_windows
        Be = self.block_edges
        P = self.n_parts

        per = []                       # (p, s) -> geometry pieces
        need_t = need_b = 1
        for p in range(P):
            m = pg.emask[p]
            for s in range(S):
                cols = slice(s * Se, (s + 1) * Se)
                ms = m[cols]
                ls, ld = pg.esrc[p][cols][ms], pg.edst[p][cols][ms]
                td, ts, et, er, ec = _tile_geometry(ls, ld, ndt, nst)
                es, ldst, bw, nb = _window_geometry(ld, nw, Be)
                per.append((np.nonzero(ms)[0] + s * Se, td, ts, et, er, ec,
                            es, ldst, bw, nb))
                need_t = max(need_t, td.shape[0])
                need_b = max(need_b, nb)
        prev_t, prev_b = self._shard_caps.get(S, (0, 0))
        t_loc = max(prev_t, self.policy.bucket(need_t))
        b_loc = max(prev_b, self.policy.bucket(need_b))
        self._shard_caps[S] = (t_loc, b_loc)

        geom = dict(
            n_shards=S, t_loc=t_loc, b_loc=b_loc,
            tile_dst=np.full((P, S * t_loc), ndt - 1, np.int32),
            tile_src=np.full((P, S * t_loc), nst - 1, np.int32),
            edge_tile=np.full((P, self.e_max), -1, np.int32),
            edge_r=np.zeros((P, self.e_max), np.int32),
            edge_c=np.zeros((P, self.e_max), np.int32),
            eslot=np.full((P, self.e_max), -1, np.int32),
            ldst=np.zeros((P, S * b_loc * Be), np.int32),
            bwin=np.full((P, S * b_loc), nw - 1, np.int32),
            n_tiles=np.zeros((P, S), np.int64),
            n_blocks=np.zeros((P, S), np.int64),
        )
        it = iter(per)
        for p in range(P):
            for s in range(S):
                cols, td, ts, et, er, ec, es, ldst, bw, nb = next(it)
                T = td.shape[0]
                t0, b0 = s * t_loc, s * b_loc
                geom["tile_dst"][p, t0:t0 + T] = td
                geom["tile_src"][p, t0:t0 + T] = ts
                geom["n_tiles"][p, s] = T
                # edge_tile indexes the concatenated [S*t_loc] list: the
                # host-side value realization scatters through it; on
                # device each shard sees only its own [t_loc] slice
                geom["edge_tile"][p, cols] = et + t0
                geom["edge_r"][p, cols] = er
                geom["edge_c"][p, cols] = ec
                geom["eslot"][p, cols] = es        # shard-local slot ids
                geom["ldst"][p, b0 * Be:b0 * Be + ldst.shape[0]] = ldst
                geom["bwin"][p, b0:b0 + nb] = bw
                geom["n_blocks"][p, s] = nb
        self._shard_geom[S] = geom
        return geom

    def device_tiles_sharded(self, pg, semiring: str, kind: str, dtype,
                             n_shards: int) -> TileBlock:
        import jax.numpy as jnp
        S = int(n_shards)
        key = ("tiles_sharded", S, semiring, kind, np.dtype(dtype).str)
        blk = self._device.get(key)
        if blk is None:
            g = self._sharded_geometry(pg, S)
            dt = np.dtype(dtype)
            ident = tile_pad_identity(semiring, dt)
            tiles = np.full((self.n_parts, S * g["t_loc"], TM, TN), ident,
                            dt)
            for p in range(self.n_parts):
                valid = g["edge_tile"][p] >= 0
                vals = _edge_values(kind, pg.ew[p][valid], dt)
                idx = (g["edge_tile"][p][valid], g["edge_r"][p][valid],
                       g["edge_c"][p][valid])
                if semiring == "plus_times":
                    np.add.at(tiles[p], idx, vals)
                else:
                    np.minimum.at(tiles[p], idx, vals)
            blk = TileBlock(tiles=jnp.asarray(tiles),
                            tile_dst=jnp.asarray(g["tile_dst"]),
                            tile_src=jnp.asarray(g["tile_src"]))
            self._device[key] = blk
        return blk

    def device_windows_sharded(self, pg, n_shards: int) -> WindowBlock:
        import jax.numpy as jnp
        S = int(n_shards)
        key = ("windows_sharded", S)
        blk = self._device.get(key)
        if blk is None:
            g = self._sharded_geometry(pg, S)
            blk = WindowBlock(eslot=jnp.asarray(g["eslot"]),
                              ldst=jnp.asarray(g["ldst"]),
                              bwin=jnp.asarray(g["bwin"]))
            self._device[key] = blk
        return blk

    # ------------------------------------------------------------------ #
    # accounting
    # ------------------------------------------------------------------ #
    def flops_per_sweep(self, backend: str, K: int, n_shards: int = 1,
                        pg=None) -> np.ndarray:
        """[P] semiring ops one local sweep costs per partition: the dense
        work the kernels actually issue (multiply+accumulate per tile entry;
        compare+combine per block slot), *including* identity padding inside
        real tiles/blocks — that is the density tax the stats surface.
        ``n_shards > 1`` bills the per-shard coverage fillers of the
        edge-axis-sharded launch."""
        if n_shards > 1:
            g = self._sharded_geometry(pg, n_shards)
            if backend == "pallas_tiles":
                return (g["n_tiles"].sum(axis=1)
                        * (2 * TM * TN * K)).astype(np.int64)
            return (g["n_blocks"].sum(axis=1)
                    * (2 * W * self.block_edges * K)).astype(np.int64)
        if backend == "pallas_tiles":
            return (self.n_tiles * (2 * TM * TN * K)).astype(np.int64)
        return (self.n_blocks * (2 * W * self.block_edges * K)).astype(
            np.int64)

    # ------------------------------------------------------------------ #
    # (re)build
    # ------------------------------------------------------------------ #
    def _build_partition(self, pg, p: int):
        """Recompute partition ``p``'s geometry rows in place (caps must
        already fit; callers grow them first)."""
        m = pg.emask[p]
        ls, ld = pg.esrc[p][m], pg.edst[p][m]
        ne = ls.shape[0]
        ndt, nst, nw = self.n_dst_tiles, self.n_src_tiles, self.n_windows
        td, ts, et, er, ec = _tile_geometry(ls, ld, ndt, nst)
        T = td.shape[0]
        self.tile_dst[p] = ndt - 1       # padding tiles: last dst row
        self.tile_src[p] = nst - 1
        self.tile_dst[p, :T] = td
        self.tile_src[p, :T] = ts
        self.n_tiles[p] = T
        self.edge_tile[p] = -1
        self.edge_r[p] = 0
        self.edge_c[p] = 0
        self.edge_tile[p, :ne] = et
        self.edge_r[p, :ne] = er
        self.edge_c[p, :ne] = ec

        es, ldst, bw, nb = _window_geometry(ld, nw, self.block_edges)
        self.eslot[p] = -1
        self.eslot[p, :ne] = es
        self.ldst[p] = 0
        self.ldst[p, :ldst.shape[0]] = ldst
        self.bwin[p] = nw - 1            # padding blocks: last window
        self.bwin[p, :nb] = bw
        self.n_blocks[p] = nb

    def _partition_caps(self, pg, p: int) -> Tuple[int, int]:
        """(tiles, blocks) partition ``p`` needs at the current shapes."""
        m = pg.emask[p]
        ls, ld = pg.esrc[p][m], pg.edst[p][m]
        nst, nw = self.n_src_tiles, self.n_windows
        key = (ld.astype(np.int64) // TM) * nst + (ls.astype(np.int64) // TN)
        uniq = np.unique(key)
        covered = np.zeros(self.n_dst_tiles, bool)
        covered[(uniq // nst).astype(np.int64)] = True
        T = uniq.shape[0] + int((~covered).sum())
        counts = np.bincount(ld.astype(np.int64) // W, minlength=nw)
        B = int(np.maximum(-(-counts // self.block_edges), 1).sum())
        return T, B

    def _grow_caps(self, need_t: int, need_b: int) -> bool:
        """Grow ``t_max``/``b_max`` to the policy bucket (grow-only, like
        ``e_max`` under a delta). Returns True if anything grew."""
        grew = False
        if need_t > self.t_max:
            new_t = max(self.t_max, self.policy.bucket(need_t))
            pad = new_t - self.t_max
            self.tile_dst = np.concatenate(
                [self.tile_dst, np.full((self.n_parts, pad),
                                        self.n_dst_tiles - 1, np.int32)], 1)
            self.tile_src = np.concatenate(
                [self.tile_src, np.full((self.n_parts, pad),
                                        self.n_src_tiles - 1, np.int32)], 1)
            for key, tiles in list(self._tiles.items()):
                ident = tile_pad_identity(key[0], np.dtype(key[2]))
                self._tiles[key] = np.concatenate(
                    [tiles, np.full((self.n_parts, pad, TM, TN), ident,
                                    tiles.dtype)], 1)
            self.t_max = new_t
            grew = True
        if need_b > self.b_max:
            new_b = max(self.b_max, self.policy.bucket(need_b))
            pad = new_b - self.b_max
            self.bwin = np.concatenate(
                [self.bwin, np.full((self.n_parts, pad),
                                    self.n_windows - 1, np.int32)], 1)
            self.ldst = np.concatenate(
                [self.ldst, np.zeros((self.n_parts, pad * self.block_edges),
                                     np.int32)], 1)
            self.b_max = new_b
            grew = True
        return grew

    def rebuild_partitions(self, pg, parts: Iterable[int]) -> None:
        """Incrementally refresh the layout after a delta patched ``parts``
        (stream/delta.py): grow the bucketed caps if any patched partition
        overflows them, rebuild only the touched partitions' geometry, and
        re-realize only their rows of every cached tile realization. The
        capacities are grow-only, so untouched partitions' rows are valid
        as-is."""
        parts = sorted(set(int(p) for p in parts))
        need_t = need_b = 0
        for p in parts:
            t, b = self._partition_caps(pg, p)
            need_t, need_b = max(need_t, t), max(need_b, b)
        self._grow_caps(need_t, need_b)
        for p in parts:
            self._build_partition(pg, p)
        for key in self._tiles:
            self._realize_tiles(pg, key, parts)
        self._device.clear()
        self._shard_geom.clear()    # caps persist (grow-only) in _shard_caps

    def sync_capacity(self, pg) -> bool:
        """Column-grow the per-edge arrays after ``e_max`` growth (``v_max``
        growth moves the tile/window grid and needs a full rebuild — then
        this returns False). Geometry content is untouched: new columns are
        padding until ``rebuild_partitions`` fills them."""
        if self.n_parts != pg.n_parts or self.v_max != pg.v_max:
            return False
        if pg.e_max > self.e_max:
            pad = pg.e_max - self.e_max

            def grow(a, fill):
                return np.concatenate(
                    [a, np.full((self.n_parts, pad), fill, a.dtype)], 1)

            self.edge_tile = grow(self.edge_tile, -1)
            self.edge_r = grow(self.edge_r, 0)
            self.edge_c = grow(self.edge_c, 0)
            self.eslot = grow(self.eslot, -1)
            self.e_max = pg.e_max
            self._device.clear()
            self._shard_geom.clear()
        return self.e_max == pg.e_max

    def matches(self, pg) -> bool:
        """False when the graph's padded shapes moved under us (bucket
        growth, compaction): the tile/window grid is derived from ``v_max``,
        so the whole geometry must be rebuilt."""
        return (self.n_parts == pg.n_parts and self.v_max == pg.v_max
                and self.e_max == pg.e_max)


def build_edge_layouts(pg, policy,
                       block_edges: int = DEFAULT_BLOCK_EDGES) -> EdgeLayouts:
    """Full build for all partitions of ``pg`` (assembly time / first use);
    capacities land on ``policy`` buckets so in-bucket streaming growth
    never changes a compiled runner's input shapes."""
    P, v_max, e_max = pg.n_parts, pg.v_max, pg.e_max
    lay = EdgeLayouts(
        n_parts=P, v_max=v_max, e_max=e_max, t_max=0, b_max=0,
        block_edges=int(block_edges), policy=policy,
        tile_dst=np.zeros((P, 0), np.int32),
        tile_src=np.zeros((P, 0), np.int32),
        n_tiles=np.zeros(P, np.int64),
        edge_tile=np.full((P, e_max), -1, np.int32),
        edge_r=np.zeros((P, e_max), np.int32),
        edge_c=np.zeros((P, e_max), np.int32),
        eslot=np.full((P, e_max), -1, np.int32),
        ldst=np.zeros((P, 0), np.int32),
        bwin=np.zeros((P, 0), np.int32),
        n_blocks=np.zeros(P, np.int64),
    )
    need_t = need_b = 1
    for p in range(P):
        t, b = lay._partition_caps(pg, p)
        need_t, need_b = max(need_t, t), max(need_b, b)
    lay._grow_caps(need_t, need_b)
    for p in range(P):
        lay._build_partition(pg, p)
    return lay
