"""Graph partitioners (paper §6).

Vertex-cut (edge-partitioning) assigns *edges* to partitions:
  - ``random_hash_vertex_cut``  — RH: hash the (canonical) edge key.
  - ``cdbh_vertex_cut``         — Canonical Degree-Based Hashing, the paper's
    default: hash the endpoint with the *smaller full degree*, after sorting
    the endpoint pair by id so (u,v) and (v,u) co-locate (§6.3).
  - ``grid_vertex_cut``         — 2D/grid constrained vertex-cut (beyond-paper
    option; bounds replication factor by 2*sqrt(P)-1).

Edge-cut (vertex-partitioning) assigns *vertices* to partitions; an edge is
stored with its source's partition and remote endpoints become ghosts:
  - ``random_hash_edge_cut``    — the DRONE-EC baseline (paper §8; PARMETIS is
    out of scope and could not partition WebBase in the paper either).
  - ``greedy_edge_cut``         — LDG-style greedy streaming edge-cut, a
    stronger-than-hash baseline standing in for METIS-quality cuts on the
    small graphs where the paper used PARMETIS.

All functions are pure in (graph, n_parts, seed): the elasticity story
(DESIGN.md §7) depends on deterministic re-partitioning.

The hash partitioners are layered over *chunk-reusable pure routing
functions* (``route_edges_*``) that take raw endpoint arrays — no ``Graph``
object. The streaming subsystem (repro.stream) routes edge chunks and delta
batches through exactly these functions, which is what makes out-of-core
ingestion and incremental re-routing bit-identical to the one-shot path.
``STREAM_ROUTERS`` lists the streamable partitioners: most are pure per-edge
(chunkable) functions; the ``"ebv"`` entry is a ``StatefulRouterSpec`` — a
load-aware stateful-streaming router (repro.partition.ebv) whose placement
depends on every previously routed edge and whose state travels with the
``StreamContext``. ``greedy_edge_cut`` is stateful-streaming without a
context protocol and stays one-shot-only.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graph import Graph, splitmix64

__all__ = [
    "random_hash_vertex_cut", "cdbh_vertex_cut", "grid_vertex_cut",
    "random_hash_edge_cut", "greedy_edge_cut", "PARTITIONERS",
    "route_edges_rh_vc", "route_edges_cdbh", "route_edges_grid",
    "route_edges_range", "route_edges_rh_ec", "route_vertices_rh",
    "STREAM_ROUTERS", "StatefulRouterSpec", "is_stateful_router",
]


def _canonical(src: np.ndarray, dst: np.ndarray):
    lo = np.minimum(src, dst)
    hi = np.maximum(src, dst)
    return lo, hi


# --------------------------------------------------------------------------- #
# Pure per-edge routing (chunk-reusable)
#
# Each router maps raw endpoint arrays to an int32 partition id per edge and
# is pure in (edge, n_parts, seed[, degrees]) — independent of chunking,
# ordering, and of every other edge. ``degrees`` is the FULL-graph degree
# table (only CDBH consults it; streaming ingest computes it in pass 1 and
# the delta path reuses the frozen ingest-time snapshot so patches land in
# the same partition as an identical ingest-time edge would).
# --------------------------------------------------------------------------- #
def route_edges_rh_vc(src: np.ndarray, dst: np.ndarray, n_parts: int,
                      *, seed: int = 0) -> np.ndarray:
    """RH vertex-cut: uniformly hash the canonical edge key."""
    lo, hi = _canonical(src, dst)
    key = splitmix64(lo.astype(np.uint64) * np.uint64(0x9E3779B1)
                     ^ splitmix64(hi.astype(np.uint64) + np.uint64(seed)))
    return (key % np.uint64(n_parts)).astype(np.int32)


def route_edges_cdbh(src: np.ndarray, dst: np.ndarray, degrees: np.ndarray,
                     n_parts: int, *, seed: int = 0) -> np.ndarray:
    """CDBH: hash the endpoint with the smaller full degree (canonically
    ordered pair; ties broken on id)."""
    lo, hi = _canonical(src, dst)
    dl, dh = degrees[lo], degrees[hi]
    pick_lo = (dl < dh) | ((dl == dh) & (lo <= hi))
    chosen = np.where(pick_lo, lo, hi)
    key = splitmix64(chosen.astype(np.uint64) + np.uint64(seed))
    return (key % np.uint64(n_parts)).astype(np.int32)


def route_edges_range(src: np.ndarray, dst: np.ndarray, n_vertices: int,
                      n_parts: int) -> np.ndarray:
    """Id-range block of the canonical lower endpoint."""
    lo, _ = _canonical(src, dst)
    return ((lo.astype(np.uint64) * np.uint64(n_parts))
            // np.uint64(max(n_vertices, 1))).astype(np.int32)


def route_edges_grid(src: np.ndarray, dst: np.ndarray, n_parts: int,
                     *, seed: int = 0) -> np.ndarray:
    """2D grid-constrained placement in an r x c layout with r*c == P.

    ``r`` is the largest divisor of P at most sqrt(P) (so a square P keeps
    the historical sqrt(P) x sqrt(P) grid, cell ids unchanged). The old
    non-square fold — floor(sqrt(P))^2 cells pushed through ``% P`` — was
    the identity on cell ids: partitions [q*q, P) never received an edge
    and the low ids absorbed everything. An exact rectangular factorization
    instead covers all P partitions with uniform cell weights, and keeps
    the grid property (a vertex's edges stay inside one row + one column:
    replication <= r + c - 1 partitions)."""
    r = 1
    for d in range(int(np.sqrt(n_parts)), 1, -1):
        if n_parts % d == 0:
            r = d
            break
    c = n_parts // r
    lo, hi = _canonical(src, dst)
    hu = splitmix64(lo.astype(np.uint64) + np.uint64(seed)) % np.uint64(r)
    hv = splitmix64(hi.astype(np.uint64) + np.uint64(seed ^ 0xABCDEF)) % np.uint64(c)
    return (hu * np.uint64(c) + hv).astype(np.int32)


def route_vertices_rh(vids: np.ndarray, n_parts: int,
                      *, seed: int = 0) -> np.ndarray:
    """RH vertex->partition hash (edge-cut placement + isolated vertices)."""
    return (splitmix64(vids.astype(np.uint64) + np.uint64(seed))
            % np.uint64(n_parts)).astype(np.int32)


def route_edges_rh_ec(src: np.ndarray, dst: np.ndarray, n_parts: int,
                      *, seed: int = 0) -> np.ndarray:
    """RH edge-cut: an edge follows its source's vertex hash (Pregel-style)."""
    del dst
    return route_vertices_rh(src, n_parts, seed=seed)


@dataclasses.dataclass(frozen=True)
class StatefulRouterSpec:
    """A *stateful-streaming* ``STREAM_ROUTERS`` entry.

    Pure entries are chunk functions; a stateful router's placement depends
    on every previously routed edge, so the entry is a factory instead:
    ``make_state(n_parts, n_vertices, seed)`` builds the mutable router
    state a ``StreamContext`` carries (``ctx.router_state``). The state
    implements ``route_adds`` / ``route_deletes`` / ``route_preview`` /
    ``grow`` / ``checkpoint`` (see repro.partition.ebv, the reference
    implementation). Membership tests (``name in STREAM_ROUTERS``) keep
    working — a stateful partitioner IS streamable, it just routes through
    its state rather than through a memoryless hash."""

    name: str
    factory_module: str      # lazy import target (avoids core <-> partition
    factory_name: str        # import cycles at module-load time)

    def make_state(self, n_parts: int, n_vertices: int, seed: int = 0):
        import importlib
        fn = getattr(importlib.import_module(self.factory_module),
                     self.factory_name)
        return fn(n_parts, n_vertices, seed=seed)

    @property
    def stateful(self) -> bool:
        return True


def is_stateful_router(entry) -> bool:
    """True for ``STREAM_ROUTERS`` entries that need per-stream state."""
    return isinstance(entry, StatefulRouterSpec)


# Streamable routers under a uniform chunk signature:
#   router(src, dst, degrees, n_vertices, n_parts, seed) -> int32[chunk]
# (values may instead be a StatefulRouterSpec — see is_stateful_router)
STREAM_ROUTERS = {
    "rh-vc": lambda s, d, deg, nv, p, seed: route_edges_rh_vc(s, d, p, seed=seed),
    "cdbh": lambda s, d, deg, nv, p, seed: route_edges_cdbh(s, d, deg, p, seed=seed),
    "grid": lambda s, d, deg, nv, p, seed: route_edges_grid(s, d, p, seed=seed),
    "range": lambda s, d, deg, nv, p, seed: route_edges_range(s, d, nv, p),
    "rh-ec": lambda s, d, deg, nv, p, seed: route_edges_rh_ec(s, d, p, seed=seed),
    "ebv": StatefulRouterSpec("ebv", "repro.partition.ebv", "EBVRouterState"),
}


# --------------------------------------------------------------------------- #
# Vertex-cut partitioners: edge -> partition
# --------------------------------------------------------------------------- #
def random_hash_vertex_cut(g: Graph, n_parts: int, *, seed: int = 0) -> np.ndarray:
    """RH vertex-cut: uniformly hash the canonical edge key."""
    return route_edges_rh_vc(g.src, g.dst, n_parts, seed=seed)


def cdbh_vertex_cut(g: Graph, n_parts: int, *, seed: int = 0,
                    degrees: np.ndarray | None = None) -> np.ndarray:
    """Canonical Degree-Based Hashing (paper §6.3).

    owner(e=(u,v)) = hash(endpoint with smaller full degree) mod P, with the
    endpoint pair canonically ordered by id first, so both directions of an
    undirected edge land in the same partition. Hub endpoints are thereby
    *cut* (their edges spread by their low-degree neighbours' hashes), which
    is exactly the PowerGraph insight that makes vertex-cut win on power-law
    graphs.
    """
    if degrees is None:
        degrees = g.total_degrees()
    return route_edges_cdbh(g.src, g.dst, degrees, n_parts, seed=seed)


def range_vertex_cut(g: Graph, n_parts: int, *, seed: int = 0) -> np.ndarray:
    """Locality-preserving vertex-cut: assign an edge by the id-range block of
    its canonical lower endpoint. Preserves contiguous structure (road
    networks / meshes with locality-coherent ids), standing in for the
    locality-aware partitioners (Blogel's Voronoi, METIS) the paper compares
    with. On hashed/power-law ids it degrades to imbalanced cuts — which is
    the paper's argument for CDBH on power-law graphs."""
    del seed
    return route_edges_range(g.src, g.dst, g.n_vertices, n_parts)


def grid_vertex_cut(g: Graph, n_parts: int, *, seed: int = 0) -> np.ndarray:
    """2D grid-constrained vertex-cut (GraphBuilder/GRID style): place edge
    (u,v) in the intersection of u's row-block and v's column-block of a
    sqrt(P) x sqrt(P) layout. Bounds each vertex's replication by
    2*sqrt(P) - 1. Beyond-paper partitioning option."""
    return route_edges_grid(g.src, g.dst, n_parts, seed=seed)


# --------------------------------------------------------------------------- #
# Edge-cut partitioners: vertex -> partition, then edge follows its source
# --------------------------------------------------------------------------- #
def _edges_from_vertex_assignment(g: Graph, vpart: np.ndarray) -> np.ndarray:
    return vpart[g.src].astype(np.int32)


def random_hash_edge_cut(g: Graph, n_parts: int, *, seed: int = 0) -> np.ndarray:
    """DRONE-EC-RH baseline: hash vertices to partitions; each edge is stored
    in its source's partition (Pregel-style placement)."""
    return route_edges_rh_ec(g.src, g.dst, n_parts, seed=seed)


def greedy_edge_cut(g: Graph, n_parts: int, *, seed: int = 0,
                    n_chunks: int = 64) -> np.ndarray:
    """Linear Deterministic Greedy (LDG) streaming edge-cut, chunked for
    vectorization: assign vertex chunks to the partition maximizing
    |neighbours already in partition| * (1 - |P_i|/capacity)."""
    rng = np.random.default_rng(seed)
    order = rng.permutation(g.n_vertices)
    vpart = np.full(g.n_vertices, -1, dtype=np.int32)
    sizes = np.zeros(n_parts, dtype=np.int64)
    cap = g.n_vertices / n_parts * 1.1 + 1
    # adjacency in CSR for neighbour counting
    und = np.concatenate([np.stack([g.src, g.dst], 1),
                          np.stack([g.dst, g.src], 1)], 0)
    sort = np.argsort(und[:, 0], kind="stable")
    und = und[sort]
    starts = np.searchsorted(und[:, 0], np.arange(g.n_vertices + 1))
    for chunk in np.array_split(order, min(n_chunks, len(order))):
        for v in chunk:
            nbrs = und[starts[v]:starts[v + 1], 1]
            np_parts = vpart[nbrs]
            np_parts = np_parts[np_parts >= 0]
            if np_parts.size:
                counts = np.bincount(np_parts, minlength=n_parts)
            else:
                counts = np.zeros(n_parts)
            score = counts * np.maximum(1.0 - sizes / cap, 0.0)
            best = int(np.argmax(score + rng.random(n_parts) * 1e-9))
            vpart[v] = best
            sizes[best] += 1
    return _edges_from_vertex_assignment(g, vpart)


def _ebv_vertex_cut(g: Graph, n_parts: int, *, seed: int = 0) -> np.ndarray:
    """EBV one-shot entry (lazy import: repro.partition builds on core)."""
    from repro.partition.ebv import ebv_vertex_cut
    return ebv_vertex_cut(g, n_parts, seed=seed)


PARTITIONERS = {
    "rh-vc": random_hash_vertex_cut,
    "cdbh": cdbh_vertex_cut,
    "grid": grid_vertex_cut,
    "range": range_vertex_cut,
    "rh-ec": random_hash_edge_cut,
    "greedy-ec": greedy_edge_cut,
    "ebv": _ebv_vertex_cut,
}
