from repro.core.api import DeviceSubgraph, SemiringSweep, VertexProgram
from repro.core.engine import (EdgeCombine, EngineConfig, make_bsp_runner,
                               make_sim_runner, normalize_edge_backend,
                               resolve_edge_backend,
                               resolve_partition_backends, run,
                               run_sim, run_shard_map)
from repro.core.layouts import EdgeLayouts, TileBlock, WindowBlock
from repro.core.graph import Graph
from repro.core.metrics import ExecutionStats, PartitionMetrics, partition_metrics
from repro.core.partition import (PARTITIONERS, STREAM_ROUTERS,
                                  cdbh_vertex_cut, greedy_edge_cut,
                                  grid_vertex_cut, random_hash_edge_cut,
                                  random_hash_vertex_cut)
from repro.core.subgraph import (PartitionedGraph, ShapePolicy,
                                 assemble_partitioned_graph,
                                 build_partitioned_graph, frontier_election,
                                 recompute_frontier, repack_partitions)

__all__ = [
    "DeviceSubgraph", "SemiringSweep", "VertexProgram", "EdgeCombine",
    "EngineConfig", "run",
    "run_sim", "run_shard_map", "make_bsp_runner", "make_sim_runner",
    "resolve_edge_backend", "normalize_edge_backend",
    "resolve_partition_backends", "EdgeLayouts", "TileBlock", "WindowBlock",
    "Graph", "ExecutionStats", "PartitionMetrics",
    "partition_metrics", "PARTITIONERS", "STREAM_ROUTERS", "cdbh_vertex_cut",
    "greedy_edge_cut", "grid_vertex_cut", "random_hash_edge_cut",
    "random_hash_vertex_cut", "PartitionedGraph", "ShapePolicy",
    "build_partitioned_graph",
    "assemble_partitioned_graph", "frontier_election", "recompute_frontier",
    "repack_partitions", "partition_and_build",
]


def partition_and_build(g: Graph, n_parts: int, partitioner: str = "cdbh",
                        *, seed: int = 0, pad_multiple: int = 8):
    """One-call preprocessing: partition edges + build device arrays.

    Low-level layer: pairs with the one-shot ``run``/``run_sim``/
    ``run_shard_map``. For serving (resident device graph, cached compiled
    runners, streaming updates) open a ``repro.session.GraphSession`` —
    ``GraphSession.from_graph`` is this call plus a session."""
    part = PARTITIONERS[partitioner](g, n_parts, seed=seed)
    return build_partitioned_graph(g, part, n_parts, pad_multiple=pad_multiple)
