"""Subgraph Boundary Synchronization (paper §4.3) — TPU-native.

The paper's SBS routes (key,value) pairs mirror->master, Aggregates with a
user combiner, then Disseminates master->mirrors. On a TPU mesh this entire
protocol *is* an all-reduce with that combiner over a dense frontier-slot
vector (DESIGN.md §2): the reduction tree takes the role of the master (the
paper itself notes masters are "randomly elected ... aggregation workload is
evenly distributed", i.e. a balanced reduction).

Two exchange contexts share one scatter/gather implementation:

  - ``SimExchange``    — single-process simulator: the per-partition buffers
    are stacked on a leading P axis and reduced with jnp over axis 0.
  - ``ShardExchange``  — shard_map backend: each partition holds its own
    buffer; the reduce is ``jax.lax.psum/pmin/pmax`` over the subgraph mesh
    axes (pod, data).

A sparse compacted exchange (``compact_exchange``) is provided as the
beyond-paper optimization for frontier-sparse supersteps: the changed slots
are compacted to the top-C (idx, val) pairs and all-gathered, cutting
collective bytes when #changed << n_slots (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp

__all__ = ["scatter_combine", "gather_merged", "SimExchange", "ShardExchange",
           "compact_allgather_exchange"]


def scatter_combine(out, slot, vmask, n_slots: int, combiner: str, identity):
    """[v_max, K] contributions -> [n_slots + 1, K] partition-local buffer.

    Row ``n_slots`` is the dump row for non-frontier vertices.
    """
    k = out.shape[-1]
    mask = vmask[:, None]
    contrib = jnp.where(mask, out, identity)
    buf = jnp.full((n_slots + 1, k), identity, dtype=out.dtype)
    if combiner == "min":
        return buf.at[slot].min(contrib, mode="drop")
    if combiner == "max":
        return buf.at[slot].max(contrib, mode="drop")
    if combiner == "sum":
        return buf.at[slot].add(contrib, mode="drop")
    raise ValueError(combiner)


def gather_merged(buf, slot):
    """[n_slots + 1, K] merged buffer -> [v_max, K] per-vertex view
    (identity-valued dump row lands on non-frontier vertices)."""
    return buf[slot]


@dataclasses.dataclass(frozen=True)
class SimExchange:
    """Reduce stacked buffers [P, n_slots+1, K] over axis 0."""

    def all_combine(self, bufs: jnp.ndarray, combiner: str) -> jnp.ndarray:
        if combiner == "min":
            return jnp.min(bufs, axis=0)
        if combiner == "max":
            return jnp.max(bufs, axis=0)
        if combiner == "sum":
            return jnp.sum(bufs, axis=0)
        raise ValueError(combiner)

    def all_sum_scalar(self, x):
        return jnp.sum(x, axis=0)


@dataclasses.dataclass(frozen=True)
class ShardExchange:
    """lax collectives over the subgraph mesh axes (inside shard_map)."""

    axis_names: Sequence[str]

    def all_combine(self, buf: jnp.ndarray, combiner: str) -> jnp.ndarray:
        ax = tuple(self.axis_names)
        if combiner == "min":
            return jax.lax.pmin(buf, ax)
        if combiner == "max":
            return jax.lax.pmax(buf, ax)
        if combiner == "sum":
            return jax.lax.psum(buf, ax)
        raise ValueError(combiner)

    def all_sum_scalar(self, x):
        return jax.lax.psum(x, tuple(self.axis_names))


# --------------------------------------------------------------------------- #
# Beyond-paper: compacted sparse exchange
# --------------------------------------------------------------------------- #
@partial(jax.jit, static_argnames=("capacity", "combiner", "n_slots"))
def _compact_local(buf, changed_slots_mask, *, capacity: int, combiner: str,
                   n_slots: int):
    """Select up to ``capacity`` changed slots into (idx, val) pairs."""
    scores = changed_slots_mask.astype(jnp.int32)
    idx = jnp.argsort(-scores)[:capacity]
    valid = scores[idx] > 0
    idx = jnp.where(valid, idx, n_slots)
    return idx.astype(jnp.int32), buf[idx]


def compact_allgather_exchange(buf, identity, combiner: str, n_slots: int,
                               capacity: int, axis_names):
    """All-gather compacted (idx, val) pairs and re-combine locally.

    Collective bytes: P * capacity * (4 + K*itemsize) instead of
    n_slots * K * itemsize * ring-factor — a win when the active frontier is
    small (late CC/SSSP supersteps). Falls back to correctness (not volume)
    when capacity < #changed is violated by the caller's capacity policy.
    """
    changed = jnp.any(buf[:-1] != identity, axis=-1)
    idx, vals = _compact_local(buf, changed, capacity=capacity,
                               combiner=combiner, n_slots=n_slots)
    all_idx = jax.lax.all_gather(idx, axis_names, tiled=True)     # [P*C]
    all_vals = jax.lax.all_gather(vals, axis_names, tiled=True)   # [P*C, K]
    merged = jnp.full_like(buf, identity)
    if combiner == "min":
        merged = merged.at[all_idx].min(all_vals, mode="drop")
    elif combiner == "max":
        merged = merged.at[all_idx].max(all_vals, mode="drop")
    else:
        merged = merged.at[all_idx].add(all_vals, mode="drop")
    merged = merged.at[n_slots].set(identity)
    return merged
