"""SVHM BSP engine (paper §4).

Executes a ``VertexProgram`` over a ``PartitionedGraph`` in bulk-synchronous
supersteps:

  superstep =  apply merged frontier data (paper: incoming messages M_i)
             → iterate local sweeps to a fixed point    ["think like a graph"]
             → emit frontier contributions ΔD_i
             → SBS combiner all-reduce (Aggregate + Disseminate, §4.3)
             → vote-to-halt when no partition changed anything and no
               messages are pending.

``mode='vc'`` bounds local iteration at one hop — the vertex-centric
(Pregel/Giraph) baseline the paper compares against. ``mode='sc'`` iterates to
the local fixed point — the subgraph-centric model. The partitioner choice
(vertex-cut vs edge-cut) is orthogonal and lives in the PartitionedGraph,
exactly the DRONE-VC / DRONE-EC split of §8.

Backends:
  - ``sim``       — single-process: [P, ...] stacked arrays, vmapped local
    phase, SBS = axis-0 reductions. Used by tests/benchmarks on CPU.
  - ``shard_map`` — production: partitions on the (pod, data) mesh axes, the
    model axis shards each partition's *edges* (hierarchical SVHM,
    DESIGN.md §2); SBS = lax.pmin/psum over (pod, data), intra-partition
    edge-combine = collectives over (model,).

This module is the **low-level one-shot layer**: ``run``/``run_sim``/
``run_shard_map`` build a fresh runner, upload the graph and execute a single
job. For serving — repeated queries, streaming updates, amortized
compilation — use ``repro.session.GraphSession``, which keeps the device
pytree resident and caches the compiled runners built by
``make_sim_runner``/``make_bsp_runner`` below.

Invariants the runner builders guarantee (sessions and tests rely on them):

  - **warm blocks are dtype-cast on entry** — ``_warm_block`` casts a
    previous global result to ``program.dtype`` and fills padded rows with
    the combiner identity *before* the array reaches either backend, so a
    caller's float64 numpy result can never leak its dtype into the
    compiled superstep loop (and force a retrace or an upcast sweep).
  - **``n_slots`` may be over-provisioned** — a runner built with
    ``n_slots >= `` the graph's actual frontier count is correct: slot rows
    in ``[actual, n_slots)`` only ever receive identity contributions
    (``scatter_combine`` routes unchanged/non-frontier vertices to identity)
    and are never gathered by a live vertex, whose sentinel row is identity
    too. ``GraphSession`` exploits this to build runners on *bucketed* slot
    capacities that survive frontier re-elections.
  - **the warm input is structural** — a runner either takes the
    ``[P, v_max, K]`` warm block (``warm_start=True``; cold starts feed the
    combiner identity) or does not take it at all; there is no silent
    dropped-argument path, so a non-monotone program's cold start is
    visible in the lowered HLO.
"""
from __future__ import annotations

import dataclasses
import os
import time
from functools import partial
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core import sbs
from repro.core.api import DeviceSubgraph, VertexProgram
from repro.core.metrics import ExecutionStats
from repro.core.subgraph import PartitionedGraph

__all__ = ["EngineConfig", "EdgeCombine", "run", "run_sim", "run_shard_map",
           "make_sim_runner", "make_bsp_runner"]


# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class EdgeCombine:
    """Merges edge-parallel partial aggregates inside a partition.

    Programs call ``ec.sum/min/max`` on any value derived from a reduction
    over the partition's edges. In the simulator this is the identity; under
    shard_map it reduces over the model axis, which shards the edge list.
    """

    axis_names: tuple = ()

    def sum(self, x):
        return jax.lax.psum(x, self.axis_names) if self.axis_names else x

    def min(self, x):
        return jax.lax.pmin(x, self.axis_names) if self.axis_names else x

    def max(self, x):
        return jax.lax.pmax(x, self.axis_names) if self.axis_names else x


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static engine configuration. Frozen so the module-level default
    instances in ``run``/``run_sim`` signatures stay shared-state-free
    (params travel as explicit arguments, never stashed on the config)."""

    mode: str = "sc"                  # 'sc' | 'vc'
    max_local_iters: int = 10_000     # straggler bound (DESIGN.md §7)
    max_supersteps: int = 100_000
    backend: str = "sim"              # 'sim' | 'shard_map'
    trace: bool = False               # python superstep loop w/ per-step stats
    sparse_sync_capacity: int = 0     # >0: compacted all-gather SBS (shard)
    shard_slots: bool = False         # shard the SBS buffer over edge_axes
    lean_frontier: bool = False       # detect changes vs last *merged* value
                                      # (no last_out buffer; suppresses
                                      # globally-dominated updates — §Perf)
    subgraph_axes: tuple = ("sub",)   # mesh axes carrying partitions
    edge_axes: tuple = ()             # mesh axes sharding edges in-partition
    checkpoint_every: int = 0         # supersteps; 0 = off (trace mode only)
    checkpoint_dir: Optional[str] = None

    _MODES = ("sc", "vc")
    _BACKENDS = ("sim", "shard_map")

    def __post_init__(self):
        """Fail at construction, not deep inside a run (a typo'd mode would
        otherwise silently degrade: anything != 'vc' iterates to the local
        fixed point)."""
        if self.mode not in self._MODES:
            raise ValueError(
                f"EngineConfig.mode={self.mode!r}: allowed values are "
                f"{self._MODES}")
        if self.backend not in self._BACKENDS:
            raise ValueError(
                f"EngineConfig.backend={self.backend!r}: allowed values are "
                f"{self._BACKENDS}")
        for name in ("subgraph_axes", "edge_axes"):
            axes = getattr(self, name)
            if isinstance(axes, str) or not all(
                    isinstance(a, str) for a in tuple(axes)):
                raise ValueError(
                    f"EngineConfig.{name}={axes!r} must be a tuple of mesh "
                    f"axis names, e.g. ('pod', 'data')")
            object.__setattr__(self, name, tuple(axes))   # lists hash too
        for name in ("max_local_iters", "max_supersteps"):
            if getattr(self, name) < 1:
                raise ValueError(f"EngineConfig.{name} must be >= 1, got "
                                 f"{getattr(self, name)}")
        for name in ("sparse_sync_capacity", "checkpoint_every"):
            if getattr(self, name) < 0:
                raise ValueError(f"EngineConfig.{name} must be >= 0, got "
                                 f"{getattr(self, name)}")

    @property
    def local_bound(self) -> int:
        return 1 if self.mode == "vc" else self.max_local_iters


# --------------------------------------------------------------------------- #
def _device_subgraph(pg: PartitionedGraph) -> DeviceSubgraph:
    """Stacked [P, ...] DeviceSubgraph pytree."""
    assert pg.n_vertices < 2**31
    vid32 = pg.gvid.astype(np.int64).copy()
    vid32[~pg.vmask] = np.iinfo(np.int32).max
    return DeviceSubgraph(
        esrc=jnp.asarray(pg.esrc), edst=jnp.asarray(pg.edst),
        ew=jnp.asarray(pg.ew), emask=jnp.asarray(pg.emask),
        slot=jnp.asarray(pg.slot), vmask=jnp.asarray(pg.vmask),
        vid32=jnp.asarray(vid32.astype(np.int32)),
        is_frontier=jnp.asarray(pg.is_frontier),
        out_deg=jnp.asarray(pg.out_deg), in_deg=jnp.asarray(pg.in_deg),
        is_master=jnp.asarray(pg.is_master),
        vlabel=None if pg.vlabel is None else jnp.asarray(pg.vlabel),
    )


def _local_phase(program: VertexProgram, sg: DeviceSubgraph, params, state,
                 merged_v, ec: EdgeCombine, bound: int, first):
    """apply incoming -> sweep to local fixed point (or one hop).

    ``first`` is True at superstep 0, where there are no incoming messages
    (paper Algorithm 1's ``if superstep = 0`` branch) and apply is skipped.
    """
    state = jax.lax.cond(
        first, lambda st: st,
        lambda st: program.apply_frontier(sg, params, st, merged_v, ec)[0],
        state)
    state, ch = program.sweep(sg, params, state, ec)

    def cond(c):
        i, _, chg = c
        return (chg > 0) & (i < bound)

    def body(c):
        i, st, _ = c
        st, chg = program.sweep(sg, params, st, ec)
        return (i + 1, st, chg)

    i, state, last_ch = jax.lax.while_loop(cond, body, (jnp.int32(1), state, ch))
    out = program.frontier_out(sg, params, state)
    return state, out, i, last_ch


def _pack(program: VertexProgram, sg: DeviceSubgraph, out, last_out,
          n_slots: int):
    changed = program.changed_mask(out, last_out) & sg.frontier
    buf = sbs.scatter_combine(out, sg.slot, changed, n_slots,
                              program.combiner, program.identity)
    return buf, changed


def _warm_block(program: VertexProgram, pg: PartitionedGraph,
                init_state) -> np.ndarray:
    """Map a previous *global* converged result [n_vertices(, K)] into the
    [P, v_max, K] per-partition local layout the backends feed to
    ``program.warm_init`` — combiner identity at padded rows, cast to the
    program dtype on entry (a float64 result array must not leak its dtype
    into the superstep loop). Shorter arrays (the graph grew since the run)
    are padded with the identity: new vertices start cold."""
    K = program.payload
    ident = program.identity
    dt = np.dtype(program.dtype)
    warm = np.asarray(init_state)
    if warm.ndim == 1:
        warm = warm[:, None]
    warm = warm.astype(dt, copy=False)
    if warm.shape[0] < pg.n_vertices:      # graph grew since the run
        warm = np.concatenate(
            [warm, np.full((pg.n_vertices - warm.shape[0], warm.shape[1]),
                           ident, dtype=dt)])
    wv = np.full((pg.n_parts, pg.v_max, K), ident, dtype=dt)
    wv[pg.vmask] = warm[pg.gvid[pg.vmask]]
    return wv


def _exchange_bytes_per_step(cfg: EngineConfig, n_slots: int, K: int,
                             dtype, n_parts: int, n_edge_shards: int) -> int:
    """Collective bytes one superstep's SBS exchange moves — matching the
    exchange variant the runner actually lowered, so sparse-vs-dense
    benchmark comparisons measure real volume. Counts the inter-partition
    (subgraph-axes) collective only: intra-partition edge-axis combines
    (sweep reductions, the sharded merged-view rebuild) are excluded
    everywhere, like the paper's network-message metric."""
    itemsize = np.dtype(dtype).itemsize
    if cfg.shard_slots and n_edge_shards > 1:
        # each of the n_edge_shards slot slices is all-reduced over the
        # subgraph axes: n_loc + 1 rows (incl. the dump row) per device,
        # n_parts * n_edge_shards devices
        n_loc = -(-(n_slots + 1) // n_edge_shards)
        return (n_loc + 1) * K * itemsize * n_parts * n_edge_shards
    if cfg.sparse_sync_capacity > 0:
        # compacted all-gather: capacity (int32 idx, K-vector val) pairs
        cap = min(cfg.sparse_sync_capacity, n_slots + 1)
        return cap * (4 + K * itemsize) * n_parts
    return (n_slots + 1) * K * itemsize * n_parts


# --------------------------------------------------------------------------- #
# Simulator backend
# --------------------------------------------------------------------------- #
def _make_sim_superstep(program: VertexProgram, cfg: EngineConfig,
                        n_slots: int):
    """One vmapped BSP superstep over the stacked [P, ...] pytree."""
    ident = program.identity
    ec = EdgeCombine(())
    ex = sbs.SimExchange()

    def superstep(sgs, params, state, last_out, merged_buf, first):
        merged_v = jax.vmap(lambda sg: sbs.gather_merged(merged_buf, sg.slot))(sgs)
        state, out, sweeps, last_ch = jax.vmap(
            lambda sg, st, m: _local_phase(program, sg, params, st, m, ec,
                                           cfg.local_bound, first)
        )(sgs, state, merged_v)
        bufs, changed = jax.vmap(
            lambda sg, o, lo: _pack(program, sg, o, lo, n_slots)
        )(sgs, out, last_out)
        merged_buf = ex.all_combine(bufs, program.combiner)
        merged_buf = merged_buf.at[n_slots].set(ident)
        msgs = jnp.sum(changed, dtype=jnp.int32)
        active = jnp.sum(last_ch > 0, dtype=jnp.int32)
        return state, out, merged_buf, msgs, active, sweeps

    return superstep


def make_sim_runner(program: VertexProgram, cfg: EngineConfig, n_slots: int,
                    *, warm_start=False):
    """Build the simulator BSP loop as a pure function

        runner(sgs, params[, warm_block]) ->
            (results, supersteps, total_messages, sweeps_per_part)

    ``sgs`` is the stacked [P, ...] DeviceSubgraph pytree, ``params`` the
    program's parameter pytree (traced — repeated calls with different
    params reuse one compilation), ``warm_block`` (``warm_start=True``) a
    [P, v_max, K] previous-result block threaded into ``program.warm_init``.

    ``run_sim`` calls the runner eagerly once per job; ``GraphSession``
    wraps it in ``jax.jit``, AOT-compiles it once per
    (program, config, padded shapes) key and reuses the executable across
    queries with zero retraces."""
    K = program.payload
    ident = program.identity
    ec = EdgeCombine(())
    superstep = _make_sim_superstep(program, cfg, n_slots)

    def runner(sgs, params, *warm):
        n_parts, v_max = sgs.vmask.shape
        v_init = jax.vmap(lambda sg: program.init(sg, params, ec))(sgs)
        if warm_start:
            v_init = jax.vmap(
                lambda sg, st, w: program.warm_init(sg, params, st, w)
            )(sgs, v_init, warm[0])
        last0 = jnp.full((n_parts, v_max, K), ident, dtype=program.dtype)
        merged0 = jnp.full((n_slots + 1, K), ident, dtype=program.dtype)

        def cond(c):
            step, msgs, active = c[0], c[-2], c[-1]
            return (step == 0) | (((msgs > 0) | (active > 0))
                                  & (step < cfg.max_supersteps))

        def body(c):
            step, state, last_out, merged_buf, tot_msgs, tot_sweeps, _, _ = c
            state, out, merged_buf, msgs, active, sweeps = superstep(
                sgs, params, state, last_out, merged_buf, step == 0)
            return (step + 1, state, out, merged_buf, tot_msgs + msgs,
                    tot_sweeps + sweeps, msgs, active)

        carry = (jnp.int32(0), v_init, last0, merged0, jnp.int32(0),
                 jnp.zeros((n_parts,), jnp.int32), jnp.int32(1),
                 jnp.int32(1))
        carry = jax.lax.while_loop(cond, body, carry)
        (steps, state, last_out, merged_buf, tot_msgs, tot_sweeps, *_) = carry
        results = jax.vmap(
            lambda sg, st: program.result(sg, params, st))(sgs, state)
        return results, steps, tot_msgs, tot_sweeps

    return runner


def run_sim(program: VertexProgram, pg: PartitionedGraph, params=None,
            cfg: EngineConfig = EngineConfig(), *, resume_from=None,
            init_state=None):
    """One-shot simulator job: upload ``pg``, build the runner, execute.
    (Low-level layer — ``repro.session.GraphSession`` amortizes the upload
    and the compilation across queries.)

    ``resume_from``: path to a BSP checkpoint written by a previous trace
    run (cfg.checkpoint_every) — restart mid-job (DESIGN.md §7).

    ``init_state``: global per-vertex values [n_vertices(, K)] from a
    previous *converged* run (e.g. before a stream delta was applied) — a
    warm start. Only sound for monotone programs (values tighten under the
    combiner; SSSP/MSSP/CC after edge/vertex growth): non-monotone programs
    (PageRank) silently fall back to a cold start. Shorter arrays (the graph
    grew) are padded with the combiner identity."""
    sgs = _device_subgraph(pg)
    n_slots, K = pg.n_slots, program.payload
    warm = init_state is not None and program.monotone

    stats = ExecutionStats()
    epp_host = pg.edges_per_part.astype(np.int64)
    t0 = time.perf_counter()

    if cfg.trace:
        ident = program.identity
        ec = EdgeCombine(())
        v_init = jax.vmap(lambda sg: program.init(sg, params, ec))(sgs)
        if warm:
            wv = _warm_block(program, pg, init_state)
            v_init = jax.vmap(
                lambda sg, st, w: program.warm_init(sg, params, st, w)
            )(sgs, v_init, jnp.asarray(wv))
        last0 = jnp.full((pg.n_parts, pg.v_max, K), ident,
                         dtype=program.dtype)
        merged0 = jnp.full((n_slots + 1, K), ident, dtype=program.dtype)
        start_step = 0
        if resume_from is not None:
            from repro.training.checkpoint import load_pytree
            ckpt, meta = load_pytree(
                resume_from, like=dict(state=v_init, last_out=last0,
                                       merged=merged0, step=jnp.int32(0)))
            v_init, last0, merged0 = (ckpt["state"], ckpt["last_out"],
                                      ckpt["merged"])
            start_step = int(ckpt["step"])

        superstep = _make_sim_superstep(program, cfg, n_slots)
        step_fn = jax.jit(lambda st, lo, mb, first: superstep(
            sgs, params, st, lo, mb, first))
        state, last_out, merged_buf = v_init, last0, merged0
        for step in range(start_step, cfg.max_supersteps):
            state, last_out, merged_buf, msgs, active, sweeps = step_fn(
                state, last_out, merged_buf, jnp.bool_(step == 0))
            msgs, active = int(msgs), int(active)
            stats.messages_per_step.append(msgs)
            stats.active_parts_per_step.append(active)
            stats.total_messages += msgs
            stats.processed_edges += int(
                (np.asarray(sweeps, dtype=np.int64) * epp_host).sum())
            stats.total_bytes += (n_slots + 1) * K * np.dtype(program.dtype).itemsize * pg.n_parts
            stats.supersteps = step + 1
            if cfg.checkpoint_every and (step + 1) % cfg.checkpoint_every == 0 \
                    and cfg.checkpoint_dir:
                from repro.training.checkpoint import save_pytree
                os.makedirs(cfg.checkpoint_dir, exist_ok=True)
                save_pytree(f"{cfg.checkpoint_dir}/bsp_{step + 1:06d}.npz",
                            dict(state=state, last_out=last_out,
                                 merged=merged_buf, step=step + 1))
            if msgs == 0 and active == 0:
                break
        results = jax.vmap(
            lambda sg, st: program.result(sg, params, st))(sgs, state)
    else:
        assert resume_from is None, "resume requires trace mode"
        runner = make_sim_runner(program, cfg, n_slots, warm_start=warm)
        args = (sgs, params)
        if warm:
            args += (jnp.asarray(_warm_block(program, pg, init_state)),)
        results, steps, tot_msgs, tot_sweeps = runner(*args)
        stats.supersteps = int(steps)
        stats.total_messages = int(tot_msgs)
        stats.processed_edges = int(
            (np.asarray(tot_sweeps, dtype=np.int64) * epp_host).sum())
        stats.total_bytes = stats.supersteps * (n_slots + 1) * K * \
            np.dtype(program.dtype).itemsize * pg.n_parts

    stats.wall_time = time.perf_counter() - t0
    return np.asarray(results), stats


# --------------------------------------------------------------------------- #
# shard_map backend
# --------------------------------------------------------------------------- #
def make_bsp_runner(program: VertexProgram, mesh: Mesh,
                    cfg: EngineConfig, n_slots: int, *, params=None,
                    has_vlabel=False, warm_start=False,
                    params_as_input=False):
    """Build the shard_map'd BSP loop (shared by run_shard_map, the
    graph-engine dry-run — which lowers it against ShapeDtypeStructs — and
    ``GraphSession``'s compiled-runner cache).

    ``params`` is the program's parameter pytree. By default it is closed
    over at trace time (EngineConfig is frozen and never carries it). With
    ``params_as_input=True`` it is instead a *template*: the returned runner
    takes a pytree of the same structure as its last argument, replicated
    (``P()``) across the mesh — so one compiled runner serves every
    parameter value (e.g. SSSP from any source) with zero retraces.

    ``warm_start=True`` builds the runner with an extra input: a
    [P, v_max, K] warm-state block sharded like the vertex tables, threaded
    into ``program.warm_init`` right after on-device init — the incremental
    recompute path (docs/STREAMING.md). The caller owns the soundness check
    (monotone program, insert-only delta)."""
    sub_axes = tuple(cfg.subgraph_axes)
    edge_axes = tuple(cfg.edge_axes)
    K = program.payload
    ident = program.identity
    ec = EdgeCombine(edge_axes)
    ex = sbs.ShardExchange(sub_axes)

    edge_spec = P(sub_axes, edge_axes if edge_axes else None)
    vert_spec = P(sub_axes, None)
    sg_specs = DeviceSubgraph(
        esrc=edge_spec, edst=edge_spec, ew=edge_spec, emask=edge_spec,
        slot=vert_spec, vmask=vert_spec, vid32=vert_spec,
        is_frontier=vert_spec, out_deg=vert_spec, in_deg=vert_spec,
        is_master=vert_spec,
        vlabel=vert_spec if has_vlabel else None,
    )

    def _squeeze(x):
        return None if x is None else x.reshape(x.shape[1:])

    n_edge_shards = int(np.prod([mesh.shape[a] for a in edge_axes])) \
        if edge_axes else 1
    shard_slots = cfg.shard_slots and n_edge_shards > 1
    n_loc = -(-(n_slots + 1) // n_edge_shards) if shard_slots else n_slots + 1

    def _body(sg_block, warm_block, params):
        sg = DeviceSubgraph(*[_squeeze(x) for x in sg_block])
        state = program.init(sg, params, ec)
        if warm_block is not None:
            state = program.warm_init(sg, params, state,
                                      _squeeze(warm_block))
        last0 = jnp.full((sg.v_max, K), ident, dtype=program.dtype)
        merged_v0 = jnp.full((sg.v_max, K), ident, dtype=program.dtype)

        def _exchange_dense(out, changed):
            buf = sbs.scatter_combine(out, sg.slot, changed, n_slots,
                                      program.combiner, ident)
            if cfg.sparse_sync_capacity > 0:
                merged = sbs.compact_allgather_exchange(
                    buf, ident, program.combiner, n_slots,
                    cfg.sparse_sync_capacity, sub_axes)
            else:
                merged = ex.all_combine(buf, program.combiner)
            merged = merged.at[n_slots].set(ident)
            return sbs.gather_merged(merged, sg.slot)

        def _exchange_sharded(out, changed):
            # Sharded SBS (DESIGN.md §7): frontier slots are owned by the
            # edge-axis shard slot % n_edge_shards; the (pod,data) combiner
            # all-reduce runs on the 1/n_edge_shards slot slice, and the
            # per-vertex merged view is rebuilt with an edge-axis combine —
            # O(n_slots / n_edge_shards) state per device, which is what
            # keeps the trillion-edge configuration within HBM.
            rank = jax.lax.axis_index(edge_axes)
            owned = changed & (sg.slot % n_edge_shards == rank)
            slot_loc = jnp.where(owned, sg.slot // n_edge_shards, n_loc)
            buf = sbs.scatter_combine(out, slot_loc, owned, n_loc,
                                      program.combiner, ident)
            merged = ex.all_combine(buf, program.combiner)
            gather_own = sg.frontier & (sg.slot % n_edge_shards == rank)
            mv = jnp.where(
                gather_own[:, None],
                merged[jnp.clip(sg.slot // n_edge_shards, 0, n_loc)], ident)
            if program.combiner == "min":
                return ec.min(mv)
            if program.combiner == "max":
                return ec.max(mv)
            return ec.sum(jnp.where(gather_own[:, None], mv, 0).astype(mv.dtype))

        def superstep(state, last_out, merged_v, first):
            state, out, sweeps, last_ch = _local_phase(
                program, sg, params, state, merged_v, ec, cfg.local_bound,
                first)
            ref = merged_v if cfg.lean_frontier else last_out
            changed = program.changed_mask(out, ref) & sg.frontier
            if shard_slots:
                merged_v = _exchange_sharded(out, changed)
            else:
                merged_v = _exchange_dense(out, changed)
            msgs = ex.all_sum_scalar(jnp.sum(changed, dtype=jnp.int32))
            active = ex.all_sum_scalar((last_ch > 0).astype(jnp.int32))
            return state, out, merged_v, msgs, active, sweeps

        def cond(c):
            step, msgs, active = c[0], c[-2], c[-1]
            return (step == 0) | (((msgs > 0) | (active > 0))
                                  & (step < cfg.max_supersteps))

        if cfg.lean_frontier:
            # no last_out buffer: 2 fewer [v_max, K] live values in the loop
            def body(c):
                step, state, merged_v, tm, tsw, _, _ = c
                state, _, merged_v, msgs, active, sweeps = superstep(
                    state, None, merged_v, step == 0)
                return (step + 1, state, merged_v, tm + msgs, tsw + sweeps,
                        msgs, active)

            carry = (jnp.int32(0), state, merged_v0, jnp.int32(0),
                     jnp.int32(0), jnp.int32(1), jnp.int32(1))
        else:
            def body(c):
                step, state, last_out, merged_v, tm, tsw, _, _ = c
                state, out, merged_v, msgs, active, sweeps = superstep(
                    state, last_out, merged_v, step == 0)
                return (step + 1, state, out, merged_v, tm + msgs,
                        tsw + sweeps, msgs, active)

            carry = (jnp.int32(0), state, last0, merged_v0, jnp.int32(0),
                     jnp.int32(0), jnp.int32(1), jnp.int32(1))
        steps, state, *_, tm, tsw, _, _ = jax.lax.while_loop(cond, body, carry)
        res = program.result(sg, params, state)
        return res[None], steps, tm, tsw[None]

    out_specs = (vert_spec, P(), P(), P(sub_axes))
    warm_spec = P(sub_axes, None, None)
    if params_as_input:
        pspec = jax.tree.map(lambda _: P(), params)
        if warm_start:
            @partial(shard_map, mesh=mesh,
                     in_specs=(sg_specs, warm_spec, pspec),
                     out_specs=out_specs)
            def go(sg_block, warm_block, params):
                return _body(sg_block, warm_block, params)
        else:
            @partial(shard_map, mesh=mesh, in_specs=(sg_specs, pspec),
                     out_specs=out_specs)
            def go(sg_block, params):
                return _body(sg_block, None, params)
    elif warm_start:
        @partial(shard_map, mesh=mesh, in_specs=(sg_specs, warm_spec),
                 out_specs=out_specs)
        def go(sg_block, warm_block):
            return _body(sg_block, warm_block, params)
    else:
        @partial(shard_map, mesh=mesh, in_specs=(sg_specs,),
                 out_specs=out_specs)
        def go(sg_block):
            return _body(sg_block, None, params)

    return go


def run_shard_map(program: VertexProgram, pg: PartitionedGraph, mesh: Mesh,
                  params=None, cfg: EngineConfig = EngineConfig(), *,
                  init_state=None):
    """``init_state``: global per-vertex values from a previous converged
    run, injected on-device through ``program.warm_init`` (same semantics as
    ``run_sim``: monotone programs only; non-monotone programs get an
    explicit cold start — the runner is built without the warm input, so the
    fallback is visible in the lowered program, never a silent drop)."""
    sub_axes = tuple(cfg.subgraph_axes)
    edge_axes = tuple(cfg.edge_axes)
    n_sub = int(np.prod([mesh.shape[a] for a in sub_axes]))
    n_edge = int(np.prod([mesh.shape[a] for a in edge_axes])) if edge_axes else 1
    assert pg.n_parts == n_sub, (pg.n_parts, n_sub)
    assert pg.e_max % n_edge == 0, "pad edges to a multiple of the edge axes"

    n_slots, K = pg.n_slots, program.payload
    warm = init_state is not None and program.monotone
    go = make_bsp_runner(program, mesh, cfg, n_slots, params=params,
                         has_vlabel=pg.vlabel is not None, warm_start=warm)
    sgs = _device_subgraph(pg)

    t0 = time.perf_counter()
    with mesh:
        if warm:
            wv = jnp.asarray(_warm_block(program, pg, init_state))
            res, steps, tot_msgs, sweeps_per_part = go(sgs, wv)
        else:
            res, steps, tot_msgs, sweeps_per_part = go(sgs)
    res = np.asarray(res)
    sweeps_per_part = np.asarray(sweeps_per_part, dtype=np.int64)
    stats = ExecutionStats(
        supersteps=int(steps), total_messages=int(tot_msgs),
        processed_edges=int(
            (sweeps_per_part * pg.edges_per_part.astype(np.int64)).sum()),
        total_bytes=int(steps) * _exchange_bytes_per_step(
            cfg, n_slots, K, program.dtype, pg.n_parts, n_edge),
        wall_time=time.perf_counter() - t0,
    )
    return res, stats


def run(program: VertexProgram, pg: PartitionedGraph, params=None,
        cfg: EngineConfig = EngineConfig(), mesh: Optional[Mesh] = None,
        *, init_state=None, resume_from=None):
    if cfg.backend == "sim":
        return run_sim(program, pg, params, cfg, resume_from=resume_from,
                       init_state=init_state)
    if cfg.backend != "shard_map":
        raise ValueError(f"unknown backend {cfg.backend!r}")
    if mesh is None:
        raise ValueError("shard_map backend needs a mesh")
    if resume_from is not None:
        raise NotImplementedError(
            "checkpoint resume is a trace-mode feature of the simulator "
            "backend; rerun with cfg.backend='sim' (and cfg.trace=True)")
    return run_shard_map(program, pg, mesh, params, cfg,
                         init_state=init_state)
