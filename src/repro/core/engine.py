"""SVHM BSP engine (paper §4).

Executes a ``VertexProgram`` over a ``PartitionedGraph`` in bulk-synchronous
supersteps:

  superstep =  apply merged frontier data (paper: incoming messages M_i)
             → iterate local sweeps to a fixed point    ["think like a graph"]
             → emit frontier contributions ΔD_i
             → SBS combiner all-reduce (Aggregate + Disseminate, §4.3)
             → vote-to-halt when no partition changed anything and no
               messages are pending.

``mode='vc'`` bounds local iteration at one hop — the vertex-centric
(Pregel/Giraph) baseline the paper compares against. ``mode='sc'`` iterates to
the local fixed point — the subgraph-centric model. The partitioner choice
(vertex-cut vs edge-cut) is orthogonal and lives in the PartitionedGraph,
exactly the DRONE-VC / DRONE-EC split of §8.

Backends:
  - ``sim``       — single-process: [P, ...] stacked arrays, vmapped local
    phase, SBS = axis-0 reductions. Used by tests/benchmarks on CPU.
  - ``shard_map`` — production: partitions on the (pod, data) mesh axes, the
    model axis shards each partition's *edges* (hierarchical SVHM,
    DESIGN.md §2); SBS = lax.pmin/psum over (pod, data), intra-partition
    edge-combine = collectives over (model,).

This module is the **low-level one-shot layer**: ``run``/``run_sim``/
``run_shard_map`` build a fresh runner, upload the graph and execute a single
job. For serving — repeated queries, streaming updates, amortized
compilation — use ``repro.session.GraphSession``, which keeps the device
pytree resident and caches the compiled runners built by
``make_sim_runner``/``make_bsp_runner`` below.

Invariants the runner builders guarantee (sessions and tests rely on them):

  - **warm blocks are dtype-cast on entry** — ``_warm_block`` casts a
    previous global result to ``program.dtype`` and fills padded rows with
    the combiner identity *before* the array reaches either backend, so a
    caller's float64 numpy result can never leak its dtype into the
    compiled superstep loop (and force a retrace or an upcast sweep).
  - **``n_slots`` may be over-provisioned** — a runner built with
    ``n_slots >= `` the graph's actual frontier count is correct: slot rows
    in ``[actual, n_slots)`` only ever receive identity contributions
    (``scatter_combine`` routes unchanged/non-frontier vertices to identity)
    and are never gathered by a live vertex, whose sentinel row is identity
    too. ``GraphSession`` exploits this to build runners on *bucketed* slot
    capacities that survive frontier re-elections.
  - **the warm input is structural** — a runner either takes the
    ``[P, v_max, K]`` warm block (``warm_start=True``; cold starts feed the
    combiner identity) or does not take it at all; there is no silent
    dropped-argument path, so a non-monotone program's cold start is
    visible in the lowered HLO.
"""
from __future__ import annotations

import dataclasses
import os
import time
from functools import partial
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core import sbs
from repro.core.api import DeviceSubgraph, SemiringSweep, VertexProgram
from repro.core.layouts import EdgeLayouts, TileBlock, WindowBlock
from repro.core.metrics import ExecutionStats
from repro.core.subgraph import PartitionedGraph
from repro.kernels.bsp_spmv import TM, TN, bsp_spmv
from repro.kernels.ref import combine_identity, tile_pad_identity
from repro.kernels.segment_combine import W, segment_combine_windowed

__all__ = ["EngineConfig", "EdgeCombine", "run", "run_sim", "run_shard_map",
           "make_sim_runner", "make_bsp_runner", "resolve_edge_backend",
           "normalize_edge_backend", "resolve_partition_backends"]


# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class EdgeCombine:
    """Merges edge-parallel partial aggregates inside a partition.

    Programs call ``ec.sum/min/max`` on any value derived from a reduction
    over the partition's edges. In the simulator this is the identity; under
    shard_map it reduces over the model axis, which shards the edge list.
    """

    axis_names: tuple = ()

    def sum(self, x):
        return jax.lax.psum(x, self.axis_names) if self.axis_names else x

    def min(self, x):
        return jax.lax.pmin(x, self.axis_names) if self.axis_names else x

    def max(self, x):
        return jax.lax.pmax(x, self.axis_names) if self.axis_names else x


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static engine configuration. Frozen so the module-level default
    instances in ``run``/``run_sim`` signatures stay shared-state-free
    (params travel as explicit arguments, never stashed on the config)."""

    mode: str = "sc"                  # 'sc' | 'vc'
    max_local_iters: int = 10_000     # straggler bound (DESIGN.md §7)
    max_supersteps: int = 100_000
    backend: str = "sim"              # 'sim' | 'shard_map'
    edge_backend: str = "coo"         # 'coo' | 'pallas_tiles' |
                                      # 'pallas_windows' | 'auto' — how the
                                      # local sweep's semiring product is
                                      # computed for SemiringSweep programs
                                      # (programs without a spec always run
                                      # COO). 'auto' picks per partition
                                      # from the calibrated density policy
                                      # (core/autotune.py)
    trace: bool = False               # python superstep loop w/ per-step stats
    sparse_sync_capacity: int = 0     # >0: compacted all-gather SBS (shard)
    shard_slots: bool = False         # shard the SBS buffer over edge_axes
    lean_frontier: bool = False       # detect changes vs last *merged* value
                                      # (no last_out buffer; suppresses
                                      # globally-dominated updates — §Perf)
    subgraph_axes: tuple = ("sub",)   # mesh axes carrying partitions
    edge_axes: tuple = ()             # mesh axes sharding edges in-partition
    checkpoint_every: int = 0         # supersteps; 0 = off (trace mode only)
    checkpoint_dir: Optional[str] = None

    _MODES = ("sc", "vc")
    _BACKENDS = ("sim", "shard_map")
    # backends a partition's sweep can actually execute on; 'auto' resolves
    # to one of these per partition (resolve_partition_backends)
    _CONCRETE_EDGE_BACKENDS = ("coo", "pallas_tiles", "pallas_windows")
    _EDGE_BACKENDS = _CONCRETE_EDGE_BACKENDS + ("auto",)

    def __post_init__(self):
        """Fail at construction, not deep inside a run (a typo'd mode would
        otherwise silently degrade: anything != 'vc' iterates to the local
        fixed point)."""
        if self.mode not in self._MODES:
            raise ValueError(
                f"EngineConfig.mode={self.mode!r}: allowed values are "
                f"{self._MODES}")
        if self.backend not in self._BACKENDS:
            raise ValueError(
                f"EngineConfig.backend={self.backend!r}: allowed values are "
                f"{self._BACKENDS}")
        if self.edge_backend not in self._EDGE_BACKENDS:
            raise ValueError(
                f"EngineConfig.edge_backend={self.edge_backend!r}: allowed "
                f"values are {self._EDGE_BACKENDS}")
        for name in ("subgraph_axes", "edge_axes"):
            axes = getattr(self, name)
            if isinstance(axes, str) or not all(
                    isinstance(a, str) for a in tuple(axes)):
                raise ValueError(
                    f"EngineConfig.{name}={axes!r} must be a tuple of mesh "
                    f"axis names, e.g. ('pod', 'data')")
            object.__setattr__(self, name, tuple(axes))   # lists hash too
        for name in ("max_local_iters", "max_supersteps"):
            if getattr(self, name) < 1:
                raise ValueError(f"EngineConfig.{name} must be >= 1, got "
                                 f"{getattr(self, name)}")
        for name in ("sparse_sync_capacity", "checkpoint_every"):
            if getattr(self, name) < 0:
                raise ValueError(f"EngineConfig.{name} must be >= 0, got "
                                 f"{getattr(self, name)}")

    @property
    def local_bound(self) -> int:
        return 1 if self.mode == "vc" else self.max_local_iters


# --------------------------------------------------------------------------- #
def _device_subgraph(pg: PartitionedGraph) -> DeviceSubgraph:
    """Stacked [P, ...] DeviceSubgraph pytree."""
    assert pg.n_vertices < 2**31
    vid32 = pg.gvid.astype(np.int64).copy()
    vid32[~pg.vmask] = np.iinfo(np.int32).max
    return DeviceSubgraph(
        esrc=jnp.asarray(pg.esrc), edst=jnp.asarray(pg.edst),
        ew=jnp.asarray(pg.ew), emask=jnp.asarray(pg.emask),
        slot=jnp.asarray(pg.slot), vmask=jnp.asarray(pg.vmask),
        vid32=jnp.asarray(vid32.astype(np.int32)),
        is_frontier=jnp.asarray(pg.is_frontier),
        out_deg=jnp.asarray(pg.out_deg), in_deg=jnp.asarray(pg.in_deg),
        is_master=jnp.asarray(pg.is_master),
        vlabel=None if pg.vlabel is None else jnp.asarray(pg.vlabel),
    )


# --------------------------------------------------------------------------- #
# Edge-compute backends: how a SemiringSweep program's local relaxation
# product is evaluated. 'coo' is the reference dense gather/scatter
# (api.coo_semiring_product, inside program.sweep); the Pallas backends
# route the product through the kernels in repro.kernels against the
# device layouts built by core.layouts (interpret mode off-TPU).
# --------------------------------------------------------------------------- #
def resolve_edge_backend(program: VertexProgram, cfg: EngineConfig) -> str:
    """The backend this (program, config) pair actually runs.

    Declarative ``sweep_spec`` programs run on whatever
    ``cfg.edge_backend`` asks for — the engine generates their product;
    ``'auto'`` passes through here and resolves per *partition* in
    ``resolve_partition_backends``. Programs that override ``sweep``
    declare the backends their hand-rolled code implements via the
    ``supports_edge_backends`` class attribute (today ``("coo",)`` for
    every shipped custom sweep); when the requested backend — including
    ``'auto'``, which no custom sweep can implement — is unsupported they
    fall back to the first declared one so a session can serve a mixed
    program suite under one config. A custom sweep that declares nothing
    is refused outright: silently running it on an arbitrary backend it
    ignores is exactly the bug class this resolution step exists to
    prevent."""
    declared = program.supports_edge_backends
    if declared is not None:
        allowed = EngineConfig._CONCRETE_EDGE_BACKENDS
        unknown = tuple(b for b in declared if b not in allowed)
        if unknown or not declared:
            raise ValueError(
                f"{type(program).__name__}.supports_edge_backends={declared!r}"
                f" contains unknown backends {unknown!r}; allowed values are "
                f"{allowed}")
        return cfg.edge_backend if cfg.edge_backend in declared else declared[0]
    if program.sweep_spec is not None:
        return cfg.edge_backend           # generated product: any backend
    raise ValueError(
        f"{type(program).__name__} overrides sweep but does not declare "
        "supports_edge_backends: a hand-rolled sweep must name the edge "
        "backends it implements (e.g. supports_edge_backends = ('coo',)) "
        "so the engine cannot silently route it onto a backend it ignores")


def normalize_edge_backend(program: VertexProgram,
                           cfg: EngineConfig) -> tuple:
    """``(resolved backend, config rewritten to it)`` — the ONLY sanctioned
    way to consume ``cfg.edge_backend`` outside this resolution layer
    (drone-lint DL007). Raw reads are a correctness trap: a session serving
    a custom-sweep program under a Pallas or ``'auto'`` config would key
    its runner cache and pick its argument protocol off the *requested*
    backend while the engine silently runs the *resolved* one."""
    eb = resolve_edge_backend(program, cfg)
    if eb != cfg.edge_backend:
        cfg = dataclasses.replace(cfg, edge_backend=eb)
    return eb, cfg


#: lax.switch branch ids of the shard_map mixed-backend sweep
_BACKEND_IDS = {"coo": 0, "pallas_tiles": 1, "pallas_windows": 2}


def resolve_partition_backends(program: VertexProgram, cfg: EngineConfig,
                               pg: PartitionedGraph, *, lay=None,
                               table=None) -> tuple:
    """Per-partition concrete backend assignment. Uniform (non-``'auto'``)
    configs broadcast the resolved backend; ``'auto'`` consults the
    platform's calibration table (core/autotune.py) over the partition's
    layout-geometry unit counts. Deterministic for a given (table,
    geometry) — sessions additionally pin the assignment per shape bucket
    so in-bucket growth cannot flip it."""
    eb = resolve_edge_backend(program, cfg)
    if eb != "auto":
        return (eb,) * pg.n_parts
    from repro.core import autotune
    if lay is None:
        lay = pg.ensure_edge_layouts()
    if table is None:
        table = autotune.get_table()
    return autotune.pick_backends(table, pg, lay)


def _tile_product(blk: TileBlock, vals, spec: SemiringSweep, v_max: int):
    """Semiring product via bsp_spmv for one partition ([v_max, K] vals) or
    the whole stacked graph ([P, v_max, K]): the stacked case flattens every
    partition's tile list into ONE kernel launch by offsetting the tile ids
    with ``p * n_tiles_per_partition`` — per-partition lists are dst-major
    sorted, so the concatenation is too, and each partition covers its own
    dst rows (no cross-partition accumulation is possible)."""
    ident = tile_pad_identity(spec.semiring, vals.dtype)
    if not jnp.issubdtype(vals.dtype, jnp.floating):
        # integer min_plus: pads are ADDED to values — clamp so that
        # ident + ident cannot wrap (sound below 2**30, see kernels/ref.py)
        vals = jnp.minimum(vals, ident)
    ndt = max(-(-v_max // TM), 1)
    nst = max(-(-v_max // TN), 1)
    if vals.ndim == 2:                                     # one partition
        K = vals.shape[-1]
        v = jnp.pad(vals, ((0, nst * TN - v_max), (0, 0)),
                    constant_values=ident)
        out = bsp_spmv(blk.tiles, blk.tile_dst, blk.tile_src,
                       v.reshape(nst, TN, K), n_dst_tiles=ndt,
                       semiring=spec.semiring)
        return out.reshape(ndt * TM, K)[:v_max]
    P, _, K = vals.shape                                   # stacked [P, ...]
    t_max = blk.tiles.shape[1]
    v = jnp.pad(vals, ((0, 0), (0, nst * TN - v_max), (0, 0)),
                constant_values=ident)
    offs = jnp.arange(P, dtype=jnp.int32)[:, None]
    out = bsp_spmv(blk.tiles.reshape(P * t_max, TM, TN),
                   (blk.tile_dst + offs * ndt).reshape(-1),
                   (blk.tile_src + offs * nst).reshape(-1),
                   v.reshape(P * nst, TN, K), n_dst_tiles=P * ndt,
                   semiring=spec.semiring)
    return out.reshape(P, ndt * TM, K)[:, :v_max]


def _edge_messages(spec: SemiringSweep, vals, esrc, ew):
    """Per-edge semiring messages ``vals[src] (+|*) ev`` (padding edges are
    computed too — their buffer slot is out of range and dropped)."""
    sv = jnp.take_along_axis(vals, esrc[..., None], axis=-2) \
        if vals.ndim == 3 else vals[esrc]
    if spec.edge_values == "weight":
        ev = ew.astype(vals.dtype)[..., None]
        return sv + ev if spec.semiring == "min_plus" else sv * ev
    if spec.edge_values == "zero":
        return sv if spec.semiring == "min_plus" else jnp.zeros_like(sv)
    # 'one': * 1 is the identity, but + 1 is NOT — min_plus over unit edge
    # values is hop counting (BFS levels). The COO reference and the baked
    # tile layouts (layouts._edge_values) both add the 1; returning ``sv``
    # here would make the windowed backend count every hop as free.
    return sv + jnp.asarray(1, vals.dtype) if spec.semiring == "min_plus" \
        else sv


def _window_product(blk: WindowBlock, vals, spec: SemiringSweep, v_max: int,
                    esrc, ew):
    """Semiring product via segment_combine_windowed; same one-partition /
    stacked duality (window ids offset by ``p * n_windows``)."""
    ident = combine_identity(spec.combiner, vals.dtype)
    nw = max(-(-v_max // W), 1)
    msgs = _edge_messages(spec, vals, esrc, ew)
    if vals.ndim == 2:
        K = vals.shape[-1]
        n_buf = blk.ldst.shape[-1]
        slot = jnp.where(blk.eslot >= 0, blk.eslot, n_buf)   # pad -> dropped
        buf = jnp.full((n_buf, K), ident, vals.dtype)
        buf = buf.at[slot].set(msgs, mode="drop")
        out = segment_combine_windowed(buf, blk.ldst, blk.bwin, n_windows=nw,
                                       combiner=spec.combiner)
        return out.reshape(nw * W, K)[:v_max]
    P, _, K = vals.shape
    n_buf = blk.ldst.shape[-1]
    offs = jnp.arange(P, dtype=jnp.int32)[:, None]
    slot = jnp.where(blk.eslot >= 0, blk.eslot + offs * n_buf, P * n_buf)
    buf = jnp.full((P * n_buf, K), ident, vals.dtype)
    buf = buf.at[slot.reshape(-1)].set(msgs.reshape(-1, K), mode="drop")
    out = segment_combine_windowed(
        buf, blk.ldst.reshape(-1), (blk.bwin + offs * nw).reshape(-1),
        n_windows=P * nw, combiner=spec.combiner)
    return out.reshape(P, nw * W, K)[:, :v_max]


def _make_pallas_sweep(program: VertexProgram, edge_backend: str):
    """Per-partition sweep closure for the shard_map body (and the
    superstep of ``_batched_local_phase``): pre-transform -> kernel product
    -> edge-combine -> fold, exactly the shape of the base-class COO sweep
    in api.py."""
    spec = program.sweep_spec

    def sweep(sg: DeviceSubgraph, lay_blk, params, state, ec: EdgeCombine):
        vals = program.sweep_values(sg, params, state)
        squeeze = vals.ndim == sg.vmask.ndim           # [.., v_max] -> K=1
        v = vals[..., None] if squeeze else vals
        v_max = sg.vmask.shape[-1]
        if edge_backend == "pallas_tiles":
            agg = _tile_product(lay_blk, v, spec, v_max)
        else:
            agg = _window_product(lay_blk, v, spec, v_max, sg.esrc, sg.ew)
        agg = ec.min(agg) if spec.semiring == "min_plus" else ec.sum(agg)
        if squeeze:
            agg = agg[..., 0]
        return program.sweep_fold(sg, params, state, agg)

    return sweep


def _layout_block_from(lay: EdgeLayouts, pg: PartitionedGraph,
                       program: VertexProgram, edge_backend: str,
                       n_shards: int = 1):
    """Device layout pytree a Pallas runner takes as an explicit input
    (never closed over: the arrays change under streaming, the compiled
    runner must not bake them in). ``n_shards > 1`` returns the
    edge-axis-sharded variant (per-shard tile/window lists)."""
    spec = program.sweep_spec
    if edge_backend == "pallas_tiles":
        if not jnp.issubdtype(jnp.dtype(program.dtype), jnp.floating):
            assert pg.n_vertices < 2**30, \
                ("integer min_plus through the tile kernel clamps values to "
                 "iinfo.max >> 1 (kernels/ref.py tile_pad_identity); ids "
                 "must stay below 2**30")
        if n_shards > 1:
            return lay.device_tiles_sharded(pg, spec.semiring,
                                            spec.edge_values, program.dtype,
                                            n_shards)
        return lay.device_tiles(pg, spec.semiring, spec.edge_values,
                                program.dtype)
    if n_shards > 1:
        return lay.device_windows_sharded(pg, n_shards)
    return lay.device_windows()


def _assignment_groups(assignment) -> tuple:
    """Static per-backend partition groups of an ``'auto'`` assignment:
    ``((backend, [P_g] int64 indices), ...)`` in a fixed order."""
    groups = []
    for b in EngineConfig._CONCRETE_EDGE_BACKENDS:
        idx = np.asarray([p for p, a in enumerate(assignment) if a == b],
                         np.int64)
        if idx.size:
            groups.append((b, idx))
    return tuple(groups)


def _auto_layout_blocks(lay: EdgeLayouts, pg: PartitionedGraph,
                        program: VertexProgram, assignment,
                        mixed_shard: bool = False, n_shards: int = 1):
    """Layout input of an ``'auto'`` runner.

    Simulator (``mixed_shard=False``): ``(tiles, windows)`` with each block
    group-sliced to just the partitions its backend owns (``None`` when the
    backend owns nothing) — the mixed superstep launches one kernel per
    group over its sub-stack. Cached on the layouts' device cache so
    repeated queries reuse the slices until a rebuild invalidates them.

    shard_map (``mixed_shard=True``): ``(tiles, windows, backend_ids)``
    with *full* (possibly edge-axis-sharded) blocks — every device gets
    same-shaped slices and a ``lax.switch`` on its partition's backend id
    picks the path, so one executable serves any assignment shape."""
    spec = program.sweep_spec
    if mixed_shard:
        ids = jnp.asarray([_BACKEND_IDS[b] for b in assignment], jnp.int32)
        return (_layout_block_from(lay, pg, program, "pallas_tiles",
                                   n_shards),
                _layout_block_from(lay, pg, program, "pallas_windows",
                                   n_shards), ids)
    t_idx = tuple(p for p, b in enumerate(assignment)
                  if b == "pallas_tiles")
    w_idx = tuple(p for p, b in enumerate(assignment)
                  if b == "pallas_windows")
    key = ("auto_groups", t_idx, w_idx, spec.semiring, spec.edge_values,
           np.dtype(program.dtype).str)
    blk = lay._device.get(key)
    if blk is None:
        t_blk = w_blk = None
        if t_idx:
            full = _layout_block_from(lay, pg, program, "pallas_tiles")
            t_blk = TileBlock(*[x[np.asarray(t_idx)] for x in full])
        if w_idx:
            full = lay.device_windows()
            w_blk = WindowBlock(*[x[np.asarray(w_idx)] for x in full])
        blk = (t_blk, w_blk)
        lay._device[key] = blk
    return blk


def _mixed_product(program: VertexProgram, groups, sgs, lay_blks, v):
    """Stacked [P, v_max, K] semiring product under a mixed per-partition
    backend assignment: one launch per backend group over its (static)
    partition sub-stack, scattered back into the full aggregate. Matches
    the uniform paths bit-for-bit per partition — the COO group is the
    vmapped reference product, the Pallas groups are the flattened kernel
    launches over group-sliced layout blocks."""
    from repro.core.api import coo_semiring_product
    spec = program.sweep_spec
    t_blk, w_blk = lay_blks
    v_max = sgs.vmask.shape[-1]
    agg = jnp.zeros(v.shape, v.dtype)       # every row overwritten below
    for backend, gidx in groups:
        if backend == "coo":
            sub = jax.tree.map(lambda a: a[gidx], sgs)
            part = jax.vmap(
                lambda sg, vv: coo_semiring_product(sg, spec, vv)
            )(sub, v[gidx])
        elif backend == "pallas_tiles":
            part = _tile_product(t_blk, v[gidx], spec, v_max)
        else:
            part = _window_product(w_blk, v[gidx], spec, v_max,
                                   sgs.esrc[gidx], sgs.ew[gidx])
        agg = agg.at[jnp.asarray(gidx)].set(part)
    return agg


def _local_phase(program: VertexProgram, sg: DeviceSubgraph, params, state,
                 merged_v, ec: EdgeCombine, bound: int, first,
                 sweep_fn=None):
    """apply incoming -> sweep to local fixed point (or one hop).

    ``first`` is True at superstep 0, where there are no incoming messages
    (paper Algorithm 1's ``if superstep = 0`` branch) and apply is skipped.
    ``sweep_fn`` overrides ``program.sweep`` (Pallas edge backends).
    """
    sweep = sweep_fn if sweep_fn is not None else program.sweep
    state = jax.lax.cond(
        first, lambda st: st,
        lambda st: program.apply_frontier(sg, params, st, merged_v, ec)[0],
        state)
    state, ch = sweep(sg, params, state, ec)

    def cond(c):
        i, _, chg = c
        return (chg > 0) & (i < bound)

    def body(c):
        i, st, _ = c
        st, chg = sweep(sg, params, st, ec)
        return (i + 1, st, chg)

    i, state, last_ch = jax.lax.while_loop(cond, body, (jnp.int32(1), state, ch))
    out = program.frontier_out(sg, params, state)
    return state, out, i, last_ch


def _batched_local_phase(program: VertexProgram, sgs, lay_blk, params, state,
                         merged_v, ec: EdgeCombine, bound: int, first,
                         edge_backend: str, groups=None):
    """Stacked-graph local phase for the simulator's Pallas (and mixed
    ``'auto'``) path.

    The vmapped ``_local_phase`` cannot host a Pallas call (the batching
    rule would have to lift the kernel); instead the whole [P, ...] stack
    goes through ONE flattened kernel launch per sweep — per backend group
    under a mixed assignment — and the while loop emulates vmap-of-while
    semantics by hand: a partition whose local fixed point is reached stops
    updating (its rows are select-frozen) while the others continue —
    identical results, per-partition sweep counts, and straggler bound as
    the vmapped COO path."""
    state = jax.lax.cond(
        first, lambda st: st,
        lambda st: jax.vmap(
            lambda sg, s, m: program.apply_frontier(sg, params, s, m, ec)[0]
        )(sgs, st, merged_v), state)

    def sweep_all(st):
        vals = jax.vmap(
            lambda sg, s: program.sweep_values(sg, params, s))(sgs, st)
        squeeze = vals.ndim == 2
        v = vals[..., None] if squeeze else vals
        v_max = sgs.vmask.shape[-1]
        if edge_backend == "auto":
            agg = _mixed_product(program, groups, sgs, lay_blk, v)
        elif edge_backend == "pallas_tiles":
            agg = _tile_product(lay_blk, v, program.sweep_spec, v_max)
        else:
            agg = _window_product(lay_blk, v, program.sweep_spec, v_max,
                                  sgs.esrc, sgs.ew)
        if squeeze:
            agg = agg[..., 0]
        return jax.vmap(
            lambda sg, s, a: program.sweep_fold(sg, params, s, a)
        )(sgs, st, agg)

    state, ch = sweep_all(state)
    n_parts = sgs.vmask.shape[0]
    i0 = jnp.ones((n_parts,), jnp.int32)

    def cond(c):
        i, _, chg = c
        return jnp.any((chg > 0) & (i < bound))

    def body(c):
        i, st, chg = c
        live = (chg > 0) & (i < bound)
        st2, ch2 = sweep_all(st)
        st = jax.tree.map(
            lambda a, b: jnp.where(live.reshape((-1,) + (1,) * (b.ndim - 1)),
                                   b, a), st, st2)
        return (jnp.where(live, i + 1, i), st, jnp.where(live, ch2, chg))

    i, state, last_ch = jax.lax.while_loop(cond, body, (i0, state, ch))
    out = jax.vmap(
        lambda sg, s: program.frontier_out(sg, params, s))(sgs, state)
    return state, out, i, last_ch


def _pack(program: VertexProgram, sg: DeviceSubgraph, out, last_out,
          n_slots: int):
    changed = program.changed_mask(out, last_out) & sg.frontier
    buf = sbs.scatter_combine(out, sg.slot, changed, n_slots,
                              program.combiner, program.identity)
    return buf, changed


def _warm_block(program: VertexProgram, pg: PartitionedGraph,
                init_state) -> np.ndarray:
    """Map a previous *global* converged result [n_vertices(, K)] into the
    [P, v_max, K] per-partition local layout the backends feed to
    ``program.warm_init`` — combiner identity at padded rows, cast to the
    program dtype on entry (a float64 result array must not leak its dtype
    into the superstep loop). Shorter arrays (the graph grew since the run)
    are padded with the identity: new vertices start cold."""
    K = program.payload
    ident = program.identity
    dt = np.dtype(program.dtype)
    warm = np.asarray(init_state)
    if warm.ndim == 1:
        warm = warm[:, None]
    warm = warm.astype(dt, copy=False)
    if warm.shape[0] < pg.n_vertices:      # graph grew since the run
        warm = np.concatenate(
            [warm, np.full((pg.n_vertices - warm.shape[0], warm.shape[1]),
                           ident, dtype=dt)])
    wv = np.full((pg.n_parts, pg.v_max, K), ident, dtype=dt)
    wv[pg.vmask] = warm[pg.gvid[pg.vmask]]
    return wv


def _exchange_bytes_per_step(cfg: EngineConfig, n_slots: int, K: int,
                             dtype, n_parts: int, n_edge_shards: int) -> int:
    """Collective bytes one superstep's SBS exchange moves — matching the
    exchange variant the runner actually lowered, so sparse-vs-dense
    benchmark comparisons measure real volume. Counts the inter-partition
    (subgraph-axes) collective only: intra-partition edge-axis combines
    (sweep reductions, the sharded merged-view rebuild) are excluded
    everywhere, like the paper's network-message metric."""
    itemsize = np.dtype(dtype).itemsize
    if cfg.shard_slots and n_edge_shards > 1:
        # each of the n_edge_shards slot slices is all-reduced over the
        # subgraph axes: n_loc + 1 rows (incl. the dump row) per device,
        # n_parts * n_edge_shards devices
        n_loc = -(-(n_slots + 1) // n_edge_shards)
        return (n_loc + 1) * K * itemsize * n_parts * n_edge_shards
    if cfg.sparse_sync_capacity > 0:
        # compacted all-gather: capacity (int32 idx, K-vector val) pairs
        cap = min(cfg.sparse_sync_capacity, n_slots + 1)
        return cap * (4 + K * itemsize) * n_parts
    return (n_slots + 1) * K * itemsize * n_parts


def _flops_per_sweep(program: VertexProgram, edge_backend: str,
                     pg: PartitionedGraph,
                     lay: Optional[EdgeLayouts], assignment=None,
                     n_edge_shards: int = 1) -> np.ndarray:
    """[P] semiring ops one local sweep issues per partition, for
    ``ExecutionStats.backend_flops``: the COO path pays one combine + one
    reduce per resident edge per payload lane; the Pallas backends pay for
    the dense tiles/blocks they actually launch (identity padding included —
    that is the density tax the stats make visible). Under ``'auto'`` each
    partition is billed at its *assigned* backend's rate."""
    K = program.payload
    coo = 2 * K * pg.edges_per_part.astype(np.int64)
    if edge_backend == "coo" or lay is None:
        return coo
    if edge_backend == "auto":
        out = coo.copy()
        asg = np.asarray(assignment)
        for b in ("pallas_tiles", "pallas_windows"):
            m = asg == b
            if m.any():
                out[m] = lay.flops_per_sweep(
                    b, K, n_shards=n_edge_shards, pg=pg)[m]
        return out
    return lay.flops_per_sweep(edge_backend, K, n_shards=n_edge_shards,
                               pg=pg)


# --------------------------------------------------------------------------- #
# Simulator backend
# --------------------------------------------------------------------------- #
def _make_sim_superstep(program: VertexProgram, cfg: EngineConfig,
                        n_slots: int, edge_backend: str = "coo",
                        assignment=None):
    """One BSP superstep over the stacked [P, ...] pytree: vmapped local
    phase on the COO backend, one flattened Pallas launch per sweep on the
    kernel backends (per backend group under a mixed ``'auto'``
    ``assignment``). ``lay`` is the device layout pytree (None for COO)."""
    ident = program.identity
    ec = EdgeCombine(())
    ex = sbs.SimExchange()
    groups = _assignment_groups(assignment) if edge_backend == "auto" \
        else None

    def superstep(sgs, lay, params, state, last_out, merged_buf, first):
        merged_v = jax.vmap(lambda sg: sbs.gather_merged(merged_buf, sg.slot))(sgs)
        if edge_backend == "coo":
            state, out, sweeps, last_ch = jax.vmap(
                lambda sg, st, m: _local_phase(program, sg, params, st, m, ec,
                                               cfg.local_bound, first)
            )(sgs, state, merged_v)
        else:
            state, out, sweeps, last_ch = _batched_local_phase(
                program, sgs, lay, params, state, merged_v, ec,
                cfg.local_bound, first, edge_backend, groups)
        bufs, changed = jax.vmap(
            lambda sg, o, lo: _pack(program, sg, o, lo, n_slots)
        )(sgs, out, last_out)
        merged_buf = ex.all_combine(bufs, program.combiner)
        merged_buf = merged_buf.at[n_slots].set(ident)
        msgs = jnp.sum(changed, dtype=jnp.int32)
        active = jnp.sum(last_ch > 0, dtype=jnp.int32)
        return state, out, merged_buf, msgs, active, sweeps

    return superstep


def make_sim_runner(program: VertexProgram, cfg: EngineConfig, n_slots: int,
                    *, warm_start=False, batch=False,
                    partition_backends=None):
    """Build the simulator BSP loop as a pure function

        runner(sgs[, lay], params[, warm_block]) ->
            (results, supersteps, total_messages, sweeps_per_part)

    ``sgs`` is the stacked [P, ...] DeviceSubgraph pytree, ``params`` the
    program's parameter pytree (traced — repeated calls with different
    params reuse one compilation), ``warm_block`` (``warm_start=True``) a
    [P, v_max, K] previous-result block threaded into ``program.warm_init``.

    ``batch=True`` builds the cross-request micro-batching variant
    (serving/batcher.py): every params leaf — and the warm block — carries
    a leading batch axis B, the graph (and layout) inputs stay shared, and
    ONE launch returns per-lane ``(results[B], steps[B], msgs[B],
    sweeps[B, P])``. The COO path vmaps the whole BSP loop over the lanes
    (vmap-of-while: a converged lane's carry is select-frozen while the
    rest run on, so per-lane math is identical to a singleton run); the
    Pallas backends cannot ride vmap's lifting of ``pallas_call``, so they
    scan the lanes sequentially inside the same single launch instead —
    same executable-count and dispatch amortization, no lane parallelism.

    When ``resolve_edge_backend(program, cfg)`` picks a Pallas backend the
    runner takes the device layout pytree (``TileBlock``/``WindowBlock``,
    built by ``_layout_block_from``) as its second argument — an explicit
    input,
    not a closure, so a serving session's compiled executable keeps working
    as the layouts evolve under streaming. Under ``'auto'`` the caller must
    pass the per-partition ``partition_backends`` assignment
    (``resolve_partition_backends``) and the layout argument becomes the
    group-sliced ``(tiles, windows)`` pair of ``_auto_layout_blocks``.

    ``run_sim`` calls the runner eagerly once per job; ``GraphSession``
    wraps it in ``jax.jit``, AOT-compiles it once per
    (program, config, padded shapes) key and reuses the executable across
    queries with zero retraces."""
    K = program.payload
    ident = program.identity
    ec = EdgeCombine(())
    edge_backend = resolve_edge_backend(program, cfg)
    if edge_backend == "auto" and partition_backends is None:
        raise ValueError("edge_backend='auto' runners need the resolved "
                         "partition_backends assignment "
                         "(resolve_partition_backends)")
    superstep = _make_sim_superstep(program, cfg, n_slots, edge_backend,
                                    partition_backends)

    def _run(sgs, lay, params, warm):
        n_parts, v_max = sgs.vmask.shape
        v_init = jax.vmap(lambda sg: program.init(sg, params, ec))(sgs)
        if warm_start:
            v_init = jax.vmap(
                lambda sg, st, w: program.warm_init(sg, params, st, w)
            )(sgs, v_init, warm[0])
        last0 = jnp.full((n_parts, v_max, K), ident, dtype=program.dtype)
        merged0 = jnp.full((n_slots + 1, K), ident, dtype=program.dtype)

        def cond(c):
            step, msgs, active = c[0], c[-2], c[-1]
            return (step == 0) | (((msgs > 0) | (active > 0))
                                  & (step < cfg.max_supersteps))

        def body(c):
            step, state, last_out, merged_buf, tot_msgs, tot_sweeps, _, _ = c
            state, out, merged_buf, msgs, active, sweeps = superstep(
                sgs, lay, params, state, last_out, merged_buf, step == 0)
            return (step + 1, state, out, merged_buf, tot_msgs + msgs,
                    tot_sweeps + sweeps, msgs, active)

        carry = (jnp.int32(0), v_init, last0, merged0, jnp.int32(0),
                 jnp.zeros((n_parts,), jnp.int32), jnp.int32(1),
                 jnp.int32(1))
        carry = jax.lax.while_loop(cond, body, carry)
        (steps, state, last_out, merged_buf, tot_msgs, tot_sweeps, *_) = carry
        results = jax.vmap(
            lambda sg, st: program.result(sg, params, st))(sgs, state)
        return results, steps, tot_msgs, tot_sweeps

    if not batch:
        if edge_backend == "coo":
            def runner(sgs, params, *warm):
                return _run(sgs, None, params, warm)
        else:
            def runner(sgs, lay, params, *warm):
                return _run(sgs, lay, params, warm)
        return runner

    if edge_backend == "coo":
        def runner(sgs, params, *warm):
            return jax.vmap(lambda p, w: _run(sgs, None, p, w),
                            in_axes=(0, 0))(params, warm)
    else:
        def runner(sgs, lay, params, *warm):
            def step(c, x):
                p, w = x
                return c, _run(sgs, lay, p, w)
            _, out = jax.lax.scan(step, jnp.int32(0), (params, warm))
            return out

    return runner


def run_sim(program: VertexProgram, pg: PartitionedGraph, params=None,
            cfg: EngineConfig = EngineConfig(), *, resume_from=None,
            init_state=None):
    """One-shot simulator job: upload ``pg``, build the runner, execute.
    (Low-level layer — ``repro.session.GraphSession`` amortizes the upload
    and the compilation across queries.)

    ``resume_from``: path to a BSP checkpoint written by a previous trace
    run (cfg.checkpoint_every) — restart mid-job (DESIGN.md §7).

    ``init_state``: global per-vertex values [n_vertices(, K)] from a
    previous *converged* run (e.g. before a stream delta was applied) — a
    warm start. Only sound for monotone programs (values tighten under the
    combiner; SSSP/MSSP/CC after edge/vertex growth): non-monotone programs
    (PageRank) silently fall back to a cold start. Shorter arrays (the graph
    grew) are padded with the combiner identity."""
    sgs = _device_subgraph(pg)
    n_slots, K = pg.n_slots, program.payload
    warm = init_state is not None and program.monotone
    edge_backend = resolve_edge_backend(program, cfg)
    lay = lay_blk = assignment = None
    if edge_backend == "auto":
        lay = pg.ensure_edge_layouts()
        assignment = resolve_partition_backends(program, cfg, pg, lay=lay)
        lay_blk = _auto_layout_blocks(lay, pg, program, assignment)
    elif edge_backend != "coo":
        lay = pg.ensure_edge_layouts()
        lay_blk = _layout_block_from(lay, pg, program, edge_backend)

    stats = ExecutionStats(edge_backend=edge_backend)
    epp_host = pg.edges_per_part.astype(np.int64)
    flops_pp = _flops_per_sweep(program, edge_backend, pg, lay, assignment)
    if assignment is not None:
        stats.partition_edge_backends = list(assignment)
    if edge_backend in ("pallas_tiles", "auto"):
        spec = program.sweep_spec
        stats.tile_density = lay.density(pg, spec.semiring, spec.edge_values,
                                         program.dtype)
        stats.partition_tile_density = list(lay.partition_density(
            pg, spec.semiring, spec.edge_values, program.dtype))
    t0 = time.perf_counter()

    if cfg.trace:
        ident = program.identity
        ec = EdgeCombine(())
        v_init = jax.vmap(lambda sg: program.init(sg, params, ec))(sgs)
        if warm:
            wv = _warm_block(program, pg, init_state)
            v_init = jax.vmap(
                lambda sg, st, w: program.warm_init(sg, params, st, w)
            )(sgs, v_init, jnp.asarray(wv))
        last0 = jnp.full((pg.n_parts, pg.v_max, K), ident,
                         dtype=program.dtype)
        merged0 = jnp.full((n_slots + 1, K), ident, dtype=program.dtype)
        start_step = 0
        if resume_from is not None:
            from repro.training.checkpoint import load_pytree
            ckpt, meta = load_pytree(
                resume_from, like=dict(state=v_init, last_out=last0,
                                       merged=merged0, step=jnp.int32(0)))
            v_init, last0, merged0 = (ckpt["state"], ckpt["last_out"],
                                      ckpt["merged"])
            start_step = int(ckpt["step"])

        superstep = _make_sim_superstep(program, cfg, n_slots, edge_backend,
                                        assignment)
        step_fn = jax.jit(lambda st, lo, mb, first: superstep(
            sgs, lay_blk, params, st, lo, mb, first))
        state, last_out, merged_buf = v_init, last0, merged0
        for step in range(start_step, cfg.max_supersteps):
            state, last_out, merged_buf, msgs, active, sweeps = step_fn(
                state, last_out, merged_buf, jnp.bool_(step == 0))
            msgs, active = int(msgs), int(active)
            stats.messages_per_step.append(msgs)
            stats.active_parts_per_step.append(active)
            stats.total_messages += msgs
            sweeps_h = np.asarray(sweeps, dtype=np.int64)
            stats.processed_edges += int((sweeps_h * epp_host).sum())
            stats.backend_flops += int((sweeps_h * flops_pp).sum())
            stats.total_bytes += (n_slots + 1) * K * np.dtype(program.dtype).itemsize * pg.n_parts
            stats.supersteps = step + 1
            if cfg.checkpoint_every and (step + 1) % cfg.checkpoint_every == 0 \
                    and cfg.checkpoint_dir:
                from repro.training.checkpoint import save_pytree
                os.makedirs(cfg.checkpoint_dir, exist_ok=True)
                save_pytree(f"{cfg.checkpoint_dir}/bsp_{step + 1:06d}.npz",
                            dict(state=state, last_out=last_out,
                                 merged=merged_buf, step=step + 1))
            if msgs == 0 and active == 0:
                break
        results = jax.vmap(
            lambda sg, st: program.result(sg, params, st))(sgs, state)
    else:
        assert resume_from is None, "resume requires trace mode"
        runner = make_sim_runner(program, cfg, n_slots, warm_start=warm,
                                 partition_backends=assignment)
        args = (sgs,) if edge_backend == "coo" else (sgs, lay_blk)
        args += (params,)
        if warm:
            args += (jnp.asarray(_warm_block(program, pg, init_state)),)
        results, steps, tot_msgs, tot_sweeps = runner(*args)
        stats.supersteps = int(steps)
        stats.total_messages = int(tot_msgs)
        sweeps_h = np.asarray(tot_sweeps, dtype=np.int64)
        stats.processed_edges = int((sweeps_h * epp_host).sum())
        stats.backend_flops = int((sweeps_h * flops_pp).sum())
        stats.total_bytes = stats.supersteps * (n_slots + 1) * K * \
            np.dtype(program.dtype).itemsize * pg.n_parts

    stats.wall_time = time.perf_counter() - t0
    return np.asarray(results), stats


# --------------------------------------------------------------------------- #
# shard_map backend
# --------------------------------------------------------------------------- #
def make_bsp_runner(program: VertexProgram, mesh: Mesh,
                    cfg: EngineConfig, n_slots: int, *, params=None,
                    has_vlabel=False, warm_start=False,
                    params_as_input=False, batch=False,
                    partition_backends=None):
    """Build the shard_map'd BSP loop (shared by run_shard_map, the
    graph-engine dry-run — which lowers it against ShapeDtypeStructs — and
    ``GraphSession``'s compiled-runner cache).

    ``params`` is the program's parameter pytree. By default it is closed
    over at trace time (EngineConfig is frozen and never carries it). With
    ``params_as_input=True`` it is instead a *template*: the returned runner
    takes a pytree of the same structure as its last argument, replicated
    (``P()``) across the mesh — so one compiled runner serves every
    parameter value (e.g. SSSP from any source) with zero retraces.

    ``warm_start=True`` builds the runner with an extra input: a
    [P, v_max, K] warm-state block sharded like the vertex tables, threaded
    into ``program.warm_init`` right after on-device init — the incremental
    recompute path (docs/STREAMING.md). The caller owns the soundness check
    (monotone program, insert-only delta).

    When ``resolve_edge_backend(program, cfg)`` picks a Pallas backend the
    runner takes the device layout pytree as an additional input directly
    after ``sgs`` (positional protocol: ``sgs[, layout][, warm][, params]``),
    sharded over the subgraph axes like the vertex tables. With
    ``cfg.edge_axes`` set, the tile/window lists are additionally sharded
    over the edge axes (``EdgeLayouts._sharded_geometry``): each edge shard
    runs the kernel product over its own per-shard tile/window lists and
    the ``EdgeCombine`` epilogue of the generated sweep (pmin for
    ``min_plus``, psum for ``plus_times``) reduces the partial per-vertex
    aggregates across the shards before the fold — bit-identical to the
    unsharded launch for min-combines, float-associativity-tolerant for
    sums, exactly like the COO path's sharded product. Under ``'auto'``
    (``partition_backends`` required) the layout input is
    ``(tiles, windows, backend_ids)`` with full blocks and a per-partition
    ``lax.switch`` picks the sweep — one executable serves any assignment.

    ``batch=True`` (requires ``params_as_input=True``) builds the
    micro-batching variant: the warm block (when present) and every params
    leaf carry a leading batch axis B, and the returned runner scans the
    lanes through the shard_map'd superstep loop inside one launch —
    ``lax.scan`` rather than vmap, because a vmap would have to batch
    through the shard_map collectives. Outputs gain the same leading B."""
    sub_axes = tuple(cfg.subgraph_axes)
    edge_axes = tuple(cfg.edge_axes)
    K = program.payload
    ident = program.identity
    ec = EdgeCombine(edge_axes)
    ex = sbs.ShardExchange(sub_axes)
    edge_backend = resolve_edge_backend(program, cfg)

    edge_spec = P(sub_axes, edge_axes if edge_axes else None)
    vert_spec = P(sub_axes, None)
    sg_specs = DeviceSubgraph(
        esrc=edge_spec, edst=edge_spec, ew=edge_spec, emask=edge_spec,
        slot=vert_spec, vmask=vert_spec, vid32=vert_spec,
        is_frontier=vert_spec, out_deg=vert_spec, in_deg=vert_spec,
        is_master=vert_spec,
        vlabel=vert_spec if has_vlabel else None,
    )

    def _squeeze(x):
        return None if x is None else x.reshape(x.shape[1:])

    n_edge_shards = int(np.prod([mesh.shape[a] for a in edge_axes])) \
        if edge_axes else 1
    shard_slots = cfg.shard_slots and n_edge_shards > 1
    n_loc = -(-(n_slots + 1) // n_edge_shards) if shard_slots else n_slots + 1

    # Pallas layout specs: tile/window lists shard over the edge axes like
    # the edge arrays themselves — each edge shard's slice is a standalone
    # per-shard tile/window list (EdgeLayouts._sharded_geometry), and the
    # EdgeCombine epilogue inside the generated sweep merges the partial
    # aggregates across shards. With no edge axes these reduce to the
    # replicated-within-partition specs of the unsharded launch.
    e_ax = edge_axes if edge_axes else None
    tile_specs = TileBlock(tiles=P(sub_axes, e_ax, None, None),
                           tile_dst=P(sub_axes, e_ax),
                           tile_src=P(sub_axes, e_ax))
    window_specs = WindowBlock(eslot=edge_spec, ldst=P(sub_axes, e_ax),
                               bwin=P(sub_axes, e_ax))
    lay_specs = None
    if edge_backend == "auto":
        if partition_backends is None:
            raise ValueError("edge_backend='auto' runners need the resolved "
                             "partition_backends assignment "
                             "(resolve_partition_backends)")
        lay_specs = (tile_specs, window_specs, P(sub_axes))
        tiles_sweep = _make_pallas_sweep(program, "pallas_tiles")
        windows_sweep = _make_pallas_sweep(program, "pallas_windows")
    elif edge_backend != "coo":
        lay_specs = tile_specs if edge_backend == "pallas_tiles" \
            else window_specs
        pallas_sweep = _make_pallas_sweep(program, edge_backend)

    def _body(sg_block, lay_block, warm_block, params):
        sg = DeviceSubgraph(*[_squeeze(x) for x in sg_block])
        sweep_fn = None
        if lay_block is not None and edge_backend == "auto":
            t_raw, w_raw, bid = lay_block
            t_lay = TileBlock(*[_squeeze(x) for x in t_raw])
            w_lay = WindowBlock(*[_squeeze(x) for x in w_raw])
            bid = _squeeze(bid)                      # () int32 backend id

            def sweep_fn(sg_, p_, st_, ec_):
                return jax.lax.switch(
                    bid,
                    [lambda s: program.sweep(sg_, p_, s, ec_),
                     lambda s: tiles_sweep(sg_, t_lay, p_, s, ec_),
                     lambda s: windows_sweep(sg_, w_lay, p_, s, ec_)],
                    st_)
        elif lay_block is not None:
            lay = type(lay_block)(*[_squeeze(x) for x in lay_block])
            sweep_fn = (lambda sg_, p_, st_, ec_:
                        pallas_sweep(sg_, lay, p_, st_, ec_))
        state = program.init(sg, params, ec)
        if warm_block is not None:
            state = program.warm_init(sg, params, state,
                                      _squeeze(warm_block))
        last0 = jnp.full((sg.v_max, K), ident, dtype=program.dtype)
        merged_v0 = jnp.full((sg.v_max, K), ident, dtype=program.dtype)

        def _exchange_dense(out, changed):
            buf = sbs.scatter_combine(out, sg.slot, changed, n_slots,
                                      program.combiner, ident)
            if cfg.sparse_sync_capacity > 0:
                merged = sbs.compact_allgather_exchange(
                    buf, ident, program.combiner, n_slots,
                    cfg.sparse_sync_capacity, sub_axes)
            else:
                merged = ex.all_combine(buf, program.combiner)
            merged = merged.at[n_slots].set(ident)
            return sbs.gather_merged(merged, sg.slot)

        def _exchange_sharded(out, changed):
            # Sharded SBS (DESIGN.md §7): frontier slots are owned by the
            # edge-axis shard slot % n_edge_shards; the (pod,data) combiner
            # all-reduce runs on the 1/n_edge_shards slot slice, and the
            # per-vertex merged view is rebuilt with an edge-axis combine —
            # O(n_slots / n_edge_shards) state per device, which is what
            # keeps the trillion-edge configuration within HBM.
            rank = jax.lax.axis_index(edge_axes)
            owned = changed & (sg.slot % n_edge_shards == rank)
            slot_loc = jnp.where(owned, sg.slot // n_edge_shards, n_loc)
            buf = sbs.scatter_combine(out, slot_loc, owned, n_loc,
                                      program.combiner, ident)
            merged = ex.all_combine(buf, program.combiner)
            gather_own = sg.frontier & (sg.slot % n_edge_shards == rank)
            mv = jnp.where(
                gather_own[:, None],
                merged[jnp.clip(sg.slot // n_edge_shards, 0, n_loc)], ident)
            if program.combiner == "min":
                return ec.min(mv)
            if program.combiner == "max":
                return ec.max(mv)
            return ec.sum(jnp.where(gather_own[:, None], mv, 0).astype(mv.dtype))

        def superstep(state, last_out, merged_v, first):
            state, out, sweeps, last_ch = _local_phase(
                program, sg, params, state, merged_v, ec, cfg.local_bound,
                first, sweep_fn=sweep_fn)
            ref = merged_v if cfg.lean_frontier else last_out
            changed = program.changed_mask(out, ref) & sg.frontier
            if shard_slots:
                merged_v = _exchange_sharded(out, changed)
            else:
                merged_v = _exchange_dense(out, changed)
            msgs = ex.all_sum_scalar(jnp.sum(changed, dtype=jnp.int32))
            active = ex.all_sum_scalar((last_ch > 0).astype(jnp.int32))
            return state, out, merged_v, msgs, active, sweeps

        def cond(c):
            step, msgs, active = c[0], c[-2], c[-1]
            return (step == 0) | (((msgs > 0) | (active > 0))
                                  & (step < cfg.max_supersteps))

        if cfg.lean_frontier:
            # no last_out buffer: 2 fewer [v_max, K] live values in the loop
            def body(c):
                step, state, merged_v, tm, tsw, _, _ = c
                state, _, merged_v, msgs, active, sweeps = superstep(
                    state, None, merged_v, step == 0)
                return (step + 1, state, merged_v, tm + msgs, tsw + sweeps,
                        msgs, active)

            carry = (jnp.int32(0), state, merged_v0, jnp.int32(0),
                     jnp.int32(0), jnp.int32(1), jnp.int32(1))
        else:
            def body(c):
                step, state, last_out, merged_v, tm, tsw, _, _ = c
                state, out, merged_v, msgs, active, sweeps = superstep(
                    state, last_out, merged_v, step == 0)
                return (step + 1, state, out, merged_v, tm + msgs,
                        tsw + sweeps, msgs, active)

            carry = (jnp.int32(0), state, last0, merged_v0, jnp.int32(0),
                     jnp.int32(0), jnp.int32(1), jnp.int32(1))
        steps, state, *_, tm, tsw, _, _ = jax.lax.while_loop(cond, body, carry)
        res = program.result(sg, params, state)
        return res[None], steps, tm, tsw[None]

    out_specs = (vert_spec, P(), P(), P(sub_axes))
    warm_spec = P(sub_axes, None, None)
    # positional protocol (in this order): sgs [, layout][, warm][, params]
    in_specs = [sg_specs]
    if lay_specs is not None:
        in_specs.append(lay_specs)
    if warm_start:
        in_specs.append(warm_spec)
    if params_as_input:
        in_specs.append(jax.tree.map(lambda _: P(), params))

    @partial(shard_map, mesh=mesh, in_specs=tuple(in_specs),
             out_specs=out_specs)
    def go(*args):
        it = iter(args)
        sg_block = next(it)
        lay_block = next(it) if lay_specs is not None else None
        warm_block = next(it) if warm_start else None
        p = next(it) if params_as_input else params
        return _body(sg_block, lay_block, warm_block, p)

    if not batch:
        return go

    assert params_as_input, "batch=True batches the params input"
    # positional protocol unchanged (sgs[, layout][, warm][, params]); the
    # warm block and params are the scanned ("moving") inputs, graph and
    # layout stay shared across the lanes
    n_static = 2 if lay_specs is not None else 1

    def go_batched(*args):
        static, moving = args[:n_static], tuple(args[n_static:])

        def step(c, x):
            return c, go(*static, *x)

        _, out = jax.lax.scan(step, jnp.int32(0), moving)
        return out

    return go_batched


def run_shard_map(program: VertexProgram, pg: PartitionedGraph, mesh: Mesh,
                  params=None, cfg: EngineConfig = EngineConfig(), *,
                  init_state=None):
    """``init_state``: global per-vertex values from a previous converged
    run, injected on-device through ``program.warm_init`` (same semantics as
    ``run_sim``: monotone programs only; non-monotone programs get an
    explicit cold start — the runner is built without the warm input, so the
    fallback is visible in the lowered program, never a silent drop)."""
    sub_axes = tuple(cfg.subgraph_axes)
    edge_axes = tuple(cfg.edge_axes)
    n_sub = int(np.prod([mesh.shape[a] for a in sub_axes]))
    n_edge = int(np.prod([mesh.shape[a] for a in edge_axes])) if edge_axes else 1
    assert pg.n_parts == n_sub, (pg.n_parts, n_sub)
    assert pg.e_max % n_edge == 0, "pad edges to a multiple of the edge axes"

    n_slots, K = pg.n_slots, program.payload
    warm = init_state is not None and program.monotone
    sgs = _device_subgraph(pg)
    edge_backend = resolve_edge_backend(program, cfg)
    lay = assignment = None
    args = (sgs,)
    if edge_backend == "auto":
        lay = pg.ensure_edge_layouts()
        assignment = resolve_partition_backends(program, cfg, pg, lay=lay)
        args += (_auto_layout_blocks(lay, pg, program, assignment,
                                     mixed_shard=True, n_shards=n_edge),)
    elif edge_backend != "coo":
        lay = pg.ensure_edge_layouts()
        args += (_layout_block_from(lay, pg, program, edge_backend,
                                    n_shards=n_edge),)
    go = make_bsp_runner(program, mesh, cfg, n_slots, params=params,
                         has_vlabel=pg.vlabel is not None, warm_start=warm,
                         partition_backends=assignment)

    t0 = time.perf_counter()
    with mesh:
        if warm:
            args += (jnp.asarray(_warm_block(program, pg, init_state)),)
        res, steps, tot_msgs, sweeps_per_part = go(*args)
    res = np.asarray(res)
    sweeps_per_part = np.asarray(sweeps_per_part, dtype=np.int64)
    stats = ExecutionStats(
        supersteps=int(steps), total_messages=int(tot_msgs),
        processed_edges=int(
            (sweeps_per_part * pg.edges_per_part.astype(np.int64)).sum()),
        total_bytes=int(steps) * _exchange_bytes_per_step(
            cfg, n_slots, K, program.dtype, pg.n_parts, n_edge),
        wall_time=time.perf_counter() - t0,
        edge_backend=edge_backend,
        backend_flops=int((sweeps_per_part * _flops_per_sweep(
            program, edge_backend, pg, lay, assignment,
            n_edge_shards=n_edge)).sum()),
    )
    if assignment is not None:
        stats.partition_edge_backends = list(assignment)
    if edge_backend in ("pallas_tiles", "auto"):
        spec = program.sweep_spec
        stats.tile_density = lay.density(pg, spec.semiring, spec.edge_values,
                                         program.dtype)
        stats.partition_tile_density = list(lay.partition_density(
            pg, spec.semiring, spec.edge_values, program.dtype))
    return res, stats


def run(program: VertexProgram, pg: PartitionedGraph, params=None,
        cfg: EngineConfig = EngineConfig(), mesh: Optional[Mesh] = None,
        *, init_state=None, resume_from=None):
    if cfg.backend == "sim":
        return run_sim(program, pg, params, cfg, resume_from=resume_from,
                       init_state=init_state)
    if cfg.backend != "shard_map":
        raise ValueError(f"unknown backend {cfg.backend!r}")
    if mesh is None:
        raise ValueError("shard_map backend needs a mesh")
    if resume_from is not None:
        raise NotImplementedError(
            "checkpoint resume is a trace-mode feature of the simulator "
            "backend; rerun with cfg.backend='sim' (and cfg.trace=True)")
    return run_shard_map(program, pg, mesh, params, cfg,
                         init_state=init_state)
