"""DRONE programming API (paper §5.1), adapted to JAX.

The paper exposes ``Compute(g Subgraph, M message) -> vector`` plus
``addPairToVector``/``voteToHalt``. The TPU-native equivalent is a
``VertexProgram``: a pytree-pure description of

  - how to initialize per-partition state                         (init)
  - how to consume merged frontier data at a superstep boundary   (apply_frontier)
  - one local relaxation sweep over the partition                 (sweep)
  - which per-vertex payload to contribute to SBS                 (frontier_out)

Programs whose sweep is a semiring SpMV declare it as a ``SemiringSweep``
spec plus ``sweep_values``/``sweep_fold`` transforms instead of overriding
``sweep``: the base-class ``sweep`` then runs the COO reference product
(``coo_semiring_product``), and the engine can swap in a Pallas kernel
backend (``EngineConfig.edge_backend``) without the program noticing.

The engine (engine.py) iterates ``sweep`` to a local fixed point per superstep
("think like a graph"; ``max_local_iters=1`` degrades to the vertex-centric
baseline), performs SBS with the program's combiner, counts changed
(key,value) pairs — the paper's network-message metric — and terminates when
no partition emits changes (voteToHalt + no pending messages).
"""
from __future__ import annotations

import dataclasses
from typing import Any, ClassVar, NamedTuple, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.kernels.ref import combine_identity as _combine_identity


class DeviceSubgraph(NamedTuple):
    """Per-partition device arrays (one shard; no leading P dim).

    ``v_max``/``e_max`` are padded capacities chosen by a ``ShapePolicy``
    (core/subgraph.py) — content fills a prefix, masks mark the rest. The
    engine's exchange buffer may likewise be built on an over-provisioned
    slot count >= the actual ``n_slots``: rows at and above the actual
    count (including every vertex's ``slot`` sentinel) only ever hold the
    combiner identity, which is what lets a serving session bucket all four
    padded dims without retracing on in-bucket growth.
    """
    esrc: jnp.ndarray     # [e_max] int32 local src
    edst: jnp.ndarray     # [e_max] int32 local dst (ascending)
    ew: jnp.ndarray       # [e_max] f32
    emask: jnp.ndarray    # [e_max] bool
    slot: jnp.ndarray     # [v_max] int32 frontier slot (n_slots if none)
    vmask: jnp.ndarray    # [v_max] bool
    vid32: jnp.ndarray    # [v_max] int32 global vertex id (INT32_MAX pad)
    is_frontier: jnp.ndarray  # [v_max] bool — vertex replicated elsewhere
    out_deg: jnp.ndarray  # [v_max] f32 full out-degree
    in_deg: jnp.ndarray   # [v_max] f32 full in-degree
    is_master: jnp.ndarray  # [v_max] bool
    vlabel: Optional[jnp.ndarray] = None  # [v_max] int32

    @property
    def v_max(self) -> int:
        return self.vmask.shape[-1]

    @property
    def e_max(self) -> int:
        return self.emask.shape[-1]

    @property
    def frontier(self) -> jnp.ndarray:
        """[v_max] bool — valid vertices that have an SBS slot."""
        return self.vmask & self.is_frontier

    @property
    def internal(self) -> jnp.ndarray:
        """[v_max] bool — valid vertices living only in this partition."""
        return self.vmask & ~self.is_frontier


# The engine's supported (combiner, dtype) envelope. Values delegate to the
# one generic implementation in kernels/ref.py (the kernels share it), so
# identity semantics cannot silently diverge between the COO and Pallas
# paths; the dict itself stays the strict allowlist the error message names.
COMBINER_IDENTITY = {
    (c, jnp.dtype(d)): _combine_identity(c, d)
    for c in ("min", "max", "sum")
    for d in (jnp.float32, jnp.int32)
}


def combiner_identity(combiner: str, dtype: Any) -> np.generic:
    try:
        return COMBINER_IDENTITY[(combiner, jnp.dtype(dtype))]
    except KeyError:
        supported = ", ".join(
            f"({c!r}, {d.name})" for c, d in sorted(
                COMBINER_IDENTITY, key=lambda k: (k[0], k[1].name)))
        raise ValueError(
            f"no combiner identity for (combiner={combiner!r}, "
            f"dtype={jnp.dtype(dtype).name}); supported (combiner, dtype) "
            f"pairs: {supported}") from None


@dataclasses.dataclass(frozen=True)
class SemiringSweep:
    """Declarative local-sweep spec: the partition-local relaxation is a
    semiring SpMV over the partition's adjacency (kernels/ref.py):

      min_plus    agg[d] = min_e  vals[src(e)] + ev(e)     (SSSP relax, CC
                  min-label propagation with ev = 0)
      plus_times  agg[d] = sum_e  vals[src(e)] * ev(e)     (PageRank push
                  with ev = 1; vals carry the alpha/out_deg rate)

    ``edge_values`` names the edge-value map ``ev`` declaratively
    (``'weight'`` | ``'zero'`` | ``'one'``) so edge-compute backends can
    bake it into device layouts at assembly time (core/layouts.py). The
    vertex-side pre/post transforms around the product are the program's
    ``sweep_values``/``sweep_fold`` methods.

    A program that publishes a spec (``sweep_spec``) gets its ``sweep``
    generated: the engine routes the product through the backend selected
    by ``EngineConfig.edge_backend`` — COO gather/scatter, dense Pallas
    tiles, or windowed Pallas combine — while pre/post transforms and the
    changed-count stay the program's own code. Programs whose sweep does
    not fit the shape (graph simulation's label-indexed joins, or anything
    stateful per edge) leave ``sweep_spec`` as None and override ``sweep``
    directly; they always run on the COO path.
    """

    semiring: str                    # 'min_plus' | 'plus_times'
    edge_values: str = "weight"      # 'weight' | 'zero' | 'one'

    _SEMIRINGS: ClassVar[Tuple[str, ...]] = ("min_plus", "plus_times")
    _EDGE_VALUES: ClassVar[Tuple[str, ...]] = ("weight", "zero", "one")

    def __post_init__(self) -> None:
        if self.semiring not in self._SEMIRINGS:
            raise ValueError(f"SemiringSweep.semiring={self.semiring!r}: "
                             f"allowed values are {self._SEMIRINGS}")
        if self.edge_values not in self._EDGE_VALUES:
            raise ValueError(
                f"SemiringSweep.edge_values={self.edge_values!r}: allowed "
                f"values are {self._EDGE_VALUES}")

    @property
    def combiner(self) -> str:
        """The reduce-by-destination combiner of the semiring's 'addition'."""
        return "min" if self.semiring == "min_plus" else "sum"

    def identity(self, dtype: Any) -> np.generic:
        """Absorbing element absent edges contribute (inf / int-max / 0)."""
        return combiner_identity(self.combiner, dtype)


def coo_semiring_product(sg: "DeviceSubgraph", spec: SemiringSweep,
                         vals: jnp.ndarray) -> jnp.ndarray:
    """The reference edge-compute backend: one semiring product over the
    partition's COO edge list (dense gather + segment scatter). This is
    bit-for-bit the historical hand-rolled sweep body of SSSP/CC/PageRank;
    the Pallas backends (engine.py) must match it exactly for ``min_plus``
    and to float tolerance for ``plus_times``.

    ``vals`` is [v_max] or [v_max, K]; returns an aggregate of the same
    shape (identity where a vertex has no in-edge).
    """
    ident = spec.identity(vals.dtype)
    if spec.edge_values == "weight":
        ev = sg.ew.astype(vals.dtype)
    elif spec.edge_values == "zero":
        ev = jnp.zeros_like(sg.ew, dtype=vals.dtype)
    else:
        ev = jnp.ones_like(sg.ew, dtype=vals.dtype)
    sv = vals[sg.esrc]                               # [e_max(, K)]
    if vals.ndim == 2:
        ev = ev[:, None]
        emask = sg.emask[:, None]
    else:
        emask = sg.emask
    cand = sv + ev if spec.semiring == "min_plus" else sv * ev
    cand = jnp.where(emask, cand, ident)
    agg = jnp.full(vals.shape, ident, vals.dtype)
    if spec.semiring == "min_plus":
        return agg.at[sg.edst].min(cand)
    return agg.at[sg.edst].add(cand)


@dataclasses.dataclass
class VertexProgram:
    """Base class. Subclasses implement the four methods below.

    combiner:    'min' | 'sum' | 'max' — the SBS Aggregate operator (§4.3).
    payload:     K, width of the per-vertex exchanged vector. Scalar algos
                 use K=1; graph simulation uses K=|V_Q|.
    dtype:       dtype of the exchanged payload.
    delta_based: True if frontier_out is a *delta* (sum-combined, e.g. the
                 PageRank accumulator); False if it is the value itself
                 (min/max-combined, e.g. CC labels / SSSP distances).
    tol:         significance threshold for float change detection.
    monotone:    True if per-vertex values only ever tighten under the
                 combiner (SSSP/MSSP/CC). Such programs can warm-start from a
                 previous converged result after graph growth: seeding old
                 values is always sound because extra edges can only improve
                 them further. Non-monotone programs (PageRank) must cold
                 start — the engine enforces that fallback on both backends
                 (the simulator seeds host-side; shard_map threads a sharded
                 warm block into ``warm_init`` on-device).
    value_key:   state entry holding the per-vertex values ``warm_init``
                 tightens (required when ``monotone``).
    """

    combiner: str = "min"
    payload: int = 1
    dtype: Any = jnp.float32
    delta_based: bool = False
    tol: float = 0.0
    monotone: bool = False
    value_key: Optional[str] = None

    # Which delta polarity preserves monotonicity (and therefore warm-start
    # soundness). ``'inserts'``: adding edges only tightens values (SSSP/CC/
    # BFS/LP — any deletion invalidates warm state). ``'deletes'``: removing
    # edges only tightens values (the k-core peel: edges can only disappear
    # from a vertex's support, so previously-peeled vertices stay peeled —
    # any insertion invalidates warm state). A serving session keeps a warm
    # entry across a flush only when every applied op matches the program's
    # polarity (session.py `_on_flush`); the low-level engines trust the
    # caller (`run_sim(init_state=...)` docs).
    warm_under: ClassVar[str] = "inserts"

    # Edge-compute backends this program's ``sweep`` can run on. ``None``
    # (the default) means "derive from the sweep kind": declarative
    # ``sweep_spec`` programs support every backend (the engine generates
    # their product); programs that *override* ``sweep`` must declare the
    # backends their hand-rolled code actually implements — today that is
    # ``("coo",)`` for all shipped custom sweeps — or
    # ``engine.resolve_edge_backend`` refuses to run them at all rather
    # than silently routing them onto a backend they ignore.
    supports_edge_backends: ClassVar[Optional[Tuple[str, ...]]] = None

    # -------------------------------------------------------------- #
    def init(self, sg: DeviceSubgraph, params: Any, ec: Any) -> Any:
        """Build per-partition state. ``ec`` is the EdgeCombine context for
        merging any edge-derived reductions (see engine.EdgeCombine)."""
        raise NotImplementedError

    def apply_frontier(self, sg: DeviceSubgraph, params: Any, state: Any,
                       merged: jnp.ndarray) -> Tuple[Any, jnp.ndarray]:
        """Consume merged [v_max, K] (identity at non-frontier rows).
        Returns (state, n_changed:int32)."""
        raise NotImplementedError

    # ---- local sweep: declarative spec or hand-rolled override -------- #
    # Class-level (ClassVar, not a dataclass field): the spec is part of a
    # program's *type*, like its method overrides — per-instance knobs that
    # change the traced computation belong in dataclass fields instead.
    sweep_spec: ClassVar[Optional[SemiringSweep]] = None

    def sweep_values(self, sg: DeviceSubgraph, params: Any,
                     state: Any) -> jnp.ndarray:
        """Per-vertex values entering the semiring product ([v_max] or
        [v_max, K]); only consulted when ``sweep_spec`` is set."""
        raise NotImplementedError

    def sweep_fold(self, sg: DeviceSubgraph, params: Any, state: Any,
                   agg: jnp.ndarray) -> Tuple[Any, jnp.ndarray]:
        """Fold the product's aggregate (same shape as ``sweep_values``)
        back into state. Returns (state, n_changed:int32)."""
        raise NotImplementedError

    def sweep(self, sg: DeviceSubgraph, params: Any, state: Any,
              ec: Any) -> Tuple[Any, jnp.ndarray]:
        """One local relaxation pass. Returns (state, n_changed:int32).

        Programs with a ``sweep_spec`` inherit this implementation — the
        COO reference backend; ``EngineConfig.edge_backend`` swaps the
        product for a Pallas kernel without touching the program. Programs
        without a spec override the whole method."""
        spec = self.sweep_spec
        if spec is None:
            raise NotImplementedError(
                f"{type(self).__name__} defines neither sweep_spec nor a "
                "sweep override")
        vals = self.sweep_values(sg, params, state)
        agg = coo_semiring_product(sg, spec, vals)
        agg = ec.min(agg) if spec.semiring == "min_plus" else ec.sum(agg)
        return self.sweep_fold(sg, params, state, agg)

    def frontier_out(self, sg: DeviceSubgraph, params: Any,
                     state: Any) -> jnp.ndarray:
        """Per-vertex SBS contribution [v_max, K]."""
        raise NotImplementedError

    def result(self, sg: DeviceSubgraph, params: Any,
               state: Any) -> jnp.ndarray:
        """Per-vertex output [v_max, ...] for collection from masters."""
        raise NotImplementedError

    def warm_init(self, sg: DeviceSubgraph, params: Any, state: Any,
                  warm: jnp.ndarray) -> Any:
        """Fold a previous converged result into a fresh ``init`` state
        (incremental recompute, stream/delta.py). ``warm`` is [v_max, K] in
        this partition's local layout, combiner-identity at padded rows, cast
        to the program dtype by the engine before it reaches either backend
        (host-side under ``run_sim``, inside the shard_map body under
        ``run_shard_map``). Default: tighten ``state[value_key]`` with the
        combiner — correct for any monotone value-typed program."""
        assert self.monotone and self.value_key, \
            "warm_init requires a monotone program with value_key set"
        assert self.combiner in ("min", "max"), \
            "default warm_init only knows min/max tightening; override it"
        cur = state[self.value_key]
        w = warm if cur.ndim == warm.ndim else warm[..., 0]
        op = jnp.minimum if self.combiner == "min" else jnp.maximum
        mask = sg.vmask if cur.ndim == 1 else sg.vmask[..., None]
        state = dict(state)
        state[self.value_key] = jnp.where(mask, op(cur, w.astype(cur.dtype)),
                                          cur)
        return state

    # -------------------------------------------------------------- #
    @property
    def identity(self) -> np.generic:
        return combiner_identity(self.combiner, self.dtype)

    def changed_mask(self, out: jnp.ndarray, last_out: jnp.ndarray) -> jnp.ndarray:
        """[v_max] bool — which vertices would emit a (key,value) pair."""
        if self.delta_based:
            if self.tol > 0:
                return jnp.any(jnp.abs(out) > self.tol, axis=-1)
            return jnp.any(out != 0, axis=-1)
        if self.tol > 0:
            return jnp.any(jnp.abs(out - last_out) > self.tol, axis=-1)
        return jnp.any(out != last_out, axis=-1)
