"""Subgraph construction (paper §4.1, §6.3 "construct subgraphs by received
data").

Given an edge -> partition assignment (any vertex-cut or edge-cut partitioner),
build the device-ready ``PartitionedGraph``: dense padded per-partition arrays
with *local* int32 vertex indexing, plus the frontier-slot structure that SBS
(subgraph boundary synchronization) reduces over.

Frontier vertices (replicated in >= 2 partitions) each get a global *slot* in
``[0, n_slots)``. SBS scatters local contributions into a ``[n_slots(+1)]``
buffer, all-reduces it with the algorithm's combiner across the subgraph mesh
axes, and gathers merged values back — the TPU-native realization of the
paper's master/mirror Aggregate+Disseminate (DESIGN.md §2). The paper's
master designation survives as ``is_master`` (random replica election via
hash, §4.3) and is used for result collection and the aggregation-balance
statistic.

The builder is split into composable layers so the streaming subsystem
(repro.stream) can assemble partitions from per-partition spill shards
without ever materializing the global edge list:

  - ``frontier_election``        — slots + master election from per-partition
                                   vertex membership alone (no edges);
  - ``assemble_partitioned_graph`` — fill the padded arrays, pulling each
                                   partition's edges through a loader
                                   callback (one partition resident at a
                                   time);
  - ``build_partitioned_graph``  — the classic one-shot in-memory wrapper;
  - ``recompute_frontier``       — re-derive slots/masters in place after a
                                   membership patch (stream/delta.py).

Padded capacities (``v_max``/``e_max``) are chosen by a ``ShapePolicy``.
The default everywhere in this low-level layer is the exact policy (round
the content maximum up to ``pad_multiple`` — the historical behavior, and
what the bit-identical streaming-parity tests pin). Serving sessions pass a
*bucketed* policy instead, so capacities land on a geometric bucket grid and
a growing graph changes its padded shapes O(log growth) times instead of
once per flush (docs/ARCHITECTURE.md, "shape-bucket lifecycle").
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core.graph import Graph, splitmix64
from repro.core.partition import route_vertices_rh

__all__ = ["PartitionedGraph", "ShapePolicy", "build_partitioned_graph",
           "frontier_election", "assemble_partitioned_graph",
           "partition_vertex_sets", "recompute_frontier",
           "repack_partitions", "localize_edges"]


# --------------------------------------------------------------------------- #
# Padded-shape policy
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class ShapePolicy:
    """How content sizes become padded device capacities.

    Every compiled runner is specialized to the padded shapes
    ``(P, v_max, e_max, n_slots)``, so each distinct capacity costs one
    trace+compile. ``bucket(n)`` rounds a content maximum up to the next
    value of the geometric series ``pad_multiple * growth^k`` (each bucket
    itself rounded to ``pad_multiple``): under streaming growth the
    capacities change O(log growth) times instead of once per flush — the
    jax_pallas analogue of amortizing per-partition setup cost across
    queries in subgraph-centric engines (GoFFish, arXiv:1311.5949).

    growth       geometric ratio between buckets; ``1.0`` disables
                 bucketing (``bucket`` = exact round-up to ``pad_multiple``,
                 the historical behavior — see ``ShapePolicy.exact``).
    headroom     multiplier (>= 1) applied to the content size *before*
                 bucketing, so capacity is re-chosen while there is still
                 slack rather than exactly at overflow.
    pad_multiple every capacity is a multiple of this (device tiling).
    bucket_slots also bucket the SBS slot count fed to the runners.
                 ``n_slots`` is not a host-array dimension, only the
                 compiled exchange-buffer height, and it moves on *every*
                 frontier re-election — without bucketing it is the main
                 source of shape churn. Padded slot rows only ever hold the
                 combiner identity, so over-provisioning is sound.
    """

    growth: float = 2.0
    headroom: float = 1.0
    pad_multiple: int = 8
    bucket_slots: bool = True

    def __post_init__(self):
        if self.growth < 1.0:
            raise ValueError(f"ShapePolicy.growth must be >= 1.0, got "
                             f"{self.growth}")
        if self.headroom < 1.0:
            raise ValueError(f"ShapePolicy.headroom must be >= 1.0, got "
                             f"{self.headroom}")
        if self.pad_multiple < 1:
            raise ValueError(f"ShapePolicy.pad_multiple must be >= 1, got "
                             f"{self.pad_multiple}")

    @classmethod
    def exact(cls, pad_multiple: int = 8) -> "ShapePolicy":
        """The no-bucketing legacy policy: capacities are the content
        maximum rounded up to ``pad_multiple``, slot counts are exact."""
        return cls(growth=1.0, headroom=1.0, pad_multiple=pad_multiple,
                   bucket_slots=False)

    # ------------------------------------------------------------------ #
    def _round(self, n: int) -> int:
        return int(-(-max(n, 1) // self.pad_multiple) * self.pad_multiple)

    def bucket(self, n: int) -> int:
        """Smallest admissible capacity >= ``n * headroom`` (the bucket
        floor). Compaction uses the same function, so shrink-then-regrow
        traffic inside one bucket keeps the padded shapes — and therefore
        the compiled runners — stable."""
        need = max(1, int(math.ceil(max(n, 1) * self.headroom)))
        if self.growth <= 1.0:
            return self._round(need)
        b = self.pad_multiple
        while b < need:
            b = self._round(int(math.ceil(b * self.growth)))
        return b

    def slot_capacity(self, n_slots: int) -> int:
        """Exchange-buffer slot count a runner is built with. Slots in
        ``[n_slots, capacity)`` receive only identity contributions and are
        never gathered by a live vertex, so the padding is invisible to
        results (it is all-reduced, though — bytes are billed on the padded
        height)."""
        if not self.bucket_slots or self.growth <= 1.0:
            return int(n_slots)
        return self.bucket(n_slots)


def resolve_shape_policy(shape_policy: Optional[ShapePolicy],
                         pad_multiple: int) -> ShapePolicy:
    """Default every low-level builder to the exact legacy policy; callers
    that want buckets (GraphSession) pass one explicitly. An explicit
    policy always wins: it carries its own ``pad_multiple``, and the bare
    ``pad_multiple`` parameter the builders keep for backward compatibility
    is consulted only when no policy is given."""
    if shape_policy is None:
        return ShapePolicy.exact(pad_multiple)
    return shape_policy


def _pad_to(arr: np.ndarray, n: int, fill) -> np.ndarray:
    out = np.full((n,) + arr.shape[1:], fill, dtype=arr.dtype)
    out[: arr.shape[0]] = arr
    return out


def localize_edges(lv: np.ndarray, gs: np.ndarray, gd: np.ndarray, w):
    """Global-id edges -> local int32 indices against the sorted membership
    ``lv``, stably sorted by destination (segment ops expect ascending dst).
    Every writer of the dense edge arrays (assembly, delta patching,
    compaction) must go through this so the layout invariant lives in one
    place."""
    ls = np.searchsorted(lv, gs).astype(np.int32)
    ld = np.searchsorted(lv, gd).astype(np.int32)
    eo = np.argsort(ld, kind="stable")
    return ls[eo], ld[eo], np.asarray(w, dtype=np.float32)[eo]


@dataclasses.dataclass
class PartitionedGraph:
    """Dense, padded, device-ready partitioned graph.

    All ``[P, ...]`` arrays are numpy on the host; the engine moves them to
    device (full for the simulator backend, per-shard under shard_map).
    """

    n_parts: int
    n_vertices: int      # global vertex count
    n_edges: int         # global edge count (unpadded)
    n_slots: int         # number of frontier (replicated) vertices
    v_max: int           # padded per-partition vertex capacity
    e_max: int           # padded per-partition edge capacity

    gvid: np.ndarray     # [P, v_max] int64 global id per local slot (-1 pad)
    vmask: np.ndarray    # [P, v_max] bool
    esrc: np.ndarray     # [P, e_max] int32 local src index (0 where padded)
    edst: np.ndarray     # [P, e_max] int32 local dst index, sorted ascending
    ew: np.ndarray       # [P, e_max] float32 edge weight (0 where padded)
    emask: np.ndarray    # [P, e_max] bool
    slot: np.ndarray     # [P, v_max] int32 frontier slot id; n_slots if none
    is_frontier: np.ndarray  # [P, v_max] bool — vertex replicated elsewhere
    out_deg: np.ndarray  # [P, v_max] float32 FULL (global) out-degree
    in_deg: np.ndarray   # [P, v_max] float32 FULL (global) in-degree
    is_master: np.ndarray  # [P, v_max] bool

    frontier_gvid: np.ndarray  # [n_slots] int64
    edge_part: Optional[np.ndarray] = None  # [E] int32 host-side assignment
    vlabel: Optional[np.ndarray] = None     # [P, v_max] int32 (gsim labels)
    # Stacked tile/window decompositions for the Pallas edge-compute
    # backends (core/layouts.py EdgeLayouts) — built on demand via
    # ensure_edge_layouts (or eagerly at assembly), kept incrementally
    # fresh by stream/delta.py, rebuilt by repack_partitions.
    edge_layouts: Optional[object] = None

    # ------------------------------------------------------------------ #
    @property
    def edges_per_part(self) -> np.ndarray:
        return self.emask.sum(axis=1)

    @property
    def vertices_per_part(self) -> np.ndarray:
        return self.vmask.sum(axis=1)

    def device_arrays(self) -> dict:
        """The pytree the engine ships to device."""
        d = dict(esrc=self.esrc, edst=self.edst, ew=self.ew, emask=self.emask,
                 slot=self.slot, vmask=self.vmask, is_frontier=self.is_frontier,
                 out_deg=self.out_deg, in_deg=self.in_deg,
                 is_master=self.is_master)
        if self.vlabel is not None:
            d["vlabel"] = self.vlabel
        return d

    # ------------------------------------------------------------------ #
    def collect(self, values: np.ndarray, fill=0) -> np.ndarray:
        """Gather per-vertex results from master replicas into a global
        [n_vertices, ...] array (paper: masters hold the primary value)."""
        values = np.asarray(values)
        out = np.full((self.n_vertices,) + values.shape[2:], fill,
                      dtype=values.dtype)
        sel = self.vmask & self.is_master
        out[self.gvid[sel]] = values[sel]
        return out

    def ensure_edge_layouts(self, shape_policy: Optional["ShapePolicy"] = None,
                            block_edges: int = 512):
        """The ``EdgeLayouts`` for this graph, built on first use (and
        rebuilt whenever the padded shapes moved since — delta patching
        keeps an existing one fresh incrementally instead). The policy of
        the first build sticks; callers with a bucketed serving policy
        (GraphSession) pass it here before the first Pallas query."""
        from repro.core.layouts import build_edge_layouts
        lay = self.edge_layouts
        if lay is not None and lay.matches(self):
            return lay
        policy = resolve_shape_policy(
            shape_policy if lay is None or shape_policy is not None
            else lay.policy, 8)
        if lay is not None and shape_policy is None:
            block_edges = lay.block_edges
        self.edge_layouts = build_edge_layouts(self, policy, block_edges)
        return self.edge_layouts

    def set_vertex_labels(self, labels: np.ndarray) -> None:
        """Attach global per-vertex int labels (graph simulation §7.3)."""
        lab = np.zeros((self.n_parts, self.v_max), dtype=np.int32)
        lab[self.vmask] = labels[self.gvid[self.vmask]]
        self.vlabel = lab


# --------------------------------------------------------------------------- #
# Layer 1 — vertex membership (in-memory path; streaming derives its own
# membership incrementally from spill shards)
# --------------------------------------------------------------------------- #
def partition_vertex_sets(src: np.ndarray, dst: np.ndarray,
                          edge_part: np.ndarray, n_parts: int,
                          n_vertices: int, *,
                          isolated: Optional[np.ndarray] = None
                          ) -> list[np.ndarray]:
    """Per-partition sorted unique vertex ids: the endpoints of each
    partition's edges (Eq. 3), plus hash-round-robin isolated vertices."""
    pair_part = np.concatenate([edge_part, edge_part]).astype(np.int64)
    pair_vid = np.concatenate([src, dst])
    key = pair_part * np.int64(n_vertices) + pair_vid
    ukey = np.unique(key)
    up = (ukey // n_vertices).astype(np.int32)
    uv = (ukey % n_vertices).astype(np.int64)
    if isolated is not None and isolated.size:
        iso_p = route_vertices_rh(isolated, n_parts)
        up = np.concatenate([up, iso_p])
        uv = np.concatenate([uv, isolated])
        re = np.lexsort((uv, up))
        up, uv = up[re], uv[re]
    starts = np.searchsorted(up, np.arange(n_parts + 1))
    return [uv[starts[p]:starts[p + 1]] for p in range(n_parts)]


# --------------------------------------------------------------------------- #
# Layer 2 — frontier slots + master election from membership alone
# --------------------------------------------------------------------------- #
def frontier_election(part_vertices: Sequence[np.ndarray], n_vertices: int):
    """Slots and masters from per-partition vertex membership.

    Returns ``(frontier_gvid, slot_of_gvid, masters)`` where ``masters[p]``
    is a bool array aligned with ``part_vertices[p]``. The elected master of
    v is its ``hash(v) % replica_count(v)``-th replica in partition-id order
    (paper §4.3 random replica election) — a pure function of membership, so
    streaming ingest, one-shot build and delta patching all agree."""
    replica_count = np.zeros(n_vertices, dtype=np.int64)
    for lv in part_vertices:
        replica_count[lv] += 1
    frontier_gvid = np.nonzero(replica_count >= 2)[0].astype(np.int64)
    n_slots = int(frontier_gvid.shape[0])
    slot_of_gvid = np.full(n_vertices, n_slots, dtype=np.int64)
    slot_of_gvid[frontier_gvid] = np.arange(n_slots)

    pick = (splitmix64(np.arange(n_vertices, dtype=np.uint64))
            % np.maximum(replica_count, 1).astype(np.uint64)).astype(np.int64)
    seen = np.zeros(n_vertices, dtype=np.int64)   # replicas in partitions < p
    masters = []
    for lv in part_vertices:
        masters.append(seen[lv] == pick[lv])
        seen[lv] += 1
    return frontier_gvid, slot_of_gvid, masters


# --------------------------------------------------------------------------- #
# Layer 3 — padded assembly, one partition resident at a time
# --------------------------------------------------------------------------- #
def assemble_partitioned_graph(
        n_parts: int, n_vertices: int, n_edges: int,
        part_vertices: Sequence[np.ndarray],
        edge_counts: np.ndarray,
        load_edges: Callable[[int], tuple],
        out_degrees: np.ndarray, in_degrees: np.ndarray,
        *, pad_multiple: int = 8,
        shape_policy: Optional[ShapePolicy] = None,
        edge_part: Optional[np.ndarray] = None,
        build_edge_layouts: bool = False) -> PartitionedGraph:
    """Fill the dense padded arrays.

    ``load_edges(p) -> (src, dst, w)`` supplies partition p's edges in global
    ids, in their original stream order; only one partition's edge list is
    resident at a time, so callers can stream from spill shards
    (``edge_counts`` pre-sizes ``e_max`` without loading anything).

    ``shape_policy`` picks ``v_max``/``e_max`` from the content maxima;
    omitted, it is ``ShapePolicy.exact(pad_multiple)``.

    ``build_edge_layouts=True`` also assembles the Pallas edge-compute
    layouts (core/layouts.py) under the same policy — what a serving
    session that knows it will run ``edge_backend='pallas_*'`` wants;
    otherwise they are built lazily by ``ensure_edge_layouts`` on first
    use, and maintained incrementally either way.
    """
    P = n_parts
    policy = resolve_shape_policy(shape_policy, pad_multiple)
    frontier_gvid, slot_of_gvid, masters = frontier_election(
        part_vertices, n_vertices)
    n_slots = int(frontier_gvid.shape[0])

    vcounts = np.array([lv.shape[0] for lv in part_vertices], dtype=np.int64)
    v_max = policy.bucket(int(vcounts.max()) if P else 1)
    e_max = policy.bucket(int(np.max(edge_counts)) if P else 1)

    gvid = np.full((P, v_max), -1, dtype=np.int64)
    vmask = np.zeros((P, v_max), dtype=bool)
    slot = np.full((P, v_max), n_slots, dtype=np.int32)
    is_master = np.zeros((P, v_max), dtype=bool)
    out_deg = np.zeros((P, v_max), dtype=np.float32)
    in_deg = np.zeros((P, v_max), dtype=np.float32)
    esrc = np.zeros((P, e_max), dtype=np.int32)
    edst = np.zeros((P, e_max), dtype=np.int32)
    ew = np.zeros((P, e_max), dtype=np.float32)
    emask = np.zeros((P, e_max), dtype=bool)

    g_out = out_degrees.astype(np.float32)
    g_in = in_degrees.astype(np.float32)

    for p in range(P):
        lv = part_vertices[p]                        # sorted ascending
        nv = lv.shape[0]
        gvid[p, :nv] = lv
        vmask[p, :nv] = True
        slot[p, :nv] = slot_of_gvid[lv]
        is_master[p, :nv] = masters[p]
        out_deg[p, :nv] = g_out[lv]
        in_deg[p, :nv] = g_in[lv]

        es, ed, w = load_edges(p)
        ls, ld, ww = localize_edges(lv, es, ed, w)
        ne = es.shape[0]
        esrc[p, :ne] = ls
        edst[p, :ne] = ld
        ew[p, :ne] = ww
        emask[p, :ne] = True

    pg = PartitionedGraph(
        n_parts=P, n_vertices=n_vertices, n_edges=n_edges,
        n_slots=n_slots, v_max=v_max, e_max=e_max,
        gvid=gvid, vmask=vmask, esrc=esrc, edst=edst, ew=ew, emask=emask,
        slot=slot, is_frontier=(slot < n_slots) & vmask,
        out_deg=out_deg, in_deg=in_deg, is_master=is_master,
        frontier_gvid=frontier_gvid, edge_part=edge_part,
    )
    if build_edge_layouts:
        pg.ensure_edge_layouts(shape_policy=policy)
    return pg


# --------------------------------------------------------------------------- #
# One-shot in-memory wrapper (the classic path)
# --------------------------------------------------------------------------- #
def build_partitioned_graph(g: Graph, edge_part: np.ndarray, n_parts: int,
                            *, pad_multiple: int = 8,
                            shape_policy: Optional[ShapePolicy] = None,
                            include_isolated: bool = True,
                            build_edge_layouts: bool = False
                            ) -> PartitionedGraph:
    edge_part = np.asarray(edge_part, dtype=np.int32)
    assert edge_part.shape == g.src.shape

    # ---- group edges by partition -------------------------------------- #
    order = np.argsort(edge_part, kind="stable")
    ps, pd = g.src[order], g.dst[order]
    pw = g.weights[order]
    counts = np.bincount(edge_part, minlength=n_parts).astype(np.int64)
    starts = np.concatenate([[0], np.cumsum(counts)])

    iso = g.isolated_vertices() if include_isolated else None
    part_vertices = partition_vertex_sets(g.src, g.dst, edge_part, n_parts,
                                          g.n_vertices, isolated=iso)

    def load_edges(p):
        return (ps[starts[p]:starts[p + 1]], pd[starts[p]:starts[p + 1]],
                pw[starts[p]:starts[p + 1]])

    return assemble_partitioned_graph(
        n_parts, g.n_vertices, g.n_edges, part_vertices, counts, load_edges,
        g.out_degrees(), g.in_degrees(), pad_multiple=pad_multiple,
        shape_policy=shape_policy, edge_part=edge_part,
        build_edge_layouts=build_edge_layouts)


# --------------------------------------------------------------------------- #
# In-place repack at fresh capacities (stream/delta.py compaction)
# --------------------------------------------------------------------------- #
def repack_partitions(pg: PartitionedGraph,
                      part_vertices: Sequence[np.ndarray],
                      part_edges: Sequence[tuple],
                      *, pad_multiple: int = 8,
                      shape_policy: Optional[ShapePolicy] = None
                      ) -> np.ndarray:
    """Rebuild ``pg``'s dense padded arrays in place from explicit
    per-partition membership (sorted unique global ids) and edge lists
    ``(src, dst, w)`` in global ids, re-deriving ``v_max``/``e_max`` from the
    new content — capacities *shrink* when the content does, unlike the
    grow-only delta path. Under a bucketed ``shape_policy`` they shrink to
    the **bucket floor** (the smallest admissible bucket), not the exact
    minimum, so compact-then-regrow traffic inside one bucket keeps the
    padded shapes stable. Frontier slots and masters are re-elected from the
    new membership; per-vertex tables (degrees, labels) are carried through
    their global ids.

    Returns ``remap``: ``[P, old_v_max]`` int32 mapping each old local row to
    its new local row (-1 for evicted members and padding), so live
    per-partition state survives the repack.
    """
    P = pg.n_parts
    old_v_max = pg.v_max
    policy = resolve_shape_policy(shape_policy, pad_multiple)

    new_v_max = policy.bucket(
        max((lv.shape[0] for lv in part_vertices), default=1))
    new_e_max = policy.bucket(
        max((e[0].shape[0] for e in part_edges), default=1))

    # global per-vertex tables, read from the old replicas (all agree)
    sel = pg.vmask
    g_out = np.zeros(pg.n_vertices, np.float32)
    g_in = np.zeros(pg.n_vertices, np.float32)
    g_out[pg.gvid[sel]] = pg.out_deg[sel]
    g_in[pg.gvid[sel]] = pg.in_deg[sel]
    g_lab = None
    if pg.vlabel is not None:
        g_lab = np.zeros(pg.n_vertices, np.int32)
        g_lab[pg.gvid[sel]] = pg.vlabel[sel]

    remap = np.full((P, old_v_max), -1, np.int32)
    gvid = np.full((P, new_v_max), -1, np.int64)
    vmask = np.zeros((P, new_v_max), bool)
    out_deg = np.zeros((P, new_v_max), np.float32)
    in_deg = np.zeros((P, new_v_max), np.float32)
    vlabel = np.zeros((P, new_v_max), np.int32) if g_lab is not None else None
    esrc = np.zeros((P, new_e_max), np.int32)
    edst = np.zeros((P, new_e_max), np.int32)
    ew = np.zeros((P, new_e_max), np.float32)
    emask = np.zeros((P, new_e_max), bool)

    for p in range(P):
        lv = np.asarray(part_vertices[p], np.int64)
        nv = lv.shape[0]
        gvid[p, :nv] = lv
        vmask[p, :nv] = True
        out_deg[p, :nv] = g_out[lv]
        in_deg[p, :nv] = g_in[lv]
        if vlabel is not None:
            vlabel[p, :nv] = g_lab[lv]

        old_lv = pg.gvid[p][pg.vmask[p]]
        pos = np.searchsorted(lv, old_lv)
        kept = np.zeros(old_lv.shape[0], bool)
        in_range = pos < nv
        kept[in_range] = lv[pos[in_range]] == old_lv[in_range]
        remap[p, :old_lv.shape[0]] = np.where(kept, pos, -1).astype(np.int32)

        gs, gd, w = part_edges[p]
        ne = gs.shape[0]
        ls, ld, ww = localize_edges(lv, gs, gd, w)
        esrc[p, :ne] = ls
        edst[p, :ne] = ld
        ew[p, :ne] = ww
        emask[p, :ne] = True

    pg.gvid, pg.vmask = gvid, vmask
    pg.out_deg, pg.in_deg, pg.vlabel = out_deg, in_deg, vlabel
    pg.esrc, pg.edst, pg.ew, pg.emask = esrc, edst, ew, emask
    pg.v_max, pg.e_max = new_v_max, new_e_max
    pg.n_edges = int(emask.sum())
    pg.edge_part = None
    recompute_frontier(pg)
    if pg.edge_layouts is not None:
        # a repack moves the tile/window grid (v_max changed, rows moved):
        # rebuild the layouts under their own policy at assembly time, so
        # Pallas queries after a compaction see fresh geometry immediately
        old = pg.edge_layouts
        pg.edge_layouts = None
        pg.ensure_edge_layouts(shape_policy=old.policy,
                               block_edges=old.block_edges)
    return remap


# --------------------------------------------------------------------------- #
# Frontier maintenance after a membership patch (stream/delta.py)
# --------------------------------------------------------------------------- #
def recompute_frontier(pg: PartitionedGraph) -> None:
    """Re-derive ``slot``/``is_frontier``/``is_master``/``frontier_gvid``
    in place from the current ``gvid``/``vmask`` membership. Uses the same
    hash election as the builders, so an unchanged membership round-trips
    bit-identically; a patched membership gets consistent fresh slots."""
    part_vertices = [pg.gvid[p][pg.vmask[p]] for p in range(pg.n_parts)]
    frontier_gvid, slot_of_gvid, masters = frontier_election(
        part_vertices, pg.n_vertices)
    n_slots = int(frontier_gvid.shape[0])
    pg.slot = np.full((pg.n_parts, pg.v_max), n_slots, dtype=np.int32)
    pg.is_master = np.zeros((pg.n_parts, pg.v_max), dtype=bool)
    for p in range(pg.n_parts):
        nv = part_vertices[p].shape[0]
        pg.slot[p, :nv] = slot_of_gvid[part_vertices[p]]
        pg.is_master[p, :nv] = masters[p]
    pg.n_slots = n_slots
    pg.frontier_gvid = frontier_gvid
    pg.is_frontier = (pg.slot < n_slots) & pg.vmask
