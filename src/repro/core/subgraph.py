"""Subgraph construction (paper §4.1, §6.3 "construct subgraphs by received
data").

Given an edge -> partition assignment (any vertex-cut or edge-cut partitioner),
build the device-ready ``PartitionedGraph``: dense padded per-partition arrays
with *local* int32 vertex indexing, plus the frontier-slot structure that SBS
(subgraph boundary synchronization) reduces over.

Frontier vertices (replicated in >= 2 partitions) each get a global *slot* in
``[0, n_slots)``. SBS scatters local contributions into a ``[n_slots(+1)]``
buffer, all-reduces it with the algorithm's combiner across the subgraph mesh
axes, and gathers merged values back — the TPU-native realization of the
paper's master/mirror Aggregate+Disseminate (DESIGN.md §2). The paper's
master designation survives as ``is_master`` (random replica election via
hash, §4.3) and is used for result collection and the aggregation-balance
statistic.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.graph import Graph, splitmix64

__all__ = ["PartitionedGraph", "build_partitioned_graph"]


def _pad_to(arr: np.ndarray, n: int, fill) -> np.ndarray:
    out = np.full((n,) + arr.shape[1:], fill, dtype=arr.dtype)
    out[: arr.shape[0]] = arr
    return out


@dataclasses.dataclass
class PartitionedGraph:
    """Dense, padded, device-ready partitioned graph.

    All ``[P, ...]`` arrays are numpy on the host; the engine moves them to
    device (full for the simulator backend, per-shard under shard_map).
    """

    n_parts: int
    n_vertices: int      # global vertex count
    n_edges: int         # global edge count (unpadded)
    n_slots: int         # number of frontier (replicated) vertices
    v_max: int           # padded per-partition vertex capacity
    e_max: int           # padded per-partition edge capacity

    gvid: np.ndarray     # [P, v_max] int64 global id per local slot (-1 pad)
    vmask: np.ndarray    # [P, v_max] bool
    esrc: np.ndarray     # [P, e_max] int32 local src index (0 where padded)
    edst: np.ndarray     # [P, e_max] int32 local dst index, sorted ascending
    ew: np.ndarray       # [P, e_max] float32 edge weight (0 where padded)
    emask: np.ndarray    # [P, e_max] bool
    slot: np.ndarray     # [P, v_max] int32 frontier slot id; n_slots if none
    is_frontier: np.ndarray  # [P, v_max] bool — vertex replicated elsewhere
    out_deg: np.ndarray  # [P, v_max] float32 FULL (global) out-degree
    in_deg: np.ndarray   # [P, v_max] float32 FULL (global) in-degree
    is_master: np.ndarray  # [P, v_max] bool

    frontier_gvid: np.ndarray  # [n_slots] int64
    edge_part: Optional[np.ndarray] = None  # [E] int32 host-side assignment
    vlabel: Optional[np.ndarray] = None     # [P, v_max] int32 (gsim labels)

    # ------------------------------------------------------------------ #
    @property
    def edges_per_part(self) -> np.ndarray:
        return self.emask.sum(axis=1)

    @property
    def vertices_per_part(self) -> np.ndarray:
        return self.vmask.sum(axis=1)

    def device_arrays(self) -> dict:
        """The pytree the engine ships to device."""
        d = dict(esrc=self.esrc, edst=self.edst, ew=self.ew, emask=self.emask,
                 slot=self.slot, vmask=self.vmask, is_frontier=self.is_frontier,
                 out_deg=self.out_deg, in_deg=self.in_deg,
                 is_master=self.is_master)
        if self.vlabel is not None:
            d["vlabel"] = self.vlabel
        return d

    # ------------------------------------------------------------------ #
    def collect(self, values: np.ndarray, fill=0) -> np.ndarray:
        """Gather per-vertex results from master replicas into a global
        [n_vertices, ...] array (paper: masters hold the primary value)."""
        values = np.asarray(values)
        out = np.full((self.n_vertices,) + values.shape[2:], fill,
                      dtype=values.dtype)
        sel = self.vmask & self.is_master
        out[self.gvid[sel]] = values[sel]
        return out

    def set_vertex_labels(self, labels: np.ndarray) -> None:
        """Attach global per-vertex int labels (graph simulation §7.3)."""
        lab = np.zeros((self.n_parts, self.v_max), dtype=np.int32)
        lab[self.vmask] = labels[self.gvid[self.vmask]]
        self.vlabel = lab


def build_partitioned_graph(g: Graph, edge_part: np.ndarray, n_parts: int,
                            *, pad_multiple: int = 8,
                            include_isolated: bool = True) -> PartitionedGraph:
    edge_part = np.asarray(edge_part, dtype=np.int32)
    assert edge_part.shape == g.src.shape
    P = n_parts

    # ---- group edges by partition -------------------------------------- #
    order = np.argsort(edge_part, kind="stable")
    ps, pd = g.src[order], g.dst[order]
    pw = g.weights[order]
    counts = np.bincount(edge_part, minlength=P).astype(np.int64)
    starts = np.concatenate([[0], np.cumsum(counts)])

    # ---- per-partition vertex sets (endpoints of local edges) ---------- #
    pair_part = np.concatenate([edge_part, edge_part]).astype(np.int64)
    pair_vid = np.concatenate([g.src, g.dst])
    key = pair_part * np.int64(g.n_vertices) + pair_vid
    ukey = np.unique(key)
    up = (ukey // g.n_vertices).astype(np.int32)
    uv = (ukey % g.n_vertices).astype(np.int64)

    # isolated vertices -> round-robin
    if include_isolated:
        iso = g.isolated_vertices()
        if iso.size:
            iso_p = (splitmix64(iso.astype(np.uint64)) % np.uint64(P)).astype(np.int32)
            up = np.concatenate([up, iso_p])
            uv = np.concatenate([uv, iso])
            re = np.lexsort((uv, up))
            up, uv = up[re], uv[re]

    vcounts = np.bincount(up, minlength=P).astype(np.int64)
    vstarts = np.concatenate([[0], np.cumsum(vcounts)])

    # ---- replica counts and frontier slots ------------------------------ #
    replica_count = np.bincount(uv, minlength=g.n_vertices)
    frontier_gvid = np.nonzero(replica_count >= 2)[0].astype(np.int64)
    n_slots = int(frontier_gvid.shape[0])
    slot_of_gvid = np.full(g.n_vertices, n_slots, dtype=np.int64)
    slot_of_gvid[frontier_gvid] = np.arange(n_slots)

    # ---- master election (random replica via hash, paper §4.3) --------- #
    # replicas of v appear consecutively in (uv sorted by (vid)); pick
    # hash(v) % replica_count-th one.
    v_sort = np.argsort(uv, kind="stable")
    uv_s = uv[v_sort]
    first_occ = np.concatenate([[True], uv_s[1:] != uv_s[:-1]])
    group_start = np.maximum.accumulate(np.where(first_occ, np.arange(uv_s.size), 0))
    rank_in_group = np.arange(uv_s.size) - group_start
    pick = (splitmix64(uv_s.astype(np.uint64)) % replica_count[uv_s].astype(np.uint64)).astype(np.int64)
    master_sorted = rank_in_group == pick
    is_master_flat = np.zeros(uv.size, dtype=bool)
    is_master_flat[v_sort] = master_sorted

    # ---- padded sizes ---------------------------------------------------- #
    def _round(n):
        return int(-(-max(n, 1) // pad_multiple) * pad_multiple)

    v_max = _round(int(vcounts.max()))
    e_max = _round(int(counts.max()))

    gvid = np.full((P, v_max), -1, dtype=np.int64)
    vmask = np.zeros((P, v_max), dtype=bool)
    slot = np.full((P, v_max), n_slots, dtype=np.int32)
    is_master = np.zeros((P, v_max), dtype=bool)
    out_deg = np.zeros((P, v_max), dtype=np.float32)
    in_deg = np.zeros((P, v_max), dtype=np.float32)
    esrc = np.zeros((P, e_max), dtype=np.int32)
    edst = np.zeros((P, e_max), dtype=np.int32)
    ew = np.zeros((P, e_max), dtype=np.float32)
    emask = np.zeros((P, e_max), dtype=bool)

    g_out = g.out_degrees().astype(np.float32)
    g_in = g.in_degrees().astype(np.float32)

    for p in range(P):
        lv = uv[vstarts[p]:vstarts[p + 1]]           # sorted ascending
        nv = lv.shape[0]
        gvid[p, :nv] = lv
        vmask[p, :nv] = True
        slot[p, :nv] = slot_of_gvid[lv]
        is_master[p, :nv] = is_master_flat[vstarts[p]:vstarts[p + 1]]
        out_deg[p, :nv] = g_out[lv]
        in_deg[p, :nv] = g_in[lv]

        es, ed = ps[starts[p]:starts[p + 1]], pd[starts[p]:starts[p + 1]]
        w = pw[starts[p]:starts[p + 1]]
        ls = np.searchsorted(lv, es).astype(np.int32)
        ld = np.searchsorted(lv, ed).astype(np.int32)
        # sort local edges by destination (segment ops expect sorted ids)
        eo = np.argsort(ld, kind="stable")
        ne = es.shape[0]
        esrc[p, :ne] = ls[eo]
        edst[p, :ne] = ld[eo]
        ew[p, :ne] = w[eo]
        emask[p, :ne] = True

    return PartitionedGraph(
        n_parts=P, n_vertices=g.n_vertices, n_edges=g.n_edges,
        n_slots=n_slots, v_max=v_max, e_max=e_max,
        gvid=gvid, vmask=vmask, esrc=esrc, edst=edst, ew=ew, emask=emask,
        slot=slot, is_frontier=(slot < n_slots) & vmask,
        out_deg=out_deg, in_deg=in_deg, is_master=is_master,
        frontier_gvid=frontier_gvid, edge_part=edge_part,
    )
