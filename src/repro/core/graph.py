"""Graph containers for DRONE/SVHM.

Host-side (numpy) representation used by the partitioners and the subgraph
builder. Vertex ids are int64 end-to-end so the *design* scales to
trillion-edge graphs (the paper's headline claim); local per-partition indices
are int32.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = ["Graph", "splitmix64"]


def splitmix64(x: np.ndarray) -> np.ndarray:
    """Deterministic 64-bit mix hash (SplitMix64 finalizer), vectorized.

    Used everywhere a hash-based placement decision is made (RH / CDBH / EC),
    so that partitioning is a pure function of (entity, n_parts, seed) — the
    property our elastic re-partitioning relies on (DESIGN.md §7).
    """
    x = x.astype(np.uint64, copy=True)
    x += np.uint64(0x9E3779B97F4A7C15)
    x ^= x >> np.uint64(30)
    x *= np.uint64(0xBF58476D1CE4E5B9)
    x ^= x >> np.uint64(27)
    x *= np.uint64(0x94D049BB133111EB)
    x ^= x >> np.uint64(31)
    return x


@dataclasses.dataclass
class Graph:
    """A directed graph in COO form. Undirected graphs are stored with both
    edge directions present (the paper's convention, §2 Notations)."""

    n_vertices: int
    src: np.ndarray  # [E] int64
    dst: np.ndarray  # [E] int64
    weight: Optional[np.ndarray] = None  # [E] float32 (None -> unit weights)
    directed: bool = True

    def __post_init__(self):
        self.src = np.asarray(self.src, dtype=np.int64)
        self.dst = np.asarray(self.dst, dtype=np.int64)
        if self.weight is not None:
            self.weight = np.asarray(self.weight, dtype=np.float32)
            assert self.weight.shape == self.src.shape
        assert self.src.shape == self.dst.shape
        if self.n_edges:
            assert int(self.src.max()) < self.n_vertices
            assert int(self.dst.max()) < self.n_vertices
            assert int(min(self.src.min(), self.dst.min())) >= 0

    # ------------------------------------------------------------------ #
    @property
    def n_edges(self) -> int:
        return int(self.src.shape[0])

    @property
    def weights(self) -> np.ndarray:
        if self.weight is None:
            return np.ones_like(self.src, dtype=np.float32)
        return self.weight

    # ------------------------------------------------------------------ #
    def out_degrees(self) -> np.ndarray:
        return np.bincount(self.src, minlength=self.n_vertices).astype(np.int64)

    def in_degrees(self) -> np.ndarray:
        return np.bincount(self.dst, minlength=self.n_vertices).astype(np.int64)

    def total_degrees(self) -> np.ndarray:
        """Full degree per the paper's ``getDegree()`` (in + out)."""
        return self.out_degrees() + self.in_degrees()

    # ------------------------------------------------------------------ #
    def as_undirected(self) -> "Graph":
        """Replace each edge by two opposite-direction edges (paper §2),
        de-duplicated."""
        s = np.concatenate([self.src, self.dst])
        d = np.concatenate([self.dst, self.src])
        w = np.concatenate([self.weights, self.weights])
        # dedupe on (s, d)
        key = s * np.int64(self.n_vertices) + d
        _, idx = np.unique(key, return_index=True)
        return Graph(self.n_vertices, s[idx], d[idx], w[idx], directed=False)

    def dedup(self) -> "Graph":
        key = self.src * np.int64(self.n_vertices) + self.dst
        _, idx = np.unique(key, return_index=True)
        w = None if self.weight is None else self.weight[idx]
        return Graph(self.n_vertices, self.src[idx], self.dst[idx], w,
                     directed=self.directed)

    def drop_self_loops(self) -> "Graph":
        keep = self.src != self.dst
        w = None if self.weight is None else self.weight[keep]
        return Graph(self.n_vertices, self.src[keep], self.dst[keep], w,
                     directed=self.directed)

    # ------------------------------------------------------------------ #
    def isolated_vertices(self) -> np.ndarray:
        touched = np.zeros(self.n_vertices, dtype=bool)
        touched[self.src] = True
        touched[self.dst] = True
        return np.nonzero(~touched)[0].astype(np.int64)
