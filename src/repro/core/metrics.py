"""Partitioning + execution metrics (paper §6.2).

Partitioning metrics:
  - Imbalance         = max_i |E_i| / (|E| / n)
  - Replication Factor = sum_i |V_i| / |V|

Execution metrics (gathered by the engine): supersteps, network messages
((key,value) pairs, i.e. changed frontier slots per superstep), bytes moved,
per-phase time breakdown, PEPS (processed edges per second, paper Fig 9).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.subgraph import PartitionedGraph

__all__ = ["PartitionMetrics", "partition_metrics", "ExecutionStats"]


@dataclasses.dataclass
class PartitionMetrics:
    n_parts: int
    imbalance: float
    replication_factor: float
    edges_per_part_max: int
    edges_per_part_min: int
    n_frontier: int
    master_balance: float  # max masters per part / mean (SBS aggregation balance)

    def __str__(self):
        return (f"P={self.n_parts} imbalance={self.imbalance:.4f} "
                f"RF={self.replication_factor:.4f} frontier={self.n_frontier} "
                f"master_balance={self.master_balance:.3f}")


def partition_metrics(pg: PartitionedGraph) -> PartitionMetrics:
    epp = pg.edges_per_part
    vpp = pg.vertices_per_part
    masters = (pg.is_master & pg.vmask & (pg.slot < pg.n_slots)).sum(axis=1)
    mmean = masters.mean() if pg.n_slots else 1.0
    return PartitionMetrics(
        n_parts=pg.n_parts,
        imbalance=float(epp.max() / max(epp.mean(), 1e-12)),
        replication_factor=float(vpp.sum() / max(pg.n_vertices, 1)),
        edges_per_part_max=int(epp.max()),
        edges_per_part_min=int(epp.min()),
        n_frontier=pg.n_slots,
        master_balance=float(masters.max() / max(mmean, 1e-12)) if pg.n_slots else 1.0,
    )


@dataclasses.dataclass
class ExecutionStats:
    """Filled in by the engine; one entry per superstep when tracing."""
    supersteps: int = 0
    total_messages: int = 0            # changed (key,value) pairs, paper metric
    total_bytes: int = 0               # dense SBS buffer bytes actually reduced
    messages_per_step: list = dataclasses.field(default_factory=list)
    active_parts_per_step: list = dataclasses.field(default_factory=list)
    compute_time: float = 0.0
    sync_time: float = 0.0
    wall_time: float = 0.0             # execution only — compile billed apart
    compile_time: float = 0.0          # trace+compile on a GraphSession
                                       # runner-cache miss; 0.0 on a hit, so
                                       # steady-state serving latency is
                                       # wall_time alone (one-shot run_* pay
                                       # trace cost inside wall_time as ever)
    evicted_runners: int = 0           # LRU evictions this query's cache
                                       # admission forced (GraphSession only)
    processed_edges: int = 0
    edge_backend: str = "coo"          # which edge-compute backend ran the
                                       # local sweeps ('coo' also for
                                       # programs without a SemiringSweep)
    backend_flops: int = 0             # semiring ops the backend issued:
                                       # 2*K per resident edge on COO; the
                                       # dense tile/block work (identity
                                       # padding included) on Pallas
    tile_density: float = 0.0          # non-identity fraction of the real
                                       # tiles ('pallas_tiles' only): the
                                       # MXU utilization of the dense path
                                       # — low density says use windows/COO
    queue_time: float = 0.0            # admission-queue dwell before launch
                                       # (serving/batcher.py fills it in)
    batch_size: int = 1                # lanes in the micro-batched launch
                                       # that served this query (1 = a
                                       # singleton launch)
    result_cache_tier: str = ""        # '' when no result cache consulted;
                                       # 'l1'/'l2' when the converged result
                                       # was served without a device launch,
                                       # 'miss' when it ran and was stored
    # Per-partition (per-shard) load gauges — the LoadMonitor's measured-
    # work inputs, and independently useful in benchmark tables. Empty
    # lists when the run path did not fill them (result-cache hits, trace
    # mode).
    partition_edge_counts: list = dataclasses.field(default_factory=list)
    partition_flops: list = dataclasses.field(default_factory=list)
                                       # backend_flops split per shard:
                                       # sweeps[p] * flops-per-sweep[p]
    partition_sweep_time: list = dataclasses.field(default_factory=list)
                                       # wall_time apportioned by each
                                       # shard's flops share — the realized
                                       # per-shard sweep-time estimate
    partition_tile_density: list = dataclasses.field(default_factory=list)
                                       # per-partition non-identity tile
                                       # fraction — the auto policy's input
                                       # (filled on pallas_tiles and auto)
    partition_edge_backends: list = dataclasses.field(default_factory=list)
                                       # edge_backend='auto' only: the
                                       # resolved concrete backend billed to
                                       # each partition this run

    @property
    def peps(self) -> float:
        """Actual processed edges per second (paper §8.5, [25])."""
        return self.processed_edges / self.wall_time if self.wall_time else 0.0

    @property
    def total_time(self) -> float:
        """wall_time + compile_time — what the first (cold) query costs."""
        return self.wall_time + self.compile_time
