"""Calibrated edge-backend selection for ``EngineConfig.edge_backend='auto'``.

The three edge-compute backends trade memory traffic very differently
(docs/ARCHITECTURE.md, "Edge-compute backends"):

  - ``coo``            pays ~24 bytes per *resident edge* (gather + scatter
                       through HBM) plus a dense per-vertex aggregate;
  - ``pallas_tiles``   pays a fixed ~64 KiB per 128x128 tile regardless of
                       how empty it is — a coverage floor of ``n_dst_tiles``
                       tiles even for a near-empty partition;
  - ``pallas_windows`` pays per occupied 512-edge block (~8 bytes/slot) plus
                       a per-window epilogue — cheaper than COO once blocks
                       fill, cheaper than tiles until they densify.

The crossover points are machine properties, not constants, so ``'auto'``
derives them from a small **calibration sweep** run once per platform and
cached on disk: synthetic single-partition adjacencies spanning a tile
density grid are pushed through the same geometry builders the engine uses
(``core/layouts.py``), each point is costed per backend, and per-unit costs
(seconds per COO edge, per dense tile, per window block, ...) are fitted by
least squares. Off-TPU the point costs are the *modeled* roofline times of
``benchmarks/kernel_roofline.py`` — interpret-mode wall-clocks are
meaningless there, and the modeled table is deterministic by construction,
which is what makes cached replay and the calibration tests exact. On a
real TPU the sweep times the kernels themselves.

The policy is then a pure argmin over per-partition unit counts the layout
geometry already tracks (``edges_per_part``, ``EdgeLayouts.n_tiles``,
``EdgeLayouts.n_blocks``): no tracing, no device work, same answer for the
same geometry. ``engine.resolve_partition_backends`` is the engine-facing
entry; sessions pin the resulting assignment per shape bucket so in-bucket
streaming growth can never flip a partition's backend mid-session
(zero-retrace contract, docs/API.md "Caching rules").

Cache location: ``$DRONE_AUTOTUNE_DIR`` when set, else
``~/.cache/drone/``, one JSON per (platform, schema version). Delete the
file (or bump ``SCHEMA_VERSION``) to force recalibration; a corrupt or
stale-schema file is recalibrated, never trusted.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.layouts import _tile_geometry, _window_geometry
from repro.kernels.bsp_spmv import TM, TN
from repro.kernels.segment_combine import W

__all__ = ["CalibrationTable", "calibrate", "get_table", "load_table",
           "save_table", "table_path", "pick_backends", "BACKEND_ORDER",
           "SCHEMA_VERSION"]

SCHEMA_VERSION = 1

#: argmin tie-break order — fixed so replayed tables pick identically.
BACKEND_ORDER: Tuple[str, ...] = ("coo", "pallas_windows", "pallas_tiles")

#: roofline constants shared with benchmarks/kernel_roofline.py
HBM_BW = 819e9          # bytes/s
DEFAULT_BLOCK_EDGES = 512

#: calibration grid: (n_vertices, target tile density) pairs. Two vertex
#: counts make the COO per-edge/per-vertex costs separately identifiable;
#: the density axis spans the ultra-sparse -> dense crossover region.
GRID_NV: Tuple[int, ...] = (256, 512)
GRID_DENSITY: Tuple[float, ...] = (0.0005, 0.002, 0.01, 0.05, 0.2, 0.6)
_GRID_SEED = 0xD120


# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class CalibrationTable:
    """One platform's calibrated per-unit backend costs + the sweep points
    they were fitted from (kept for the ``--crossover`` benchmark and for
    determinism tests — same platform, same schema => byte-identical JSON).
    """

    platform: str
    source: str                       # 'modeled' | 'measured'
    points: list                      # list of per-point dicts (JSON rows)
    unit_costs: Dict[str, float]      # seconds per unit of work

    # ------------------------------------------------------------------ #
    def partition_costs(self, *, n_edges, n_vertices: int, n_tiles,
                        n_blocks, n_windows: int) -> Dict[str, np.ndarray]:
        """Predicted per-partition sweep cost (seconds) per backend.

        ``n_edges``/``n_tiles``/``n_blocks`` are [P] unit counts straight
        from the graph and its ``EdgeLayouts`` geometry; ``n_vertices`` and
        ``n_windows`` are the shared padded per-partition constants."""
        u = self.unit_costs
        ne = np.asarray(n_edges, np.float64)
        coo = u["coo_edge"] * ne + u["coo_vertex"] * float(n_vertices)
        tiles = u["tile"] * np.asarray(n_tiles, np.float64)
        windows = (u["win_block"] * np.asarray(n_blocks, np.float64)
                   + u["win_window"] * float(n_windows)
                   + u["win_edge"] * ne)
        return {"coo": coo, "pallas_tiles": tiles, "pallas_windows": windows}

    def pick(self, *, n_edges, n_vertices: int, n_tiles, n_blocks,
             n_windows: int) -> Tuple[str, ...]:
        """Per-partition argmin over ``partition_costs`` (ties resolve to
        the earliest entry of ``BACKEND_ORDER`` — deterministic replay)."""
        costs = self.partition_costs(
            n_edges=n_edges, n_vertices=n_vertices, n_tiles=n_tiles,
            n_blocks=n_blocks, n_windows=n_windows)
        mat = np.stack([np.atleast_1d(costs[b]) for b in BACKEND_ORDER])
        return tuple(BACKEND_ORDER[i] for i in np.argmin(mat, axis=0))

    # ------------------------------------------------------------------ #
    def to_json(self) -> str:
        return json.dumps(
            dict(version=SCHEMA_VERSION, platform=self.platform,
                 source=self.source, unit_costs=self.unit_costs,
                 points=self.points),
            indent=1, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "CalibrationTable":
        d = json.loads(text)
        if d.get("version") != SCHEMA_VERSION:
            raise ValueError(f"autotune table schema {d.get('version')!r} != "
                             f"{SCHEMA_VERSION}")
        return cls(platform=d["platform"], source=d["source"],
                   points=d["points"], unit_costs=d["unit_costs"])


# --------------------------------------------------------------------------- #
# calibration sweep
# --------------------------------------------------------------------------- #
def _synthetic_edges(nv: int, density: float,
                     seed: int) -> Tuple[np.ndarray, np.ndarray]:
    """A deterministic single-partition adjacency with ~``density``
    occupancy of the nv x nv grid, dst-sorted ascending like
    ``localize_edges`` output."""
    rng = np.random.default_rng(seed)
    ne = int(np.clip(round(density * nv * nv), 1, nv * nv))
    flat = rng.choice(nv * nv, size=ne, replace=False)
    dst, src = flat // nv, flat % nv
    order = np.lexsort((src, dst))
    return src[order].astype(np.int64), dst[order].astype(np.int64)


def _point_units(nv: int, src: np.ndarray, dst: np.ndarray) -> dict:
    """Unit counts the engine's geometry builders would assign this
    adjacency (coverage fillers and per-window block minima included)."""
    ndt = max(-(-nv // TM), 1)
    nst = max(-(-nv // TN), 1)
    nw = max(-(-nv // W), 1)
    td, _ts, _et, _er, _ec = _tile_geometry(src, dst, ndt, nst)
    _es, _ld, _bw, nb = _window_geometry(dst, nw, DEFAULT_BLOCK_EDGES)
    filled = np.unique(dst * np.int64(nv) + src).shape[0]
    return dict(n_vertices=int(nv), n_edges=int(src.shape[0]),
                n_tiles=int(td.shape[0]), n_blocks=int(nb),
                n_windows=int(nw),
                density=filled / float(td.shape[0] * TM * TN))


def _modeled_costs(units: dict) -> Dict[str, float]:
    """Roofline-modeled sweep time per backend (K=1), matching the byte
    accounting of ``benchmarks/kernel_roofline.py``: COO streams ~24 B per
    edge + 8 B per vertex row; a dense tile streams its values + the v/out
    slices; a window block streams its slot buffer + the per-window
    epilogue, and every edge pays the int32 slot read + f32 message."""
    ne, nv = units["n_edges"], units["n_vertices"]
    coo = (ne * 24.0 + nv * 8.0) / HBM_BW
    tiles = units["n_tiles"] * (TM * TN * 4.0 + (TM + TN) * 4.0) / HBM_BW
    windows = (units["n_blocks"] * DEFAULT_BLOCK_EDGES * 8.0
               + units["n_windows"] * W * 8.0 + ne * 8.0) / HBM_BW
    return {"coo": coo, "pallas_tiles": tiles, "pallas_windows": windows}


def _measured_costs(units: dict, src: np.ndarray,
                    dst: np.ndarray) -> Dict[str, float]:
    """Wall-clock the three single-partition reference paths (TPU only —
    interpret-mode CPU times are meaningless and are never recorded)."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    nv = units["n_vertices"]
    w = np.ones(src.shape[0], np.float32)
    vals = np.linspace(0.0, 1.0, nv, dtype=np.float32)

    def timed(fn):
        fn()                                       # compile + warm
        best = np.inf
        for _ in range(3):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            best = min(best, time.perf_counter() - t0)
        return best

    tl = ops.build_tiles(src, dst, w, n_src_rows=nv, n_dst_rows=nv,
                         semiring="min_plus", dtype=np.float32)
    wl = ops.window_align_edges(dst, n_rows=nv,
                                block_edges=DEFAULT_BLOCK_EDGES)
    v = jnp.asarray(vals)
    s, d, ew = jnp.asarray(src), jnp.asarray(dst), jnp.asarray(w)

    def coo_fn(v_, s_, d_, ew_):
        agg = jnp.full((nv,), jnp.inf, jnp.float32)
        return agg.at[d_].min(v_[s_] + ew_)

    coo_jit = jax.jit(coo_fn)
    return {"coo": timed(lambda: coo_jit(v, s, d, ew)),
            "pallas_tiles": timed(lambda: tl(v)),
            "pallas_windows": timed(lambda: wl(v[s] + ew))}


def _fit_unit_costs(points: Sequence[dict]) -> Dict[str, float]:
    """Least-squares per-unit costs from the sweep points. On the modeled
    path the regression is exact (the costs *are* linear in the unit
    counts); on the measured path it smooths launch noise. Coefficients are
    clipped at >= 0 so one noisy point can never invert a cost."""
    def fit(cols: np.ndarray, y: np.ndarray) -> np.ndarray:
        coef, *_ = np.linalg.lstsq(cols, y, rcond=None)
        return np.maximum(coef, 0.0)

    ne = np.array([p["n_edges"] for p in points], np.float64)
    nv = np.array([p["n_vertices"] for p in points], np.float64)
    nt = np.array([p["n_tiles"] for p in points], np.float64)
    nb = np.array([p["n_blocks"] for p in points], np.float64)
    nw = np.array([p["n_windows"] for p in points], np.float64)

    c_coo = fit(np.stack([ne, nv], 1),
                np.array([p["cost_coo"] for p in points]))
    c_tile = fit(nt[:, None], np.array([p["cost_tiles"] for p in points]))
    c_win = fit(np.stack([nb, nw, ne], 1),
                np.array([p["cost_windows"] for p in points]))
    return {"coo_edge": float(c_coo[0]), "coo_vertex": float(c_coo[1]),
            "tile": float(c_tile[0]), "win_block": float(c_win[0]),
            "win_window": float(c_win[1]), "win_edge": float(c_win[2])}


def _platform() -> str:
    import jax
    return jax.default_backend()


def calibrate(platform: Optional[str] = None) -> CalibrationTable:
    """Run the calibration sweep for ``platform`` (default: the current jax
    backend). Pure host work off-TPU — safe to call at import-ish time."""
    platform = platform or _platform()
    measured = platform == "tpu"
    points = []
    for i, nv in enumerate(GRID_NV):
        for j, density in enumerate(GRID_DENSITY):
            src, dst = _synthetic_edges(nv, density,
                                        _GRID_SEED + 97 * i + j)
            units = _point_units(nv, src, dst)
            costs = _measured_costs(units, src, dst) if measured \
                else _modeled_costs(units)
            points.append(dict(units, cost_coo=costs["coo"],
                               cost_tiles=costs["pallas_tiles"],
                               cost_windows=costs["pallas_windows"]))
    return CalibrationTable(platform=platform,
                            source="measured" if measured else "modeled",
                            points=points,
                            unit_costs=_fit_unit_costs(points))


# --------------------------------------------------------------------------- #
# disk cache
# --------------------------------------------------------------------------- #
def cache_dir() -> str:
    return os.environ.get("DRONE_AUTOTUNE_DIR") or os.path.join(
        os.path.expanduser("~"), ".cache", "drone")


def table_path(platform: Optional[str] = None) -> str:
    return os.path.join(cache_dir(),
                        f"autotune_{platform or _platform()}"
                        f"_v{SCHEMA_VERSION}.json")


def load_table(platform: Optional[str] = None) -> Optional[CalibrationTable]:
    path = table_path(platform)
    try:
        with open(path, "r", encoding="utf-8") as f:
            return CalibrationTable.from_json(f.read())
    except FileNotFoundError:
        return None
    except (ValueError, KeyError, json.JSONDecodeError) as e:
        # stale schema / corrupt cache: recalibrate rather than trust it
        import logging
        logging.getLogger(__name__).debug(
            "discarding autotune cache %s: %s", path, e)
        return None


def save_table(table: CalibrationTable) -> str:
    path = table_path(table.platform)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w", encoding="utf-8") as f:
        f.write(table.to_json())
    os.replace(tmp, path)
    return path


def get_table(platform: Optional[str] = None, *,
              force: bool = False) -> CalibrationTable:
    """The platform's calibration table: disk cache first, else calibrate
    and persist. ``force=True`` recalibrates unconditionally."""
    if not force:
        cached = load_table(platform)
        if cached is not None:
            return cached
    table = calibrate(platform)
    save_table(table)
    return table


# --------------------------------------------------------------------------- #
def pick_backends(table: CalibrationTable, pg, lay) -> Tuple[str, ...]:
    """Per-partition backend assignment for a ``PartitionedGraph`` + its
    ``EdgeLayouts`` geometry — the ``edge_backend='auto'`` policy."""
    return table.pick(
        n_edges=pg.edges_per_part, n_vertices=pg.v_max,
        n_tiles=lay.n_tiles, n_blocks=lay.n_blocks,
        n_windows=lay.n_windows)
