"""Two-pass out-of-core ingestion: edge log -> PartitionedGraph.

Pass 1  (degrees):   stream chunks, accumulate full in/out degree counts —
                     CDBH routing needs the *full-graph* degree of every
                     endpoint before any edge can be placed (paper §6.3).
Pass 2  (routing):   stream chunks again, route each edge with the pure
                     chunk routers (core.partition.STREAM_ROUTERS) and append
                     it to its partition's on-disk spill shard.
Assembly:            per partition, read the spill shard back (one partition
                     resident at a time), derive membership, and fill the
                     padded device arrays via core.subgraph's layered builder.

Because the routers are pure per-edge functions, the result is bit-identical
to the one-shot in-memory path (``partition_and_build``) — the parity the
tests pin down (that parity contract is also why the default
``ShapePolicy`` here is the *exact* one; a session passes its bucketed
policy explicitly). The returned ``StreamContext`` freezes the routing
inputs (partitioner, seed, degree snapshot, ingest-time id-space size):
every later delta must route through it unchanged or resident edges stop
being findable. Peak *edge* memory is O(chunk_size), never O(|E|): the
``ChunkAccountant`` measures every transient edge buffer the passes hold and
``streaming_ingest`` asserts the measured peak against an analytic
O(chunk_size) bound. O(n_vertices) columnar state (degree counters, the
membership tables) is carried like the paper's degree pass; the final
PartitionedGraph is O(|E|) by definition — on the production mesh each host
would assemble only its own partitions.

Stateful-streaming routers (the ``"ebv"`` ``STREAM_ROUTERS`` entry) relax
the columnar claim knowingly: their router state adds O(V * P / 64) replica
bitmasks plus an exact pair->partition table, O(distinct pairs) host memory
— the documented price of load-aware placement (docs/PARTITIONING.md). The
transient chunk buffers stay bounded either way, which is what the
``ChunkAccountant`` assertion pins.
"""
from __future__ import annotations

import dataclasses
import os
import shutil
import tempfile
import time
from typing import Optional, Union

import numpy as np

from repro.core.partition import (STREAM_ROUTERS, is_stateful_router,
                                  route_vertices_rh)
from repro.core.subgraph import (PartitionedGraph, ShapePolicy,
                                 assemble_partitioned_graph)
from repro.stream.edgelog import (BYTES_PER_EDGE, EdgeLogReader,
                                  EdgeLogWriter)

__all__ = ["StreamContext", "IngestStats", "ChunkAccountant",
           "streaming_ingest"]


@dataclasses.dataclass
class StreamContext:
    """Routing metadata frozen at ingest time.

    ``routing_degrees`` is the degree snapshot CDBH consulted when edges
    were placed. Delta batches (stream.delta) must route through the *same*
    snapshot so an edge deletion finds its edge in the partition where
    ingestion put it, and re-inserted edges co-locate deterministically —
    the pure-hash elasticity property (DESIGN.md §7). Grown id-spaces extend
    the snapshot with zeros (new vertices route by their own hash).
    """

    partitioner: str
    n_parts: int
    seed: int
    n_vertices: int
    routing_degrees: np.ndarray  # int64 [n_vertices]
    # id-space size frozen at ingest: the 'range' router divides by it, so
    # routing must keep using the ingest-time value after growth or resident
    # edges would stop being findable (post-growth ids clip to the last
    # block — deterministic, and a no-op for ingest-time ids).
    routing_n_vertices: int = -1
    # Stateful-streaming routers (STREAM_ROUTERS entries that are a
    # StatefulRouterSpec, e.g. "ebv") carry their mutable state here; a
    # rebalanced pure-hash context carries a RelocationOverlay. None for an
    # untouched pure router — the common case.
    router_state: Optional[object] = None

    def __post_init__(self):
        if self.routing_n_vertices < 0:
            self.routing_n_vertices = self.n_vertices

    def _route_pure(self, src: np.ndarray, dst: np.ndarray) -> np.ndarray:
        entry = STREAM_ROUTERS[self.partitioner]
        if is_stateful_router(entry):
            raise ValueError(
                f"partitioner {self.partitioner!r} is stateful-streaming "
                "but this StreamContext has no router_state — build the "
                "context through streaming_ingest / GraphSession.from_graph "
                "(or attach spec.make_state(...) yourself)")
        part = entry(src, dst, self.routing_degrees,
                     self.routing_n_vertices, self.n_parts, self.seed)
        return np.minimum(part, self.n_parts - 1)

    def route(self, src: np.ndarray, dst: np.ndarray) -> np.ndarray:
        """Non-mutating routing: the pure hash, or a stateful router's
        *preview* (where an insert would currently land). Mutation paths
        must use ``route_adds`` / ``route_deletes`` instead — for a pure
        router all three coincide."""
        if self.router_state is not None:
            return np.minimum(self.router_state.route_preview(src, dst),
                              self.n_parts - 1)
        return self._route_pure(src, dst)

    def route_adds(self, src: np.ndarray, dst: np.ndarray) -> np.ndarray:
        """Route inserted edges; a stateful router commits the placement
        (load counters, replica sets, pair table) as it routes."""
        if self.router_state is not None:
            return np.minimum(self.router_state.route_adds(src, dst),
                              self.n_parts - 1)
        return self._route_pure(src, dst)

    def route_deletes(self, src: np.ndarray, dst: np.ndarray) -> np.ndarray:
        """Route deletions to the partition holding the resident copies —
        a stateful router answers from its exact pair table; a pure router
        re-hashes (placement never moved)."""
        if self.router_state is not None:
            return np.minimum(self.router_state.route_deletes(src, dst),
                              self.n_parts - 1)
        return self._route_pure(src, dst)

    def grow(self, n_vertices: int) -> None:
        if n_vertices > self.n_vertices:
            self.routing_degrees = np.concatenate(
                [self.routing_degrees,
                 np.zeros(n_vertices - self.n_vertices, np.int64)])
            self.n_vertices = n_vertices
            if self.router_state is not None:
                self.router_state.grow(n_vertices)


class ChunkAccountant:
    """Tracks transient edge-buffer bytes held by the streaming passes.

    ``hold``/``drop`` bracket every chunk-sized allocation; ``sample`` folds
    in externally-owned buffers (spill-writer backlogs). The assembly phase
    is accounted separately — it is bounded by the largest partition, not by
    the chunk size."""

    def __init__(self):
        self.live = 0
        self.peak_stream = 0
        self.peak_assemble = 0

    def hold(self, nbytes: int) -> int:
        self.live += int(nbytes)
        self.peak_stream = max(self.peak_stream, self.live)
        return int(nbytes)

    def drop(self, nbytes: int) -> None:
        self.live -= int(nbytes)

    def sample(self, extra: int = 0) -> None:
        self.peak_stream = max(self.peak_stream, self.live + int(extra))


@dataclasses.dataclass
class IngestStats:
    n_edges: int = 0
    n_chunks: int = 0
    chunk_size: int = 0
    spill_chunk_size: int = 0
    peak_stream_bytes: int = 0       # measured: passes 1-2 transient buffers
    stream_bound_bytes: int = 0      # analytic O(chunk_size) bound (asserted)
    peak_assemble_bytes: int = 0     # measured: largest resident partition
    pass1_time: float = 0.0
    pass2_time: float = 0.0
    assemble_time: float = 0.0

    @property
    def ingest_edges_per_s(self) -> float:
        t = self.pass1_time + self.pass2_time + self.assemble_time
        return self.n_edges / t if t > 0 else float("nan")


def _chunk_nbytes(src, dst, w) -> int:
    return src.nbytes + dst.nbytes + (w.nbytes if w is not None else 0)


def streaming_ingest(log: Union[str, EdgeLogReader], n_parts: int,
                     partitioner: str = "cdbh", *, seed: int = 0,
                     pad_multiple: int = 8,
                     shape_policy: Optional[ShapePolicy] = None,
                     include_isolated: bool = True,
                     spill_dir: Optional[str] = None, cleanup: bool = True,
                     ) -> tuple[PartitionedGraph, StreamContext, IngestStats]:
    """Stream an edge log into a PartitionedGraph without materializing |E|.

    Returns ``(pg, ctx, stats)`` — ``ctx`` is the frozen routing context for
    later incremental deltas (stream.delta.apply_delta). An assertion inside
    enforces the chunk-bounded memory contract on the streaming passes.
    ``shape_policy`` picks the padded capacities (exact round-up by default
    — the bit-identical parity contract with ``partition_and_build``;
    sessions pass their bucketed policy so ingest lands on bucket
    boundaries from the start).
    """
    if isinstance(log, str):
        log = EdgeLogReader(log)
    if partitioner not in STREAM_ROUTERS:
        raise ValueError(
            f"partitioner {partitioner!r} is not pure per-edge "
            f"(streamable: {sorted(STREAM_ROUTERS)})")
    meta = log.meta
    V = meta.n_vertices
    chunk = meta.chunk_size
    acct = ChunkAccountant()
    stats = IngestStats(n_edges=meta.n_edges, n_chunks=meta.n_chunks,
                        chunk_size=chunk)

    # ---- pass 1: full degree counts + touched mask ---------------------- #
    t0 = time.perf_counter()
    out_deg = np.zeros(V, dtype=np.int64)
    in_deg = np.zeros(V, dtype=np.int64)
    touched = np.zeros(V, dtype=bool)
    for src, dst, w in log.chunks():
        held = acct.hold(_chunk_nbytes(src, dst, w))
        out_deg += np.bincount(src, minlength=V)
        in_deg += np.bincount(dst, minlength=V)
        touched[src] = True
        touched[dst] = True
        acct.drop(held)
    degrees = out_deg + in_deg
    ctx = StreamContext(partitioner=partitioner, n_parts=n_parts, seed=seed,
                        n_vertices=V, routing_degrees=degrees)
    entry = STREAM_ROUTERS[partitioner]
    if is_stateful_router(entry):
        # Stateful routers (EBV) start scoring from an empty state after the
        # degree pass; the state is O(V + routed pairs) columnar host memory
        # (like the degree counters) and rides on the returned ctx so the
        # delta path keeps routing through it.
        ctx.router_state = entry.make_state(n_parts, V, seed)
    stats.pass1_time = time.perf_counter() - t0

    # ---- pass 2: route chunks to per-partition spill shards -------------- #
    t0 = time.perf_counter()
    own_spill = spill_dir is None
    if own_spill:
        spill_dir = tempfile.mkdtemp(prefix="drone_spill_")
    os.makedirs(spill_dir, exist_ok=True)
    # Spill writers flush at ~chunk/P edges so their combined backlog stays
    # O(chunk_size) even with every partition's buffer full.
    spill_chunk = max(chunk // max(n_parts, 1), 1024)
    stats.spill_chunk_size = spill_chunk
    writers = [EdgeLogWriter(os.path.join(spill_dir, f"part_{p:05d}"),
                             chunk_size=spill_chunk, weighted=True,
                             n_vertices=V)
               for p in range(n_parts)]
    for src, dst, w in log.chunks():
        held = acct.hold(_chunk_nbytes(src, dst, w))
        part = ctx.route_adds(src, dst)
        order = np.argsort(part, kind="stable")   # chunk order == log order
        held2 = acct.hold(order.nbytes + src.nbytes + dst.nbytes
                          + 4 * src.size)
        s, d = src[order], dst[order]
        ww = (np.ones(src.shape, np.float32) if w is None else w)[order]
        starts = np.searchsorted(part[order], np.arange(n_parts + 1))
        for p in range(n_parts):
            lo, hi = starts[p], starts[p + 1]
            if lo < hi:
                writers[p].append(s[lo:hi], d[lo:hi], ww[lo:hi])
        acct.sample(sum(wr.buffered_nbytes for wr in writers))
        acct.drop(held + held2)
    shard_meta = [wr.close() for wr in writers]
    edge_counts = np.array([m.n_edges for m in shard_meta], dtype=np.int64)
    assert int(edge_counts.sum()) == meta.n_edges
    stats.pass2_time = time.perf_counter() - t0

    # Chunk-bounded contract for the streaming passes: one chunk in flight,
    # one routed copy, plus the spill writers' bounded backlog.
    chunk_bytes = chunk * BYTES_PER_EDGE
    stats.stream_bound_bytes = (3 * chunk_bytes
                                + n_parts * spill_chunk * BYTES_PER_EDGE
                                + (1 << 16))
    stats.peak_stream_bytes = acct.peak_stream
    assert stats.peak_stream_bytes <= stats.stream_bound_bytes, (
        "streaming ingest exceeded its chunk-bounded memory contract: "
        f"{stats.peak_stream_bytes} > {stats.stream_bound_bytes}")

    # ---- assembly: one partition resident at a time ---------------------- #
    t0 = time.perf_counter()
    iso = np.nonzero(~touched)[0].astype(np.int64) if include_isolated else \
        np.empty(0, np.int64)
    iso_part = route_vertices_rh(iso, n_parts) if iso.size else iso

    # Each spill shard is read twice: once to derive membership (v_max must
    # be known for every partition before any row is filled) and once to fill
    # rows. Caching the first read would reintroduce O(|E|) host memory —
    # the bounded-memory contract deliberately pays the extra disk pass.
    readers = [EdgeLogReader(os.path.join(spill_dir, f"part_{p:05d}"))
               for p in range(n_parts)]
    part_vertices = []
    for p in range(n_parts):
        s, d, _ = readers[p].read_all()
        lv = np.unique(np.concatenate([s, d]))
        if iso.size:
            lv = np.unique(np.concatenate([lv, iso[iso_part == p]]))
        part_vertices.append(lv)
        acct.peak_assemble = max(acct.peak_assemble,
                                 s.nbytes + d.nbytes + lv.nbytes)

    def load_edges(p):
        s, d, w = readers[p].read_all()
        acct.peak_assemble = max(acct.peak_assemble,
                                 s.nbytes + d.nbytes + w.nbytes)
        return s, d, w

    pg = assemble_partitioned_graph(
        n_parts, V, meta.n_edges, part_vertices, edge_counts, load_edges,
        out_deg, in_deg, pad_multiple=pad_multiple,
        shape_policy=shape_policy, edge_part=None)
    stats.assemble_time = time.perf_counter() - t0
    stats.peak_assemble_bytes = acct.peak_assemble

    if cleanup and own_spill:
        shutil.rmtree(spill_dir, ignore_errors=True)
    return pg, ctx, stats
