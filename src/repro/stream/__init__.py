"""Streaming graph subsystem: out-of-core ingestion, incremental partition
patching, delta batching, membership compaction, warm-start recompute (see
docs/STREAMING.md).

  - edgelog:  chunked on-disk edge log (reader/writer, spill shards)
  - ingest:   two-pass streaming pipeline -> PartitionedGraph + StreamContext
  - delta:    edge insert/delete batches patched through the frozen hashes,
              plus membership compaction after delete-heavy traffic
  - buffer:   coalescing DeltaBuffer for continuous producer traffic
"""
from repro.stream.buffer import BufferStats, DeltaBuffer
from repro.stream.delta import (CompactStats, DeltaStats, EdgeDelta,
                                apply_delta, compact)
from repro.stream.edgelog import (EdgeLogMeta, EdgeLogReader, EdgeLogWriter,
                                  write_edge_log)
from repro.stream.ingest import (ChunkAccountant, IngestStats, StreamContext,
                                 streaming_ingest)

__all__ = [
    "EdgeLogMeta", "EdgeLogReader", "EdgeLogWriter", "write_edge_log",
    "ChunkAccountant", "IngestStats", "StreamContext", "streaming_ingest",
    "EdgeDelta", "DeltaStats", "apply_delta", "CompactStats", "compact",
    "BufferStats", "DeltaBuffer",
]
