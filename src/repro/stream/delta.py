"""Incremental graph mutation: route edge deltas with the frozen pure
hashes and patch the affected partitions in place.

An ``EdgeDelta`` (insert + delete batches) is routed through the *same*
``StreamContext`` the graph was ingested with, so every mutation lands in
exactly the partition a full re-ingest would choose — no global re-shuffle,
no re-routing of resident edges. Only partitions that actually receive a
mutation are rebuilt (O(partition) each); a partition whose new edge count
overflows ``e_max`` triggers a grow-and-re-pad of the dense arrays (the
padded capacity is shared across partitions by construction). Vertex-level
metadata (frontier slots, master election, full degrees) is recomputed from
the patched membership — O(P * v_max), cheap next to any edge pass — using
the same hash election as the builders.

Membership is grow-only between compactions: a vertex whose last local edge
was deleted stays a (edge-less) member of its partition. That is harmless —
it contributes nothing to sweeps and only its own initial value to SBS — and
keeps deletion O(partition). ``n_vertices`` grows automatically when a delta
references ids beyond the current space. After delete-heavy traffic the
zombie members (and the grown ``e_max``/``v_max`` padding) inflate every
device buffer; ``compact`` evicts edge-less members, re-homes fully isolated
vertices by the same hash round-robin as ingest, and shrinks the padded
capacities back down — returning a remap so live per-partition state
survives.

Warm-start pairing: after ``apply_delta``, monotone programs (SSSP/MSSP/CC)
can restart from the previous converged result via ``run_sim(...,
init_state=prev)`` — sound for *insert-only* deltas, where old values remain
valid upper bounds. ``apply_delta`` reports ``warm_start_safe`` accordingly;
deletions require a cold start (the engine also refuses warm starts for
non-monotone programs on its own).

Invariants this module owns (callers and docs rely on them):

  - **delete-before-add batch semantics** — within one ``EdgeDelta``,
    deletions hit the *pre-delta* graph, then adds are appended; a pair in
    both lists nets to an insert, never a cancel (producer-order
    cancellation is ``DeltaBuffer``'s job, resolved before flush).
  - **capacity is grow-only here** — ``v_max``/``e_max`` only ever grow
    under ``apply_delta`` (per the ``ShapePolicy``, exact round-up by
    default, geometric buckets on a serving session); shrinking is
    exclusively ``compact``'s job, which rounds *down to the bucket floor*.
  - **every patch reports a row remap** — ``DeltaStats.remap`` maps old
    local rows to new ones (membership is grow-only, so no row is ever
    evicted by a delta; an empty delta's remap is the identity), letting
    sessions carry ``[P, v_max, K]`` device-layout state (cached warm
    results) across a patch exactly like ``CompactStats.remap_state`` does
    across a compaction.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.partition import route_vertices_rh
from repro.core.subgraph import (PartitionedGraph, ShapePolicy,
                                 localize_edges, recompute_frontier,
                                 repack_partitions, resolve_shape_policy)
from repro.stream.ingest import StreamContext

__all__ = ["EdgeDelta", "DeltaStats", "apply_delta",
           "CompactStats", "compact"]


def _remap_rows(remap: np.ndarray, v_max_after: int, state: np.ndarray,
                fill) -> np.ndarray:
    """Carry a live ``[P, v_max_before(, K)]`` per-partition array across a
    re-layout described by ``remap``: surviving rows move to their new local
    index, evicted/padded rows get ``fill``."""
    state = np.asarray(state)
    P, old_v = remap.shape
    assert state.shape[:2] == (P, old_v), (state.shape, remap.shape)
    out = np.full((P, v_max_after) + state.shape[2:], fill,
                  dtype=state.dtype)
    ip, iold = np.nonzero(remap >= 0)
    out[ip, remap[ip, iold]] = state[ip, iold]
    return out


@dataclasses.dataclass
class EdgeDelta:
    """A batch of edge mutations in global vertex ids."""

    add_src: np.ndarray = dataclasses.field(
        default_factory=lambda: np.empty(0, np.int64))
    add_dst: np.ndarray = dataclasses.field(
        default_factory=lambda: np.empty(0, np.int64))
    add_w: Optional[np.ndarray] = None       # None -> unit weights
    del_src: np.ndarray = dataclasses.field(
        default_factory=lambda: np.empty(0, np.int64))
    del_dst: np.ndarray = dataclasses.field(
        default_factory=lambda: np.empty(0, np.int64))

    def __post_init__(self):
        self.add_src = np.asarray(self.add_src, np.int64)
        self.add_dst = np.asarray(self.add_dst, np.int64)
        self.del_src = np.asarray(self.del_src, np.int64)
        self.del_dst = np.asarray(self.del_dst, np.int64)
        if self.add_w is not None:
            self.add_w = np.asarray(self.add_w, np.float32)
            assert self.add_w.shape == self.add_src.shape
        assert self.add_src.shape == self.add_dst.shape
        assert self.del_src.shape == self.del_dst.shape

    @property
    def n_adds(self) -> int:
        return int(self.add_src.shape[0])

    @property
    def n_dels(self) -> int:
        return int(self.del_src.shape[0])

    @property
    def max_id(self) -> int:
        parts = [a.max() for a in (self.add_src, self.add_dst,
                                   self.del_src, self.del_dst) if a.size]
        return int(max(parts)) if parts else -1


@dataclasses.dataclass
class DeltaStats:
    n_added: int = 0
    n_deleted: int = 0               # edges actually found and removed
    parts_patched: int = 0
    repadded: bool = False           # e_max/v_max grew (dense arrays re-pad)
    n_slots_before: int = 0
    n_slots_after: int = 0
    warm_start_safe: bool = False    # True for insert-only deltas
    v_max_before: int = 0
    v_max_after: int = 0
    # [P, v_max_before] int32: old local row -> new local row. Membership is
    # grow-only under a delta, so every pre-patch member survives; -1 marks
    # only padding rows. None for an empty delta (nothing was applied, so
    # the layout is unchanged).
    remap: Optional[np.ndarray] = None

    def remap_state(self, state: np.ndarray, fill) -> np.ndarray:
        """Carry a live ``[P, v_max_before(, K)]`` per-partition array (e.g.
        a cached warm-result block) across this patch's row re-layout —
        the delta counterpart of ``CompactStats.remap_state``. An empty
        delta never moved a row, so its remap is the identity."""
        if self.remap is None:
            return np.asarray(state)
        return _remap_rows(self.remap, self.v_max_after, state, fill)


def _grow_cols(arr: np.ndarray, n: int, fill) -> np.ndarray:
    if arr.shape[1] >= n:
        return arr
    out = np.full((arr.shape[0], n) + arr.shape[2:], fill, dtype=arr.dtype)
    out[:, :arr.shape[1]] = arr
    return out


def _edge_key(src: np.ndarray, dst: np.ndarray, n_vertices: int) -> np.ndarray:
    # Collision-free for n_vertices < 2^31.5; the dense in-memory builder has
    # the same id-space envelope (local indices are int32).
    return src.astype(np.int64) * np.int64(n_vertices) + dst.astype(np.int64)


def apply_delta(pg: PartitionedGraph, ctx: StreamContext, delta: EdgeDelta,
                *, pad_multiple: int = 8,
                shape_policy: Optional[ShapePolicy] = None) -> DeltaStats:
    """Apply ``delta`` to ``pg`` in place, routing through ``ctx``.

    Deletions remove *every* resident copy of a (src, dst) pair in the
    partition the pair routes to; pairs that are not resident are ignored.

    Batch semantics: **deletes apply to the pre-delta graph, then adds are
    appended** — a pair appearing in both lists of one ``EdgeDelta`` has its
    pre-existing resident copies removed and exactly the new copies
    inserted (i.e. it nets to an insert, never to a cancel). Producer-order
    coalescing — "I added this pair a moment ago, now forget it" — is the
    ``DeltaBuffer``'s job (stream/buffer.py), which resolves op order
    *before* anything reaches this function.
    """
    policy = resolve_shape_policy(shape_policy, pad_multiple)
    stats = DeltaStats(n_slots_before=pg.n_slots,
                       warm_start_safe=delta.n_dels == 0,
                       v_max_before=pg.v_max, v_max_after=pg.v_max)
    if delta.n_adds == 0 and delta.n_dels == 0:
        stats.n_slots_after = pg.n_slots
        return stats
    old_v_max = pg.v_max
    old_nv = pg.vmask.sum(axis=1)    # rows are packed at the front

    # ---- id-space growth ------------------------------------------------ #
    new_v = max(pg.n_vertices, delta.max_id + 1)
    ctx.grow(new_v)
    pg.n_vertices = new_v

    # ---- route mutations through the frozen routing context -------------- #
    # Adds first: a stateful router (EBV) commits placements as it routes,
    # and its pair table is what lets the deletes of a DEL_ADD pair find the
    # resident copies (same partition — placement is pair-sticky). For the
    # pure hashes route_adds == route_deletes == route.
    add_part = ctx.route_adds(delta.add_src, delta.add_dst)
    del_part = ctx.route_deletes(delta.del_src, delta.del_dst)
    add_w = (np.ones(delta.n_adds, np.float32) if delta.add_w is None
             else delta.add_w)
    affected = np.unique(np.concatenate([add_part, del_part]))

    # Current full degrees, reconstructed from replica rows while they are
    # still aligned with gvid (all replicas agree on the value); the delta's
    # shifts are folded in below — O(V + delta), no global edge re-scan.
    g_out = np.zeros(new_v, np.float64)
    g_in = np.zeros(new_v, np.float64)
    sel = pg.vmask
    g_out[pg.gvid[sel]] = pg.out_deg[sel]
    g_in[pg.gvid[sel]] = pg.in_deg[sel]
    g_out += np.bincount(delta.add_src, minlength=new_v)
    g_in += np.bincount(delta.add_dst, minlength=new_v)

    # ---- rebuild each affected partition's local arrays ------------------ #
    # Rebuilt content is staged, then written after any capacity growth.
    staged = {}
    need_e = int(pg.e_max)
    need_v = int(pg.v_max)
    for p in affected.tolist():
        m = pg.emask[p]
        gs = pg.gvid[p][pg.esrc[p][m]]
        gd = pg.gvid[p][pg.edst[p][m]]
        w = pg.ew[p][m]

        dsel = del_part == p
        if dsel.any():
            dkey = _edge_key(delta.del_src[dsel], delta.del_dst[dsel], new_v)
            keep = ~np.isin(_edge_key(gs, gd, new_v), dkey)
            stats.n_deleted += int(gs.shape[0] - keep.sum())
            if not keep.all():   # only matched copies shift degrees
                g_out -= np.bincount(gs[~keep], minlength=new_v)
                g_in -= np.bincount(gd[~keep], minlength=new_v)
            gs, gd, w = gs[keep], gd[keep], w[keep]

        asel = add_part == p
        if asel.any():
            gs = np.concatenate([gs, delta.add_src[asel]])
            gd = np.concatenate([gd, delta.add_dst[asel]])
            w = np.concatenate([w, add_w[asel]])
            stats.n_added += int(asel.sum())

        # grow-only membership: old members stay, new endpoints join
        old_lv = pg.gvid[p][pg.vmask[p]]
        lv = np.unique(np.concatenate([old_lv, gs, gd]))
        staged[p] = (lv, gs, gd, w, old_lv)
        need_e = max(need_e, gs.shape[0])
        need_v = max(need_v, lv.shape[0])

    # ---- capacity growth (shared padded dims, policy-bucketed) ----------- #
    new_e_max = max(pg.e_max, policy.bucket(need_e)) \
        if need_e > pg.e_max else pg.e_max
    new_v_max = max(pg.v_max, policy.bucket(need_v)) \
        if need_v > pg.v_max else pg.v_max
    if new_e_max > pg.e_max or new_v_max > pg.v_max:
        stats.repadded = True
        pg.esrc = _grow_cols(pg.esrc, new_e_max, 0)
        pg.edst = _grow_cols(pg.edst, new_e_max, 0)
        pg.ew = _grow_cols(pg.ew, new_e_max, 0.0)
        pg.emask = _grow_cols(pg.emask, new_e_max, False)
        pg.gvid = _grow_cols(pg.gvid, new_v_max, -1)
        pg.vmask = _grow_cols(pg.vmask, new_v_max, False)
        pg.out_deg = _grow_cols(pg.out_deg, new_v_max, 0.0)
        pg.in_deg = _grow_cols(pg.in_deg, new_v_max, 0.0)
        # slot/is_frontier/is_master are rebuilt below at the new width
        pg.e_max, pg.v_max = new_e_max, new_v_max
        if pg.vlabel is not None:
            pg.vlabel = _grow_cols(pg.vlabel, new_v_max, 0)

    for p, (lv, gs, gd, w, _) in staged.items():
        nv, ne = lv.shape[0], gs.shape[0]
        pg.gvid[p] = -1
        pg.gvid[p, :nv] = lv
        pg.vmask[p] = False
        pg.vmask[p, :nv] = True
        ls, ld, ww = localize_edges(lv, gs, gd, w)
        pg.esrc[p] = 0
        pg.edst[p] = 0
        pg.ew[p] = 0.0
        pg.emask[p] = False
        pg.esrc[p, :ne] = ls
        pg.edst[p, :ne] = ld
        pg.ew[p, :ne] = ww
        pg.emask[p, :ne] = True
    stats.parts_patched = len(staged)
    pg.n_edges += stats.n_added - stats.n_deleted
    pg.edge_part = None   # host-side assignment is stale after a patch

    # ---- old-row -> new-row remap (carries device-layout state) ----------- #
    # Patched partitions: old members keep their values at a new sorted
    # position; untouched partitions: rows do not move (column growth only
    # appends padding).
    remap = np.full((pg.n_parts, old_v_max), -1, np.int32)
    for p in range(pg.n_parts):
        st = staged.get(p)
        if st is None:
            n = int(old_nv[p])
            remap[p, :n] = np.arange(n, dtype=np.int32)
        else:
            lv, old_lv = st[0], st[4]
            remap[p, :old_lv.shape[0]] = np.searchsorted(
                lv, old_lv).astype(np.int32)
    stats.remap = remap
    stats.v_max_after = pg.v_max

    # ---- write refreshed full degrees to every replica -------------------- #
    # (rows of patched partitions were re-ordered and new members appeared,
    # so every replica row re-reads the updated global table; ctx's
    # routing_degrees stays frozen — that is the delta-routing contract)
    sel = pg.vmask
    pg.out_deg[sel] = g_out[pg.gvid[sel]].astype(np.float32)
    pg.in_deg[sel] = g_in[pg.gvid[sel]].astype(np.float32)

    # ---- frontier-slot + master maintenance ------------------------------ #
    recompute_frontier(pg)
    stats.n_slots_after = pg.n_slots

    # ---- Pallas edge-compute layouts: incremental refresh ----------------- #
    # Only the partitions this delta actually patched get their tile/window
    # geometry (and the touched rows of every cached tile realization)
    # rebuilt; capacities are grow-only buckets, so an in-bucket flush keeps
    # every compiled Pallas runner's input shapes intact. v_max growth moves
    # the tile/window grid itself — then the whole layout is rebuilt (it
    # coincides with a shape-key change, which already recompiles runners).
    if pg.edge_layouts is not None:
        lay = pg.edge_layouts
        if lay.sync_capacity(pg):
            lay.rebuild_partitions(pg, staged.keys())
        else:
            pg.edge_layouts = None
            pg.ensure_edge_layouts(shape_policy=lay.policy,
                                   block_edges=lay.block_edges)
    return stats


# --------------------------------------------------------------------------- #
# Membership compaction after delete-heavy traffic
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class CompactStats:
    """What ``compact`` did, plus the state-carrying remap."""

    n_evicted: int = 0               # replica rows removed
    v_max_before: int = 0
    v_max_after: int = 0
    e_max_before: int = 0
    e_max_after: int = 0
    n_slots_before: int = 0
    n_slots_after: int = 0
    remap: Optional[np.ndarray] = None   # [P, v_max_before] int32, -1 evicted

    @property
    def shrunk(self) -> bool:
        return (self.v_max_after < self.v_max_before
                or self.e_max_after < self.e_max_before)

    def remap_state(self, state: np.ndarray, fill) -> np.ndarray:
        """Carry a live ``[P, v_max_before(, K)]`` per-partition array across
        the compaction: surviving rows move to their new local index, evicted
        and padded rows get ``fill`` (use the program's combiner identity for
        warm-state blocks)."""
        return _remap_rows(self.remap, self.v_max_after, state, fill)


def compact(pg: PartitionedGraph, ctx: StreamContext,
            *, pad_multiple: int = 8,
            shape_policy: Optional[ShapePolicy] = None) -> CompactStats:
    """Evict edge-less members and shrink the padded capacities in place.

    Membership after compaction is exactly what a from-scratch re-ingest of
    the resident edges would produce: each partition keeps the endpoints of
    its resident edges, and vertices with no resident edge *anywhere* are
    re-homed by the same hash round-robin ingest uses for isolated vertices
    (so every global id stays collectable from a master replica). Resident
    edges never move — placement is frozen in ``ctx`` — so slots and masters
    are re-elected (``n_slots`` shrinks with the evicted frontier rows) but
    the graph itself is unchanged: a previous converged result remains a
    valid warm start after ``compact``.

    Returns ``CompactStats``; ``stats.remap_state`` carries live
    ``[P, v_max, K]`` device-layout state into the compacted layout. Global
    ``[n_vertices]`` results (``pg.collect``) are untouched by compaction.

    Under a bucketed ``shape_policy`` the capacities shrink to the **bucket
    floor** (the smallest bucket that still fits the compacted content), not
    the exact minimum — so a session that compacts and then regrows inside
    the same bucket keeps its padded shapes, and with them every compiled
    runner.
    """
    assert ctx.n_parts == pg.n_parts, (ctx.n_parts, pg.n_parts)
    P = pg.n_parts
    stats = CompactStats(v_max_before=pg.v_max, e_max_before=pg.e_max,
                         n_slots_before=pg.n_slots)
    members_before = int(pg.vmask.sum())

    part_edges = []
    members = []
    touched = np.zeros(pg.n_vertices, bool)
    for p in range(P):
        m = pg.emask[p]
        gs = pg.gvid[p][pg.esrc[p][m]]
        gd = pg.gvid[p][pg.edst[p][m]]
        part_edges.append((gs, gd, pg.ew[p][m]))
        lv = np.unique(np.concatenate([gs, gd]))
        members.append(lv)
        touched[lv] = True

    iso = np.nonzero(~touched)[0].astype(np.int64)
    if iso.size:
        iso_part = route_vertices_rh(iso, P)
        for p in range(P):
            mine = iso[iso_part == p]
            if mine.size:
                members[p] = np.unique(np.concatenate([members[p], mine]))

    stats.remap = repack_partitions(pg, members, part_edges,
                                    pad_multiple=pad_multiple,
                                    shape_policy=shape_policy)
    stats.n_evicted = members_before - int(pg.vmask.sum())
    stats.v_max_after = pg.v_max
    stats.e_max_after = pg.e_max
    stats.n_slots_after = pg.n_slots
    return stats
