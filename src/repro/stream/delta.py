"""Incremental graph mutation: route edge deltas with the frozen pure
hashes and patch the affected partitions in place.

An ``EdgeDelta`` (insert + delete batches) is routed through the *same*
``StreamContext`` the graph was ingested with, so every mutation lands in
exactly the partition a full re-ingest would choose — no global re-shuffle,
no re-routing of resident edges. Only partitions that actually receive a
mutation are rebuilt (O(partition) each); a partition whose new edge count
overflows ``e_max`` triggers a grow-and-re-pad of the dense arrays (the
padded capacity is shared across partitions by construction). Vertex-level
metadata (frontier slots, master election, full degrees) is recomputed from
the patched membership — O(P * v_max), cheap next to any edge pass — using
the same hash election as the builders.

Membership is grow-only between compactions: a vertex whose last local edge
was deleted stays a (edge-less) member of its partition. That is harmless —
it contributes nothing to sweeps and only its own initial value to SBS — and
keeps deletion O(partition). ``n_vertices`` grows automatically when a delta
references ids beyond the current space.

Warm-start pairing: after ``apply_delta``, monotone programs (SSSP/MSSP/CC)
can restart from the previous converged result via ``run_sim(...,
init_state=prev)`` — sound for *insert-only* deltas, where old values remain
valid upper bounds. ``apply_delta`` reports ``warm_start_safe`` accordingly;
deletions require a cold start (the engine also refuses warm starts for
non-monotone programs on its own).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.subgraph import PartitionedGraph, recompute_frontier
from repro.stream.ingest import StreamContext

__all__ = ["EdgeDelta", "DeltaStats", "apply_delta"]


@dataclasses.dataclass
class EdgeDelta:
    """A batch of edge mutations in global vertex ids."""

    add_src: np.ndarray = dataclasses.field(
        default_factory=lambda: np.empty(0, np.int64))
    add_dst: np.ndarray = dataclasses.field(
        default_factory=lambda: np.empty(0, np.int64))
    add_w: Optional[np.ndarray] = None       # None -> unit weights
    del_src: np.ndarray = dataclasses.field(
        default_factory=lambda: np.empty(0, np.int64))
    del_dst: np.ndarray = dataclasses.field(
        default_factory=lambda: np.empty(0, np.int64))

    def __post_init__(self):
        self.add_src = np.asarray(self.add_src, np.int64)
        self.add_dst = np.asarray(self.add_dst, np.int64)
        self.del_src = np.asarray(self.del_src, np.int64)
        self.del_dst = np.asarray(self.del_dst, np.int64)
        if self.add_w is not None:
            self.add_w = np.asarray(self.add_w, np.float32)
            assert self.add_w.shape == self.add_src.shape
        assert self.add_src.shape == self.add_dst.shape
        assert self.del_src.shape == self.del_dst.shape

    @property
    def n_adds(self) -> int:
        return int(self.add_src.shape[0])

    @property
    def n_dels(self) -> int:
        return int(self.del_src.shape[0])

    @property
    def max_id(self) -> int:
        parts = [a.max() for a in (self.add_src, self.add_dst,
                                   self.del_src, self.del_dst) if a.size]
        return int(max(parts)) if parts else -1


@dataclasses.dataclass
class DeltaStats:
    n_added: int = 0
    n_deleted: int = 0               # edges actually found and removed
    parts_patched: int = 0
    repadded: bool = False           # e_max/v_max grew (dense arrays re-pad)
    n_slots_before: int = 0
    n_slots_after: int = 0
    warm_start_safe: bool = False    # True for insert-only deltas


def _round_up(n: int, m: int) -> int:
    return int(-(-max(n, 1) // m) * m)


def _grow_cols(arr: np.ndarray, n: int, fill) -> np.ndarray:
    if arr.shape[1] >= n:
        return arr
    out = np.full((arr.shape[0], n) + arr.shape[2:], fill, dtype=arr.dtype)
    out[:, :arr.shape[1]] = arr
    return out


def _edge_key(src: np.ndarray, dst: np.ndarray, n_vertices: int) -> np.ndarray:
    # Collision-free for n_vertices < 2^31.5; the dense in-memory builder has
    # the same id-space envelope (local indices are int32).
    return src.astype(np.int64) * np.int64(n_vertices) + dst.astype(np.int64)


def apply_delta(pg: PartitionedGraph, ctx: StreamContext, delta: EdgeDelta,
                *, pad_multiple: int = 8) -> DeltaStats:
    """Apply ``delta`` to ``pg`` in place, routing through ``ctx``.

    Deletions remove *every* resident copy of a (src, dst) pair in the
    partition the pair routes to; pairs that are not resident are ignored.
    """
    stats = DeltaStats(n_slots_before=pg.n_slots,
                       warm_start_safe=delta.n_dels == 0)
    if delta.n_adds == 0 and delta.n_dels == 0:
        stats.n_slots_after = pg.n_slots
        return stats

    # ---- id-space growth ------------------------------------------------ #
    new_v = max(pg.n_vertices, delta.max_id + 1)
    ctx.grow(new_v)
    pg.n_vertices = new_v

    # ---- route mutations through the frozen hashes ----------------------- #
    add_part = ctx.route(delta.add_src, delta.add_dst)
    del_part = ctx.route(delta.del_src, delta.del_dst)
    add_w = (np.ones(delta.n_adds, np.float32) if delta.add_w is None
             else delta.add_w)
    affected = np.unique(np.concatenate([add_part, del_part]))

    # Current full degrees, reconstructed from replica rows while they are
    # still aligned with gvid (all replicas agree on the value); the delta's
    # shifts are folded in below — O(V + delta), no global edge re-scan.
    g_out = np.zeros(new_v, np.float64)
    g_in = np.zeros(new_v, np.float64)
    sel = pg.vmask
    g_out[pg.gvid[sel]] = pg.out_deg[sel]
    g_in[pg.gvid[sel]] = pg.in_deg[sel]
    g_out += np.bincount(delta.add_src, minlength=new_v)
    g_in += np.bincount(delta.add_dst, minlength=new_v)

    # ---- rebuild each affected partition's local arrays ------------------ #
    # Rebuilt content is staged, then written after any capacity growth.
    staged = {}
    need_e = int(pg.e_max)
    need_v = int(pg.v_max)
    for p in affected.tolist():
        m = pg.emask[p]
        gs = pg.gvid[p][pg.esrc[p][m]]
        gd = pg.gvid[p][pg.edst[p][m]]
        w = pg.ew[p][m]

        dsel = del_part == p
        if dsel.any():
            dkey = _edge_key(delta.del_src[dsel], delta.del_dst[dsel], new_v)
            keep = ~np.isin(_edge_key(gs, gd, new_v), dkey)
            stats.n_deleted += int(gs.shape[0] - keep.sum())
            if not keep.all():   # only matched copies shift degrees
                g_out -= np.bincount(gs[~keep], minlength=new_v)
                g_in -= np.bincount(gd[~keep], minlength=new_v)
            gs, gd, w = gs[keep], gd[keep], w[keep]

        asel = add_part == p
        if asel.any():
            gs = np.concatenate([gs, delta.add_src[asel]])
            gd = np.concatenate([gd, delta.add_dst[asel]])
            w = np.concatenate([w, add_w[asel]])
            stats.n_added += int(asel.sum())

        # grow-only membership: old members stay, new endpoints join
        lv = np.unique(np.concatenate([pg.gvid[p][pg.vmask[p]], gs, gd]))
        staged[p] = (lv, gs, gd, w)
        need_e = max(need_e, gs.shape[0])
        need_v = max(need_v, lv.shape[0])

    # ---- capacity growth (shared padded dims) ---------------------------- #
    new_e_max = _round_up(need_e, pad_multiple) if need_e > pg.e_max else pg.e_max
    new_v_max = _round_up(need_v, pad_multiple) if need_v > pg.v_max else pg.v_max
    if new_e_max > pg.e_max or new_v_max > pg.v_max:
        stats.repadded = True
        pg.esrc = _grow_cols(pg.esrc, new_e_max, 0)
        pg.edst = _grow_cols(pg.edst, new_e_max, 0)
        pg.ew = _grow_cols(pg.ew, new_e_max, 0.0)
        pg.emask = _grow_cols(pg.emask, new_e_max, False)
        pg.gvid = _grow_cols(pg.gvid, new_v_max, -1)
        pg.vmask = _grow_cols(pg.vmask, new_v_max, False)
        pg.out_deg = _grow_cols(pg.out_deg, new_v_max, 0.0)
        pg.in_deg = _grow_cols(pg.in_deg, new_v_max, 0.0)
        # slot/is_frontier/is_master are rebuilt below at the new width
        pg.e_max, pg.v_max = new_e_max, new_v_max
        if pg.vlabel is not None:
            pg.vlabel = _grow_cols(pg.vlabel, new_v_max, 0)

    for p, (lv, gs, gd, w) in staged.items():
        nv, ne = lv.shape[0], gs.shape[0]
        pg.gvid[p] = -1
        pg.gvid[p, :nv] = lv
        pg.vmask[p] = False
        pg.vmask[p, :nv] = True
        ls = np.searchsorted(lv, gs).astype(np.int32)
        ld = np.searchsorted(lv, gd).astype(np.int32)
        eo = np.argsort(ld, kind="stable")
        pg.esrc[p] = 0
        pg.edst[p] = 0
        pg.ew[p] = 0.0
        pg.emask[p] = False
        pg.esrc[p, :ne] = ls[eo]
        pg.edst[p, :ne] = ld[eo]
        pg.ew[p, :ne] = w[eo]
        pg.emask[p, :ne] = True
    stats.parts_patched = len(staged)
    pg.n_edges += stats.n_added - stats.n_deleted
    pg.edge_part = None   # host-side assignment is stale after a patch

    # ---- write refreshed full degrees to every replica -------------------- #
    # (rows of patched partitions were re-ordered and new members appeared,
    # so every replica row re-reads the updated global table; ctx's
    # routing_degrees stays frozen — that is the delta-routing contract)
    sel = pg.vmask
    pg.out_deg[sel] = g_out[pg.gvid[sel]].astype(np.float32)
    pg.in_deg[sel] = g_in[pg.gvid[sel]].astype(np.float32)

    # ---- frontier-slot + master maintenance ------------------------------ #
    recompute_frontier(pg)
    stats.n_slots_after = pg.n_slots
    return stats
