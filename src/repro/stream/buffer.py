"""Delta batching under continuous producer traffic.

``apply_delta`` is O(affected partitions) per call: a producer emitting one
edge at a time pays a partition rebuild *and* a full ``recompute_frontier``
per edge. The ``DeltaBuffer`` sits between the producer and ``apply_delta``,
coalescing the op stream per (src, dst) pair and flushing one merged
``EdgeDelta`` when a threshold trips — N tiny patches become one partition
rebuild with one frontier re-election.

Coalescing preserves *sequential* semantics — the flushed graph equals
applying the buffered ops one ``apply_delta`` at a time in arrival order —
with one documented coarsening: duplicate adds of a live pair merge into a
single resident copy (last weight wins) instead of accumulating parallel
copies. The per-pair state machine:

  op stream (oldest -> newest)       buffered state     flushed as
  ---------------------------------  -----------------  -------------------
  add(w)                             ADD(w)             insert
  add(w) ... add(w')                 ADD(w')            insert (merged)
  add(w) ... delete                  DEL                delete only [#]
  delete                             DEL                delete
  delete ... add(w)                  DEL_ADD(w)         delete, then insert
  delete ... add(w) ... delete       DEL                delete

[#] the buffered add cancels in-buffer; the delete still flushes because
``apply_delta`` deletions target every *resident* copy of the pair — there
may be pre-buffer copies on device — and deleting a non-resident pair is a
no-op. ``apply_delta`` applies a flushed batch deletes-first, which is
exactly the DEL_ADD ordering.

Invariants: the buffer never mutates the graph outside ``flush`` (reads
between flushes see the pre-buffer graph — callers who need the tail must
flush first, which ``GraphSession.query`` does automatically); flush order
over pairs is deterministic (sorted), so identical op streams produce
identical patches; the configured ``shape_policy`` is forwarded to every
``apply_delta``, so a session's bucket choices apply to auto-flushes too.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.subgraph import (PartitionedGraph, ShapePolicy,
                                 resolve_shape_policy)
from repro.stream.delta import DeltaStats, EdgeDelta, apply_delta
from repro.stream.ingest import StreamContext

__all__ = ["BufferStats", "DeltaBuffer"]

_ADD, _DEL, _DEL_ADD = 0, 1, 2


@dataclasses.dataclass
class BufferStats:
    """Cumulative producer-side accounting across the buffer's lifetime."""

    ops_in: int = 0              # add/delete ops the producer enqueued
    adds_merged: int = 0         # duplicate adds collapsed in-buffer
    adds_cancelled: int = 0      # buffered adds consumed by a later delete
    dels_merged: int = 0         # duplicate deletes collapsed in-buffer
    n_flushes: int = 0
    auto_flushes: int = 0        # flushes tripped by a threshold
    edges_flushed: int = 0       # add+del entries handed to apply_delta

    @property
    def coalesced(self) -> int:
        return self.adds_merged + self.adds_cancelled + self.dels_merged


class DeltaBuffer:
    """Coalescing write buffer in front of ``apply_delta``.

    ``max_edges``: auto-flush when the number of distinct buffered pairs
    reaches this bound. ``max_parts``: auto-flush when the buffered pairs
    touch this many partitions (each touched partition is rebuilt at flush,
    so this caps per-flush patch latency). Pass ``None`` to disable either
    trigger; ``flush()`` can always be called manually (and must be, before
    reading results that should see the buffered tail).
    """

    def __init__(self, pg: PartitionedGraph, ctx: StreamContext, *,
                 max_edges: Optional[int] = 4096,
                 max_parts: Optional[int] = None,
                 pad_multiple: int = 8,
                 shape_policy: Optional[ShapePolicy] = None):
        self.pg = pg
        self.ctx = ctx
        self.max_edges = max_edges
        self.max_parts = max_parts
        # resolve once: an explicit policy carries its own tiling, the bare
        # pad_multiple is only consulted when no policy is given
        self.shape_policy = resolve_shape_policy(shape_policy, pad_multiple)
        self.pad_multiple = self.shape_policy.pad_multiple
        self.stats = BufferStats()
        self._ops: dict = {}          # (src, dst) -> (STATE, weight|None)
        self._parts: set = set()
        self.last_flush: Optional[DeltaStats] = None

    # ------------------------------------------------------------------ #
    def __len__(self) -> int:
        return len(self._ops)

    @property
    def pending_edges(self) -> int:
        return len(self._ops)

    @property
    def pending_parts(self) -> int:
        return len(self._parts)

    # ------------------------------------------------------------------ #
    def add(self, src, dst, w=None) -> None:
        src = np.atleast_1d(np.asarray(src, np.int64))
        dst = np.atleast_1d(np.asarray(dst, np.int64))
        ww = (np.ones(src.shape, np.float32) if w is None
              else np.atleast_1d(np.asarray(w, np.float32)))
        assert src.shape == dst.shape == ww.shape
        self._touch(src, dst)
        for s, d, x in zip(src.tolist(), dst.tolist(), ww.tolist()):
            self._push_add((s, d), np.float32(x))
        self._maybe_flush()

    def delete(self, src, dst) -> None:
        src = np.atleast_1d(np.asarray(src, np.int64))
        dst = np.atleast_1d(np.asarray(dst, np.int64))
        assert src.shape == dst.shape
        self._touch(src, dst)
        for s, d in zip(src.tolist(), dst.tolist()):
            self._push_del((s, d))
        self._maybe_flush()

    def push(self, delta: EdgeDelta) -> None:
        """Enqueue a whole producer ``EdgeDelta`` (its deletes are older
        than its adds, matching ``apply_delta`` batch order)."""
        if delta.n_dels:
            self.delete(delta.del_src, delta.del_dst)
        if delta.n_adds:
            self.add(delta.add_src, delta.add_dst, delta.add_w)

    # ------------------------------------------------------------------ #
    def _push_add(self, key, w) -> None:
        self.stats.ops_in += 1
        cur = self._ops.get(key)
        if cur is None:
            self._ops[key] = (_ADD, w)
        elif cur[0] == _ADD:
            self.stats.adds_merged += 1
            self._ops[key] = (_ADD, w)
        elif cur[0] == _DEL:
            self._ops[key] = (_DEL_ADD, w)
        else:                                   # DEL_ADD: merge the add leg
            self.stats.adds_merged += 1
            self._ops[key] = (_DEL_ADD, w)

    def _push_del(self, key) -> None:
        self.stats.ops_in += 1
        cur = self._ops.get(key)
        if cur is None:
            self._ops[key] = (_DEL, None)
        elif cur[0] == _DEL:
            self.stats.dels_merged += 1
        else:                                   # ADD or DEL_ADD: cancel add
            self.stats.adds_cancelled += 1
            self._ops[key] = (_DEL, None)

    def _touch(self, src, dst) -> None:
        if self.max_parts is not None:
            # brand-new ids must grow the routing snapshot before they can
            # be routed (apply_delta does the same at flush; grow is
            # monotonic and zero-extending, so growing early is harmless)
            hi = int(max(src.max(), dst.max()))
            if hi >= self.ctx.n_vertices:
                self.ctx.grow(hi + 1)
            # route() is the non-mutating preview: a stateful router must
            # not commit placements for ops that are merely buffered (the
            # flush's apply_delta does the committing route_adds call)
            self._parts.update(self.ctx.route(src, dst).tolist())

    def _maybe_flush(self) -> None:
        if ((self.max_edges is not None
             and len(self._ops) >= self.max_edges)
                or (self.max_parts is not None
                    and len(self._parts) >= self.max_parts)):
            self.flush(_auto=True)

    # ------------------------------------------------------------------ #
    def flush(self, _auto: bool = False) -> Optional[DeltaStats]:
        """Resolve the buffer into one ``EdgeDelta`` and apply it. Returns
        the patch's ``DeltaStats`` (also kept as ``self.last_flush``), or
        None if nothing was buffered."""
        if not self._ops:
            return None
        keys = sorted(self._ops)                # deterministic flush order
        asrc, adst, aw, dsrc, ddst = [], [], [], [], []
        for k in keys:
            state, w = self._ops[k]
            if state in (_DEL, _DEL_ADD):
                dsrc.append(k[0])
                ddst.append(k[1])
            if state in (_ADD, _DEL_ADD):
                asrc.append(k[0])
                adst.append(k[1])
                aw.append(w)
        delta = EdgeDelta(
            add_src=np.array(asrc, np.int64), add_dst=np.array(adst, np.int64),
            add_w=np.array(aw, np.float32) if aw else None,
            del_src=np.array(dsrc, np.int64), del_dst=np.array(ddst, np.int64))
        self._ops.clear()
        self._parts.clear()
        self.stats.n_flushes += 1
        self.stats.auto_flushes += int(_auto)
        self.stats.edges_flushed += delta.n_adds + delta.n_dels
        self.last_flush = apply_delta(self.pg, self.ctx, delta,
                                      shape_policy=self.shape_policy)
        return self.last_flush
