"""Chunked on-disk edge log — the out-of-core graph representation.

Layout (one directory per log):

    <path>/manifest.json            {"n_vertices", "n_edges", "weighted",
                                     "chunk_size", "chunk_edges": [...]}
    <path>/chunk_000000.npz         src:int64[c], dst:int64[c][, w:f32[c]]
    <path>/chunk_000001.npz         ...

Chunks are bounded at ``chunk_size`` edges, so any consumer that processes
one chunk at a time holds O(chunk_size) edge data — never O(|E|). The same
writer/reader pair serves both the user-facing edge log and the ingest
pipeline's per-partition spill shards (repro.stream.ingest pass 2).

Writes are streaming-append (``EdgeLogWriter.append``) with an atomic
manifest rename on ``close()``, so a crashed producer never leaves a log
that parses as complete.

Invariants: chunk order preserves append order (ingest parity with the
in-memory path depends on it); ``BYTES_PER_EDGE`` (int64 src + int64 dst +
float32 w = 20) is the accounting constant the ingest memory contract and
the benchmarks bill transient edge buffers with; the manifest's
``n_vertices`` covers every appended id (the writer tracks ``max(id) + 1``
and widens a caller-declared id-space that turns out too small).
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Iterator, Optional

import numpy as np

from repro.core.graph import Graph

__all__ = ["EdgeLogMeta", "EdgeLogWriter", "EdgeLogReader", "write_edge_log"]

_MANIFEST = "manifest.json"
# host bytes per buffered edge: int64 src + int64 dst + float32 w
BYTES_PER_EDGE = 20


@dataclasses.dataclass(frozen=True)
class EdgeLogMeta:
    n_vertices: int
    n_edges: int
    n_chunks: int
    chunk_size: int
    weighted: bool


def _chunk_name(i: int) -> str:
    return f"chunk_{i:06d}.npz"


class EdgeLogWriter:
    """Append edges; flush a chunk file whenever ``chunk_size`` is reached.

    ``n_vertices`` may be passed (id-space is known up front) or inferred as
    ``max(id) + 1`` over everything appended.
    """

    def __init__(self, path: str, *, chunk_size: int = 1 << 20,
                 weighted: bool = False, n_vertices: Optional[int] = None):
        assert chunk_size > 0
        self.path = path
        self.chunk_size = int(chunk_size)
        self.weighted = weighted
        self._given_nv = n_vertices
        self._max_id = -1
        self._n_edges = 0
        self._chunk_edges: list[int] = []
        self._buf_src: list[np.ndarray] = []
        self._buf_dst: list[np.ndarray] = []
        self._buf_w: list[np.ndarray] = []
        self._buffered = 0
        self._closed = False
        os.makedirs(path, exist_ok=True)

    # ------------------------------------------------------------------ #
    @property
    def buffered_nbytes(self) -> int:
        """Host bytes currently buffered (ingest chunk accounting)."""
        return self._buffered * BYTES_PER_EDGE

    def append(self, src, dst, w=None) -> None:
        assert not self._closed
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        if src.size == 0:
            return
        if self.weighted:
            w = (np.ones(src.shape, np.float32) if w is None
                 else np.asarray(w, dtype=np.float32))
            assert w.shape == src.shape
        self._max_id = max(self._max_id, int(src.max()), int(dst.max()))
        self._buf_src.append(src)
        self._buf_dst.append(dst)
        if self.weighted:
            self._buf_w.append(w)
        self._buffered += src.size
        self._n_edges += src.size
        if self._buffered >= self.chunk_size:
            self._drain(self.chunk_size)

    def _concat(self):
        src = np.concatenate(self._buf_src) if self._buf_src else \
            np.empty(0, np.int64)
        dst = np.concatenate(self._buf_dst) if self._buf_dst else \
            np.empty(0, np.int64)
        w = (np.concatenate(self._buf_w) if self._buf_w else
             np.empty(0, np.float32)) if self.weighted else None
        return src, dst, w

    def _write_chunk(self, src, dst, w) -> None:
        out = {"src": src, "dst": dst}
        if self.weighted:
            out["w"] = w
        idx = len(self._chunk_edges)
        np.savez(os.path.join(self.path, _chunk_name(idx)), **out)
        self._chunk_edges.append(int(src.shape[0]))

    def _drain(self, min_tail: int) -> None:
        """Flush full chunks; keep a < ``min_tail`` remainder buffered.
        Concatenates the backlog ONCE and slices windows off it (a large
        append flushing k chunks copies O(backlog), not O(k * backlog))."""
        src, dst, w = self._concat()
        off, n, cs = 0, src.shape[0], self.chunk_size
        while n - off >= max(min_tail, 1):
            take = min(cs, n - off)
            self._write_chunk(src[off:off + take], dst[off:off + take],
                              None if w is None else w[off:off + take])
            off += take
        self._buf_src = [src[off:]] if off < n else []
        self._buf_dst = [dst[off:]] if off < n else []
        if self.weighted:
            self._buf_w = [w[off:]] if off < n else []
        self._buffered = n - off

    # ------------------------------------------------------------------ #
    def close(self) -> EdgeLogMeta:
        if self._closed:
            return self.meta
        if self._buffered:
            self._drain(1)   # flush everything, remainder included
        # cover every appended id even when the caller declared a smaller
        # id-space (a short manifest would crash ingest's degree bincount)
        n_v = self._max_id + 1 if self._given_nv is None \
            else max(self._given_nv, self._max_id + 1)
        meta = dict(n_vertices=int(max(n_v, 0)), n_edges=self._n_edges,
                    weighted=self.weighted, chunk_size=self.chunk_size,
                    chunk_edges=self._chunk_edges)
        tmp = os.path.join(self.path, _MANIFEST + ".tmp")
        with open(tmp, "w") as f:
            json.dump(meta, f)
        os.replace(tmp, os.path.join(self.path, _MANIFEST))
        self._closed = True
        self._meta = EdgeLogMeta(meta["n_vertices"], meta["n_edges"],
                                 len(self._chunk_edges), self.chunk_size,
                                 self.weighted)
        return self._meta

    @property
    def meta(self) -> EdgeLogMeta:
        assert self._closed, "close() the writer first"
        return self._meta

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        if exc[0] is None:
            self.close()


class EdgeLogReader:
    """Iterate (src, dst, w) chunk triples; ``w`` is None when unweighted."""

    def __init__(self, path: str):
        self.path = path
        with open(os.path.join(path, _MANIFEST)) as f:
            m = json.load(f)
        self.meta = EdgeLogMeta(m["n_vertices"], m["n_edges"],
                                len(m["chunk_edges"]), m["chunk_size"],
                                m["weighted"])
        self._chunk_edges = m["chunk_edges"]

    def chunks(self) -> Iterator[tuple]:
        for i in range(self.meta.n_chunks):
            with np.load(os.path.join(self.path, _chunk_name(i))) as z:
                w = z["w"] if self.meta.weighted else None
                yield z["src"], z["dst"], w

    def __iter__(self):
        return self.chunks()

    def read_all(self) -> tuple:
        """Concatenate every chunk (spill-shard assembly: one partition's
        shards are loaded together, bounded by that partition's size)."""
        srcs, dsts, ws = [], [], []
        for s, d, w in self.chunks():
            srcs.append(s)
            dsts.append(d)
            if w is not None:
                ws.append(w)
        if not srcs:
            return (np.empty(0, np.int64), np.empty(0, np.int64),
                    np.empty(0, np.float32) if self.meta.weighted else None)
        return (np.concatenate(srcs), np.concatenate(dsts),
                np.concatenate(ws) if self.meta.weighted else None)


def write_edge_log(g: Graph, path: str, *,
                   chunk_size: int = 1 << 20) -> EdgeLogMeta:
    """Spill an in-memory Graph to a chunked edge log (tests/benchmarks;
    production producers append straight to an EdgeLogWriter)."""
    with EdgeLogWriter(path, chunk_size=chunk_size,
                       weighted=g.weight is not None,
                       n_vertices=g.n_vertices) as w:
        for lo in range(0, g.n_edges, chunk_size):
            hi = min(lo + chunk_size, g.n_edges)
            w.append(g.src[lo:hi], g.dst[lo:hi],
                     None if g.weight is None else g.weight[lo:hi])
    return w.meta
