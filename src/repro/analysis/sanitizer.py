"""Runtime retrace sanitizer: assert a region performed no hidden compiles.

The static rules (DL001-DL005) catch retrace *hazards*; this module catches
the retrace itself. :func:`retrace_guard` wraps any region — a query loop, a
serving benchmark, a test body — and raises :class:`RetraceError` (or warns,
configurable) when jax's tracing counter shows a compile the region did not
account for. It replaces the trace-counter boilerplate that used to be
copy-pasted across ``test_session.py``/``test_edge_backends.py``/
``test_serving.py``:

    with retrace_guard():                 # was: jtu.count_jit_tracing_...
        for q in queries:
            session.query(prog, q)        # any retrace -> RetraceError

Sessions whose compiles are *expected* (cold-start runner builds) are passed
in so their ``stats.cache_misses`` deltas excuse the traces they cause:

    with retrace_guard(session, pool):    # cold compiles allowed,
        ...                               # anything else raises

Production use: ``GraphSession(debug_sanitize=True)`` arms the guard around
every cache-hit launch — an AOT-compiled runner re-entering the tracer is
always a bug — and ``debug_sanitize="warn"`` downgrades it to a warning.

The counter is ``jax._src.test_util.count_jit_tracing_cache_miss`` (private
but stable across the pinned jax line). When unavailable the guard degrades
gracefully: ``guard.traces`` is ``None`` and only session-counter checks
run.
"""
from __future__ import annotations

import contextlib
import dataclasses
import warnings
from typing import Iterator, Optional

__all__ = ["RetraceError", "RetraceWarning", "RetraceGuard",
           "retrace_guard"]


class RetraceError(RuntimeError):
    """An unexpected jax trace/compile happened inside a guarded region."""


class RetraceWarning(UserWarning):
    """Warning twin of :class:`RetraceError` (``action="warn"``)."""


def _tracing_counter():
    """The jax tracing-cache-miss counter context manager, or None."""
    try:
        import jax._src.test_util as jtu
        return jtu.count_jit_tracing_cache_miss
    except (ImportError, AttributeError):  # pragma: no cover - old jax
        return None


@dataclasses.dataclass
class RetraceGuard:
    """What the guarded region did; populated when the ``with`` exits.

    traces             jit tracing-cache misses observed (None when the
                       jax counter is unavailable)
    expected_compiles  runner compiles the passed sessions recorded —
                       these excuse their traces
    allow              extra traces tolerated (constructor arg)
    triggered          the guard found unexpected traces (after the region
                       raised or warned, for ``action="warn"`` callers)
    """

    traces: Optional[int] = None
    expected_compiles: int = 0
    allow: int = 0
    triggered: bool = False

    @property
    def unexpected(self) -> int:
        if self.traces is None or self.expected_compiles > 0:
            return 0
        return max(0, self.traces - self.allow)


@contextlib.contextmanager
def retrace_guard(*sessions, allow: int = 0, action: str = "raise",
                  label: str = "") -> Iterator[RetraceGuard]:
    """Fail if the region traced more than its sessions' compiles explain.

    sessions   objects with ``stats.cache_misses`` (``GraphSession``,
               ``SessionPool`` members, ...). Compiles they record inside
               the region are expected — a cold start may trace several
               internal jits, so any recorded compile disarms the count
               check for that region.
    allow      tolerated traces when no session compile occurred (for
               regions that intentionally build one ad-hoc jit).
    action     ``"raise"`` -> :class:`RetraceError`,
               ``"warn"`` -> :class:`RetraceWarning`.
    label      prefix for the error message (e.g. the query being served).
    """
    if action not in ("raise", "warn"):
        raise ValueError(f"retrace_guard action must be 'raise' or 'warn', "
                         f"got {action!r}")
    guard = RetraceGuard(allow=allow)
    before = [s.stats.cache_misses for s in sessions]
    counter = _tracing_counter()
    if counter is None:                       # pragma: no cover - old jax
        yield guard
        guard.expected_compiles = sum(
            s.stats.cache_misses - b for s, b in zip(sessions, before))
        return
    with counter() as tracked:
        yield guard
    guard.traces = int(tracked[0])
    guard.expected_compiles = sum(
        s.stats.cache_misses - b for s, b in zip(sessions, before))
    if guard.unexpected:
        guard.triggered = True
        where = f"{label}: " if label else ""
        msg = (f"{where}{guard.traces} unexpected jax trace(s) in a "
               f"retrace_guard region (expected_compiles="
               f"{guard.expected_compiles}, allow={guard.allow}). A "
               f"compiled runner re-entered the tracer — check for "
               f"closure-captured arrays (DL001), unstable cache keys "
               f"(DL002), or shape/dtype drift in the inputs.")
        if action == "raise":
            raise RetraceError(msg)
        warnings.warn(msg, RetraceWarning, stacklevel=3)
