"""drone-lint framework: findings, rule registry, suppressions, baseline.

A *rule* is a function ``(tree, src, path) -> Iterable[Finding]`` registered
with the :func:`rule` decorator under a ``DLnnn`` code. :func:`analyze_source`
runs every (selected) rule over one parsed module and filters findings that
an inline ``# drone-lint: disable=DLnnn`` comment suppresses — on the flagged
line itself or the line directly above it.

The *baseline* is a checked-in JSON multiset of finding fingerprints
``(rule, path, stripped source line text)`` — line numbers are deliberately
not part of the fingerprint so unrelated edits above a baselined finding do
not resurrect it. ``baseline_delta`` subtracts the baseline from a fresh run;
CI fails only on the delta, so pre-existing findings never block a PR while
every *new* one does.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
import re
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "Finding", "Rule", "RULES", "rule",
    "analyze_source", "analyze_file", "analyze_paths",
    "load_baseline", "write_baseline", "baseline_delta",
]


@dataclasses.dataclass(frozen=True)
class Finding:
    """One diagnostic: ``path:line:col  CODE severity  message``."""

    rule: str                 # "DL001"
    path: str                 # repo-relative (or as passed) file path
    line: int                 # 1-based
    col: int                  # 0-based
    message: str
    severity: str = "error"   # "error" | "warning"
    line_text: str = ""       # stripped source line (fingerprint component)

    @property
    def fingerprint(self) -> Tuple[str, str, str]:
        """Line-number-free identity used by the baseline: the same finding
        survives unrelated edits elsewhere in the file."""
        return (self.rule, self.path, self.line_text)

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col + 1}: "
                f"{self.rule} [{self.severity}] {self.message}")


@dataclasses.dataclass(frozen=True)
class Rule:
    code: str
    severity: str
    summary: str
    check: Callable[[ast.AST, str, str], Iterable[Finding]]


RULES: Dict[str, "Rule"] = {}


def rule(code: str, severity: str, summary: str):
    """Register a checker under ``code``; the checker yields findings with
    only (line, col, message) — the registry fills rule/severity/text."""
    def deco(fn):
        RULES[code] = Rule(code=code, severity=severity, summary=summary,
                           check=fn)
        return fn
    return deco


# ------------------------------------------------------------------ #
# suppressions
_DISABLE = re.compile(r"#\s*drone-lint:\s*disable=([\w,\s]+)")


def _suppressed_codes(src_lines: Sequence[str]) -> Dict[int, set]:
    """Map 1-based line number -> set of codes disabled on that line
    (``all`` disables every rule). A trailing comment covers its own line;
    a comment alone on a line also covers the line below it."""
    out: Dict[int, set] = {}
    for i, line in enumerate(src_lines, 1):
        m = _DISABLE.search(line)
        if not m:
            continue
        codes = {c.strip().upper() for c in m.group(1).split(",") if c.strip()}
        out.setdefault(i, set()).update(codes)
        if line.split("#", 1)[0].strip() == "":   # comment-only line
            out.setdefault(i + 1, set()).update(codes)
    return out


def _is_suppressed(f: Finding, supp: Dict[int, set]) -> bool:
    codes = supp.get(f.line, set())
    return "ALL" in codes or f.rule in codes


# ------------------------------------------------------------------ #
# drivers
def analyze_source(src: str, path: str,
                   select: Optional[Sequence[str]] = None) -> List[Finding]:
    """Run the (selected) rules over one module's source text."""
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [Finding(rule="DL000", path=path, line=e.lineno or 1,
                        col=(e.offset or 1) - 1, severity="error",
                        message=f"syntax error: {e.msg}",
                        line_text=(e.text or "").strip())]
    lines = src.splitlines()
    supp = _suppressed_codes(lines)
    out: List[Finding] = []
    for code in sorted(RULES):
        if select and code not in select:
            continue
        r = RULES[code]
        for f in r.check(tree, src, path):
            text = lines[f.line - 1].strip() if 0 < f.line <= len(lines) \
                else ""
            f = dataclasses.replace(f, rule=code, severity=r.severity,
                                    path=path, line_text=text)
            if not _is_suppressed(f, supp):
                out.append(f)
    out.sort(key=lambda f: (f.path, f.line, f.rule))
    return out


def analyze_file(path: str,
                 select: Optional[Sequence[str]] = None,
                 relative_to: Optional[str] = None) -> List[Finding]:
    with open(path, encoding="utf-8") as fh:
        src = fh.read()
    rel = os.path.relpath(path, relative_to) if relative_to else path
    return analyze_source(src, rel.replace(os.sep, "/"), select=select)


def analyze_paths(paths: Sequence[str],
                  select: Optional[Sequence[str]] = None,
                  relative_to: Optional[str] = None) -> List[Finding]:
    """Analyze files and/or directory trees (``*.py``, sorted, recursive)."""
    files: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            for root, dirs, names in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if d not in ("__pycache__", ".git"))
                files += [os.path.join(root, n) for n in sorted(names)
                          if n.endswith(".py")]
        else:
            files.append(p)
    out: List[Finding] = []
    for f in files:
        out += analyze_file(f, select=select, relative_to=relative_to)
    return out


# ------------------------------------------------------------------ #
# baseline
def load_baseline(path: str) -> Dict[Tuple[str, str, str], int]:
    """Baseline file -> fingerprint multiset (missing file = empty)."""
    if not os.path.exists(path):
        return {}
    with open(path, encoding="utf-8") as fh:
        data = json.load(fh)
    out: Dict[Tuple[str, str, str], int] = {}
    for entry in data.get("findings", []):
        key = (entry["rule"], entry["path"], entry.get("text", ""))
        out[key] = out.get(key, 0) + int(entry.get("count", 1))
    return out


def write_baseline(path: str, findings: Sequence[Finding]) -> None:
    counts: Dict[Tuple[str, str, str], int] = {}
    for f in findings:
        counts[f.fingerprint] = counts.get(f.fingerprint, 0) + 1
    entries = [{"rule": r, "path": p, "text": t, "count": c}
               for (r, p, t), c in sorted(counts.items())]
    with open(path, "w", encoding="utf-8") as fh:
        json.dump({"version": 1, "findings": entries}, fh, indent=2,
                  sort_keys=True)
        fh.write("\n")


def baseline_delta(findings: Sequence[Finding],
                   baseline: Dict[Tuple[str, str, str], int]
                   ) -> List[Finding]:
    """Findings not absorbed by the baseline multiset (new ones)."""
    budget = dict(baseline)
    new: List[Finding] = []
    for f in findings:
        if budget.get(f.fingerprint, 0) > 0:
            budget[f.fingerprint] -= 1
        else:
            new.append(f)
    return new
