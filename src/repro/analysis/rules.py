"""The drone-lint rules (DL001-DL007).

Each rule is grounded in a failure mode this repo has actually hit (see
docs/ANALYSIS.md for the before/after history):

  DL001  device arrays captured by closure inside a function handed to
         ``jit``/``shard_map``/``pallas_call``. PR 5 refactored the layout
         blocks to "explicit runner inputs, never closures" after exactly
         this pattern tied compiled-runner identity to array object identity
         (cache misses + retraces on every rebuild).
  DL002  cache-key dataclasses (``EngineConfig``, ``ShapePolicy``,
         ``SemiringSweep``, ``VertexProgram`` subclasses — anything flowing
         into ``program_key``/``params_struct_key``) holding unhashable or
         mutable fields: list/dict/set annotations or defaults silently
         break ``RunnerCache`` keying.
  DL003  ``shard_map`` call sites whose literal ``in_specs`` arity does not
         match the wrapped function's positional signature (jax reports
         this only at trace time, deep inside the engine).
  DL004  Python ``if``/``while`` on traced values inside traced functions —
         a concretization error at best, a silent specialization retrace at
         worst. Use ``lax.cond``/``lax.while_loop``/``jnp.where``.
  DL005  Pallas kernel entry points (functions invoking ``pallas_call``)
         without an explicit dtype guard/cast, or padding with numeric
         literals instead of ``tile_pad_identity``/``combine_identity`` /
         ``semiring_identity`` (a 0-fill is wrong for min_plus).
  DL006  ``except Exception``/bare ``except`` that swallows the error:
         no re-raise, no logging, no use of the bound exception. Narrow the
         type and log at debug level, or annotate deliberate suppressions.
  DL007  raw ``cfg.edge_backend`` reads outside the engine's resolution
         layer. Since ``edge_backend='auto'`` the stored value may not name
         a backend a sweep can execute on — dispatching or cache-keying on
         it mis-handles 'auto'. Consume the field through
         ``resolve_edge_backend``/``resolve_partition_backends``/
         ``normalize_edge_backend`` only.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

from repro.analysis.core import Finding, rule

# --------------------------------------------------------------------- #
# shared AST helpers

#: callables that move a python function into jax's tracing machinery
_TRACE_ENTRIES = ("jit", "shard_map", "pallas_call")

#: attribute names that read static metadata off a tracer (not its value)
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "sharding", "aval",
                 "weak_type"}

#: builtins whose result is static even on traced arguments
_STATIC_CALLS = {"len", "isinstance", "issubclass", "hasattr", "getattr",
                 "type", "callable", "id", "repr", "str"}

#: jnp/jax helpers that return static (python) values
_STATIC_JAX_CALLS = {"issubdtype", "result_type", "ndim", "shape", "dtype",
                     "iinfo", "finfo", "canonicalize_dtype"}

#: identity helpers DL005 requires for kernel padding
_IDENTITY_HELPERS = {"tile_pad_identity", "combine_identity",
                     "semiring_identity"}


def _qualname(node: ast.AST) -> Optional[str]:
    """Dotted name of a Name/Attribute chain, e.g. ``jnp.zeros``."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_trace_entry(func: ast.AST) -> Optional[str]:
    """If ``func`` resolves to jit/shard_map/pallas_call, return which."""
    q = _qualname(func)
    if q is None:
        return None
    tail = q.split(".")[-1]
    return tail if tail in _TRACE_ENTRIES else None


def _partial_entry(call: ast.Call) -> Optional[Tuple[str, ast.Call]]:
    """``partial(jit, ...)`` / ``functools.partial(shard_map, ...)`` →
    (entry name, the partial call)."""
    q = _qualname(call.func)
    if q and q.split(".")[-1] == "partial" and call.args:
        entry = _is_trace_entry(call.args[0])
        if entry:
            return entry, call
    return None


def _traced_defs(tree: ast.AST) -> List[Tuple[ast.AST, str, List[ast.AST]]]:
    """Every function that ends up inside jax tracing, with how it got
    there and the stack of enclosing function defs.

    Detected forms:
      - ``@jit`` / ``@jax.jit`` / ``@partial(shard_map, ...)`` decorators;
      - ``jit(f)`` / ``shard_map(f, ...)`` / ``pl.pallas_call(kernel, ...)``
        where ``f`` names a def in an enclosing (or module) scope;
      - a ``lambda`` passed directly to an entry.
    """
    # name -> def nodes, per scope path (module + enclosing functions)
    out: List[Tuple[ast.AST, str, List[ast.AST]]] = []
    seen: Set[int] = set()

    def add(node: ast.AST, entry: str, stack: List[ast.AST]) -> None:
        if id(node) not in seen:
            seen.add(id(node))
            out.append((node, entry, list(stack)))

    def walk(node: ast.AST, stack: List[ast.AST],
             defs: Dict[str, ast.AST]) -> None:
        local_defs = dict(defs)
        body = getattr(node, "body", [])
        if isinstance(body, list):
            for stmt in body:
                if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    local_defs[stmt.name] = stmt
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in child.decorator_list:
                    entry = None
                    if isinstance(dec, ast.Call):
                        entry = _is_trace_entry(dec.func)
                        if entry is None:
                            pe = _partial_entry(dec)
                            entry = pe[0] if pe else None
                    else:
                        entry = _is_trace_entry(dec)
                    if entry:
                        add(child, entry, stack)
                walk(child, stack + [child], local_defs)
            elif isinstance(child, ast.Call):
                entry = _is_trace_entry(child.func)
                if entry:
                    for arg in child.args:
                        if isinstance(arg, ast.Lambda):
                            add(arg, entry, stack)
                        elif isinstance(arg, ast.Name) and \
                                arg.id in local_defs:
                            add(local_defs[arg.id], entry, stack)
                walk(child, stack, local_defs)
            else:
                walk(child, stack, local_defs)

    walk(tree, [], {})
    return out


def _bound_names(fn: ast.AST) -> Set[str]:
    """Names bound anywhere inside ``fn``: params, assignments, nested
    defs, imports, comprehension/loop targets, with/except aliases."""
    bound: Set[str] = set()
    args = fn.args
    for a in (args.posonlyargs + args.args + args.kwonlyargs
              + ([args.vararg] if args.vararg else [])
              + ([args.kwarg] if args.kwarg else [])):
        bound.add(a.arg)
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
            bound.add(node.id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
            if node is not fn:
                bound.add(node.name)
        elif isinstance(node, (ast.Import, ast.ImportFrom)):
            for alias in node.names:
                bound.add((alias.asname or alias.name).split(".")[0])
        elif isinstance(node, ast.ExceptHandler) and node.name:
            bound.add(node.name)
        elif isinstance(node, (ast.Lambda,)) and node is not fn:
            pass  # lambda params bind only inside the lambda
        elif isinstance(node, ast.arg) and node is not None:
            bound.add(node.arg)
    return bound


def _loaded_names(fn: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(fn)
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)}


def _params(fn: ast.AST) -> List[str]:
    args = fn.args
    return [a.arg for a in args.posonlyargs + args.args]


def _has_varargs(fn: ast.AST) -> bool:
    return fn.args.vararg is not None


# --------------------------------------------------------------------- #
@rule("DL001", "error",
      "device array captured by closure in a traced function")
def check_closure_capture(tree, src, path) -> Iterator[Finding]:
    """Inside a function passed to jit/shard_map/pallas_call, a free
    variable bound in an *enclosing function* to a ``jnp.*`` constructor or
    ``jax.device_put`` result is a device array smuggled in by closure: it
    bakes array identity into the compiled callable, so rebuilding the
    closure (or mutating the binding) silently recompiles. Pass it as an
    explicit runner input. Host ``np.*`` constants are static and exempt."""
    device_ctors = ("jnp.", "jax.numpy.")
    for fn, entry, stack in _traced_defs(tree):
        if not stack or not isinstance(fn, (ast.FunctionDef,
                                            ast.AsyncFunctionDef,
                                            ast.Lambda)):
            continue
        enclosing = [f for f in stack if f is not fn]
        if not enclosing:
            continue
        free = _loaded_names(fn) - _bound_names(fn)
        if not free:
            continue
        for outer in reversed(enclosing):          # innermost scope first
            for node in ast.walk(outer):
                targets: List[ast.Name] = []
                value = None
                if isinstance(node, ast.Assign):
                    value = node.value
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            targets.append(t)
                elif isinstance(node, ast.AnnAssign) and node.value and \
                        isinstance(node.target, ast.Name):
                    value, targets = node.value, [node.target]
                if value is None or not isinstance(value, ast.Call):
                    continue
                q = _qualname(value.func) or ""
                is_device = (q == "jax.device_put"
                             or any(q.startswith(p) for p in device_ctors))
                if not is_device:
                    continue
                for t in targets:
                    if t.id in free:
                        name = getattr(fn, "name", "<lambda>")
                        yield Finding(
                            rule="", path=path, line=fn.lineno,
                            col=fn.col_offset,
                            message=(f"`{name}` (passed to {entry}) captures"
                                     f" device array `{t.id}` by closure "
                                     f"(bound at line {node.lineno}); make "
                                     f"it an explicit argument"))
                        free.discard(t.id)


# --------------------------------------------------------------------- #
#: dataclasses whose instances flow into RunnerCache keys
_KEY_DATACLASS_NAMES = {"EngineConfig", "ShapePolicy", "SemiringSweep",
                        "VertexProgram"}
_MUTABLE_ANNOS = {"list", "List", "dict", "Dict", "set", "Set",
                  "bytearray", "ndarray", "Array"}
_MUTABLE_CTORS = {"list", "dict", "set", "bytearray"}


def _dataclass_deco(cls: ast.ClassDef) -> Optional[ast.AST]:
    for dec in cls.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        q = _qualname(target) or ""
        if q.split(".")[-1] == "dataclass":
            return dec
    return None


def _is_frozen(dec: ast.AST) -> bool:
    if isinstance(dec, ast.Call):
        for kw in dec.keywords:
            if kw.arg == "frozen" and isinstance(kw.value, ast.Constant):
                return bool(kw.value.value)
    return False


def _anno_head(anno: ast.AST) -> Optional[str]:
    if isinstance(anno, ast.Subscript):
        anno = anno.value
    q = _qualname(anno)
    return q.split(".")[-1] if q else None


@rule("DL002", "error",
      "mutable/unhashable field on a cache-key dataclass")
def check_cache_key_fields(tree, src, path) -> Iterator[Finding]:
    """Frozen dataclasses, the named key dataclasses (``EngineConfig``,
    ``ShapePolicy``, ``SemiringSweep``, ``VertexProgram``), and
    ``VertexProgram`` subclasses all flow into ``program_key`` /
    ``RunnerCache`` keys and must stay hashable: no list/dict/set/ndarray
    annotations, no mutable defaults or default_factories. ``ClassVar``
    and ``Sequence``/``tuple`` annotations are fine."""
    for cls in ast.walk(tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        dec = _dataclass_deco(cls)
        if dec is None:
            continue
        base_names = {_qualname(b) or "" for b in cls.bases}
        base_tails = {b.split(".")[-1] for b in base_names}
        is_key = (_is_frozen(dec)
                  or cls.name in _KEY_DATACLASS_NAMES
                  or bool(base_tails & _KEY_DATACLASS_NAMES))
        if not is_key:
            continue
        for stmt in cls.body:
            if not isinstance(stmt, ast.AnnAssign) or \
                    not isinstance(stmt.target, ast.Name):
                continue
            head = _anno_head(stmt.annotation)
            if head == "ClassVar":
                continue
            fname = stmt.target.id
            if head in _MUTABLE_ANNOS:
                yield Finding(
                    rule="", path=path, line=stmt.lineno,
                    col=stmt.col_offset,
                    message=(f"cache-key dataclass `{cls.name}` field "
                             f"`{fname}` has unhashable annotation "
                             f"`{head}`; use a tuple/frozen type"))
                continue
            default = stmt.value
            if default is None:
                continue
            bad = None
            if isinstance(default, (ast.List, ast.Dict, ast.Set)):
                bad = type(default).__name__.lower() + " literal"
            elif isinstance(default, ast.Call):
                q = _qualname(default.func) or ""
                tail = q.split(".")[-1]
                if tail in _MUTABLE_CTORS:
                    bad = f"{tail}() call"
                elif tail == "field":
                    for kw in default.keywords:
                        if kw.arg != "default_factory":
                            continue
                        fq = (_qualname(kw.value) or "").split(".")[-1]
                        if fq in _MUTABLE_CTORS or \
                                isinstance(kw.value, ast.Lambda):
                            bad = f"default_factory={fq or 'lambda'}"
            if bad:
                yield Finding(
                    rule="", path=path, line=stmt.lineno,
                    col=stmt.col_offset,
                    message=(f"cache-key dataclass `{cls.name}` field "
                             f"`{fname}` has mutable default ({bad}); "
                             f"cache keys must be hashable and immutable"))


# --------------------------------------------------------------------- #
def _literal_tuple_len(node: ast.AST) -> Optional[int]:
    if isinstance(node, (ast.Tuple, ast.List)):
        if any(isinstance(e, ast.Starred) for e in node.elts):
            return None
        return len(node.elts)
    return None


def _module_defs(tree: ast.AST) -> Dict[str, ast.AST]:
    """name -> def node for every function def anywhere in the module."""
    return {n.name: n for n in ast.walk(tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}


@rule("DL003", "error",
      "shard_map specs arity does not match the wrapped signature")
def check_shard_map_arity(tree, src, path) -> Iterator[Finding]:
    """When ``in_specs`` is a literal tuple and the wrapped function's
    signature is statically known (no ``*args``), the lengths must match;
    same for a literal-tuple ``out_specs`` against a function whose every
    ``return`` is a literal tuple. jax only reports the mismatch at trace
    time, deep inside the engine."""
    defs = _module_defs(tree)

    def specs_of(call: ast.Call) -> Dict[str, ast.AST]:
        return {kw.arg: kw.value for kw in call.keywords
                if kw.arg in ("in_specs", "out_specs")}

    def check(call: ast.Call, fn: ast.AST) -> Iterator[Finding]:
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.Lambda)):
            return
        specs = specs_of(call)
        in_specs = specs.get("in_specs")
        if in_specs is not None and not _has_varargs(fn):
            n_spec = _literal_tuple_len(in_specs)
            n_par = len(_params(fn))
            if n_spec is not None and n_spec != n_par:
                name = getattr(fn, "name", "<lambda>")
                yield Finding(
                    rule="", path=path, line=call.lineno,
                    col=call.col_offset,
                    message=(f"shard_map in_specs has {n_spec} entries but "
                             f"`{name}` takes {n_par} positional "
                             f"arguments"))
        out_specs = specs.get("out_specs")
        n_out = _literal_tuple_len(out_specs) if out_specs is not None \
            else None
        if n_out is not None and not isinstance(fn, ast.Lambda):
            ret_lens = set()
            for node in ast.walk(fn):
                if isinstance(node, ast.Return) and node.value is not None:
                    n = _literal_tuple_len(node.value)
                    if n is not None:
                        ret_lens.add(n)
            if len(ret_lens) == 1:
                (n_ret,) = ret_lens
                if n_ret != n_out:
                    yield Finding(
                        rule="", path=path, line=call.lineno,
                        col=call.col_offset,
                        message=(f"shard_map out_specs has {n_out} entries "
                                 f"but `{fn.name}` returns {n_ret}-tuples"))

    for node in ast.walk(tree):
        # decorator form: @partial(shard_map, in_specs=..., out_specs=...)
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if not isinstance(dec, ast.Call):
                    continue
                entry = _is_trace_entry(dec.func)
                pe = _partial_entry(dec)
                if entry == "shard_map" or (pe and pe[0] == "shard_map"):
                    yield from check(dec, node)
        # call form: shard_map(f, mesh=..., in_specs=..., out_specs=...)
        elif isinstance(node, ast.Call) and \
                _is_trace_entry(node.func) == "shard_map" and node.args:
            target = node.args[0]
            fn = target if isinstance(target, ast.Lambda) else \
                defs.get(target.id) if isinstance(target, ast.Name) else None
            if fn is not None:
                yield from check(node, fn)


# --------------------------------------------------------------------- #
def _jnp_value_call(node: ast.Call) -> bool:
    """A call that yields a traced value inside traced code."""
    q = _qualname(node.func) or ""
    parts = q.split(".")
    if not parts:
        return False
    root, tail = parts[0], parts[-1]
    if tail in _STATIC_JAX_CALLS:
        return False
    return root in ("jnp", "lax") or q.startswith("jax.")


def _dynamic_refs(expr: ast.AST, traced: Set[str]) -> List[ast.AST]:
    """Sub-expressions of a branch test that read a traced *value* (as
    opposed to static metadata like ``.shape``/``len()``/``is None``)."""
    if isinstance(expr, ast.Name):
        return [expr] if expr.id in traced else []
    if isinstance(expr, ast.Attribute):
        if expr.attr in _STATIC_ATTRS:
            return []
        return _dynamic_refs(expr.value, traced)
    if isinstance(expr, ast.Call):
        q = _qualname(expr.func) or ""
        tail = q.split(".")[-1]
        if tail in _STATIC_CALLS or tail in _STATIC_JAX_CALLS:
            return []
        refs: List[ast.AST] = []
        if _jnp_value_call(expr):
            refs.append(expr)
        for a in list(expr.args) + [kw.value for kw in expr.keywords]:
            refs += _dynamic_refs(a, traced)
        return refs
    if isinstance(expr, ast.Compare):
        if all(isinstance(op, (ast.Is, ast.IsNot)) for op in expr.ops):
            return []
        refs = _dynamic_refs(expr.left, traced)
        for c in expr.comparators:
            refs += _dynamic_refs(c, traced)
        return refs
    if isinstance(expr, ast.BoolOp):
        return [r for v in expr.values for r in _dynamic_refs(v, traced)]
    if isinstance(expr, ast.UnaryOp):
        return _dynamic_refs(expr.operand, traced)
    if isinstance(expr, ast.BinOp):
        return (_dynamic_refs(expr.left, traced)
                + _dynamic_refs(expr.right, traced))
    if isinstance(expr, ast.Subscript):
        return _dynamic_refs(expr.value, traced)
    if isinstance(expr, ast.IfExp):
        return (_dynamic_refs(expr.test, traced)
                + _dynamic_refs(expr.body, traced)
                + _dynamic_refs(expr.orelse, traced))
    return []


def _static_argnames(fn: ast.AST, tree: ast.AST) -> Set[str]:
    """Parameter names a jit decorator marks static (literal lists only)."""
    out: Set[str] = set()
    for dec in getattr(fn, "decorator_list", []):
        if not isinstance(dec, ast.Call):
            continue
        for kw in dec.keywords:
            if kw.arg == "static_argnames":
                vals = kw.value.elts if isinstance(
                    kw.value, (ast.Tuple, ast.List)) else [kw.value]
                for v in vals:
                    if isinstance(v, ast.Constant) and \
                            isinstance(v.value, str):
                        out.add(v.value)
            elif kw.arg == "static_argnums":
                vals = kw.value.elts if isinstance(
                    kw.value, (ast.Tuple, ast.List)) else [kw.value]
                params = _params(fn)
                for v in vals:
                    if isinstance(v, ast.Constant) and \
                            isinstance(v.value, int) and \
                            v.value < len(params):
                        out.add(params[v.value])
    return out


@rule("DL004", "error",
      "Python branch on a traced value inside a traced function")
def check_traced_branch(tree, src, path) -> Iterator[Finding]:
    """Inside a function that jax traces, ``if``/``while`` on a traced
    value either raises a concretization error or — with
    shape-specializing escape hatches — silently retraces per value. Use
    ``lax.cond``/``lax.while_loop``/``jnp.where``. Static reads
    (``x.shape``, ``len(x)``, ``x is None``, ``isinstance``) are exempt,
    as are parameters a ``jit`` marks static."""
    for fn, entry, _stack in _traced_defs(tree):
        if isinstance(fn, ast.Lambda):
            continue                       # lambdas cannot contain if/while
        traced: Set[str] = set(_params(fn)) - _static_argnames(fn, tree)
        if entry == "jit":
            traced.discard("self")
        # one derivation pass: names assigned from jnp/lax calls
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and \
                    isinstance(node.value, ast.Call) and \
                    _jnp_value_call(node.value):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        traced.add(t.id)
        for node in ast.walk(fn):
            if not isinstance(node, (ast.If, ast.While)):
                continue
            refs = _dynamic_refs(node.test, traced)
            if not refs:
                continue
            what = _qualname(refs[0]) or \
                _qualname(getattr(refs[0], "func", refs[0])) or "a value"
            kind = "if" if isinstance(node, ast.If) else "while"
            name = getattr(fn, "name", "<lambda>")
            yield Finding(
                rule="", path=path, line=node.lineno, col=node.col_offset,
                message=(f"`{kind}` on traced value `{what}` inside "
                         f"`{name}` (traced via {entry}); use lax.cond/"
                         f"lax.while_loop/jnp.where"))


# --------------------------------------------------------------------- #
@rule("DL005", "error",
      "Pallas kernel entry without dtype guard or identity padding")
def check_kernel_contract(tree, src, path) -> Iterator[Finding]:
    """A function invoking ``pallas_call`` is a kernel entry point. It must
    (a) contain an explicit dtype guard — an ``assert``/``raise`` that
    inspects ``.dtype``, or an ``.astype`` cast — because refs with mixed
    dtypes make the kernel read garbage rather than fail; and (b) never pad
    its operands with numeric literals: fills must come from
    ``tile_pad_identity``/``combine_identity``/``semiring_identity`` so
    min/max semirings keep their identity (a 0-fill corrupts min_plus)."""
    entries: List[ast.AST] = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        calls_pallas = any(
            isinstance(c, ast.Call)
            and ((_qualname(c.func) or "").split(".")[-1] == "pallas_call")
            for c in ast.walk(node))
        if calls_pallas:
            entries.append(node)

    for fn in entries:
        has_guard = False
        for node in ast.walk(fn):
            if isinstance(node, (ast.Assert, ast.Raise, ast.If)):
                if any(isinstance(sub, ast.Attribute) and
                       sub.attr == "dtype" for sub in ast.walk(node)):
                    has_guard = True
                    break
            if isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "astype":
                has_guard = True
                break
        if not has_guard:
            yield Finding(
                rule="", path=path, line=fn.lineno, col=fn.col_offset,
                message=(f"kernel entry `{fn.name}` calls pallas_call "
                         f"without an explicit dtype guard (assert/raise "
                         f"on `.dtype`) or `.astype` cast"))

        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            tail = (_qualname(node.func) or "").split(".")[-1]
            fill = None
            if tail == "pad":
                for kw in node.keywords:
                    if kw.arg == "constant_values":
                        fill = kw.value
            elif tail in ("full", "full_like") and len(node.args) >= 2:
                fill = node.args[1]
            if fill is None:
                continue
            lit = fill
            if isinstance(lit, ast.UnaryOp):
                lit = lit.operand
            if isinstance(lit, ast.Constant) and \
                    isinstance(lit.value, (int, float)):
                yield Finding(
                    rule="", path=path, line=node.lineno,
                    col=node.col_offset,
                    message=(f"kernel entry `{fn.name}` pads with numeric "
                             f"literal {ast.unparse(fill)}; use "
                             f"tile_pad_identity/combine_identity/"
                             f"semiring_identity"))


# --------------------------------------------------------------------- #
_LOG_CALL_ATTRS = {"debug", "info", "warning", "warn", "error", "exception",
                   "critical", "log", "set_exception"}


def _handler_is_silent(handler: ast.ExceptHandler) -> bool:
    """True when the handler neither re-raises, logs, nor uses the caught
    exception — i.e. the error vanishes."""
    bound = handler.name
    for node in ast.walk(handler):
        if isinstance(node, ast.Raise):
            return False
        if isinstance(node, ast.Call):
            q = _qualname(node.func) or ""
            parts = q.split(".")
            if parts[-1] in _LOG_CALL_ATTRS:
                return False
            if parts[0] in ("warnings", "logging", "traceback"):
                return False
        if bound and isinstance(node, ast.Name) and node.id == bound and \
                isinstance(node.ctx, ast.Load):
            return False
    return True


#: the engine's sanctioned ``cfg.edge_backend`` consumers — the resolution
#: layer itself, plus the config's own construction-time validation
_EB_RESOLVERS = {"resolve_edge_backend", "resolve_partition_backends",
                 "normalize_edge_backend", "__post_init__"}


@rule("DL007", "error",
      "raw cfg.edge_backend read outside the resolution layer")
def check_raw_edge_backend(tree, src, path) -> Iterator[Finding]:
    """``EngineConfig.edge_backend`` may hold ``'auto'``, which no sweep can
    execute on — it resolves to a concrete per-partition backend only
    through the engine's resolution layer. Any other code branching or
    keying on the raw field treats 'auto' as a concrete backend and
    silently mis-dispatches (or splits cache keys that should dedupe).
    Flags ``Load`` reads of ``.edge_backend`` on a receiver named ``cfg``/
    ``config`` (``self.cfg`` included) outside the resolver functions."""

    def visit(node, fname) -> Iterator[Finding]:
        for child in ast.iter_child_nodes(node):
            inner = fname
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                inner = child.name
            elif isinstance(child, ast.Attribute) and \
                    child.attr == "edge_backend" and \
                    isinstance(child.ctx, ast.Load) and \
                    fname not in _EB_RESOLVERS:
                recv = (_qualname(child.value) or "").split(".")[-1]
                if recv in ("cfg", "config"):
                    yield Finding(
                        rule="", path=path, line=child.lineno,
                        col=child.col_offset,
                        message=(f"raw `{_qualname(child) or 'cfg.edge_backend'}` "
                                 f"read in `{fname}`: the field may hold "
                                 f"'auto'; go through resolve_edge_backend/"
                                 f"resolve_partition_backends/"
                                 f"normalize_edge_backend"))
            yield from visit(child, inner)

    yield from visit(tree, "<module>")


# --------------------------------------------------------------------- #
@rule("DL006", "warning",
      "broad except swallows the error silently")
def check_silent_handler(tree, src, path) -> Iterator[Finding]:
    """``except Exception``/bare ``except`` whose body neither re-raises,
    logs, nor touches the bound exception hides real failures (the
    ``runner_nbytes``/``get_abstract_mesh`` pattern this rule was written
    for). Catch the narrow expected type and log at debug level;
    ``# pragma: no cover`` paths are exempt."""
    lines = src.splitlines()

    def broad(tnode: Optional[ast.AST]) -> bool:
        if tnode is None:
            return True
        names = [tnode] if not isinstance(tnode, ast.Tuple) else tnode.elts
        for n in names:
            q = (_qualname(n) or "").split(".")[-1]
            if q in ("Exception", "BaseException"):
                return True
        return False

    for node in ast.walk(tree):
        if not isinstance(node, ast.Try):
            continue
        for handler in node.handlers:
            if not broad(handler.type):
                continue
            hl = handler.lineno
            nearby = lines[max(0, hl - 2):hl + 1]
            if any("pragma: no cover" in ln for ln in nearby):
                continue
            if _handler_is_silent(handler):
                what = "bare except" if handler.type is None else \
                    "except Exception"
                yield Finding(
                    rule="", path=path, line=hl, col=handler.col_offset,
                    message=(f"{what} swallows the error (no raise/log/use "
                             f"of the exception); narrow the type and log "
                             f"at debug level"))
