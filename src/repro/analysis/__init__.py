"""drone-lint: static trace-safety, cache-key, and kernel-contract checks.

The analyzer half (``repro.analysis.core`` + ``repro.analysis.rules``) is a
stdlib-``ast`` pass over the repo's own source that machine-checks the
engine's performance contracts — the invariants DRONE's one-launch-per-
superstep story rests on — before CI ever runs a kernel:

  DL001  arrays captured by closure inside jit/shard_map/pallas_call bodies
  DL002  unhashable/mutable fields on cache-key dataclasses
  DL003  shard_map in_specs/out_specs arity vs the wrapped signature
  DL004  Python ``if``/``while`` on traced values inside traced functions
  DL005  Pallas kernel entry points without dtype guards / identity padding
  DL006  ``except Exception`` that swallows errors silently

The runtime half (``repro.analysis.sanitizer``) is ``retrace_guard()`` — a
context manager that turns jax's tracing counter into an assertion that a
region performed no unexpected compiles. ``GraphSession(debug_sanitize=True)``
uses it to fail loudly when a cache-hit query still retraced.

Command line: ``python tools/drone_lint.py src/repro``.
"""
from repro.analysis.core import (          # noqa: F401
    Finding,
    Rule,
    RULES,
    analyze_file,
    analyze_paths,
    analyze_source,
    baseline_delta,
    load_baseline,
    write_baseline,
)
from repro.analysis import rules as _rules  # noqa: F401  (registers rules)
from repro.analysis.sanitizer import (      # noqa: F401
    RetraceError,
    retrace_guard,
)

__all__ = [
    "Finding", "Rule", "RULES",
    "analyze_file", "analyze_paths", "analyze_source",
    "baseline_delta", "load_baseline", "write_baseline",
    "RetraceError", "retrace_guard",
]
