"""Logical-axis -> mesh-axis sharding rules for the production meshes.

Single pod  (data=16, model=16):
  - 'model' carries tensor parallelism: attention heads, FFN hidden, vocab,
    experts (expert parallelism), mamba inner channels;
  - 'data' carries batch DP + FSDP (ZeRO-3 parameter sharding on the embed
    dim of every weight matrix) — grads reduce-scatter over 'data'.
Multi pod  (pod=2, data=16, model=16):
  - batch and FSDP extend over ('pod', 'data') — 32-way ZeRO-3, which is
    what makes llama3-405b's optimizer state fit per chip (DESIGN.md §2);
  - the pod axis only ever carries DP/FSDP traffic (DCN-friendly), never TP.

KV caches: batch over DP axes, sequence over 'model' (flash-decoding style
partial-KV attention; XLA inserts the softmax partial reductions).
"""
from __future__ import annotations

import logging

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

log = logging.getLogger(__name__)


def get_abstract_mesh():
    """Ambient-mesh lookup that works on both new and old jax.

    Newer jax exposes ``jax.sharding.get_abstract_mesh()``; on older releases
    (0.4.x) the equivalent ambient state set by ``with mesh:`` lives in the
    thread-local resource env. Returns an object with ``empty``/``axis_names``
    /``shape`` or None when no mesh is active.
    """
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is not None:
        return fn()
    try:
        from jax._src import mesh as _mesh_lib
        return _mesh_lib.thread_resources.env.physical_mesh
    except (ImportError, AttributeError) as e:
        # private-module layout moved on this jax version: behave as if no
        # ambient mesh is active, but leave a trace for debugging
        log.debug("ambient mesh lookup unavailable: %r", e)
        return None

LOGICAL_RULES = {
    "vocab": "model",
    "heads": "model",
    "kv_heads": None,        # kv heads (8) don't divide model=16: replicate
    "ff": "model",
    "ff_expert": None,
    "experts": "model",
    "inner": "model",        # mamba expanded channels
    "embed": "data",         # FSDP / ZeRO-3
    "lora": None,
    "qkv": None,
    "frontend": None,
    "layers": None,
    "batch": "data",
    "kv_seq": "model",
    "seq": None,
}

MULTIPOD_RULES = dict(LOGICAL_RULES, embed=("pod", "data"),
                      batch=("pod", "data"))


def rules_for(mesh: Mesh) -> dict:
    return MULTIPOD_RULES if "pod" in mesh.axis_names else LOGICAL_RULES


def logical_to_spec(axes, rules, shape=None) -> P:
    """Map a tuple of logical axis names to a PartitionSpec. A mesh axis may
    appear at most once per spec: repeats (e.g. ('embed','embed') weights)
    keep only the first occurrence and replicate the rest."""
    spec, used = [], set()
    for ax in axes:
        m = rules.get(ax) if ax is not None else None
        names = (m,) if isinstance(m, str) else tuple(m or ())
        if any(n in used for n in names):
            m = None
            names = ()
        used.update(names)
        spec.append(m)
    return P(*spec)


def _divides(shape_dim: int, mesh: Mesh, mesh_axes) -> bool:
    if mesh_axes is None:
        return True
    if isinstance(mesh_axes, str):
        mesh_axes = (mesh_axes,)
    n = 1
    for a in mesh_axes:
        n *= mesh.shape[a]
    return shape_dim % n == 0


def spec_tree(logical_tree, rules):
    """Pytree of logical-axis tuples -> pytree of PartitionSpec."""
    return jax.tree.map(lambda axes: logical_to_spec(axes, rules),
                        logical_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def param_shardings(mesh: Mesh, logical_tree, shape_tree=None):
    """NamedShardings for a logical-axes pytree. If shape_tree (of
    ShapeDtypeStruct/arrays) is given, any mesh axis that does not divide
    its dim is dropped (replicated) — e.g. kv_heads=8 on model=16, or odd
    vocab sizes stay safely shardable via jit's auto-padding for the last
    dim only when divisible; otherwise replicate."""
    specs = spec_tree(logical_tree, rules_for(mesh))
    if shape_tree is not None:
        def fix(spec, leaf):
            parts = []
            for i, m in enumerate(spec):
                ok = i < len(leaf.shape) and _divides(leaf.shape[i], mesh, m)
                parts.append(m if ok else None)
            return P(*parts)
        specs = jax.tree.map(fix, specs, shape_tree,
                             is_leaf=lambda x: isinstance(x, P))
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_spec(mesh: Mesh, *, with_frontend=False, enc_dec=False) -> dict:
    rules = rules_for(mesh)
    b = rules["batch"]
    out = {"tokens": P(b, None), "labels": P(b, None)}
    if with_frontend:
        out["frontend"] = P(b, None, None)
    if enc_dec:
        out["memory"] = P(b, None, None)
    return out


def cache_shardings(mesh: Mesh, cache_logical, cache_shapes):
    return param_shardings(mesh, cache_logical, cache_shapes)


def constrain_gathered(params_tree, logical_tree):
    """with_sharding_constraint that keeps tensor-parallel axes but drops the
    FSDP ('embed') mapping — materializes the per-layer weight all-gather
    (the FSDP dataflow) instead of GSPMD's activation-partial all-reduces."""
    am = get_abstract_mesh()
    if am is None or getattr(am, "empty", True):
        return params_tree
    rules = dict(MULTIPOD_RULES if "pod" in am.axis_names else LOGICAL_RULES)
    rules["embed"] = None

    def fix(p, axes):
        spec = logical_to_spec(axes, rules)
        parts = []
        for dim, m in zip(p.shape, spec):
            ms = (m,) if isinstance(m, str) else tuple(m or ())
            ms = tuple(a for a in ms if a in am.axis_names)
            n = 1
            for a in ms:
                n *= am.shape[a]
            parts.append(m if (ms and n > 1 and dim % n == 0) else None)
        if len(parts) < p.ndim:
            parts += [None] * (p.ndim - len(parts))
        return jax.lax.with_sharding_constraint(p, P(*parts[:p.ndim]))

    # params' array leaves pair with logical_tree's tuple "subtrees" via the
    # tree-prefix rule, so each fix() call sees (array, axes-tuple)
    return jax.tree.map(fix, params_tree, logical_tree)


def maybe_constrain(x, *mesh_axes):
    """with_sharding_constraint that degrades to a no-op when no ambient
    mesh is set (CPU tests) or an axis doesn't exist / divide.

    mesh_axes: one mesh-axis name (or tuple of names, or None) per dim.
    """
    am = get_abstract_mesh()
    if am is None or getattr(am, "empty", True):
        return x
    spec = []
    for dim, ax in zip(x.shape, mesh_axes):
        axes = (ax,) if isinstance(ax, str) else (ax or ())
        # drop axes absent from the ambient mesh (e.g. 'pod' on single-pod)
        axes = tuple(a for a in axes if a in am.axis_names)
        n = 1
        for a in axes:
            n *= am.shape[a]
        ok = n > 1 and dim % n == 0
        spec.append((axes if len(axes) > 1 else axes[0]) if ok else None)
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))
