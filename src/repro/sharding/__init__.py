from repro.sharding.rules import (LOGICAL_RULES, MULTIPOD_RULES,
                                  logical_to_spec, param_shardings,
                                  batch_spec, cache_shardings)

__all__ = ["LOGICAL_RULES", "MULTIPOD_RULES", "logical_to_spec",
           "param_shardings", "batch_spec", "cache_shardings"]
