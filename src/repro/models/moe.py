"""Mixture-of-Experts layer — sort-based token dispatch with capacity drop
(the TPU-native dense-dispatch pattern: grouped expert GEMMs on the MXU,
all-to-all materialized by GSPMD when experts are sharded over the model
axis).

Supports DeepSeek-style shared experts and the aux-loss-free balancing bias
(a router logit bias that is *updated outside the gradient* — here kept as a
parameter updated by the training loop's balance callback).

The token->expert assignment is itself a bipartite graph-cut problem; the
paper's vertex-cut balance objective (imbalance -> 1) is exactly what
capacity-limited top-k dispatch enforces per batch — see DESIGN.md §6.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import _dense_init, init_mlp, mlp_apply, mlp_specs
from repro.sharding.rules import maybe_constrain


def init_moe(key, cfg, dtype):
    m = cfg.moe
    d = cfg.d_model
    d_ffe = m.d_ff_expert or cfg.d_ff
    ks = jax.random.split(key, 5)
    params = {
        "router": _dense_init(ks[0], (d, m.n_experts), d, jnp.float32),
        "router_bias": jnp.zeros((m.n_experts,), jnp.float32),
        "w_gate": _dense_init(ks[1], (m.n_experts, d, d_ffe), d, dtype),
        "w_up": _dense_init(ks[2], (m.n_experts, d, d_ffe), d, dtype),
        "w_down": _dense_init(ks[3], (m.n_experts, d_ffe, d), d_ffe, dtype),
    }
    if m.n_shared:
        params["shared"] = init_mlp(ks[4], d, d_ffe * m.n_shared, cfg.act,
                                    dtype)
    return params


def moe_specs(cfg):
    m = cfg.moe
    specs = {"router": ("embed", "experts"), "router_bias": ("experts",),
             "w_gate": ("experts", "embed", "ff_expert"),
             "w_up": ("experts", "embed", "ff_expert"),
             "w_down": ("experts", "ff_expert", "embed")}
    if m.n_shared:
        specs["shared"] = mlp_specs(cfg.act)
    return specs


def _dp_groups(total_tokens: int) -> int:
    """Number of DP shards in the ambient mesh that divide the token count
    (hierarchical dispatch group count; 1 when unsharded/CPU)."""
    from repro.sharding.rules import get_abstract_mesh
    am = get_abstract_mesh()
    if am is None or getattr(am, "empty", True):
        return 1
    g = 1
    for ax in ("pod", "data"):
        if ax in am.axis_names:
            g *= am.shape[ax]
    return g if (g > 1 and total_tokens % g == 0) else 1


def moe_apply(params, x, cfg):
    """x [B, S, d] -> [B, S, d]."""
    m = cfg.moe
    if m.dispatch == "hierarchical":
        return moe_apply_hierarchical(params, x, cfg)
    B, S, d = x.shape
    T = B * S
    E, k = m.n_experts, m.top_k
    xt = x.reshape(T, d)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, -1)
    sel_basis = probs + params["router_bias"][None, :] \
        if m.router_aux_free_bias else probs
    gate, expert_idx = jax.lax.top_k(sel_basis, k)              # [T, k]
    gate = jnp.take_along_axis(probs, expert_idx, axis=-1)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # ---- sort-based dispatch with capacity drop ------------------------- #
    cap = int(math.ceil(m.capacity_factor * T * k / E / 8.0) * 8)
    cap = min(cap, T * k)   # dropless ceiling
    flat_e = expert_idx.reshape(-1)                              # [T*k]
    order = jnp.argsort(flat_e)                                  # stable
    se = flat_e[order]
    seg_start = jnp.searchsorted(se, jnp.arange(E))              # [E]
    pos = jnp.arange(T * k) - seg_start[se]
    keep = pos < cap
    slot = jnp.where(keep, se * cap + pos, E * cap)              # drop row
    tok = order // k

    buf = jnp.zeros((E * cap, d), x.dtype)
    buf = buf.at[slot].set(jnp.where(keep[:, None], xt[tok], 0), mode="drop")
    h = buf.reshape(E, cap, d)
    # expert parallelism: expert dim over 'model', token slots over DP axes
    h = maybe_constrain(h, "model", ("pod", "data"), None)

    g = jnp.einsum("ecd,edf->ecf", h, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", h, params["w_up"])
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, params["w_down"])
    y = maybe_constrain(y, "model", ("pod", "data"), None)

    y_flat = y.reshape(E * cap, d)
    y_tok = jnp.take(y_flat, slot, axis=0, mode="fill", fill_value=0)
    w = jnp.where(keep, gate.reshape(-1)[order], 0.0).astype(y_tok.dtype)
    out = jnp.zeros((T, d), x.dtype).at[tok].add(y_tok * w[:, None])

    if m.n_shared:
        out = out + mlp_apply(params["shared"], xt, cfg.act)

    # load-balance stats (consumed by the aux-free bias update / metrics)
    load = jnp.zeros((E,), jnp.float32).at[flat_e].add(1.0) / (T * k)
    return out.reshape(B, S, d), {"load": load,
                                  "dropped": 1.0 - keep.mean()}


def moe_apply_hierarchical(params, x, cfg):
    """Hierarchical (per-DP-shard) dispatch — the §Perf optimization for the
    MoE architectures.

    The baseline global argsort-dispatch makes GSPMD all-reduce the full
    [E*cap, d] buffers (every shard contributes masked rows to every slot).
    Here tokens are grouped by DP shard: the sort, capacity drop and scatter
    are *local* to each group (leading G axis sharded over (pod, data)), and
    the only cross-device movement is the [G, E, capG, d] -> [E, G*capG, d]
    transpose — a true all-to-all, exactly the paper's SBS-style exchange
    and what real TPU MoE systems emit. Capacity is enforced per shard
    (standard practice; slightly different drop semantics than the global
    sort, both capacity-faithful)."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, k = m.n_experts, m.top_k
    G = _dp_groups(T)
    Tg = T // G
    xt = x.reshape(G, Tg, d)
    xt = maybe_constrain(xt, ("pod", "data"), None, None)

    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, -1)
    sel_basis = probs + params["router_bias"] if m.router_aux_free_bias \
        else probs
    gate, expert_idx = jax.lax.top_k(sel_basis, k)            # [G, Tg, k]
    gate = jnp.take_along_axis(probs, expert_idx, axis=-1)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    cap = int(math.ceil(m.capacity_factor * Tg * k / E / 8.0) * 8)
    cap = min(cap, Tg * k)

    def dispatch_one(xg, eg, gg):
        flat_e = eg.reshape(-1)                               # [Tg*k]
        order = jnp.argsort(flat_e)
        se = flat_e[order]
        seg_start = jnp.searchsorted(se, jnp.arange(E))
        pos = jnp.arange(Tg * k) - seg_start[se]
        keep = pos < cap
        slot = jnp.where(keep, se * cap + pos, E * cap)
        tok = order // k
        buf = jnp.zeros((E * cap, xg.shape[-1]), xg.dtype)
        buf = buf.at[slot].set(jnp.where(keep[:, None], xg[tok], 0),
                               mode="drop")
        w = jnp.where(keep, gg.reshape(-1)[order], 0.0)
        return buf.reshape(E, cap, xg.shape[-1]), slot, tok, w

    buf, slot, tok, w = jax.vmap(dispatch_one)(xt, expert_idx, gate)
    # [G, E, cap, d] -> [E, G, cap, d]: the all-to-all
    h = buf.transpose(1, 0, 2, 3).reshape(E, G * cap, d)
    h = maybe_constrain(h, "model", ("pod", "data"), None)

    g = jnp.einsum("ecd,edf->ecf", h, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", h, params["w_up"])
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, params["w_down"])
    y = maybe_constrain(y, "model", ("pod", "data"), None)

    yg = y.reshape(E, G, cap, d).transpose(1, 0, 2, 3)        # all-to-all back
    yg = maybe_constrain(yg, ("pod", "data"), "model", None, None)

    def combine_one(yb, slot_g, tok_g, w_g):
        y_flat = yb.reshape(E * cap, d)
        y_tok = jnp.take(y_flat, slot_g, axis=0, mode="fill", fill_value=0)
        out = jnp.zeros((Tg, d), y_tok.dtype)
        return out.at[tok_g].add(y_tok * w_g[:, None].astype(y_tok.dtype))

    out = jax.vmap(combine_one)(yg, slot, tok, w)
    out = maybe_constrain(out, ("pod", "data"), None, None).reshape(T, d)

    if m.n_shared:
        out = out + mlp_apply(params["shared"], xt.reshape(T, d), cfg.act)

    load = jnp.zeros((E,), jnp.float32).at[expert_idx.reshape(-1)].add(1.0) \
        / (T * k)
    return out.reshape(B, S, d), {"load": load, "dropped": 0.0}


def update_router_bias(params, load, *, rate=1e-3):
    """DeepSeek aux-loss-free balancing: nudge under-loaded experts up,
    over-loaded down (applied outside the gradient by the train loop)."""
    target = 1.0 / load.shape[-1]
    bias = params["router_bias"] + rate * jnp.sign(target - load)
    return dict(params, router_bias=bias)
