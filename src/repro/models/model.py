"""Model assembly: config -> init / forward / prefill / decode.

Layers are grouped into periodic *super-blocks* (config.super_blocks) whose
parameters are stacked on a leading repeat axis and applied with ``lax.scan``
(+ ``jax.checkpoint`` remat) — compact HLO even for llama3-405b's 126 layers,
and the scan carry is the natural FSDP all-gather overlap point.

Supports: dense/GQA/MLA attention, MoE (shared+routed), Mamba, mLSTM/sLSTM,
encoder-decoder (cross-attention), modality frontend stubs (precomputed
patch/frame embeddings per the assignment spec), and DeepSeek-style MTP.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import moe as moe_lib
from repro.models import ssm
from repro.models.config import BlockSpec, ModelConfig
from repro.sharding.rules import maybe_constrain
from repro.models.layers import (_dense_init, attention_apply,
                                 attention_cache_shape, attention_specs,
                                 cross_attention_apply, init_attention,
                                 init_cross_attention, init_mla, init_mlp,
                                 init_norm, mla_apply, mla_cache_shape,
                                 mla_specs, mlp_apply, mlp_specs, norm_apply,
                                 norm_specs)

# --------------------------------------------------------------------------- #
# one block
# --------------------------------------------------------------------------- #
_MIXER_INIT = {"attn": init_attention, "mla": init_mla,
               "mamba": ssm.init_mamba, "mlstm": ssm.init_mlstm,
               "slstm": ssm.init_slstm}
_MIXER_SPECS = {"attn": attention_specs, "mla": mla_specs,
                "mamba": ssm.mamba_specs, "mlstm": ssm.mlstm_specs,
                "slstm": ssm.slstm_specs}


def init_block(key, spec: BlockSpec, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 6)
    p = {"norm1": init_norm(ks[0], cfg.d_model, cfg.norm, dtype),
         "mixer": _MIXER_INIT[spec.mixer](ks[1], cfg, dtype)}
    if getattr(spec, "cross", False):
        p["norm_cross"] = init_norm(ks[2], cfg.d_model, cfg.norm, dtype)
        p["cross"] = init_cross_attention(ks[3], cfg, dtype)
    if spec.mlp != "none":
        p["norm2"] = init_norm(ks[4], cfg.d_model, cfg.norm, dtype)
        p["mlp"] = (moe_lib.init_moe(ks[5], cfg, dtype) if spec.mlp == "moe"
                    else init_mlp(ks[5], cfg.d_model, cfg.d_ff, cfg.act, dtype))
    return p


def block_specs(spec: BlockSpec, cfg: ModelConfig):
    s = {"norm1": norm_specs(cfg.norm),
         "mixer": _MIXER_SPECS[spec.mixer](cfg)}
    if getattr(spec, "cross", False):
        s["norm_cross"] = norm_specs(cfg.norm)
        s["cross"] = attention_specs(cfg)
    if spec.mlp != "none":
        s["norm2"] = norm_specs(cfg.norm)
        s["mlp"] = (moe_lib.moe_specs(cfg) if spec.mlp == "moe"
                    else mlp_specs(cfg.act))
    return s


def _cast_floats(tree, dtype):
    """Compute-dtype cast (flax 'dtype' semantics): float params are cast to
    the activation dtype at application time; int/bool left alone."""
    return jax.tree.map(
        lambda p: p.astype(dtype) if jnp.issubdtype(p.dtype, jnp.floating)
        else p, tree)


def block_apply(params, x, spec: BlockSpec, cfg: ModelConfig, *, positions,
                causal=True, cache=None, memory=None):
    params = _cast_floats(params, jnp.dtype(cfg.activation_dtype))
    if cfg.fsdp_gather_weights:
        from repro.sharding.rules import constrain_gathered
        params = constrain_gathered(params, block_specs(spec, cfg))
    h = norm_apply(params["norm1"], x, cfg.norm)
    if spec.mixer in ("attn", "mla"):
        fn = attention_apply if spec.mixer == "attn" else mla_apply
        out, new_cache = fn(params["mixer"], h, cfg, positions=positions,
                            causal=causal, cache=cache)
    elif spec.mixer == "mamba":
        out, new_cache = ssm.mamba_apply(params["mixer"], h, cfg, cache=cache)
    elif spec.mixer == "mlstm":
        out, new_cache = ssm.mlstm_apply(params["mixer"], h, cfg, cache=cache)
    else:
        out, new_cache = ssm.slstm_apply(params["mixer"], h, cfg, cache=cache)
    def _settle(o):
        o = o.astype(x.dtype)
        if cfg.tp_bf16_payload:
            o = jax.lax.optimization_barrier(o)
        return o

    x = x + _settle(out)

    if getattr(spec, "cross", False):
        h = norm_apply(params["norm_cross"], x, cfg.norm)
        x = x + _settle(cross_attention_apply(params["cross"], h, memory,
                                              cfg, positions=positions))

    aux = None
    if spec.mlp != "none":
        h = norm_apply(params["norm2"], x, cfg.norm)
        if spec.mlp == "moe":
            out, aux = moe_lib.moe_apply(params["mlp"], h, cfg)
        else:
            out = mlp_apply(params["mlp"], h, cfg.act)
        x = x + _settle(out)
    return x, new_cache, aux


def block_cache_shape(spec: BlockSpec, cfg: ModelConfig, batch, max_len,
                      dtype):
    if spec.mixer == "attn":
        return attention_cache_shape(cfg, batch, max_len, dtype)
    if spec.mixer == "mla":
        return mla_cache_shape(cfg, batch, max_len, dtype)
    if spec.mixer == "mamba":
        return ssm.mamba_cache_shape(cfg, batch, dtype)
    if spec.mixer == "mlstm":
        return ssm.mlstm_cache_shape(cfg, batch, dtype)
    return ssm.slstm_cache_shape(cfg, batch, dtype)


# --------------------------------------------------------------------------- #
# full model
# --------------------------------------------------------------------------- #
def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_model(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 10)
    params: dict[str, Any] = {
        "embed": _dense_init(ks[0], (cfg.vocab, cfg.d_model), cfg.d_model,
                             dtype),
        "final_norm": init_norm(ks[1], cfg.d_model, cfg.norm, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _dense_init(ks[2], (cfg.d_model, cfg.vocab),
                                        cfg.d_model, dtype)
    # scan groups: list over groups of list over pattern positions of
    # repeat-stacked block params
    params["blocks"] = []
    for gi, (pattern, n_rep) in enumerate(cfg.scan_groups()):
        kg = jax.random.fold_in(ks[3], gi)
        reps = []
        for r in range(n_rep):
            kr = jax.random.fold_in(kg, r)
            reps.append([init_block(jax.random.fold_in(kr, i), s, cfg, dtype)
                         for i, s in enumerate(pattern)])
        params["blocks"].append(
            [_stack([reps[r][i] for r in range(n_rep)])
             for i in range(len(pattern))])

    if cfg.n_enc_layers:
        enc_spec = BlockSpec(mixer="attn", mlp="dense")
        enc = [init_block(jax.random.fold_in(ks[4], r), enc_spec, cfg, dtype)
               for r in range(cfg.n_enc_layers)]
        params["encoder"] = [_stack(enc)]
        params["enc_norm"] = init_norm(ks[5], cfg.d_model, cfg.norm, dtype)
    if cfg.frontend:
        fdim = cfg.frontend_dim or cfg.d_model
        params["frontend_adapter"] = _dense_init(ks[6], (fdim, cfg.d_model),
                                                 fdim, dtype)
    if cfg.mtp_depth:
        params["mtp_proj"] = _dense_init(ks[7], (2 * cfg.d_model, cfg.d_model),
                                         2 * cfg.d_model, dtype)
        params["mtp_block"] = init_block(ks[8],
                                         BlockSpec(mixer="attn", mlp="dense"),
                                         cfg, dtype)
        params["mtp_norm"] = init_norm(ks[9], cfg.d_model, cfg.norm, dtype)
    return params


def model_specs(cfg: ModelConfig):
    """Logical-axis pytree matching init_model's structure (leading 'layers'
    axis on stacked blocks)."""

    def _with_layers(tree):
        return jax.tree.map(lambda axes: ("layers",) + tuple(axes), tree,
                            is_leaf=lambda x: isinstance(x, tuple))

    specs: dict[str, Any] = {
        "embed": ("vocab", "embed"),
        "final_norm": norm_specs(cfg.norm),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = ("embed", "vocab")
    specs["blocks"] = [[_with_layers(block_specs(s, cfg)) for s in pattern]
                       for pattern, _ in cfg.scan_groups()]
    if cfg.n_enc_layers:
        enc_spec = BlockSpec(mixer="attn", mlp="dense")
        specs["encoder"] = [_with_layers(block_specs(enc_spec, cfg))]
        specs["enc_norm"] = norm_specs(cfg.norm)
    if cfg.frontend:
        specs["frontend_adapter"] = ("frontend", "embed")
    if cfg.mtp_depth:
        specs["mtp_proj"] = ("embed", "embed")
        specs["mtp_block"] = block_specs(BlockSpec(mixer="attn", mlp="dense"),
                                         cfg)
        specs["mtp_norm"] = norm_specs(cfg.norm)
    return specs


# --------------------------------------------------------------------------- #
def _apply_stack(stacked_list, pattern, x, cfg, *, positions, causal,
                 caches=None, memory=None, remat=True):
    """Apply n_rep x pattern layers via scan. caches: list (per pattern pos)
    of stacked cache pytrees or None."""
    n_pos = len(pattern)
    scanned = {"p": stacked_list}
    if caches is not None:
        scanned["c"] = caches

    def body(x, per_rep):
        new_caches = []
        aux_sum = jnp.zeros((), jnp.float32)
        for i in range(n_pos):
            c = per_rep["c"][i] if caches is not None else None
            x, nc, aux = block_apply(per_rep["p"][i], x, pattern[i], cfg,
                                     positions=positions, causal=causal,
                                     cache=c, memory=memory)
            new_caches.append(nc)
            if aux is not None:
                aux_sum = aux_sum + aux["dropped"]
        return x, (new_caches if caches is not None else None, aux_sum)

    if remat:
        body = jax.checkpoint(body)
    x, (new_caches, aux) = jax.lax.scan(body, x, scanned)
    return x, new_caches, jnp.sum(aux)


def _embed_inputs(params, batch, cfg):
    dtype = jnp.dtype(cfg.activation_dtype)
    x = params["embed"][batch["tokens"]].astype(dtype)
    if cfg.frontend and "frontend" in batch:
        pre = jnp.einsum("bld,de->ble", batch["frontend"].astype(dtype),
                         params["frontend_adapter"].astype(dtype))
        x = jnp.concatenate([pre, x], axis=1)
    # activations: batch over the DP axes, d_model replicated
    return maybe_constrain(x, ("pod", "data"), None, None)


def _encode(params, batch, cfg):
    dtype = jnp.dtype(cfg.activation_dtype)
    enc_in = jnp.einsum("bld,de->ble", batch["frontend"].astype(dtype),
                        params["frontend_adapter"].astype(dtype))
    enc_in = maybe_constrain(enc_in, ("pod", "data"), None, None)
    pos = jnp.arange(enc_in.shape[1])
    enc_spec = (BlockSpec(mixer="attn", mlp="dense"),)
    h, _, _ = _apply_stack(params["encoder"], enc_spec, enc_in, cfg,
                           positions=pos, causal=False)
    h = norm_apply(params["enc_norm"], h, cfg.norm)
    return maybe_constrain(h, ("pod", "data"), None, None)


def _apply_groups(params, x, cfg, *, positions, causal, caches=None,
                  memory=None, remat=True):
    new_caches, aux_tot = [], jnp.zeros((), jnp.float32)
    for gi, (pattern, _) in enumerate(cfg.scan_groups()):
        c = caches[gi] if caches is not None else None
        x, nc, aux = _apply_stack(params["blocks"][gi], pattern, x, cfg,
                                  positions=positions, causal=causal,
                                  caches=c, memory=memory, remat=remat)
        new_caches.append(nc)
        aux_tot = aux_tot + aux
    return x, (new_caches if caches is not None else None), aux_tot


def forward(params, batch, cfg: ModelConfig):
    """Full-sequence forward -> (logits [B,S,V], aux dict). For enc-dec,
    encodes batch['frontend'] and decodes batch['tokens']."""
    memory = _encode(params, batch, cfg) if cfg.n_enc_layers else None
    x = _embed_inputs(params, batch, cfg) if not cfg.n_enc_layers else \
        maybe_constrain(
            params["embed"][batch["tokens"]].astype(
                jnp.dtype(cfg.activation_dtype)),
            ("pod", "data"), None, None)
    pos = jnp.arange(x.shape[1])
    x, _, aux = _apply_groups(params, x, cfg, positions=pos, causal=True,
                              memory=memory)
    h = norm_apply(params["final_norm"], x, cfg.norm)
    logits = _lm_logits(params, h, cfg)
    out_aux = {"moe_dropped": aux}
    if cfg.mtp_depth:
        out_aux["mtp_hidden"] = h  # consumed by the MTP loss in train.py
    return logits, out_aux


def _lm_logits(params, h, cfg):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("btd,dv->btv", h, head.astype(h.dtype))


def mtp_logits(params, h, next_embed, cfg):
    """DeepSeek MTP module: combine current hidden with next-token embedding,
    one extra block, shared head -> depth-2 prediction logits."""
    dtype = h.dtype
    z = jnp.concatenate([h, next_embed.astype(dtype)], -1)
    z = jnp.einsum("btd,de->bte", z, params["mtp_proj"].astype(dtype))
    pos = jnp.arange(z.shape[1])
    z, _, _ = block_apply(params["mtp_block"], z,
                          BlockSpec(mixer="attn", mlp="dense"), cfg,
                          positions=pos)
    z = norm_apply(params["mtp_norm"], z, cfg.norm)
    return _lm_logits(params, z, cfg)


# --------------------------------------------------------------------------- #
# decode
# --------------------------------------------------------------------------- #
def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """ShapeDtypeStruct pytree of the decode cache (also used to allocate).
    Structure: list over scan groups of list over pattern positions of
    repeat-stacked cache pytrees."""
    dtype = jnp.dtype(cfg.activation_dtype)
    out = []
    for pattern, n_rep in cfg.scan_groups():
        def _stacked(shape_tree, n=n_rep):
            return jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype),
                shape_tree)
        out.append([_stacked(block_cache_shape(s, cfg, batch, max_len, dtype))
                    for s in pattern])
    return out


def _one_cache_spec(s: BlockSpec):
    if s.mixer == "attn":
        return {"k": ("layers", "batch", "kv_seq", "kv_heads", None),
                "v": ("layers", "batch", "kv_seq", "kv_heads", None),
                "idx": ("layers",)}
    if s.mixer == "mla":
        return {"ckv": ("layers", "batch", "kv_seq", None),
                "kr": ("layers", "batch", "kv_seq", None),
                "idx": ("layers",)}
    if s.mixer == "mamba":
        return {"conv": ("layers", "batch", None, "inner"),
                "h": ("layers", "batch", "inner", None),
                "idx": ("layers",)}
    if s.mixer == "mlstm":
        return {"C": ("layers", "batch", "heads", None, None),
                "n": ("layers", "batch", "heads", None),
                "m": ("layers", "batch", "heads"), "idx": ("layers",)}
    return {"h": ("layers", "batch", "embed"),
            "c": ("layers", "batch", "embed"),
            "n": ("layers", "batch", "embed"),
            "m": ("layers", "batch", "embed"), "idx": ("layers",)}


def cache_specs(cfg: ModelConfig):
    """Logical axes for the cache pytree (leading 'layers')."""
    return [[_one_cache_spec(s) for s in pattern]
            for pattern, _ in cfg.scan_groups()]


def decode_step(params, caches, batch, cfg: ModelConfig):
    """One-token decode: batch['tokens'] [B,1] (+ 'memory' for enc-dec).
    Returns (logits [B,1,V], new_caches)."""
    dtype = jnp.dtype(cfg.activation_dtype)
    x = params["embed"][batch["tokens"]].astype(dtype)
    # positions from the first layer-stack's idx (uniform across batch)
    pos = caches[0][0]["idx"][0][None]
    memory = batch.get("memory")
    x, new_caches, _ = _apply_groups(params, x, cfg, positions=pos,
                                     causal=True, caches=caches,
                                     memory=memory, remat=False)
    h = norm_apply(params["final_norm"], x, cfg.norm)
    return _lm_logits(params, h, cfg), new_caches


def prefill(params, batch, cfg: ModelConfig, max_len: int):
    """Run the full prompt, building a decode cache of capacity max_len.
    Returns (last-position logits, caches)."""
    dtype = jnp.dtype(cfg.activation_dtype)
    B, S = batch["tokens"].shape
    caches = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                          init_cache(cfg, B, max_len))
    memory = _encode(params, batch, cfg) if cfg.n_enc_layers else None
    x = _embed_inputs(params, batch, cfg) if not cfg.n_enc_layers else \
        maybe_constrain(params["embed"][batch["tokens"]].astype(dtype),
                        ("pod", "data"), None, None)
    pos = jnp.arange(x.shape[1])
    x, new_caches, _ = _apply_groups(params, x, cfg, positions=pos,
                                     causal=True, caches=caches,
                                     memory=memory, remat=False)
    h = norm_apply(params["final_norm"], x[:, -1:], cfg.norm)
    return _lm_logits(params, h, cfg), new_caches
