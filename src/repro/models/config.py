"""Model configuration for the assigned architecture zoo.

A config is a declarative description: per-layer *block specs* (attention
variant / SSM variant / MLP variant) grouped into repeat-stacks so the model
applies them with ``lax.scan`` over stacked parameters (compact HLO even at
126 layers).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int = 16
    top_k: int = 2
    n_shared: int = 0          # shared (always-on) experts, DeepSeek-style
    d_ff_expert: int = 0       # expert hidden dim (0 -> d_ff)
    capacity_factor: float = 1.25
    router_aux_free_bias: bool = True   # DeepSeek aux-loss-free balancing bias
    dispatch: str = "global"   # 'global' (baseline sort) | 'hierarchical'
                               # (per-DP-shard sort + all-to-all, §Perf)


@dataclasses.dataclass(frozen=True)
class MLACfg:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MambaCfg:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2


@dataclasses.dataclass(frozen=True)
class XLSTMCfg:
    # xLSTM[a:b] -> a mLSTM blocks per sLSTM block
    mlstm_per_slstm: int = 7
    conv_dim: int = 4
    proj_factor_mlstm: float = 2.0
    proj_factor_slstm: float = 1.3333


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """One layer's recipe."""
    mixer: str = "attn"        # 'attn' | 'mla' | 'mamba' | 'mlstm' | 'slstm'
    mlp: str = "dense"         # 'dense' | 'moe' | 'none'
    cross: bool = False        # add cross-attention (enc-dec decoder)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                # dense|moe|hybrid|ssm|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0            # 0 -> d_model // n_heads
    norm: str = "rmsnorm"      # rmsnorm|layernorm|nonparam_ln
    act: str = "swiglu"        # swiglu|gelu
    rope_theta: float = 10000.0
    rotary_pct: float = 1.0
    tie_embeddings: bool = False
    attn_logit_soft_cap: float = 0.0
    moe: Optional[MoECfg] = None
    mla: Optional[MLACfg] = None
    mamba: Optional[MambaCfg] = None
    xlstm: Optional[XLSTMCfg] = None
    # layer pattern: explicit sequence of BlockSpec; if None, homogeneous attn
    pattern: Optional[Tuple[BlockSpec, ...]] = None
    first_k_dense: int = 0     # leading dense layers before MoE (DeepSeek: 3)
    # encoder-decoder
    n_enc_layers: int = 0      # >0 -> enc-dec model (n_layers = decoder layers)
    # modality frontend stub: precomputed embeddings prepended/consumed
    frontend: Optional[str] = None    # 'patch_stub' | 'frame_stub'
    frontend_dim: int = 0      # incoming embedding dim (0 -> d_model)
    frontend_len: int = 0      # number of frontend positions (prefix)
    mtp_depth: int = 0         # DeepSeek multi-token-prediction modules
    # numerics
    param_dtype: str = "float32"
    activation_dtype: str = "bfloat16"
    # §Perf lever: explicitly all-gather each layer's FSDP-sharded weights
    # before use (per scan step), instead of letting GSPMD all-reduce
    # activation partials from contracting-dim-sharded matmuls
    fsdp_gather_weights: bool = False
    # §Perf lever: optimization_barrier after mixer/mlp outputs so XLA can't
    # hoist the norm's f32 upcast above the TP all-reduce (payload stays
    # bf16 -> halves the dominant activation all-reduce bytes)
    tp_bf16_payload: bool = False
    # attention flavour for long-context feasibility bookkeeping
    subquadratic: bool = False  # True for ssm/hybrid (long_500k eligible)

    # ------------------------------------------------------------------ #
    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    def layer_pattern(self) -> Tuple[BlockSpec, ...]:
        if self.pattern is not None:
            assert len(self.pattern) == self.n_layers
            return self.pattern
        mlp = "moe" if (self.moe and not self.first_k_dense) else "dense"
        mixer = "mla" if self.mla else "attn"
        specs = []
        for i in range(self.n_layers):
            use_moe = self.moe is not None and i >= self.first_k_dense
            specs.append(BlockSpec(mixer=mixer,
                                   mlp="moe" if use_moe else "dense"))
        return tuple(specs)

    def layer_groups(self) -> Sequence[Tuple[BlockSpec, int]]:
        """Adjacent identical specs collapsed into (spec, repeat) stacks —
        scan units. Heterogeneous periodic patterns (Jamba/xLSTM) instead
        collapse into (tuple-of-specs, repeat) super-blocks."""
        pat = self.layer_pattern()
        groups = []
        for s in pat:
            if groups and groups[-1][0] == s:
                groups[-1][1] += 1
            else:
                groups.append([s, 1])
        return [(s, n) for s, n in groups]

    def super_blocks(self) -> Tuple[Tuple[BlockSpec, ...], int]:
        """(period_pattern, n_repeats) if the pattern is periodic with a
        period dividing n_layers, else (full_pattern, 1)."""
        pat = self.layer_pattern()
        n = len(pat)
        for period in range(1, n + 1):
            if n % period == 0 and all(pat[i] == pat[i % period]
                                       for i in range(n)):
                return pat[:period], n // period
        return pat, 1

    def scan_groups(self):
        """Scan decomposition: list of (sub_pattern tuple, n_repeats).
        Periodic models (jamba, xlstm) -> one multi-layer super-block scan;
        otherwise adjacent identical layers collapse into homogeneous scans
        (deepseek: [(mla+dense,)x3, (mla+moe,)x58])."""
        pat, nrep = self.super_blocks()
        if nrep > 1:
            return [(pat, nrep)]
        return [((spec,), n) for spec, n in self.layer_groups()]


# shape cells assigned to every LM arch (system spec)
SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}


def shape_applicable(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    """Whether a shape cell runs for an arch; reason if skipped
    (DESIGN.md §6: long_500k only for sub-quadratic archs)."""
    if shape == "long_500k" and not cfg.subquadratic:
        return False, ("full softmax attention at 524288-token context is "
                       "quadratic; config defines no sub-quadratic attention "
                       "(skip per spec; run for ssm/hybrid archs)")
    return True, ""
