"""Neural layers for the architecture zoo (pure-function JAX, no framework).

Every module is a pair ``init_*(key, ...) -> params`` / ``*_apply(params, x,
...)`` plus a parallel ``*_specs(...)`` pytree of *logical axis names* used by
sharding/rules.py to produce PartitionSpecs. Parameters are plain nested
dicts.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------------- #
# initializers
# --------------------------------------------------------------------------- #
def _dense_init(key, shape, in_axis_size, dtype):
    scale = 1.0 / math.sqrt(max(in_axis_size, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# --------------------------------------------------------------------------- #
# norms
# --------------------------------------------------------------------------- #
def init_norm(key, d, kind, dtype):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    if kind == "nonparam_ln":   # OLMo: no learned affine
        return {}
    raise ValueError(kind)


def norm_specs(kind):
    if kind == "rmsnorm":
        return {"scale": ("embed",)}
    if kind == "layernorm":
        return {"scale": ("embed",), "bias": ("embed",)}
    return {}


def norm_apply(params, x, kind, eps=1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
        return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    if kind == "layernorm":
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# --------------------------------------------------------------------------- #
# rotary embeddings
# --------------------------------------------------------------------------- #
def rope(x, positions, *, theta=10000.0, pct=1.0):
    """x [..., S, H, D]; positions [..., S] int32."""
    D = x.shape[-1]
    rot = int(D * pct) // 2 * 2
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    half = rot // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    # positions [..., S] -> [..., S, 1(H), half]
    ang = positions[..., :, None, None].astype(jnp.float32) * freq
    x1, x2 = xr[..., :half], xr[..., half:]
    c, s = jnp.cos(ang), jnp.sin(ang)
    y = jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], -1)
    return jnp.concatenate([y.astype(x.dtype), xp], -1)


# --------------------------------------------------------------------------- #
# dense MLP (swiglu / gelu)
# --------------------------------------------------------------------------- #
def init_mlp(key, d, d_ff, act, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    if act == "swiglu":
        return {"w_gate": _dense_init(k1, (d, d_ff), d, dtype),
                "w_up": _dense_init(k2, (d, d_ff), d, dtype),
                "w_down": _dense_init(k3, (d_ff, d), d_ff, dtype)}
    return {"w_in": _dense_init(k1, (d, d_ff), d, dtype),
            "w_out": _dense_init(k2, (d_ff, d), d_ff, dtype)}


def mlp_specs(act):
    if act == "swiglu":
        return {"w_gate": ("embed", "ff"), "w_up": ("embed", "ff"),
                "w_down": ("ff", "embed")}
    return {"w_in": ("embed", "ff"), "w_out": ("ff", "embed")}


def mlp_apply(params, x, act):
    if act == "swiglu":
        g = jnp.einsum("...d,df->...f", x, params["w_gate"])
        u = jnp.einsum("...d,df->...f", x, params["w_up"])
        h = jax.nn.silu(g) * u
        return jnp.einsum("...f,fd->...d", h, params["w_down"])
    h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, params["w_in"]))
    return jnp.einsum("...f,fd->...d", h, params["w_out"])


# --------------------------------------------------------------------------- #
# GQA attention (with optional decode cache)
# --------------------------------------------------------------------------- #
def init_attention(key, cfg, dtype):
    d, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {"wq": _dense_init(k1, (d, H, Dh), d, dtype),
            "wk": _dense_init(k2, (d, Hkv, Dh), d, dtype),
            "wv": _dense_init(k3, (d, Hkv, Dh), d, dtype),
            "wo": _dense_init(k4, (H, Dh, d), H * Dh, dtype)}


def attention_specs(cfg):
    return {"wq": ("embed", "heads", "qkv"), "wk": ("embed", "kv_heads", "qkv"),
            "wv": ("embed", "kv_heads", "qkv"), "wo": ("heads", "qkv", "embed")}


_SDPA_BLOCK_THRESHOLD = 4096 * 4096   # T*S above this -> blockwise path
_SDPA_KV_BLOCK = 1024


def _sdpa_dense(q, k, v, *, causal, q_offset, kv_len_valid=None,
                soft_cap=0.0):
    """q [B,T,H,D], k/v [B,S,Hkv,D] -> [B,T,H,D]; GQA via head grouping."""
    B, T, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, T, Hkv, G, D)
    scores = jnp.einsum("bthgd,bshd->bhgts", qg, k) / math.sqrt(D)
    scores = scores.astype(jnp.float32)
    if soft_cap > 0:
        scores = soft_cap * jnp.tanh(scores / soft_cap)
    tpos = jnp.arange(T)[:, None] + q_offset
    spos = jnp.arange(S)[None, :]
    mask = jnp.ones((T, S), bool)
    if causal:
        mask &= spos <= tpos
    if kv_len_valid is not None:
        mask &= spos < kv_len_valid
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgts,bshd->bthgd", p, v)
    return out.reshape(B, T, H, v.shape[-1])


def _sdpa_blockwise(q, k, v, *, causal, q_offset, kv_len_valid=None,
                    soft_cap=0.0, kv_block=_SDPA_KV_BLOCK):
    """Online-softmax blockwise attention (flash-attention dataflow in pure
    JAX): lax.scan over KV blocks with (m, l, acc) carry — O(T * kv_block)
    live memory instead of O(T * S) scores. The long-context prefill path;
    on TPU the Pallas/XLA fused kernel would slot in here (DESIGN.md §5)."""
    B, T, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    nb = -(-S // kv_block)
    pad = nb * kv_block - S
    k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, nb, kv_block, Hkv, k.shape[-1]).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nb, kv_block, Hkv, v.shape[-1]).transpose(1, 0, 2, 3, 4)
    qg = q.reshape(B, T, Hkv, G, D)
    tpos = jnp.arange(T) + q_offset
    valid_len = S if kv_len_valid is None else kv_len_valid

    def body(carry, inp):
        m, l, acc = carry
        j, kj, vj = inp
        spos = j * kv_block + jnp.arange(kv_block)
        s = jnp.einsum("bthgd,bshd->bhgts", qg, kj).astype(jnp.float32)
        s = s / math.sqrt(D)
        if soft_cap > 0:
            s = soft_cap * jnp.tanh(s / soft_cap)
        mask = (spos[None, :] < valid_len)
        if causal:
            mask = mask & (spos[None, :] <= tpos[:, None])
        s = jnp.where(mask[None, None, None], s, -1e30)
        mj = jnp.max(s, axis=-1)
        m2 = jnp.maximum(m, mj)
        corr = jnp.exp(m - m2)
        p = jnp.exp(s - m2[..., None])
        l2 = l * corr + p.sum(-1)
        pv = jnp.einsum("bhgts,bshd->bhgtd", p.astype(vj.dtype), vj)
        acc2 = acc * corr[..., None].astype(acc.dtype) + pv
        return (m2, l2, acc2), None

    Dv = v.shape[-1]
    m0 = jnp.full((B, Hkv, G, T), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, T), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, T, Dv), v.dtype)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (jnp.arange(nb), kb, vb))
    out = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, T, H, Dv)


def _sdpa(q, k, v, *, causal, q_offset, kv_len_valid=None, soft_cap=0.0):
    T, S = q.shape[1], k.shape[1]
    if T * S > _SDPA_BLOCK_THRESHOLD and T > 1:
        return _sdpa_blockwise(q, k, v, causal=causal, q_offset=q_offset,
                               kv_len_valid=kv_len_valid, soft_cap=soft_cap)
    return _sdpa_dense(q, k, v, causal=causal, q_offset=q_offset,
                       kv_len_valid=kv_len_valid, soft_cap=soft_cap)


def attention_apply(params, x, cfg, *, positions, causal=True, cache=None):
    """cache: None (full-seq) or dict(k,v [B,Smax,Hkv,D], idx scalar) for
    one-token decode. Returns (y, new_cache)."""
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"])
    k = jnp.einsum("btd,dhk->bthk", x, params["wk"])
    v = jnp.einsum("btd,dhk->bthk", x, params["wv"])
    q = rope(q, positions, theta=cfg.rope_theta, pct=cfg.rotary_pct)
    k = rope(k, positions, theta=cfg.rope_theta, pct=cfg.rotary_pct)
    if cache is None:
        out = _sdpa(q, k, v, causal=causal, q_offset=0,
                    soft_cap=cfg.attn_logit_soft_cap)
        new_cache = {"k": k, "v": v, "idx": jnp.int32(x.shape[1])}
    else:
        idx = cache["idx"]
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, idx, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, idx, 0, 0))
        out = _sdpa(q, ck, cv, causal=causal, q_offset=idx,
                    kv_len_valid=idx + x.shape[1],
                    soft_cap=cfg.attn_logit_soft_cap)
        new_cache = {"k": ck, "v": cv, "idx": idx + x.shape[1]}
    y = jnp.einsum("bthk,hkd->btd", out, params["wo"])
    return y, new_cache


def attention_cache_shape(cfg, batch, max_len, dtype):
    Hkv, Dh = cfg.n_kv_heads, cfg.head_dim
    return {"k": jax.ShapeDtypeStruct((batch, max_len, Hkv, Dh), dtype),
            "v": jax.ShapeDtypeStruct((batch, max_len, Hkv, Dh), dtype),
            "idx": jax.ShapeDtypeStruct((), jnp.int32)}


# --------------------------------------------------------------------------- #
# MLA — Multi-head Latent Attention (DeepSeek-V2/V3)
# --------------------------------------------------------------------------- #
def init_mla(key, cfg, dtype):
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 8)
    return {
        "wdq": _dense_init(ks[0], (d, m.q_lora_rank), d, dtype),
        "q_norm": init_norm(ks[1], m.q_lora_rank, "rmsnorm", dtype),
        "wuq": _dense_init(ks[2], (m.q_lora_rank, H,
                                   m.nope_head_dim + m.rope_head_dim),
                           m.q_lora_rank, dtype),
        "wdkv": _dense_init(ks[3], (d, m.kv_lora_rank + m.rope_head_dim), d,
                            dtype),
        "kv_norm": init_norm(ks[4], m.kv_lora_rank, "rmsnorm", dtype),
        "wuk": _dense_init(ks[5], (m.kv_lora_rank, H, m.nope_head_dim),
                           m.kv_lora_rank, dtype),
        "wuv": _dense_init(ks[6], (m.kv_lora_rank, H, m.v_head_dim),
                           m.kv_lora_rank, dtype),
        "wo": _dense_init(ks[7], (H, m.v_head_dim, d), H * m.v_head_dim,
                          dtype),
    }


def mla_specs(cfg):
    return {"wdq": ("embed", "lora"), "q_norm": norm_specs("rmsnorm"),
            "wuq": ("lora", "heads", "qkv"), "wdkv": ("embed", "lora"),
            "kv_norm": norm_specs("rmsnorm"), "wuk": ("lora", "heads", "qkv"),
            "wuv": ("lora", "heads", "qkv"), "wo": ("heads", "qkv", "embed")}


def mla_apply(params, x, cfg, *, positions, causal=True, cache=None):
    m = cfg.mla
    H = cfg.n_heads
    B, T, _ = x.shape
    cq = norm_apply(params["q_norm"], jnp.einsum("btd,dr->btr", x, params["wdq"]),
                    "rmsnorm")
    q = jnp.einsum("btr,rhk->bthk", cq, params["wuq"])
    qn, qr = q[..., :m.nope_head_dim], q[..., m.nope_head_dim:]
    qr = rope(qr, positions, theta=cfg.rope_theta)

    dkv = jnp.einsum("btd,dr->btr", x, params["wdkv"])
    ckv = norm_apply(params["kv_norm"], dkv[..., :m.kv_lora_rank], "rmsnorm")
    kr = rope(dkv[..., m.kv_lora_rank:][:, :, None, :], positions,
              theta=cfg.rope_theta)[:, :, 0, :]        # shared rope key head

    if cache is not None:
        idx = cache["idx"]
        ckv = jax.lax.dynamic_update_slice(cache["ckv"], ckv, (0, idx, 0))
        kr = jax.lax.dynamic_update_slice(cache["kr"], kr, (0, idx, 0))
        new_cache = {"ckv": ckv, "kr": kr, "idx": idx + T}
        q_offset, kv_valid = idx, idx + T
    else:
        new_cache = {"ckv": ckv, "kr": kr, "idx": jnp.int32(T)}
        q_offset, kv_valid = 0, None

    kn = jnp.einsum("bsr,rhk->bshk", ckv, params["wuk"])
    v = jnp.einsum("bsr,rhk->bshk", ckv, params["wuv"])
    S = kn.shape[1]
    # fold the shared rope key head into a concat so the standard (block-
    # wise-capable) SDPA computes qn.kn + qr.kr in one pass
    q_eff = jnp.concatenate([qn, qr], -1)
    k_eff = jnp.concatenate(
        [kn, jnp.broadcast_to(kr[:, :, None, :],
                              (kr.shape[0], S, H, m.rope_head_dim))], -1)
    out = _sdpa(q_eff, k_eff, v, causal=causal or cache is not None,
                q_offset=q_offset,
                kv_len_valid=kv_valid)
    y = jnp.einsum("bthk,hkd->btd", out, params["wo"])
    return y, new_cache


def mla_cache_shape(cfg, batch, max_len, dtype):
    m = cfg.mla
    return {"ckv": jax.ShapeDtypeStruct((batch, max_len, m.kv_lora_rank), dtype),
            "kr": jax.ShapeDtypeStruct((batch, max_len, m.rope_head_dim), dtype),
            "idx": jax.ShapeDtypeStruct((), jnp.int32)}


# --------------------------------------------------------------------------- #
# cross attention (enc-dec)
# --------------------------------------------------------------------------- #
def init_cross_attention(key, cfg, dtype):
    return init_attention(key, cfg, dtype)


def cross_attention_apply(params, x, memory, cfg, *, positions):
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", memory, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", memory, params["wv"])
    out = _sdpa(q, k, v, causal=False, q_offset=0)
    return jnp.einsum("bthk,hkd->btd", out, params["wo"])
