"""Sequence-mixing recurrences: Mamba (selective SSM) for Jamba, and
xLSTM's mLSTM (matrix memory, attention-like parallel form) + sLSTM (scalar
memory, strictly sequential scan).

Train paths use parallel forms (associative_scan / masked-matrix); decode
paths carry explicit recurrent state — which is what makes the hybrid/ssm
archs eligible for the ``long_500k`` cell (O(1) state per step).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import _dense_init, init_norm, norm_apply

# --------------------------------------------------------------------------- #
# Mamba (selective SSM), diagonal A
# --------------------------------------------------------------------------- #
def init_mamba(key, cfg, dtype):
    mc = cfg.mamba
    d = cfg.d_model
    di = int(mc.expand * d)
    dt_rank = max(d // 16, 1)
    ks = jax.random.split(key, 7)
    return {
        "in_proj": _dense_init(ks[0], (d, 2 * di), d, dtype),
        "conv_w": _dense_init(ks[1], (mc.d_conv, di), mc.d_conv, dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": _dense_init(ks[2], (di, dt_rank + 2 * mc.d_state), di, dtype),
        "dt_proj": _dense_init(ks[3], (dt_rank, di), dt_rank, dtype),
        "dt_bias": jnp.zeros((di,), dtype),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, mc.d_state + 1, dtype=jnp.float32), (di, mc.d_state))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": _dense_init(ks[4], (di, d), di, dtype),
    }


def mamba_specs(cfg):
    return {"in_proj": ("embed", "inner"), "conv_w": (None, "inner"),
            "conv_b": ("inner",), "x_proj": ("inner", None),
            "dt_proj": (None, "inner"), "dt_bias": ("inner",),
            "A_log": ("inner", None), "D": ("inner",),
            "out_proj": ("inner", "embed")}


_MAMBA_CHUNK = 512


def _assoc_combine(a, b):
    (ga, xa), (gb, xb) = a, b
    return ga * gb, xa * gb + xb


def _mamba_scan(u, dt, B, C, A, D, chunk=_MAMBA_CHUNK):
    """u [b,s,di], dt [b,s,di], B/C [b,s,n], A [di,n] -> (y [b,s,di],
    h_last [b,di,n]).  h_t = exp(dt*A) h_{t-1} + dt * B_t * u_t.

    Chunked: sequential scan over S/chunk chunks carrying h, associative
    scan inside each chunk — O(b * chunk * di * n) live memory instead of
    O(b * S * di * n) (the 32k/500k-context enabling layout; the fused
    Mamba kernel's dataflow)."""
    b, s, di = u.shape
    n = B.shape[-1]
    if s <= chunk:
        dA = jnp.exp(dt[..., None] * A)
        dBu = dt[..., None] * B[..., None, :] * u[..., None]
        cumA, h = jax.lax.associative_scan(_assoc_combine, (dA, dBu), axis=1)
        y = jnp.einsum("bsdn,bsn->bsd", h, C)
        return y + D * u, h[:, -1]

    nb = -(-s // chunk)
    pad = nb * chunk - s
    def _pad(x):
        return jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))
    uc = _pad(u).reshape(b, nb, chunk, di).transpose(1, 0, 2, 3)
    dtc = _pad(dt).reshape(b, nb, chunk, di).transpose(1, 0, 2, 3)
    Bc = _pad(B).reshape(b, nb, chunk, n).transpose(1, 0, 2, 3)
    Cc = _pad(C).reshape(b, nb, chunk, n).transpose(1, 0, 2, 3)

    def body(h_in, inp):
        uj, dtj, Bj, Cj = inp
        dA = jnp.exp(dtj[..., None] * A)                     # [b,c,di,n]
        dBu = dtj[..., None] * Bj[..., None, :] * uj[..., None]
        cumA, hloc = jax.lax.associative_scan(_assoc_combine, (dA, dBu),
                                              axis=1)
        h = hloc + cumA * h_in[:, None]                      # carry folded in
        y = jnp.einsum("bcdn,bcn->bcd", h, Cj) + D * uj
        return h[:, -1], y

    h_last, ys = jax.lax.scan(body, jnp.zeros((b, di, n), u.dtype),
                              (uc, dtc, Bc, Cc))
    y = ys.transpose(1, 0, 2, 3).reshape(b, nb * chunk, di)[:, :s]
    return y, h_last


def mamba_apply(params, x, cfg, *, cache=None):
    """x [B,S,d]. cache (decode): dict(conv [B,K-1,di], h [B,di,n], idx)."""
    mc = cfg.mamba
    di = params["in_proj"].shape[1] // 2
    dt_rank = params["dt_proj"].shape[0]
    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    u, z = xz[..., :di], xz[..., di:]

    A = -jnp.exp(params["A_log"])
    if cache is None or x.shape[1] > 1:
        # full-sequence path (training, or prefill when cache is given)
        K = mc.d_conv
        up = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
        uc = sum(up[:, i:i + u.shape[1]] * params["conv_w"][i]
                 for i in range(K)) + params["conv_b"]
        uc = jax.nn.silu(uc)
        proj = jnp.einsum("bsd,de->bse", uc, params["x_proj"])
        dt = jax.nn.softplus(
            jnp.einsum("bsr,rd->bsd", proj[..., :dt_rank], params["dt_proj"])
            + params["dt_bias"])
        Bm = proj[..., dt_rank:dt_rank + mc.d_state]
        Cm = proj[..., dt_rank + mc.d_state:]
        y, h_last = _mamba_scan(uc, dt, Bm, Cm, A, params["D"])
        new_cache = None
        if cache is not None:   # prefill: hand the final state to decode
            new_cache = {"conv": up[:, -(K - 1):] if K > 1 else u[:, :0],
                         "h": h_last,
                         "idx": cache["idx"] + x.shape[1]}
    else:
        # single-token decode: S == 1
        K = mc.d_conv
        conv_hist = jnp.concatenate([cache["conv"], u], axis=1)  # [B,K,di]
        uc = jnp.einsum("bkd,kd->bd", conv_hist, params["conv_w"]) \
            + params["conv_b"]
        uc = jax.nn.silu(uc)[:, None]
        proj = jnp.einsum("bsd,de->bse", uc, params["x_proj"])
        dt = jax.nn.softplus(
            jnp.einsum("bsr,rd->bsd", proj[..., :dt_rank], params["dt_proj"])
            + params["dt_bias"])
        Bm = proj[..., dt_rank:dt_rank + mc.d_state]
        Cm = proj[..., dt_rank + mc.d_state:]
        dA = jnp.exp(dt[:, 0, :, None] * A)
        h = dA * cache["h"] + dt[:, 0, :, None] * Bm[:, 0, None, :] * uc[:, 0, :, None]
        y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0])[:, None] + params["D"] * uc
        new_cache = {"conv": conv_hist[:, 1:], "h": h,
                     "idx": cache["idx"] + 1}
    y = y * jax.nn.silu(z)
    return jnp.einsum("bsd,do->bso", y, params["out_proj"]), new_cache


def mamba_cache_shape(cfg, batch, dtype):
    mc = cfg.mamba
    di = int(mc.expand * cfg.d_model)
    return {"conv": jax.ShapeDtypeStruct((batch, mc.d_conv - 1, di), dtype),
            "h": jax.ShapeDtypeStruct((batch, di, mc.d_state), jnp.float32),
            "idx": jax.ShapeDtypeStruct((), jnp.int32)}


# --------------------------------------------------------------------------- #
# mLSTM (xLSTM matrix-memory cell) — parallel (train) + recurrent (decode)
# --------------------------------------------------------------------------- #
def init_mlstm(key, cfg, dtype):
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    ks = jax.random.split(key, 6)
    return {"wq": _dense_init(ks[0], (d, H, dh), d, dtype),
            "wk": _dense_init(ks[1], (d, H, dh), d, dtype),
            "wv": _dense_init(ks[2], (d, H, dh), d, dtype),
            "wi": _dense_init(ks[3], (d, H), d, jnp.float32),
            "wf": _dense_init(ks[4], (d, H), d, jnp.float32),
            "wo": _dense_init(ks[5], (H, dh, d), d, dtype),
            "og": _dense_init(jax.random.fold_in(key, 9), (d, H, dh), d, dtype)}


def mlstm_specs(cfg):
    return {"wq": ("embed", "heads", "qkv"), "wk": ("embed", "heads", "qkv"),
            "wv": ("embed", "heads", "qkv"), "wi": ("embed", "heads"),
            "wf": ("embed", "heads"), "wo": ("heads", "qkv", "embed"),
            "og": ("embed", "heads", "qkv")}


_MLSTM_CHUNK = 512


def _mlstm_chunked(q, k, v, i_pre, f_pre, chunk=_MLSTM_CHUNK):
    """Chunkwise mLSTM (xLSTM chunkwise backend dataflow): sequential scan
    over S/chunk chunks carrying the (C, n, m) matrix-memory state, masked
    parallel form within each chunk — O(B*chunk^2*H) live memory instead of
    the O(B*S^2*H) of the fully-parallel form. Returns (h, final_state)."""
    B, S, H, dh = q.shape
    nb = -(-S // chunk)
    pad = nb * chunk - S

    def _pad(x, fill=0.0):
        return jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2),
                       constant_values=fill)

    lf = jax.nn.log_sigmoid(f_pre)                       # [B,S,H]
    qc = _pad(q).reshape(B, nb, chunk, H, dh).transpose(1, 0, 2, 3, 4)
    kc = _pad(k).reshape(B, nb, chunk, H, dh).transpose(1, 0, 2, 3, 4)
    vc = _pad(v).reshape(B, nb, chunk, H, dh).transpose(1, 0, 2, 3, 4)
    lfc = _pad(lf, 0.0).reshape(B, nb, chunk, H).transpose(1, 0, 2, 3)
    ic = _pad(i_pre, -1e30).reshape(B, nb, chunk, H).transpose(1, 0, 2, 3)

    tri = (jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :])

    def _f32(x):
        return x.astype(jnp.float32)

    def body(carry, inp):
        Cst, nst, m_in = carry                           # [B,H,dh,dh] ...
        qj, kj, vj, lfj, ij = inp
        a = jnp.cumsum(lfj, axis=1)                      # [B,C,H]
        logw = a[:, :, None, :] - a[:, None, :, :] + ij[:, None, :, :]
        logw = jnp.where(tri[None, :, :, None], logw, -jnp.inf)
        inter = a + m_in[:, None, :]                     # [B,C,H]
        m_t = jnp.maximum(jnp.max(logw, axis=2), inter)  # [B,C,H]
        m_t = jnp.maximum(m_t, -1e30)
        wD = jnp.exp(logw - m_t[:, :, None, :])          # [B,C,C,H]
        qk = jnp.einsum("bthd,bshd->btsh", qj, kj).astype(jnp.float32)
        intra = jnp.einsum("btsh,bshe->bthe", (qk * wD).astype(vj.dtype), vj)
        winter = jnp.exp(inter - m_t)                    # [B,C,H]
        qC = jnp.einsum("bthd,bhde->bthe", qj.astype(jnp.float32), Cst)
        num = winter[..., None] * qC + intra.astype(jnp.float32)
        qn = jnp.einsum("bthd,bhd->bth", qj.astype(jnp.float32), nst)
        n_t = winter * qn + (qk * wD).sum(axis=2)
        den = jnp.maximum(jnp.abs(n_t), jnp.exp(-m_t))
        h = (num / den[..., None]).astype(vj.dtype)      # [B,C,H,dh]
        # chunk-end state
        a_end = a[:, -1]                                 # [B,H]
        w_end = a_end[:, None, :] - a + ij               # [B,C,H]
        m_out = jnp.maximum(a_end + m_in, jnp.max(w_end, axis=1))
        m_out = jnp.maximum(m_out, -1e30)
        carry_scale = jnp.exp(a_end + m_in - m_out)
        we = jnp.exp(w_end - m_out[:, None, :])
        Cst2 = carry_scale[..., None, None] * Cst + \
            jnp.einsum("bsh,bshd,bshe->bhde", we, _f32(kj), _f32(vj))
        nst2 = carry_scale[..., None] * nst + \
            jnp.einsum("bsh,bshd->bhd", we, _f32(kj))
        return (Cst2, nst2, m_out), h

    C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, H, dh), jnp.float32)
    m0 = jnp.full((B, H), -1e30, jnp.float32)
    (Cs, ns, ms), hs = jax.lax.scan(body, (C0, n0, m0),
                                    (qc, kc, vc, lfc, ic))
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, nb * chunk, H, dh)[:, :S]
    return h, (Cs, ns, ms)


def mlstm_apply(params, x, cfg, *, cache=None):
    H = cfg.n_heads
    B, S, d = x.shape
    dh = d // H
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"]) / math.sqrt(dh)
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"]) / math.sqrt(dh)
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    i_pre = jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32), params["wi"])
    f_pre = jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32), params["wf"])
    og = jax.nn.sigmoid(jnp.einsum("bsd,dhk->bshk", x, params["og"]))

    if (cache is None or S > 1) and S > _MLSTM_CHUNK:
        h, (Cs, ns, ms) = _mlstm_chunked(q, k, v, i_pre, f_pre)
        new_cache = None
        if cache is not None:
            new_cache = {"C": Cs, "n": ns, "m": ms, "idx": cache["idx"] + S}
        y = jnp.einsum("bshk,hkd->bsd", h * og, params["wo"])
        return y, new_cache

    if cache is None or S > 1:
        logf = jax.nn.log_sigmoid(f_pre)                    # [B,S,H]
        a = jnp.cumsum(logf, axis=1)
        # log D[t, s] = a[t] - a[s] + i_pre[s], s <= t
        logD = a[:, :, None, :] - a[:, None, :, :] + i_pre[:, None, :, :]
        tpos = jnp.arange(S)[:, None]
        causal = tpos >= jnp.arange(S)[None, :]
        logD = jnp.where(causal[None, :, :, None], logD, -jnp.inf)
        mrow = jnp.max(logD, axis=2, keepdims=True)          # [B,S,1,H]
        mrow = jnp.maximum(mrow, -1e30)
        Dmat = jnp.exp(logD - mrow)                          # [B,S,S,H]
        scores = jnp.einsum("bthk,bshk->btsh", q, k).astype(jnp.float32) * Dmat
        # stabilized-domain floor exp(-m) == true-scale floor 1.0 (paper eq.)
        norm = jnp.maximum(jnp.abs(scores.sum(2)),
                           jnp.exp(-mrow[:, :, 0, :]))       # [B,S,H]
        h = jnp.einsum("btsh,bshk->bthk", scores.astype(v.dtype), v)
        h = h / norm[..., None].astype(v.dtype)
        new_cache = None
        if cache is not None:   # prefill: fold the sequence into the state
            w = (a[:, -1:, :] - a) + i_pre                   # [B,S,H]
            m_fin = jnp.max(w, axis=1)                       # [B,H]
            wt = jnp.exp(w - m_fin[:, None, :])
            Cs = jnp.einsum("bsh,bshk,bshl->bhkl", wt,
                            k.astype(jnp.float32), v.astype(jnp.float32))
            ns = jnp.einsum("bsh,bshk->bhk", wt, k.astype(jnp.float32))
            new_cache = {"C": Cs, "n": ns, "m": m_fin,
                         "idx": cache["idx"] + S}
    else:
        # recurrent step: C [B,H,dh,dh], n [B,H,dh], m [B,H]
        C, n, m, idx = cache["C"], cache["n"], cache["m"], cache["idx"]
        # an empty (zero-allocated) cache means "no state": log-scale m = -inf
        m = jnp.where(idx == 0, -1e30, m)
        logf = jax.nn.log_sigmoid(f_pre[:, 0])               # [B,H]
        m_new = jnp.maximum(logf + m, i_pre[:, 0])
        fg = jnp.exp(logf + m - m_new)
        ig = jnp.exp(i_pre[:, 0] - m_new)
        k0, v0, q0 = k[:, 0], v[:, 0], q[:, 0]
        C = fg[..., None, None] * C + ig[..., None, None] * \
            jnp.einsum("bhk,bhl->bhkl", k0.astype(jnp.float32),
                       v0.astype(jnp.float32))
        n = fg[..., None] * n + ig[..., None] * k0.astype(jnp.float32)
        num = jnp.einsum("bhk,bhkl->bhl", q0.astype(jnp.float32), C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q0.astype(jnp.float32), n)),
                          jnp.exp(-m_new))
        h = (num / den[..., None]).astype(v.dtype)[:, None]
        new_cache = {"C": C, "n": n, "m": m_new, "idx": idx + 1}
        h = h.reshape(B, 1, H, dh)
    y = jnp.einsum("bshk,hkd->bsd", h * og, params["wo"])
    return y, new_cache


def mlstm_cache_shape(cfg, batch, dtype):
    H = cfg.n_heads
    dh = cfg.d_model // H
    return {"C": jax.ShapeDtypeStruct((batch, H, dh, dh), jnp.float32),
            "n": jax.ShapeDtypeStruct((batch, H, dh), jnp.float32),
            "m": jax.ShapeDtypeStruct((batch, H), jnp.float32),
            "idx": jax.ShapeDtypeStruct((), jnp.int32)}


# --------------------------------------------------------------------------- #
# sLSTM (scalar memory, exponential gating) — sequential scan
# --------------------------------------------------------------------------- #
def init_slstm(key, cfg, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 2)
    # gates i,f,z,o from input and recurrent h
    return {"w": _dense_init(ks[0], (d, 4 * d), d, dtype),
            "r": _dense_init(ks[1], (d, 4 * d), d, dtype),
            "b": jnp.zeros((4 * d,), jnp.float32)}


def slstm_specs(cfg):
    return {"w": ("embed", "ff"), "r": ("embed", "ff"), "b": ("ff",)}


def _slstm_step(params, carry, xw):
    h, c, n, m = carry
    gates = xw + jnp.einsum("bd,de->be", h, params["r"]).astype(jnp.float32) \
        + params["b"]
    d = h.shape[-1]
    i_pre, f_pre, z_pre, o_pre = jnp.split(gates, 4, -1)
    m_new = jnp.maximum(jax.nn.log_sigmoid(f_pre) + m, i_pre)
    ig = jnp.exp(i_pre - m_new)
    fg = jnp.exp(jax.nn.log_sigmoid(f_pre) + m - m_new)
    c = fg * c + ig * jnp.tanh(z_pre)
    n = fg * n + ig
    # stabilized-domain floor exp(-m) == true-scale floor 1.0
    h_new = jax.nn.sigmoid(o_pre) * c / jnp.maximum(n, jnp.exp(-m_new))
    return (h_new.astype(h.dtype), c, n, m_new), h_new


def slstm_apply(params, x, cfg, *, cache=None):
    B, S, d = x.shape
    xw = jnp.einsum("bsd,de->bse", x, params["w"]).astype(jnp.float32)
    if cache is None:
        carry = (jnp.zeros((B, d), x.dtype), jnp.zeros((B, d), jnp.float32),
                 jnp.zeros((B, d), jnp.float32),
                 jnp.full((B, d), -1e30, jnp.float32))
    else:
        # zero-allocated cache == empty state: log-scale stabilizer -> -inf
        m0 = jnp.where(cache["idx"] == 0, -1e30, cache["m"])
        carry = (cache["h"], cache["c"], cache["n"], m0)

    def step(carry, xt):
        return _slstm_step(params, carry, xt)

    carry, hs = jax.lax.scan(step, carry, xw.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).astype(x.dtype)
    new_cache = None
    if cache is not None:
        h, c, n, m = carry
        new_cache = {"h": h.astype(x.dtype), "c": c, "n": n, "m": m,
                     "idx": cache["idx"] + S}
    return y, new_cache


def slstm_cache_shape(cfg, batch, dtype):
    d = cfg.d_model
    return {"h": jax.ShapeDtypeStruct((batch, d), dtype),
            "c": jax.ShapeDtypeStruct((batch, d), jnp.float32),
            "n": jax.ShapeDtypeStruct((batch, d), jnp.float32),
            "m": jax.ShapeDtypeStruct((batch, d), jnp.float32),
            "idx": jax.ShapeDtypeStruct((), jnp.int32)}
