"""Version-compat shims for jax APIs that moved between releases.

The repo targets current jax (``jax.shard_map``, ``jax.sharding.AxisType``,
``jax.sharding.get_abstract_mesh``) but must also run on 0.4.x images where
those still live under ``jax.experimental`` / don't exist. Everything here is
a thin alias — no behaviour differences beyond disabling the replication
check (``check_vma``/``check_rep``), which the engine's collectives violate
intentionally (per-shard scalars are returned unreplicated).
"""
from __future__ import annotations

import jax

__all__ = ["make_mesh", "set_mesh", "shard_map"]


def make_mesh(shape, axes):
    """``jax.make_mesh`` that tolerates jax versions without ``axis_types``
    (explicit-sharding AxisType only exists on newer jax; Auto is the
    default behaviour on older releases anyway)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(tuple(shape), tuple(axes))
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(axis_type.Auto,) * len(axes))


def set_mesh(mesh):
    """``with set_mesh(mesh):`` — ambient-mesh context on any jax version
    (``jax.set_mesh`` on new releases; Mesh is itself the context manager on
    0.4.x, where it sets the thread-local resource env)."""
    fn = getattr(jax, "set_mesh", None)
    if fn is not None:
        return fn(mesh)
    return mesh


if hasattr(jax, "shard_map"):
    def shard_map(f, *, mesh, in_specs, out_specs):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
else:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_legacy

    def shard_map(f, *, mesh, in_specs, out_specs):
        return _shard_map_legacy(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=False)
