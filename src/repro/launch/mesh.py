"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required by the dry-run, whose first two lines
set XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_graph_mesh(*, multi_pod: bool = False):
    """Same chips, graph-engine view: (pod, data) -> subgraphs, model ->
    intra-partition edge shards (hierarchical SVHM, DESIGN.md §2)."""
    return make_production_mesh(multi_pod=multi_pod)


def make_host_mesh(n: int = 1, axis: str = "data"):
    """Small CPU mesh for tests/examples."""
    return make_mesh((n,), (axis,))
