import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_FLAGS",
                                         "--xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks the device count on first init.

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np   # noqa: E402

from repro.compat import set_mesh                                 # noqa: E402
from repro.configs import ARCHS, get_config                       # noqa: E402
from repro.launch import hlo_stats                                # noqa: E402
from repro.launch.mesh import make_production_mesh                # noqa: E402
from repro.launch.specs import cache_len, input_specs             # noqa: E402
from repro.models import model as M                               # noqa: E402
from repro.models.config import SHAPES, shape_applicable          # noqa: E402
from repro.sharding import rules as R                             # noqa: E402
from repro.training import steps as S                             # noqa: E402
from repro.training.optimizer import AdamWState                   # noqa: E402

"""Multi-pod dry-run (deliverable e).

For every (architecture x input-shape x mesh) cell:
  jit(step).lower(ShapeDtypeStructs w/ NamedShardings).compile()
then record memory_analysis / cost_analysis / collective bytes to JSON for
EXPERIMENTS.md §Dry-run and the roofline (§Roofline). No arrays are ever
allocated — params, optimizer state, caches and batches are all
ShapeDtypeStruct stand-ins.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out results/dryrun
"""

LM_ARCHS = [a for a in ARCHS if a != "drone_graph"]


def _sds_with(shardings, shapes):
    return jax.tree.map(
        lambda sh, sd: jax.ShapeDtypeStruct(sd.shape, sd.dtype, sharding=sh),
        shardings, shapes)


def _eval_shape_params(cfg):
    return jax.eval_shape(lambda k: M.init_model(k, cfg),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def lower_cell(arch: str, shape_name: str, mesh, *, lower_only=False,
               variant: str = "base"):
    cfg = get_config(arch)
    if variant == "opt":
        from repro.configs.variants import optimized
        cfg = optimized(cfg)
    kind, batch_sds, cache_sds = input_specs(cfg, shape_name)
    rules = R.rules_for(mesh)

    p_shapes = _eval_shape_params(cfg)
    p_shard = R.param_shardings(mesh, M.model_specs(cfg), p_shapes)
    params_in = _sds_with(p_shard, p_shapes)

    def _bshard(sd):
        spec = R.logical_to_spec(("batch",) + (None,) * (len(sd.shape) - 1),
                                 rules)
        # drop the batch mapping if the global batch doesn't divide the axes
        m = spec[0]
        axes = (m,) if isinstance(m, str) else tuple(m or ())
        n = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
        if n > 1 and sd.shape[0] % n != 0:
            spec = jax.sharding.PartitionSpec(*((None,) + tuple(spec)[1:]))
        return jax.ShapeDtypeStruct(
            sd.shape, sd.dtype, sharding=jax.NamedSharding(mesh, spec))

    batch_in = jax.tree.map(_bshard, batch_sds)

    if kind == "train":
        opt_shapes = jax.eval_shape(
            lambda p: AdamWState(step=jnp.zeros((), jnp.int32),
                                 m=jax.tree.map(
                                     lambda x: jnp.zeros(x.shape, jnp.float32), p),
                                 v=jax.tree.map(
                                     lambda x: jnp.zeros(x.shape, jnp.float32), p)),
            p_shapes)
        opt_shard = AdamWState(
            step=jax.NamedSharding(mesh, jax.sharding.PartitionSpec()),
            m=R.param_shardings(mesh, M.model_specs(cfg), p_shapes),
            v=R.param_shardings(mesh, M.model_specs(cfg), p_shapes))
        state_in = S.TrainState(params=params_in,
                                opt=_sds_with(opt_shard, opt_shapes))
        step = S.make_train_step(cfg)
        fn = jax.jit(step, donate_argnums=(0,))
        args = (state_in, batch_in)
    elif kind == "prefill":
        step = S.make_prefill_step(cfg, cache_len(cfg,
                                                  SHAPES[shape_name]["seq_len"]))
        fn = jax.jit(step)
        args = (params_in, batch_in)
    else:  # decode
        c_shard = R.cache_shardings(mesh, M.cache_specs(cfg), cache_sds)
        cache_in = _sds_with(c_shard, cache_sds)
        step = S.make_serve_step(cfg)
        fn = jax.jit(step, donate_argnums=(1,))
        args = (params_in, cache_in, batch_in)

    with set_mesh(mesh):
        lowered = fn.lower(*args)
        if lower_only:
            return lowered, None
        compiled = lowered.compile()
    return lowered, compiled


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             *, force=False, variant: str = "base") -> dict:
    suffix = "" if variant == "base" else f"__{variant}"
    path = os.path.join(out_dir,
                        f"{arch}__{shape_name}__{mesh_kind}{suffix}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            cached = json.load(f)
        if cached.get("status") != "error":   # errored cells always re-run
            return cached
    cfg = get_config(arch)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "family": cfg.family, "variant": variant}
    ok, why = shape_applicable(cfg, shape_name)
    if not ok:
        rec.update(status="skipped", reason=why)
    else:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
        t0 = time.time()
        try:
            lowered, compiled = lower_cell(arch, shape_name, mesh,
                                           variant=variant)
            rec["compile_s"] = round(time.time() - t0, 1)
            rec["cost"] = hlo_stats.cost_stats(compiled)
            rec["memory"] = hlo_stats.memory_stats(compiled)
            txt = compiled.as_text()
            rec["collectives"] = hlo_stats.collective_stats(txt)
            from repro.launch import hlo_walk
            rec["walk"] = hlo_walk.analyze(txt)
            rec["hlo_lines"] = txt.count("\n")
            rec["n_devices"] = int(np.prod(list(mesh.shape.values())))
            rec["status"] = "ok"
        except Exception as e:
            rec["status"] = "error"
            rec["error"] = f"{type(e).__name__}: {e}"
            rec["traceback"] = traceback.format_exc()[-3000:]
    os.makedirs(out_dir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multipod", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="base", choices=["base", "opt"])
    args = ap.parse_args()

    archs = LM_ARCHS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = (["single", "multipod"] if args.mesh == "both" else [args.mesh])

    n_ok = n_skip = n_err = 0
    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                rec = run_cell(arch, shape, mk, args.out, force=args.force,
                               variant=args.variant)
                tag = rec["status"]
                n_ok += tag == "ok"
                n_skip += tag == "skipped"
                n_err += tag == "error"
                extra = ""
                if tag == "ok":
                    f = rec["cost"].get("flops", 0)
                    mem = rec["memory"].get("temp_size_in_bytes", 0)
                    extra = (f" flops={f:.3e} temp={mem/2**30:.2f}GiB"
                             f" coll={rec['collectives']['bytes_per_device']/2**30:.3f}GiB"
                             f" t={rec.get('compile_s')}s")
                elif tag == "error":
                    extra = " " + rec["error"][:200]
                print(f"[{tag:7s}] {arch:24s} {shape:12s} {mk:8s}{extra}",
                      flush=True)
    print(f"done: ok={n_ok} skipped={n_skip} err={n_err}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
