"""Collective/byte statistics from compiled HLO text (roofline inputs).

``compiled.cost_analysis()`` reports FLOPs and bytes accessed but NOT
collective traffic; we parse the post-SPMD optimized HLO and sum the shapes
flowing through every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute. Per-op byte accounting (per participating device):

  all-gather:          output_bytes * (N-1)/N     received
  all-reduce:          2 * bytes * (N-1)/N        (ring: RS + AG phases)
  reduce-scatter:      input_bytes * (N-1)/N
  all-to-all:          bytes * (N-1)/N
  collective-permute:  bytes                       (one hop)

N = participants per replica group (parsed from replica_groups when
available, else the mesh size hint).
"""
from __future__ import annotations

import re
from collections import defaultdict

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s+(.{0,400}?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str, default_group: int = 2) -> dict:
    """Sum per-device collective bytes by op kind."""
    per_kind = defaultdict(float)
    counts = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or m.group(3) == "-done":
            continue
        result_txt, kind = m.group(1), m.group(2)
        size = _shape_bytes(result_txt)
        # participants
        g = _GROUPS_RE.search(line)
        if g:
            n = len([x for x in g.group(1).split(",") if x.strip() != ""])
        else:
            g2 = _GROUPS2_RE.search(line)
            n = int(g2.group(2)) if g2 else default_group
        n = max(n, 2)
        frac = (n - 1) / n
        if kind == "all-gather":
            moved = size * frac
        elif kind == "all-reduce":
            moved = 2 * size * frac
        elif kind == "reduce-scatter":
            # result is the scattered shard; ring moves input = shard * N
            moved = size * n * frac
        elif kind == "all-to-all":
            moved = size * frac
        else:  # collective-permute
            moved = size
        per_kind[kind] += moved
        counts[kind] += 1
    total = float(sum(per_kind.values()))
    return {"bytes_per_device": total,
            "by_kind": {k: float(v) for k, v in per_kind.items()},
            "counts": dict(counts)}


def cost_stats(compiled) -> dict:
    out = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        for k in ("flops", "bytes accessed", "transcendentals",
                  "optimal_seconds"):
            if k in ca:
                out[k.replace(" ", "_")] = float(ca[k])
        out["_raw_keys"] = sorted(ca.keys())[:50]
    except Exception as e:  # pragma: no cover
        out["error"] = repr(e)
    return out


def memory_stats(compiled) -> dict:
    out = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes",
                  "host_argument_size_in_bytes"):
            if hasattr(ma, k):
                out[k] = int(getattr(ma, k))
    except Exception as e:  # pragma: no cover
        out["error"] = repr(e)
    return out
