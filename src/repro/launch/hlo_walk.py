"""Loop-aware HLO accounting.

XLA's HloCostAnalysis counts each computation ONCE — a scan lowered to
``while`` with trip count 126 under-reports its body's FLOPs and collective
bytes by 126x. This walker parses the post-optimization HLO text into
computations, recovers while-loop trip counts from their condition
computations, and accumulates

  - matmul FLOPs:        2 * |output| * prod(contracting dims) per dot
                         (+ convolutions via the same formula)
  - collective bytes:    per-device moved bytes per op kind (ring model)
  - HBM traffic proxy:   bytes of every dot/convolution operand + result
                         (once per execution) — a lower bound on touched
                         bytes that scales with trip count, unlike
                         cost_analysis' 'bytes accessed'

multiplied through while trip counts and fusion/call/conditional edges.
Elementwise FLOPs are not counted (matmuls dominate the archs here; the
roofline notes this).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1, "c128": 16,
}

_COMP_NAME_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_TRIP_RE = re.compile(r"known_trip_count\\?\":\{\\?\"n\\?\":\\?\"(\d+)\\?\"")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_CALLED_RE = re.compile(
    r"(?:body|condition|calls|branch_computations|to_apply)="
    r"(?:\{([^}]*)\}|%?([\w\.\-]+))")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_DOT_RE = re.compile(r"=\s*(\(?.{0,400}?)\s(dot|convolution)\(")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_LHS_SHAPE_RE = re.compile(r"(dot|convolution)\(\s*([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s+(.{0,400}?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_WHILE_RE = re.compile(r"\swhile\(")


def _elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _first_shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt in _DTYPE_BYTES:
            total += _elems(dims) * _DTYPE_BYTES[dt]
    return total


@dataclass
class CompStats:
    flops: float = 0.0
    coll: dict = field(default_factory=lambda: defaultdict(float))
    coll_counts: dict = field(default_factory=lambda: defaultdict(float))
    hbm: float = 0.0
    # edges: (multiplier_kind, called_comp) — 'while' resolved w/ trip count
    whiles: list = field(default_factory=list)  # (cond, body, trip_or_None)
    calls: list = field(default_factory=list)       # called once per exec
    max_s32_const: int = 1


def parse_computations(hlo: str) -> dict:
    comps, name, buf = {}, None, []
    for line in hlo.splitlines():
        stripped = line.strip()
        if name is None:
            if stripped.endswith("{") and "->" in stripped:
                m = _COMP_NAME_RE.match(stripped)
                if m:
                    name, buf = m.group(1), []
            continue
        if stripped == "}":
            comps[name] = buf
            name = None
            continue
        buf.append(line)
    return comps


_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*?)\s+[a-z][\w\-]*\(")
_OPERAND_RE = re.compile(r"\(\s*%([\w\.\-]+)")


def _build_symtab(lines) -> dict:
    """instruction name -> (dims list, bytes) from its result type."""
    tab = {}
    for line in lines:
        m = _DEF_RE.match(line)
        if not m:
            continue
        shapes = _SHAPE_RE.findall(m.group(2))
        if shapes:
            dt, dims = shapes[0]
            tab[m.group(1)] = ([int(x) for x in dims.split(",") if x],
                               _first_shape_bytes(m.group(2)))
    return tab


def _line_stats(line: str, st: CompStats, symtab: dict):
    # dots / convolutions
    m = _DOT_RE.search(line)
    if m:
        out_elems = 0
        for dt, dims in _SHAPE_RE.findall(m.group(1)):
            if dt in _DTYPE_BYTES:
                out_elems += _elems(dims)
        c = _CONTRACT_RE.search(line)
        contract = 1
        lhs_dims, lhs_bytes = None, 0
        # operand shapes: inline (rare) or via the symbol table
        lhs_inline = _LHS_SHAPE_RE.search(line)
        if lhs_inline:
            lhs_dims = [int(x) for x in lhs_inline.group(3).split(",") if x]
        else:
            ops = _OPERAND_RE.search(line[m.start(2):])
            if ops and ops.group(1) in symtab:
                lhs_dims, lhs_bytes = symtab[ops.group(1)]
        if c and lhs_dims is not None:
            for ci in c.group(1).split(","):
                if ci and int(ci) < len(lhs_dims):
                    contract *= lhs_dims[int(ci)]
        st.flops += 2.0 * out_elems * contract
        # HBM proxy: result + operand bytes
        opb = 0
        tail = line[m.start(2):]
        for opname in re.findall(r"%([\w\.\-]+)", tail)[:4]:
            if opname in symtab:
                opb += symtab[opname][1]
        st.hbm += _first_shape_bytes(m.group(1)) + opb
    # collectives
    mc = _COLL_RE.search(line)
    if mc and mc.group(3) != "-done":
        size = _first_shape_bytes(mc.group(1))
        kind = mc.group(2)
        n = 2.0
        g = re.search(r"replica_groups=\{\{([0-9, ]+)\}", line)
        if g:
            n = max(len([x for x in g.group(1).split(",") if x.strip()]), 2)
        else:
            g2 = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
            if g2:
                n = max(int(g2.group(2)), 2)
        frac = (n - 1) / n
        moved = {"all-gather": size * frac,
                 "all-reduce": 2 * size * frac,
                 "reduce-scatter": size * n * frac,
                 "all-to-all": size * frac,
                 "collective-permute": size}[kind]
        st.coll[kind] += moved
        st.coll_counts[kind] += 1
    # constants (trip-count hints when this comp is a while condition)
    for cst in _CONST_RE.findall(line):
        st.max_s32_const = max(st.max_s32_const, int(cst))
    # called computations
    if _WHILE_RE.search(line):
        mcond = re.search(r"condition=%?([\w\.\-]+)", line)
        mbody = re.search(r"body=%?([\w\.\-]+)", line)
        mt = _TRIP_RE.search(line)
        trip = int(mt.group(1)) if mt else None
        if mcond and mbody:
            st.whiles.append((mcond.group(1), mbody.group(1), trip))
    else:
        for m2 in _CALLED_RE.finditer(line):
            blob = m2.group(1)
            if blob is not None:
                for nm in blob.split(","):
                    nm = nm.strip().lstrip("%")
                    if nm:
                        st.calls.append(nm)
            elif m2.group(2):
                st.calls.append(m2.group(2).lstrip("%"))


def analyze(hlo: str) -> dict:
    comps = parse_computations(hlo)
    stats = {}
    for nm, lines in comps.items():
        st = CompStats()
        symtab = _build_symtab(lines)
        for line in lines:
            _line_stats(line, st, symtab)
        stats[nm] = st

    memo = {}

    def total(nm, depth=0):
        if nm in memo:
            return memo[nm]
        if nm not in stats or depth > 50:
            return (0.0, defaultdict(float), defaultdict(float), 0.0)
        st = stats[nm]
        fl = st.flops
        co = defaultdict(float, st.coll)
        cc = defaultdict(float, st.coll_counts)
        hb = st.hbm
        for callee in st.calls:
            f2, c2, n2, h2 = total(callee, depth + 1)
            fl += f2
            hb += h2
            for k, v in c2.items():
                co[k] += v
            for k, v in n2.items():
                cc[k] += v
        for cond, body, trip in st.whiles:
            if trip is None:
                trip = stats[cond].max_s32_const if cond in stats else 1
            fb, cb, nb, hbb = total(body, depth + 1)
            fc, ccnd, ncnd, hc = total(cond, depth + 1)
            fl += trip * (fb + fc)
            hb += trip * (hbb + hc)
            for k, v in cb.items():
                co[k] += trip * v
            for k, v in nb.items():
                cc[k] += trip * v
        memo[nm] = (fl, co, cc, hb)
        return memo[nm]

    # entry computation: the one nobody calls
    called = set()
    for st in stats.values():
        called.update(st.calls)
        for c, b, _ in st.whiles:
            called.update([c, b])
    entries = [nm for nm in stats if nm not in called]
    fl = hb = 0.0
    co, cc = defaultdict(float), defaultdict(float)
    for e in entries:
        f, c, n, h = total(e)
        fl += f
        hb += h
        for k, v in c.items():
            co[k] += v
        for k, v in n.items():
            cc[k] += v
    return {"dot_flops_per_device": fl,
            "collective_bytes_per_device": float(sum(co.values())),
            "collective_by_kind": {k: float(v) for k, v in co.items()},
            "collective_counts": {k: float(v) for k, v in cc.items()},
            "dot_hbm_bytes_per_device": hb,
            "n_computations": len(comps),
            "n_entries": len(entries)}
