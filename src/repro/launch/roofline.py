"""Roofline analysis (deliverable g) — reads the dry-run JSONs and derives
the three terms per (arch x shape x mesh) cell on TPU v5e constants:

  compute term    = dot_FLOPs_per_device / 197e12          [s]
  memory term     = HBM_bytes_per_device / 819e9           [s]
  collective term = collective_bytes_per_device / 50e9     [s]

FLOPs/bytes come from the loop-aware HLO walk (hlo_walk.py) — XLA's
cost_analysis does not multiply `while` bodies by their trip counts, so raw
cost_analysis numbers are reported only as a cross-check. MODEL_FLOPS uses
6*N*D (dense) / 6*N_active*D (MoE) per the spec; the ratio
MODEL_FLOPS / HLO_FLOPS exposes remat/redundancy overhead.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / link (ICI)
HBM_CAP = 16 * 2 ** 30       # v5e HBM per chip

_PARAM_CACHE = {}


def param_counts(arch: str):
    """(n_total, n_active) parameters (active = per-token, MoE-aware)."""
    if arch in _PARAM_CACHE:
        return _PARAM_CACHE[arch]
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import model as M
    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda k: M.init_model(k, cfg),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    total = active = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        keys = "".join(str(p) for p in path)
        if cfg.moe and ("w_gate" in keys or "w_up" in keys or
                        "w_down" in keys) and "blocks" in keys:
            active += n * cfg.moe.top_k / cfg.moe.n_experts
        else:
            active += n
    _PARAM_CACHE[arch] = (int(total), int(active))
    return _PARAM_CACHE[arch]


def model_flops(rec: dict) -> float:
    """Spec MODEL_FLOPS for the cell (total across chips)."""
    from repro.models.config import SHAPES
    sh = SHAPES[rec["shape"]]
    n_total, n_active = param_counts(rec["arch"])
    if sh["kind"] == "train":
        tokens = sh["seq_len"] * sh["global_batch"]
        return 6.0 * n_active * tokens
    if sh["kind"] == "prefill":
        tokens = sh["seq_len"] * sh["global_batch"]
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * sh["global_batch"]


def analyze_record(rec: dict) -> dict:
    if rec.get("status") != "ok":
        return dict(rec, terms=None)
    w = rec["walk"]
    n_dev = rec.get("n_devices", 512 if rec["mesh"] == "multipod" else 256)
    t_comp = w["dot_flops_per_device"] / PEAK_FLOPS
    t_mem = w["dot_hbm_bytes_per_device"] / HBM_BW
    t_coll = w["collective_bytes_per_device"] / LINK_BW
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    mem = rec.get("memory", {})
    footprint = mem.get("temp_size_in_bytes", 0) + \
        mem.get("argument_size_in_bytes", 0) / max(n_dev, 1)
    mf = model_flops(rec) if rec.get("kind") != "graph_engine" else None
    hlo_total = w["dot_flops_per_device"] * n_dev
    out = dict(rec)
    out.update(
        terms=terms, dominant=dominant.replace("_s", ""),
        bound_s=max(terms.values()),
        model_flops=mf,
        useful_ratio=(mf / hlo_total) if (mf and hlo_total) else None,
        roofline_fraction=(min(mf / n_dev / PEAK_FLOPS, t_comp)
                           / max(max(terms.values()), 1e-30)) if mf else None,
        fits_hbm=footprint <= HBM_CAP,
        temp_gib=mem.get("temp_size_in_bytes", 0) / 2 ** 30,
    )
    return out


def suggestion(row: dict) -> str:
    if row.get("terms") is None:
        return ""
    d = row["dominant"]
    coll = row["walk"].get("collective_by_kind", {})
    top_coll = max(coll, key=coll.get) if coll else ""
    if d == "collective":
        return (f"dominated by {top_coll}; reduce via sharding that keeps "
                "the operand local (expert/data remap), comm-compute overlap,"
                " or quantized payloads")
    if d == "memory":
        return ("HBM-bound: fuse/blockwise the dominant op, tighten remat "
                "policy, or shard the live tensor further")
    if (row.get("useful_ratio") or 1) < 0.4:
        return "compute-bound but low useful ratio: cut remat recompute"
    return "compute-bound: near the right regime; raise per-chip utilization"


def markdown_table(rows, *, include_graph=True) -> str:
    lines = ["| arch | shape | mesh | compute s | memory s | collective s | "
             "dominant | MODEL/HLO | roofline frac | temp GiB | fits |",
             "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r.get("arch", r.get("scale", "")),
                                         r.get("shape", r.get("algo", "")),
                                         r["mesh"])):
        if r.get("status") == "skipped":
            name = r.get("arch") or f"graph:{r.get('scale')}"
            lines.append(f"| {name} | {r.get('shape') or r.get('algo')} | "
                         f"{r['mesh']} | — | — | — | skipped | — | — | — | "
                         f"{r['reason'][:70]}… |")
            continue
        if r.get("terms") is None:
            continue
        t = r["terms"]
        ur = f"{r['useful_ratio']:.2f}" if r.get("useful_ratio") else "—"
        rf = f"{r['roofline_fraction']:.2f}" if r.get("roofline_fraction") else "—"
        name = r.get("arch") or f"graph:{r.get('scale')}"
        if r.get("variant") not in (None, "base", "opt") or \
                (r.get("variant") == "opt" and r.get("arch")):
            name += f" [{r['variant']}]"
        shape = r.get("shape") or r.get("algo")
        lines.append(
            f"| {name} | {shape} | {r['mesh']} | {t['compute_s']:.3e} | "
            f"{t['memory_s']:.3e} | {t['collective_s']:.3e} | "
            f"{r['dominant']} | {ur} | {rf} | {r['temp_gib']:.1f} | "
            f"{'y' if r['fits_hbm'] else 'NO'} |")
    return "\n".join(lines)


def load_all(dry_dir: str):
    rows = []
    for path in sorted(glob.glob(os.path.join(dry_dir, "*.json"))):
        with open(path) as f:
            rows.append(analyze_record(json.load(f)))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry", default="results/dryrun")
    ap.add_argument("--out", default="results/roofline.md")
    args = ap.parse_args()
    rows = load_all(args.dry)
    md = ["# Roofline (v5e: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s/link ICI)",
          "", markdown_table(rows), "", "## Bottleneck notes", ""]
    for r in rows:
        if r.get("terms") is None:
            continue
        name = r.get("arch") or f"graph:{r.get('scale')}"
        if r.get("variant") not in (None, "base") and r.get("arch"):
            name += f" [{r['variant']}]"
        md.append(f"- **{name} / {r.get('shape') or r.get('algo')} / "
                  f"{r['mesh']}** — {r['dominant']}-bound "
                  f"({r['bound_s']:.2e}s): {suggestion(r)}")
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        f.write("\n".join(md) + "\n")
    with open(args.out.replace(".md", ".json"), "w") as f:
        json.dump([{k: v for k, v in r.items()
                    if k not in ("traceback",)} for r in rows], f, indent=1,
                  default=str)
    ok = sum(1 for r in rows if r.get("status") == "ok")
    print(f"wrote {args.out}: {ok} analyzed cells")


if __name__ == "__main__":
    main()
