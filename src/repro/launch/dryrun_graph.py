import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_FLAGS",
                                         "--xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import (see dryrun.py).

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np   # noqa: E402

from repro.algos import ConnectedComponents, PageRank, SSSP  # noqa: E402
from repro.core.api import DeviceSubgraph                    # noqa: E402
from repro.core.engine import EngineConfig, make_bsp_runner  # noqa: E402
from repro.launch import hlo_stats, hlo_walk                 # noqa: E402
from repro.launch.mesh import make_mesh, make_production_mesh  # noqa: E402

"""Graph-engine multi-pod dry-run — the paper's own workload on the
production mesh, including the TRILLION-EDGE capability point (the paper's
headline: 'orders of magnitude larger than previously reported by SC
frameworks').

Subgraph arrays are ShapeDtypeStruct stand-ins sized from (n_edges, n_parts,
replication-factor estimate); the BSP superstep loop (engine.make_bsp_runner:
local fixed-point sweeps + SBS combiner all-reduce) is lowered + compiled for
(pod, data) x model = 512 chips. memory_analysis proves the per-device
footprint fits; the roofline terms come from the compiled HLO.
"""


@dataclasses.dataclass
class GraphScale:
    name: str
    n_edges: int
    n_vertices: int
    rf: float = 4.0          # replication factor estimate (CDBH, power-law)
    frontier_frac: float = 0.5

    def meta(self, n_parts, edge_shards, pad=1.05):
        e_max = int(self.n_edges / n_parts * pad)
        e_max = -(-e_max // (128 * edge_shards)) * (128 * edge_shards)
        v_max = int(self.n_vertices * self.rf / n_parts * pad)
        v_max = -(-v_max // 128) * 128
        n_slots = min(int(self.n_vertices * self.frontier_frac),
                      v_max * n_parts)
        return dict(e_max=e_max, v_max=v_max, n_slots=n_slots)


SCALES = {
    "kron26": GraphScale("kron26", 2 ** 26 * 16 * 2, 2 ** 26),       # 2.1B
    "kron30": GraphScale("kron30", 2 ** 30 * 16 * 2, 2 ** 30),       # 34B
    "kron33-100B": GraphScale("kron33-100B", 2 ** 33 * 16, 2 ** 33),  # 137B
    # 1.1T edges (Kronecker scale-34, edge-factor 64): raw capacity needs
    # >= 4 v5e pods (13TB of edges), so this runs on an 8-pod
    # (8,16,16)=2048-chip mesh — the 1000+-node design point. Requires
    # DRYRUN_XLA_FLAGS=--xla_force_host_platform_device_count=2048
    "trillion": GraphScale("trillion", 2 ** 40, 2 ** 34, rf=2.5,
                           frontier_frac=0.25),                      # 1.1T
}
TRILLION_MESH = (8, 16, 16)
INT32_LIMIT = 2 ** 31

ALGOS = {
    "cc": (ConnectedComponents, None),
    "sssp": (SSSP, {"source": jnp.int32(0)}),
    "pagerank": (PageRank, {"n_vertices": 2.0 ** 30}),
}


def _sds_subgraph(meta, n_parts, mesh, sub_axes, edge_axes):
    from jax.sharding import NamedSharding, PartitionSpec as P
    e, v = meta["e_max"], meta["v_max"]
    espec = NamedSharding(mesh, P(sub_axes, edge_axes or None))
    vspec = NamedSharding(mesh, P(sub_axes, None))

    def E(dt):
        return jax.ShapeDtypeStruct((n_parts, e), dt, sharding=espec)

    def V(dt):
        return jax.ShapeDtypeStruct((n_parts, v), dt, sharding=vspec)

    return DeviceSubgraph(
        esrc=E(jnp.int32), edst=E(jnp.int32), ew=E(jnp.float32),
        emask=E(jnp.bool_), slot=V(jnp.int32), vmask=V(jnp.bool_),
        vid32=V(jnp.int32), is_frontier=V(jnp.bool_), out_deg=V(jnp.float32),
        in_deg=V(jnp.float32), is_master=V(jnp.bool_), vlabel=None)


def lower_graph_cell(scale_name: str, algo: str, multi_pod: bool,
                     *, max_local_iters=64, dense_slots=False,
                     lean=True):
    if scale_name == "trillion":
        if len(jax.devices()) < int(np.prod(TRILLION_MESH)):
            raise RuntimeError(
                "trillion point needs a 2048-chip mesh: rerun with "
                "DRYRUN_XLA_FLAGS=--xla_force_host_platform_device_count=2048")
        mesh = make_mesh(TRILLION_MESH, ("pod", "data", "model"))
        sub_axes = ("pod", "data")
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
        sub_axes = ("pod", "data") if multi_pod else ("data",)
    edge_axes = ("model",)
    n_parts = int(np.prod([mesh.shape[a] for a in sub_axes]))
    sc = SCALES[scale_name]
    meta = sc.meta(n_parts, mesh.shape["model"])
    if meta["v_max"] >= INT32_LIMIT:
        raise ValueError(
            f"per-partition vertex table v_max={meta['v_max']:.3e} exceeds "
            "int32 local indexing — scale out to more subgraphs "
            "(design constraint, DESIGN.md §7)")

    prog_cls, params = ALGOS[algo]
    prog = prog_cls()
    cfg = EngineConfig(mode="sc", backend="shard_map",
                       subgraph_axes=sub_axes, edge_axes=edge_axes,
                       max_local_iters=max_local_iters,
                       shard_slots=not dense_slots, lean_frontier=lean)
    go = make_bsp_runner(prog, mesh, cfg, meta["n_slots"], params=params,
                         has_vlabel=False)
    sgs = _sds_subgraph(meta, n_parts, mesh, sub_axes, edge_axes)
    with mesh:
        lowered = jax.jit(go).lower(sgs)
        compiled = lowered.compile()
    return meta, n_parts, compiled


def run_cell(scale_name, algo, mesh_kind, out_dir, force=False,
             variant="opt"):
    suffix = "" if variant == "opt" else f"__{variant}"
    path = os.path.join(out_dir,
                        f"graph__{scale_name}__{algo}__{mesh_kind}{suffix}.json")
    if os.path.exists(path) and not force:
        return json.load(open(path))
    rec = {"scale": scale_name, "algo": algo, "mesh": mesh_kind,
           "kind": "graph_engine", "variant": variant}
    t0 = time.time()
    try:
        meta, n_parts, compiled = lower_graph_cell(
            scale_name, algo, mesh_kind == "multipod",
            dense_slots=(variant == "dense"), lean=(variant != "dense"))
        txt = compiled.as_text()
        rec.update(status="ok", compile_s=round(time.time() - t0, 1),
                   meta=meta, n_parts=n_parts,
                   cost=hlo_stats.cost_stats(compiled),
                   memory=hlo_stats.memory_stats(compiled),
                   collectives=hlo_stats.collective_stats(txt),
                   walk=hlo_walk.analyze(txt))
    except (RuntimeError, ValueError) as e:
        # capacity/topology constraints -> documented skip, not a bug
        rec.update(status="skipped", reason=str(e))
    except Exception as e:
        import traceback
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    os.makedirs(out_dir, exist_ok=True)
    json.dump(rec, open(path, "w"), indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="all")
    ap.add_argument("--algo", default="cc")
    ap.add_argument("--mesh", default="both")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="opt", choices=["opt", "dense"])
    args = ap.parse_args()
    scales = list(SCALES) if args.scale == "all" else [args.scale]
    meshes = ["single", "multipod"] if args.mesh == "both" else [args.mesh]
    algos = list(ALGOS) if args.algo == "all" else [args.algo]
    bad = 0
    for s in scales:
        for a in algos:
            for mk in meshes:
                rec = run_cell(s, a, mk, args.out, args.force,
                               variant=args.variant)
                ok = rec["status"] == "ok"
                bad += not ok
                if ok:
                    mem = rec["memory"].get("temp_size_in_bytes", 0)
                    arg = rec["memory"].get("argument_size_in_bytes", 0)
                    print(f"[ok   ] graph {s:12s} {a:8s} {mk:8s} "
                          f"temp={mem/2**30:.2f}GiB args={arg/2**30:.1f}GiB "
                          f"coll/step~{rec['walk']['collective_bytes_per_device']/2**20:.1f}MiB",
                          flush=True)
                else:
                    print(f"[error] graph {s} {a} {mk}: {rec['error'][:200]}",
                          flush=True)
    if bad:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
