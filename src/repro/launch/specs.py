"""ShapeDtypeStruct input stand-ins for every (arch x shape) cell — no
device allocation; the dry-run lowers against these."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import SHAPES, ModelConfig, shape_applicable


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def train_batch_specs(cfg: ModelConfig, seq_len: int, global_batch: int):
    b = {"tokens": _sds((global_batch, seq_len), jnp.int32),
         "labels": _sds((global_batch, seq_len), jnp.int32)}
    if cfg.frontend:
        b["frontend"] = _sds((global_batch, cfg.frontend_len,
                              cfg.frontend_dim), jnp.dtype(cfg.activation_dtype))
    return b


def prefill_batch_specs(cfg: ModelConfig, seq_len: int, global_batch: int):
    b = {"tokens": _sds((global_batch, seq_len), jnp.int32)}
    if cfg.frontend:
        b["frontend"] = _sds((global_batch, cfg.frontend_len,
                              cfg.frontend_dim), jnp.dtype(cfg.activation_dtype))
    return b


def decode_batch_specs(cfg: ModelConfig, global_batch: int):
    b = {"tokens": _sds((global_batch, 1), jnp.int32)}
    if cfg.n_enc_layers:
        b["memory"] = _sds((global_batch, cfg.frontend_len, cfg.d_model),
                           jnp.dtype(cfg.activation_dtype))
    return b


def cache_len(cfg: ModelConfig, seq_len: int) -> int:
    """KV capacity: the context plus the modality prefix (VLM)."""
    extra = cfg.frontend_len if (cfg.frontend and not cfg.n_enc_layers) else 0
    return seq_len + extra


def input_specs(cfg: ModelConfig, shape_name: str):
    """-> (kind, batch_sds, cache_sds_or_None). kind: train|prefill|decode."""
    ok, why = shape_applicable(cfg, shape_name)
    if not ok:
        raise ValueError(f"{cfg.name} x {shape_name} skipped: {why}")
    sh = SHAPES[shape_name]
    if sh["kind"] == "train":
        return "train", train_batch_specs(cfg, sh["seq_len"],
                                          sh["global_batch"]), None
    if sh["kind"] == "prefill":
        return "prefill", prefill_batch_specs(cfg, sh["seq_len"],
                                              sh["global_batch"]), None
    caches = M.init_cache(cfg, sh["global_batch"],
                          cache_len(cfg, sh["seq_len"]))
    return "decode", decode_batch_specs(cfg, sh["global_batch"]), caches
