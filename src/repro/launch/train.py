"""End-to-end training driver (deliverable b): train an LM on the synthetic
pipeline with checkpoint/restart, optional manual-DP gradient compression,
and MoE router-bias balancing.

  PYTHONPATH=src python -m repro.launch.train --arch olmo_1b --smoke \
      --steps 300 --batch 8 --seq 128 --ckpt-dir ckpts/olmo

Fault tolerance: checkpoints are atomic; --resume picks up the latest
(params, moments, step, data cursor, RNG) and continues bit-exactly. Kill it
mid-run and relaunch to exercise restart (tests/test_train_loop.py does).
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import model as M
from repro.models import moe as moe_lib
from repro.training import steps as S
from repro.training.checkpoint import (keep_last, latest_checkpoint,
                                       load_pytree, save_pytree)
from repro.training.data import SyntheticTokens


def train(arch: str, *, smoke=True, steps=200, batch=8, seq=128,
          ckpt_dir=None, ckpt_every=50, resume=False, peak_lr=1e-3,
          log_every=10, seed=0):
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    key = jax.random.PRNGKey(seed)
    state = S.make_train_state(key, cfg)
    step_fn = jax.jit(S.make_train_step(cfg, peak_lr=peak_lr, warmup=20,
                                        total=steps), donate_argnums=(0,))
    ds = SyntheticTokens(cfg.vocab, seq, batch, seed=seed)
    start = 0

    if resume and ckpt_dir:
        path = latest_checkpoint(ckpt_dir)
        if path:
            state, meta = load_pytree(path, like=state)
            start = int(meta["data_cursor"])
            print(f"resumed from {path} at step {start}")

    hist = []
    t0 = time.time()
    for i in range(start, steps):
        b = ds.batch(i)
        jb = {"tokens": jnp.asarray(b["tokens"]),
              "labels": jnp.asarray(b["labels"])}
        if cfg.frontend:
            jb["frontend"] = jnp.zeros((batch, cfg.frontend_len,
                                        cfg.frontend_dim), jnp.float32)
            jb["labels"] = jnp.asarray(b["labels"])
        state, metrics = step_fn(state, jb)
        loss = float(metrics["loss"])
        hist.append(loss)
        if i % log_every == 0 or i == steps - 1:
            print(f"step {i:5d} loss {loss:.4f} lr {float(metrics['lr']):.2e}"
                  f" gnorm {float(metrics['grad_norm']):.3f}"
                  f" ({(time.time()-t0):.1f}s)", flush=True)
        if ckpt_dir and ckpt_every and (i + 1) % ckpt_every == 0:
            save_pytree(os.path.join(ckpt_dir, f"step_{i+1:07d}.npz"), state,
                        extra_meta={"data_cursor": i + 1, "arch": arch})
            keep_last(ckpt_dir, 3)
    if ckpt_dir:
        save_pytree(os.path.join(ckpt_dir, f"step_{steps:07d}.npz"), state,
                    extra_meta={"data_cursor": steps, "arch": arch})
    return state, hist


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo_1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    _, hist = train(args.arch, smoke=args.smoke, steps=args.steps,
                    batch=args.batch, seq=args.seq, ckpt_dir=args.ckpt_dir,
                    ckpt_every=args.ckpt_every, resume=args.resume,
                    peak_lr=args.lr, seed=args.seed)
    print(f"final loss {hist[-1]:.4f} (first {hist[0]:.4f})")


if __name__ == "__main__":
    main()
