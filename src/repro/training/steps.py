"""train_step / serve_step builders — the functions the launcher jits (and
the dry-run lowers) for every architecture.

train_step: CE loss (+ DeepSeek MTP auxiliary term) -> grad -> global-norm
clip -> AdamW. Remat/scan live inside the model. serve_step: one-token decode
against a KV/state cache; prefill_step builds the cache from a prompt.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.training.optimizer import (AdamWState, adamw_init, adamw_update,
                                      clip_by_global_norm, lr_schedule)


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def make_train_state(key, cfg: ModelConfig) -> TrainState:
    params = M.init_model(key, cfg)
    return TrainState(params=params, opt=adamw_init(params))


def cross_entropy(logits, labels, mask):
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    # gold logit via masked reduction, NOT take_along_axis: a gather along
    # the vocab axis (model-sharded) makes GSPMD all-gather the full logits
    # tensor (537 GiB for seamless train_4k); the iota-compare reduction
    # keeps the contraction local + one tiny psum (§Perf).
    onehot = labels[..., None] == jax.lax.broadcasted_iota(
        jnp.int32, logits.shape, logits.ndim - 1)
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


def loss_fn(params, batch, cfg: ModelConfig):
    logits, aux = M.forward(params, batch, cfg)
    labels = batch["labels"]
    # modality-prefix positions carry no labels
    if cfg.frontend and not cfg.n_enc_layers:
        logits = logits[:, cfg.frontend_len:]
    mask = (labels >= 0).astype(jnp.float32)
    labels = jnp.maximum(labels, 0)
    loss = cross_entropy(logits, labels, mask)
    metrics = {"loss": loss, "moe_dropped": aux.get("moe_dropped", 0.0)}
    if cfg.mtp_depth:
        # depth-2 multi-token prediction: predict labels shifted one more
        h = aux["mtp_hidden"]
        if cfg.frontend and not cfg.n_enc_layers:
            h = h[:, cfg.frontend_len:]
        nxt = jnp.pad(labels[:, 1:], ((0, 0), (0, 1)))
        mtp_lg = M.mtp_logits(params, h, params["embed"][nxt], cfg)
        lbl2 = jnp.pad(labels[:, 2:], ((0, 0), (0, 2)))
        msk2 = jnp.pad(mask[:, 2:], ((0, 0), (0, 2)))
        mtp_loss = cross_entropy(mtp_lg, lbl2, msk2)
        metrics["mtp_loss"] = mtp_loss
        loss = loss + 0.3 * mtp_loss
    return loss, metrics


def make_train_step(cfg: ModelConfig, *, peak_lr=3e-4, warmup=200,
                    total=10_000, clip=1.0, weight_decay=0.1):
    def train_step(state: TrainState, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params, batch, cfg)
        grads, gnorm = clip_by_global_norm(grads, clip)
        lr = lr_schedule(state.opt.step, peak_lr=peak_lr, warmup=warmup,
                         total=total)
        params, opt = adamw_update(state.params, grads, state.opt, lr=lr,
                                   weight_decay=weight_decay)
        metrics = dict(metrics, grad_norm=gnorm, lr=lr)
        return TrainState(params=params, opt=opt), metrics

    return train_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, caches, batch):
        logits, caches = M.decode_step(params, caches, batch, cfg)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, caches

    return serve_step


def make_prefill_step(cfg: ModelConfig, max_len: int):
    def prefill_step(params, batch):
        logits, caches = M.prefill(params, batch, cfg, max_len)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, caches

    return prefill_step
