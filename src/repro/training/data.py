"""Deterministic synthetic token pipeline.

Not uniform noise: tokens follow a Zipf marginal with a hash-induced bigram
structure (each token biases the next draw), so a language model has real
structure to learn and training loss meaningfully decreases — while the
stream stays a pure function of (seed, cursor), which is what makes the
data-cursor checkpoint/resume exact (DESIGN.md §7).
"""
from __future__ import annotations

import numpy as np

from repro.core.graph import splitmix64


def _zipf_table(vocab: int, alpha: float = 1.1) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** (-alpha)
    return (p / p.sum()).cumsum()


class SyntheticTokens:
    """Stateless-addressable token stream: batch(i) is a pure function."""

    def __init__(self, vocab: int, seq_len: int, batch_size: int, *,
                 seed: int = 0, alpha: float = 1.1, bigram_strength=0.7):
        self.vocab, self.seq_len, self.batch_size = vocab, seq_len, batch_size
        self.seed = seed
        self.cdf = _zipf_table(vocab, alpha)
        self.bigram_strength = bigram_strength

    def batch(self, index: int) -> dict:
        n = self.batch_size * (self.seq_len + 1)
        base = (np.uint64(self.seed) * np.uint64(0x1000003)
                + np.uint64(index) * np.uint64(n + 1))
        u = splitmix64(base + np.arange(n, dtype=np.uint64))
        unif = (u >> np.uint64(11)).astype(np.float64) / float(1 << 53)
        toks = np.searchsorted(self.cdf, unif).astype(np.int64)
        # bigram structure: with prob bigram_strength, token t+1 is a hash
        # of token t (deterministic successor) -> learnable transitions
        succ = (splitmix64(toks.astype(np.uint64) * np.uint64(2654435761))
                % np.uint64(self.vocab)).astype(np.int64)
        gate_u = splitmix64(u ^ np.uint64(0xDEADBEEF))
        gate = (gate_u >> np.uint64(11)).astype(np.float64) / float(1 << 53)
        shifted = np.concatenate([toks[:1], succ[:-1]])
        toks = np.where(gate < self.bigram_strength, shifted, toks)
        toks = toks.reshape(self.batch_size, self.seq_len + 1)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}


def synthetic_batches(vocab, seq_len, batch_size, *, seed=0, start=0):
    ds = SyntheticTokens(vocab, seq_len, batch_size, seed=seed)
    i = start
    while True:
        yield i, ds.batch(i)
        i += 1
