"""AdamW + global-norm clip + warmup-cosine schedule, self-contained
(no optax in this environment). Moments are fp32 regardless of param dtype
(mixed-precision master-moment convention).

Also: int8 gradient compression with stochastic rounding + error feedback —
the distributed-optimization hook used by the manual-DP (shard_map) training
wrapper to quantize DP-axis gradient all-reduces (DESIGN.md §7).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def clip_by_global_norm(grads, max_norm: float):
    g2 = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(g2)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def lr_schedule(step, *, peak_lr=3e-4, warmup=200, total=10_000,
                min_ratio=0.1):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(warmup, 1)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return peak_lr * jnp.where(step < warmup, warm, cos)


def adamw_update(params, grads, state: AdamWState, *, lr, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1):
    step = state.step + 1
    t = step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * gf * gf
        mhat = m2 / (1 - b1 ** t)
        vhat = v2 / (1 - b2 ** t)
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v)


# --------------------------------------------------------------------------- #
# gradient compression (int8 stochastic rounding + error feedback)
# --------------------------------------------------------------------------- #
def quantize_grad(g, err, key, scale):
    """g fp -> int8-valued q (given a shared scale); error feedback added."""
    gf = g.astype(jnp.float32) + err
    scaled = gf / scale
    noise = jax.random.uniform(key, scaled.shape, minval=-0.5, maxval=0.5)
    q = jnp.clip(jnp.round(scaled + noise), -127, 127)
    new_err = gf - q * scale
    return q.astype(jnp.int8), new_err


def dequantize_grad(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(grads, err_state, key, axis_name):
    """Quantized DP gradient all-reduce with error feedback: int8 payload
    over the data axis instead of fp32 (4x fewer collective bytes).

    A scalar pmax per tensor establishes a *shared* scale, so the integer
    psum is an exact fixed-point sum; stochastic rounding keeps the
    quantizer unbiased and the residual is re-injected next step
    (error feedback), which is what keeps convergence intact.
    """
    flat, tree = jax.tree.flatten(grads)
    errs = jax.tree.leaves(err_state) if err_state is not None \
        else [jnp.zeros_like(g, jnp.float32) for g in flat]
    keys = jax.random.split(key, len(flat))
    n = jax.lax.psum(1, axis_name)
    out, new_errs = [], []
    for g, e, k in zip(flat, errs, keys):
        local_max = jnp.max(jnp.abs(g.astype(jnp.float32) + e))
        scale = jax.lax.pmax(local_max, axis_name) / 127.0 + 1e-12
        q, ne = quantize_grad(g, e, k, scale)
        qs = jax.lax.psum(q.astype(jnp.int32), axis_name)   # int8 payload
        out.append((qs.astype(jnp.float32) * scale / n).astype(g.dtype))
        new_errs.append(ne)
    return tree.unflatten(out), tree.unflatten(new_errs)
