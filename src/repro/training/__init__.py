from repro.training.optimizer import (AdamWState, adamw_init, adamw_update,
                                      clip_by_global_norm, lr_schedule)
from repro.training.checkpoint import (latest_checkpoint, load_pytree,
                                       save_pytree)
from repro.training.data import synthetic_batches

__all__ = ["AdamWState", "adamw_init", "adamw_update", "clip_by_global_norm",
           "lr_schedule", "latest_checkpoint", "load_pytree", "save_pytree",
           "synthetic_batches"]
