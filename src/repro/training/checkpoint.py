"""Fault-tolerant checkpointing (DESIGN.md §7).

Atomic (.tmp + rename) npz checkpoints of arbitrary pytrees with a flattened
keypath manifest. Used by the LM training loop (params + AdamW moments + data
cursor + RNG) and by the graph engine's BSP superstep checkpoints. Resume is
exact. Keys encode the tree structure so re-sharding onto a different mesh at
load time is just a matter of providing new shardings (arrays are saved
unsharded from the host's view — for multi-host, one file per host with a
manifest, same format).
"""
from __future__ import annotations

import json
import os
import re
import tempfile
from typing import Any, Optional

import jax
import numpy as np

_SEP = "|"


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(jax.tree_util.keystr((p,))) for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def save_pytree(path: str, tree: Any, *, extra_meta: Optional[dict] = None):
    """Atomic write: serialize to <path>.tmp then rename."""
    flat, _ = _flatten(tree)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    meta = {"keys": sorted(flat.keys()), "meta": extra_meta or {}}
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)),
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, __manifest__=np.frombuffer(
                json.dumps(meta).encode(), dtype=np.uint8), **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return path


def load_pytree(path: str, like: Any = None):
    """Load; if ``like`` is given, restore exactly that tree structure (and
    cast/device-put onto its shardings if they are jax arrays)."""
    with np.load(path) as z:
        meta = json.loads(bytes(z["__manifest__"]).decode())
        flat = {k: z[k] for k in meta["keys"]}
    if like is None:
        return flat, meta["meta"]
    want, treedef = _flatten(like)
    assert sorted(want.keys()) == sorted(flat.keys()), \
        "checkpoint/tree structure mismatch"
    leaves_like, td = jax.tree_util.tree_flatten(like)
    flat_p, _ = jax.tree_util.tree_flatten_with_path(like)
    restored = []
    for (path_k, leaf) in flat_p:
        key = _SEP.join(str(jax.tree_util.keystr((p,))) for p in path_k)
        arr = flat[key]
        if hasattr(leaf, "sharding") and hasattr(leaf, "dtype"):
            arr = jax.device_put(arr.astype(leaf.dtype), leaf.sharding)
        restored.append(arr)
    return jax.tree_util.tree_unflatten(td, restored), meta["meta"]


def latest_checkpoint(ckpt_dir: str, prefix: str = "step_") -> Optional[str]:
    if not os.path.isdir(ckpt_dir):
        return None
    best, best_n = None, -1
    for f in os.listdir(ckpt_dir):
        m = re.fullmatch(rf"{re.escape(prefix)}(\d+)\.npz", f)
        if m and int(m.group(1)) > best_n:
            best, best_n = os.path.join(ckpt_dir, f), int(m.group(1))
    return best


def keep_last(ckpt_dir: str, n: int, prefix: str = "step_"):
    """Retention: delete all but the newest n checkpoints."""
    if not os.path.isdir(ckpt_dir):
        return
    files = []
    for f in os.listdir(ckpt_dir):
        m = re.fullmatch(rf"{re.escape(prefix)}(\d+)\.npz", f)
        if m:
            files.append((int(m.group(1)), f))
    for _, f in sorted(files)[:-n]:
        os.unlink(os.path.join(ckpt_dir, f))
