"""Tiered converged-result cache (docs/SERVING.md).

Identical ``(tenant, graph_version, program, params, config)`` queries
return the *same converged result* — BSP fixed points are deterministic —
so serving them again should never touch the device. ``ResultCache`` layers:

  - **L1**: an in-process LRU (entry- and byte-bounded) holding the
    deserialized result arrays, hit in microseconds;
  - **L2**: a pluggable :class:`ExternalStore` — the cross-process tier.
    The reference implementation is the dict-backed :class:`DictStore`
    (tests, single-process multi-pool sharing); :class:`FileStore` persists
    to a directory (cross-process on one host); :class:`RedisStore` wraps a
    ``redis`` client *if the package is importable* — it is import-gated,
    never a hard dependency. L2 hits are promoted into L1.

Invalidation is **by key, not by sweep**: the cache key embeds the
session's ``graph_version`` (bumped by every applied flush/compact), so any
mutation — including the deleting flushes that break warm-start soundness —
makes old entries unreachable immediately; TTL (``ttl=`` seconds, lazily
enforced on ``get``) reaps the orphaned bytes. ``clock`` is injectable so
TTL expiry is testable without sleeping.

Values are numpy pytrees serialized with ``np.savez`` for the external
tier; the L1 tier keeps them deserialized. Keys are stable sha256 digests
(``result_key``) built from repr()-stable components plus raw param bytes,
so two processes over the same graph lineage compute the same key.
"""
from __future__ import annotations

import dataclasses
import hashlib
import io
import os
import time
from collections import OrderedDict
from typing import Any, Optional

import numpy as np

__all__ = ["ResultCache", "ExternalStore", "DictStore", "FileStore",
           "RedisStore", "result_key"]


# --------------------------------------------------------------------------- #
# keys
# --------------------------------------------------------------------------- #
def result_key(tenant, graph_version: int, program, params_c, cfg) -> str:
    """Stable digest of everything that determines a converged result:
    which graph (tenant + version), which computation (program type +
    dataclass fields + engine config) and which parameter *values*
    (structure + raw leaf bytes). ``warm`` is deliberately excluded — warm
    and cold runs of a monotone program converge to the same fixed point."""
    import jax

    h = hashlib.sha256()
    h.update(repr((str(tenant), int(graph_version),
                   type(program).__name__)).encode())
    try:
        fields = tuple((f.name, repr(getattr(program, f.name)))
                       for f in dataclasses.fields(program))
    except TypeError:
        fields = (("id", str(id(program))),)
    h.update(repr(fields).encode())
    h.update(repr(cfg).encode())
    leaves, treedef = jax.tree.flatten(params_c)
    h.update(str(treedef).encode())
    for leaf in leaves:
        arr = np.asarray(leaf)
        h.update(f"{arr.shape}{arr.dtype}".encode())
        h.update(arr.tobytes())
    return h.hexdigest()


def _serialize(value: dict) -> bytes:
    buf = io.BytesIO()
    arrays = {k: np.asarray(v) for k, v in value.items()}
    np.savez(buf, **arrays)
    return buf.getvalue()


def _deserialize(data: bytes) -> dict:
    with np.load(io.BytesIO(data), allow_pickle=False) as z:
        out = {}
        for k in z.files:
            v = z[k]
            out[k] = v.item() if v.ndim == 0 else v
        return out


# --------------------------------------------------------------------------- #
# external stores (the L2 tier protocol)
# --------------------------------------------------------------------------- #
class ExternalStore:
    """Protocol for the cross-process tier: opaque bytes keyed by the digest
    string, with optional per-entry TTL. Implementations only need these
    three methods; expiry may be enforced lazily on ``get``."""

    def get(self, key: str) -> Optional[bytes]:
        raise NotImplementedError

    def put(self, key: str, data: bytes, ttl: Optional[float] = None) -> None:
        raise NotImplementedError

    def delete(self, key: str) -> None:
        raise NotImplementedError


class DictStore(ExternalStore):
    """Reference in-memory store: a dict of key -> (bytes, expiry). Not a
    cache speedup in itself — it exists to exercise and share the L2
    protocol (several pools in one process, tests) and as the template for
    real adapters."""

    def __init__(self, clock=time.monotonic):
        self._d: dict = {}
        self._clock = clock

    def get(self, key):
        hit = self._d.get(key)
        if hit is None:
            return None
        data, expiry = hit
        if expiry is not None and self._clock() >= expiry:
            del self._d[key]
            return None
        return data

    def put(self, key, data, ttl=None):
        expiry = None if ttl is None else self._clock() + ttl
        self._d[key] = (data, expiry)

    def delete(self, key):
        self._d.pop(key, None)

    def __len__(self):
        return len(self._d)


class FileStore(ExternalStore):
    """Directory-backed store: one file per key, expiry stamped in an
    8-byte little-endian float header (0.0 = no TTL). Survives the process;
    concurrent readers are safe (writes go through ``os.replace``)."""

    def __init__(self, root: str, clock=time.time):
        self.root = root
        self._clock = clock
        os.makedirs(root, exist_ok=True)

    def _path(self, key: str) -> str:
        return os.path.join(self.root, f"{key}.npz")

    def get(self, key):
        p = self._path(key)
        try:
            with open(p, "rb") as f:
                expiry = np.frombuffer(f.read(8), dtype="<f8")[0]
                if expiry and self._clock() >= expiry:
                    f.close()
                    os.unlink(p)
                    return None
                return f.read()
        except (FileNotFoundError, ValueError):
            return None

    def put(self, key, data, ttl=None):
        expiry = 0.0 if ttl is None else self._clock() + ttl
        p = self._path(key)
        tmp = p + ".tmp"
        with open(tmp, "wb") as f:
            f.write(np.array(expiry, dtype="<f8").tobytes())
            f.write(data)
        os.replace(tmp, p)

    def delete(self, key):
        try:
            os.unlink(self._path(key))
        except FileNotFoundError:
            pass


class RedisStore(ExternalStore):
    """Adapter over a ``redis``-like client (anything with get/set/delete
    and ``ex=`` seconds on set). The package is NOT a dependency: pass a
    constructed client, or let ``from_url`` raise a clear error where
    ``redis`` is absent."""

    def __init__(self, client):
        self.client = client

    @classmethod
    def from_url(cls, url: str) -> "RedisStore":
        try:
            import redis  # type: ignore
        except ImportError as e:  # pragma: no cover - env without redis
            raise ImportError(
                "RedisStore.from_url needs the optional 'redis' package; "
                "install it or pass a constructed client to RedisStore()"
            ) from e
        return cls(redis.Redis.from_url(url))

    def get(self, key):
        return self.client.get(key)

    def put(self, key, data, ttl=None):
        if ttl is None:
            self.client.set(key, data)
        else:
            self.client.set(key, data, ex=max(1, int(round(ttl))))

    def delete(self, key):
        self.client.delete(key)


# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class ResultCacheStats:
    l1_hits: int = 0
    l2_hits: int = 0               # found in the external store (promoted)
    misses: int = 0
    puts: int = 0
    expirations: int = 0           # L1 entries reaped by TTL on access
    l1_evictions: int = 0


class ResultCache:
    """The tiered cache. ``max_entries``/``max_bytes`` bound L1 (LRU;
    ``None`` = unbounded); ``store`` is the optional L2
    :class:`ExternalStore`; ``ttl`` (seconds, ``None`` = forever) applies
    to both tiers. One ``ResultCache`` may front many sessions — keys carry
    the tenant and graph version, so entries never collide across graphs."""

    def __init__(self, max_entries: Optional[int] = 256,
                 max_bytes: Optional[int] = None,
                 ttl: Optional[float] = None,
                 store: Optional[ExternalStore] = None,
                 clock=time.monotonic):
        self.max_entries = max_entries
        self.max_bytes = max_bytes
        self.ttl = ttl
        self.store = store
        self._clock = clock
        self._l1: OrderedDict = OrderedDict()    # key -> (value, expiry, nbytes)
        self.stats = ResultCacheStats()

    # ------------------------------------------------------------------ #
    def __len__(self):
        return len(self._l1)

    @property
    def l1_bytes(self) -> int:
        return sum(n for _, _, n in self._l1.values())

    @staticmethod
    def _nbytes(value: dict) -> int:
        return sum(np.asarray(v).nbytes for v in value.values())

    # ------------------------------------------------------------------ #
    def get(self, key: str):
        """Returns ``(value, tier)`` with tier in ``('l1', 'l2')``, or
        ``(None, 'miss')``. L2 hits are deserialized and promoted to L1."""
        hit = self._l1.get(key)
        if hit is not None:
            value, expiry, _ = hit
            if expiry is not None and self._clock() >= expiry:
                del self._l1[key]
                self.stats.expirations += 1
            else:
                self._l1.move_to_end(key)
                self.stats.l1_hits += 1
                return value, "l1"
        if self.store is not None:
            data = self.store.get(key)
            if data is not None:
                value = _deserialize(data)
                self._admit_l1(key, value)
                self.stats.l2_hits += 1
                return value, "l2"
        self.stats.misses += 1
        return None, "miss"

    def peek(self, key: str) -> Optional[str]:
        """Which tier holds ``key`` right now (``'l1'``/``'l2'``) or
        ``None`` — WITHOUT billing stats, promoting, or refreshing LRU.
        ``GraphSession.query_batch`` uses it to decide whether a whole
        batch can short-circuit before any lane is billed a hit."""
        hit = self._l1.get(key)
        if hit is not None:
            _, expiry, _ = hit
            if expiry is None or self._clock() < expiry:
                return "l1"
        if self.store is not None and self.store.get(key) is not None:
            return "l2"
        return None

    def put(self, key: str, value: dict) -> None:
        """Store a converged result (a dict of numpy-able leaves) in both
        tiers."""
        self._admit_l1(key, value)
        if self.store is not None:
            self.store.put(key, _serialize(value), ttl=self.ttl)
        self.stats.puts += 1

    def _admit_l1(self, key, value):
        expiry = None if self.ttl is None else self._clock() + self.ttl
        self._l1[key] = (value, expiry, self._nbytes(value))
        self._l1.move_to_end(key)
        if self.max_entries is not None:
            while len(self._l1) > self.max_entries:
                self._l1.popitem(last=False)
                self.stats.l1_evictions += 1
        if self.max_bytes is not None:
            total = self.l1_bytes
            while total > self.max_bytes and len(self._l1) > 1:
                _, (_, _, n) = self._l1.popitem(last=False)
                total -= n
                self.stats.l1_evictions += 1

    # ------------------------------------------------------------------ #
    def invalidate(self, key: str) -> None:
        """Drop one key from both tiers. Rarely needed — graph-version
        keying makes every mutation an implicit invalidation — but exposed
        for external stores shared beyond one session lineage."""
        self._l1.pop(key, None)
        if self.store is not None:
            self.store.delete(key)

    def clear_l1(self) -> None:
        self._l1.clear()
