"""MicroBatcher — the cross-request admission queue (docs/SERVING.md).

Serving traffic arrives one query at a time, but the engine's batched
runners (``GraphSession.query_batch``) amortize a whole group of compatible
queries over ONE device launch — the MSSP observation (multi-source batches
share the sweep) generalized to any same-structure param batch.
``MicroBatcher`` sits between the two:

  - ``submit()`` enqueues a request and returns a
    ``concurrent.futures.Future`` resolving to ``(results, ExecutionStats)``
    — exactly what ``query`` returns, plus ``queue_time``/``batch_size``
    filled in;
  - requests coalesce by **compatibility key**: (session, graph version,
    program identity, param structure, config, warm mode). Only lanes a
    single executable can serve land in one group — anything else is its
    own group and degrades to a singleton launch, and a batch launch that
    fails for any reason retries each lane as a singleton before failing
    its future;
  - the **launch policy**: a group launches the moment it holds
    ``max_batch`` lanes (inline, on the submitting thread), when its oldest
    request has waited ``max_delay`` seconds (on the next ``poll()``), or
    when a lane's absolute ``deadline`` is within ``max_delay`` of now.
    ``flush()`` launches everything immediately; ``start()``/``stop()`` run
    ``poll()`` on a background thread for fully async operation, and the
    context manager form flushes and stops on exit.

A result-cache fast path answers ``submit`` synchronously (zero queueing,
zero launches) when the session's tiered result cache already holds the
converged result and no mutations are pending.

The batcher never reorders effects it can see: a group key pins the graph
version at submit time, so a flush between submit and launch simply starts
a new group (the launch itself flushes pending buffers first, as ``query``
always has).
"""
from __future__ import annotations

import dataclasses
import logging
import threading
import time
from collections import OrderedDict
from concurrent.futures import Future
from typing import Optional

from repro.serving.runner_cache import (canonical_params, params_struct_key,
                                        program_key)

__all__ = ["MicroBatcher", "BatchPolicy", "BatcherStats"]

log = logging.getLogger(__name__)


@dataclasses.dataclass(frozen=True)
class BatchPolicy:
    """The coalescing knobs: ``max_batch`` lanes launch a group eagerly,
    ``max_delay`` (seconds) bounds how long the first request in a group may
    wait for company. Latency-sensitive callers pass ``deadline=`` per
    request instead of shrinking the global delay."""
    max_batch: int = 8
    max_delay: float = 0.002


@dataclasses.dataclass
class BatcherStats:
    submitted: int = 0
    launched_batches: int = 0       # multi-lane launches
    launched_singletons: int = 0    # one-lane groups (no compatible company)
    batched_requests: int = 0       # requests served inside batch launches
    largest_batch: int = 0
    fast_path_hits: int = 0         # answered from the result cache at
                                    # submit time, bypassing the queue
    degraded: int = 0               # lanes replayed as singletons after a
                                    # batch launch failed


@dataclasses.dataclass
class _Request:
    program: object
    params: object
    warm: object
    cfg: object
    future: Future
    t_enqueue: float
    deadline: Optional[float]


class _Group:
    __slots__ = ("session", "requests", "t_first")

    def __init__(self, session, t_first):
        self.session = session
        self.requests: list = []
        self.t_first = t_first


class MicroBatcher:
    """Admission queue over one ``GraphSession`` or a whole ``SessionPool``
    (pass ``tenant=`` on submit in the pool case). ``clock`` is injectable
    for deterministic tests. Thread-safe: ``submit``/``poll``/``flush`` may
    race; launches hold the lock only to detach a group, never across
    device work — but the underlying sessions are still single-launcher
    objects, so all launches happen on whichever thread triggered them."""

    def __init__(self, target, policy: Optional[BatchPolicy] = None,
                 clock=time.monotonic):
        self.target = target
        self.policy = policy or BatchPolicy()
        self.clock = clock
        self.stats = BatcherStats()
        self._groups: OrderedDict = OrderedDict()    # key -> _Group
        self._lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._stop_evt = threading.Event()

    # ------------------------------------------------------------------ #
    def _session(self, tenant):
        if hasattr(self.target, "session"):          # a SessionPool
            return self.target.session(tenant)
        return self.target

    def submit(self, program, params=None, *, tenant=None, warm="auto",
               cfg=None, deadline: Optional[float] = None,
               use_result_cache=True) -> Future:
        """Enqueue one query; returns a Future of ``(results, stats)``.
        ``deadline`` is an absolute ``clock()`` time by which the request
        must launch. May resolve synchronously: on a result-cache fast-path
        hit, or when this request fills its group to ``max_batch``."""
        sess = self._session(tenant)
        fut: Future = Future()
        now = self.clock()
        self.stats.submitted += 1

        if (use_result_cache and sess.result_cache is not None
                and (sess.buffer is None or not len(sess.buffer))):
            rkey = sess.result_key_for(program, params, cfg)
            if sess.result_cache.peek(rkey) is not None:
                try:
                    res, st = sess.query(program, params, warm=warm, cfg=cfg)
                    st.queue_time = 0.0
                    fut.set_result((res, st))
                    self.stats.fast_path_hits += 1
                except Exception as e:               # pragma: no cover
                    fut.set_exception(e)
                return fut

        params_c = canonical_params(params)
        key = (id(sess), sess._host_version, program_key(program),
               params_struct_key(params_c), cfg, warm, use_result_cache)
        req = _Request(program=program, params=params, warm=warm, cfg=cfg,
                       future=fut, t_enqueue=now, deadline=deadline)
        launch = None
        with self._lock:
            grp = self._groups.get(key)
            if grp is None:
                grp = self._groups[key] = _Group(sess, now)
            grp.requests.append(req)
            if len(grp.requests) >= self.policy.max_batch:
                launch = self._groups.pop(key)
        if launch is not None:
            self._launch(launch)
        return fut

    # ------------------------------------------------------------------ #
    def poll(self) -> int:
        """Launch every group that is due — oldest lane waited
        ``max_delay``, or some lane's deadline is within ``max_delay`` of
        now. Returns the number of groups launched."""
        now = self.clock()
        due = []
        with self._lock:
            for key in list(self._groups):
                grp = self._groups[key]
                deadlines = [r.deadline for r in grp.requests
                             if r.deadline is not None]
                if (now - grp.t_first >= self.policy.max_delay
                        or (deadlines and now >= min(deadlines)
                            - self.policy.max_delay)):
                    due.append(self._groups.pop(key))
        for grp in due:
            self._launch(grp)
        return len(due)

    def flush(self) -> int:
        """Launch every pending group immediately."""
        with self._lock:
            due = list(self._groups.values())
            self._groups.clear()
        for grp in due:
            self._launch(grp)
        return len(due)

    @property
    def pending(self) -> int:
        with self._lock:
            return sum(len(g.requests) for g in self._groups.values())

    # ------------------------------------------------------------------ #
    def _launch(self, grp: _Group) -> None:
        sess, reqs = grp.session, grp.requests
        t_launch = self.clock()
        r0 = reqs[0]
        try:
            if len(reqs) == 1:
                res, st = sess.query(r0.program, r0.params, warm=r0.warm,
                                     cfg=r0.cfg)
                st.queue_time = t_launch - r0.t_enqueue
                r0.future.set_result((res, st))
                self.stats.launched_singletons += 1
                return
            out = sess.query_batch(r0.program, [r.params for r in reqs],
                                   warm=r0.warm, cfg=r0.cfg)
            for r, (res, st) in zip(reqs, out):
                st.queue_time = t_launch - r.t_enqueue
                r.future.set_result((res, st))
            self.stats.launched_batches += 1
            self.stats.batched_requests += len(reqs)
            self.stats.largest_batch = max(self.stats.largest_batch,
                                           len(reqs))
        except Exception as batch_err:
            # the graceful degradation path (deliberately broad: any batch
            # failure must not take down unrelated lanes): replay each lane
            # alone; a lane that still fails gets the real error on its own
            # future, so nothing is swallowed — only deferred per-lane
            log.debug("batch launch failed (%r); replaying %d lane(s) "
                      "individually", batch_err, len(reqs))
            for r in reqs:
                try:
                    res, st = sess.query(r.program, r.params, warm=r.warm,
                                         cfg=r.cfg)
                    st.queue_time = t_launch - r.t_enqueue
                    r.future.set_result((res, st))
                    self.stats.degraded += 1
                except Exception as e:
                    r.future.set_exception(e)

    # ------------------------------------------------------------------ #
    # background pump
    # ------------------------------------------------------------------ #
    def start(self, interval: Optional[float] = None) -> None:
        """Run ``poll()`` on a daemon thread every ``interval`` seconds
        (default ``max_delay / 2``) until ``stop()``."""
        if self._thread is not None:
            return
        interval = self.policy.max_delay / 2 if interval is None else interval
        self._stop_evt.clear()

        def pump():
            while not self._stop_evt.wait(interval):
                self.poll()

        self._thread = threading.Thread(target=pump, daemon=True,
                                        name="micro-batcher")
        self._thread.start()

    def stop(self) -> None:
        """Stop the background pump and flush whatever is still queued."""
        if self._thread is not None:
            self._stop_evt.set()
            self._thread.join()
            self._thread = None
        self.flush()

    def __enter__(self) -> "MicroBatcher":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()
