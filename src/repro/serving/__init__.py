"""Multi-tenant serving subsystem (docs/SERVING.md).

Layers on top of ``GraphSession`` (paper §5's long-lived engine posture,
scaled out to many graphs and live traffic):

  - ``repro.serving.runner_cache`` — the shared compiled-runner LRU with
    per-tenant pin accounting and fair eviction; same-bucket graphs of
    different tenants reuse one AOT executable.
  - ``repro.serving.result_cache`` — the tiered converged-result cache
    (in-process L1 + pluggable :class:`ExternalStore` L2) with TTL and
    graph-version invalidation.
  - ``repro.serving.pool`` — :class:`SessionPool`: many graphs on one
    mesh, one runner cache, one result cache.
  - ``repro.serving.batcher`` — :class:`MicroBatcher`: the async admission
    queue coalescing compatible requests into micro-batched launches.

``SessionPool``/``MicroBatcher`` import lazily (PEP 562): ``repro.session``
imports this package for the cache layers, and the pool imports
``repro.session`` back — eager imports here would cycle.
"""
from repro.serving.result_cache import (DictStore, ExternalStore, FileStore,
                                        RedisStore, ResultCache, result_key)
from repro.serving.runner_cache import (OwnerStats, RunnerCache, RunnerEntry,
                                        canonical_params, params_fingerprint,
                                        params_struct_key, program_key,
                                        runner_nbytes)

__all__ = [
    "RunnerCache", "RunnerEntry", "OwnerStats", "program_key",
    "canonical_params", "params_struct_key", "params_fingerprint",
    "runner_nbytes",
    "ResultCache", "ExternalStore", "DictStore", "FileStore", "RedisStore",
    "result_key",
    "SessionPool", "MicroBatcher", "BatchPolicy", "BatcherStats",
]

_LAZY = {
    "SessionPool": "repro.serving.pool",
    "MicroBatcher": "repro.serving.batcher",
    "BatchPolicy": "repro.serving.batcher",
    "BatcherStats": "repro.serving.batcher",
}


def __getattr__(name):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(mod), name)
