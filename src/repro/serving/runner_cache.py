"""Shared compiled-runner cache for the serving layer (docs/SERVING.md).

``RunnerCache`` is the bounded LRU of AOT-compiled executables that used to
live inline in ``GraphSession`` — refactored out so a ``SessionPool`` can
host **many graphs on one mesh sharing ONE cache**. Runner keys carry the
bucketed padded shapes (never a tenant id), so two same-bucket graphs of
different tenants resolve to the *same* key and reuse the same executable:
the pool compiles each (program, param structure, config, shapes) runner
exactly once no matter how many tenants serve it.

What the shared cache adds over the old per-session ``OrderedDict``:

  - **per-tenant pin accounting** — every entry records which owners
    (tenants) created or hit it, and per-owner hit/miss/compile-time
    tallies are kept for introspection (``stats_by_owner``). Pins are
    bookkeeping, not hard locks: the LRU/byte bounds still evict.
  - **fair eviction** — when the cache overflows, the victim is the
    least-recently-used entry *among the entries of the most-loaded
    owner* (ties fall back to plain LRU). A tenant that floods the cache
    with distinct programs evicts its own entries first; a small tenant's
    runners survive the flood. With a single owner this is exactly the old
    LRU policy.
  - **pin release** — ``release(owner)`` (``GraphSession.close``) and
    ``release_stale(owner, pred)`` (shape-bucket growth) drop an owner's
    pins; an entry nobody pins anymore is dropped outright, an entry other
    tenants still pin survives for them. On a private single-owner cache
    this reduces to the old delete-on-stale behavior.

The key helpers (``program_key``/``canonical_params``/``params_struct_key``/
``params_fingerprint``) moved here with the cache; ``repro.session`` imports
them. ``canonical_params`` now also normalizes *scalar* leaf dtype drift:
a Python ``int``, a ``np.int32``, a ``np.int64`` and a 0-d array all
canonicalize to the same jax default-dtype leaf, so mixed-type callers of
the same logical query can never force a spurious retrace (regression-
pinned in tests/test_serving.py).
"""
from __future__ import annotations

import dataclasses
import logging
from collections import OrderedDict
from typing import Any, Callable, Dict, Hashable, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["RunnerCache", "RunnerEntry", "OwnerStats", "program_key",
           "canonical_params", "params_struct_key", "params_fingerprint",
           "runner_nbytes"]

log = logging.getLogger(__name__)


# --------------------------------------------------------------------------- #
# cache keys
# --------------------------------------------------------------------------- #
def program_key(program):
    """Hashable identity of a program's *static* structure: its type plus
    every dataclass field (combiner/payload/dtype/tol/... — anything that
    changes the traced computation). Programs carrying unhashable fields
    fall back to per-instance identity (still cached, just not shared
    across equal instances)."""
    try:
        fields = tuple((f.name, getattr(program, f.name))
                       for f in dataclasses.fields(program))
        hash(fields)
        return (type(program), fields)
    except TypeError:
        return (type(program), id(program))


def _canonical_scalar(x: np.ndarray) -> jnp.ndarray:
    """0-d leaf -> jax default scalar dtype. Python ints, numpy scalars of
    any width and 0-d arrays of one logical value must all produce the SAME
    aval, or the struct key (and the runner cache) fragments on caller
    habits. Values that cannot fit the default int keep int64 (x64 mode)."""
    if x.dtype.kind == "b":
        return jnp.asarray(bool(x))
    if x.dtype.kind in "iu":
        v = int(x)
        info = jnp.iinfo(jnp.int32)
        if info.min <= v <= info.max:
            return jnp.asarray(v, dtype=jnp.int32)
        return jnp.asarray(v)                      # jax picks the wide dtype
    if x.dtype.kind == "f":
        return jnp.asarray(float(x), dtype=jnp.float32)
    return jnp.asarray(x)


def canonical_params(params: Any) -> Any:
    """Params pytree with every leaf a jnp array of a fixed dtype, so the
    runner's input avals (and therefore the cache key) are stable across
    caller-side representation drift. Scalar-ish leaves (Python numbers,
    numpy scalars, 0-d arrays) normalize to the jax default dtypes —
    ``{"source": 0}``, ``{"source": np.int64(0)}`` and
    ``{"source": np.array(0)}`` are one key; leaves with ``ndim >= 1`` keep
    their dtype (an explicitly float64 array is the caller's choice)."""
    if params is None:
        return {}

    def canon(leaf):
        x = np.asarray(leaf)
        if x.ndim == 0:
            return _canonical_scalar(x)
        return jnp.asarray(leaf)

    return jax.tree.map(canon, params)


def params_struct_key(params: Any) -> Tuple[Any, ...]:
    """Structure-only key (treedef + leaf shape/dtype): runners take params
    as *traced* inputs, so different values share one executable."""
    leaves, treedef = jax.tree.flatten(params)
    return (treedef, tuple((tuple(l.shape), str(l.dtype)) for l in leaves))


def params_fingerprint(params: Any) -> Tuple[Any, ...]:
    """Value-level key — warm results and converged-result cache entries are
    only reusable for the *same* query (SSSP distances from source 0 say
    nothing about source 7)."""
    leaves, treedef = jax.tree.flatten(params)
    return (treedef, tuple((tuple(l.shape), str(l.dtype),
                            np.asarray(l).tobytes()) for l in leaves))


def runner_nbytes(compiled: Any) -> int:
    """Estimated device bytes a cached executable keeps alive: outputs +
    temps + generated code from XLA's ``memory_analysis``. Inputs are the
    session-owned resident graph, shared across runners, so they are
    deliberately not billed. Where the analysis is unavailable the entry
    weighs 0 — an unknown footprint must not be billed, or a single
    mis-estimated runner could thrash the whole byte-bounded cache."""
    try:
        m = compiled.memory_analysis()
        return int(m.output_size_in_bytes + m.temp_size_in_bytes
                   + m.generated_code_size_in_bytes)
    except (AttributeError, NotImplementedError, RuntimeError) as e:
        # memory_analysis is backend-dependent (XlaRuntimeError is a
        # RuntimeError); absence must weigh 0, but should still be visible
        log.debug("memory_analysis unavailable for %r: %r", compiled, e)
        return 0


# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class RunnerEntry:
    """One bounded-cache slot: the AOT-compiled executable plus the
    introspection the LRU policy and ``cache_info`` report on.
    ``shape_key`` is ``(padded-shape key, layout key)`` — the latter is None
    for COO runners and the Pallas layout capacities otherwise, so a layout
    cap growth evicts only the Pallas runners it actually staled.
    ``owners`` is the pin set: every tenant that compiled or hit the entry;
    ``release``/``release_stale`` drop pins, the fairness policy charges
    load against them."""
    compiled: Any
    shape_key: Any
    program: str                   # program type name (display only)
    compile_time: float = 0.0
    hits: int = 0
    nbytes: int = 0                # estimated device bytes this executable
                                   # pins (outputs + temps + generated code)
    owners: Set[Hashable] = dataclasses.field(default_factory=set)


@dataclasses.dataclass
class OwnerStats:
    """Per-tenant accounting on a shared cache (``stats_by_owner``)."""
    hits: int = 0
    misses: int = 0                # compilations this owner triggered
    compile_time: float = 0.0
    evicted_pins: int = 0          # this owner's pins lost to LRU/byte
                                   # eviction (fairness: a flooding tenant's
                                   # counter grows, its neighbors' don't)


class RunnerCache:
    """Byte- and slot-bounded LRU of compiled runners, shareable across
    sessions. ``max_entries``/``max_bytes`` follow the old session bounds
    (``None`` = unbounded; the most recent entry is never evicted, so a
    single over-budget executable still serves)."""

    def __init__(self, max_entries: Optional[int] = 32,
                 max_bytes: Optional[int] = None):
        self.max_entries = max_entries
        self.max_bytes = max_bytes
        self._entries: "OrderedDict[Hashable, RunnerEntry]" = OrderedDict()
        self.hits: int = 0
        self.misses: int = 0
        self.evictions: int = 0
        self.compile_time_total: float = 0.0
        self.by_owner: Dict[Hashable, OwnerStats] = {}

    # ------------------------------------------------------------------ #
    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._entries

    def keys(self):
        return self._entries.keys()

    @property
    def entries(self) -> OrderedDict:
        """The live key -> ``RunnerEntry`` map in LRU order (oldest first).
        Exposed for introspection/tests; mutate through the cache API."""
        return self._entries

    @property
    def total_bytes(self) -> int:
        return sum(e.nbytes for e in self._entries.values())

    def _owner_stats(self, owner: Hashable) -> OwnerStats:
        st = self.by_owner.get(owner)
        if st is None:
            st = self.by_owner[owner] = OwnerStats()
        return st

    # ------------------------------------------------------------------ #
    def lookup(self, key: Hashable,
               owner: Hashable) -> Optional[RunnerEntry]:
        """Fetch + LRU-refresh. A hit pins ``owner`` onto the entry (this is
        how a tenant B query comes to share a runner tenant A compiled)."""
        e = self._entries.get(key)
        if e is None:
            self.misses += 1
            self._owner_stats(owner).misses += 1
            return None
        self._entries.move_to_end(key)
        e.hits += 1
        e.owners.add(owner)
        self.hits += 1
        self._owner_stats(owner).hits += 1
        return e

    def insert(self, key: Hashable, entry: RunnerEntry,
               owner: Hashable) -> int:
        """Admit a freshly compiled runner pinned by ``owner``; returns how
        many entries the bounds evicted to make room."""
        entry.owners.add(owner)
        self._entries[key] = entry
        self._entries.move_to_end(key)
        ost = self._owner_stats(owner)
        ost.compile_time += entry.compile_time
        self.compile_time_total += entry.compile_time
        return self._evict()

    # ------------------------------------------------------------------ #
    def _victim_key(self) -> Hashable:
        """Fair victim choice: the LRU entry among the most-loaded owner's
        entries. Load = number of live entries an owner pins; entries pinned
        by several owners charge each of them. With one owner (a private
        session cache) every entry is the max-loaded owner's, so this is
        plain LRU."""
        load: Dict[Hashable, int] = {}
        for e in self._entries.values():
            for o in e.owners:
                load[o] = load.get(o, 0) + 1
        if not load:
            return next(iter(self._entries))
        top = max(load.values())
        heavy = {o for o, n in load.items() if n == top}
        for k, e in self._entries.items():           # LRU order: oldest first
            if not e.owners or e.owners & heavy:
                return k
        return next(iter(self._entries))

    def _pop(self, key: Hashable) -> RunnerEntry:
        e = self._entries.pop(key)
        self.evictions += 1
        for o in e.owners:
            self._owner_stats(o).evicted_pins += 1
        return e

    def _evict(self) -> int:
        evicted = 0
        if self.max_entries is not None:
            while len(self._entries) > self.max_entries:
                self._pop(self._victim_key())
                evicted += 1
        if self.max_bytes is not None:
            total = self.total_bytes
            while total > self.max_bytes and len(self._entries) > 1:
                e = self._pop(self._victim_key())
                total -= e.nbytes
                evicted += 1
        return evicted

    # ------------------------------------------------------------------ #
    def release(self, owner: Hashable) -> int:
        """Drop every pin ``owner`` holds (``GraphSession.close``). Entries
        left with no owner are removed — nothing can account for them
        anymore; entries other tenants still pin survive for those tenants.
        Returns the number of entries dropped."""
        dead: List[Hashable] = []
        for k, e in self._entries.items():
            e.owners.discard(owner)
            if not e.owners:
                dead.append(k)
        for k in dead:
            del self._entries[k]
        return len(dead)

    def release_stale(self, owner: Hashable,
                      stale: Callable[[RunnerEntry], bool]) -> int:
        """Unpin ``owner`` from entries whose shapes it outgrew (bucket
        growth/shrink). The entry itself survives while any other tenant at
        those shapes still pins it — on a shared cache a tenant crossing a
        bucket must never invalidate its neighbors' runners. Returns how
        many entries this owner released (dropped or not): the session
        bills them as its shape evictions."""
        released, dead = 0, []  # type: int, List[Hashable]
        for k, e in self._entries.items():
            if owner in e.owners and stale(e):
                e.owners.discard(owner)
                released += 1
                if not e.owners:
                    dead.append(k)
        for k in dead:
            del self._entries[k]
        return released

    # ------------------------------------------------------------------ #
    def info(self) -> List[dict]:
        """LRU-ordered snapshot (oldest — next to be evicted — first), one
        dict per entry; ``owners`` is the sorted pin set."""
        return [dict(program=e.program, shape_key=e.shape_key, hits=e.hits,
                     compile_time=e.compile_time, nbytes=e.nbytes,
                     owners=sorted(map(str, e.owners)))
                for e in self._entries.values()]
