"""SessionPool — many graphs on one mesh, one runner cache
(docs/SERVING.md).

DRONE's pitch is a long-lived engine; a serving fleet hosts MANY long-lived
graphs per process. ``SessionPool`` owns the shared pieces a naive
session-per-graph loop would duplicate:

  - ONE :class:`~repro.serving.runner_cache.RunnerCache` for every hosted
    session — runner keys carry the bucketed padded shapes and never a
    tenant id, so two tenants whose graphs land in the same shape bucket
    resolve the same key and reuse the same AOT executable. K same-bucket
    tenants compile each (program, backend) runner exactly ONCE
    (tests/test_serving.py pins this with trace counters);
  - one shared :class:`~repro.serving.result_cache.ResultCache` (optional):
    converged-result keys carry the tenant and graph version, so entries
    never collide across graphs while the capacity is pooled;
  - one ``ShapePolicy`` — shared bucketing is what MAKES same-sized graphs
    land on the same padded shapes;
  - an LRU session bound (``max_sessions``): opening tenant N+1 closes the
    least-recently-served session (``GraphSession.close`` releases its
    device pytree and its shared-cache pins — neighbors' entries survive).

All sessions share the pool's mesh (or the simulator when ``mesh=None``),
matching the one-device-fleet deployment the ROADMAP targets.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Optional

from repro.serving.result_cache import ResultCache
from repro.serving.runner_cache import RunnerCache

__all__ = ["SessionPool"]


class SessionPool:
    """Host many :class:`~repro.session.GraphSession` tenants on one mesh
    with shared runner/result caches. ``max_runners``/``max_runner_bytes``
    bound the SHARED runner cache (the per-session bounds are bypassed);
    ``result_cache`` attaches a shared tiered result cache; ``max_sessions``
    LRU-closes the least-recently-served tenant when exceeded
    (``None`` = unbounded)."""

    def __init__(self, *, mesh=None, cfg=None, shape_policy=None,
                 max_runners: Optional[int] = 64,
                 max_runner_bytes: Optional[int] = None,
                 result_cache: Optional[ResultCache] = None,
                 max_sessions: Optional[int] = None,
                 rebalance: str = "off"):
        from repro.core.subgraph import ShapePolicy
        self.mesh = mesh
        self.cfg = cfg
        # one policy for every tenant: shared geometric buckets are what
        # make same-sized graphs share padded shapes (and executables)
        self.shape_policy = shape_policy if shape_policy is not None \
            else ShapePolicy()
        self.runner_cache = RunnerCache(max_runners, max_runner_bytes)
        self.result_cache = result_cache
        self.max_sessions = max_sessions
        # pool-wide default for the online load rebalancer
        # (docs/PARTITIONING.md): every opened session inherits it unless
        # open(..., rebalance=...) overrides per tenant
        self.rebalance = rebalance
        self._sessions: OrderedDict = OrderedDict()   # tenant -> session
        self.sessions_closed = 0                      # by the LRU bound

    # ------------------------------------------------------------------ #
    def open(self, tenant: str, graph=None, *, pg=None, edge_log=None,
             n_parts: int = 8, partitioner: str = "cdbh", ctx=None,
             **kwargs):
        """Open a session for ``tenant`` over ``graph`` (an in-memory
        ``Graph``), ``pg`` (a prebuilt ``PartitionedGraph``) or ``edge_log``
        (the on-disk ingest path) — exactly one of the three. Extra kwargs
        flow to the ``GraphSession`` constructor; the pool always injects
        its mesh, config, shape policy and shared caches."""
        from repro.session import GraphSession
        if tenant in self._sessions:
            raise ValueError(f"tenant {tenant!r} already has an open "
                             "session (pool.close(tenant) first)")
        if sum(x is not None for x in (graph, pg, edge_log)) != 1:
            raise ValueError("pass exactly one of graph=, pg=, edge_log=")
        common = dict(mesh=self.mesh, cfg=self.cfg,
                      shape_policy=self.shape_policy,
                      runner_cache=self.runner_cache,
                      result_cache=self.result_cache, tenant=tenant,
                      rebalance=self.rebalance)
        common.update(kwargs)
        if pg is not None:
            sess = GraphSession(pg, ctx=ctx, **common)
        elif graph is not None:
            sess = GraphSession.from_graph(graph, n_parts, partitioner,
                                           **common)
        else:
            sess = GraphSession.from_edge_log(edge_log, n_parts, partitioner,
                                              **common)
        self._sessions[tenant] = sess
        self._evict_sessions()
        return sess

    def session(self, tenant: str):
        """The tenant's open session (refreshes its LRU recency)."""
        sess = self._sessions.get(tenant)
        if sess is None:
            raise KeyError(f"no open session for tenant {tenant!r}")
        self._sessions.move_to_end(tenant)
        return sess

    def __contains__(self, tenant) -> bool:
        return tenant in self._sessions

    def __len__(self) -> int:
        return len(self._sessions)

    @property
    def tenants(self) -> list:
        """Open tenants in LRU order (least recently served first)."""
        return list(self._sessions)

    # ------------------------------------------------------------------ #
    def query(self, tenant: str, program, params=None, **kwargs):
        """``pool.query(t, ...)`` == ``pool.session(t).query(...)``."""
        return self.session(tenant).query(program, params, **kwargs)

    def query_batch(self, tenant: str, program, params_list, **kwargs):
        return self.session(tenant).query_batch(program, params_list,
                                                **kwargs)

    # ------------------------------------------------------------------ #
    def close(self, tenant: str) -> None:
        """Close and drop one tenant's session (its shared-cache pins are
        released; entries other tenants pin survive for them)."""
        sess = self._sessions.pop(tenant, None)
        if sess is not None:
            sess.close()

    def close_all(self) -> None:
        for t in list(self._sessions):
            self.close(t)

    def _evict_sessions(self) -> None:
        if self.max_sessions is None:
            return
        while len(self._sessions) > self.max_sessions:
            t, sess = self._sessions.popitem(last=False)
            sess.close()
            self.sessions_closed += 1

    def __enter__(self) -> "SessionPool":
        return self

    def __exit__(self, *exc) -> None:
        self.close_all()

    # ------------------------------------------------------------------ #
    def stats(self) -> dict:
        """Pool-wide snapshot: the shared runner cache (global + per-tenant
        accounting), the shared result cache, and each open session's
        ``SessionStats``."""
        out = dict(
            runner_cache=dict(
                entries=len(self.runner_cache),
                bytes=self.runner_cache.total_bytes,
                hits=self.runner_cache.hits,
                misses=self.runner_cache.misses,
                evictions=self.runner_cache.evictions,
                compile_time_total=self.runner_cache.compile_time_total,
                by_owner=dict(self.runner_cache.by_owner),
            ),
            sessions={t: s.stats for t, s in self._sessions.items()},
            sessions_closed=self.sessions_closed,
        )
        if self.result_cache is not None:
            out["result_cache"] = self.result_cache.stats
        return out
