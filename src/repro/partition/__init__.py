"""Balanced vertex-cut partitioning subsystem (docs/PARTITIONING.md).

Three layers on top of the pure-hash routers in ``core/partition.py``:

  - ``ebv``        — the EBV (efficiency-and-balance vertex-cut) stateful
    streaming router (Zhang et al., arXiv:2010.09007 — DRONE's follow-up):
    scores each edge against running per-partition replication sets and
    edge/vertex load counters instead of a memoryless hash.
  - ``monitor``    — ``LoadMonitor`` folds per-partition signals (edge
    counts, frontier occupancy, per-shard sweep time / ``backend_flops``)
    into an imbalance gauge with hysteresis.
  - ``rebalance``  — online rebalancer: picks a minimal set of boundary
    edges to migrate and executes the move through the same
    ``repack_partitions`` remap machinery that carries warm device state
    across ``compact()``.
"""
from repro.partition.ebv import (EBVConfig, EBVRouterState, RelocationOverlay,
                                 ebv_vertex_cut)
from repro.partition.monitor import LoadMonitor, MonitorConfig
from repro.partition.rebalance import (RebalancePlan, RebalanceStats,
                                       execute_rebalance, plan_rebalance)

__all__ = [
    "EBVConfig", "EBVRouterState", "RelocationOverlay", "ebv_vertex_cut",
    "LoadMonitor", "MonitorConfig",
    "RebalancePlan", "RebalanceStats", "execute_rebalance", "plan_rebalance",
]
