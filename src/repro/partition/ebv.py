"""EBV: efficiency-and-balance vertex-cut streaming router.

Zhang et al. (arXiv:2010.09007, DRONE's own follow-up paper) partition the
edge stream by jointly minimizing replication and load imbalance: edge
(u, v) goes to the partition minimizing

    score(p) = I[u not replicated on p] + I[v not replicated on p]
             + alpha * |E_p| * P / (|E_routed| + 1)
             + beta  * |V_p| * P / (sum_q |V_q| + 1)

The first two terms prefer partitions that already hold the endpoints (low
replication factor); the load terms steer ties — and eventually any
placement — toward underloaded partitions. Unlike the pure hashes in
``core/partition.py`` this is **stateful-streaming**: the score depends on
every previously routed edge, so chunking order matters and the state must
travel with the ``StreamContext``.

Determinism and resumability contract (what the tests pin):

  - given the same sequence of ``route_adds`` calls, assignments are
    bit-identical — scoring runs in fixed-size mini-blocks with the state
    frozen inside a block and folded in between blocks;
  - ``checkpoint()``/``from_checkpoint()`` snapshot/restore the full state:
    a restored router continues the stream with bit-identical assignments;
  - routing is **pair-sticky**: every placement is recorded in an exact
    edge->partition table keyed by the canonical pair key, so duplicate
    copies and both directions of an undirected edge co-locate, and
    ``route_deletes`` finds resident edges without replaying the stream.

The price of load-awareness is O(distinct pairs) host memory for the
assignment table plus O(V * P / 64) for the packed replica bitmask — the
table is two-tier (sorted base arrays + a small dict overlay merged in
batches) so lookups stay O(log E) and inserts amortized O(1).
``route_deletes`` does not decrement the load counters (a delete does not
say how many resident copies it removed); ``resync()`` re-reads the exact
counters from a realized ``PartitionedGraph`` — the rebalancer calls it
after every migration.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graph import Graph, splitmix64

__all__ = ["EBVConfig", "EBVRouterState", "RelocationOverlay",
           "ebv_vertex_cut"]

_KEY_SHIFT = np.uint64(32)
_ONE = np.uint64(1)
# overlay entries are merged into the sorted base arrays at this size: large
# enough to amortize the re-sort, small enough to keep per-edge dict cost flat
_MERGE_AT = 1 << 16


def pair_keys(src: np.ndarray, dst: np.ndarray) -> np.ndarray:
    """Canonical uint64 key for an undirected endpoint pair: (lo << 32) | hi.

    Growth-stable (independent of ``n_vertices``, unlike the dense
    ``src * V + dst`` key the delta patcher uses internally), so table
    entries survive id-space growth. Requires ids < 2**32 — far beyond the
    int32 local-index envelope the builders already impose.
    """
    lo = np.minimum(src, dst).astype(np.uint64)
    hi = np.maximum(src, dst).astype(np.uint64)
    return (lo << _KEY_SHIFT) | hi


class _PairTable:
    """Exact edge-key -> partition map, two-tier: a sorted uint64 base array
    (binary-searched) under a dict overlay (recent inserts; wins on
    conflict), merged down when the overlay grows past ``_MERGE_AT``."""

    def __init__(self, keys=None, parts=None):
        self.base_keys = (np.empty(0, np.uint64) if keys is None
                          else np.asarray(keys, np.uint64))
        self.base_parts = (np.empty(0, np.int32) if parts is None
                           else np.asarray(parts, np.int32))
        self.overlay: dict = {}

    def __len__(self) -> int:
        # upper bound: overlay entries may shadow base entries until merged
        return int(self.base_keys.size) + len(self.overlay)

    def get(self, keys: np.ndarray) -> np.ndarray:
        """Partition per key; -1 where the pair was never recorded."""
        out = np.full(keys.shape, -1, np.int32)
        if self.base_keys.size:
            pos = np.searchsorted(self.base_keys, keys)
            pos_c = np.minimum(pos, self.base_keys.size - 1)
            hit = self.base_keys[pos_c] == keys
            out[hit] = self.base_parts[pos_c[hit]]
        if self.overlay:
            ov = self.overlay
            for i, k in enumerate(keys.tolist()):
                p = ov.get(k)
                if p is not None:
                    out[i] = p
        return out

    def put(self, keys: np.ndarray, parts: np.ndarray) -> None:
        ov = self.overlay
        for k, p in zip(keys.tolist(), parts.tolist()):
            ov[k] = p
        if len(ov) >= _MERGE_AT:
            self.merge()

    def merge(self) -> None:
        """Fold the overlay into the sorted base (overlay wins on dups)."""
        if not self.overlay:
            return
        ok = np.fromiter(self.overlay.keys(), np.uint64, len(self.overlay))
        op = np.fromiter(self.overlay.values(), np.int32, len(self.overlay))
        keys = np.concatenate([self.base_keys, ok])
        parts = np.concatenate([self.base_parts, op])
        order = np.argsort(keys, kind="stable")   # base first, overlay after
        keys, parts = keys[order], parts[order]
        # keep the LAST entry of every duplicate run (the overlay's value)
        keep = np.ones(keys.size, bool)
        keep[:-1] = keys[:-1] != keys[1:]
        self.base_keys = keys[keep]
        self.base_parts = parts[keep]
        self.overlay = {}

    def snapshot(self) -> tuple:
        self.merge()
        return self.base_keys.copy(), self.base_parts.copy()


@dataclasses.dataclass(frozen=True)
class EBVConfig:
    """EBV objective weights + scoring granularity (all deterministic)."""

    alpha: float = 1.0      # edge-balance weight
    beta: float = 1.0       # vertex(replica)-balance weight
    block: int = 256        # mini-block size: state is frozen within a block


class EBVRouterState:
    """Running EBV router state: per-partition replica sets (packed bitmask),
    edge/replica load counters, and the exact pair->partition table.

    Mutating entry point is ``route_adds``; ``route_deletes`` and
    ``route_preview`` never change state. ``checkpoint``/``from_checkpoint``
    round-trip the whole thing (the streaming-resume contract)."""

    name = "ebv"

    def __init__(self, n_parts: int, n_vertices: int, *, seed: int = 0,
                 cfg: EBVConfig | None = None):
        assert n_parts >= 1
        self.n_parts = int(n_parts)
        self.n_vertices = int(n_vertices)
        self.seed = int(seed)
        self.cfg = cfg or EBVConfig()
        words = (self.n_parts + 63) // 64
        # replicas[v, w] bit b set <=> vertex v has a replica on part w*64+b
        self.replicas = np.zeros((self.n_vertices, words), np.uint64)
        self.edge_load = np.zeros(self.n_parts, np.int64)
        self.replica_load = np.zeros(self.n_parts, np.int64)
        self.total_edges = 0
        self.table = _PairTable()
        self._word = np.arange(self.n_parts) // 64
        self._bit = (np.arange(self.n_parts) % 64).astype(np.uint64)

    # ------------------------------------------------------------------ #
    def grow(self, n_vertices: int) -> None:
        if n_vertices > self.n_vertices:
            extra = np.zeros((n_vertices - self.n_vertices,
                              self.replicas.shape[1]), np.uint64)
            self.replicas = np.concatenate([self.replicas, extra])
            self.n_vertices = int(n_vertices)

    def _present(self, vids: np.ndarray) -> np.ndarray:
        """[N, P] bool: does vertex vids[i] have a replica on partition p?"""
        rows = self.replicas[vids]                       # [N, W]
        return ((rows[:, self._word] >> self._bit) & _ONE).astype(bool)

    def _score_block(self, lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
        """Argmin-EBV partition per edge, state frozen (ties -> lowest id)."""
        P = self.n_parts
        miss = 2.0 - (self._present(lo).astype(np.float64)
                      + self._present(hi).astype(np.float64))
        e_norm = self.cfg.alpha * P / (self.total_edges + 1.0)
        r_norm = self.cfg.beta * P / (float(self.replica_load.sum()) + 1.0)
        score = miss + self.edge_load * e_norm + self.replica_load * r_norm
        return np.argmin(score, axis=1).astype(np.int32)

    def _place(self, lo: np.ndarray, hi: np.ndarray,
               parts: np.ndarray) -> None:
        """Fold a scored block into the state: set replica bits (counting
        only newly-set ones into ``replica_load``) and bump edge loads."""
        vid = np.concatenate([lo, hi])
        pp = np.concatenate([parts, parts]).astype(np.int64)
        # dedup (vertex, partition) pairs so a block never double-counts
        uniq = np.unique(vid * np.int64(self.n_parts) + pp)
        uv = uniq // self.n_parts
        up = uniq % self.n_parts
        w = self._word[up]
        m = _ONE << self._bit[up]
        newbit = (self.replicas[uv, w] & m) == 0
        np.bitwise_or.at(self.replicas, (uv, w), m)
        self.replica_load += np.bincount(up[newbit], minlength=self.n_parts)
        self.edge_load += np.bincount(parts, minlength=self.n_parts)
        self.total_edges += int(parts.size)

    # ------------------------------------------------------------------ #
    def route_adds(self, src: np.ndarray, dst: np.ndarray) -> np.ndarray:
        """Place a chunk of inserts; updates state. Pairs already in the
        table stick to their recorded partition (co-location of duplicate
        copies and of both directions of an undirected edge)."""
        src = np.asarray(src, np.int64)
        dst = np.asarray(dst, np.int64)
        if src.size == 0:
            return np.empty(0, np.int32)
        if src.size and int(max(src.max(), dst.max())) >= self.n_vertices:
            self.grow(int(max(src.max(), dst.max())) + 1)
        keys = pair_keys(src, dst)
        out = self.table.get(keys)
        lo = np.minimum(src, dst)
        hi = np.maximum(src, dst)
        unknown = np.nonzero(out < 0)[0]
        known = np.nonzero(out >= 0)[0]
        for s in range(0, unknown.size, self.cfg.block):
            idx = unknown[s:s + self.cfg.block]
            if s:
                # a duplicate pair may have been placed by an earlier block
                # of this very call — stick to it (within one block, equal
                # rows score identically, so same-block dups already agree)
                now = self.table.get(keys[idx])
                stick = now >= 0
                if stick.any():
                    out[idx[stick]] = now[stick]
                    self._place(lo[idx[stick]], hi[idx[stick]], now[stick])
                    idx = idx[~stick]
                    if idx.size == 0:
                        continue
            choice = self._score_block(lo[idx], hi[idx])
            out[idx] = choice
            self._place(lo[idx], hi[idx], choice)
            self.table.put(keys[idx], choice)
        if known.size:
            # sticky re-adds: another copy lands on the recorded partition
            self._place(lo[known], hi[known], out[known])
        return out

    def route_deletes(self, src: np.ndarray, dst: np.ndarray) -> np.ndarray:
        """Partition holding the pair's resident copies (exact, from the
        table). Pairs never routed fall back to a deterministic hash — a
        delete of a non-resident pair is a no-op wherever it lands. Never
        mutates state (load counters drift; ``resync`` squares them)."""
        src = np.asarray(src, np.int64)
        dst = np.asarray(dst, np.int64)
        if src.size == 0:
            return np.empty(0, np.int32)
        keys = pair_keys(src, dst)
        out = self.table.get(keys)
        miss = out < 0
        if miss.any():
            out[miss] = (splitmix64(keys[miss] + np.uint64(self.seed))
                         % np.uint64(self.n_parts)).astype(np.int32)
        return out

    def route_preview(self, src: np.ndarray, dst: np.ndarray) -> np.ndarray:
        """Where ``route_adds`` *would currently* place each pair, without
        committing anything (DeltaBuffer part-counting)."""
        src = np.asarray(src, np.int64)
        dst = np.asarray(dst, np.int64)
        if src.size == 0:
            return np.empty(0, np.int32)
        keys = pair_keys(src, dst)
        out = self.table.get(keys)
        unknown = np.nonzero(out < 0)[0]
        if unknown.size:
            lo = np.minimum(src, dst)
            hi = np.maximum(src, dst)
            sel = np.minimum(lo[unknown], self.n_vertices - 1)
            seh = np.minimum(hi[unknown], self.n_vertices - 1)
            out[unknown] = self._score_block(sel, seh)
        return out

    # ------------------------------------------------------------------ #
    def apply_moves(self, pg, move_src: np.ndarray, move_dst: np.ndarray,
                    new_parts: np.ndarray) -> None:
        """Record a rebalancer migration (pair -> new partition) and resync
        the load counters/replica sets from the realized graph."""
        if np.asarray(move_src).size:
            self.table.put(pair_keys(np.asarray(move_src, np.int64),
                                     np.asarray(move_dst, np.int64)),
                           np.asarray(new_parts, np.int32))
        self.resync(pg)

    def resync(self, pg) -> None:
        """Re-read the exact per-partition loads and replica sets from a
        ``PartitionedGraph`` (post-migration, or after delete-heavy churn
        has drifted the streaming counters)."""
        self.grow(pg.n_vertices)
        self.replicas[:] = 0
        for p in range(pg.n_parts):
            members = pg.gvid[p][pg.vmask[p]]
            if members.size:
                np.bitwise_or.at(
                    self.replicas, (members, self._word[p]),
                    _ONE << self._bit[p])
        self.replica_load = pg.vertices_per_part.astype(np.int64).copy()
        self.edge_load = pg.edges_per_part.astype(np.int64).copy()
        self.total_edges = int(pg.n_edges)

    # ------------------------------------------------------------------ #
    def checkpoint(self) -> dict:
        """Full state snapshot (plain numpy arrays + scalars — picklable).
        ``from_checkpoint(blob)`` resumes the stream bit-identically."""
        keys, parts = self.table.snapshot()
        return dict(
            name=self.name, n_parts=self.n_parts, n_vertices=self.n_vertices,
            seed=self.seed, alpha=self.cfg.alpha, beta=self.cfg.beta,
            block=self.cfg.block, replicas=self.replicas.copy(),
            edge_load=self.edge_load.copy(),
            replica_load=self.replica_load.copy(),
            total_edges=self.total_edges, table_keys=keys, table_parts=parts)

    @classmethod
    def from_checkpoint(cls, blob: dict) -> "EBVRouterState":
        st = cls(blob["n_parts"], blob["n_vertices"], seed=blob["seed"],
                 cfg=EBVConfig(alpha=blob["alpha"], beta=blob["beta"],
                               block=blob["block"]))
        st.replicas = np.asarray(blob["replicas"], np.uint64).copy()
        st.edge_load = np.asarray(blob["edge_load"], np.int64).copy()
        st.replica_load = np.asarray(blob["replica_load"], np.int64).copy()
        st.total_edges = int(blob["total_edges"])
        st.table = _PairTable(blob["table_keys"], blob["table_parts"])
        return st


class RelocationOverlay:
    """Sticky relocation table over a pure chunk router.

    Installed by ``execute_rebalance`` on a *stateless* ``StreamContext``:
    migrated pairs are pinned to their new partition in an exact table,
    everything else keeps routing through the frozen base hash — so deletes
    and re-adds of moved edges still find the resident copies, and
    unmigrated traffic stays bit-identical to the pure-hash contract."""

    name = "relocation-overlay"

    def __init__(self, base_route):
        self._base = base_route      # (src, dst) -> int32[chunk]
        self.table = _PairTable()

    def _route(self, src: np.ndarray, dst: np.ndarray) -> np.ndarray:
        src = np.asarray(src, np.int64)
        dst = np.asarray(dst, np.int64)
        if src.size == 0:
            return np.empty(0, np.int32)
        out = self.table.get(pair_keys(src, dst))
        miss = out < 0
        if miss.any():
            out[miss] = np.asarray(self._base(src[miss], dst[miss]),
                                   np.int32)
        return out

    # moved pairs route identically on every path
    route_adds = _route
    route_deletes = _route
    route_preview = _route

    def grow(self, n_vertices: int) -> None:
        pass                         # the base hash owns the id space

    def apply_moves(self, pg, move_src, move_dst, new_parts) -> None:
        del pg
        if np.asarray(move_src).size:
            self.table.put(pair_keys(np.asarray(move_src, np.int64),
                                     np.asarray(move_dst, np.int64)),
                           np.asarray(new_parts, np.int32))

    def checkpoint(self) -> dict:
        keys, parts = self.table.snapshot()
        return dict(name=self.name, table_keys=keys, table_parts=parts)


def ebv_vertex_cut(g: Graph, n_parts: int, *, seed: int = 0,
                   cfg: EBVConfig | None = None,
                   state_out: list | None = None) -> np.ndarray:
    """One-shot EBV vertex-cut over an in-memory ``Graph`` — streams the
    edge list through a fresh ``EBVRouterState`` in storage order (the same
    order ``partition_and_build`` and a single-chunk ingest would use, so
    the two paths agree bit-for-bit). Pass ``state_out=[]`` to also receive
    the final router state (``GraphSession.from_graph`` attaches it to the
    session's ``StreamContext``)."""
    state = EBVRouterState(n_parts, g.n_vertices, seed=seed, cfg=cfg)
    part = state.route_adds(g.src, g.dst)
    if state_out is not None:
        state_out.append(state)
    return part
