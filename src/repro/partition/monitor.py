"""Per-partition load monitoring: imbalance gauge with hysteresis.

The monitor folds whatever per-partition signals the serving layer already
produces into one scalar gauge (1.0 = perfectly balanced, max/mean of the
blended load vector otherwise):

  - **edge counts** — ``PartitionedGraph.edges_per_part`` at every graph
    event (flush/compact), the structural signal;
  - **frontier occupancy** — active frontier slots per partition, the
    SBS-exchange pressure signal;
  - **measured work** — per-shard sweep time / ``backend_flops`` from
    ``ExecutionStats`` (``partition_sweep_time`` / ``partition_flops``),
    EWMA-smoothed across queries, the realized-latency signal.

Hysteresis: ``should_rebalance()`` arms only after the gauge has sat at or
above ``high`` for ``patience`` consecutive graph observations, and after a
rebalance (``notify_rebalanced``) stays disarmed until the gauge drops
below ``low`` — so a borderline graph neither thrashes migrations nor
re-triggers on the first post-migration wobble. A graph the rebalancer
cannot improve (e.g. one partition pinned by a single hub) therefore
triggers exactly once, not every flush.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = ["MonitorConfig", "LoadMonitor"]


@dataclasses.dataclass(frozen=True)
class MonitorConfig:
    """Thresholds + signal weights for the imbalance gauge."""

    high: float = 1.5        # gauge >= high (for `patience` obs) -> trigger
    low: float = 1.15        # re-arm only once the gauge drops below this
    patience: int = 2        # consecutive high observations before arming
    ema: float = 0.5         # EWMA factor for the measured-work signals
    w_edges: float = 1.0     # edge-count signal weight
    w_time: float = 1.0      # per-shard sweep-time signal weight
    w_frontier: float = 0.25  # frontier-occupancy signal weight


def _imbalance(loads: Optional[np.ndarray]) -> float:
    if loads is None or loads.size == 0:
        return 1.0
    mean = float(loads.mean())
    if mean <= 0.0:
        return 1.0
    return float(loads.max()) / mean


class LoadMonitor:
    """Folds per-partition load signals into a hysteresis-gated gauge.

    ``observe_graph(pg)`` feeds the structural signals at every graph event;
    ``observe_query(stats)`` feeds the measured per-shard work from an
    ``ExecutionStats``. ``gauge`` blends the per-signal imbalances by the
    configured weights (signals never observed contribute nothing).
    """

    def __init__(self, cfg: Optional[MonitorConfig] = None):
        self.cfg = cfg or MonitorConfig()
        self._edge_loads: Optional[np.ndarray] = None
        self._frontier_loads: Optional[np.ndarray] = None
        self._time_loads: Optional[np.ndarray] = None   # EWMA seconds
        self._streak = 0          # consecutive high graph observations
        self._armed = True        # False between a rebalance and re-arm
        self.observations = 0
        self.triggers = 0

    # ------------------------------------------------------------------ #
    def observe_graph(self, pg) -> float:
        """Fold the structural signals of a ``PartitionedGraph`` (edge
        counts + frontier occupancy) and advance the hysteresis state.
        Returns the updated gauge."""
        self._edge_loads = pg.edges_per_part.astype(np.float64)
        live = pg.vmask & pg.is_frontier
        self._frontier_loads = live.sum(axis=1).astype(np.float64)
        self.observations += 1
        g = self.gauge
        if g >= self.cfg.high:
            self._streak += 1
        else:
            self._streak = 0
        if not self._armed and g < self.cfg.low:
            self._armed = True
        return g

    def observe_query(self, stats) -> None:
        """EWMA-fold a query's measured per-shard work (``ExecutionStats``
        with ``partition_sweep_time``/``partition_flops`` filled in)."""
        t = getattr(stats, "partition_sweep_time", None)
        if not t:
            flops = getattr(stats, "partition_flops", None)
            if not flops:
                return
            t = flops
        t = np.asarray(t, np.float64)
        if self._time_loads is None or self._time_loads.size != t.size:
            self._time_loads = t
        else:
            a = self.cfg.ema
            self._time_loads = a * t + (1.0 - a) * self._time_loads

    # ------------------------------------------------------------------ #
    @property
    def gauge(self) -> float:
        """Weighted blend of the per-signal max/mean imbalances."""
        parts = [(self.cfg.w_edges, _imbalance(self._edge_loads)),
                 (self.cfg.w_time, _imbalance(self._time_loads)),
                 (self.cfg.w_frontier, _imbalance(self._frontier_loads))]
        num = den = 0.0
        for w, g in parts:
            if w > 0.0:
                num += w * g
                den += w
        return num / den if den else 1.0

    def blended_loads(self, n_parts: int) -> Optional[np.ndarray]:
        """[n_parts] weighted blend of the observed per-partition load
        vectors (each mean-normalized so the weights compare signal
        *shapes*, not units) — what ``plan_rebalance(loads=...)`` wants for
        sweep-time-weighted donor selection. Signals never observed — or
        observed for a different partition count — contribute nothing;
        returns None when nothing usable has been observed at all (the
        planner then falls back to raw edge counts)."""
        out = np.zeros(n_parts, np.float64)
        tot = 0.0
        for w, arr in ((self.cfg.w_edges, self._edge_loads),
                       (self.cfg.w_time, self._time_loads),
                       (self.cfg.w_frontier, self._frontier_loads)):
            if w <= 0.0 or arr is None or arr.size != n_parts:
                continue
            mean = float(arr.mean())
            if mean <= 0.0:
                continue
            out += w * (arr / mean)
            tot += w
        return out / tot if tot > 0.0 else None

    def signals(self) -> dict:
        """Per-signal imbalance snapshot (benchmark tables / debugging)."""
        return {
            "edges": _imbalance(self._edge_loads),
            "sweep_time": _imbalance(self._time_loads),
            "frontier": _imbalance(self._frontier_loads),
            "gauge": self.gauge,
            "armed": self._armed,
            "streak": self._streak,
        }

    def should_rebalance(self) -> bool:
        """True when armed and the gauge has sat at/above ``high`` for
        ``patience`` consecutive graph observations."""
        return self._armed and self._streak >= self.cfg.patience

    def notify_rebalanced(self) -> None:
        """A migration ran: reset the streak and disarm until the gauge
        drops below ``low`` (thrash protection)."""
        self.triggers += 1
        self._streak = 0
        self._armed = False
