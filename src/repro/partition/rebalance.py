"""Online rebalancing: migrate boundary edges off overloaded partitions.

Streaming churn skews partitions no matter how well ingest placed the
initial graph — a hot producer keeps appending to the same community, a
delete wave hollows out another partition. When the ``LoadMonitor`` gauge
trips, the rebalancer picks a **minimal, cheapest-first** set of resident
edges to migrate and executes the move through the *same*
``repack_partitions`` remap machinery that ``compact()`` already uses —
which is exactly what lets warm device state, runner-cache entries, and the
tiered result cache survive a migration:

  - the remap carries ``[P, v_max, K]`` warm blocks to their new rows
    (``RebalanceStats.remap_state``, same contract as ``CompactStats``);
  - capacities land on the shape policy's bucket floor, so a migration that
    stays inside the current buckets keeps every compiled runner — zero
    retraces (the acceptance test pins this with ``retrace_guard``);
  - the session bumps its graph version, which *implicitly* invalidates all
    result-cache entries (keys carry the version) — no flush protocol.

Planning is deterministic greedy: donors (partitions above ``target`` x
mean edge load) shed their overflow, cheapest edges first, where the cost
of moving edge (u, v) to partition r counts the replicas the move would
*create* (0 if r already hosts both endpoints — a boundary edge, 1 for one
endpoint, 2 for none). Receivers fill up to the mean; spill beyond a
receiver's capacity is deferred to the next trigger rather than forced
into a worse placement. Migrated pairs are recorded in the routing
context's relocation table (``EBVRouterState.apply_moves`` or a fresh
``RelocationOverlay`` over a pure hash) so later deletes/re-adds of a
moved pair still find the resident copies.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.partition import route_vertices_rh
from repro.core.subgraph import (PartitionedGraph, ShapePolicy,
                                 repack_partitions)
from repro.stream.delta import _remap_rows
from repro.stream.ingest import StreamContext

__all__ = ["RebalancePlan", "RebalanceStats", "plan_rebalance",
           "execute_rebalance"]


@dataclasses.dataclass
class RebalancePlan:
    """A concrete migration: per-donor edge indices (into the donor's
    *masked resident edge list*, valid until the next mutation) and their
    destination partitions."""

    # donor partition -> (edge indices int64[], destination parts int32[])
    moves: dict = dataclasses.field(default_factory=dict)
    imbalance_before: float = 1.0
    imbalance_after: float = 1.0      # predicted edge-count imbalance
    edges_considered: int = 0

    @property
    def n_moves(self) -> int:
        return sum(int(idx.size) for idx, _ in self.moves.values())


@dataclasses.dataclass
class RebalanceStats:
    """What ``execute_rebalance`` did, plus the state-carrying remap
    (same ``remap_state`` contract as ``CompactStats``/``DeltaStats``)."""

    n_moved: int = 0
    parts_from: int = 0
    parts_to: int = 0
    replicas_created: int = 0         # new replica rows the moves added
    imbalance_before: float = 1.0
    imbalance_after: float = 1.0      # realized edge-count imbalance
    v_max_before: int = 0
    v_max_after: int = 0
    e_max_before: int = 0
    e_max_after: int = 0
    n_slots_before: int = 0
    n_slots_after: int = 0
    remap: Optional[np.ndarray] = None   # [P, v_max_before] int32

    def remap_state(self, state: np.ndarray, fill) -> np.ndarray:
        """Carry a live ``[P, v_max_before(, K)]`` per-partition array
        across the migration's row re-layout. Migration moves *edges*, not
        values: surviving members keep their values at their new rows, new
        replica rows start at ``fill`` (the program's combiner identity — a
        valid bound, SBS combines replicas every superstep)."""
        if self.remap is None:
            return np.asarray(state)
        return _remap_rows(self.remap, self.v_max_after, state, fill)


def _resident_edges(pg: PartitionedGraph, p: int):
    m = pg.emask[p]
    gs = pg.gvid[p][pg.esrc[p][m]]
    gd = pg.gvid[p][pg.edst[p][m]]
    return gs, gd, pg.ew[p][m]


def _member_lookup(pg: PartitionedGraph, p: int) -> np.ndarray:
    """Sorted member ids of partition p (gvid rows are sorted unique)."""
    return pg.gvid[p][pg.vmask[p]]


def _has_member(members: np.ndarray, vids: np.ndarray) -> np.ndarray:
    if members.size == 0:
        return np.zeros(vids.shape, bool)
    pos = np.searchsorted(members, vids)
    pos = np.minimum(pos, members.size - 1)
    return members[pos] == vids


def plan_rebalance(pg: PartitionedGraph, *, target: float = 1.05,
                   max_fraction: float = 0.25,
                   loads: Optional[np.ndarray] = None) -> RebalancePlan:
    """Plan a minimal cheapest-first migration toward balanced edge loads.

    ``target``: donors are partitions above ``target * mean`` edges; the
    plan sheds them down to the mean. ``max_fraction`` caps the total moved
    edges at that fraction of |E| (a rebalance is an online nicety, not a
    re-partition). ``loads`` optionally weights donor selection by a
    measured per-partition load vector (the monitor's blended signal) in
    place of raw edge counts — moves themselves are always edges.
    """
    P = pg.n_parts
    epp = pg.edges_per_part.astype(np.int64)
    total = int(epp.sum())
    mean = total / max(P, 1)
    plan = RebalancePlan(
        imbalance_before=float(epp.max() / max(mean, 1e-12)),
        imbalance_after=float(epp.max() / max(mean, 1e-12)))
    if total == 0 or P < 2:
        return plan
    sel = epp if loads is None else np.asarray(loads, np.float64)
    donors = [p for p in np.argsort(-sel, kind="stable").tolist()
              if epp[p] > target * mean]
    if not donors:
        return plan

    move_budget = int(max_fraction * total)
    new_epp = epp.astype(np.float64).copy()
    # receivers absorb up to the mean; refreshed as the plan fills them
    capacity = np.maximum(mean - new_epp, 0.0)
    members = [_member_lookup(pg, p) for p in range(P)]

    for p in donors:
        quota = int(min(np.ceil(new_epp[p] - mean), move_budget))
        if quota <= 0:
            continue
        gs, gd, _ = _resident_edges(pg, p)
        plan.edges_considered += int(gs.size)
        receivers = np.array([r for r in range(P)
                              if r != p and capacity[r] >= 1.0], np.int64)
        if receivers.size == 0:
            break
        # cost[e, r] = replicas created by moving edge e to receiver r
        cost = np.zeros((gs.size, receivers.size), np.int8)
        for j, r in enumerate(receivers.tolist()):
            cost[:, j] = ((~_has_member(members[r], gs)).astype(np.int8)
                          + (~_has_member(members[r], gd)).astype(np.int8))
        # per edge: cheapest receiver, load-ascending tie-break (receiver
        # columns scanned in load order so argmin lands on the emptiest)
        order_j = np.argsort(new_epp[receivers], kind="stable")
        cost_sorted = cost[:, order_j]
        best_j = np.argmin(cost_sorted, axis=1)
        best_r = receivers[order_j][best_j]
        best_cost = cost_sorted[np.arange(gs.size), best_j]
        # cheapest edges first; stable sort keeps the plan deterministic
        order = np.argsort(best_cost, kind="stable")[:max(4 * quota, quota)]
        take_idx, take_dst = [], []
        taken = 0
        for e in order.tolist():
            r = int(best_r[e])
            if capacity[r] < 1.0:
                continue
            take_idx.append(e)
            take_dst.append(r)
            capacity[r] -= 1.0
            new_epp[r] += 1.0
            taken += 1
            if taken >= quota:
                break
        if taken:
            plan.moves[p] = (np.asarray(take_idx, np.int64),
                             np.asarray(take_dst, np.int32))
            new_epp[p] -= taken
            move_budget -= taken
        if move_budget <= 0:
            break

    plan.imbalance_after = float(new_epp.max() / max(mean, 1e-12))
    return plan


def execute_rebalance(pg: PartitionedGraph, ctx: StreamContext,
                      plan: RebalancePlan, *, pad_multiple: int = 8,
                      shape_policy: Optional[ShapePolicy] = None
                      ) -> RebalanceStats:
    """Execute a migration plan in place through ``repack_partitions``.

    Rebuilds every partition's membership/edge lists with the planned moves
    applied, repacks the dense padded arrays (capacities land on the shape
    policy's bucket floor — in-bucket migrations keep compiled runners),
    records the moved pairs in ``ctx``'s relocation table, and returns the
    stats whose ``remap_state`` carries live device-layout state across."""
    assert ctx is not None and ctx.n_parts == pg.n_parts
    P = pg.n_parts
    epp = pg.edges_per_part.astype(np.float64)
    mean = max(float(epp.mean()), 1e-12)
    stats = RebalanceStats(
        imbalance_before=float(epp.max() / mean),
        imbalance_after=float(epp.max() / mean),
        v_max_before=pg.v_max, e_max_before=pg.e_max,
        n_slots_before=pg.n_slots, n_slots_after=pg.n_slots)
    if plan.n_moves == 0:
        return stats
    replicas_before = int(pg.vmask.sum())

    part_edges = [list(_resident_edges(pg, p)) for p in range(P)]
    moved_src, moved_dst, moved_part = [], [], []
    appends: dict = {r: [] for r in range(P)}
    for p, (idx, dst_part) in plan.moves.items():
        gs, gd, w = part_edges[p]
        for r in np.unique(dst_part).tolist():
            sel = idx[dst_part == r]
            appends[r].append((gs[sel], gd[sel], w[sel]))
        moved_src.append(gs[idx])
        moved_dst.append(gd[idx])
        moved_part.append(dst_part)
        keep = np.ones(gs.size, bool)
        keep[idx] = False
        part_edges[p] = [gs[keep], gd[keep], w[keep]]
    for r, chunks in appends.items():
        if chunks:
            gs, gd, w = part_edges[r]
            part_edges[r] = [
                np.concatenate([gs] + [c[0] for c in chunks]),
                np.concatenate([gd] + [c[1] for c in chunks]),
                np.concatenate([w] + [c[2] for c in chunks])]
    moved_src = np.concatenate(moved_src)
    moved_dst = np.concatenate(moved_dst)
    moved_part = np.concatenate(moved_part)

    # membership = endpoints of resident edges; fully isolated vertices are
    # re-homed by the same hash round-robin as ingest/compact
    members = []
    touched = np.zeros(pg.n_vertices, bool)
    for p in range(P):
        gs, gd, _ = part_edges[p]
        lv = np.unique(np.concatenate([gs, gd]))
        members.append(lv)
        touched[lv] = True
    iso = np.nonzero(~touched)[0].astype(np.int64)
    if iso.size:
        iso_part = route_vertices_rh(iso, P)
        for p in range(P):
            mine = iso[iso_part == p]
            if mine.size:
                members[p] = np.unique(np.concatenate([members[p], mine]))

    stats.remap = repack_partitions(
        pg, members, [tuple(e) for e in part_edges],
        pad_multiple=pad_multiple, shape_policy=shape_policy)

    # pin the moved pairs in the routing context so later deletes/re-adds
    # find the migrated copies (stateful router: exact table + resync;
    # pure hash: install a RelocationOverlay)
    if ctx.router_state is None:
        from repro.partition.ebv import RelocationOverlay
        ctx.router_state = RelocationOverlay(ctx._route_pure)
    ctx.router_state.apply_moves(pg, moved_src, moved_dst, moved_part)

    stats.n_moved = int(moved_src.size)
    stats.parts_from = len(plan.moves)
    stats.parts_to = int(np.unique(moved_part).size)
    stats.replicas_created = int(pg.vmask.sum()) - replicas_before
    epp = pg.edges_per_part.astype(np.float64)
    stats.imbalance_after = float(epp.max() / max(epp.mean(), 1e-12))
    stats.v_max_after = pg.v_max
    stats.e_max_after = pg.e_max
    stats.n_slots_after = pg.n_slots
    return stats
