"""GraphSession — the resident-graph serving API (ROADMAP north star).

DRONE's programming surface (paper §5.1) is "think like a graph" over a
long-lived partitioned state — the posture that distinguishes subgraph-
centric systems (GoFFish, the balanced vertex-cut line) from stateless
per-job engines. The low-level free functions (``run_sim``/``run_shard_map``)
are per-job: every call re-uploads the full ``PartitionedGraph`` and
rebuilds + retraces the BSP runner, and the streaming lifecycle makes
callers hand-thread ``StreamContext``/``DeltaBuffer``/``init_state`` between
five modules. ``GraphSession`` owns all of that:

  - the stacked ``DeviceSubgraph`` pytree stays **resident on device**
    across queries, re-uploaded only when the host graph actually changed;
  - ``query(program, params)`` goes through a **compiled-runner cache**
    keyed by (program static fields, parameter *structure*, EngineConfig,
    bucketed padded shapes P/v_max/e_max/slot_capacity) — repeated queries,
    multi-algorithm traffic and different parameter values (any SSSP
    source) all reuse one AOT-compiled executable with zero retraces;
  - each converged result of a monotone program is remembered and
    **auto-warm-starts** the next identical query after insert-only graph
    growth (``warm="auto"``);
  - the streaming lifecycle is folded in as methods: ``update`` routes
    through an internal coalescing ``DeltaBuffer``, ``flush`` applies the
    patch and refreshes the device pytree, ``compact`` shrinks the padded
    capacities; both log their row remap on a pending chain that each
    cached warm result replays lazily on its next use (a flush is O(1) in
    warm occupancy);
  - padded shapes follow a **bucketed ShapePolicy** (geometric rounding of
    ``v_max``/``e_max`` and of the SBS slot count, default growth 2x): a
    flush that stays inside the current bucket keeps the resident pytree
    layout and re-hits the compiled runner with zero retraces, so a growing
    graph compiles O(log growth) runners instead of O(flushes);
  - the runner cache is **bounded with LRU eviction** (``max_runners``):
    evicted entries recompile transparently on re-query, and eviction
    counts are surfaced in ``SessionStats`` / per-query
    ``ExecutionStats.evicted_runners`` / ``cache_info()``; warm-result
    memory is bounded the same way (``max_warm_entries``), and both caches
    take optional *byte* bounds (``max_runner_bytes``/``max_warm_bytes``)
    that count estimated device/host bytes per entry instead of slots;
  - ``EngineConfig.edge_backend`` picks the sweep's edge-compute backend
    (COO reference or the Pallas tile/window kernels); the device layouts
    ride as explicit runner inputs and their bucketed capacities join the
    cache key, so in-bucket streaming growth retraces nothing on any
    backend (docs/ARCHITECTURE.md "Edge-compute backends").

Monotone programs are always compiled with the warm input: a cold start is
served by a combiner-identity block (``warm_init`` tightening against the
identity is a no-op), so cold and warm queries share one executable and a
post-growth warm query retraces only when the padded shapes crossed a
bucket boundary.

    sess = GraphSession.from_graph(g, n_parts=16)         # or from_edge_log
    dist, st = sess.query(SSSP(), {"source": 0})          # compiles once
    dist, st = sess.query(SSSP(), {"source": 7})          # cache hit
    sess.update(adds=(src, dst, w))                       # buffered
    sess.flush()                                          # patch + re-upload
    dist, st = sess.query(SSSP(), {"source": 0})          # warm-auto restart

Backend selection is by mesh: construct with ``mesh=`` for the shard_map
production backend, without for the single-process simulator — the same
session code path serves both.

Invariants the session owns (docs/API.md "Caching rules" restates them):

  - **cache key fields** — a compiled runner is keyed by (program dataclass
    fields, param pytree *structure*, ``EngineConfig``, padded shape key
    ``(P, v_max, e_max, slot_capacity, has_vlabel)`` plus the Pallas
    layout shape-key when ``edge_backend`` is a kernel backend, warm-input
    flag); parameter *values* — and layout *contents* — are traced inputs
    and never key anything.
  - **warm entries are dtype-cast on entry** — a cached global result is
    cast to ``program.dtype`` before it reaches either backend
    (``engine._warm_block``), so a float64 numpy result can never leak its
    dtype into the compiled superstep loop and force a retrace.
  - **warm soundness** — insert-only flushes keep every cached converged
    result (values remain valid bounds, rows carried via
    ``DeltaStats.remap_state``); any deleting flush drops them all;
    ``compact`` changes layout, never the graph, so warm results survive it
    through ``CompactStats.remap_state``.
  - **slot-capacity padding is invisible** — runners are built with
    ``slot_capacity >= pg.n_slots``; the padded exchange rows only ever
    hold the combiner identity and are never gathered by a live vertex.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import (EngineConfig, _device_subgraph,
                               _exchange_bytes_per_step, _flops_per_sweep,
                               _layout_block_from, _warm_block,
                               make_bsp_runner, make_sim_runner,
                               resolve_edge_backend, run_sim)
from repro.core.api import VertexProgram
from repro.core.graph import Graph
from repro.core.metrics import ExecutionStats
from repro.core.partition import PARTITIONERS, STREAM_ROUTERS
from repro.core.subgraph import (PartitionedGraph, ShapePolicy,
                                 build_partitioned_graph)
from repro.stream.buffer import DeltaBuffer
from repro.stream.delta import CompactStats, DeltaStats, EdgeDelta
from repro.stream.delta import compact as _compact_pg
from repro.stream.ingest import StreamContext, streaming_ingest

__all__ = ["GraphSession", "SessionStats", "ShapePolicy"]


# --------------------------------------------------------------------------- #
# cache keys
# --------------------------------------------------------------------------- #
def _program_key(program: VertexProgram):
    """Hashable identity of a program's *static* structure: its type plus
    every dataclass field (combiner/payload/dtype/tol/... — anything that
    changes the traced computation). Programs carrying unhashable fields
    fall back to per-instance identity (still cached, just not shared
    across equal instances)."""
    try:
        fields = tuple((f.name, getattr(program, f.name))
                       for f in dataclasses.fields(program))
        hash(fields)
        return (type(program), fields)
    except TypeError:
        return (type(program), id(program))


def _canonical_params(params):
    """Params pytree with every leaf a jnp array of a fixed dtype, so the
    runner's input avals (and therefore the cache key) are stable across
    python ints / np scalars / device arrays."""
    if params is None:
        return {}
    return jax.tree.map(jnp.asarray, params)


def _params_struct_key(params):
    """Structure-only key (treedef + leaf shape/dtype): runners take params
    as *traced* inputs, so different values share one executable."""
    leaves, treedef = jax.tree.flatten(params)
    return (treedef, tuple((tuple(l.shape), str(l.dtype)) for l in leaves))


def _params_fingerprint(params):
    """Value-level key — warm results are only reusable for the *same*
    query (SSSP distances from source 0 say nothing about source 7)."""
    leaves, treedef = jax.tree.flatten(params)
    return (treedef, tuple((tuple(l.shape), str(l.dtype),
                            np.asarray(l).tobytes()) for l in leaves))


@dataclasses.dataclass
class _WarmEntry:
    """Last converged result of one (program, params) query.

    ``global_values`` ([n_vertices(, K)], combiner-identity filled) survives
    any membership change and is re-scattered through ``_warm_block`` when
    needed; ``device_block`` ([P, v_max, K], the program's own result
    layout) is the fast path — valid at ``device_epoch`` of the session's
    remap log: insert-only flushes and compactions do NOT eagerly remap it,
    they append to the log, and the pending chain is applied here on the
    entry's next use (``GraphSession._sync_warm_entry``)."""
    global_values: np.ndarray
    device_block: Optional[np.ndarray]
    identity: Any
    supersteps: int
    device_epoch: int = 0

    @property
    def nbytes(self) -> int:
        n = self.global_values.nbytes
        if self.device_block is not None:
            n += self.device_block.nbytes
        return n


@dataclasses.dataclass
class SessionStats:
    """Serving-side counters across the session lifetime."""
    queries: int = 0
    cache_hits: int = 0
    cache_misses: int = 0          # runner compilations
    warm_queries: int = 0          # queries served from a previous result
    flushes: int = 0               # delta batches applied to the host graph
    compactions: int = 0
    uploads: int = 0               # device pytree refreshes
    compile_time_total: float = 0.0
    cache_evictions_lru: int = 0   # runners dropped by the max_runners /
                                   # max_runner_bytes bounds
    cache_evictions_shape: int = 0  # runners dropped by a bucket change
    warm_evictions: int = 0        # warm results dropped by
                                   # max_warm_entries / max_warm_bytes
    runner_cache_bytes: int = 0    # estimated device bytes the compiled-
                                   # runner cache currently pins (outputs +
                                   # temps + code per executable)
    warm_cache_bytes: int = 0      # host bytes of the warm-result memory
    warm_remaps_applied: int = 0   # deferred warm-block remaps applied on
                                   # entry use (the lazy-flush counter: one
                                   # eager scheme would bill every entry
                                   # on every insert-only flush instead)


@dataclasses.dataclass
class _RunnerEntry:
    """One bounded-cache slot: the AOT-compiled executable plus the
    introspection the LRU policy and ``cache_info`` report on.
    ``shape_key`` is ``(padded-shape key, layout key)`` — the latter is None
    for COO runners and the Pallas layout capacities otherwise, so a layout
    cap growth evicts only the Pallas runners it actually staled."""
    compiled: Any
    shape_key: Any
    program: str                   # program type name (display only)
    compile_time: float = 0.0
    hits: int = 0
    nbytes: int = 0                # estimated device bytes this executable
                                   # pins (outputs + temps + generated code)


def _runner_nbytes(compiled) -> int:
    """Estimated device bytes a cached executable keeps alive: outputs +
    temps + generated code from XLA's ``memory_analysis``. Inputs are the
    session-owned resident graph, shared across runners, so they are
    deliberately not billed. Where the analysis is unavailable the entry
    weighs 0 — an unknown footprint must not be billed, or a single
    mis-estimated runner could thrash the whole byte-bounded cache."""
    try:
        m = compiled.memory_analysis()
        return int(m.output_size_in_bytes + m.temp_size_in_bytes
                   + m.generated_code_size_in_bytes)
    except Exception:
        return 0


class _SessionBuffer(DeltaBuffer):
    """DeltaBuffer whose flushes (manual *and* threshold-tripped) notify the
    owning session, so auto-flushes inside ``update`` never leave the device
    pytree or the warm cache stale."""

    def __init__(self, session: "GraphSession", *args, **kwargs):
        self._session = session
        super().__init__(*args, **kwargs)

    def flush(self, _auto: bool = False) -> Optional[DeltaStats]:
        st = super().flush(_auto)
        if st is not None:
            self._session._on_flush(st)
        return st


# --------------------------------------------------------------------------- #
class GraphSession:
    """Resident-graph serving session over one ``PartitionedGraph``.

    Construct from an existing partitioned graph (``GraphSession(pg, ...)``),
    an in-memory ``Graph`` (``from_graph``) or an on-disk edge log
    (``from_edge_log``). Pass ``mesh=`` to serve on the shard_map backend;
    without a mesh the session transparently uses the simulator backend.

    ``ctx`` (a ``StreamContext``) enables the mutation methods
    (``update``/``flush``/``compact``); the factory constructors provide it
    whenever the partitioner is a pure streaming router. A session without a
    context is read-only (queries still cache and warm-start).

    ``shape_policy`` governs the padded device shapes (docs/ARCHITECTURE.md,
    "shape-bucket lifecycle"): the default is the bucketed
    ``ShapePolicy()`` (geometric 2x buckets), which keeps the compiled
    runners stable under streaming growth; pass ``ShapePolicy.exact()`` for
    the tightest possible padding (one-shot analysis jobs, parity tests
    against the low-level layer). Read-only sessions have frozen shapes, so
    they never over-provision the slot capacity (and ``from_graph`` with a
    non-streamable partitioner defaults to exact padding outright).
    ``pad_multiple`` is a convenience for the default policy's tiling only —
    an explicit ``shape_policy`` always wins (it carries its own
    ``pad_multiple``). ``max_runners`` bounds the compiled-runner cache and
    ``max_warm_entries`` the per-(program, params) warm-result memory, both
    with LRU eviction (``None`` = unbounded). ``max_runner_bytes`` /
    ``max_warm_bytes`` additionally bound the same caches by *estimated
    bytes per entry* (device footprint per executable via XLA's
    ``memory_analysis``; host bytes per warm result) — slots bound entry
    counts, bytes bound what the entries actually pin.
    """

    def __init__(self, pg: PartitionedGraph, *, ctx: Optional[StreamContext]
                 = None, mesh=None, cfg: Optional[EngineConfig] = None,
                 max_buffer_edges: Optional[int] = 4096,
                 max_buffer_parts: Optional[int] = None,
                 pad_multiple: Optional[int] = None,
                 shape_policy: Optional[ShapePolicy] = None,
                 max_runners: Optional[int] = 32,
                 max_warm_entries: Optional[int] = 64,
                 max_runner_bytes: Optional[int] = None,
                 max_warm_bytes: Optional[int] = None):
        self.pg = pg
        self.ctx = ctx
        self.mesh = mesh
        self.cfg = self._normalize_cfg(cfg or EngineConfig())
        self.shape_policy = self._resolve_policy(shape_policy, pad_multiple)
        self.pad_multiple = self.shape_policy.pad_multiple
        self.max_runners = max_runners
        self.max_warm_entries = max_warm_entries
        self.max_runner_bytes = max_runner_bytes
        self.max_warm_bytes = max_warm_bytes
        self.stats = SessionStats()
        self.buffer = None if ctx is None else _SessionBuffer(
            self, pg, ctx, max_edges=max_buffer_edges,
            max_parts=max_buffer_parts, shape_policy=self.shape_policy)
        self._device = None            # resident stacked DeviceSubgraph
        self._device_version = -1
        self._host_version = 0         # bumped by every applied flush/compact
        self._runners: OrderedDict = OrderedDict()  # key -> _RunnerEntry (LRU)
        self._warm: OrderedDict = OrderedDict()     # (pkey, params) -> entry
        self._identity_blocks: dict = {}  # cold-start [P,v_max,K] blocks
        self._keepalive: dict = {}     # id-keyed programs pinned alive
        self._warm_epoch = 0           # advances per layout-moving event
        self._remap_log: list = []     # [(epoch, stats-with-remap_state)]:
                                       # pending warm-block remaps, applied
                                       # lazily on each entry's next use

    # ------------------------------------------------------------------ #
    # constructors
    # ------------------------------------------------------------------ #
    @classmethod
    def _resolve_policy(cls, shape_policy, pad_multiple) -> ShapePolicy:
        if shape_policy is not None:
            return shape_policy
        return ShapePolicy(pad_multiple=8 if pad_multiple is None
                           else pad_multiple)

    @classmethod
    def from_graph(cls, g: Graph, n_parts: int, partitioner: str = "cdbh",
                   *, seed: int = 0, mesh=None,
                   cfg: Optional[EngineConfig] = None,
                   pad_multiple: Optional[int] = None,
                   shape_policy: Optional[ShapePolicy] = None,
                   **kwargs) -> "GraphSession":
        """Partition + build + open a session in one call (the session-level
        ``partition_and_build``). Pure streaming partitioners also get a
        ``StreamContext`` so the update lifecycle works out of the box. The
        graph is padded by the session's (bucketed-by-default)
        ``shape_policy`` from the start, so the first flush already has
        in-bucket slack."""
        if shape_policy is None and partitioner not in STREAM_ROUTERS:
            # no StreamContext means no update/flush path: the shapes are
            # frozen for the session's lifetime, so buckets would only pay
            # padding overhead without ever amortizing a recompile
            shape_policy = ShapePolicy.exact(
                8 if pad_multiple is None else pad_multiple)
        policy = cls._resolve_policy(shape_policy, pad_multiple)
        part = PARTITIONERS[partitioner](g, n_parts, seed=seed)
        pg = build_partitioned_graph(g, part, n_parts, shape_policy=policy)
        ctx = None
        if partitioner in STREAM_ROUTERS:
            ctx = StreamContext(partitioner=partitioner, n_parts=n_parts,
                                seed=seed, n_vertices=g.n_vertices,
                                routing_degrees=g.total_degrees())
        return cls(pg, ctx=ctx, mesh=mesh, cfg=cfg, shape_policy=policy,
                   **kwargs)

    @classmethod
    def from_edge_log(cls, log, n_parts: int, partitioner: str = "cdbh",
                      *, seed: int = 0, mesh=None,
                      cfg: Optional[EngineConfig] = None,
                      pad_multiple: Optional[int] = None,
                      shape_policy: Optional[ShapePolicy] = None,
                      **kwargs) -> "GraphSession":
        """Open a session over a chunked on-disk edge log via the two-pass
        out-of-core ingest (docs/STREAMING.md). ``sess.ingest_stats`` holds
        the ingest throughput/memory accounting."""
        policy = cls._resolve_policy(shape_policy, pad_multiple)
        pg, ctx, stats = streaming_ingest(log, n_parts, partitioner,
                                          seed=seed, shape_policy=policy)
        sess = cls(pg, ctx=ctx, mesh=mesh, cfg=cfg, shape_policy=policy,
                   **kwargs)
        sess.ingest_stats = stats
        return sess

    # ------------------------------------------------------------------ #
    def _normalize_cfg(self, cfg: EngineConfig) -> EngineConfig:
        """The session picks the backend from mesh presence — a config asking
        for shard_map without a mesh falls back to the simulator
        transparently (and vice versa), so one call site serves both."""
        backend = "sim" if self.mesh is None else "shard_map"
        if cfg.backend != backend:
            cfg = dataclasses.replace(cfg, backend=backend)
        return cfg

    @property
    def slot_capacity(self) -> int:
        """SBS exchange-buffer height the runners are built with — the
        bucketed ``pg.n_slots``. Frontier re-elections that stay inside the
        slot bucket change nothing a compiled runner can see. A read-only
        session (no mutation path) has a frozen frontier, so it pads
        nothing."""
        if self.buffer is None:
            return int(self.pg.n_slots)
        return self.shape_policy.slot_capacity(self.pg.n_slots)

    @property
    def shape_key(self):
        """The padded device shapes a compiled runner is specialized to.
        All four dims are bucket values under the session's
        ``shape_policy``, so the key — and with it the runner cache — is
        stable across any flush that stays inside the current buckets."""
        pg = self.pg
        return (pg.n_parts, pg.v_max, pg.e_max, self.slot_capacity,
                pg.vlabel is not None)

    def device_graph(self):
        """The resident stacked [P, ...] DeviceSubgraph pytree, re-uploaded
        only when the host graph changed since the last upload."""
        if self._device is None or self._device_version != self._host_version:
            self._device = _device_subgraph(self.pg)
            self._device_version = self._host_version
            self.stats.uploads += 1
        return self._device

    # ------------------------------------------------------------------ #
    # query path
    # ------------------------------------------------------------------ #
    def query(self, program: VertexProgram, params=None, *, warm="auto",
              cfg: Optional[EngineConfig] = None):
        """Run ``program`` over the resident graph; returns
        ``(results, ExecutionStats)`` exactly like the low-level ``run``
        (results in the [P, v_max(, K)] local layout; ``self.pg.collect``
        maps them to global ids).

        ``warm`` — ``"auto"`` (default): monotone programs restart from this
        (program, params) pair's last converged result whenever one is still
        sound (every flush since was insert-only); ``False``: force a cold
        start; ``True``: require a warm start and raise ``ValueError`` when
        none is available (non-monotone program, no previous result, or a
        deleting flush invalidated it).

        ``cfg`` overrides the session config for this query (e.g. the
        vertex-centric baseline ``EngineConfig(mode="vc")``); the backend
        still follows the session's mesh. ``cfg.trace=True`` queries
        delegate to the uncached ``run_sim`` trace loop (per-superstep stats
        and checkpointing are job-level features, not serving features).

        Buffered updates are flushed first: a query always sees every
        mutation accepted by ``update``.
        """
        if self.buffer is not None and len(self.buffer):
            self.flush()
        cfg = self._normalize_cfg(cfg or self.cfg)
        params_c = _canonical_params(params)
        pkey = _program_key(program)
        if isinstance(pkey[1], int):
            # id()-based fallback key: pin the program object so a freed id
            # can never be reused by a different program and hit this entry
            self._keepalive[pkey[1]] = program

        entry = wkey = None
        if program.monotone:
            wkey = (pkey, _params_fingerprint(params_c))
            entry = self._warm.get(wkey)
            if entry is not None:
                self._warm.move_to_end(wkey)   # refresh LRU recency
        if warm is True:
            if not program.monotone:
                raise ValueError(
                    f"warm=True: {type(program).__name__} is not monotone — "
                    "warm starts are only sound for programs whose values "
                    "tighten under the combiner (program.monotone)")
            if entry is None:
                raise ValueError(
                    "warm=True but no previous converged result is cached "
                    "for this (program, params) query (or a deleting flush "
                    "invalidated it); use warm='auto' to fall back to cold")
        use_warm = entry is not None and warm in ("auto", True)

        if cfg.trace:
            init = entry.global_values if use_warm else None
            return run_sim(program, self.pg, params, cfg, init_state=init)

        self.stats.queries += 1
        # programs without a SemiringSweep always run COO: normalize the
        # config so their runners dedupe across edge_backend settings
        eb = resolve_edge_backend(program, cfg)
        if eb != cfg.edge_backend:
            cfg = dataclasses.replace(cfg, edge_backend=eb)
        warm_in = bool(program.monotone)
        args = (self.device_graph(),)
        if eb != "coo":
            args += (self._layout_arg(program, eb),)
        args += (params_c,)
        if warm_in:
            args += (self._warm_arg(program, entry, use_warm),)
        compiled, compile_time, evicted = self._get_runner(
            program, pkey, params_c, cfg, warm_in, args, eb)
        t0 = time.perf_counter()
        out = compiled(*args)
        res, steps, tot_msgs, sweeps = jax.block_until_ready(out)
        wall = time.perf_counter() - t0
        if use_warm:
            self.stats.warm_queries += 1

        res = np.asarray(res)
        stats = self._execution_stats(program, cfg, int(steps),
                                      int(tot_msgs), np.asarray(sweeps),
                                      wall, compile_time, eb)
        stats.evicted_runners = evicted
        if program.monotone:
            self._remember(program, wkey, res, stats.supersteps)
        return res, stats

    def _layout_arg(self, program, eb):
        """Device layout pytree for a Pallas-backend query — an explicit
        runner input (like params), so the executable survives layout
        content changes and retraces only when the layout *capacities*
        cross a bucket (a new layout shape-key)."""
        lay = self.pg.ensure_edge_layouts(shape_policy=self.shape_policy)
        return _layout_block_from(lay, self.pg, program, eb)

    def _layout_key(self, eb):
        if eb == "coo":
            return None
        lay = self.pg.edge_layouts
        return None if lay is None else lay.shape_key(eb)

    def _sync_warm_entry(self, entry: _WarmEntry) -> None:
        """Apply the pending remap chain to this entry's device block (lazy
        counterpart of the old eager per-flush remap): every insert-only
        flush / compaction since the entry was last touched is replayed in
        order. Entries never queried again never pay for any flush."""
        if entry.device_block is None \
                or entry.device_epoch == self._warm_epoch:
            return
        for ep, st in self._remap_log:
            if ep > entry.device_epoch:
                entry.device_block = st.remap_state(entry.device_block,
                                                    fill=entry.identity)
                self.stats.warm_remaps_applied += 1
        entry.device_epoch = self._warm_epoch
        self._sync_warm_bytes()

    def _prune_remap_log(self) -> None:
        """Drop log entries every live device block is already past. The
        log length is bounded by the slowest-moving warm entry; clearing
        the warm memory (deleting flush, evictions) empties it."""
        blocks = [e.device_epoch for e in self._warm.values()
                  if e.device_block is not None]
        if not blocks:
            self._remap_log.clear()
            return
        floor = min(blocks)
        self._remap_log = [(ep, st) for ep, st in self._remap_log
                           if ep > floor]

    def _sync_warm_bytes(self) -> None:
        self.stats.warm_cache_bytes = sum(e.nbytes
                                          for e in self._warm.values())

    def _warm_arg(self, program, entry, use_warm):
        """[P, v_max, K] warm block: the cached result when warming, the
        combiner identity (a structural no-op for ``warm_init``) when cold —
        so both paths share one compiled runner."""
        pg = self.pg
        K = program.payload
        if not use_warm:
            # constant per (shapes, dtype, identity): keep it resident so
            # repeated cold queries skip the rebuild + host->device transfer
            ikey = (pg.n_parts, pg.v_max, K, str(np.dtype(program.dtype)),
                    float(program.identity))
            blk = self._identity_blocks.get(ikey)
            if blk is None:
                blk = jnp.full((pg.n_parts, pg.v_max, K), program.identity,
                               dtype=program.dtype)
                self._identity_blocks[ikey] = blk
            return blk
        self._sync_warm_entry(entry)
        blk = entry.device_block
        if blk is not None and blk.shape == (pg.n_parts, pg.v_max, K):
            return jnp.asarray(blk)
        return jnp.asarray(_warm_block(program, pg, entry.global_values))

    def _get_runner(self, program, pkey, params_c, cfg, warm_in, args, eb):
        """AOT-compile (trace + lower + compile, once) or fetch the cached
        executable for this (program, param structure, config, shapes).
        Returns ``(compiled, compile_time, n_lru_evictions)``; a hit
        refreshes the entry's LRU position. Runners are built against the
        bucketed ``slot_capacity``, not the exact ``pg.n_slots``; Pallas
        runners additionally key on the layout capacities (``shape_key`` of
        the ``EdgeLayouts``), which are bucketed and grow-only too."""
        lkey = self._layout_key(eb)
        full_shape = (self.shape_key, lkey)
        key = (pkey, _params_struct_key(params_c), cfg, full_shape, warm_in)
        hit = self._runners.get(key)
        if hit is not None:
            self._runners.move_to_end(key)
            hit.hits += 1
            self.stats.cache_hits += 1
            return hit.compiled, 0.0, 0
        self.stats.cache_misses += 1
        n_slots = self.slot_capacity
        t0 = time.perf_counter()
        if cfg.backend == "sim":
            fn = make_sim_runner(program, cfg, n_slots, warm_start=warm_in)
            compiled = jax.jit(fn).lower(*args).compile()
        else:
            self._check_mesh(cfg)
            go = make_bsp_runner(program, self.mesh, cfg, n_slots,
                                 params=params_c,
                                 has_vlabel=self.pg.vlabel is not None,
                                 warm_start=warm_in, params_as_input=True)
            # session args are (sgs[, lay], params[, warm]); the shard
            # runner wants (sgs[, lay][, warm], params) — reorder inside
            # the jitted wrapper
            n_pre = 2 if eb != "coo" else 1
            with self.mesh:
                compiled = jax.jit(
                    lambda *a: go(*(a[:n_pre] + a[n_pre + 1:]
                                    + (a[n_pre],)))
                ).lower(*args).compile()
        compile_time = time.perf_counter() - t0
        self.stats.compile_time_total += compile_time
        self._runners[key] = _RunnerEntry(
            compiled=compiled, shape_key=full_shape,
            program=type(program).__name__, compile_time=compile_time,
            nbytes=_runner_nbytes(compiled))
        evicted = self._evict_lru(self._runners, self.max_runners,
                                  "cache_evictions_lru",
                                  max_bytes=self.max_runner_bytes)
        self._sync_runner_bytes()
        return compiled, compile_time, evicted

    def _sync_runner_bytes(self) -> None:
        self.stats.runner_cache_bytes = sum(e.nbytes
                                            for e in self._runners.values())

    def _evict_lru(self, cache: OrderedDict, bound: Optional[int],
                   counter: str, max_bytes: Optional[int] = None) -> int:
        """Pop least-recently-used entries until ``cache`` fits ``bound``
        AND its estimated bytes fit ``max_bytes`` (the most recent entry is
        never evicted — a single over-budget entry must still serve),
        billing the named ``SessionStats`` counter and releasing any
        program pins the evictions orphaned."""
        evicted = 0
        if bound is not None:
            while len(cache) > bound:
                cache.popitem(last=False)
                evicted += 1
        if max_bytes is not None:
            total = sum(e.nbytes for e in cache.values())
            while total > max_bytes and len(cache) > 1:
                _, e = cache.popitem(last=False)
                total -= e.nbytes
                evicted += 1
        if evicted:
            setattr(self.stats, counter,
                    getattr(self.stats, counter) + evicted)
            self._prune_keepalive()
        return evicted

    def _prune_keepalive(self) -> None:
        """Release id-keyed program pins whose id no longer appears in any
        runner-cache or warm-memory key: once nothing can look the id up,
        the id-reuse hazard the pin guards against is gone, and keeping the
        object would leak host memory on a bounded cache."""
        if not self._keepalive:
            return
        live = {k[0][1] for k in self._runners} | \
               {wk[0][1] for wk in self._warm}
        self._keepalive = {i: p for i, p in self._keepalive.items()
                           if i in live}

    def _check_mesh(self, cfg: EngineConfig):
        sub = tuple(cfg.subgraph_axes)
        edge = tuple(cfg.edge_axes)
        n_sub = int(np.prod([self.mesh.shape[a] for a in sub]))
        n_edge = int(np.prod([self.mesh.shape[a] for a in edge])) \
            if edge else 1
        assert self.pg.n_parts == n_sub, (self.pg.n_parts, n_sub)
        assert self.pg.e_max % n_edge == 0, \
            "pad edges to a multiple of the edge axes"

    def _execution_stats(self, program, cfg, steps, msgs, sweeps, wall,
                         compile_time, eb="coo") -> ExecutionStats:
        pg = self.pg
        K = program.payload
        itemsize = np.dtype(program.dtype).itemsize
        # bytes are billed on the bucketed exchange height the runner
        # actually reduces, not the exact n_slots
        n_slots = self.slot_capacity
        if cfg.backend == "sim":
            total_bytes = steps * (n_slots + 1) * K * itemsize * pg.n_parts
        else:
            n_edge = int(np.prod([self.mesh.shape[a]
                                  for a in cfg.edge_axes])) \
                if cfg.edge_axes else 1
            total_bytes = steps * _exchange_bytes_per_step(
                cfg, n_slots, K, program.dtype, pg.n_parts, n_edge)
        lay = pg.edge_layouts
        sweeps64 = sweeps.astype(np.int64)
        st = ExecutionStats(
            supersteps=steps, total_messages=msgs,
            processed_edges=int(
                (sweeps64 * pg.edges_per_part.astype(np.int64)).sum()),
            total_bytes=total_bytes, wall_time=wall,
            compile_time=compile_time, edge_backend=eb,
            backend_flops=int((sweeps64 * _flops_per_sweep(
                program, eb, pg, lay)).sum()))
        if eb == "pallas_tiles" and lay is not None:
            spec = program.sweep_spec
            st.tile_density = lay.density(pg, spec.semiring,
                                          spec.edge_values, program.dtype)
        return st

    def _remember(self, program, wkey, res, supersteps):
        """Cache this converged result as the warm seed for the next
        identical query (padded rows sanitized to the combiner identity),
        evicting the least-recently-used result beyond
        ``max_warm_entries`` — the bound that keeps warm host memory and
        the per-flush remap cost independent of how many distinct queries
        the session has ever served."""
        pg = self.pg
        blk = res if res.ndim == 3 else res[..., None]
        blk = np.where(pg.vmask[..., None], blk,
                       np.asarray(program.identity, blk.dtype))
        self._warm[wkey] = _WarmEntry(
            global_values=pg.collect(res, fill=program.identity),
            device_block=blk, identity=program.identity,
            supersteps=supersteps, device_epoch=self._warm_epoch)
        self._warm.move_to_end(wkey)
        self._evict_lru(self._warm, self.max_warm_entries, "warm_evictions",
                        max_bytes=self.max_warm_bytes)
        self._prune_remap_log()
        self._sync_warm_bytes()

    # ------------------------------------------------------------------ #
    # streaming lifecycle
    # ------------------------------------------------------------------ #
    def _require_buffer(self, what: str) -> DeltaBuffer:
        if self.buffer is None:
            raise ValueError(
                f"{what} needs a StreamContext (this session was opened "
                "from a bare PartitionedGraph, or with a non-streamable "
                "partitioner); use GraphSession.from_graph/from_edge_log "
                "with a pure routing partitioner, or pass ctx=")
        return self.buffer

    def update(self, adds=None, deletes=None) -> None:
        """Enqueue edge mutations. ``adds`` is ``(src, dst)`` or
        ``(src, dst, w)`` (array-likes of global ids), ``deletes`` is
        ``(src, dst)``; an ``EdgeDelta`` is accepted for either role via
        ``push``. Ops coalesce in the internal ``DeltaBuffer`` and are
        applied on ``flush()`` (or automatically when a buffer threshold
        trips — the session notices either way)."""
        buf = self._require_buffer("update()")
        if isinstance(adds, EdgeDelta) or isinstance(deletes, EdgeDelta):
            raise TypeError("pass an EdgeDelta through session.push()")
        if deletes is not None:
            buf.delete(*deletes[:2])
        if adds is not None:
            buf.add(*adds[:3])

    def push(self, delta: EdgeDelta) -> None:
        """Enqueue a whole producer ``EdgeDelta`` (deletes-then-adds)."""
        self._require_buffer("push()").push(delta)

    def flush(self) -> Optional[DeltaStats]:
        """Apply every buffered mutation as one coalesced patch. Returns the
        applied patch's ``DeltaStats`` — if a buffer threshold already
        auto-flushed everything during ``update``, the stats of that last
        applied patch (never None once any patch has been applied; None only
        when nothing was ever buffered). The device pytree refreshes lazily
        on the next query; compiled runners survive unless the padded shapes
        crossed a bucket boundary."""
        buf = self._require_buffer("flush()")
        st = buf.flush()
        return st if st is not None else buf.last_flush

    def _on_flush(self, st: DeltaStats) -> None:
        self._host_version += 1
        self.stats.flushes += 1
        if st.warm_start_safe:
            # insert-only growth: previous results stay valid upper bounds.
            # Local rows reshuffle (and v_max may cross a bucket), but the
            # remap is only LOGGED here — each warm entry replays the
            # pending chain on its next use (_sync_warm_entry), so a flush
            # costs O(1) regardless of warm occupancy and entries that are
            # never queried again never pay at all.
            self._warm_epoch += 1
            self._remap_log.append((self._warm_epoch, st))
            self._prune_remap_log()
        else:
            # deletions can loosen values: nothing cached is sound anymore
            self._warm.clear()
            self._remap_log.clear()
            self._sync_warm_bytes()
        self._evict_stale_runners()

    def compact(self) -> CompactStats:
        """Evict edge-less members, shrink the padded capacities to the
        session policy's **bucket floor**, and carry every cached warm
        result across the re-layout (global values are layout-independent;
        device blocks move through ``remap_state``). When the compacted
        content still fits the current buckets the padded shapes — and every
        compiled runner — survive untouched."""
        if self.ctx is None:
            self._require_buffer("compact()")
        if self.buffer is not None and len(self.buffer):
            self.flush()
        cs = _compact_pg(self.pg, self.ctx, shape_policy=self.shape_policy)
        self._host_version += 1
        self.stats.compactions += 1
        # compaction changes layout, never values: joins the pending-remap
        # chain like an insert-only flush (applied on each entry's next use)
        self._warm_epoch += 1
        self._remap_log.append((self._warm_epoch, cs))
        self._prune_remap_log()
        self._evict_stale_runners()
        return cs

    def _evict_stale_runners(self) -> None:
        """Drop executables specialized to padded shapes the graph no longer
        has (bucket growth via flush, bucket shrink via compact). Any patch
        that stays inside the current buckets evicts nothing — the whole
        point of the bucketed cache. Pallas runners also check their layout
        capacities: a tile/block cap crossing its bucket stales only the
        runners of that backend, never the COO ones."""
        cur = self.shape_key
        lay = self.pg.edge_layouts
        cur_lay = {}
        if lay is not None and lay.matches(self.pg):
            cur_lay = {"tiles": lay.shape_key("pallas_tiles"),
                       "windows": lay.shape_key("pallas_windows")}

        def stale_entry(e):
            base, lkey = e.shape_key
            if base != cur:
                return True
            if lkey is None:
                return False
            return cur_lay.get(lkey[0]) != lkey

        stale = [k for k, e in self._runners.items() if stale_entry(e)]
        for k in stale:
            del self._runners[k]
        self.stats.cache_evictions_shape += len(stale)
        self._sync_runner_bytes()
        # flush/compact may also have dropped warm entries — release any
        # id-keyed program pins nothing references anymore
        self._prune_keepalive()
        self._identity_blocks = {
            k: v for k, v in self._identity_blocks.items()
            if k[:2] == (self.pg.n_parts, self.pg.v_max)}

    # ------------------------------------------------------------------ #
    # introspection
    # ------------------------------------------------------------------ #
    def cache_info(self) -> list:
        """Snapshot of the compiled-runner cache in LRU order (oldest —
        next to be evicted — first): one dict per entry with the program
        type name, the (padded-shape, layout) key it was specialized to,
        its hit count, what its compilation cost, and the estimated device
        bytes it pins (what ``max_runner_bytes`` evicts against)."""
        return [dict(program=e.program, shape_key=e.shape_key, hits=e.hits,
                     compile_time=e.compile_time, nbytes=e.nbytes)
                for e in self._runners.values()]
