"""GraphSession — the resident-graph serving API (ROADMAP north star).

DRONE's programming surface (paper §5.1) is "think like a graph" over a
long-lived partitioned state — the posture that distinguishes subgraph-
centric systems (GoFFish, the balanced vertex-cut line) from stateless
per-job engines. The low-level free functions (``run_sim``/``run_shard_map``)
are per-job: every call re-uploads the full ``PartitionedGraph`` and
rebuilds + retraces the BSP runner, and the streaming lifecycle makes
callers hand-thread ``StreamContext``/``DeltaBuffer``/``init_state`` between
five modules. ``GraphSession`` owns all of that:

  - the stacked ``DeviceSubgraph`` pytree stays **resident on device**
    across queries, re-uploaded only when the host graph actually changed;
  - ``query(program, params)`` goes through a **compiled-runner cache**
    keyed by (program static fields, parameter *structure*, EngineConfig,
    bucketed padded shapes P/v_max/e_max/slot_capacity) — repeated queries,
    multi-algorithm traffic and different parameter values (any SSSP
    source) all reuse one AOT-compiled executable with zero retraces;
  - each converged result of a monotone program is remembered and
    **auto-warm-starts** the next identical query after insert-only graph
    growth (``warm="auto"``);
  - the streaming lifecycle is folded in as methods: ``update`` routes
    through an internal coalescing ``DeltaBuffer``, ``flush`` applies the
    patch and refreshes the device pytree, ``compact`` shrinks the padded
    capacities; both log their row remap on a pending chain that each
    cached warm result replays lazily on its next use (a flush is O(1) in
    warm occupancy);
  - padded shapes follow a **bucketed ShapePolicy** (geometric rounding of
    ``v_max``/``e_max`` and of the SBS slot count, default growth 2x): a
    flush that stays inside the current bucket keeps the resident pytree
    layout and re-hits the compiled runner with zero retraces, so a growing
    graph compiles O(log growth) runners instead of O(flushes);
  - the runner cache is **bounded with LRU eviction** (``max_runners``):
    evicted entries recompile transparently on re-query, and eviction
    counts are surfaced in ``SessionStats`` / per-query
    ``ExecutionStats.evicted_runners`` / ``cache_info()``; warm-result
    memory is bounded the same way (``max_warm_entries``), and both caches
    take optional *byte* bounds (``max_runner_bytes``/``max_warm_bytes``)
    that count estimated device/host bytes per entry instead of slots;
  - ``EngineConfig.edge_backend`` picks the sweep's edge-compute backend
    (COO reference or the Pallas tile/window kernels); the device layouts
    ride as explicit runner inputs and their bucketed capacities join the
    cache key, so in-bucket streaming growth retraces nothing on any
    backend (docs/ARCHITECTURE.md "Edge-compute backends").

Monotone programs are always compiled with the warm input: a cold start is
served by a combiner-identity block (``warm_init`` tightening against the
identity is a no-op), so cold and warm queries share one executable and a
post-growth warm query retraces only when the padded shapes crossed a
bucket boundary.

    sess = GraphSession.from_graph(g, n_parts=16)         # or from_edge_log
    dist, st = sess.query(SSSP(), {"source": 0})          # compiles once
    dist, st = sess.query(SSSP(), {"source": 7})          # cache hit
    sess.update(adds=(src, dst, w))                       # buffered
    sess.flush()                                          # patch + re-upload
    dist, st = sess.query(SSSP(), {"source": 0})          # warm-auto restart

Backend selection is by mesh: construct with ``mesh=`` for the shard_map
production backend, without for the single-process simulator — the same
session code path serves both.

Invariants the session owns (docs/API.md "Caching rules" restates them):

  - **cache key fields** — a compiled runner is keyed by (program dataclass
    fields, param pytree *structure*, ``EngineConfig``, padded shape key
    ``(P, v_max, e_max, slot_capacity, has_vlabel)`` plus the Pallas
    layout shape-key when ``edge_backend`` is a kernel backend, warm-input
    flag); parameter *values* — and layout *contents* — are traced inputs
    and never key anything.
  - **warm entries are dtype-cast on entry** — a cached global result is
    cast to ``program.dtype`` before it reaches either backend
    (``engine._warm_block``), so a float64 numpy result can never leak its
    dtype into the compiled superstep loop and force a retrace.
  - **warm soundness** — insert-only flushes keep every cached converged
    result (values remain valid bounds, rows carried via
    ``DeltaStats.remap_state``); any deleting flush drops them all;
    ``compact`` changes layout, never the graph, so warm results survive it
    through ``CompactStats.remap_state``.
  - **slot-capacity padding is invisible** — runners are built with
    ``slot_capacity >= pg.n_slots``; the padded exchange rows only ever
    hold the combiner identity and are never gathered by a live vertex.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import (EngineConfig, _auto_layout_blocks,
                               _device_subgraph,
                               _exchange_bytes_per_step, _flops_per_sweep,
                               _layout_block_from, _warm_block,
                               make_bsp_runner, make_sim_runner,
                               normalize_edge_backend,
                               resolve_partition_backends, run_sim)
from repro.core.api import VertexProgram
from repro.core.graph import Graph
from repro.core.metrics import ExecutionStats
from repro.core.partition import (PARTITIONERS, STREAM_ROUTERS,
                                  is_stateful_router)
from repro.core.subgraph import (PartitionedGraph, ShapePolicy,
                                 build_partitioned_graph)
from repro.partition.monitor import LoadMonitor
from repro.partition.rebalance import (RebalanceStats, execute_rebalance,
                                       plan_rebalance)
from repro.serving.result_cache import ResultCache
from repro.serving.result_cache import result_key as _result_key
from repro.serving.runner_cache import RunnerCache
from repro.serving.runner_cache import RunnerEntry as _RunnerEntry
from repro.serving.runner_cache import canonical_params as _canonical_params
from repro.serving.runner_cache import params_fingerprint as \
    _params_fingerprint
from repro.serving.runner_cache import params_struct_key as _params_struct_key
from repro.serving.runner_cache import program_key as _program_key
from repro.serving.runner_cache import runner_nbytes as _runner_nbytes
from repro.stream.buffer import DeltaBuffer
from repro.stream.delta import CompactStats, DeltaStats, EdgeDelta
from repro.stream.delta import compact as _compact_pg
from repro.stream.ingest import StreamContext, streaming_ingest

__all__ = ["GraphSession", "SessionStats", "ShapePolicy"]


@dataclasses.dataclass
class _WarmEntry:
    """Last converged result of one (program, params) query.

    ``global_values`` ([n_vertices(, K)], combiner-identity filled) survives
    any membership change and is re-scattered through ``_warm_block`` when
    needed; ``device_block`` ([P, v_max, K], the program's own result
    layout) is the fast path — valid at ``device_epoch`` of the session's
    remap log: insert-only flushes and compactions do NOT eagerly remap it,
    they append to the log, and the pending chain is applied here on the
    entry's next use (``GraphSession._sync_warm_entry``).

    ``polarity`` is the program's ``warm_under`` declaration: the delta
    polarity this entry survives (``'inserts'``: SSSP/CC/BFS/LP results
    stay valid upper bounds while edges only appear; ``'deletes'``: the
    k-core peel stays valid while edges only disappear). ``_on_flush``
    drops exactly the entries whose polarity the applied patch violated."""
    global_values: np.ndarray
    device_block: Optional[np.ndarray]
    identity: Any
    supersteps: int
    device_epoch: int = 0
    polarity: str = "inserts"

    @property
    def nbytes(self) -> int:
        n = self.global_values.nbytes
        if self.device_block is not None:
            n += self.device_block.nbytes
        return n


@dataclasses.dataclass
class SessionStats:
    """Serving-side counters across the session lifetime."""
    queries: int = 0
    cache_hits: int = 0
    cache_misses: int = 0          # runner compilations
    warm_queries: int = 0          # queries served from a previous result
    flushes: int = 0               # delta batches applied to the host graph
    compactions: int = 0
    uploads: int = 0               # device pytree refreshes
    compile_time_total: float = 0.0
    cache_evictions_lru: int = 0   # runners dropped by the max_runners /
                                   # max_runner_bytes bounds
    cache_evictions_shape: int = 0  # runners dropped by a bucket change
    warm_evictions: int = 0        # warm results dropped by
                                   # max_warm_entries / max_warm_bytes
    runner_cache_bytes: int = 0    # estimated device bytes the compiled-
                                   # runner cache currently pins (outputs +
                                   # temps + code per executable)
    warm_cache_bytes: int = 0      # host bytes of the warm-result memory
    warm_remaps_applied: int = 0   # deferred warm-block remaps applied on
                                   # entry use (the lazy-flush counter: one
                                   # eager scheme would bill every entry
                                   # on every insert-only flush instead)
    device_launches: int = 0       # compiled-runner executions — a result-
                                   # cache hit serves with ZERO launches
    batches: int = 0               # micro-batched launches (query_batch)
    batched_queries: int = 0       # queries served inside those launches
    result_cache_l1_hits: int = 0  # converged results served from the
    result_cache_l2_hits: int = 0  # in-process / external tier
    result_cache_misses: int = 0   # result-cache consultations that ran
    rebalances: int = 0            # online migrations executed
    load_imbalance: float = 1.0    # the LoadMonitor's latest blended gauge
                                   # (1.0 when no monitor is attached)
    partition_edge_counts: list = dataclasses.field(default_factory=list)
                                   # latest per-partition resident edges
    partition_sweep_time: list = dataclasses.field(default_factory=list)
                                   # EWMA per-shard sweep seconds across
                                   # queries (the monitor's measured-work
                                   # signal, surfaced for benchmark tables)
    tile_density_min: float = 0.0  # spread of the per-partition tile
    tile_density_mean: float = 0.0  # densities from the latest Pallas/auto
    tile_density_max: float = 0.0  # query — the auto policy's raw input


class _SessionBuffer(DeltaBuffer):
    """DeltaBuffer whose flushes (manual *and* threshold-tripped) notify the
    owning session, so auto-flushes inside ``update`` never leave the device
    pytree or the warm cache stale."""

    def __init__(self, session: "GraphSession", *args, **kwargs):
        self._session = session
        super().__init__(*args, **kwargs)

    def flush(self, _auto: bool = False) -> Optional[DeltaStats]:
        st = super().flush(_auto)
        if st is not None:
            self._session._on_flush(st)
        return st


# --------------------------------------------------------------------------- #
class GraphSession:
    """Resident-graph serving session over one ``PartitionedGraph``.

    Construct from an existing partitioned graph (``GraphSession(pg, ...)``),
    an in-memory ``Graph`` (``from_graph``) or an on-disk edge log
    (``from_edge_log``). Pass ``mesh=`` to serve on the shard_map backend;
    without a mesh the session transparently uses the simulator backend.

    ``ctx`` (a ``StreamContext``) enables the mutation methods
    (``update``/``flush``/``compact``); the factory constructors provide it
    whenever the partitioner is a pure streaming router. A session without a
    context is read-only (queries still cache and warm-start).

    ``shape_policy`` governs the padded device shapes (docs/ARCHITECTURE.md,
    "shape-bucket lifecycle"): the default is the bucketed
    ``ShapePolicy()`` (geometric 2x buckets), which keeps the compiled
    runners stable under streaming growth; pass ``ShapePolicy.exact()`` for
    the tightest possible padding (one-shot analysis jobs, parity tests
    against the low-level layer). Read-only sessions have frozen shapes, so
    they never over-provision the slot capacity (and ``from_graph`` with a
    non-streamable partitioner defaults to exact padding outright).
    ``pad_multiple`` is a convenience for the default policy's tiling only —
    an explicit ``shape_policy`` always wins (it carries its own
    ``pad_multiple``). ``max_runners`` bounds the compiled-runner cache and
    ``max_warm_entries`` the per-(program, params) warm-result memory, both
    with LRU eviction (``None`` = unbounded). ``max_runner_bytes`` /
    ``max_warm_bytes`` additionally bound the same caches by *estimated
    bytes per entry* (device footprint per executable via XLA's
    ``memory_analysis``; host bytes per warm result) — slots bound entry
    counts, bytes bound what the entries actually pin.

    Serving extras (docs/SERVING.md): ``runner_cache=`` injects a shared
    :class:`repro.serving.runner_cache.RunnerCache` (how a ``SessionPool``
    makes same-bucket tenants reuse one executable — ``max_runners`` /
    ``max_runner_bytes`` are ignored in favor of the shared bounds);
    ``result_cache=`` attaches a tiered
    :class:`repro.serving.result_cache.ResultCache` consulted by ``query``
    before launching anything; ``tenant=`` names this session in the shared
    caches' keys and pin accounting. ``close()`` (or the context-manager
    protocol) drops the resident device pytree and releases every shared-
    cache pin; a closed session raises ``RuntimeError`` on use.

    ``rebalance=`` wires in the online load rebalancer
    (docs/PARTITIONING.md): ``"auto"`` attaches a ``LoadMonitor`` (pass
    ``monitor=`` to configure it) that watches per-partition edge counts,
    frontier occupancy and measured per-shard sweep time, and — when its
    hysteresis gauge trips under streaming churn — migrates boundary edges
    off the overloaded partitions through the same remap machinery as
    ``compact()`` (warm state and in-bucket compiled runners survive; the
    graph-version bump invalidates result-cache entries). ``"manual"``
    keeps the gauge live but only ``session.rebalance()`` migrates;
    ``"off"`` (default) disables both. ``rebalance_target`` is the edge-
    balance the planner aims for (donors shed down to the mean).

    ``debug_sanitize=True`` arms the runtime retrace sanitizer
    (``repro.analysis.sanitizer``): every cache-hit launch runs under a
    ``retrace_guard``, so an AOT-compiled runner that silently re-enters
    the jax tracer raises ``RetraceError`` at the query that did it instead
    of degrading latency forever. ``debug_sanitize="warn"`` downgrades the
    failure to a ``RetraceWarning`` for production canaries.
    """

    def __init__(self, pg: PartitionedGraph, *, ctx: Optional[StreamContext]
                 = None, mesh=None, cfg: Optional[EngineConfig] = None,
                 max_buffer_edges: Optional[int] = 4096,
                 max_buffer_parts: Optional[int] = None,
                 pad_multiple: Optional[int] = None,
                 shape_policy: Optional[ShapePolicy] = None,
                 max_runners: Optional[int] = 32,
                 max_warm_entries: Optional[int] = 64,
                 max_runner_bytes: Optional[int] = None,
                 max_warm_bytes: Optional[int] = None,
                 runner_cache: Optional[RunnerCache] = None,
                 result_cache: Optional[ResultCache] = None,
                 tenant: Optional[str] = None,
                 rebalance: str = "off",
                 monitor: Optional[LoadMonitor] = None,
                 rebalance_target: float = 1.05,
                 debug_sanitize=False):
        self.pg = pg
        self.ctx = ctx
        self.mesh = mesh
        self.cfg = self._normalize_cfg(cfg or EngineConfig())
        self.shape_policy = self._resolve_policy(shape_policy, pad_multiple)
        self.pad_multiple = self.shape_policy.pad_multiple
        self.max_warm_entries = max_warm_entries
        self.max_warm_bytes = max_warm_bytes
        if rebalance not in ("off", "auto", "manual"):
            raise ValueError(
                f"rebalance={rebalance!r}: expected 'off', 'manual' or "
                "'auto'")
        self._rebalance_mode = rebalance
        self.rebalance_target = rebalance_target
        # "manual" keeps the monitor's gauge live without auto-triggering;
        # "off" attaches one only if the caller handed it in explicitly
        self.monitor = monitor if monitor is not None else (
            LoadMonitor() if rebalance != "off" else None)
        self._rebalancing = False      # re-entrancy guard (auto trigger
                                       # fires from _on_flush, and
                                       # rebalance() itself flushes)
        self.tenant = f"session-{id(self):x}" if tenant is None else tenant
        self._runner_cache = runner_cache if runner_cache is not None \
            else RunnerCache(max_runners, max_runner_bytes)
        self.result_cache = result_cache
        self.debug_sanitize = debug_sanitize
        self._closed = False
        self.stats = SessionStats()
        self.buffer = None if ctx is None else _SessionBuffer(
            self, pg, ctx, max_edges=max_buffer_edges,
            max_parts=max_buffer_parts, shape_policy=self.shape_policy)
        self._device = None            # resident stacked DeviceSubgraph
        self._device_version = -1
        self._host_version = 0         # bumped by every applied flush/compact
        self._warm: OrderedDict = OrderedDict()     # (pkey, params) -> entry
        self._identity_blocks: dict = {}  # cold-start [P,v_max,K] blocks
        self._auto_pin: dict = {}      # (shape, tiles, windows buckets) ->
                                       # pinned 'auto' backend assignment
        self._keepalive: dict = {}     # id-keyed programs pinned alive
        self._warm_epoch = 0           # advances per layout-moving event
        self._remap_log: list = []     # [(epoch, stats-with-remap_state)]:
                                       # pending warm-block remaps, applied
                                       # lazily on each entry's next use

    # ------------------------------------------------------------------ #
    # constructors
    # ------------------------------------------------------------------ #
    @classmethod
    def _resolve_policy(cls, shape_policy, pad_multiple) -> ShapePolicy:
        if shape_policy is not None:
            return shape_policy
        return ShapePolicy(pad_multiple=8 if pad_multiple is None
                           else pad_multiple)

    @classmethod
    def from_graph(cls, g: Graph, n_parts: int, partitioner: str = "cdbh",
                   *, seed: int = 0, mesh=None,
                   cfg: Optional[EngineConfig] = None,
                   pad_multiple: Optional[int] = None,
                   shape_policy: Optional[ShapePolicy] = None,
                   **kwargs) -> "GraphSession":
        """Partition + build + open a session in one call (the session-level
        ``partition_and_build``). Pure streaming partitioners also get a
        ``StreamContext`` so the update lifecycle works out of the box. The
        graph is padded by the session's (bucketed-by-default)
        ``shape_policy`` from the start, so the first flush already has
        in-bucket slack."""
        if shape_policy is None and partitioner not in STREAM_ROUTERS:
            # no StreamContext means no update/flush path: the shapes are
            # frozen for the session's lifetime, so buckets would only pay
            # padding overhead without ever amortizing a recompile
            shape_policy = ShapePolicy.exact(
                8 if pad_multiple is None else pad_multiple)
        policy = cls._resolve_policy(shape_policy, pad_multiple)
        entry = STREAM_ROUTERS.get(partitioner)
        router_state = None
        if is_stateful_router(entry):
            # stateful-streaming partitioner (EBV): the one-shot assignment
            # and the session's routing state must come from the SAME
            # streamed pass, or later deltas would not find resident edges
            router_state = entry.make_state(n_parts, g.n_vertices, seed)
            part = np.minimum(router_state.route_adds(g.src, g.dst),
                              n_parts - 1)
        else:
            part = PARTITIONERS[partitioner](g, n_parts, seed=seed)
        pg = build_partitioned_graph(g, part, n_parts, shape_policy=policy)
        ctx = None
        if partitioner in STREAM_ROUTERS:
            ctx = StreamContext(partitioner=partitioner, n_parts=n_parts,
                                seed=seed, n_vertices=g.n_vertices,
                                routing_degrees=g.total_degrees(),
                                router_state=router_state)
        return cls(pg, ctx=ctx, mesh=mesh, cfg=cfg, shape_policy=policy,
                   **kwargs)

    @classmethod
    def from_edge_log(cls, log, n_parts: int, partitioner: str = "cdbh",
                      *, seed: int = 0, mesh=None,
                      cfg: Optional[EngineConfig] = None,
                      pad_multiple: Optional[int] = None,
                      shape_policy: Optional[ShapePolicy] = None,
                      **kwargs) -> "GraphSession":
        """Open a session over a chunked on-disk edge log via the two-pass
        out-of-core ingest (docs/STREAMING.md). ``sess.ingest_stats`` holds
        the ingest throughput/memory accounting."""
        policy = cls._resolve_policy(shape_policy, pad_multiple)
        pg, ctx, stats = streaming_ingest(log, n_parts, partitioner,
                                          seed=seed, shape_policy=policy)
        sess = cls(pg, ctx=ctx, mesh=mesh, cfg=cfg, shape_policy=policy,
                   **kwargs)
        sess.ingest_stats = stats
        return sess

    # ------------------------------------------------------------------ #
    def _normalize_cfg(self, cfg: EngineConfig) -> EngineConfig:
        """The session picks the backend from mesh presence — a config asking
        for shard_map without a mesh falls back to the simulator
        transparently (and vice versa), so one call site serves both."""
        backend = "sim" if self.mesh is None else "shard_map"
        if cfg.backend != backend:
            cfg = dataclasses.replace(cfg, backend=backend)
        return cfg

    @property
    def slot_capacity(self) -> int:
        """SBS exchange-buffer height the runners are built with — the
        bucketed ``pg.n_slots``. Frontier re-elections that stay inside the
        slot bucket change nothing a compiled runner can see. A read-only
        session (no mutation path) has a frozen frontier, so it pads
        nothing."""
        if self.buffer is None:
            return int(self.pg.n_slots)
        return self.shape_policy.slot_capacity(self.pg.n_slots)

    @property
    def shape_key(self):
        """The padded device shapes a compiled runner is specialized to.
        All four dims are bucket values under the session's
        ``shape_policy``, so the key — and with it the runner cache — is
        stable across any flush that stays inside the current buckets."""
        pg = self.pg
        return (pg.n_parts, pg.v_max, pg.e_max, self.slot_capacity,
                pg.vlabel is not None)

    @property
    def _runners(self):
        """The compiled-runner entries (key -> ``RunnerEntry``, LRU order).
        On a pool-shared cache this is the WHOLE shared map — other tenants'
        entries included; on the default private cache it is exactly the old
        per-session ``OrderedDict``. Kept as a property for introspection
        back-compat; mutate through ``self._runner_cache``."""
        return self._runner_cache.entries

    # The runner-cache bounds live on the cache itself (shared in a pool);
    # these proxies keep the historical mutable-attribute surface — setting
    # one re-bounds the cache this session uses, applied on the next insert.
    # On a pool-shared cache that IS the shared bound.
    @property
    def max_runners(self) -> Optional[int]:
        return self._runner_cache.max_entries

    @max_runners.setter
    def max_runners(self, v: Optional[int]) -> None:
        self._runner_cache.max_entries = v

    @property
    def max_runner_bytes(self) -> Optional[int]:
        return self._runner_cache.max_bytes

    @max_runner_bytes.setter
    def max_runner_bytes(self, v: Optional[int]) -> None:
        self._runner_cache.max_bytes = v

    # ------------------------------------------------------------------ #
    # lifecycle
    # ------------------------------------------------------------------ #
    @property
    def closed(self) -> bool:
        return self._closed

    def close(self) -> None:
        """Release everything this session holds: the resident device
        pytree, its pins in the (possibly shared) runner cache, the warm-
        result memory, identity blocks and program pins. Idempotent; any
        subsequent query/mutation raises ``RuntimeError``. Without close,
        a dropped session keeps device memory alive until GC — the pool
        eviction path needs the deterministic version."""
        if self._closed:
            return
        self._closed = True
        self._runner_cache.release(self.tenant)
        self._warm.clear()
        self._remap_log.clear()
        self._identity_blocks.clear()
        self._keepalive.clear()
        self._device = None
        self._device_version = -1
        self._sync_warm_bytes()
        self._sync_runner_bytes()

    def __enter__(self) -> "GraphSession":
        self._check_open()
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _check_open(self) -> None:
        if self._closed:
            raise RuntimeError("GraphSession is closed")

    def device_graph(self):
        """The resident stacked [P, ...] DeviceSubgraph pytree, re-uploaded
        only when the host graph changed since the last upload."""
        self._check_open()
        if self._device is None or self._device_version != self._host_version:
            self._device = _device_subgraph(self.pg)
            self._device_version = self._host_version
            self.stats.uploads += 1
        return self._device

    # ------------------------------------------------------------------ #
    # query path
    # ------------------------------------------------------------------ #
    def query(self, program: VertexProgram, params=None, *, warm="auto",
              cfg: Optional[EngineConfig] = None, use_result_cache=True):
        """Run ``program`` over the resident graph; returns
        ``(results, ExecutionStats)`` exactly like the low-level ``run``
        (results in the [P, v_max(, K)] local layout; ``self.pg.collect``
        maps them to global ids).

        ``warm`` — ``"auto"`` (default): monotone programs restart from this
        (program, params) pair's last converged result whenever one is still
        sound (every flush since was insert-only); ``False``: force a cold
        start; ``True``: require a warm start and raise ``ValueError`` when
        none is available (non-monotone program, no previous result, or a
        deleting flush invalidated it).

        ``cfg`` overrides the session config for this query (e.g. the
        vertex-centric baseline ``EngineConfig(mode="vc")``); the backend
        still follows the session's mesh. ``cfg.trace=True`` queries
        delegate to the uncached ``run_sim`` trace loop (per-superstep stats
        and checkpointing are job-level features, not serving features).

        When a ``result_cache`` is attached, the converged result of this
        exact ``(graph version, program, params, cfg)`` query may be served
        straight from the cache with **zero device launches**
        (``ExecutionStats.result_cache_tier`` says which tier answered);
        pass ``use_result_cache=False`` to force a device run. Keys carry
        the graph version, so any flush — including deleting ones —
        implicitly invalidates prior entries.

        Buffered updates are flushed first: a query always sees every
        mutation accepted by ``update``.
        """
        self._check_open()
        if self.buffer is not None and len(self.buffer):
            self.flush()
        cfg = self._normalize_cfg(cfg or self.cfg)
        params_c = _canonical_params(params)
        pkey = _program_key(program)
        if isinstance(pkey[1], int):
            # id()-based fallback key: pin the program object so a freed id
            # can never be reused by a different program and hit this entry
            self._keepalive[pkey[1]] = program

        entry = wkey = None
        if program.monotone:
            wkey = (pkey, _params_fingerprint(params_c))
            entry = self._warm.get(wkey)
            if entry is not None:
                self._warm.move_to_end(wkey)   # refresh LRU recency
        if warm is True:
            if not program.monotone:
                raise ValueError(
                    f"warm=True: {type(program).__name__} is not monotone — "
                    "warm starts are only sound for programs whose values "
                    "tighten under the combiner (program.monotone)")
            if entry is None:
                raise ValueError(
                    "warm=True but no previous converged result is cached "
                    "for this (program, params) query (or a deleting flush "
                    "invalidated it); use warm='auto' to fall back to cold")
        use_warm = entry is not None and warm in ("auto", True)

        if cfg.trace:
            init = entry.global_values if use_warm else None
            return run_sim(program, self.pg, params, cfg, init_state=init)

        self.stats.queries += 1
        # programs without a SemiringSweep always run COO: normalize the
        # config so their runners dedupe across edge_backend settings
        eb, cfg = normalize_edge_backend(program, cfg)

        use_rc = use_result_cache and self.result_cache is not None
        rkey = None
        if use_rc:
            rkey = _result_key(self.tenant, self._host_version, program,
                               params_c, cfg)
            t0 = time.perf_counter()
            val, tier = self.result_cache.get(rkey)
            if val is not None:
                # converged-result hit: no runner, no launch, no transfer
                if tier == "l1":
                    self.stats.result_cache_l1_hits += 1
                else:
                    self.stats.result_cache_l2_hits += 1
                st = ExecutionStats(
                    supersteps=int(val["supersteps"]),
                    wall_time=time.perf_counter() - t0,
                    edge_backend=str(val.get("edge_backend", eb)),
                    result_cache_tier=tier)
                return np.asarray(val["results"]), st
            self.stats.result_cache_misses += 1

        warm_in = bool(program.monotone)
        args = (self.device_graph(),)
        if eb != "coo":
            args += (self._layout_arg(program, eb, cfg),)
        args += (params_c,)
        if warm_in:
            args += (self._warm_arg(program, entry, use_warm),)
        compiled, compile_time, evicted = self._get_runner(
            program, pkey, params_c, cfg, warm_in, args, eb)
        t0 = time.perf_counter()
        out = self._launch(compiled, args, compile_time)
        self.stats.device_launches += 1
        res, steps, tot_msgs, sweeps = jax.block_until_ready(out)
        wall = time.perf_counter() - t0
        if use_warm:
            self.stats.warm_queries += 1

        res = np.asarray(res)
        stats = self._execution_stats(program, cfg, int(steps),
                                      int(tot_msgs), np.asarray(sweeps),
                                      wall, compile_time, eb)
        stats.evicted_runners = evicted
        if program.monotone:
            self._remember(program, wkey, res, stats.supersteps)
        if use_rc:
            stats.result_cache_tier = "miss"
            self.result_cache.put(rkey, dict(
                results=res, supersteps=stats.supersteps, edge_backend=eb))
        return res, stats

    def query_batch(self, program: VertexProgram, params_list, *,
                    warm="auto", cfg: Optional[EngineConfig] = None,
                    use_result_cache=True):
        """Serve ``len(params_list)`` queries of one program in a SINGLE
        device launch (the micro-batching engine entry point —
        ``serving/batcher.py`` coalesces live traffic into these). Returns
        ``[(results, ExecutionStats), ...]`` in input order, each exactly
        what ``query`` would have returned: the batched runner maps the
        same per-lane superstep loop over a stacked params pytree (COO
        simulator: ``jax.vmap`` — converged lanes are select-frozen, so
        per-lane results are bit-identical to singleton launches; Pallas /
        shard_map backends: ``lax.scan`` over lanes inside one executable).

        Every lane must share the program and the param *structure*
        (``ValueError`` otherwise — the batcher degrades mismatches to
        singleton ``query`` calls). Batch sizes are padded up to the next
        power of two (replicating lane 0) so the runner cache holds
        O(log max_batch) batched executables per program, not one per
        batch size; the pad lanes' outputs are discarded.

        Warm starts (``warm="auto"``) and the result cache work per lane:
        each lane looks up / stores its own warm entry and result-cache
        key. The result cache short-circuits only when EVERY lane hits —
        a partial hit still launches the full batch (the lanes that hit
        are simply recomputed; their entries refresh)."""
        self._check_open()
        if self.buffer is not None and len(self.buffer):
            self.flush()
        B = len(params_list)
        if B == 0:
            return []
        cfg = self._normalize_cfg(cfg or self.cfg)
        if cfg.trace:
            raise ValueError("query_batch does not support cfg.trace — "
                             "trace one query at a time")
        params_cs = [_canonical_params(p) for p in params_list]
        skey = _params_struct_key(params_cs[0])
        for pc in params_cs[1:]:
            if _params_struct_key(pc) != skey:
                raise ValueError(
                    "query_batch needs an identical param structure on "
                    "every lane (same treedef, leaf shapes and dtypes); "
                    "mismatched requests must go through query()")
        if B == 1:
            res, st = self.query(program, params_list[0], warm=warm,
                                 cfg=cfg, use_result_cache=use_result_cache)
            return [(res, st)]
        if not jax.tree.leaves(params_cs[0]) and not program.monotone:
            # leafless lanes (no params, no warm input): nothing carries a
            # batch axis and every lane is the same computation — serve one
            # singleton and fan the result out
            res, st = self.query(program, params_list[0], warm=warm,
                                 cfg=cfg, use_result_cache=use_result_cache)
            return [(res, dataclasses.replace(st, batch_size=B))
                    for _ in range(B)]

        pkey = _program_key(program)
        if isinstance(pkey[1], int):
            self._keepalive[pkey[1]] = program
        eb, cfg = normalize_edge_backend(program, cfg)

        use_rc = use_result_cache and self.result_cache is not None
        rkeys = None
        if use_rc:
            rkeys = [_result_key(self.tenant, self._host_version, program,
                                 pc, cfg) for pc in params_cs]
            if all(self.result_cache.peek(k) is not None for k in rkeys):
                out = []
                for k in rkeys:
                    t0 = time.perf_counter()
                    val, tier = self.result_cache.get(k)
                    if tier == "l1":
                        self.stats.result_cache_l1_hits += 1
                    else:
                        self.stats.result_cache_l2_hits += 1
                    out.append((np.asarray(val["results"]), ExecutionStats(
                        supersteps=int(val["supersteps"]),
                        wall_time=time.perf_counter() - t0,
                        edge_backend=str(val.get("edge_backend", eb)),
                        result_cache_tier=tier, batch_size=B)))
                self.stats.queries += B
                return out
            self.stats.result_cache_misses += B

        # per-lane warm bookkeeping, same rules as query()
        entries, use_warms, wkeys = [], [], []
        for pc in params_cs:
            entry = wkey = None
            if program.monotone:
                wkey = (pkey, _params_fingerprint(pc))
                entry = self._warm.get(wkey)
                if entry is not None:
                    self._warm.move_to_end(wkey)
            if warm is True:
                if not program.monotone:
                    raise ValueError(
                        f"warm=True: {type(program).__name__} is not "
                        "monotone")
                if entry is None:
                    raise ValueError(
                        "warm=True but a lane has no cached converged "
                        "result; use warm='auto'")
            wkeys.append(wkey)
            entries.append(entry)
            use_warms.append(entry is not None and warm in ("auto", True))

        self.stats.queries += B
        self.stats.batches += 1
        self.stats.batched_queries += B
        warm_in = bool(program.monotone)
        Bp = 1 << (B - 1).bit_length()           # power-of-2 batch bucket
        pad = Bp - B
        params_pad = params_cs + [params_cs[0]] * pad
        batched_params = jax.tree.map(lambda *ls: jnp.stack(ls), *params_pad)
        args = (self.device_graph(),)
        if eb != "coo":
            args += (self._layout_arg(program, eb, cfg),)
        args += (batched_params,)
        if warm_in:
            blocks = [self._warm_arg(program, entries[i], use_warms[i])
                      for i in range(B)]
            blocks += [blocks[0]] * pad
            args += (jnp.stack(blocks),)
        compiled, compile_time, evicted = self._get_runner(
            program, pkey, batched_params, cfg, warm_in, args, eb, batch=Bp)
        t0 = time.perf_counter()
        out = self._launch(compiled, args, compile_time)
        self.stats.device_launches += 1
        res_b, steps_b, msgs_b, sweeps_b = jax.block_until_ready(out)
        wall = time.perf_counter() - t0

        results = []
        for i in range(B):
            res = np.asarray(res_b[i])
            st = self._execution_stats(
                program, cfg, int(steps_b[i]), int(msgs_b[i]),
                np.asarray(sweeps_b[i]), wall, compile_time, eb)
            st.evicted_runners = evicted
            st.batch_size = B
            if use_warms[i]:
                self.stats.warm_queries += 1
            if program.monotone:
                self._remember(program, wkeys[i], res, st.supersteps)
            if use_rc:
                st.result_cache_tier = "miss"
                self.result_cache.put(rkeys[i], dict(
                    results=res, supersteps=st.supersteps, edge_backend=eb))
            results.append((res, st))
        return results

    def result_key_for(self, program: VertexProgram, params=None,
                       cfg: Optional[EngineConfig] = None) -> str:
        """The tiered result-cache key ``query`` would consult for this
        request right now (tenant + current graph version + normalized
        config) — the batcher's fast path peeks it before queueing."""
        cfg = self._normalize_cfg(cfg or self.cfg)
        _, cfg = normalize_edge_backend(program, cfg)
        return _result_key(self.tenant, self._host_version, program,
                           _canonical_params(params), cfg)

    def _n_edge_shards(self, cfg) -> int:
        if cfg.backend != "shard_map" or not cfg.edge_axes \
                or self.mesh is None:
            return 1
        return int(np.prod([self.mesh.shape[a] for a in cfg.edge_axes]))

    def _resolve_assignment(self, program, cfg) -> tuple:
        """The per-partition backend assignment a ``'auto'`` query runs
        with, PINNED per (padded-shape, layout-capacity) bucket: the policy
        is consulted once when a bucket combination is first seen, and every
        later query in the same buckets reuses the pick even though the
        measured densities drift with streaming growth — that is the
        zero-retrace guarantee ('auto' never flips a backend mid-bucket).
        Bucket crossings (flush past a capacity, compact, rebalance)
        naturally re-resolve under their new key."""
        lay = self.pg.ensure_edge_layouts(shape_policy=self.shape_policy)
        key = (self.shape_key, lay.shape_key("pallas_tiles"),
               lay.shape_key("pallas_windows"))
        asg = self._auto_pin.get(key)
        if asg is None:
            asg = resolve_partition_backends(program, cfg, self.pg, lay=lay)
            self._auto_pin[key] = asg
        return asg

    def _layout_arg(self, program, eb, cfg):
        """Device layout pytree for a Pallas-backend query — an explicit
        runner input (like params), so the executable survives layout
        content changes and retraces only when the layout *capacities*
        cross a bucket (a new layout shape-key). ``'auto'`` passes the
        mixed-backend blocks (group-sliced pair on the simulator, full
        blocks + per-partition backend ids under shard_map); edge-axis
        sharding passes the per-shard geometry."""
        lay = self.pg.ensure_edge_layouts(shape_policy=self.shape_policy)
        ns = self._n_edge_shards(cfg)
        if eb == "auto":
            asg = self._resolve_assignment(program, cfg)
            if cfg.backend == "shard_map":
                return _auto_layout_blocks(lay, self.pg, program, asg,
                                           mixed_shard=True, n_shards=ns)
            return _auto_layout_blocks(lay, self.pg, program, asg)
        return _layout_block_from(lay, self.pg, program, eb, n_shards=ns)

    def _layout_key(self, program, eb, cfg):
        if eb == "coo":
            return None
        lay = self.pg.edge_layouts
        if lay is None:
            return None
        ns = self._n_edge_shards(cfg)
        if eb == "auto":
            # the pinned assignment joins the key: a re-resolution that
            # lands on different picks must compile a fresh runner (group
            # composition is baked into the traced argument structure)
            asg = self._resolve_assignment(program, cfg)
            return ("auto", asg,
                    lay.shape_key("pallas_tiles", n_shards=ns, pg=self.pg),
                    lay.shape_key("pallas_windows", n_shards=ns, pg=self.pg))
        return lay.shape_key(eb, n_shards=ns, pg=self.pg)

    def _sync_warm_entry(self, entry: _WarmEntry) -> None:
        """Apply the pending remap chain to this entry's device block (lazy
        counterpart of the old eager per-flush remap): every insert-only
        flush / compaction since the entry was last touched is replayed in
        order. Entries never queried again never pay for any flush."""
        if entry.device_block is None \
                or entry.device_epoch == self._warm_epoch:
            return
        for ep, st in self._remap_log:
            if ep > entry.device_epoch:
                entry.device_block = st.remap_state(entry.device_block,
                                                    fill=entry.identity)
                self.stats.warm_remaps_applied += 1
        entry.device_epoch = self._warm_epoch
        self._sync_warm_bytes()

    def _prune_remap_log(self) -> None:
        """Drop log entries every live device block is already past. The
        log length is bounded by the slowest-moving warm entry; clearing
        the warm memory (deleting flush, evictions) empties it."""
        blocks = [e.device_epoch for e in self._warm.values()
                  if e.device_block is not None]
        if not blocks:
            self._remap_log.clear()
            return
        floor = min(blocks)
        self._remap_log = [(ep, st) for ep, st in self._remap_log
                           if ep > floor]

    def _sync_warm_bytes(self) -> None:
        self.stats.warm_cache_bytes = sum(e.nbytes
                                          for e in self._warm.values())

    def _warm_arg(self, program, entry, use_warm):
        """[P, v_max, K] warm block: the cached result when warming, the
        combiner identity (a structural no-op for ``warm_init``) when cold —
        so both paths share one compiled runner."""
        pg = self.pg
        K = program.payload
        if not use_warm:
            # constant per (shapes, dtype, identity): keep it resident so
            # repeated cold queries skip the rebuild + host->device transfer
            ikey = (pg.n_parts, pg.v_max, K, str(np.dtype(program.dtype)),
                    float(program.identity))
            blk = self._identity_blocks.get(ikey)
            if blk is None:
                blk = jnp.full((pg.n_parts, pg.v_max, K), program.identity,
                               dtype=program.dtype)
                self._identity_blocks[ikey] = blk
            return blk
        self._sync_warm_entry(entry)
        blk = entry.device_block
        if blk is not None and blk.shape == (pg.n_parts, pg.v_max, K):
            return jnp.asarray(blk)
        return jnp.asarray(_warm_block(program, pg, entry.global_values))

    def _launch(self, compiled, args, compile_time):
        """Execute an AOT runner. With ``debug_sanitize`` armed, a *cache
        hit* (``compile_time == 0``) runs under ``retrace_guard``: the
        executable was traced long ago, so any tracer activity during the
        launch is a retrace bug and raises ``RetraceError`` (or warns for
        ``debug_sanitize="warn"``). Fresh compiles are exempt — their trace
        already happened, legitimately, inside ``_get_runner``."""
        if not self.debug_sanitize or compile_time > 0.0:
            return compiled(*args)
        from repro.analysis.sanitizer import retrace_guard
        action = "warn" if self.debug_sanitize == "warn" else "raise"
        with retrace_guard(action=action,
                           label=f"GraphSession[{self.tenant}] cache-hit "
                                 f"launch"):
            return compiled(*args)

    def _get_runner(self, program, pkey, params_c, cfg, warm_in, args, eb,
                    batch=0):
        """AOT-compile (trace + lower + compile, once) or fetch the cached
        executable for this (program, param structure, config, shapes).
        Returns ``(compiled, compile_time, n_lru_evictions)``; a hit
        refreshes the entry's LRU position. Runners are built against the
        bucketed ``slot_capacity``, not the exact ``pg.n_slots``; Pallas
        runners additionally key on the layout capacities (``shape_key`` of
        the ``EdgeLayouts``), which are bucketed and grow-only too.

        The cache may be shared across sessions (``SessionPool``): keys
        carry shapes and never the tenant, so a same-bucket lookup by a
        different tenant hits the same entry — that is the cross-tenant
        executable sharing. ``batch`` (a padded lane count from
        ``query_batch``) joins the key explicitly so a batched runner can
        never collide with a singleton runner whose params genuinely carry
        a leading axis of the same length."""
        lkey = self._layout_key(program, eb, cfg)
        full_shape = (self.shape_key, lkey)
        key = (pkey, _params_struct_key(params_c), cfg, full_shape, warm_in)
        if batch:
            key = key + (("batch", batch),)
        hit = self._runner_cache.lookup(key, self.tenant)
        if hit is not None:
            self.stats.cache_hits += 1
            return hit.compiled, 0.0, 0
        self.stats.cache_misses += 1
        n_slots = self.slot_capacity
        asg = self._resolve_assignment(program, cfg) if eb == "auto" \
            else None
        t0 = time.perf_counter()
        if cfg.backend == "sim":
            fn = make_sim_runner(program, cfg, n_slots, warm_start=warm_in,
                                 batch=bool(batch), partition_backends=asg)
            compiled = jax.jit(fn).lower(*args).compile()
        else:
            self._check_mesh(cfg)
            go = make_bsp_runner(program, self.mesh, cfg, n_slots,
                                 params=params_c,
                                 has_vlabel=self.pg.vlabel is not None,
                                 warm_start=warm_in, params_as_input=True,
                                 batch=bool(batch), partition_backends=asg)
            # session args are (sgs[, lay], params[, warm]); the shard
            # runner wants (sgs[, lay][, warm], params) — reorder inside
            # the jitted wrapper
            n_pre = 2 if eb != "coo" else 1
            with self.mesh:
                compiled = jax.jit(
                    lambda *a: go(*(a[:n_pre] + a[n_pre + 1:]
                                    + (a[n_pre],)))
                ).lower(*args).compile()
        compile_time = time.perf_counter() - t0
        self.stats.compile_time_total += compile_time
        entry = _RunnerEntry(
            compiled=compiled, shape_key=full_shape,
            program=type(program).__name__, compile_time=compile_time,
            nbytes=_runner_nbytes(compiled))
        evicted = self._runner_cache.insert(key, entry, self.tenant)
        if evicted:
            self.stats.cache_evictions_lru += evicted
            self._prune_keepalive()
        self._sync_runner_bytes()
        return compiled, compile_time, evicted

    def _sync_runner_bytes(self) -> None:
        self.stats.runner_cache_bytes = self._runner_cache.total_bytes

    def _evict_lru(self, cache: OrderedDict, bound: Optional[int],
                   counter: str, max_bytes: Optional[int] = None) -> int:
        """Pop least-recently-used entries until ``cache`` fits ``bound``
        AND its estimated bytes fit ``max_bytes`` (the most recent entry is
        never evicted — a single over-budget entry must still serve),
        billing the named ``SessionStats`` counter and releasing any
        program pins the evictions orphaned."""
        evicted = 0
        if bound is not None:
            while len(cache) > bound:
                cache.popitem(last=False)
                evicted += 1
        if max_bytes is not None:
            total = sum(e.nbytes for e in cache.values())
            while total > max_bytes and len(cache) > 1:
                _, e = cache.popitem(last=False)
                total -= e.nbytes
                evicted += 1
        if evicted:
            setattr(self.stats, counter,
                    getattr(self.stats, counter) + evicted)
            self._prune_keepalive()
        return evicted

    def _prune_keepalive(self) -> None:
        """Release id-keyed program pins whose id no longer appears in any
        runner-cache or warm-memory key: once nothing can look the id up,
        the id-reuse hazard the pin guards against is gone, and keeping the
        object would leak host memory on a bounded cache."""
        if not self._keepalive:
            return
        live = {k[0][1] for k in self._runners} | \
               {wk[0][1] for wk in self._warm}
        self._keepalive = {i: p for i, p in self._keepalive.items()
                           if i in live}

    def _check_mesh(self, cfg: EngineConfig):
        sub = tuple(cfg.subgraph_axes)
        edge = tuple(cfg.edge_axes)
        n_sub = int(np.prod([self.mesh.shape[a] for a in sub]))
        n_edge = int(np.prod([self.mesh.shape[a] for a in edge])) \
            if edge else 1
        assert self.pg.n_parts == n_sub, (self.pg.n_parts, n_sub)
        assert self.pg.e_max % n_edge == 0, \
            "pad edges to a multiple of the edge axes"

    def _execution_stats(self, program, cfg, steps, msgs, sweeps, wall,
                         compile_time, eb="coo") -> ExecutionStats:
        pg = self.pg
        K = program.payload
        itemsize = np.dtype(program.dtype).itemsize
        # bytes are billed on the bucketed exchange height the runner
        # actually reduces, not the exact n_slots
        n_slots = self.slot_capacity
        if cfg.backend == "sim":
            total_bytes = steps * (n_slots + 1) * K * itemsize * pg.n_parts
        else:
            n_edge = int(np.prod([self.mesh.shape[a]
                                  for a in cfg.edge_axes])) \
                if cfg.edge_axes else 1
            total_bytes = steps * _exchange_bytes_per_step(
                cfg, n_slots, K, program.dtype, pg.n_parts, n_edge)
        lay = pg.edge_layouts
        sweeps64 = sweeps.astype(np.int64)
        epp = pg.edges_per_part.astype(np.int64)
        ns = self._n_edge_shards(cfg)
        asg = self._resolve_assignment(program, cfg) if eb == "auto" \
            else None
        flops_pp = sweeps64 * _flops_per_sweep(program, eb, pg, lay,
                                               assignment=asg,
                                               n_edge_shards=ns)
        tot_flops = int(flops_pp.sum())
        # per-shard sweep time: the launch wall time apportioned by each
        # shard's flops share (shards run lock-step supersteps, so the
        # flops skew IS the critical-path skew the monitor cares about)
        share = (flops_pp / tot_flops if tot_flops
                 else np.full(pg.n_parts, 1.0 / max(pg.n_parts, 1)))
        st = ExecutionStats(
            supersteps=steps, total_messages=msgs,
            processed_edges=int((sweeps64 * epp).sum()),
            total_bytes=total_bytes, wall_time=wall,
            compile_time=compile_time, edge_backend=eb,
            backend_flops=tot_flops,
            partition_edge_counts=[int(x) for x in epp],
            partition_flops=[int(x) for x in flops_pp],
            partition_sweep_time=[float(x) for x in wall * share])
        if eb in ("pallas_tiles", "auto") and lay is not None:
            spec = program.sweep_spec
            st.tile_density = lay.density(pg, spec.semiring,
                                          spec.edge_values, program.dtype)
            dens = lay.partition_density(pg, spec.semiring,
                                         spec.edge_values, program.dtype)
            st.partition_tile_density = [float(x) for x in dens]
            self.stats.tile_density_min = float(dens.min())
            self.stats.tile_density_mean = float(dens.mean())
            self.stats.tile_density_max = float(dens.max())
        if asg is not None:
            st.partition_edge_backends = list(asg)
        # surface the load gauges on SessionStats (EWMA for the measured
        # signal) and feed the monitor's measured-work input
        self.stats.partition_edge_counts = list(st.partition_edge_counts)
        prev = self.stats.partition_sweep_time
        cur = st.partition_sweep_time
        if len(prev) != len(cur):
            self.stats.partition_sweep_time = list(cur)
        else:
            a = self.monitor.cfg.ema if self.monitor is not None else 0.5
            self.stats.partition_sweep_time = [
                a * n + (1.0 - a) * o for n, o in zip(cur, prev)]
        if self.monitor is not None:
            self.monitor.observe_query(st)
            self.stats.load_imbalance = self.monitor.gauge
        return st

    def _remember(self, program, wkey, res, supersteps):
        """Cache this converged result as the warm seed for the next
        identical query (padded rows sanitized to the combiner identity),
        evicting the least-recently-used result beyond
        ``max_warm_entries`` — the bound that keeps warm host memory and
        the per-flush remap cost independent of how many distinct queries
        the session has ever served."""
        pg = self.pg
        blk = res if res.ndim == 3 else res[..., None]
        blk = np.where(pg.vmask[..., None], blk,
                       np.asarray(program.identity, blk.dtype))
        self._warm[wkey] = _WarmEntry(
            global_values=pg.collect(res, fill=program.identity),
            device_block=blk, identity=program.identity,
            supersteps=supersteps, device_epoch=self._warm_epoch,
            polarity=program.warm_under)
        self._warm.move_to_end(wkey)
        self._evict_lru(self._warm, self.max_warm_entries, "warm_evictions",
                        max_bytes=self.max_warm_bytes)
        self._prune_remap_log()
        self._sync_warm_bytes()

    # ------------------------------------------------------------------ #
    # streaming lifecycle
    # ------------------------------------------------------------------ #
    def _require_buffer(self, what: str) -> DeltaBuffer:
        self._check_open()
        if self.buffer is None:
            raise ValueError(
                f"{what} needs a StreamContext (this session was opened "
                "from a bare PartitionedGraph, or with a non-streamable "
                "partitioner); use GraphSession.from_graph/from_edge_log "
                "with a pure routing partitioner, or pass ctx=")
        return self.buffer

    def update(self, adds=None, deletes=None) -> None:
        """Enqueue edge mutations. ``adds`` is ``(src, dst)`` or
        ``(src, dst, w)`` (array-likes of global ids), ``deletes`` is
        ``(src, dst)``; an ``EdgeDelta`` is accepted for either role via
        ``push``. Ops coalesce in the internal ``DeltaBuffer`` and are
        applied on ``flush()`` (or automatically when a buffer threshold
        trips — the session notices either way)."""
        buf = self._require_buffer("update()")
        if isinstance(adds, EdgeDelta) or isinstance(deletes, EdgeDelta):
            raise TypeError("pass an EdgeDelta through session.push()")
        if deletes is not None:
            buf.delete(*deletes[:2])
        if adds is not None:
            buf.add(*adds[:3])

    def push(self, delta: EdgeDelta) -> None:
        """Enqueue a whole producer ``EdgeDelta`` (deletes-then-adds)."""
        self._require_buffer("push()").push(delta)

    def flush(self) -> Optional[DeltaStats]:
        """Apply every buffered mutation as one coalesced patch. Returns the
        applied patch's ``DeltaStats`` — if a buffer threshold already
        auto-flushed everything during ``update``, the stats of that last
        applied patch (never None once any patch has been applied; None only
        when nothing was ever buffered). The device pytree refreshes lazily
        on the next query; compiled runners survive unless the padded shapes
        crossed a bucket boundary."""
        buf = self._require_buffer("flush()")
        st = buf.flush()
        return st if st is not None else buf.last_flush

    def _on_flush(self, st: DeltaStats) -> None:
        self._host_version += 1
        self.stats.flushes += 1
        # A warm entry survives a flush only when the applied patch matches
        # its program's declared polarity (VertexProgram.warm_under):
        # 'inserts' entries survive insert-only patches (no delete was even
        # attempted — the historical warm_start_safe bit), 'deletes' entries
        # survive patches that added no edge. Membership is grow-only under
        # both, so one shared remap log serves whichever side survives.
        keep = {"inserts": st.warm_start_safe, "deletes": st.n_added == 0}
        if any(keep.values()):
            # Local rows reshuffle (and v_max may cross a bucket), but the
            # remap is only LOGGED here — each warm entry replays the
            # pending chain on its next use (_sync_warm_entry), so a flush
            # costs O(1) regardless of warm occupancy and entries that are
            # never queried again never pay at all.
            self._warm_epoch += 1
            self._remap_log.append((self._warm_epoch, st))
        if not all(keep.values()):
            # the patch loosened values for the other polarity: those
            # cached results are not sound anymore
            for wkey in [k for k, e in self._warm.items()
                         if not keep.get(e.polarity, False)]:
                del self._warm[wkey]
        self._prune_remap_log()
        self._sync_warm_bytes()
        self._evict_stale_runners()
        # streaming churn drives the load monitor; under rebalance="auto" a
        # tripped hysteresis gauge migrates right here, before the flush's
        # caller sees the new graph version
        if self.monitor is not None and not self._rebalancing:
            self.stats.load_imbalance = self.monitor.observe_graph(self.pg)
            if (self._rebalance_mode == "auto"
                    and self.monitor.should_rebalance()):
                self.rebalance()

    def rebalance(self, *, target: Optional[float] = None
                  ) -> Optional[RebalanceStats]:
        """Migrate boundary edges off overloaded partitions
        (docs/PARTITIONING.md). Plans a minimal cheapest-first move set
        (``repro.partition.rebalance``), executes it through the same
        ``repack_partitions`` remap machinery as ``compact`` — warm results
        ride the remap chain, in-bucket runners survive, the version bump
        invalidates result-cache entries — and records the moved pairs in
        the routing context so later deletes/re-adds find them. Returns
        the ``RebalanceStats``, or None when the plan is empty (already
        balanced). Needs a ``StreamContext`` like every mutation path."""
        self._check_open()
        self._require_buffer("rebalance()")
        if self._rebalancing:
            return None
        self._rebalancing = True
        try:
            if len(self.buffer):
                self.flush()
            # donor selection weights by the monitor's BLENDED load vector
            # (measured sweep time + frontier churn, not just edge counts)
            # when one is live — the moved objects are still edges
            loads = self.monitor.blended_loads(self.pg.n_parts) \
                if self.monitor is not None else None
            plan = plan_rebalance(
                self.pg, target=self.rebalance_target
                if target is None else target, loads=loads)
            if plan.n_moves == 0:
                return None
            rs = execute_rebalance(self.pg, self.ctx, plan,
                                   shape_policy=self.shape_policy)
            self._host_version += 1
            self.stats.rebalances += 1
            # migration deliberately reshaped the per-partition densities:
            # drop the pinned 'auto' assignments so the next query
            # re-consults the policy against the new geometry
            self._auto_pin.clear()
            # migration changes layout (membership moved), never values:
            # joins the pending-remap chain exactly like a compaction
            self._warm_epoch += 1
            self._remap_log.append((self._warm_epoch, rs))
            self._prune_remap_log()
            self._evict_stale_runners()
            if self.monitor is not None:
                self.monitor.notify_rebalanced()
                self.stats.load_imbalance = self.monitor.observe_graph(
                    self.pg)
            return rs
        finally:
            self._rebalancing = False

    def compact(self) -> CompactStats:
        """Evict edge-less members, shrink the padded capacities to the
        session policy's **bucket floor**, and carry every cached warm
        result across the re-layout (global values are layout-independent;
        device blocks move through ``remap_state``). When the compacted
        content still fits the current buckets the padded shapes — and every
        compiled runner — survive untouched."""
        self._check_open()
        if self.ctx is None:
            self._require_buffer("compact()")
        if self.buffer is not None and len(self.buffer):
            self.flush()
        cs = _compact_pg(self.pg, self.ctx, shape_policy=self.shape_policy)
        self._host_version += 1
        self.stats.compactions += 1
        self._auto_pin.clear()     # compaction re-lays the geometry: let
                                   # the next 'auto' query re-resolve
        # compaction changes layout, never values: joins the pending-remap
        # chain like an insert-only flush (applied on each entry's next use)
        self._warm_epoch += 1
        self._remap_log.append((self._warm_epoch, cs))
        self._prune_remap_log()
        self._evict_stale_runners()
        return cs

    def _evict_stale_runners(self) -> None:
        """Drop executables specialized to padded shapes the graph no longer
        has (bucket growth via flush, bucket shrink via compact). Any patch
        that stays inside the current buckets evicts nothing — the whole
        point of the bucketed cache. Pallas runners also check their layout
        capacities: a tile/block cap crossing its bucket stales only the
        runners of that backend, never the COO ones.

        On a shared cache this RELEASES the session's pins rather than
        deleting entries outright: a tenant crossing a bucket must never
        invalidate the runners its same-shaped neighbors still serve from.
        Entries nobody pins anymore are dropped; on a private cache that is
        every stale entry — exactly the old behavior."""
        cur = self.shape_key
        lay = self.pg.edge_layouts
        have_lay = lay is not None and lay.matches(self.pg)

        def lay_key_now(backend, ns):
            # the entry's layout key recomputed against the CURRENT layout
            # at the entry's own shard count; None (can't realize, e.g.
            # e_max no longer divides the shards) means stale
            try:
                return lay.shape_key(backend, n_shards=ns, pg=self.pg)
            except AssertionError:
                return None

        def stale_entry(e):
            base, lkey = e.shape_key
            if base != cur:
                return True
            if lkey is None:
                return False
            if not have_lay:
                return True
            if lkey[0] == "auto":
                _, asg, tk, wk = lkey
                ns = tk[1] if len(tk) == 5 else 1
                if tk != lay_key_now("pallas_tiles", ns) \
                        or wk != lay_key_now("pallas_windows", ns):
                    return True
                # a re-resolved pin that landed on different picks stales
                # the old mixed-backend executable
                pin = self._auto_pin.get(
                    (cur, lay.shape_key("pallas_tiles"),
                     lay.shape_key("pallas_windows")))
                return pin is not None and pin != asg
            ns = lkey[1] if len(lkey) == 5 else 1
            backend = "pallas_tiles" if lkey[0] == "tiles" \
                else "pallas_windows"
            return lkey != lay_key_now(backend, ns)

        released = self._runner_cache.release_stale(self.tenant, stale_entry)
        self.stats.cache_evictions_shape += released
        self._sync_runner_bytes()
        # flush/compact may also have dropped warm entries — release any
        # id-keyed program pins nothing references anymore
        self._prune_keepalive()
        self._identity_blocks = {
            k: v for k, v in self._identity_blocks.items()
            if k[:2] == (self.pg.n_parts, self.pg.v_max)}

    # ------------------------------------------------------------------ #
    # introspection
    # ------------------------------------------------------------------ #
    def cache_info(self) -> list:
        """Snapshot of the compiled-runner cache in LRU order (oldest —
        next to be evicted — first): one dict per entry with the program
        type name, the (padded-shape, layout) key it was specialized to,
        its hit count, what its compilation cost, the estimated device
        bytes it pins (what ``max_runner_bytes`` evicts against), and the
        tenants pinning it (``owners`` — more than one on a pool-shared
        cache)."""
        return self._runner_cache.info()
